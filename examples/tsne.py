"""End-to-end driver (the paper's application kind, §3.1): t-SNE on a
synthetic high-dimensional mixture, with the attractive force computed
through the paper's pipeline — kNN graph -> dual-tree reorder -> two-level
ELL-BSR -> blockwise-dense iterative interactions. Repulsive forces are
exact (small N).

The interaction *values* (affinities P) are fixed, but the cluster
structure lives in the moving low-dimensional embedding — so the plan is
ordered by the embedding coordinates and ``plan.refresh`` re-buckets it
periodically in the inner loop: as the embedding separates, the refreshed
ordering concentrates the fixed pattern into dense patches (γ rises),
exactly the paper's locality story measured live.

  PYTHONPATH=src python examples/tsne.py [--n 1024] [--iters 300]
       [--force-backend pallas]   # fused Mosaic tsne_force kernel
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import knn
from repro.data.pipeline import feature_mixture


def p_matrix(x, k, perplexity=30.0):
    """Symmetrized kNN-restricted affinities with per-point bandwidth."""
    n = x.shape[0]
    idx, d2 = knn.knn_graph(jnp.asarray(x), jnp.asarray(x), k,
                            exclude_self=True)
    d2 = np.asarray(d2)
    idx = np.asarray(idx)
    # binary-search bandwidths to hit the target perplexity
    p = np.zeros_like(d2)
    target = np.log(perplexity)
    for i in range(n):
        lo, hi = 1e-10, 1e10
        for _ in range(40):
            beta = np.sqrt(lo * hi)
            w = np.exp(-d2[i] * beta)
            s = w.sum() + 1e-30
            h = np.log(s) + beta * (d2[i] * w).sum() / s
            if h > target:
                lo = beta
            else:
                hi = beta
        p[i] = w / s
    rows = np.repeat(np.arange(n), k)
    cols = idx.ravel()
    vals = p.ravel()
    # symmetrize
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    v2 = np.concatenate([vals, vals]) / (2 * n)
    key = r2.astype(np.int64) * n + c2
    order = np.argsort(key, kind="stable")
    key, r2, c2, v2 = key[order], r2[order], c2[order], v2[order]
    uniq, start = np.unique(key, return_index=True)
    sums = np.add.reduceat(v2, start)
    return r2[start], c2[start], sums.astype(np.float32)


@jax.jit
def repulsive(y):
    d2 = jnp.sum((y[:, None] - y[None]) ** 2, -1)
    q = 1.0 / (1.0 + d2)
    q = q.at[jnp.arange(len(y)), jnp.arange(len(y))].set(0.0)
    z = q.sum()
    f = jnp.einsum("ij,ijd->id", q * q / z, y[:, None] - y[None])
    return f, q / z


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1024)
    ap.add_argument("--iters", type=int, default=300)
    ap.add_argument("--k", type=int, default=24)
    ap.add_argument("--refresh-every", type=int, default=50)
    ap.add_argument("--force-backend", default=None,
                    choices=[None, "pallas"],
                    help="attractive-force kernel: default XLA blockwise "
                         "path, or the fused Mosaic tsne_force kernel "
                         "(interpret mode on CPU)")
    args = ap.parse_args()

    n, k = args.n, args.k
    labels = np.repeat(np.arange(8), n // 8)
    x = feature_mixture(n, 128, n_clusters=8, seed=1)
    # regenerate with labels aligned: one cluster per label block
    rng = np.random.default_rng(1)
    basis = rng.standard_normal((8, 128)) / np.sqrt(8)
    centers = rng.standard_normal((8, 8)) @ basis * 3.0
    x = (centers[labels] + 0.15 * rng.standard_normal((n, 128))
         ).astype(np.float32)

    print("building P (kNN affinities)...")
    rows, cols, pvals = p_matrix(x, k)

    print("planning (embedding-ordered ELL-BSR, refreshed as it moves)...")
    y0 = (0.01 * rng.standard_normal((n, 2))).astype(np.float32)
    # the ordering coordinates are the *moving* t-SNE embedding: the plan
    # starts on noise and plan.refresh re-buckets it as clusters form
    plan = api.InteractionPlan.from_coo(rows, cols, pvals, n, x=y0, d=2,
                                        ordering="dual_tree", bs=32, sb=8)
    # reorder points/labels so vectors are cluster-contiguous (paper §2.4)
    labels_s = plan.permute(labels)
    print(f"  {plan}")

    y = jnp.asarray(plan.permute(y0))
    lr, mom = float(n) / 12.0, 0.5
    vel = jnp.zeros_like(y)
    t0 = time.time()
    for it in range(args.iters):
        f_attr = plan.tsne_attractive(y, backend=args.force_backend)
        f_rep, _ = repulsive(y)
        exagg = 4.0 if it < 100 else 1.0
        grad = 4.0 * (exagg * f_attr - f_rep)
        vel = mom * vel - lr * grad
        y = y + vel
        y = y - y.mean(0)
        if it == 120:
            mom = 0.8
        if (it + 1) % args.refresh_every == 0:
            # lifecycle refresh: re-bucket the ordering around the current
            # embedding; state vectors migrate to the new cluster order
            y_o = plan.unpermute(np.asarray(y))
            v_o = plan.unpermute(np.asarray(vel))
            plan = plan.refresh(y_o)
            y = jnp.asarray(plan.permute(y_o))
            vel = jnp.asarray(plan.permute(v_o))
            labels_s = plan.permute(labels)
            st = plan.refresh_stats
            print(f"iter {it:4d} refresh: {st.last_action:8s} "
                  f"migrated={st.last_migrated_frac:5.2f} "
                  f"gamma={plan.gamma:6.2f} fill={plan.fill:.3f}")
        if it % 100 == 0 or it == args.iters - 1:
            # cluster separation: mean intra vs inter distance in embedding
            yn = np.asarray(y)
            intra = np.mean([np.var(yn[labels_s == c], axis=0).sum()
                             for c in range(8)])
            inter = np.var(yn, axis=0).sum()
            print(f"iter {it:4d} separation={inter/max(intra,1e-9):8.2f}")
    print(f"{args.iters} iterations in {time.time()-t0:.1f}s")
    yn = np.asarray(y)
    intra = np.mean([np.var(yn[labels_s == c], axis=0).sum()
                     for c in range(8)])
    inter = np.var(yn, axis=0).sum()
    assert inter / intra > 5, "clusters failed to separate"
    print(f"final separation {inter/intra:.1f}x — clusters separated OK")


if __name__ == "__main__":
    main()
