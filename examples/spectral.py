"""Spectral embedding of a planted clustering, matrix-free on the plan.

  PYTHONPATH=src python examples/spectral.py [--n 4096]

Builds the KDE-weighted similarity graph over a Gaussian mixture (the
plan's symmetrized kNN pattern, RBF-dressed edges), then extracts the top
eigenvectors of the degree-normalized similarity ``D^-1/2 W D^-1/2`` with
Lanczos — every spectral step is a ``plan.apply`` matvec, the similarity
matrix is never materialized.

The embedding is scored by how well single-linkage thresholding of the
spectral coordinates recovers the planted mixture components: with
``n_components >= #clusters - 1`` the leading eigenvectors are nearly
piecewise-constant on the components, so k-means-free nearest-centroid
labeling already matches the plant.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.solvers import spectral_embedding  # noqa: E402


def planted_mixture(n, d, c, seed=0, spread=0.45):
    """Gaussian mixture WITH its labels (``data.pipeline.feature_mixture``
    shuffles its components away). The spread is chosen so neighboring
    clusters stay weakly *bridged*: a fully disconnected similarity graph
    has eigenvalue 1 with multiplicity c, and a single-vector Krylov
    method cannot split a degenerate eigenspace — near-1-but-distinct
    eigenvalues are the honest regime for Lanczos spectral embedding."""
    rng = np.random.default_rng(seed)
    centers = rng.standard_normal((c, d)).astype(np.float32)
    labels = rng.integers(0, c, size=n)
    x = centers[labels] + spread * rng.standard_normal((n, d))
    return x.astype(np.float32), labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=8)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--k", type=int, default=16)
    args = ap.parse_args()

    x, labels = planted_mixture(args.n, args.d, args.clusters, seed=0)

    t0 = time.perf_counter()
    # keep the (near-)trivial top eigenvector: on a c-cluster graph the
    # top c eigenvectors together carry the component indicators
    w, Y = spectral_embedding(x, n_components=args.clusters, k=args.k,
                              bs=32, sb=8, backend="bsr", drop_first=False)
    Y = np.asarray(Y)
    t1 = time.perf_counter()
    print(f"embedded {args.n} points -> {Y.shape[1]} spectral coords "
          f"in {t1 - t0:.3f}s; top eigenvalues {np.asarray(w).round(4)}")

    # Ng-Jordan-Weiss row normalization, then nearest planted centroid
    Y = Y / np.maximum(np.linalg.norm(Y, axis=1, keepdims=True), 1e-12)
    centroids = np.stack([Y[labels == c].mean(0)
                          for c in range(args.clusters)])
    d2 = ((Y[:, None, :] - centroids[None, :, :]) ** 2).sum(-1)
    pred = d2.argmin(1)
    acc = float((pred == labels).mean())
    print(f"planted-cluster recovery: {acc:.3f} "
          f"(chance {1.0 / args.clusters:.3f})")
    assert acc > 0.9, "spectral embedding failed to separate the plant"
    print("OK")


if __name__ == "__main__":
    main()
