"""Mean-shift case study (paper §3.2): iterative kernel-weighted mean
shifting over a fixed source set, targets migrating — the non-stationary
interaction case, driven through the plan *lifecycle*: one ``build_plan``
up front, then ``plan.refresh`` in the inner loop. The refresh policy
decides per step whether the moved targets need a cheap in-place pattern
patch, a stable partial re-bucket, or a full rebuild (the paper notes the
target-side clustering "needs not be updated as frequently" — here that
observation is a measured policy, not a hand-tuned stride).

  PYTHONPATH=src python examples/meanshift.py [--iters 30]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=30)
    args = ap.parse_args()

    n, d, k = 1024, 32, 32
    rng = np.random.default_rng(2)
    basis = rng.standard_normal((6, d)) / np.sqrt(6)
    centers = rng.standard_normal((6, 6)) @ basis * 4.0
    labels = rng.integers(0, 6, n)
    src = (centers[labels] + 0.4 * rng.standard_normal((n, d))
           ).astype(np.float32)
    t = src.copy()                      # targets start at the points
    h2 = 2.0

    # one plan for the whole run: kNN of the (moving) targets among the
    # fixed sources, dual-tree ordered, with ELL slack so migrated rows
    # can gain neighbor tiles in place
    plan = api.build_plan(t, k=k, sources=src, bs=32, ell_slack=2,
                          backend="bsr")
    print(f"initial {plan}")

    t0 = time.time()
    for it in range(args.iters):
        if it:
            plan = plan.refresh(t)
        t_s = plan.permute(t)
        src_s = plan.permute(src)
        t = np.asarray(plan.unpermute(
            plan.meanshift_step(jnp.asarray(t_s), jnp.asarray(src_s), h2)))
    dt = time.time() - t0
    st = plan.refresh_stats
    print(f"{args.iters} mean-shift iterations in {dt:.1f}s — refreshes: "
          f"{st.patches} patched ({st.patched_rows} rows), "
          f"{st.rebuckets} re-bucketed, {st.rebuilds} rebuilt")
    print(f"final γ drift vs lineage reference: {plan.gamma_drift():+.3f}")

    # targets should have collapsed near the 6 modes
    from scipy.cluster.vq import kmeans2
    modes, assign = kmeans2(t, 6, seed=0, minit="++")
    spread = np.mean([t[assign == c].std(0).mean() for c in range(6)
                      if (assign == c).any()])
    print(f"residual intra-mode spread: {spread:.4f} (start ~0.4)")
    assert spread < 0.1, "mean shift failed to converge to modes"
    print("converged to modes OK")


if __name__ == "__main__":
    main()
