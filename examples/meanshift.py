"""Mean-shift case study (paper §3.2): iterative kernel-weighted mean
shifting over a fixed source set, targets migrating — the non-stationary
interaction case. Neighbor pattern refreshed every few iterations (the
paper notes target-side clustering "needs not be updated as frequently").

  PYTHONPATH=src python examples/meanshift.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import knn
from repro.data.pipeline import feature_mixture


def main():
    n, d, k = 1024, 32, 32
    rng = np.random.default_rng(2)
    basis = rng.standard_normal((6, d)) / np.sqrt(6)
    centers = rng.standard_normal((6, 6)) @ basis * 4.0
    labels = rng.integers(0, 6, n)
    src = (centers[labels] + 0.4 * rng.standard_normal((n, d))
           ).astype(np.float32)

    # dual-tree ordering of the (fixed) sources: cluster-contiguous memory.
    # Ordering only (no pattern yet) — the interaction plans below are
    # rebuilt per pattern refresh in the already-ordered index space.
    pi = api.cluster_order(src, ordering="dual_tree")
    src_s = src[pi]
    t = src_s.copy()                    # targets start at the points
    h2 = 2.0

    t0 = time.time()
    for it in range(30):
        if it % 10 == 0:               # refresh neighbor pattern (cheap-ish)
            idx, _ = knn.knn_graph(jnp.asarray(t), jnp.asarray(src_s), k)
            rows = np.repeat(np.arange(n), k)
            cols = np.asarray(idx).ravel()
            plan = api.InteractionPlan.from_coo(rows, cols, None, n, bs=32)
        t = np.asarray(plan.meanshift_step(jnp.asarray(t), src_s, h2))
    dt = time.time() - t0

    # targets should have collapsed near the 6 modes
    from scipy.cluster.vq import kmeans2
    modes, assign = kmeans2(t, 6, seed=0, minit="++")
    spread = np.mean([t[assign == c].std(0).mean() for c in range(6)
                      if (assign == c).any()])
    print(f"30 mean-shift iterations in {dt:.1f}s")
    print(f"residual intra-mode spread: {spread:.4f} (start ~0.4)")
    assert spread < 0.1, "mean shift failed to converge to modes"
    print("converged to modes OK")


if __name__ == "__main__":
    main()
