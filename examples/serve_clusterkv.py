"""Serve a small model with batched requests, decoding with the paper's
cluster-sparse KV selection vs dense attention — the LM-side analog of the
paper's iterative near-neighbor interaction.

  PYTHONPATH=src python examples/serve_clusterkv.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig
from repro.models import model_api
from repro.train import trainer


def main():
    cfg = reduced_config("qwen2-0.5b").with_(
        clusterkv=ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                                  blocks_per_query=4, decode_clusters=4))
    key = jax.random.PRNGKey(0)
    params, _ = model_api.init(cfg, key)
    batch_size, prompt, gen = 4, 256, 32

    batch = model_api.make_small_batch(cfg, key, batch_size, prompt,
                                       kind="prefill")
    prefill = jax.jit(trainer.make_prefill_step(cfg, None, "flash"))

    results = {}
    for backend in ("flash", "clusterkv"):
        decode = jax.jit(trainer.make_decode_step(cfg, None, backend))
        cache, logits = prefill(params, batch)
        cache = dict(cache)
        for k in ("k", "v"):
            pads = [(0, 0)] * cache[k].ndim
            pads[-2] = (0, gen)
            cache[k] = jnp.pad(cache[k], pads)
        toks = jnp.argmax(logits, -1)[:, None]
        seqs = [toks]
        # warm up compile then time the loop
        first_logits, _ = decode(params, cache, {"tokens": toks})
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode(params, cache, {"tokens": toks})
            toks = jnp.argmax(logits, -1)[:, None]
            seqs.append(toks)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        results[backend] = (np.asarray(first_logits), dt)
        print(f"{backend:10s}: {gen} steps x {batch_size} seqs in {dt:.2f}s "
              f"({batch_size*gen/dt:.0f} tok/s)")

    a, b = results["flash"][0], results["clusterkv"][0]
    cos = float((a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b)))
    rel = float(np.linalg.norm(a - b) / np.linalg.norm(a))
    print(f"first-step logits: cosine {cos:.4f}, rel-L2 {rel:.3f} "
          f"(selection covers {4*32}/{prompt} keys; untrained weights)")


if __name__ == "__main__":
    main()
