"""Serve a small model with batched requests, decoding with the paper's
cluster-sparse KV selection vs dense attention — the LM-side analog of the
paper's iterative near-neighbor interaction. The cluster budget is not
hardcoded: ``core.autotune`` probes the prefilled key cache's coverage
curve (the γ-score idea of §2.3) and sizes ``blocks_per_query`` /
``decode_clusters`` to hit a target softmax-mass coverage.

  PYTHONPATH=src python examples/serve_clusterkv.py
"""
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig
from repro.core import autotune
from repro.models import model_api
from repro.train import trainer


def main():
    cfg = reduced_config("qwen2-0.5b").with_(
        clusterkv=ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                                  blocks_per_query=4, decode_clusters=4))
    key = jax.random.PRNGKey(0)
    params, _ = model_api.init(cfg, key)
    batch_size, prompt, gen = 4, 256, 32

    batch = model_api.make_small_batch(cfg, key, batch_size, prompt,
                                       kind="prefill")
    prefill = jax.jit(trainer.make_prefill_step(cfg, None, "flash"))

    # γ-guided budget autotune on the prefilled keys (self-coverage proxy)
    cache0, _ = prefill(params, batch)
    k0 = cache0["k"][0].astype(jnp.float32)          # (B, Hkv, S, dh)
    tuned, cov = autotune.tune_blocks_per_query(k0, k0, cfg.clusterkv,
                                                target_coverage=0.9)
    tuned = dataclasses.replace(tuned,
                                decode_clusters=max(tuned.blocks_per_query,
                                                    cfg.clusterkv.decode_clusters))
    print(f"autotuned cluster budget: blocks_per_query="
          f"{tuned.blocks_per_query}, decode_clusters="
          f"{tuned.decode_clusters} (est. coverage {cov:.2f})")
    cfg = cfg.with_(clusterkv=tuned)

    results = {}
    for backend in ("flash", "clusterkv"):
        decode = jax.jit(trainer.make_decode_step(cfg, None, backend))
        cache, logits = prefill(params, batch)
        cache = dict(cache)
        for k in ("k", "v"):
            pads = [(0, 0)] * cache[k].ndim
            pads[-2] = (0, gen)
            cache[k] = jnp.pad(cache[k], pads)
        toks = jnp.argmax(logits, -1)[:, None]
        seqs = [toks]
        # warm up compile then time the loop
        first_logits, _ = decode(params, cache, {"tokens": toks})
        t0 = time.time()
        for _ in range(gen - 1):
            logits, cache = decode(params, cache, {"tokens": toks})
            toks = jnp.argmax(logits, -1)[:, None]
            seqs.append(toks)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        results[backend] = (np.asarray(first_logits), dt)
        print(f"{backend:10s}: {gen} steps x {batch_size} seqs in {dt:.2f}s "
              f"({batch_size*gen/dt:.0f} tok/s)")

    a, b = results["flash"][0], results["clusterkv"][0]
    cos = float((a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b)))
    rel = float(np.linalg.norm(a - b) / np.linalg.norm(a))
    print(f"first-step logits: cosine {cos:.4f}, rel-L2 {rel:.3f} "
          f"(selection covers {4*32}/{prompt} keys; untrained weights)")


if __name__ == "__main__":
    main()
