"""Decode through the ClusterKV decode service — plans as serving state.

A batch of requests flows through ``repro.serve.ClusterKVEngine``: each
admission builds one ordering ``PlanBatch`` per layer over the prefilled
keys (``core.clusterkv.kv_plan_batch``, capacity = ``max_seq``), decode
runs over the PLAN-ORDERED cache, and every generated key streams into
the session's plans through the insert tier (Morton-leaf slot claim — no
per-step re-sort). Because all sessions unify to one ``PlanSpec``, the
whole run compiles exactly ONE decode kernel, and with a cluster budget
covering every tile the service decode is exact: the argmax tokens are
asserted to match a dense-attention engine token for token.

  PYTHONPATH=src python examples/serve_clusterkv.py
"""
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig
from repro.models import model_api
from repro.serve import ClusterKVEngine
from repro.train.serve_loop import Engine, Request


def make_requests(cfg, n, rng, max_new):
    return [Request(rid=i,
                    tokens=rng.integers(0, cfg.vocab,
                                        int(rng.integers(16, 60))
                                        ).astype(np.int32),
                    max_new=max_new)
            for i in range(n)]


def main():
    max_seq, slots, n_req, max_new = 256, 2, 6, 12
    # decode_clusters covers every tile (max_seq/block_k = 8), so the
    # sparse decode selects ALL live clusters -> exact attention; float32
    # so the dense-vs-service argmax comparison is not at the mercy of
    # bf16 rounding between differently-compiled but equivalent graphs
    cfg = reduced_config("qwen2-0.5b").with_(
        dtype="float32",
        clusterkv=ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                                  blocks_per_query=8, decode_clusters=8))
    key = jax.random.PRNGKey(0)
    params, _ = model_api.init(cfg, key)
    rng = np.random.default_rng(0)
    prompts = make_requests(cfg, n_req, rng, max_new)

    # dense-attention reference engine
    dense = Engine(cfg, params, slots=slots, max_seq=max_seq,
                   prefill_bucket=64, backend="flash")
    ref_reqs = [dataclasses.replace(r, output=[]) for r in prompts]
    for r in ref_reqs:
        dense.submit(r)
    t0 = time.time()
    dense.run()
    t_dense = time.time() - t0

    # the ClusterKV decode service: plan-cached continuous batching
    svc = ClusterKVEngine(cfg, params, slots=slots, max_seq=max_seq,
                          prefill_bucket=64, mode="plan", plan_prefill=True)
    svc_reqs = [dataclasses.replace(r, output=[]) for r in prompts]
    for r in svc_reqs:
        svc.submit(r)
    t0 = time.time()
    svc.run()
    t_svc = time.time() - t0

    for ref, got in zip(ref_reqs, svc_reqs):
        assert ref.output == got.output, (ref.rid, ref.output, got.output)
    print(f"service tokens match dense decode for all {n_req} requests ✓")

    rep = svc.report()
    assert rep["decode_traces"] == 1, rep["decode_traces"]
    assert rep["specs_seen"] == 1, rep["specs_seen"]
    print(f"admissions: {rep['counters']['admits']} "
          f"(slots={slots}, specs seen: {rep['specs_seen']}, "
          f"decode kernels compiled: {rep['decode_traces']})")
    print(f"insert tier: {rep['insert_tiers']['appends']} streamed appends, "
          f"{rep['counters']['flushed_edges']} kNN edges folded")
    print(f"wall: dense {t_dense:.2f}s, service {t_svc:.2f}s "
          f"(both include per-bucket prefill compiles)")


if __name__ == "__main__":
    main()
