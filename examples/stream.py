"""Streaming point sets: a plan serving a feed of arrivals and retirements.

  PYTHONPATH=src python examples/stream.py [--steps 20]

The paper's pipeline assumes a fixed point set; real neighborhood-graph
workloads ingest and retire points continuously. This example drives one
``InteractionPlan`` through sustained churn with the streaming tiers:

  tombstone   deletes flip the row-validity mask and re-dress only the
              row-blocks that referenced the dead points (broken edges
              are routed around the tombstone to the dead point's own
              surviving neighbors)
  append      inserts re-embed through the stored PCA map, claim the
              free slot nearest their Morton leaf, and land as row-block
              patches; rows whose kNN the arrival enters adopt it
  rebucket    a γ-drift guard re-sorts the slots by their maintained
              Morton codes when displaced inserts decay the ordering
  restripe    an ELL overflow (or whole-matrix churn) re-dresses the
              storage from the maintained COO at the kept ordering
  compact     dead capacity beyond PlanConfig.max_dead_frac triggers the
              full rebuild on the survivors — bit-exact with build_plan

Per step the plan serves a matvec; at the end the streamed plan is
compared against a from-scratch build on the surviving points.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.data.pipeline import feature_mixture


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--churn", type=float, default=0.02,
                    help="fraction of points replaced per step")
    args = ap.parse_args()

    n, d, k = args.n, 64, 16
    m = max(int(n * args.churn), 1)
    rng = np.random.default_rng(0)
    pool = feature_mixture(n + args.steps * m, d, n_clusters=16, seed=0)

    plan = api.build_plan(pool[:n], k=k, bs=32, sb=8, backend="bsr",
                          ell_slack=4, capacity=int(n * 1.1))
    _ = plan.gamma                      # arm the γ-drift rebucket guard
    print(f"built {plan}")

    feed = n
    charges = rng.standard_normal(plan.n).astype(np.float32)
    for step in range(args.steps):
        live = np.nonzero(plan.alive)[0]
        kill = rng.choice(live, m, replace=False)
        xin = pool[feed:feed + m]
        feed += m
        t0 = time.perf_counter()
        plan = api.update_plan(plan, insert=xin, delete=kill)
        dt = time.perf_counter() - t0
        if len(charges) != plan.n:      # capacity grew / plan compacted
            charges = np.resize(charges, plan.n)
        y = plan.matvec(jnp.asarray(charges))
        st = plan.refresh_stats
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {step:3d}: {st.last_action:9s} {dt*1e3:6.1f}ms  "
                  f"n={plan.n_alive}/cap={plan.capacity} "
                  f"dead={plan.dead_frac:.3f} |y|="
                  f"{float(jnp.linalg.norm(y)):.2f}")

    st = plan.refresh_stats
    print(f"\ntier telemetry after {args.steps} steps of "
          f"{2 * args.churn:.0%} churn:")
    print(f"  appends={st.appends} tombstones={st.tombstones} "
          f"rebuckets={st.rebuckets} restripes={st.restripes} "
          f"compactions={st.compactions} grows={st.grows}")
    print(f"  inserted={st.inserted_total} deleted={st.deleted_total}")

    fresh = api.build_plan(plan.host.x[plan.alive], config=plan.config)
    ratio = plan.gamma / fresh.gamma
    print(f"  streamed gamma {plan.gamma:.3f} vs fresh build "
          f"{fresh.gamma:.3f} (ratio {ratio:.3f})")
    assert 0.9 <= ratio <= 1.1, "streamed locality decayed"

    compacted = plan.compact()
    xv = jnp.asarray(rng.standard_normal(compacted.n), jnp.float32)
    assert np.array_equal(np.asarray(compacted.matvec(xv)),
                          np.asarray(fresh.matvec(xv)))
    print(f"  compact == fresh build on survivors (bit-exact), "
          f"{compacted}")


if __name__ == "__main__":
    main()
