"""Train a small LM end-to-end on CPU with the full framework stack
(config -> data pipeline -> train step -> checkpoint -> restart).

  PYTHONPATH=src python examples/train_lm.py [--steps 60]

Uses the qwen2 family at reduced size; demonstrates checkpoint/restart by
killing the loop halfway and resuming (the fault-tolerance contract).
"""
import argparse
import shutil
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import reduced_config
from repro.data import pipeline
from repro.models import model_api
from repro.optim.optimizers import make_optimizer
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = reduced_config("qwen2-0.5b").with_(n_layers=4, d_model=128,
                                             d_ff=512, n_heads=8,
                                             n_kv_heads=4)
    opt = make_optimizer("adamw", lr=1e-3, warmup=10, total=args.steps)
    step_fn, _ = trainer.make_train_step(cfg, None, "flash", optimizer=opt)
    step = jax.jit(step_fn, donate_argnums=(0, 1))

    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    print(f"params: {sum(x.size for x in jax.tree.leaves(params))/1e6:.2f}M")

    tmp = tempfile.mkdtemp()
    ck = Checkpointer(tmp, keep=2)
    losses = []

    def run(params, opt_state, start, stop):
        for s in range(start, stop):
            batch = {k: jnp.asarray(v) for k, v in
                     pipeline.token_batch(cfg, s, args.batch, args.seq).items()}
            params, opt_state, m = step(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if s % 10 == 0:
                print(f"step {s:4d} loss {losses[-1]:.4f}")
        return params, opt_state

    half = args.steps // 2
    params, opt_state = run(params, opt_state, 0, half)
    ck.save(half - 1, {"p": params, "o": opt_state}, blocking=True)
    print(f"-- simulated failure at step {half}; restoring from checkpoint --")
    del params, opt_state
    restored, at = ck.restore({"p": model_api.init(cfg, jax.random.PRNGKey(0))[0],
                               "o": opt.init(model_api.init(cfg, jax.random.PRNGKey(0))[0])})
    params, opt_state = restored["p"], restored["o"]
    params, opt_state = run(params, opt_state, at + 1, args.steps)

    first, last = sum(losses[:5]) / 5, sum(losses[-5:]) / 5
    print(f"loss {first:.3f} -> {last:.3f}")
    assert last < first, "training failed to reduce loss"
    shutil.rmtree(tmp, ignore_errors=True)
    print("OK: trained through a simulated failure with exact resume")


if __name__ == "__main__":
    main()
