"""Kernel ridge regression through the plan operator, end to end.

  PYTHONPATH=src python examples/krr.py [--n 2048]

Fits ``(K + lam*I) alpha = y`` where ``K`` is the RBF kernel truncated to
the plan's symmetrized kNN pattern — the solver never sees a matrix, only
``plan.apply`` with the regularized diagonal folded in. Preconditioned CG
(block-Jacobi from the plan's own diagonal BSR tiles) carries the solve;
the fitted model predicts in-sample and at held-out points through the
kNN-truncated cross kernel.

On small problems the script also checks the matrix-free fit against a
dense ``scipy.linalg.solve`` of the very same truncated kernel, so the
output shows the solver agreeing with the reference to CG tolerance.
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro import api  # noqa: E402
from repro.data.pipeline import feature_mixture  # noqa: E402
from repro.solvers import RBFValues, krr_fit  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=16)
    ap.add_argument("--lam", type=float, default=0.5)
    ap.add_argument("--dense-check", type=int, default=2048,
                    help="dense-reference check up to this n (0 disables)")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    x = feature_mixture(args.n + 256, args.d, n_clusters=16, seed=0)
    x_train, x_test = x[:args.n], x[args.n:]
    w_true = rng.standard_normal(args.d).astype(np.float32)
    y = np.tanh(x @ w_true).astype(np.float32)
    y_train, y_test = y[:args.n], y[args.n:]

    plan = api.build_plan(x_train, k=args.k, bs=32, sb=8, backend="bsr",
                          symmetrize=True, values=RBFValues())
    print(f"plan: {plan}")

    t0 = time.perf_counter()
    model = krr_fit(plan, y_train, lam=args.lam)
    model.alpha.block_until_ready()
    t1 = time.perf_counter()
    res = model.result
    print(f"fit: {int(res.iters)} CG iterations "
          f"({'converged' if bool(res.converged) else 'NOT converged'}, "
          f"final rel resid {float(res.resid / res.bnorm):.2e}) "
          f"in {t1 - t0:.3f}s")

    yhat = np.asarray(model.predict())
    in_mse = float(np.mean((yhat - y_train) ** 2))
    yhat_t = np.asarray(model.predict(x_test))
    out_mse = float(np.mean((yhat_t - y_test) ** 2))
    base = float(np.mean((y_test - y_train.mean()) ** 2))
    print(f"train mse {in_mse:.4f} | test mse {out_mse:.4f} "
          f"(predict-the-mean baseline {base:.4f})")

    if args.dense_check and args.n <= args.dense_check:
        from scipy.linalg import solve as dense_solve
        dense = np.asarray(plan.bsr.to_dense())
        # Gershgorin self weight (auto) + regularizer
        shift = float(np.asarray(model.self_weight)) + args.lam
        pi = np.asarray(plan.pi)
        inv = np.asarray(plan.inv)
        alpha_ref = dense_solve(
            dense + shift * np.eye(plan.n), y_train[pi],
            assume_a="sym")[inv]
        err = (np.abs(np.asarray(model.alpha) - alpha_ref).max()
               / np.abs(alpha_ref).max())
        print(f"dense scipy reference: max rel err {err:.2e}")
        assert err < 1e-3, "matrix-free fit disagrees with dense reference"
    print("OK")


if __name__ == "__main__":
    main()
