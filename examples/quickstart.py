"""Quickstart: the paper's pipeline on a small synthetic dataset — through
the unified planner API only.

  PYTHONPATH=src python examples/quickstart.py

One call, ``repro.api.build_plan``, runs the whole pipeline: kNN interaction
pattern (Eq. 1) -> PCA embedding + adaptive 2^d-tree ordering (§2.4) ->
two-level ELL-BSR storage -> γ-scored profile (§2.3). The plan then serves
the interaction ``y = A x`` through every registered SpMV backend; here we
compare orderings by γ (profile-only plans) and check that all backends
agree on the dual-tree plan.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro import api
from repro.data.pipeline import feature_mixture


def main():
    n, d, k = 2048, 128, 16
    x = feature_mixture(n, d, n_clusters=32, seed=0)
    print(f"dataset: {n} points in R^{d} (SIFT-like mixture)")

    print("\ngamma-score by ordering (higher = denser patches):")
    for name in api.ORDERINGS:
        profile = api.build_plan(x, k=k, ordering=name, with_bsr=False)
        print(f"  {name:10s} gamma = {profile.gamma:7.2f}")

    rng = np.random.default_rng(0)
    plan = api.build_plan(x, k=k, ordering="dual_tree", bs=32, sb=8,
                          backend="auto",
                          values=lambda r, c, d2: rng.random(len(r)))
    print(f"\ndual-tree plan: {plan}")
    print(f"  {plan.bsr.n_rb} row blocks, max {plan.bsr.max_nbr} tiles/row, "
          f"fill {plan.fill:.3f}")

    xvec = jnp.asarray(rng.standard_normal(n), jnp.float32)
    x_sorted = plan.permute(xvec)
    results = {b: np.asarray(plan.apply(x_sorted, backend=b))
               for b in api.backend_names()}
    ref = results["csr"]
    print("\nSpMV backends vs csr (max-abs):")
    worst = 0.0
    for name, y in results.items():
        err = float(np.abs(y - ref).max())
        worst = max(worst, err)
        print(f"  {name:8s} {err:.2e}")
    assert worst <= 1e-4, f"backend disagreement {worst:.2e} > 1e-4"

    y = plan.unpermute(plan.apply(x_sorted))          # auto-tuned backend
    print(f"\nbackend='auto' resolved to {plan.resolve_backend()!r}; "
          f"matvec norm {float(jnp.linalg.norm(y)):.3f}")
    print("all backends agree OK")

    # streaming: plans absorb inserts/deletes in place (capacity vs n)
    rng2 = np.random.default_rng(7)
    splan = api.build_plan(x, k=k, bs=32, sb=8, backend="bsr", ell_slack=4,
                           capacity=n + 256)
    kill = rng2.choice(n, 64, replace=False)
    splan = splan.delete(kill)                      # tombstone tier
    x_new = feature_mixture(64, d, n_clusters=32, seed=0)  # same mixture
    splan, new_ids = splan.insert(x_new)            # append tier
    st = splan.refresh_stats
    print(f"\nstreaming: {splan}")
    print(f"  deleted 64, inserted 64 (ids {new_ids[:4].tolist()}...): "
          f"tiers appends={st.appends} tombstones={st.tombstones} "
          f"restripes={st.restripes} compactions={st.compactions}, "
          f"dead_frac {splan.dead_frac:.3f}")
    assert splan.n_alive == n
    compacted = splan.compact()                     # compact tier: the
    print(f"  after compact: {compacted} "          # exact fresh build
          f"(bit-exact vs build_plan on the survivors)")

    # many small problems: one plan per head/batch entry, stacked on a
    # shared spec — ONE compiled kernel serves the whole batch (the
    # clusterkv-style workload; a python loop would pay B dispatches)
    B = 8
    xs_many = [feature_mixture(512, d, n_clusters=16, seed=s)
               for s in range(B)]
    batch = api.build_plan_batch(xs_many, k=k, bs=16, sb=4, backend="auto",
                                 ell_slack=4, capacity=576)
    # capacity slack + ELL slack: streamed inserts land in the shared
    # spec, so the compiled batch kernels survive the churn
    charges = batch.pad_charges(
        [rng.standard_normal(512).astype(np.float32) for _ in range(B)])
    ys = batch.matvec(charges)                    # one vmapped kernel
    print(f"\nbatched plans: {batch}")
    one = batch.member(3)                         # any member is a real plan
    err_b = float(np.abs(np.asarray(ys[3])
                         - np.asarray(one.matvec(charges[3]))).max())
    print(f"  member 3 vs batched lane max-abs {err_b:.2e}")
    assert err_b <= 1e-5
    # lockstep streaming: every member inserts/deletes in one step,
    # escalation decided per plan, executed against one shared spec
    batch2, new_ids2 = batch.insert(
        [feature_mixture(8, d, n_clusters=16, seed=100 + s)
         for s in range(B)])
    print(f"  after lockstep insert: n_alive={batch2.n_alive.tolist()} "
          f"(spec stable: {batch2.spec == batch.spec})")

    import jax
    if jax.device_count() >= 2:
        # sharded plan: per-device row-block shards, charge halos moved by
        # neighbor exchange instead of replicating the whole vector
        sharded = plan.shard()
        y_sh = np.asarray(sharded.apply(x_sorted))
        err = float(np.abs(y_sh - ref).max())
        print(f"\nsharded over {jax.device_count()} devices: {sharded}")
        print(f"  per-device transfer {sharded.transfer_fraction:.2f}x "
              f"of an all-gather; vs csr max-abs {err:.2e}")
        assert err <= 1e-4, f"sharded matvec disagreement {err:.2e}"
        assert plan.resolve_backend() == "dist", (
            "backend='auto' should pick the sharded dist path on a "
            f"multi-device mesh, got {plan.resolve_backend()!r}")


if __name__ == "__main__":
    main()
