"""Quickstart: the paper's pipeline on a small synthetic dataset.

  PYTHONPATH=src python examples/quickstart.py

Steps: build a kNN interaction matrix over clustered high-dimensional
points -> compare orderings by patch-density (gamma) -> build the two-level
ELL-BSR under the dual-tree ordering -> run the block-sparse interaction
three ways (CSR gather / blockwise / Pallas kernel) and check they agree.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import blocksparse, interact, knn, measures, ordering
from repro.data.pipeline import feature_mixture
from repro.kernels import ops as kops


def main():
    n, d, k = 2048, 128, 16
    x = feature_mixture(n, d, n_clusters=32, seed=0)
    print(f"dataset: {n} points in R^{d} (SIFT-like mixture)")

    rows, cols, _ = knn.knn_coo(jnp.asarray(x), jnp.asarray(x), k,
                                exclude_self=True)
    rows, cols = np.asarray(rows), np.asarray(cols)
    print(f"kNN graph: {len(rows)} nonzeros (k={k})")

    print("\ngamma-score by ordering (higher = denser patches):")
    best = {}
    for name in ordering.ORDERINGS:
        pi = ordering.compute_ordering(name, x, rows, cols)
        r2, c2 = ordering.apply_ordering(rows, cols, pi)
        g = float(measures.gamma_score(jnp.asarray(r2), jnp.asarray(c2),
                                       k / 2, n))
        best[name] = (pi, r2, c2)
        print(f"  {name:10s} gamma = {g:7.2f}")

    pi, r2, c2 = best["dual_tree"]
    vals = np.random.default_rng(0).random(len(r2)).astype(np.float32)
    bsr = blocksparse.build_bsr(r2, c2, vals, n, bs=32, sb=8)
    print(f"\ndual-tree ELL-BSR: {bsr.n_rb} row blocks, "
          f"max {bsr.max_nbr} tiles/row, fill {bsr.fill:.3f}")

    xvec = jnp.asarray(np.random.default_rng(1).standard_normal(n),
                       jnp.float32)
    y_csr = interact.spmv_csr(jnp.asarray(vals), jnp.asarray(r2),
                              jnp.asarray(c2), xvec, n)
    y_bsr = interact.spmv(bsr, xvec, "bsr")
    y_pal = kops.bsr_spmv(bsr.vals, bsr.col_idx, xvec, n)
    print(f"paths agree: csr~bsr {float(jnp.abs(y_csr-y_bsr).max()):.2e}, "
          f"bsr~pallas {float(jnp.abs(y_bsr-y_pal).max()):.2e}")


if __name__ == "__main__":
    main()
