"""Continuous-batching serving loop.

Production-serving structure over the model decode step: a fixed pool of
``slots`` (the static decode batch the step was compiled for), a request
queue, and an engine loop that

  - admits queued requests into free slots (prefilling their prompt into
    the slot's cache region),
  - runs ONE batched decode step for all active slots per tick,
  - retires slots on EOS/max-tokens and immediately backfills them.

Static shapes throughout: the decode step is compiled once for
(slots, max_seq); prefill is compiled per admitted prompt-length bucket
(lengths are rounded up to ``prefill_bucket`` to bound recompiles).

Single-host reference implementation; the sharded version places the slot
axis on "dp" and the cache per cache_specs (the dry-run decode cells prove
those lowerings).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model_api
from repro.models.sharding import NO_SHARD


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                 # prompt token ids (1-D)
    max_new: int = 32
    eos_id: Optional[int] = None
    # filled by the engine:
    output: List[int] = dataclasses.field(default_factory=list)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


class Engine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_seq: int = 512, prefill_bucket: int = 64,
                 backend: str = "flash"):
        if cfg.family not in ("dense", "moe"):
            raise NotImplementedError(
                "reference engine supports decoder-only token models")
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_seq = max_seq
        self.bucket = prefill_bucket
        self.backend = backend
        mod = model_api.module_for(cfg)
        self.mod = mod
        self.cache = mod.init_cache(cfg, slots, max_seq)
        # per-slot positions replace the scalar cache pos
        self.slot_pos = np.zeros(slots, np.int32)
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.queue: Deque[Request] = deque()
        self._decode = jax.jit(self._decode_step)
        self._prefills: Dict[int, Callable] = {}
        # which axis of each cache entry is the sequence axis, read off the
        # family's own cache spec (slot install copies along it)
        self._seq_axes = model_api.cache_seq_axes(cfg)
        self.ticks = 0

    # -- jitted pieces ------------------------------------------------------

    def _decode_step(self, params, cache, tokens, slot_pos):
        """One token for every slot, each writing and masking at ITS OWN
        position (cache['pos'] as a (slots,) vector — decode_step's
        continuous-batching contract)."""
        cache = dict(cache, pos=slot_pos)
        logits, new_cache = self.mod.decode_step(
            params, self.cfg, cache, tokens, NO_SHARD, self.backend)
        return logits, new_cache

    def _prefill_fn(self, length: int):
        if length not in self._prefills:
            def fn(params, tokens):
                cfg = dataclasses.replace(self.cfg)
                return self.mod.prefill(params, cfg, {"tokens": tokens},
                                        NO_SHARD, self.backend)
            self._prefills[length] = jax.jit(fn)
        return self._prefills[length]

    # -- engine -------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.t_submit = time.time()
        self.queue.append(req)

    def _install(self, s: int, req: Request, cache_1, blen: int):
        """Install an admitted request's prefilled state into slot ``s``.

        The base engine copies every seq-scaling cache entry (per
        ``model_api.cache_seq_axes`` — not a hardcoded key list) into the
        slot's cache region. Subclasses may stage entirely different
        serving state and return replacement first-token logits (else
        None to keep the prefill's)."""
        for key, ax in self._seq_axes.items():
            seg = cache_1[key][:, 0]             # e.g. (L, H, blen, dh)
            start = [0] * self.cache[key].ndim
            start[1] = s                         # slot on the batch axis
            self.cache[key] = jax.lax.dynamic_update_slice(
                self.cache[key], seg[:, None], tuple(start))
        return None

    def _release(self, s: int, req: Request) -> None:
        """Hook: slot ``s`` just retired ``req`` (subclass teardown)."""

    def _admit(self) -> None:
        for s in range(self.slots):
            if self.slot_req[s] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            plen = len(req.tokens)
            blen = -(-plen // self.bucket) * self.bucket
            padded = np.zeros(blen, np.int32)
            padded[-plen:] = req.tokens          # left-pad into the bucket
            pf = self._prefill_fn(blen)
            cache_1, logits = pf(self.params, jnp.asarray(padded[None]))
            override = self._install(s, req, cache_1, blen)
            if override is not None:
                logits = override
            self.slot_pos[s] = blen
            tok = int(jnp.argmax(logits[0]))
            req.output.append(tok)
            req.t_first = time.time()
            self.slot_req[s] = req

    def _retire(self) -> None:
        for s, req in enumerate(self.slot_req):
            if req is None:
                continue
            done = (len(req.output) >= req.max_new
                    or (req.eos_id is not None
                        and req.output[-1] == req.eos_id)
                    or int(self.slot_pos[s]) >= self.max_seq - 1)
            if done:
                req.t_done = time.time()
                self.slot_req[s] = None
                self.slot_pos[s] = 0
                self._release(s, req)

    def step(self) -> int:
        """One engine tick: admit, decode all active slots, retire."""
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].output[-1]
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        for s in active:
            self.slot_pos[s] += 1
            self.slot_req[s].output.append(int(nxt[s]))
        self.ticks += 1
        return len(active)

    def run(self, until_empty: bool = True, max_ticks: int = 10_000) -> None:
        while (self.queue or any(r is not None for r in self.slot_req)) \
                and self.ticks < max_ticks:
            self.step()
            self._retire()
