"""Train/serve step factories.

make_train_step builds the full training step (fwd + bwd + clip + optimizer
update + metrics) for any arch config, with:
  - remat (per-layer, inside the model's scan),
  - microbatch gradient accumulation (lax.scan, donated f32 accumulator;
    per-microbatch grads cast to bf16 before accumulation with an f32
    error-feedback buffer when compress_grads is on),
  - chunked cross-entropy (inside model loss),
  - logical->physical sharding resolution from the param spec tree.

make_prefill_step / make_decode_step build the serving steps.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model_api
from repro.models import param as pm
from repro.models.sharding import NO_SHARD, ShardCtx, resolve_spec, spec_tree
from repro.optim.optimizers import make_optimizer


@dataclass
class StepArtifacts:
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...]


def make_train_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                    backend: str = "flash", microbatch: int = 1,
                    compress_grads: bool = False,
                    optimizer=None):
    """Returns (step_fn, optimizer). step_fn(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    mod = model_api.module_for(cfg)
    shd = ShardCtx(mesh)
    opt = optimizer or make_optimizer(cfg.optimizer)

    def loss_of(p, batch):
        return mod.loss_fn(p, cfg, batch, shd, backend)

    def step(params, opt_state, batch):
        if microbatch > 1:
            def slice_mb(x, i):
                b = x.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(x, i * b, b, axis=0)

            def acc_body(carry, i):
                gacc, lacc, err = carry
                mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
                l, g = jax.value_and_grad(loss_of)(params, mb)
                if compress_grads:
                    # bf16-compressed accumulation with f32 error feedback
                    g32 = jax.tree.map(lambda a, e: a.astype(jnp.float32) + e,
                                       g, err)
                    gq = jax.tree.map(lambda a: a.astype(jnp.bfloat16), g32)
                    err = jax.tree.map(
                        lambda a, q: a - q.astype(jnp.float32), g32, gq)
                    gacc = jax.tree.map(
                        lambda acc, q: acc + q.astype(jnp.float32), gacc, gq)
                else:
                    gacc = jax.tree.map(
                        lambda acc, a: acc + a.astype(jnp.float32), gacc, g)
                return (gacc, lacc + l, err), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            errs = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params) \
                if compress_grads else jax.tree.map(lambda p: jnp.zeros((0,)),
                                                    params)
            (grads, loss, _), _ = jax.lax.scan(
                acc_body, (zeros, jnp.zeros((), jnp.float32), errs),
                jnp.arange(microbatch))
            grads = jax.tree.map(lambda g: g / microbatch, grads)
            loss = loss / microbatch
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)

        new_params, new_state, gnorm = opt.update(grads, opt_state, params)
        metrics = {"loss": loss.astype(jnp.float32), "grad_norm": gnorm}
        return new_params, new_state, metrics

    return step, opt


def train_shardings(cfg: ModelConfig, mesh: Mesh, opt, batch_parts):
    """(in_shardings, out_shardings) PartitionSpec trees for jit lowering."""
    pspecs = model_api.param_specs(cfg)
    pspecs_r = spec_tree(pspecs, mesh)
    ospecs = opt.state_specs(pspecs)
    ospecs_r = spec_tree(ospecs, mesh)
    bspecs_r = spec_tree(batch_parts, mesh)
    metrics = {"loss": P(), "grad_norm": P()}
    return ((pspecs_r, ospecs_r, bspecs_r),
            (pspecs_r, ospecs_r, spec_tree(metrics, mesh)))


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      backend: str = "flash"):
    mod = model_api.module_for(cfg)
    shd = ShardCtx(mesh)

    def step(params, batch):
        return mod.prefill(params, cfg, batch, shd, backend)

    return step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                     backend: str = "flash", sharded_long: bool = False):
    mod = model_api.module_for(cfg)
    shd = ShardCtx(mesh)

    def step(params, cache, batch):
        tokens = batch["tokens"] if isinstance(batch, dict) else batch
        return mod.decode_step(params, cfg, cache, tokens, shd, backend,
                               sharded_long)

    return step
