"""Optimizers (raw JAX): AdamW and Adafactor, with global-norm clipping and
warmup-cosine schedule. All states live in the same sharding as their params
(spec trees derived from the param spec tree), so ZeRO-3 falls out of the
param FSDP specs.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# schedule
# ---------------------------------------------------------------------------


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), tree), norm


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AdamW:
    lr: Callable
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip: float = 1.0

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(self, param_specs):
        return {"m": param_specs, "v": param_specs, "step": P()}

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr = self.lr(step)
        bc1 = 1 - self.b1 ** t
        bc2 = 1 - self.b2 ** t

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m = self.b1 * m + (1 - self.b1) * g32
            v = self.b2 * v + (1 - self.b2) * g32 * g32
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; memory ~0 extra for matrices)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Adafactor:
    lr: Callable
    decay: float = 0.99
    eps: float = 1e-30
    clip: float = 1.0
    rms_clip: float = 1.0
    weight_decay: float = 0.0

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params):
        def zeros(p):
            if self._factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + (p.shape[-1],),
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"v": jax.tree.map(zeros, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)
                                  or hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def state_specs(self, param_specs):
        def spec(s):
            t = tuple(s)
            return {"vr": P(*t[:-1]),
                    "vc": P(*(t[:-2] + (t[-1],))) if len(t) >= 2 else P()}
        def one(s):
            t = tuple(s)
            if len(t) >= 2:
                return spec(s)
            return {"v": P(*t)}
        return {"v": jax.tree.map(one, param_specs,
                                  is_leaf=lambda x: isinstance(x, P)),
                "step": P()}

    def update(self, grads, state, params):
        grads, gnorm = clip_by_global_norm(grads, self.clip)
        step = state["step"] + 1
        lr = self.lr(step)
        d = self.decay

        def upd(g, v, p):
            g32 = g.astype(jnp.float32)
            g2 = g32 * g32 + self.eps
            if self._factored(p):
                vr = d * v["vr"] + (1 - d) * g2.mean(axis=-1)
                vc = d * v["vc"] + (1 - d) * g2.mean(axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(axis=-1)[..., None, None],
                                       self.eps))
                u = g32 * jax.lax.rsqrt(denom + self.eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": d * v["v"] + (1 - d) * g2}
                u = g32 * jax.lax.rsqrt(nv["v"] + self.eps)
            rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
            u = u / jnp.maximum(1.0, rms / self.rms_clip)
            if self.weight_decay:
                u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype), nv

        flat_g, tdef = jax.tree.flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = jax.tree.leaves(params)
        new_p, new_v = [], []
        for g, v, p in zip(flat_g, flat_v, flat_p):
            np_, nv_ = upd(g, v, p)
            new_p.append(np_)
            new_v.append(nv_)
        return (jax.tree.unflatten(tdef, new_p),
                {"v": jax.tree.unflatten(tdef, new_v), "step": step}, gnorm)


def make_optimizer(name: str, lr: float = 3e-4, warmup: int = 100,
                   total: int = 10_000):
    sched = warmup_cosine(lr, warmup, total)
    if name == "adamw":
        return AdamW(lr=sched)
    if name == "adafactor":
        return Adafactor(lr=sched)
    raise ValueError(name)
