"""Production meshes. Defined as FUNCTIONS so importing this module never
touches jax device state (device count is locked on first jax init)."""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips (DP across pods)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2) -> Mesh:
    """Small mesh for CPU tests (requires host-platform device override)."""
    return jax.make_mesh((data, model), ("data", "model"))
