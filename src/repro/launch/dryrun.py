import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
ShapeDtypeStruct inputs (no allocation), record memory_analysis,
cost_analysis and the per-device collective bytes parsed from the
SPMD-partitioned HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
Results land in results/dryrun/<arch>__<shape>__<mesh>.json.
"""

import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, all_cells, get_config
from repro.launch.mesh import make_production_mesh
from repro.models import model_api
from repro.models.sharding import resolve_tree, shardings_for
from repro.optim.optimizers import make_optimizer
from repro.train import trainer

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"

# collective ops and ring-model link traffic factors (x local bytes)
# def lines look like:  %all-reduce.140 = f32[8192,9496]{1,0} all-reduce(...)
_COLL_RE = re.compile(
    r" (all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_SHAPE_RE = re.compile(r"(f32|bf16|f16|f64|s32|u32|s8|u8|pred|s64|u64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4,
          "s8": 1, "u8": 1, "pred": 1, "s64": 8, "u64": 8}
_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device result bytes of collective ops in the partitioned HLO,
    weighted by a ring-model traffic factor (all-reduce ~ 2x).

    Ops are attributed to 'entry' (ENTRY computation — executed once) vs
    'body' (non-entry computations — while/scan bodies, counted ONCE in the
    text but executed trip-count times). The roofline reader scales 'body'
    by the model's layer-scan trip count.
    """
    def fresh():
        return {"bytes_by_op": {k: 0.0 for k in _FACTOR},
                "counts": {k: 0 for k in _FACTOR}, "weighted_bytes": 0.0}

    sections = {"entry": fresh(), "body": fresh()}
    current = "body"
    for line in hlo_text.splitlines():
        ls = line.strip()
        if ls.startswith("ENTRY "):
            current = "entry"
        elif ls.endswith("{") and not ls.startswith("ENTRY") and "=" not in ls:
            current = "body"
        m = _COLL_RE.search(line)
        if not m or " = " not in line:
            continue
        op = m.group(1)
        # result shape = last shape before the op token
        shapes = [(sm.start(), sm.group(1), sm.group(2))
                  for sm in _SHAPE_RE.finditer(line[:m.start()])]
        if not shapes:
            continue
        _, dtype, dims = shapes[-1]
        size = _BYTES[dtype]
        for d in dims.split(","):
            if d:
                size *= int(d)
        sec = sections[current]
        sec["bytes_by_op"][op] += size
        sec["counts"][op] += 1
        sec["weighted_bytes"] += size * _FACTOR[op]
    total = {k: sections["entry"]["bytes_by_op"][k]
             + sections["body"]["bytes_by_op"][k] for k in _FACTOR}
    return {"entry": sections["entry"], "body": sections["body"],
            "bytes_by_op": total,
            "weighted_bytes": sections["entry"]["weighted_bytes"]
            + sections["body"]["weighted_bytes"]}


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               backend: str | None = None, microbatch: int = 1,
               layout: str = "2d", expert_parallel: bool = False,
               param_dtype: str | None = None, remat: str | None = None):
    """Returns (jitted_fn, example_args) ready to .lower()."""
    from repro.models.sharding import set_layout
    set_layout(layout)
    cfg = get_config(arch)
    if expert_parallel and cfg.moe is not None:
        import dataclasses
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, expert_parallel=True))
    if param_dtype:
        cfg = cfg.with_(param_dtype=param_dtype)
    if remat == "none":
        cfg = cfg.with_(remat=False)
    elif remat in ("dots", "full"):
        cfg = cfg.with_(remat=True, remat_policy=remat)
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq, batch, kind = SHAPES[shape_name]
    backend = backend or model_api.backend_for(cfg, shape_name)
    pshapes = model_api.param_shapes(cfg)
    pspecs = shardings_for(pshapes, model_api.param_specs(cfg), mesh)
    bshapes, bparts = model_api.input_specs(cfg, shape_name)
    bspecs = shardings_for(bshapes, bparts, mesh)

    if kind == "train":
        step, opt = trainer.make_train_step(cfg, mesh, backend,
                                            microbatch=microbatch)
        oshapes = jax.eval_shape(opt.init, pshapes)
        ospecs = shardings_for(oshapes,
                               opt.state_specs(model_api.param_specs(cfg)),
                               mesh)
        mspecs = resolve_tree({"loss": P(), "grad_norm": P()}, mesh)
        fn = jax.jit(step,
                     in_shardings=(pspecs, ospecs, bspecs),
                     out_shardings=(pspecs, ospecs, mspecs),
                     donate_argnums=(0, 1))
        args = (pshapes, oshapes, bshapes)
    elif kind == "prefill":
        step = trainer.make_prefill_step(cfg, mesh, backend)
        cshapes = jax.eval_shape(
            lambda: model_api.module_for(cfg).init_cache(cfg, batch, seq))
        cspecs = shardings_for(cshapes,
                               model_api.module_for(cfg).cache_specs(cfg),
                               mesh)
        lshape = jax.ShapeDtypeStruct((batch, cfg.vocab), jnp.float32)
        lspec = shardings_for(lshape, P("dp", "tp"), mesh)
        fn = jax.jit(step, in_shardings=(pspecs, bspecs),
                     out_shardings=(cspecs, lspec))
        args = (pshapes, bshapes)
    else:  # decode
        long_ctx = shape_name.startswith("long")
        step = trainer.make_decode_step(cfg, mesh, backend,
                                        sharded_long=long_ctx)
        cshapes, cparts = model_api.cache_shapes(cfg, shape_name)
        cspecs = shardings_for(cshapes, cparts, mesh)
        lshape = jax.ShapeDtypeStruct((batch, cfg.vocab), jnp.float32)
        lspec = shardings_for(lshape, P("dp", "tp"), mesh)
        fn = jax.jit(step, in_shardings=(pspecs, cspecs, bspecs),
                     out_shardings=(lspec, cspecs),
                     donate_argnums=(1,))
        args = (pshapes, cshapes, bshapes)
    return fn, args, mesh, backend


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             backend: str | None = None, save: bool = True,
             microbatch: int = 1, tag: str = "", layout: str = "2d",
             expert_parallel: bool = False,
             param_dtype: str | None = None,
             remat: str | None = None) -> dict:
    from repro.core.costmodel import make_report

    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    t0 = time.time()
    # repro.cost/v1 envelope merged flat (schema/kind/hardware keys) so
    # readers keyed on rec["status"]/rec["arch"] keep working unchanged
    rec = make_report("dryrun", {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "error", "layout": layout, "ep": expert_parallel,
        "microbatch": microbatch, "param_dtype": param_dtype,
        "remat": remat})
    try:
        fn, args, mesh, backend = build_cell(arch, shape_name, multi_pod,
                                             backend, microbatch, layout,
                                             expert_parallel, param_dtype,
                                             remat)
        rec["backend"] = backend
        with mesh:
            lowered = fn.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        text = compiled.as_text()
        coll = parse_collectives(text)
        rec.update({
            "status": "ok",
            "lower_s": round(t1 - t0, 1),
            "compile_s": round(t2 - t1, 1),
            "memory": {
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
                "generated_code_bytes":
                    getattr(mem, "generated_code_size_in_bytes", None),
                "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            },
            "cost": {k: cost.get(k) for k in
                     ("flops", "bytes accessed", "transcendentals",
                      "optimal_seconds") if k in cost},
            "collectives": coll,
        })
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=20)
    rec["total_s"] = round(time.time() - t0, 1)
    if save:
        RESULTS.mkdir(parents=True, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        stem = f"{arch}__{shape_name}__{mesh_name}{suffix}"
        (RESULTS / f"{stem}.json").write_text(json.dumps(rec, indent=2))
        if rec["status"] == "ok":
            import gzip
            with gzip.open(RESULTS / f"{stem}.hlo.gz", "wt") as fh:
                fh.write(text)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--backend", default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--layout", default="2d")
    ap.add_argument("--ep", action="store_true")
    ap.add_argument("--param-dtype", default=None)
    ap.add_argument("--remat", default=None, choices=["full", "dots", "none"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch, shape, _, _, _ in all_cells():
            cells.append((arch, shape))
    else:
        cells.append((args.arch, args.shape))
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    for arch, shape in cells:
        for mp in meshes:
            mesh_name = "pod2x16x16" if mp else "pod16x16"
            suffix = f"__{args.tag}" if args.tag else ""
            out = RESULTS / f"{arch}__{shape}__{mesh_name}{suffix}.json"
            if args.skip_done and out.exists() \
                    and json.loads(out.read_text()).get("status") == "ok":
                print(f"SKIP {arch} {shape} {mesh_name}")
                continue
            rec = run_cell(arch, shape, mp, args.backend,
                           microbatch=args.microbatch, tag=args.tag,
                           layout=args.layout, expert_parallel=args.ep,
                           param_dtype=args.param_dtype, remat=args.remat)
            flops = (rec.get("cost") or {}).get("flops")
            print(f"{rec['status']:5s} {arch:28s} {shape:12s} {mesh_name:10s} "
                  f"compile={rec.get('compile_s')}s flops/dev={flops} "
                  f"{rec.get('error', '')}")


if __name__ == "__main__":
    main()
