"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --steps 50 --batch 8 --seq 128

On a real TPU fleet the same entrypoint initializes jax.distributed and
builds the production mesh; on this CPU container ``--reduced`` runs the
reduced config end-to-end (single device) and ``--dry-run`` only lowers.

Distributed-optimization environment (set before jax init): the launcher
exports the XLA flags that enable latency-hiding scheduling so collectives
overlap with compute — the overlap lever referenced in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import os

XLA_PERF_FLAGS = (
    "--xla_tpu_enable_latency_hiding_scheduler=true "
    "--xla_tpu_megacore_fusion_allow_ags=true "
    "--xla_enable_async_collective_permute=true "
    "--xla_tpu_enable_async_collective_fusion=true "
    "--xla_tpu_enable_async_collective_fusion_fuse_all_gather=true"
)

if os.environ.get("REPRO_TPU"):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + XLA_PERF_FLAGS)

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import get_config, reduced_config
from repro.data import pipeline
from repro.launch.ft import Supervisor
from repro.models import model_api
from repro.optim.optimizers import make_optimizer
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--backend", default="flash")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    opt = make_optimizer(cfg.optimizer, lr=args.lr, warmup=max(args.steps // 20, 1),
                         total=args.steps)
    step_fn, _ = trainer.make_train_step(cfg, mesh=None, backend=args.backend,
                                         microbatch=args.microbatch,
                                         optimizer=opt)
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1))

    params, _ = model_api.init(cfg, jax.random.PRNGKey(args.seed))
    opt_state = opt.init(params)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"optimizer={cfg.optimizer} backend={args.backend}")

    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None

    state = {"params": params, "opt": opt_state}

    def one_step(state, step):
        batch_np = pipeline.token_batch(cfg, step, args.batch, args.seq,
                                        args.seed)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        p, o, metrics = step_jit(state["params"], state["opt"], batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        return {"params": p, "opt": o}

    if ckpt:
        sup = Supervisor(step_deadline_s=3600)
        state = sup.run(
            n_steps=args.steps,
            make_state=lambda: state,
            step_fn=one_step,
            save=lambda s, st: ckpt.save(s, st),
            restore=lambda: ckpt.restore(state),
            ckpt_every=args.ckpt_every or max(args.steps // 4, 1))
        ckpt.wait()
    else:
        t0 = time.time()
        for step in range(args.steps):
            state = one_step(state, step)
        dt = time.time() - t0
        tok = args.steps * args.batch * args.seq
        print(f"done: {dt:.1f}s, {tok/dt:.0f} tok/s")


if __name__ == "__main__":
    main()
