"""Serving launcher: prefill a batch of prompts, then decode with batched
steps — optionally with the paper's cluster-sparse KV selection.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --prompt-len 256 --gen 32 --batch 4 --backend clusterkv

``--service`` routes through the ClusterKV decode service instead of the
one-shot prefill+decode loop: a continuous-batching engine with plan-cached
sessions (``--mode plan``) or the per-call Morton-sort baseline
(``--mode percall``), emitting the service's JSON telemetry:

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --backend clusterkv --service --slots 4 --batch 8 --gen 32 \
      --report report.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model_api
from repro.train import trainer


def run_service(cfg, params, args) -> dict:
    """Decode ``args.batch`` synthetic prompts through the ClusterKV
    decode service; returns (and optionally writes) the service report."""
    from repro.serve import ClusterKVEngine
    from repro.train.serve_loop import Request

    engine = ClusterKVEngine(cfg, params, slots=args.slots,
                             max_seq=args.max_seq,
                             prefill_bucket=args.prefill_bucket,
                             mode=args.mode)
    rng = np.random.default_rng(args.seed)
    for i in range(args.batch):
        plen = int(rng.integers(args.prompt_len // 2, args.prompt_len + 1))
        engine.submit(Request(
            rid=i, tokens=rng.integers(0, cfg.vocab, plen).astype(np.int32),
            max_new=args.gen))
    engine.run()
    report = engine.report()
    print(json.dumps(report, indent=2))
    if args.report:
        with open(args.report, "w") as f:
            json.dump(report, f, indent=2)
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="flash")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--service", action="store_true",
                    help="route through the ClusterKV decode service")
    ap.add_argument("--mode", default="plan", choices=("plan", "percall"))
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--prefill-bucket", type=int, default=64)
    ap.add_argument("--report", default=None,
                    help="write the service JSON report here")
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mod = model_api.module_for(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = model_api.init(cfg, key)

    if args.service:
        run_service(cfg, params, args)
        return

    total = args.prompt_len + args.gen
    batch = model_api.make_small_batch(cfg, key, args.batch, args.prompt_len,
                                       kind="prefill")

    prefill_fn = jax.jit(trainer.make_prefill_step(cfg, None, args.backend))
    decode_fn = jax.jit(trainer.make_decode_step(cfg, None, args.backend))

    t0 = time.time()
    cache, logits = prefill_fn(params, batch)
    # pad cache seq to total length along each entry's discovered seq axis
    # (the config's own cache spec, not shape guessing)
    cache = model_api.grow_cache(cfg, cache, total)
    t1 = time.time()

    toks = jnp.argmax(logits, -1)[:, None]
    outs = [toks]
    for i in range(args.gen - 1):
        if cfg.family == "vlm":
            step_in = {"tokens": jax.random.normal(
                jax.random.fold_in(key, i),
                (args.batch, 1, cfg.d_model)).astype(jnp.bfloat16)}
        else:
            step_in = {"tokens": toks}
        logits, cache = decode_fn(params, cache, step_in)
        toks = jnp.argmax(logits, -1)[:, None]
        outs.append(toks)
    gen = jnp.concatenate(outs, 1)
    t2 = time.time()
    print(f"arch={cfg.name} backend={args.backend}")
    print(f"prefill: {t1-t0:.2f}s ({args.batch*args.prompt_len/(t1-t0):.0f} tok/s)")
    print(f"decode:  {t2-t1:.2f}s ({args.batch*(args.gen-1)/max(t2-t1,1e-9):.0f} tok/s)")
    print("sample tokens:", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
