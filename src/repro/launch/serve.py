"""Serving launcher: prefill a batch of prompts, then decode with batched
steps — optionally with the paper's cluster-sparse KV selection.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
      --prompt-len 256 --gen 32 --batch 4 --backend clusterkv
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import model_api
from repro.train import trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=256)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--backend", default="flash")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = reduced_config(args.arch) if args.reduced else get_config(args.arch)
    mod = model_api.module_for(cfg)
    key = jax.random.PRNGKey(args.seed)
    params, _ = model_api.init(cfg, key)

    total = args.prompt_len + args.gen
    batch = model_api.make_small_batch(cfg, key, args.batch, args.prompt_len,
                                       kind="prefill")

    prefill_fn = jax.jit(trainer.make_prefill_step(cfg, None, args.backend))
    decode_fn = jax.jit(trainer.make_decode_step(cfg, None, args.backend))

    t0 = time.time()
    cache, logits = prefill_fn(params, batch)
    # pad cache seq to total length
    def grow(x):
        if x.ndim >= 4 and x.shape[-2] == args.prompt_len and cfg.family != "ssm":
            pads = [(0, 0)] * x.ndim
            pads[-2] = (0, args.gen)
            return jnp.pad(x, pads)
        return x
    if cfg.family in ("dense", "vlm", "moe"):
        cache = {k: (grow(v) if k in ("k", "v", "c", "kr") else v)
                 for k, v in cache.items()}
    elif cfg.family in ("hybrid", "encdec"):
        cache = {k: (grow(v) if k in ("k", "v") else v)
                 for k, v in cache.items()}
    t1 = time.time()

    toks = jnp.argmax(logits, -1)[:, None]
    outs = [toks]
    for i in range(args.gen - 1):
        if cfg.family == "vlm":
            step_in = {"tokens": jax.random.normal(
                jax.random.fold_in(key, i),
                (args.batch, 1, cfg.d_model)).astype(jnp.bfloat16)}
        else:
            step_in = {"tokens": toks}
        logits, cache = decode_fn(params, cache, step_in)
        toks = jnp.argmax(logits, -1)[:, None]
        outs.append(toks)
    gen = jnp.concatenate(outs, 1)
    t2 = time.time()
    print(f"arch={cfg.name} backend={args.backend}")
    print(f"prefill: {t1-t0:.2f}s ({args.batch*args.prompt_len/(t1-t0):.0f} tok/s)")
    print(f"decode:  {t2-t1:.2f}s ({args.batch*(args.gen-1)/max(t2-t1,1e-9):.0f} tok/s)")
    print("sample tokens:", np.asarray(gen[0][:16]))


if __name__ == "__main__":
    main()
