"""Analytic FLOP / HBM-byte models per (arch x shape) cell.

Why analytic: XLA's cost_analysis counts while/scan bodies ONCE (verified
empirically — see EXPERIMENTS.md §Roofline "HLO semantics"), so with
scan-over-layers the HLO numbers structurally undercount by ~n_layers. The
closed forms below count every matmul in the model (the models are ours, so
this is exact for MXU work); MODEL_FLOPS (the 6ND numerator) falls out of
the same accounting restricted to "useful" weight matmuls.

Conventions:
  - MAC = 2 flops; all numbers are GLOBAL per step (divide by chips).
  - Backward = 2x forward; full remat adds ~1x forward recompute.
  - Attention flops use the backend actually lowered for the cell
    (dense/flash = full causal, clusterkv = top-B blocks, swa = window).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.base import SHAPES, ModelConfig, get_config
from repro.core.costmodel import get_hardware
from repro.models import model_api

# per-chip constants from the knob-based hardware config (defaults are the
# TPU v5e-like numbers from the brief; override with REPRO_HW_CONFIG /
# costmodel.set_hardware before import)
_HW = get_hardware()
PEAK_FLOPS = _HW.peak_flops  # bf16
HBM_BW = _HW.hbm_bw          # bytes/s
LINK_BW = _HW.link_bw        # bytes/s per ICI link


def _attn_flops_per_layer(cfg: ModelConfig, s: int, backend: str,
                          causal: bool = True) -> float:
    """Score+AV flops for one layer, one sequence (no projections)."""
    if cfg.family in ("ssm", "hybrid"):
        return 0.0   # scan flops live in the proj term; shared attn separate
    hq = cfg.n_heads
    if cfg.mla is not None:
        dqk = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
        dv = cfg.mla.v_head_dim
    else:
        dqk = dv = cfg.head_dim
    if backend == "clusterkv":
        ck = cfg.clusterkv
        kv_per_q = min(ck.blocks_per_query * ck.block_k, s)
        pairs = s * kv_per_q
        # selection: centroid scores (nqb x nkb x dh) — counted too
        nqb = max(s // ck.block_q, 1)
        nkb = max(s // ck.block_k, 1)
        sel = nqb * nkb * dqk
        return 2.0 * hq * (pairs * (dqk + dv)) + 2.0 * hq * sel
    if cfg.swa_window and s > cfg.swa_window:
        pairs = s * cfg.swa_window
    else:
        pairs = s * s / 2 if causal else s * s
    return 2.0 * hq * pairs * (dqk + dv)


def _proj_flops_per_layer_token(cfg: ModelConfig) -> float:
    """Weight-matmul flops per token per layer (the 6N/L numerator piece)."""
    d = cfg.d_model
    if cfg.mla is not None:
        m = cfg.mla
        h = cfg.n_heads
        f = (d * m.q_lora_rank + m.q_lora_rank * h * (m.qk_nope_head_dim
                                                      + m.qk_rope_head_dim)
             + d * (m.kv_lora_rank + m.qk_rope_head_dim)
             + m.kv_lora_rank * h * (m.qk_nope_head_dim + m.v_head_dim)
             + h * m.v_head_dim * d)
    elif cfg.family == "ssm":
        ssm = cfg.ssm
        di = ssm.expand * d
        dtr = ssm.dt_rank or -(-d // 16)
        n = ssm.d_state
        f = (d * 2 * di                    # in_proj
             + di * (dtr + 2 * n)          # x_proj
             + dtr * di                    # dt_proj
             + di * d                      # out_proj
             + 5 * di * n)                 # scan update + C readout
    elif cfg.family == "hybrid":
        ssm = cfg.ssm
        di = ssm.expand * d
        n = ssm.d_state
        nh = di // ssm.head_dim
        l_chunk = ssm.chunk
        f = (d * 2 * di + d * 2 * n + d * nh + di * d
             + nh * (l_chunk * (n + ssm.head_dim))   # SSD intra-chunk per tok
             + 2 * nh * ssm.head_dim * n)            # states in/out
    else:
        dh = cfg.head_dim
        f = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * dh \
            + cfg.n_heads * dh * d
        if cfg.moe is not None:
            m = cfg.moe
            f += d * m.n_experts                     # router
            f += 3 * d * m.d_ff_expert * m.top_k
            f += 3 * d * m.d_ff_expert * m.n_shared_experts
        else:
            f += 3 * d * cfg.d_ff
    return 2.0 * f  # MAC -> flops


def _shared_block_flops_token(cfg: ModelConfig) -> float:
    d2 = 2 * cfg.d_model
    return 2.0 * (4 * d2 * d2 + 3 * d2 * cfg.d_ff + d2 * cfg.d_model)


def _head_flops_token(cfg: ModelConfig) -> float:
    return 2.0 * cfg.d_model * cfg.vocab


def n_params(cfg: ModelConfig) -> int:
    import jax
    import numpy as np
    shapes = model_api.param_shapes(cfg)
    return int(sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)))


def n_active_params(cfg: ModelConfig) -> float:
    """Active params per token (MoE: routed top-k + shared only)."""
    total = n_params(cfg)
    if cfg.moe is None:
        return float(total)
    m = cfg.moe
    per_layer_all = 3 * cfg.d_model * m.d_ff_expert * m.n_experts
    per_layer_act = 3 * cfg.d_model * m.d_ff_expert * (m.top_k
                                                       + m.n_shared_experts
                                                       - m.n_shared_experts)
    per_layer_act = 3 * cfg.d_model * m.d_ff_expert * m.top_k
    return float(total - cfg.n_layers * (per_layer_all - per_layer_act))


@dataclass
class CellModel:
    flops: float              # global flops per step (all work lowered)
    model_flops: float        # "useful" 6ND-style numerator
    hbm_bytes: float          # global HBM traffic per step (first-order)


def cell_model(arch: str, shape_name: str, backend: str | None = None,
               microbatch: int = 1, layout: str = "2d", chips: int = 256,
               param_dtype: str | None = None, remat: str | None = None,
               ep: bool = False) -> CellModel:
    cfg = get_config(arch)
    if remat == "none":
        cfg = cfg.with_(remat=False)
    elif remat in ("dots", "full"):
        cfg = cfg.with_(remat=True, remat_policy=remat)
    seq, batch, kind = SHAPES[shape_name]
    backend = backend or model_api.backend_for(cfg, shape_name)
    tokens = batch * seq
    p_total = n_params(cfg)
    p_active = n_active_params(cfg)
    pbytes = 2 if (param_dtype or cfg.param_dtype) == "bfloat16" else 4
    # per-device weight HBM reads: TP-resident shards for serve_tp, EP
    # experts resident /16, the full (ZeRO-gathered) set otherwise
    w_dev = p_total * pbytes / (16 if layout == "serve_tp" else 1)
    if ep and cfg.moe is not None:
        m = cfg.moe
        p_exp = 3 * cfg.d_model * m.d_ff_expert * m.n_experts * cfg.n_layers
        w_dev = (p_total - p_exp) * pbytes + p_exp * pbytes / 16

    if kind == "train":
        fwd = tokens * (_proj_flops_per_layer_token(cfg) * cfg.n_layers
                        + _head_flops_token(cfg))
        fwd += batch * _attn_flops_per_layer(cfg, seq, backend) * cfg.n_layers
        if cfg.family == "encdec":
            # encoder stack + cross attention
            fwd += tokens * _proj_flops_per_layer_token(cfg) * cfg.n_enc_layers
            fwd += batch * _attn_flops_per_layer(cfg, seq, backend, False) \
                * cfg.n_enc_layers
            fwd += 2.0 * cfg.n_layers * batch * seq * seq \
                * cfg.n_heads * 2 * cfg.head_dim
        if cfg.family == "hybrid":
            n_shared = -(-cfg.n_layers // cfg.shared_attn_every)
            fwd += tokens * _shared_block_flops_token(cfg) * n_shared
            fwd += batch * n_shared * 2.0 * cfg.n_heads \
                * (seq * seq / 2) * 2 * (2 * cfg.d_model // cfg.n_heads)
        if not cfg.remat:
            mult = 3.0
        elif cfg.remat_policy == "dots":
            mult = 3.3          # matmul outputs saved; elementwise recomputed
        else:
            mult = 4.0
        flops = fwd * mult
        model_flops = 6.0 * p_active * tokens
        # HBM: gathered-weight reads on every device (fwd+bwd+remat) + opt
        # state passes (sharded) + activations
        act = cfg.n_layers * tokens * cfg.d_model * 2 * 8
        hbm = chips * w_dev * (3 if cfg.remat else 2) \
            + p_total * 12 + act
        return CellModel(flops, model_flops, hbm)

    if kind == "prefill":
        fwd = tokens * (_proj_flops_per_layer_token(cfg) * cfg.n_layers
                        + _head_flops_token(cfg) * (1.0 / seq))
        fwd += batch * _attn_flops_per_layer(cfg, seq, backend) * cfg.n_layers
        if cfg.family == "encdec":
            fwd += tokens * _proj_flops_per_layer_token(cfg) * cfg.n_enc_layers
            fwd += batch * _attn_flops_per_layer(cfg, seq, backend, False) \
                * cfg.n_enc_layers
            fwd += 2.0 * cfg.n_layers * batch * seq * seq \
                * cfg.n_heads * 2 * cfg.head_dim
        if cfg.family == "hybrid":
            n_shared = -(-cfg.n_layers // cfg.shared_attn_every)
            fwd += tokens * _shared_block_flops_token(cfg) * n_shared
            fwd += batch * n_shared * 2.0 * cfg.n_heads \
                * (seq * seq / 2) * 2 * (2 * cfg.d_model // cfg.n_heads)
        hbm = chips * w_dev + cache_bytes(cfg, batch, seq) \
            + cfg.n_layers * tokens * cfg.d_model * 2 * 4
        # head runs once per sequence in prefill -> exclude from "useful"
        p_useful = p_active - cfg.d_model * cfg.vocab
        return CellModel(fwd, 2.0 * p_useful * tokens, hbm)

    # decode: one token per sequence
    fwd = batch * (_proj_flops_per_layer_token(cfg) * cfg.n_layers
                   + _head_flops_token(cfg))
    if cfg.family == "hybrid":
        n_shared = -(-cfg.n_layers // cfg.shared_attn_every)
        fwd += batch * _shared_block_flops_token(cfg) * n_shared
    # attention over the cache
    fwd += batch * _decode_attn_flops(cfg, seq, backend)
    model_flops = 2.0 * p_active * batch
    hbm = chips * w_dev + decode_cache_read_bytes(cfg, batch, seq, backend)
    return CellModel(fwd, model_flops, hbm)


def _decode_attn_flops(cfg: ModelConfig, s: int, backend: str) -> float:
    if cfg.family == "ssm":
        ssm = cfg.ssm
        di = ssm.expand * cfg.d_model
        return 2.0 * cfg.n_layers * 3 * di * ssm.d_state
    layers = cfg.n_layers
    if cfg.family == "hybrid":
        layers = -(-cfg.n_layers // cfg.shared_attn_every)
        hq = cfg.n_heads
        dh = 2 * cfg.d_model // hq
        ssm = cfg.ssm
        di = ssm.expand * cfg.d_model
        ssm_f = 2.0 * cfg.n_layers * 3 * di * ssm.d_state
    else:
        hq = cfg.n_heads
        if cfg.mla is not None:
            dh = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        else:
            dh = 2 * cfg.head_dim
        ssm_f = 0.0
    if backend == "clusterkv":
        ck = cfg.clusterkv
        kv = min(ck.decode_clusters * ck.block_k, s)
        sel = s // ck.block_k * (dh // 2)
        per_layer = 2.0 * hq * (kv * dh + sel)
    elif cfg.swa_window and s > cfg.swa_window:
        per_layer = 2.0 * hq * cfg.swa_window * dh
    else:
        per_layer = 2.0 * hq * s * dh
    return layers * per_layer + ssm_f


def cache_bytes(cfg: ModelConfig, batch: int, s: int) -> float:
    if cfg.family == "ssm":
        ssm = cfg.ssm
        di = ssm.expand * cfg.d_model
        return 4.0 * cfg.n_layers * batch * di * ssm.d_state
    if cfg.mla is not None:
        per_tok = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        return 2.0 * cfg.n_layers * batch * s * per_tok
    if cfg.family == "hybrid":
        n_sh = -(-cfg.n_layers // cfg.shared_attn_every)
        ssm = cfg.ssm
        di = ssm.expand * cfg.d_model
        return (2.0 * n_sh * batch * s * 2 * 2 * cfg.d_model
                + 4.0 * cfg.n_layers * batch
                * (di // ssm.head_dim) * ssm.head_dim * ssm.d_state)
    mult = 2 if cfg.family != "encdec" else 4   # enc-dec caches cross KV too
    return 2.0 * mult * cfg.n_layers * batch * s * cfg.n_kv_heads \
        * cfg.head_dim


def analytic_collectives(arch: str, shape_name: str, multi_pod: bool = False,
                         backend: str | None = None, layout: str = "2d",
                         ep: bool = False) -> dict:
    """First-order per-DEVICE collective traffic model (ring factors:
    all-gather/reduce-scatter ~ 1x payload, all-reduce ~ 2x).

    Components: ZeRO-3 param all-gathers (fwd + bwd), gradient
    reduce-scatter, Megatron-style TP all-reduces (2/layer fwd, 2/layer bwd),
    MoE expert-TP psum of the dispatch buffer, cross-pod DP gradient
    reduction (DCN) on multi-pod.
    """
    cfg = get_config(arch)
    seq, batch, kind = SHAPES[shape_name]
    backend = backend or model_api.backend_for(cfg, shape_name)
    chips = 512 if multi_pod else 256
    pbytes = 2 if cfg.param_dtype == "bfloat16" else 4
    gbytes = pbytes                       # grads in param dtype
    p_total = n_params(cfg)
    if layout == "dp_all":
        dp, tp = chips, 1
        # full ZeRO over all chips: gathers move the whole param set
        p_dev_bytes = p_total * pbytes
        g_dev_bytes = p_total * gbytes
    elif layout == "moe_dp" and cfg.moe is not None:
        # experts resident over 'model' (EP); everything else pure DP/ZeRO
        dp, tp = chips, 1
        m = cfg.moe
        p_exp = 3 * cfg.d_model * m.d_ff_expert * m.n_experts * cfg.n_layers
        p_dev_bytes = max(p_total - p_exp, 0) * pbytes
        g_dev_bytes = (max(p_total - p_exp, 0) + p_exp / 16) * gbytes
        ep = True
    elif layout == "serve_tp":
        dp, tp = chips // 16, 16
        p_dev_bytes = 0.0                 # weights resident (TP-only)
        g_dev_bytes = 0.0                 # serving: no grads
    else:
        dp, tp = (32 if multi_pod else 16), 16
        # params are 2D-sharded (fsdp x tp): the ZeRO gather per device
        # only moves that device's TP shard of every param
        p_dev_bytes = p_total * pbytes / tp
        g_dev_bytes = p_total * gbytes / tp
    ep = ep or (cfg.moe is not None and cfg.moe.expert_parallel)
    if ep and layout == "2d":
        # EP: expert weights are stationary (sharded over 'model'), only
        # non-expert params move through ZeRO gathers
        m = cfg.moe
        p_exp = 3 * cfg.d_model * m.d_ff_expert * m.n_experts * cfg.n_layers
        p_dev_bytes = max(p_total - p_exp, 0) * pbytes / tp  # experts resident
        g_dev_bytes = (max(p_total - p_exp, 0) / tp + p_exp / tp) * gbytes
    d = cfg.d_model
    layers = cfg.n_layers + (cfg.n_enc_layers if cfg.family == "encdec" else 0)

    out = {}
    tp_work = tp > 1
    if kind == "train":
        tokens_loc = batch * seq / dp
        out["param_allgather"] = 2.0 * p_dev_bytes               # fwd + bwd
        out["grad_reduce"] = 1.0 * g_dev_bytes                   # reduce-scatter
        out["tp_allreduce"] = (2.0 * 4 * layers * tokens_loc * d * 2
                               if tp_work else 0.0)
        if cfg.family == "hybrid" and tp_work:
            n_sh = -(-cfg.n_layers // cfg.shared_attn_every)
            out["tp_allreduce"] += 2.0 * 4 * n_sh * tokens_loc * (2 * d) * 2
        if cfg.moe is not None:
            m = cfg.moe
            if ep:
                out["moe_alltoall"] = 4.0 * cfg.n_layers * tokens_loc \
                    * m.top_k * 1.25 * d * 2
            elif tp_work:
                out["moe_psum"] = 2.0 * 2 * cfg.n_layers * tokens_loc \
                    * m.top_k * 1.25 * d * 4
    else:
        tokens_loc = (batch * seq if kind == "prefill" else batch) / dp
        out["param_allgather"] = 1.0 * p_dev_bytes
        out["tp_allreduce"] = (2.0 * 2 * layers * tokens_loc * d * 2
                               if tp_work else 0.0)
        if cfg.moe is not None:
            m = cfg.moe
            if ep:
                out["moe_alltoall"] = 2.0 * cfg.n_layers * tokens_loc \
                    * m.top_k * 1.25 * d * 2
            elif tp_work:
                out["moe_psum"] = 2.0 * cfg.n_layers * tokens_loc \
                    * m.top_k * 1.25 * d * 4
        if kind == "decode" and shape_name.startswith("long"):
            # sharded flash-decode partial combine: tiny psum per layer
            out["decode_psum"] = 2.0 * layers * batch * cfg.n_heads * 3 * 4
    out["total"] = sum(out.values())
    return out


def decode_cache_read_bytes(cfg: ModelConfig, batch: int, s: int,
                            backend: str) -> float:
    total = cache_bytes(cfg, batch, s)
    if cfg.family == "ssm":
        return total
    if backend == "clusterkv":
        ck = cfg.clusterkv
        frac = min(ck.decode_clusters * ck.block_k / s, 1.0)
        # centroids are always read: 1/block_k of the cache
        return total * (frac + 1.0 / ck.block_k)
    if cfg.swa_window and s > cfg.swa_window:
        return total * (cfg.swa_window / s)
    return total
