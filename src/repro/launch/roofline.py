"""Roofline analysis (deliverable g): three terms per (arch x shape x mesh).

  compute    = FLOPs / (chips x 197e12)
  memory     = HBM bytes / (chips x 819e9)
  collective = weighted collective bytes / link_bw  (already per-device)

Sources: the dry-run JSON records (results/dryrun/*.json) for the
HLO-derived numbers, scaled for scan-body undercounting (XLA cost analysis
counts while bodies once; 'body'-attributed collectives are multiplied by
the layer-scan trip count), cross-checked against the closed-form analytic
model (launch/analytic.py). FLOPs and HBM bytes use max(HLO-derived,
analytic) — the analytic model is exact for matmul work, the HLO number
catches anything the model misses outside scans.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh pod16x16]
Writes results/roofline.json and prints the table.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, get_config
from repro.core.costmodel import make_report
from repro.launch.analytic import (HBM_BW, LINK_BW, PEAK_FLOPS,
                                   analytic_collectives, cell_model,
                                   n_active_params, n_params)

RESULTS = Path(__file__).resolve().parents[3] / "results"


def scan_trips(arch: str, shape: str) -> int:
    """Trip count of the dominant (layer) scan for body-collective scaling."""
    cfg = get_config(arch)
    if cfg.family == "hybrid":
        return cfg.shared_attn_every          # python loop over groups
    kind = SHAPES[shape][2]
    trips = cfg.n_layers
    if cfg.family == "encdec" and kind != "decode":
        trips = cfg.n_layers + cfg.n_enc_layers
    return trips


def analyse(rec: dict) -> dict:
    arch, shape, mesh = rec["arch"], rec["shape"], rec["mesh"]
    chips = 512 if mesh == "pod2x16x16" else 256
    cfg = get_config(arch)
    model = cell_model(arch, shape, rec.get("backend"),
                       layout=rec.get("layout", "2d"), chips=chips,
                       param_dtype=rec.get("param_dtype"),
                       remat=rec.get("remat"), ep=rec.get("ep", False))

    hlo_flops_dev = (rec.get("cost") or {}).get("flops") or 0.0
    trips = scan_trips(arch, shape)
    hlo_flops_scaled = hlo_flops_dev * trips      # upper-ish bound
    ana_flops_dev = model.flops / chips
    flops_dev = max(ana_flops_dev, min(hlo_flops_scaled, ana_flops_dev * 4)) \
        if hlo_flops_dev else ana_flops_dev

    hbm_dev = model.hbm_bytes / chips
    coll = rec.get("collectives") or {}
    entry_b = (coll.get("entry") or {}).get("weighted_bytes", 0.0)
    body_b = (coll.get("body") or {}).get("weighted_bytes", 0.0)
    coll_hlo = entry_b + body_b * trips          # evidence, body x layer-scan
    coll_ana = analytic_collectives(arch, shape, mesh == "pod2x16x16",
                                    rec.get("backend"),
                                    layout=rec.get("layout", "2d"),
                                    ep=rec.get("ep", False))["total"]
    coll_dev = coll_ana

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    bound = max(t_compute, t_memory, t_coll)
    mf = model.model_flops / chips
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": chips,
        "backend": rec.get("backend"),
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf,
        "hlo_flops_dev_raw": hlo_flops_dev,
        "flops_dev_corrected": flops_dev,
        "coll_bytes_hlo_scaled": coll_hlo,
        "coll_bytes_analytic": coll_ana,
        "useful_ratio": mf / flops_dev if flops_dev else None,
        "roofline_fraction": (mf / PEAK_FLOPS) / bound if bound else None,
        "peak_bytes_dev": (rec.get("memory") or {}).get("peak_bytes"),
        "status": rec["status"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None,
                    help="pod16x16 (default: both)")
    ap.add_argument("--tag", default="", help="analyse tagged variant runs")
    args = ap.parse_args()

    rows = []
    for f in sorted((RESULTS / "dryrun").glob("*.json")):
        parts = f.stem.split("__")
        tag = parts[3] if len(parts) > 3 else ""
        if tag != args.tag:
            continue
        rec = json.loads(f.read_text())
        if rec["status"] != "ok":
            continue
        if args.mesh and rec["mesh"] != args.mesh:
            continue
        rows.append(analyse(rec))

    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    RESULTS.mkdir(parents=True, exist_ok=True)
    out = RESULTS / (f"roofline{('_' + args.tag) if args.tag else ''}.json")
    # same repro.cost/v1 envelope as the autotune cost model reports
    out.write_text(json.dumps(make_report("roofline", {"rows": rows}),
                              indent=2))

    hdr = (f"{'arch':28s} {'shape':12s} {'mesh':10s} {'backend':9s} "
           f"{'compute':>9s} {'memory':>9s} {'collect':>9s} {'dom':>7s} "
           f"{'useful':>6s} {'roof%':>6s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(f"{r['arch']:28s} {r['shape']:12s} {r['mesh']:10s} "
              f"{(r['backend'] or ''):9s} "
              f"{r['compute_s']*1e3:8.2f}m {r['memory_s']*1e3:8.2f}m "
              f"{r['collective_s']*1e3:8.2f}m {r['dominant']:>7s} "
              f"{(r['useful_ratio'] or 0)*100:5.1f}% "
              f"{(r['roofline_fraction'] or 0)*100:5.1f}%")
    print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
