"""Fault tolerance for the step loop: heartbeat + deadline + restart.

On thousands of nodes the failure model is: a host stops making progress
(hardware fault, straggler, preemption). The supervisor here implements the
standard recovery contract around any step function:

  - HEARTBEAT: every completed step stamps a monotonic heartbeat;
  - DEADLINE: a watchdog thread flags the job unhealthy when no step
    completes within ``step_deadline_s`` (straggler mitigation: the
    supervisor aborts the stalled step rather than letting one slow host
    wedge the whole pod);
  - RESTART: ``run`` resumes from the latest checkpoint, and the
    deterministic data pipeline skips ahead by step index, so recovery is
    exactly-once with no data replay bookkeeping;
  - In a real multi-host deployment the abort triggers
    jax.distributed re-initialization on the surviving hosts with a smaller
    data axis (elastic downsize) — restore is elastic by construction
    (checkpoint/ckpt.py re-device_puts onto whatever mesh exists).

The single-process container cannot kill real hosts, so tests exercise the
supervisor with injected faults (see tests/test_ft.py).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional


class StepTimeout(RuntimeError):
    pass


@dataclass
class Supervisor:
    step_deadline_s: float = 600.0
    max_restarts: int = 3
    on_restart: Optional[Callable[[int], None]] = None
    _beat: float = field(default_factory=time.monotonic)
    _healthy: bool = True

    def heartbeat(self) -> None:
        self._beat = time.monotonic()

    def stalled(self) -> bool:
        return (time.monotonic() - self._beat) > self.step_deadline_s

    def run(self, *, n_steps: int, make_state: Callable[[], Any],
            step_fn: Callable[[Any, int], Any],
            save: Callable[[int, Any], None],
            restore: Callable[[], tuple[Any, int]],
            ckpt_every: int = 50) -> Any:
        """Run the loop with restart-from-checkpoint on failure.

        make_state() builds fresh state; restore() -> (state, step) or raises
        FileNotFoundError; step_fn(state, step) -> state (may raise);
        save(step, state) checkpoints.
        """
        restarts = 0
        while True:
            try:
                try:
                    state, start = restore()
                    start += 1
                except FileNotFoundError:
                    state, start = make_state(), 0
                watchdog_stop = threading.Event()

                def watchdog():
                    while not watchdog_stop.is_set():
                        if self.stalled():
                            self._healthy = False
                            return
                        time.sleep(min(self.step_deadline_s / 4, 1.0))

                wt = threading.Thread(target=watchdog, daemon=True)
                self.heartbeat()
                wt.start()
                for step in range(start, n_steps):
                    if not self._healthy:
                        raise StepTimeout(
                            f"no heartbeat for {self.step_deadline_s}s "
                            f"at step {step}")
                    state = step_fn(state, step)
                    self.heartbeat()
                    if ckpt_every and (step + 1) % ckpt_every == 0:
                        save(step, state)
                watchdog_stop.set()
                save(n_steps - 1, state)
                return state
            except Exception:
                restarts += 1
                self._healthy = True
                if restarts > self.max_restarts:
                    raise
                if self.on_restart:
                    self.on_restart(restarts)
