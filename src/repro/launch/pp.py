"""Pipeline parallelism (GPipe-style) over a mesh axis.

Stages live one-per-device along ``axis``; microbatches stream through with
``lax.ppermute`` hops. With M microbatches and S stages the schedule runs
M + S - 1 ticks (bubble fraction (S-1)/(M+S-1)); activations hop
stage->stage instead of weights moving — the collective per tick is one
microbatch of activations per link, the PP trade the roofline notes for
very deep models on slow inter-stage links.

This is the demonstration/ablation path (used by tests and available to
configs with uniform layer stacks); the production cells in EXPERIMENTS.md
use DP/TP/EP/SP, where the fixed (16,16) mesh favors them.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from repro.compat import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_apply(stage_params, x, stage_fn: Callable, mesh: Mesh,
                   axis: str = "model", microbatches: int = 4) -> jax.Array:
    """Apply ``stages`` sequential stages to ``x`` (B, ...) with GPipe.

    stage_params: pytree whose leaves have a leading stage axis of size
    mesh.shape[axis] (sharded over ``axis``: one stage per device).
    stage_fn(local_params, x_mb) -> y_mb, same shape as x_mb.
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % microbatches == 0, "batch must divide into microbatches"
    mb = b // microbatches
    xm = x.reshape((microbatches, mb) + x.shape[1:])

    fwd_perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def local(params_local, xm_local):
        # params_local leaves: (1, ...) — this device's stage
        p_here = jax.tree.map(lambda a: a[0], params_local)
        stage_id = jax.lax.axis_index(axis)
        ticks = microbatches + n_stages - 1

        def tick(carry, t):
            buf, outs = carry            # buf: activation arriving here
            # stage 0 injects microbatch t (when valid)
            inject = jnp.where(t < microbatches, t, microbatches - 1)
            x_in = jnp.where(stage_id == 0, xm_local[inject], buf)
            y = stage_fn(p_here, x_in)
            # last stage records its output for microbatch t-(S-1)
            out_slot = t - (n_stages - 1)
            valid = (out_slot >= 0) & (stage_id == n_stages - 1)
            slot = jnp.clip(out_slot, 0, microbatches - 1)
            outs = jnp.where(
                valid,
                jax.lax.dynamic_update_index_in_dim(outs, y, slot, 0),
                outs)
            buf = jax.lax.ppermute(y, axis, fwd_perm)
            return (buf, outs), None

        buf0 = jnp.zeros_like(xm_local[0])
        outs0 = jnp.zeros_like(xm_local)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(ticks))
        # broadcast final outputs from the last stage to all (psum of masked)
        outs = jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs))
        outs = jax.lax.psum(outs, axis)
        return outs

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(axis), P()),
                  out_specs=P(),
                  check_vma=False)
    ym = f(stage_params, xm)
    return ym.reshape((b,) + x.shape[1:])
