"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@jax.jit
def bsr_spmv_ref(vals: jax.Array, col_idx: jax.Array, x: jax.Array
                 ) -> jax.Array:
    """vals (n_rb, nbr, bs, bs); col_idx (n_rb, nbr); x (n_cb*bs, f)."""
    n_rb, nbr, bs, _ = vals.shape
    xb = x.reshape(-1, bs, x.shape[-1])          # (n_cb, bs, f)
    seg = xb[col_idx]                            # (n_rb, nbr, bs, f)
    y = jnp.einsum("rnij,rnjf->rif", vals, seg)
    return y.reshape(n_rb * bs, -1)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal"))
def block_attention_ref(q, k_sorted, v_sorted, kpos, qpos, idx,
                        *, bq, bk, causal=True):
    """Single-slice oracle matching kernels.block_attention."""
    s, dh = q.shape
    dv = v_sorted.shape[-1]
    nqb = s // bq
    n_sel = idx.shape[-1]
    scale = 1.0 / (dh ** 0.5)
    kb = k_sorted.reshape(-1, bk, dh)
    vb = v_sorted.reshape(-1, bk, dv)
    pb = kpos.reshape(-1, bk)
    out = []
    for i in range(nqb):
        qi = q[i * bq:(i + 1) * bq].astype(jnp.float32)
        ks = kb[idx[i]].reshape(-1, dh).astype(jnp.float32)
        vs = vb[idx[i]].reshape(-1, dv).astype(jnp.float32)
        ps = pb[idx[i]].reshape(-1)
        logit = qi @ ks.T * scale
        if causal:
            ok = ps[None, :] <= qpos[i * bq:(i + 1) * bq][:, None]
            logit = jnp.where(ok, logit, -1e30)
        w = jax.nn.softmax(logit, axis=-1)
        out.append((w @ vs).astype(q.dtype))
    return jnp.concatenate(out, axis=0)


@functools.partial(jax.jit, static_argnames=("sigma",))
def gamma_pairs_ref(coords: jax.Array, sigma: float) -> jax.Array:
    c = coords.astype(jnp.float32)
    d2 = jnp.sum((c[:, None, :] - c[None, :, :]) ** 2, axis=-1)
    return jnp.sum(jnp.exp(-d2 / (sigma * sigma)))


@jax.jit
def tsne_force_ref(p_vals: jax.Array, col_idx: jax.Array, y: jax.Array
                   ) -> jax.Array:
    """Oracle for kernels.tsne_force (pure jnp, same contract)."""
    n_rb, nbr, bs, _ = p_vals.shape
    d = y.shape[-1]
    yb = y.reshape(-1, bs, d)
    ys = yb[col_idx]                                  # (n_rb, nbr, bs, d)
    yt = yb[:n_rb]
    diff = yt[:, None, :, None, :] - ys[:, :, None, :, :]
    q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=-1))
    w = p_vals * q
    f = jnp.einsum("rnts,rntsd->rtd", w, diff)
    return f.reshape(n_rb * bs, d)
