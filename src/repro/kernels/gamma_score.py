"""Pallas TPU kernel: exact gamma-score (paper Eq. 4) pairwise sum.

gamma(A; sigma) = 1/(sigma nnz) * sum_{p,q in Inz} exp(-|p-q|^2 / sigma^2)
over the nonzero coordinates. The O(nnz^2) sum is tiled: grid step (i, j)
stages two (bn, 2) coordinate tiles into VMEM and accumulates the block's
pairwise Gaussian sum into a scalar accumulator (TPU grids execute
sequentially, so the (1, 1) output tile is a legal accumulator).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, q_ref, o_ref, *, sigma):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = p_ref[...].astype(jnp.float32)           # (bn, 2)
    b = q_ref[...].astype(jnp.float32)           # (bn, 2)
    d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
    o_ref[0, 0] += jnp.sum(jnp.exp(-d2 / (sigma * sigma)))


@functools.partial(jax.jit, static_argnames=("sigma", "bn", "interpret"))
def gamma_pairs(coords: jax.Array, sigma: float, bn: int = 256,
                *, interpret: bool = False) -> jax.Array:
    """coords (nnz, 2) float32 (row, col) of nonzeros, padded to bn multiple
    with +inf rows (their pair terms vanish). Returns the raw pairwise sum;
    divide by sigma*nnz for the gamma score."""
    n = coords.shape[0]
    nb = n // bn
    return pl.pallas_call(
        functools.partial(_kernel, sigma=sigma),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(coords, coords)[0, 0]
