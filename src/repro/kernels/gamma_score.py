"""Pallas TPU kernel: exact gamma-score (paper Eq. 4) pairwise sum.

gamma(A; sigma) = 1/(sigma nnz) * sum_{p,q in Inz} exp(-|p-q|^2 / sigma^2)
over the nonzero coordinates. The O(nnz^2) sum is tiled: grid step (i, j)
stages two (bn, 2) coordinate tiles into VMEM and accumulates the block's
pairwise Gaussian sum into a scalar accumulator (TPU grids execute
sequentially, so the (1, 1) output tile is a legal accumulator).

Production features over the bare tiled sum:

* ``weights`` — per-coordinate weights; each pair contributes
  ``w_p * w_q * exp(...)``. Zero-weight entries let callers pad the
  coordinate list to a tile multiple (or carry tombstoned streaming slots)
  without the far-sentinel hack and without perturbing the sum at all.
* ``symmetric=True`` — the Gaussian pair term is symmetric in (p, q), so
  the strict upper triangle of the tile grid is skipped and off-diagonal
  tiles are counted twice: ~2x fewer tiles staged for the same sum (the
  diagonal tile block still evaluates its full bn^2 pairs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(p_ref, q_ref, wp_ref, wq_ref, o_ref, *, sigma, symmetric):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    def tile_sum():
        a = p_ref[...].astype(jnp.float32)           # (bn, 2)
        b = q_ref[...].astype(jnp.float32)           # (bn, 2)
        w = wp_ref[:, 0][:, None] * wq_ref[:, 0][None, :]
        d2 = ((a[:, None, :] - b[None, :, :]) ** 2).sum(-1)
        return jnp.sum(w * jnp.exp(-d2 / (sigma * sigma)))

    if symmetric:
        @pl.when(j <= i)
        def _accum():
            factor = jnp.where(j < i, 2.0, 1.0).astype(jnp.float32)
            o_ref[0, 0] += factor * tile_sum()
    else:
        o_ref[0, 0] += tile_sum()


@functools.partial(jax.jit,
                   static_argnames=("sigma", "bn", "symmetric", "interpret"))
def gamma_pairs(coords: jax.Array, sigma: float, bn: int = 256,
                *, weights: jax.Array | None = None,
                symmetric: bool = False,
                interpret: bool = False) -> jax.Array:
    """coords (nnz, 2) float32 (row, col) of nonzeros, padded to a bn
    multiple — either with far sentinel rows (their pair terms vanish; the
    legacy convention) or with any rows carrying zero ``weights``. Returns
    the raw (weighted) pairwise sum; divide by sigma*nnz (or the weight
    mass) for the gamma score."""
    n = coords.shape[0]
    nb = n // bn
    if weights is None:
        weights = jnp.ones((n,), jnp.float32)
    w2 = weights.astype(jnp.float32)[:, None]        # (n, 1) for tiling
    return pl.pallas_call(
        functools.partial(_kernel, sigma=sigma, symmetric=symmetric),
        grid=(nb, nb),
        in_specs=[
            pl.BlockSpec((bn, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(coords, coords, w2, w2)[0, 0]
