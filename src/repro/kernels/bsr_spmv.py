"""Pallas TPU kernel: ELL-BSR block-sparse matrix x dense (multi-)vector.

The paper's bottom-level "block-segment multiplication" (§2.4) on the MXU:
each grid step stages one dense (bs, bs) tile of A and the (bs, f) charge
segment selected by the scalar-prefetched column index into VMEM, and
accumulates the (bs, f) response tile. Column indices arrive via
PrefetchScalarGridSpec so the index_map — not the kernel body — performs the
indirection (the TPU analog of the paper's indirect block addressing).

Two kernels live here:

* ``bsr_spmv`` — the original single-plan kernel. Grid (n_rb, nbr); the
  index_map performs the segment indirection and the y tile accumulates
  across the inner ELL dimension.
* ``bsr_spmv_batched`` — the batch-grid kernel. Grid (batch member,
  row-superblock, feature tile, ELL slot-chunk); each step keeps the whole
  member's charge block resident in VMEM and performs the column-index
  gather *inside the body* (``pl.ds`` off the resident block), fusing
  gather with the tile contraction so segments and value tiles never
  round-trip through HBM between gather and dot. Several row blocks ride
  one grid step (row-superblocking) and multi-feature charges tile over
  the f axis. B=1 degenerates to the single-plan case.

Bit-parity contract (gates the CPU-container acceptance): the contraction
per (row block, feature tile) mirrors the XLA ``bsr_ml`` batched backend —
``jax.lax.batch_matmul`` over the FULL ELL width summed over slots (f>1),
or the elementwise broadcast-sum form (f==1). Splitting the slot reduction
would reassociate the float sum, so the slot-chunk is always the full ELL
width; memory pressure is relieved via the feature tile instead.

Padding slots carry zero tiles, so no masking is needed in the body; the
same holds for rows padded up to the row-superblock and zero feature
columns padded up to the feature tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[0, 0]                      # (bs, bs)
    x = x_ref[...]                       # (bs, f)
    y_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv(vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             *, interpret: bool = False) -> jax.Array:
    """vals (n_rb, nbr, bs, bs); col_idx (n_rb, nbr) int32; x (n_cb*bs, f).

    Returns y (n_rb*bs, f) = A @ x with A the ELL-BSR matrix.
    """
    n_rb, nbr, bs, _ = vals.shape
    f = x.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rb, nbr),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, j, idx: (i, j, 0, 0)),
            pl.BlockSpec((bs, f), lambda i, j, idx: (idx[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((bs, f), lambda i, j, idx: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb * bs, f), jnp.float32),
        interpret=interpret,
    )(col_idx, vals, x)


def _batch_kernel(idx_ref, vals_ref, x_ref, y_ref, *, rbs, chunk, bs, f1):
    b = pl.program_id(0)
    i = pl.program_id(1)
    t = pl.program_id(3)

    @pl.when(t == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    for r in range(rbs):
        # fused gather: cut every slot's charge segment straight out of the
        # VMEM-resident member charge block (scalar-prefetched indices)
        segs = jnp.stack([
            x_ref[0, pl.ds(idx_ref[b, i * rbs + r, t * chunk + c] * bs, bs), :]
            for c in range(chunk)])                        # (chunk, bs, fc)
        v = vals_ref[0, r]                                 # (chunk, bs, bs)
        if f1:
            # mirror spmv_bsr_ml_batched's elementwise f==1 path bit-for-bit
            y = (v * segs[:, None, :, 0]).sum(axis=(-3, -1))[:, None]
        else:
            y = jax.lax.batch_matmul(v, segs).sum(axis=0)  # (bs, fc)
        y_ref[0, pl.ds(r * bs, bs), :] += y


@functools.partial(jax.jit,
                   static_argnames=("rbs", "chunk", "fc", "interpret"))
def bsr_spmv_batched(vals: jax.Array, col_idx: jax.Array, xs: jax.Array,
                     *, rbs: int = 1, chunk: int | None = None,
                     fc: int | None = None,
                     interpret: bool = False) -> jax.Array:
    """Batch-grid ELL-BSR SpMV/SpMM over stacked same-spec members.

    vals (B, n_rb, nbr, bs, bs); col_idx (B, n_rb, nbr) int32;
    xs (B, n, f) or (B, n) with n a whole number of column blocks.
    Returns (B, n_rb*bs, f) [or (B, n_rb*bs) for 1-D charges].

    ``rbs`` row blocks share one grid step; charges tile to ``fc``
    columns; ``chunk`` must stay the full ELL width for bit parity with
    the XLA backends (see module docstring).
    """
    B, n_rb, nbr, bs, _ = vals.shape
    squeeze = xs.ndim == 2
    if squeeze:
        xs = xs[..., None]
    n = xs.shape[1]
    f = xs.shape[-1]
    f1 = f == 1
    chunk = chunk or max(nbr, 1)
    fc = fc or f

    pad_rb = (-n_rb) % rbs
    if pad_rb:   # zero tiles gathering column block 0 contribute nothing
        vals = jnp.pad(vals, ((0, 0), (0, pad_rb), (0, 0), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, 0), (0, pad_rb), (0, 0)))
    n_rb_p = n_rb + pad_rb
    pad_f = (-f) % fc
    if pad_f:    # zero feature columns are bitwise inert per output column
        xs = jnp.pad(xs, ((0, 0), (0, 0), (0, pad_f)))
    f_p = f + pad_f

    n_sb = n_rb_p // rbs
    n_ch = nbr // chunk
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, n_sb, f_p // fc, n_ch),
        in_specs=[
            pl.BlockSpec((1, rbs, chunk, bs, bs),
                         lambda b, i, fi, t, idx: (b, i, t, 0, 0)),
            # whole member charge block resident; refetched only when the
            # batch member or feature tile changes
            pl.BlockSpec((1, n, fc), lambda b, i, fi, t, idx: (b, 0, fi)),
        ],
        out_specs=pl.BlockSpec((1, rbs * bs, fc),
                               lambda b, i, fi, t, idx: (b, i, fi)),
    )
    kern = functools.partial(_batch_kernel, rbs=rbs, chunk=chunk, bs=bs,
                             f1=f1)
    y = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, n_rb_p * bs, f_p), jnp.float32),
        interpret=interpret,
    )(col_idx, vals, xs)
    y = y[:, :, :f]
    if pad_rb:
        y = y[:, :n_rb * bs]
    return y[..., 0] if squeeze else y
