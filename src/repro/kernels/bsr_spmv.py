"""Pallas TPU kernel: ELL-BSR block-sparse matrix x dense (multi-)vector.

The paper's bottom-level "block-segment multiplication" (§2.4) on the MXU:
each grid step stages one dense (bs, bs) tile of A and the (bs, f) charge
segment selected by the scalar-prefetched column index into VMEM, and
accumulates the (bs, f) response tile. Column indices arrive via
PrefetchScalarGridSpec so the index_map — not the kernel body — performs the
indirection (the TPU analog of the paper's indirect block addressing).

Grid: (n_rb, nbr) — row blocks outer, ELL slots inner; the y tile is
accumulated across the inner dimension and written once.
Padding slots carry zero tiles, so no masking is needed in the body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, a_ref, x_ref, y_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    a = a_ref[0, 0]                      # (bs, bs)
    x = x_ref[...]                       # (bs, f)
    y_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def bsr_spmv(vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             *, interpret: bool = False) -> jax.Array:
    """vals (n_rb, nbr, bs, bs); col_idx (n_rb, nbr) int32; x (n_cb*bs, f).

    Returns y (n_rb*bs, f) = A @ x with A the ELL-BSR matrix.
    """
    n_rb, nbr, bs, _ = vals.shape
    f = x.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rb, nbr),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, j, idx: (i, j, 0, 0)),
            pl.BlockSpec((bs, f), lambda i, j, idx: (idx[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((bs, f), lambda i, j, idx: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb * bs, f), jnp.float32),
        interpret=interpret,
    )(col_idx, vals, x)
