"""Pallas TPU kernel: cluster-block-sparse flash attention.

One (batch, head) slice per pallas_call (vmapped in ops.py): for each query
tile, the scalar-prefetched index list names the top-B cluster-sorted key
tiles; each grid step stages one (bq, dh) q tile, one (bk, dh) k/v tile and
its positions into VMEM, updates the online softmax (m, l, acc) scratch, and
writes the output tile on the last selected block. Causality is enforced
elementwise via the gathered original positions — exactly the contract of
core.clusterkv.sparse_block_attention (the jnp oracle in ref.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, kpos_ref, qpos_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale, causal):
    j = pl.program_id(1)
    n_sel = pl.num_programs(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...].astype(jnp.float32)           # (bq, dh)
    k = k_ref[...].astype(jnp.float32)           # (bk, dh)
    v = v_ref[...].astype(jnp.float32)           # (bk, dv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        ok = kpos_ref[...][None, :] <= qpos_ref[...][:, None]
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = l_scr[...] * alpha + p.sum(axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == n_sel - 1)
    def _fin():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)[:, None]
                      ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret"))
def block_attention(q: jax.Array, k_sorted: jax.Array, v_sorted: jax.Array,
                    kpos: jax.Array, qpos: jax.Array, idx: jax.Array,
                    *, bq: int, bk: int, causal: bool = True,
                    interpret: bool = False) -> jax.Array:
    """q (S, dh); k/v_sorted (S_k, dh) in cluster order; kpos (S_k,) original
    positions; qpos (S,); idx (S/bq, n_sel) int32 selected key tiles.
    Returns (S, dv)."""
    s, dh = q.shape
    dv = v_sorted.shape[-1]
    nqb = s // bq
    n_sel = idx.shape[-1]
    scale = 1.0 / (dh ** 0.5)

    from jax.experimental.pallas import tpu as pltpu

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nqb, n_sel),
        in_specs=[
            pl.BlockSpec((bq, dh), lambda i, j, idx: (i, 0)),
            pl.BlockSpec((bk, dh), lambda i, j, idx: (idx[i, j], 0)),
            pl.BlockSpec((bk, dv), lambda i, j, idx: (idx[i, j], 0)),
            pl.BlockSpec((bk,), lambda i, j, idx: (idx[i, j],)),
            pl.BlockSpec((bq,), lambda i, j, idx: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, dv), lambda i, j, idx: (i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dv), jnp.float32),
        ],
    )
    kern = functools.partial(_kernel, scale=scale, causal=causal)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((s, dv), q.dtype),
        interpret=interpret,
    )(idx, q, k_sorted, v_sorted, kpos, qpos)
