"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic. The wrappers handle batching (vmap over batch/head slices) and
padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.registry import register_backend
from repro.kernels import block_attention as _ba
from repro.kernels import bsr_spmv as _bsr
from repro.kernels import gamma_score as _gs


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@register_backend("pallas")
def _pallas_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    """InteractionPlan SpMV via the Pallas MXU kernel."""
    b = plan.bsr
    return bsr_spmv(b.vals, b.col_idx, x, plan.n)


def bsr_spmv(vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             n: int | None = None) -> jax.Array:
    """ELL-BSR SpMV/SpMM. x (n,) or (n, f); returns same leading length."""
    n_rb, nbr, bs, _ = vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    pad_rows = n_rb * bs - x.shape[0]
    if pad_rows > 0:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
    y = _bsr.bsr_spmv(vals.astype(jnp.float32), col_idx.astype(jnp.int32),
                      x.astype(jnp.float32), interpret=_interpret())
    if n is not None:
        y = y[:n]
    return y[:, 0] if squeeze else y


def block_attention(q, k_sorted, v_sorted, kpos, qpos, idx, *, bq, bk,
                    causal=True):
    """Batched cluster-block-sparse attention.

    q (B,Hq,S,dh); k/v_sorted (B,Hkv,S,dh); kpos (B,Hkv,S); qpos (S,);
    idx (B,Hkv,nqb,n_sel). GQA: q heads grouped onto kv heads."""
    b, hq, s, dh = q.shape
    hkv = k_sorted.shape[1]
    g = hq // hkv
    qg = q.reshape(b * hkv, g, s, dh)
    kf = k_sorted.reshape(b * hkv, s, dh)
    vf = v_sorted.reshape(b * hkv, s, v_sorted.shape[-1])
    pf = kpos.reshape(b * hkv, s)
    idxf = idx.reshape(b * hkv, *idx.shape[2:])

    def one(qs, ks, vs, ps, ix):
        def per_head(qh):
            return _ba.block_attention(qh, ks, vs, ps, qpos, ix,
                                       bq=bq, bk=bk, causal=causal,
                                       interpret=_interpret())
        return jax.vmap(per_head)(qs)

    out = jax.vmap(one)(qg, kf, vf, pf, idxf)
    return out.reshape(b, hq, s, -1)


def gamma_exact(rows: jax.Array, cols: jax.Array, sigma: float,
                bn: int = 256) -> jax.Array:
    """Exact Eq. 4 via the tiled Pallas kernel; pads with far-away points."""
    nnz = rows.shape[0]
    coords = jnp.stack([rows, cols], 1).astype(jnp.float32)
    pad = (-nnz) % bn
    if pad:
        far = jnp.full((pad, 2), 1e9, jnp.float32) \
            + jnp.arange(pad, dtype=jnp.float32)[:, None] * 1e6
        coords = jnp.concatenate([coords, far])
    total = _gs.gamma_pairs(coords, sigma, bn, interpret=_interpret())
    total = total - pad  # each far point contributes exactly its self-pair
    return total / (sigma * nnz)


def tsne_force(p_vals: jax.Array, col_idx: jax.Array, y: jax.Array,
               n: int | None = None) -> jax.Array:
    """Blockwise t-SNE attractive force via the Pallas kernel."""
    from repro.kernels import tsne_force as _tf
    n_rb, nbr, bs, _ = p_vals.shape
    pad = n_rb * bs - y.shape[0]
    yp = jnp.pad(y, ((0, max(pad, 0)), (0, 0))) if pad > 0 else y
    f = _tf.tsne_force(p_vals.astype(jnp.float32),
                       col_idx.astype(jnp.int32),
                       yp.astype(jnp.float32), interpret=_interpret())
    return f[:n] if n is not None else f
