"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True — the kernel
body runs in Python for correctness validation; on TPU they compile to
Mosaic. The wrappers handle batching (vmap over batch/head slices) and
padding; the batch-grid SpMV sizes its tiles from the analytic cost
model's hardware config (``core.costmodel.choose_tiles``).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.core.costmodel import choose_tiles
from repro.core.registry import (register_backend, register_batched_backend,
                                 register_decode_backend)
from repro.kernels import block_attention as _ba
from repro.kernels import bsr_spmv as _bsr
from repro.kernels import decode_attend as _da
from repro.kernels import gamma_score as _gs

# traces of the pallas backends — one per compiled kernel, since the
# backend bodies only run while the enclosing jit is being traced
PALLAS_TRACE_COUNTS = {"batched": 0, "decode": 0}


def _interpret() -> bool:
    if os.environ.get("REPRO_PALLAS_INTERPRET") == "1":
        return True
    return jax.default_backend() == "cpu"


@register_backend("pallas")
def _pallas_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    """InteractionPlan SpMV via the Pallas MXU kernel (batch-grid kernel
    at B=1). Handles (n,) and (n, f) charges and capacity-padded plans —
    dead-slot rows carry zero tiles and stay zero in the output."""
    b = plan.bsr
    y = bsr_spmv_batched(b.vals[None], b.col_idx[None], x[None],
                         shape_key=plan.spec.shape_key)[0]
    return y[:plan.n]


_pallas_backend.interpret_only = _interpret


@register_batched_backend("pallas")
def _pallas_batched(spec, data, xs: jax.Array) -> jax.Array:
    """PlanBatch SpMV: the whole batch in ONE batch-grid kernel."""
    PALLAS_TRACE_COUNTS["batched"] += 1
    return bsr_spmv_batched(data.vals, data.col_idx, xs,
                            shape_key=spec.shape_key)


_pallas_batched.interpret_only = _interpret


def bsr_spmv(vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             n: int | None = None) -> jax.Array:
    """ELL-BSR SpMV/SpMM. x (n,) or (n, f); returns same leading length."""
    n_rb, nbr, bs, _ = vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    pad_rows = n_rb * bs - x.shape[0]
    if pad_rows > 0:
        x = jnp.pad(x, ((0, pad_rows), (0, 0)))
    y = _bsr.bsr_spmv(vals.astype(jnp.float32), col_idx.astype(jnp.int32),
                      x.astype(jnp.float32), interpret=_interpret())
    if n is not None:
        y = y[:n]
    return y[:, 0] if squeeze else y


def bsr_spmv_batched(vals: jax.Array, col_idx: jax.Array, xs: jax.Array,
                     shape_key: tuple | None = None) -> jax.Array:
    """Batched ELL-BSR SpMV/SpMM via the batch-grid kernel.

    vals (B, n_rb, nbr, bs, bs); xs (B, n) or (B, n, f); returns the same
    leading charge length as the XLA batched backends (sliced to n).
    Tile sizes (row-superblock, slot-chunk, feature tile) come from the
    hardware config via ``costmodel.choose_tiles``.
    """
    B, n_rb, nbr, bs, _ = vals.shape
    squeeze = xs.ndim == 2
    if squeeze:
        xs = xs[..., None]
    n = xs.shape[1]
    f = xs.shape[-1]
    # pad charges out to the plan's full column-block range (capacity may
    # exceed the live charge length on capacity-padded plans)
    n_cb = max((n + bs - 1) // bs,
               shape_key[4] if shape_key is not None else 0)
    pad = n_cb * bs - n
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    if shape_key is None:
        shape_key = (n, bs, 8, n_rb, n_cb, nbr)
    rbs, chunk, fc = choose_tiles(shape_key, f)
    y = _bsr.bsr_spmv_batched(vals.astype(jnp.float32),
                              col_idx.astype(jnp.int32),
                              xs.astype(jnp.float32),
                              rbs=rbs, chunk=chunk, fc=fc,
                              interpret=_interpret())
    y = y[:, :n]
    return y[..., 0] if squeeze else y


def block_attention(q, k_sorted, v_sorted, kpos, qpos, idx, *, bq, bk,
                    causal=True):
    """Batched cluster-block-sparse attention.

    q (B,Hq,S,dh); k/v_sorted (B,Hkv,S,dh); kpos (B,Hkv,S); qpos (S,);
    idx (B,Hkv,nqb,n_sel). GQA: q heads grouped onto kv heads."""
    b, hq, s, dh = q.shape
    hkv = k_sorted.shape[1]
    g = hq // hkv
    qg = q.reshape(b * hkv, g, s, dh)
    kf = k_sorted.reshape(b * hkv, s, dh)
    vf = v_sorted.reshape(b * hkv, s, v_sorted.shape[-1])
    pf = kpos.reshape(b * hkv, s)
    idxf = idx.reshape(b * hkv, *idx.shape[2:])

    def one(qs, ks, vs, ps, ix):
        def per_head(qh):
            return _ba.block_attention(qh, ks, vs, ps, qpos, ix,
                                       bq=bq, bk=bk, causal=causal,
                                       interpret=_interpret())
        return jax.vmap(per_head)(qs)

    out = jax.vmap(one)(qg, kf, vf, pf, idxf)
    return out.reshape(b, hq, s, -1)


def decode_attend_fused(q, k, v, pos, cent, qpos, *, n_sel, bk):
    """Fused single-token cluster decode (plain caches).

    Bitwise-identical to ``core.clusterkv.decode_select`` +
    ``decode_attend`` — selection, tile gather, and the guarded softmax
    run in ONE kernel and each selected tile streams HBM exactly once.
    q (B,Hq,dh); k/v (B,Hkv,S,dh|dv); pos (B,Hkv,S); cent (B,Hkv,S/bk,dh);
    qpos scalar or (B,)."""
    PALLAS_TRACE_COUNTS["decode"] += 1
    b, _, dh = q.shape
    hkv = k.shape[1]
    qp = jnp.broadcast_to(jnp.asarray(qpos, jnp.int32), (b,))
    zk = jnp.zeros((b, hkv, dh), k.dtype)
    zv = jnp.zeros((b, hkv, v.shape[-1]), v.dtype)
    return _da.decode_attend_fused(q, k, v, pos, cent, qp, zk, zv,
                                   n_sel=n_sel, bk=bk,
                                   interpret=_interpret())


@register_decode_backend("pallas")
def _pallas_plan_decode(q, ks, vs, ps, cent, qpos, cfg, *,
                        k_self=None, v_self=None):
    """Plan-ordered decode service attend via the fused Mosaic kernel.

    Same contract as the registered ``xla`` decode backend
    (``models.attention._plan_decode_xla``): hole tiles masked out of
    selection, local-window recency boost, optional always-visible self
    column."""
    PALLAS_TRACE_COUNTS["decode"] += 1
    b, _, dh = q.shape
    hkv, s = ks.shape[1], ks.shape[2]
    bk = min(cfg.block_k, s)
    has_self = k_self is not None
    if not has_self:
        k_self = jnp.zeros((b, hkv, dh), ks.dtype)
        v_self = jnp.zeros((b, hkv, vs.shape[-1]), vs.dtype)
    return _da.decode_attend_fused(
        q, ks, vs, ps, cent, qpos.astype(jnp.int32), k_self, v_self,
        n_sel=min(cfg.decode_clusters, s // bk), bk=bk,
        plan_mode=True, has_self=has_self,
        window=cfg.local_window_blocks * bk, interpret=_interpret())


_pallas_plan_decode.interpret_only = _interpret


def gamma_exact(rows: jax.Array, cols: jax.Array, sigma: float,
                bn: int = 256,
                weights: jax.Array | None = None) -> jax.Array:
    """Exact Eq. 4 via the tiled Pallas kernel.

    Pads the coordinate list to a tile multiple with zero-weight entries
    (exactly inert — no far-sentinel correction) and exploits pair
    symmetry to skip the upper tile triangle. ``weights`` supports
    weighted patterns (streaming tombstones carry weight 0)."""
    nnz = rows.shape[0]
    coords = jnp.stack([rows, cols], 1).astype(jnp.float32)
    w = (jnp.ones((nnz,), jnp.float32) if weights is None
         else weights.astype(jnp.float32))
    pad = (-nnz) % bn
    if pad:
        coords = jnp.concatenate([coords, jnp.zeros((pad, 2), jnp.float32)])
        w = jnp.concatenate([w, jnp.zeros((pad,), jnp.float32)])
    total = _gs.gamma_pairs(coords, sigma, bn, weights=w, symmetric=True,
                            interpret=_interpret())
    denom = jnp.float32(nnz) if weights is None else jnp.sum(w)
    return total / (sigma * denom)


def tsne_force(p_vals: jax.Array, col_idx: jax.Array, y: jax.Array,
               n: int | None = None) -> jax.Array:
    """Blockwise t-SNE attractive force via the Pallas kernel (fused
    gather, row-superblocked per the hardware config)."""
    from repro.kernels import tsne_force as _tf
    n_rb, nbr, bs, _ = p_vals.shape
    pad = n_rb * bs - y.shape[0]
    yp = jnp.pad(y, ((0, max(pad, 0)), (0, 0))) if pad > 0 else y
    n_cb = yp.shape[0] // bs
    rbs, _, _ = choose_tiles((yp.shape[0], bs, 8, n_rb, n_cb, nbr),
                             f=y.shape[-1])
    f = _tf.tsne_force(p_vals.astype(jnp.float32),
                       col_idx.astype(jnp.int32),
                       yp.astype(jnp.float32), rbs=rbs,
                       interpret=_interpret())
    return f[:n] if n is not None else f
