"""Pallas TPU kernel: blockwise t-SNE attractive force (paper §3.1).

The paper's iterative hot loop: F_i = sum_j p_ij q_ij (y_i - y_j) with
q_ij = 1/(1 + |y_i - y_j|^2) over the kNN pattern. Values q are recomputed
DENSE per kept tile from the current embedding — the TPU-native
replacement for the per-edge gather loop (DESIGN.md §2).

Same batch-grid shape as ``bsr_spmv.bsr_spmv_batched``: the whole (padded)
embedding stays resident in VMEM and the kernel body cuts both the target
and the scalar-prefetched source segments straight out of it with ``pl.ds``
(fused gather — segments never round-trip HBM between gather and the dense
pairwise arithmetic), while ``rbs`` row blocks ride one grid step to
amortize grid overhead. Rows padded up to the superblock carry zero P
tiles, so their force contributions vanish.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, p_ref, y_ref, f_ref, *, rbs, bs):
    i = pl.program_id(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        f_ref[...] = jnp.zeros_like(f_ref)

    for r in range(rbs):
        p = p_ref[r, 0].astype(jnp.float32)           # (bs, bs)
        rb = i * rbs + r
        yt = y_ref[pl.ds(rb * bs, bs), :].astype(jnp.float32)
        ys = y_ref[pl.ds(idx_ref[rb, j] * bs, bs), :].astype(jnp.float32)
        diff = yt[:, None, :] - ys[None, :, :]        # (bs, bs, d)
        q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=-1))
        w = p * q
        f_ref[pl.ds(r * bs, bs), :] += jnp.einsum(
            "ts,tsd->td", w, diff, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("rbs", "interpret"))
def tsne_force(p_vals: jax.Array, col_idx: jax.Array, y: jax.Array,
               *, rbs: int = 1, interpret: bool = False) -> jax.Array:
    """p_vals (n_rb, nbr, bs, bs); col_idx (n_rb, nbr) int32;
    y (n_cb*bs, d) current embedding (padded to block multiple).
    Returns F (n_rb*bs, d). ``rbs`` row blocks share one grid step."""
    n_rb, nbr, bs, _ = p_vals.shape
    n, d = y.shape

    pad_rb = (-n_rb) % rbs
    if pad_rb:   # zero P tiles: padded rows contribute zero force
        p_vals = jnp.pad(p_vals, ((0, pad_rb), (0, 0), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, pad_rb), (0, 0)))
    n_rb_p = n_rb + pad_rb

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rb_p // rbs, nbr),
        in_specs=[
            pl.BlockSpec((rbs, 1, bs, bs), lambda i, j, idx: (i, j, 0, 0)),
            # the whole embedding stays resident; both segments are cut
            # from it inside the body
            pl.BlockSpec((n, d), lambda i, j, idx: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rbs * bs, d), lambda i, j, idx: (i, 0)),
    )
    f = pl.pallas_call(
        functools.partial(_kernel, rbs=rbs, bs=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb_p * bs, d), jnp.float32),
        interpret=interpret,
    )(col_idx, p_vals, y)
    return f[:n_rb * bs]
