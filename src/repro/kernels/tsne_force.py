"""Pallas TPU kernel: blockwise t-SNE attractive force (paper §3.1).

The paper's iterative hot loop: F_i = sum_j p_ij q_ij (y_i - y_j) with
q_ij = 1/(1 + |y_i - y_j|^2) over the kNN pattern. Values q are recomputed
DENSE per kept tile from the current embedding — per grid step the kernel
stages one (bs, bs) P tile, the target segment and the scalar-prefetched
source segment of y into VMEM, forms the (bs, bs, d) pairwise differences,
and accumulates the (bs, d) force tile. This is the TPU-native replacement
for the per-edge gather loop (DESIGN.md §2): indirect addressing moves to
the index_map, arithmetic is dense.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, p_ref, yt_ref, ys_ref, f_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        f_ref[...] = jnp.zeros_like(f_ref)

    p = p_ref[0, 0].astype(jnp.float32)           # (bs_t, bs_s)
    yt = yt_ref[...].astype(jnp.float32)          # (bs_t, d)
    ys = ys_ref[...].astype(jnp.float32)          # (bs_s, d)
    diff = yt[:, None, :] - ys[None, :, :]        # (bs_t, bs_s, d)
    q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=-1))
    w = p * q
    f_ref[...] += jnp.einsum("ts,tsd->td", w, diff,
                             preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tsne_force(p_vals: jax.Array, col_idx: jax.Array, y: jax.Array,
               *, interpret: bool = False) -> jax.Array:
    """p_vals (n_rb, nbr, bs, bs); col_idx (n_rb, nbr) int32;
    y (n_cb*bs, d) current embedding (padded to block multiple).
    Returns F (n_rb*bs, d)."""
    n_rb, nbr, bs, _ = p_vals.shape
    d = y.shape[-1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_rb, nbr),
        in_specs=[
            pl.BlockSpec((1, 1, bs, bs), lambda i, j, idx: (i, j, 0, 0)),
            pl.BlockSpec((bs, d), lambda i, j, idx: (i, 0)),
            pl.BlockSpec((bs, d), lambda i, j, idx: (idx[i, j], 0)),
        ],
        out_specs=pl.BlockSpec((bs, d), lambda i, j, idx: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rb * bs, d), jnp.float32),
        interpret=interpret,
    )(col_idx, p_vals, y, y)
