"""Pallas TPU kernel: fused single-token cluster decode attention.

The decode-side twin of ``bsr_spmv``'s batch-grid kernel. The unfused XLA
path pays two dispatches per tick (``decode_select`` top-k, then
``decode_attend``'s vmapped tile gather) and the gather materializes the
selected k/v tiles back through HBM before the attend reads them again.
This kernel runs the whole chain per (batch member, kv head) grid step:

  centroid scoring -> top-c tile selection -> selected-tile DMA gather
  -> masked-softmax attend

so each selected tile streams from HBM exactly once, straight into VMEM
scratch (``pltpu.make_async_copy`` off the ``ANY``-space cache refs), and
nothing else of the cache moves at all. Per-slot decode positions arrive
via the ``PrefetchScalarGridSpec`` scalar-prefetch channel, the same
pattern that feeds ``bsr_spmv`` its column indices.

Two static contracts share the body:

* plain mode (``plan_mode=False``) — bitwise-identical to the pure-JAX
  ``core.clusterkv.decode_select`` + ``decode_attend`` pair (the
  CPU-container acceptance gate, asserted in interpret mode): raw
  centroid scores, ``lax.top_k`` tie semantics via iterative first-argmax,
  one guarded softmax over the concatenated selection.
* plan mode (``plan_mode=True``) — the decode service's
  ``clusterkv_plan_decode`` contract over plan-ordered caches: hole tiles
  (all positions > qpos) are masked out of selection, the local-window
  recency boost keeps the causal frontier, and the current token's own
  k/v ride an always-visible extra column (``has_self``).

Bit-parity notes (same discipline as ``bsr_spmv``): the selection scores,
gather order, and the single softmax over the concatenated ``c*bk`` axis
mirror the reference op for op — an online softmax across tiles would
reassociate the normalizer sum and break the bitwise gate. ``lax.top_k``
orders descending with ties to the LOWEST index; n_sel rounds of
min-index-of-max with mask-out replicate that exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_BIG = 2 ** 31 - 1


def _kernel(qpos_ref, q_ref, cent_ref, ps_ref, k_ref, v_ref, kself_ref,
            vself_ref, o_ref, k_scr, v_scr, k_sem, v_sem, *, n_sel, bk,
            nkb, dh, dv, plan_mode, has_self, window):
    b = pl.program_id(0)
    h = pl.program_id(1)
    qp = qpos_ref[b]

    # -- centroid scoring (mirrors decode_select / clusterkv_plan_decode) --
    qf = q_ref[0, 0].astype(jnp.float32)              # (g, dh)
    qm = jnp.mean(qf, axis=0)                         # grouped query
    cent = cent_ref[0, 0].astype(jnp.float32)         # (nkb, dh)
    # multiply+reduce mirrors ckv.decode_select's batching-stable scoring
    scores = jnp.sum(cent * qm[None, :], -1).reshape(1, nkb)
    pt = ps_ref[0, 0].reshape(nkb, bk)                # int32 positions
    if plan_mode:
        live = pt <= qp                               # causal AND not-a-hole
        tile_has = live.any(-1).reshape(1, nkb)
        scores = jnp.where(tile_has, scores, NEG_INF)
        recent = jnp.where(live, pt, -1).max(-1).reshape(1, nkb)
        near = recent >= qp - window
        scores = jnp.where(near & tile_has, scores + 1e4, scores)

    # -- top-c selection: n_sel rounds of first-argmax with mask-out ------
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, nkb), 1)
    sel = []
    cur = scores
    for _ in range(n_sel):
        t = jnp.min(jnp.where(cur == jnp.max(cur), iota, nkb))
        sel.append(t)
        cur = jnp.where(iota == t, -jnp.inf, cur)

    # -- DMA-gather the selected tiles HBM -> VMEM scratch, overlapped ----
    for j, t in enumerate(sel):
        pltpu.make_async_copy(k_ref.at[b, h, pl.ds(t * bk, bk), :],
                              k_scr.at[pl.ds(j * bk, bk), :],
                              k_sem.at[j]).start()
        pltpu.make_async_copy(v_ref.at[b, h, pl.ds(t * bk, bk), :],
                              v_scr.at[pl.ds(j * bk, bk), :],
                              v_sem.at[j]).start()
    for j, t in enumerate(sel):
        pltpu.make_async_copy(k_ref.at[b, h, pl.ds(t * bk, bk), :],
                              k_scr.at[pl.ds(j * bk, bk), :],
                              k_sem.at[j]).wait()
        pltpu.make_async_copy(v_ref.at[b, h, pl.ds(t * bk, bk), :],
                              v_scr.at[pl.ds(j * bk, bk), :],
                              v_sem.at[j]).wait()
    ksel = k_scr[...]                                 # (n_sel*bk, dh)
    vsel = v_scr[...]                                 # (n_sel*bk, dv)
    psel = jnp.concatenate(
        [jax.lax.dynamic_index_in_dim(pt, t, 0, keepdims=False)
         for t in sel])
    if plan_mode:
        spos = qp if has_self else jnp.int32(_BIG)
        ksel = jnp.concatenate([ksel, kself_ref[0, 0][None, :]], axis=0)
        vsel = jnp.concatenate([vsel, vself_ref[0, 0][None, :]], axis=0)
        psel = jnp.concatenate([psel, jnp.full((1,), spos, jnp.int32)])

    # -- one guarded softmax over the whole selection (see _masked_softmax
    # in core.clusterkv: bitwise jax.nn.softmax whenever a column is live,
    # exact zeros when the selection is empty) ----------------------------
    # einsum, not ``qf @ ksel.T``: the reference computes this matmul
    # under vmap, whose batched dot_general contracts d without
    # materializing the transpose, and the transposed per-slice form
    # rounds differently on XLA:CPU. g == 1 pads the query row to M=2
    # (mirroring ckv.decode_logits/decode_combine): an M=1 dot is
    # strength-reduced by XLA:CPU with fusion-context-dependent rounding,
    # while the padded GEMM is bit-stable per-slice vs vmapped.
    kf = ksel.astype(jnp.float32)
    vf = vsel.astype(jnp.float32)
    scale = jnp.sqrt(jnp.asarray(dh, jnp.float32))
    g = qf.shape[0]
    qpad = jnp.concatenate([qf, qf], axis=0) if g == 1 else qf
    logit = (jnp.einsum("gd,cd->gc", qpad, kf) / scale)[:g]
    mask = psel[None, :] <= qp
    logit = jnp.where(mask, logit, NEG_INF)
    m = jnp.max(logit, axis=-1, keepdims=True)
    e = jnp.exp(logit - jax.lax.stop_gradient(m))
    e = jnp.where(mask, e, 0.0)
    w = e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)
    wpad = jnp.concatenate([w, w], axis=0) if g == 1 else w
    o_ref[0, 0] = (wpad @ vf)[:g].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_sel", "bk", "plan_mode",
                                             "has_self", "window",
                                             "interpret"))
def decode_attend_fused(q, k, v, pos, cent, qpos, k_self, v_self, *,
                        n_sel: int, bk: int, plan_mode: bool = False,
                        has_self: bool = False, window: int = 0,
                        interpret: bool = False) -> jax.Array:
    """Fused select+gather+attend. q (B,Hq,dh); k/v (B,Hkv,S,dh|dv);
    pos (B,Hkv,S) int32; cent (B,Hkv,S/bk,dh); qpos (B,) int32;
    k_self/v_self (B,Hkv,dh|dv) (ignored unless ``plan_mode`` and
    ``has_self``). Returns (B,Hq,dv) in q's dtype."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    dv = v.shape[-1]
    nkb = s // bk
    if s % bk or nkb < n_sel:
        raise ValueError(f"cache length {s} needs {n_sel} whole {bk}-tiles")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda bi, hi, qp: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, nkb, dh),
                         lambda bi, hi, qp: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s), lambda bi, hi, qp: (bi, hi, 0)),
            # the caches stay in HBM; only selected tiles are DMA'd
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY),
            pl.BlockSpec((1, 1, dh), lambda bi, hi, qp: (bi, hi, 0)),
            pl.BlockSpec((1, 1, dv), lambda bi, hi, qp: (bi, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dv),
                               lambda bi, hi, qp: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((n_sel * bk, dh), k.dtype),
            pltpu.VMEM((n_sel * bk, dv), v.dtype),
            pltpu.SemaphoreType.DMA((n_sel,)),
            pltpu.SemaphoreType.DMA((n_sel,)),
        ],
    )
    kern = functools.partial(_kernel, n_sel=n_sel, bk=bk, nkb=nkb, dh=dh,
                             dv=dv, plan_mode=plan_mode, has_self=has_self,
                             window=window)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dv), q.dtype),
        interpret=interpret,
    )(qpos.astype(jnp.int32), q.reshape(b, hkv, g, dh), cent,
      pos.astype(jnp.int32), k, v, k_self, v_self)
    return out.reshape(b, hq, dv)
