"""ClusterKV decode service: plans as first-class serving state.

  session    Session / SessionStore — per-session key plans keyed by spec
  streaming  LockstepInserter — batched insert-tier streaming of generated
             tokens into every (layer, head) plan without re-sorting
  engine     ClusterKVEngine — continuous batching over plan-ordered caches
"""
from repro.serve.session import Session, SessionStore
from repro.serve.engine import ClusterKVEngine

__all__ = ["Session", "SessionStore", "ClusterKVEngine"]
