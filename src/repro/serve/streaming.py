"""Host-side insert streaming for the decode service.

Every generated token must enter its session's per-head key plans (the
PR 4 insert tier) WITHOUT re-running the Morton sort — and without paying
one ``api.update_plan`` round trip per (layer, head) per tick, which would
cost dozens of tiny device dispatches per generated token. The inserter
keeps device mirrors of the per-member embedding frames and point sets, so
a whole tick of insertions costs:

  one jitted batched call     embed + live-candidate kNN for every
                              (layer, slot, head) member at once
  one stacked numpy pass      the Morton-leaf slot claims for ALL
                              L*B*H members (``claim_slots_batched`` —
                              the exact ``update_plan`` placement
                              arithmetic, vectorized over members)
  one jitted scatter          fold the landed rows into the mirrors

Host plan state (``alive``/``codes``/coordinates/refresh telemetry) is
mutated in place on the member ``_PlanHost`` objects. That is sound
because the append tier never reorders: the PlanBatch's stacked device
``data.pi/inv`` stay valid, and only ``data.alive`` goes stale (decode
liveness is carried by the engine's ``ps`` state instead, and every
trim/rebucket rebuilds the stack).

kNN edges are BUFFERED per engine slot and folded into the host COO by
:meth:`LockstepInserter.flush` — which the engine calls before anything
that reads the COO (trim, rebucket, checkpoint).

Documented deviations from ``update_plan``'s insert tier (the claim
arithmetic itself is replicated exactly — see ``test_serve.py``):
  - each arrival's kNN is taken against the pre-insert live set (one
    point per member per tick, so the batch-mate interactions
    ``update_plan`` resolves never arise, but the arrival also never
    picks a same-tick sibling);
  - reverse adoption (``api._adopt_arrivals``) is skipped — decode never
    reads the COO, and the next compaction re-exactifies the pattern;
  - edge folding is deferred to :meth:`flush`.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy


# -- batched Morton codes with per-member boxes ------------------------------
#
# ``hierarchy.morton_codes_box`` quantizes against ONE box; members each
# have their own frozen box, and calling it member-by-member would be
# L*B*H tiny jit dispatches per tick. The quantization is elementwise, so
# a numpy replica with broadcast boxes is bitwise-identical per row.


def _np_part1by1(v: np.ndarray) -> np.ndarray:
    v = v & np.uint32(0xFFFF)
    v = (v | (v << 8)) & np.uint32(0x00FF00FF)
    v = (v | (v << 4)) & np.uint32(0x0F0F0F0F)
    v = (v | (v << 2)) & np.uint32(0x33333333)
    v = (v | (v << 1)) & np.uint32(0x55555555)
    return v


def _np_part1by2(v: np.ndarray) -> np.ndarray:
    v = v & np.uint32(0x3FF)
    v = (v | (v << 16)) & np.uint32(0x030000FF)
    v = (v | (v << 8)) & np.uint32(0x0300F00F)
    v = (v | (v << 4)) & np.uint32(0x030C30C3)
    v = (v | (v << 2)) & np.uint32(0x09249249)
    return v


def morton_codes_boxes(y: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                       bits: int) -> np.ndarray:
    """Row-wise :func:`hierarchy.morton_codes_box`: ``y``/``lo``/``hi`` all
    (..., d), each row quantized against its own box. Returns uint64."""
    y = np.asarray(y, np.float32)
    d = y.shape[-1]
    b = hierarchy.eff_bits(d, bits)
    span = np.maximum(hi - lo, np.float32(1e-30)).astype(np.float32)
    q = np.clip((y - lo) / span * (2 ** b - 1), 0, 2 ** b - 1
                ).astype(np.uint32)
    if d == 1:
        code = q[..., 0]
    elif d == 2:
        code = _np_part1by1(q[..., 0]) | (_np_part1by1(q[..., 1]) << 1)
    elif d == 3:
        code = (_np_part1by2(q[..., 0])
                | (_np_part1by2(q[..., 1]) << 1)
                | (_np_part1by2(q[..., 2]) << 2))
    else:
        raise ValueError(f"morton codes support d<=3, got d={d}")
    return code.astype(np.uint64)


def claim_slot(host, code: np.uint64) -> int:
    """Claim the free plan slot nearest a single arrival's Morton leaf —
    ``update_plan``'s ``insertion_positions`` + ``claim_free_slots``
    arithmetic specialized to one insert (no list churn). Returns the
    claimed PHYSICAL row.

    Reference semantics for :func:`claim_slots_batched` (which the
    per-tick insert path uses — one call for all L*B*H members instead
    of one Python claim per member); kept for tests and benchmarks."""
    in_order = host.codes[host.pi]
    free_pos = np.nonzero(~host.alive[host.pi])[0]
    if free_pos.size == 0:
        raise ValueError("no free plan slots; session outgrew its capacity")
    env = np.maximum.accumulate(in_order)
    t = int(np.searchsorted(env, code))
    j = int(np.searchsorted(free_pos, t))      # == bisect_left(free, t)
    if j == len(free_pos):
        j -= 1
    elif j > 0 and t - free_pos[j - 1] <= free_pos[j] - t:
        j -= 1
    return int(host.pi[free_pos[j]])


CLAIM_BLOCK = 128        # block-maxima granularity of the two-level search


def claim_slots_batched(codes_io: np.ndarray, alive_io: np.ndarray,
                        codes: np.ndarray,
                        block_max: np.ndarray | None = None) -> np.ndarray:
    """Vectorized :func:`claim_slot` over M stacked members.

    ``codes_io``/``alive_io`` (M, C) are each member's codes/liveness IN
    PLAN ORDER (``host.codes[host.pi]`` / ``host.alive[host.pi]``);
    ``codes`` (M,) the arrival Morton codes. Returns the claimed IN-ORDER
    positions (M,) int64 — callers map to physical rows via ``host.pi``.
    ``block_max`` (M, C/CLAIM_BLOCK), if given, is the per-block maximum
    of ``codes_io`` — a mirror the streaming inserter maintains
    incrementally so the search never rescans the full code arrays.

    Exactly the scalar arithmetic, restructured so the per-tick cost is
    far below M scalar claims:

    * the sorted-envelope ``searchsorted`` needs no cumulative max at
      all — ``env[j] < code`` iff every code through ``j`` is below it,
      so the target ``t`` is just the FIRST in-order position whose code
      is ``>= code`` (one stacked comparison + argmax, no per-member
      gather of ``host.codes[host.pi]``);
    * the nearest-free bisect only ever resolves within ``t``'s
      neighborhood, so the free mask is gathered in a +-W window around
      ``t``. A window miss on a side can never flip the scalar
      tie-break (the in-window candidate is closer by construction than
      anything beyond the window), and members with no free slot within
      the window at all — vanishingly rare at serving occupancies —
      fall back to the scalar bisect.

    Each member is an independent host, so one tick's claims never
    interact and the batch is exact."""
    m, c = codes_io.shape
    free = ~alive_io
    if not free.any(axis=1).all():
        raise ValueError("no free plan slots; session outgrew its capacity")
    rows = np.arange(m)
    bs = CLAIM_BLOCK
    if c % bs == 0 and c >= 2 * bs:
        # two-level: per-block maxima narrow the first >= code to one
        # block per member, so only that block's codes are compared
        bm = (block_max if block_max is not None
              else codes_io.reshape(m, c // bs, bs).max(axis=2))
        gb = bm >= codes[:, None]
        blk = gb.argmax(axis=1)
        ge = codes_io[rows[:, None],
                      blk[:, None] * bs + np.arange(bs)] >= codes[:, None]
        t = blk * bs + ge.argmax(axis=1)
        t = np.where(gb[rows, blk], t, c).astype(np.int64)
    else:
        ge = codes_io >= codes[:, None]
        t = ge.argmax(axis=1).astype(np.int64)
        t = np.where(ge[rows, t], t, c)            # all-below rows -> c
    w = min(128, c)
    cols = t[:, None] + np.arange(-w, w)           # positions t-w .. t+w-1
    fw = (free[rows[:, None], np.clip(cols, 0, c - 1)]
          & (cols >= 0) & (cols < c))
    fl, fr = fw[:, :w], fw[:, w:]
    has_l, has_r = fl.any(axis=1), fr.any(axis=1)
    pf = np.where(has_l, t - 1 - np.argmax(fl[:, ::-1], axis=1), -1)
    nf = np.where(has_r, t + np.argmax(fr, axis=1), c)
    use_pf = (nf >= c) | ((pf >= 0) & (t - pf <= nf - t))
    chosen = np.where(use_pf, pf, nf)
    for i in np.nonzero(~(has_l | has_r))[0]:      # no free within +-w
        fp = np.nonzero(free[i])[0]
        j = int(np.searchsorted(fp, t[i]))
        if j == len(fp):
            j -= 1
        elif j > 0 and t[i] - fp[j - 1] <= fp[j] - t[i]:
            j -= 1
        chosen[i] = fp[j]
    return chosen.astype(np.int64)


@functools.partial(jax.jit, static_argnames=("knn",))
def _embed_knn(k_new, mean, axes, x, alive, knn: int):
    """Batched §2.4 step-1 embed + exact kNN against the live mirror.

    k_new (L,B,H,dh); mean (L,B,H,dh); axes (L,B,H,dh,d);
    x (L,B,H,C,dh); alive (L,B,H,C). Returns (y, idx, d2)."""
    y = jnp.einsum("lbhd,lbhde->lbhe", k_new - mean, axes)
    d2 = jnp.sum((x - k_new[..., None, :]) ** 2, axis=-1)
    d2 = jnp.where(alive, d2, jnp.inf)
    neg, idx = jax.lax.top_k(-d2, knn)
    return y, idx, -neg


@jax.jit
def _land(x, alive, k_new, phys):
    """Scatter landed rows into the mirrors. phys (L,B,H) int32 with the
    capacity sentinel (== C, out of bounds) marking inactive lanes."""
    l, b, h = phys.shape
    li = jnp.arange(l)[:, None, None]
    bi = jnp.arange(b)[None, :, None]
    hi = jnp.arange(h)[None, None, :]
    x = x.at[li, bi, hi, phys].set(k_new, mode="drop")
    alive = alive.at[li, bi, hi, phys].set(True, mode="drop")
    return x, alive


class LockstepInserter:
    """Streams one generated key per (layer, head) member per tick into
    every attached session's plans, in lockstep across engine slots."""

    def __init__(self, n_layers: int, slots: int, n_heads: int,
                 capacity: int, head_dim: int, embed_d: int, knn: int):
        self.L, self.B, self.H = n_layers, slots, n_heads
        self.C, self.dh, self.d = capacity, head_dim, embed_d
        self.knn = knn
        self._mean = jnp.zeros((self.L, self.B, self.H, head_dim))
        self._axes = jnp.zeros((self.L, self.B, self.H, head_dim, embed_d))
        self._x = jnp.zeros((self.L, self.B, self.H, capacity, head_dim))
        self._alive = jnp.zeros((self.L, self.B, self.H, capacity), bool)
        # per-member frozen quantization boxes (host-side, tiny)
        self._lo = np.zeros((self.L, self.B, self.H, embed_d), np.float32)
        self._hi = np.ones((self.L, self.B, self.H, embed_d), np.float32)
        # host-side stacked claim state, IN PLAN ORDER per member — the
        # inputs of claim_slots_batched. Staged at attach, updated in
        # place on every claim so they stay exact mirrors of
        # host.codes[host.pi] / host.alive[host.pi] / host.pi.
        self._pi_io = np.zeros((self.L, self.B, self.H, capacity), np.int64)
        self._codes_io = np.zeros((self.L, self.B, self.H, capacity),
                                  np.uint64)
        self._alive_io = np.zeros((self.L, self.B, self.H, capacity), bool)
        # incrementally-maintained per-block code maxima (the two-level
        # claim search's upper tier); None when capacity doesn't tile
        self._bmax_io = (
            np.zeros((self.L, self.B, self.H, capacity // CLAIM_BLOCK),
                     np.uint64)
            if capacity % CLAIM_BLOCK == 0 and capacity >= 2 * CLAIM_BLOCK
            else None)
        self._plans: List[Optional[list]] = [None] * slots
        # slot -> list of per-tick records ((L,H) phys, (L,H,knn) nbr_idx,
        # (L,H,knn) nbr_d2); one append per slot per tick, folded by flush
        # in a single concatenation pass
        self._buf: Dict[int, list] = {}
        self._bits: Optional[int] = None
        # plan generation each slot was attached at: claims mutate the
        # member hosts in place, which is only sound against the exact
        # plan objects staged at attach time — a double-buffer swap (or
        # trim/rebucket/restore) replaces them and must re-attach with
        # the incoming generation
        self._gen: List[int] = [0] * slots

    # -- session lifecycle --------------------------------------------------

    def attach(self, slot: int, plans: list, generation: int = 0) -> None:
        """Bind a session's per-layer plan batches to an engine slot and
        stage their frames/points into the device mirrors.

        Re-attach after any operation that replaced the member hosts
        (trim, rebucket, restore, a double-buffer swap), passing the
        plans' current ``generation`` — later claims are validated
        against it, so an insert streamed at a stale generation raises
        instead of silently mutating hosts the serving plan no longer
        reads."""
        from repro import api

        cfg = plans[0].spec.config
        self._bits = cfg.bits
        mean = np.zeros((self.L, self.H, self.dh), np.float32)
        axes = np.zeros((self.L, self.H, self.dh, self.d), np.float32)
        xs = np.zeros((self.L, self.H, self.C, self.dh), np.float32)
        alv = np.zeros((self.L, self.H, self.C), bool)
        for l, pb in enumerate(plans):
            for h, host in enumerate(pb.hosts):
                if host.codes is None:
                    # first streamed insert of this lineage: freeze the
                    # quantization box + seed hole codes, exactly as
                    # update_plan would lazily
                    codes, lo, hi = api._stream_codes(host, cfg)
                    host.codes, host.code_lo, host.code_hi = codes, lo, hi
                mean[l, h] = host.embed_mean
                axes[l, h] = host.embed_axes
                xs[l, h] = host.x
                alv[l, h] = host.alive
                self._lo[l, slot, h] = host.code_lo
                self._hi[l, slot, h] = host.code_hi
                self._pi_io[l, slot, h] = host.pi
                self._codes_io[l, slot, h] = host.codes[host.pi]
                self._alive_io[l, slot, h] = host.alive[host.pi]
                if self._bmax_io is not None:
                    self._bmax_io[l, slot, h] = self._codes_io[
                        l, slot, h].reshape(-1, CLAIM_BLOCK).max(axis=1)
        self._mean = self._mean.at[:, slot].set(jnp.asarray(mean))
        self._axes = self._axes.at[:, slot].set(jnp.asarray(axes))
        self._x = self._x.at[:, slot].set(jnp.asarray(xs))
        self._alive = self._alive.at[:, slot].set(jnp.asarray(alv))
        self._plans[slot] = plans
        self._gen[slot] = generation

    def generation(self, slot: int) -> int:
        """The plan generation ``slot`` was last attached at."""
        return self._gen[slot]

    def detach(self, slot: int) -> None:
        self._plans[slot] = None
        self._alive = self._alive.at[:, slot].set(False)
        self._alive_io[:, slot] = False
        self._buf.pop(slot, None)

    # -- the per-tick insert ------------------------------------------------

    def insert(self, active: List[int], k_new,
               generations: Optional[Dict[int, int]] = None) -> np.ndarray:
        """Stream one key per (layer, head) member of every active slot.

        ``k_new`` (L, B, H, dh) device array (inactive lanes ignored).
        Claims a plan slot per member via the exact update_plan placement,
        mutates the member hosts in place, buffers the arrivals' kNN
        edges, and refreshes the device mirrors. Returns the claimed
        PHYSICAL rows (L, B, H) int64, -1 on inactive lanes.

        ``generations`` (slot -> caller's current plan generation)
        validates each claim against the generation the slot was attached
        at: after a double-buffer swap replaced a session's plans, a
        claim against the stale attachment raises ``RuntimeError``
        instead of mutating hosts the serving plan no longer reads —
        re-attach with the incoming generation first."""
        if generations is not None:
            for s in active:
                got = generations.get(s, self._gen[s])
                if got != self._gen[s]:
                    raise RuntimeError(
                        f"slot {s} plans are at generation {got} but the "
                        f"inserter was attached at {self._gen[s]}; "
                        "re-attach after a plan swap before streaming")
        for s in active:
            if self._plans[s] is None:
                raise ValueError(f"slot {s} has no attached session")
        y, nidx, nd2 = _embed_knn(k_new, self._mean, self._axes,
                                  self._x, self._alive, self.knn)
        y_np = np.asarray(y, np.float32)
        k_np = np.asarray(k_new, np.float32)
        nidx_np, nd2_np = np.asarray(nidx), np.asarray(nd2, np.float32)
        codes = morton_codes_boxes(y_np, self._lo, self._hi, self._bits)

        phys = np.full((self.L, self.B, self.H), -1, np.int64)
        if active:
            # one stacked claim pass for every (layer, slot, head) member
            sl = np.asarray(active, np.int64)
            m = self.L * len(active) * self.H
            chosen = claim_slots_batched(
                self._codes_io[:, sl].reshape(m, self.C),
                self._alive_io[:, sl].reshape(m, self.C),
                codes[:, sl].reshape(m),
                block_max=(None if self._bmax_io is None else
                           self._bmax_io[:, sl].reshape(m, -1)))
            li, si, hi = [ix.reshape(m) for ix in np.meshgrid(
                np.arange(self.L), sl, np.arange(self.H), indexing="ij")]
            p_all = self._pi_io[li, si, hi, chosen]
            phys[li, si, hi] = p_all
            # keep the in-order mirrors exact: the claimed position turns
            # alive and takes the arrival's code (host.codes[p] below is
            # the same mutation seen through host.pi)
            self._alive_io[li, si, hi, chosen] = True
            self._codes_io[li, si, hi, chosen] = codes[li, si, hi]
            if self._bmax_io is not None:
                # overwriting a hole's seed code can RAISE OR LOWER its
                # block max; recompute just the touched blocks
                blk = chosen // CLAIM_BLOCK
                seg = self._codes_io[
                    li[:, None], si[:, None], hi[:, None],
                    (blk * CLAIM_BLOCK)[:, None] + np.arange(CLAIM_BLOCK)]
                self._bmax_io[li, si, hi, blk] = seg.max(axis=1)

        for s in active:
            plans = self._plans[s]
            for l, pb in enumerate(plans):
                for h, host in enumerate(pb.hosts):
                    p = int(phys[l, s, h])
                    prev = int(host.alive.sum())
                    host.alive[p] = True
                    host.x[p] = k_np[l, s, h]
                    host.embedding[p] = y_np[l, s, h]
                    if host.y_last is not None:
                        host.y_last[p] = y_np[l, s, h]
                    host.codes[p] = codes[l, s, h]
                    host.peak_alive = max(host.peak_alive or 0, prev + 1)
                    host.last_inserted_idx = np.asarray([p], np.int64)
                    host.gamma = None
                    host.compact_map = None
                    host.shard_cache = {}
                    host.refresh = dataclasses.replace(
                        host.refresh,
                        appends=host.refresh.appends + 1,
                        inserted_total=host.refresh.inserted_total + 1,
                        last_action="append")
            self._buf.setdefault(s, []).append(
                (phys[:, s].copy(), nidx_np[:, s], nd2_np[:, s]))

        sentinel = np.where(phys < 0, self.C, phys).astype(np.int32)
        self._x, self._alive = _land(self._x, self._alive, k_new,
                                     jnp.asarray(sentinel))
        return phys

    # -- COO folding --------------------------------------------------------

    def flush(self, slot: int) -> int:
        """Fold the slot's buffered kNN edges into each member's host COO
        (cluster space, current ordering). Call before anything that reads
        or rewrites the COO: trim, rebucket, checkpoint. Returns the number
        of edges folded.

        The buffer holds one record per tick; stacking them gives each
        member its whole backlog as one (T*knn,) slab, so the fold is a
        single concatenation pass per member instead of per-tick list
        churn."""
        from repro import api

        plans = self._plans[slot]
        ticks = self._buf.pop(slot, [])
        if not ticks or plans is None:
            return 0
        phys = np.stack([t[0] for t in ticks])      # (T, L, H)
        nidx = np.stack([t[1] for t in ticks])      # (T, L, H, knn)
        nd2 = np.stack([t[2] for t in ticks])
        folded = 0
        for l, pb in enumerate(plans):
            for h, host in enumerate(pb.hosts):
                rows = np.repeat(phys[:, l, h], self.knn)
                cols = nidx[:, l, h].reshape(-1)
                d2 = nd2[:, l, h].reshape(-1)
                keep = host.alive[cols]      # neighbors trimmed since claim
                rows, cols, d2 = rows[keep], cols[keep], d2[keep]
                if rows.size == 0:
                    continue
                vals = api.edge_values(host, rows, cols, d2)
                r2, c2, v2 = host.coo
                host.coo = (np.concatenate([r2, host.inv[rows]]),
                            np.concatenate([c2, host.inv[cols]]),
                            np.concatenate([v2, vals]))
                host.coo_dev = None
                folded += int(rows.size)
        return folded

    def flush_all(self) -> int:
        return sum(self.flush(s) for s in range(self.B)
                   if self._plans[s] is not None)
