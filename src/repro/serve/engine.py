"""ClusterKV decode service: plan-cached continuous batching.

``ClusterKVEngine`` extends the reference :class:`~repro.train.serve_loop.
Engine` with plans as first-class serving state. The per-call clusterkv
decode path re-derives the cluster ordering of every slot's cache each
tick (a Morton sort per token); the service instead

  - builds one ordering ``PlanBatch`` per layer at ADMISSION
    (:func:`repro.core.clusterkv.kv_plan_batch` over the prefilled keys,
    ``capacity=max_seq``) and keeps the slot's KV cache in PLAN order,
  - streams each generated key into those plans through the PR 4 insert
    tier (:class:`~repro.serve.streaming.LockstepInserter` — claim a
    Morton-leaf slot host-side, scatter device-side; never re-sort),
  - admits by SPEC UNIFICATION: every session is built to the same pow2
    capacity and plan config, so ``PlanSpec`` equality guarantees a new
    session re-enters the one compiled decode step. ``decode_traces``
    counts retraces at trace time; the service gate is that it stays 1
    across arbitrary admission churn.

``mode="percall"`` runs the same engine over the baseline per-call
clusterkv decode (``backend="clusterkv"``) for A/B benchmarking.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.configs.base import ModelConfig
from repro.core import clusterkv as ckv
from repro.models.sharding import NO_SHARD
from repro.serve.session import Session, SessionStore
from repro.serve.streaming import LockstepInserter
from repro.train.serve_loop import Engine, Request

_BIG = np.iinfo(np.int32).max


@functools.partial(jax.jit, static_argnames=("slot", "bk"))
def _device_trim(pstate, rows, slot: int, bk: int):
    """Zero the trimmed plan rows of one engine slot and recompute its
    centroids. ``rows`` (L, Hkv, nd) plan-order rows (sentinel S: skip)."""
    ks, vs, ps = pstate["ks"], pstate["vs"], pstate["ps"]
    l, _, h, s, dh = ks.shape
    li = jnp.arange(l)[:, None, None]
    hi = jnp.arange(h)[None, :, None]
    ks = ks.at[li, slot, hi, rows].set(0.0, mode="drop")
    vs = vs.at[li, slot, hi, rows].set(0.0, mode="drop")
    ps = ps.at[li, slot, hi, rows].set(_BIG, mode="drop")
    cent = pstate["cent"].at[:, slot].set(
        ks[:, slot].astype(jnp.float32).reshape(l, h, s // bk, bk, dh).mean(3))
    return {"ks": ks, "vs": vs, "ps": ps, "cent": cent}


@functools.partial(jax.jit, static_argnames=("slot", "bk"))
def _device_regather(pstate, gather, slot: int, bk: int):
    """Reorder one engine slot's plan-ordered rows after a host rebucket:
    ``gather`` (L, Hkv, S) maps new plan row -> old plan row."""
    l, _, h, s, dh = pstate["ks"].shape
    ks = jnp.take_along_axis(pstate["ks"][:, slot], gather[..., None], axis=2)
    vs = jnp.take_along_axis(pstate["vs"][:, slot], gather[..., None], axis=2)
    ps = jnp.take_along_axis(pstate["ps"][:, slot], gather, axis=2)
    cent = ks.astype(jnp.float32).reshape(l, h, s // bk, bk, dh).mean(3)
    return {"ks": pstate["ks"].at[:, slot].set(ks),
            "vs": pstate["vs"].at[:, slot].set(vs),
            "ps": pstate["ps"].at[:, slot].set(ps),
            "cent": pstate["cent"].at[:, slot].set(cent)}


class ClusterKVEngine(Engine):
    """Continuous batching with plan-cached clusterkv decode.

    mode="plan"     plan-ordered caches + insert-streamed session plans
                    (ONE decode trace for the service's lifetime)
    mode="percall"  baseline: time-ordered cache, per-tick Morton sort
                    (``Engine`` with backend="clusterkv")
    """

    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_seq: int = 512, prefill_bucket: int = 64,
                 mode: str = "plan", knn: int = 8,
                 plan_prefill: bool = False):
        if mode not in ("plan", "percall"):
            raise ValueError(f"unknown service mode {mode!r}")
        if not cfg.clusterkv.enabled:
            cfg = dataclasses.replace(
                cfg, clusterkv=dataclasses.replace(cfg.clusterkv,
                                                   enabled=True))
        if mode == "plan" and cfg.mla is not None:
            raise NotImplementedError("plan service serves GQA caches")
        self.mode = mode
        self.knn = knn
        self.plan_prefill = plan_prefill
        self.decode_traces = 0
        self.tokens_out = 0
        self._tick_time = 0.0
        # plan-mode tick split: jitted decode+land dispatch vs the host
        # inserter's claim-and-mutate pass (bench_serve gates on the host
        # share staying small — the tick should be kernel-bound)
        self._device_time = 0.0
        self._claim_time = 0.0
        self._pf_plan: Dict[int, callable] = {}
        backend = "clusterkv" if mode == "percall" else "flash"
        super().__init__(cfg, params, slots=slots, max_seq=max_seq,
                         prefill_bucket=prefill_bucket, backend=backend)
        self.store = SessionStore()
        bk = min(self.cfg.clusterkv.block_k, max_seq)
        if max_seq % bk:
            raise ValueError("max_seq must be a multiple of block_k")
        self.bk = bk
        self.L = self.cfg.n_layers
        self.Hkv = self.cfg.n_kv_heads
        self.dh = self.cfg.head_dim
        if mode == "plan":
            dt = jnp.dtype(self.cfg.dtype)
            shape = (self.L, slots, self.Hkv)
            self.pstate = {
                "ks": jnp.zeros(shape + (max_seq, self.dh), dt),
                "vs": jnp.zeros(shape + (max_seq, self.dh), dt),
                "ps": jnp.full(shape + (max_seq,), _BIG, jnp.int32),
                "cent": jnp.zeros(shape + (max_seq // bk, self.dh),
                                  jnp.float32),
            }
            self._pend_k = jnp.zeros(shape + (self.dh,), dt)
            self._pend_v = jnp.zeros(shape + (self.dh,), dt)
            self._pend_phys = np.full(shape, -1, np.int64)
            self._pend_pos = np.zeros(slots, np.int32)
            self._slot_sess: List[Optional[Session]] = [None] * slots
            self._tier_totals = {"appends": 0, "tombstones": 0,
                                 "rebuckets": 0, "grows": 0,
                                 "compactions": 0}
            # per-slot plan generation: bumped whenever a session's plan
            # objects are replaced (trim/rebucket/restore — the engine's
            # swaps); every inserter claim is validated against it
            self._plan_gen = [0] * slots
            self.inserter = LockstepInserter(
                self.L, slots, self.Hkv, max_seq, self.dh,
                self.cfg.clusterkv.embed_dim, knn)
            # donate the plan state so the pend-landing scatter can alias
            # the cache buffers instead of copying them every tick (a
            # backend that can't donate just warns and copies)
            self._plan_decode = jax.jit(self._plan_decode_step,
                                        donate_argnums=(1,))

    # -- jitted pieces ------------------------------------------------------

    def _decode_step(self, params, cache, tokens, slot_pos):
        self.decode_traces += 1        # runs at TRACE time: counts compiles
        return super()._decode_step(params, cache, tokens, slot_pos)

    def _plan_decode_step(self, params, pstate, pend, tokens, slot_pos):
        self.decode_traces += 1        # runs at TRACE time: counts compiles
        return self.mod.plan_decode_step(params, self.cfg, pstate, pend,
                                         tokens, slot_pos, NO_SHARD)

    def _plan_prefill_fn(self, length: int):
        if length not in self._pf_plan:
            def fn(params, tokens, perms):
                return self.mod.plan_prefill(params, self.cfg,
                                             {"tokens": tokens}, perms,
                                             NO_SHARD)
            self._pf_plan[length] = jax.jit(fn)
        return self._pf_plan[length]

    # -- admission ----------------------------------------------------------

    def _install(self, s: int, req: Request, cache_1, blen: int):
        """Plan-mode admission: build the session's per-layer plan batches
        over the prefilled keys (capacity = max_seq, so every admission
        re-unifies to the SAME spec) and stage the slot's plan-ordered
        decode state. Returns plan-path logits when ``plan_prefill`` is
        set (the clusterkv_attention(plan_batch=) wiring), else None."""
        if self.mode != "plan":
            return super()._install(s, req, cache_1, blen)
        if blen <= self.knn:
            raise ValueError(
                f"prefill bucket {blen} must exceed knn={self.knn} (spec "
                "unification pins every member's k to knn)")
        k_np = np.asarray(cache_1["k"][:, 0], np.float32)   # (L,Hkv,blen,dh)
        v_np = np.asarray(cache_1["v"][:, 0], np.float32)
        S = self.max_seq
        plans = [ckv.kv_plan_batch(jnp.asarray(k_np[l]),
                                   d=self.cfg.clusterkv.embed_dim,
                                   knn=self.knn, capacity=S)
                 for l in range(self.L)]
        # physical row p < blen holds the key of time position p; tail rows
        # are capacity holes (INT32_MAX position sentinel)
        pi = np.stack([np.asarray(pb.data.pi) for pb in plans])  # (L,Hkv,S)
        k_pad = np.zeros((self.L, self.Hkv, S, self.dh), np.float32)
        v_pad = np.zeros((self.L, self.Hkv, S, self.dh), np.float32)
        k_pad[:, :, :blen], v_pad[:, :, :blen] = k_np, v_np
        ks = np.take_along_axis(k_pad, pi[..., None], axis=2)
        vs = np.take_along_axis(v_pad, pi[..., None], axis=2)
        ps = np.where(pi < blen, pi, _BIG).astype(np.int32)
        cent = ks.reshape(self.L, self.Hkv, S // self.bk, self.bk,
                          self.dh).mean(3)
        dt = self.pstate["ks"].dtype
        self.pstate = {
            "ks": self.pstate["ks"].at[:, s].set(jnp.asarray(ks, dt)),
            "vs": self.pstate["vs"].at[:, s].set(jnp.asarray(vs, dt)),
            "ps": self.pstate["ps"].at[:, s].set(jnp.asarray(ps)),
            "cent": self.pstate["cent"].at[:, s].set(jnp.asarray(cent)),
        }
        self._pend_phys[:, s] = -1
        self._plan_gen[s] = 0
        self.inserter.attach(s, plans, generation=0)
        sess = Session(rid=req.rid, slot=s, blen=blen, plans=plans)
        self.store.admit(sess)
        self._slot_sess[s] = sess
        if self.plan_prefill:
            # re-run prefill THROUGH the plans: per-head live orderings
            # drive clusterkv_attention's plan_batch path, so the first
            # generated token already comes from the clusterkv kernel
            perms = np.stack([
                np.stack([pi[l, h][pi[l, h] < blen]
                          for h in range(self.Hkv)])
                for l in range(self.L)]).astype(np.int32)  # (L,Hkv,blen)
            plen = len(req.tokens)
            padded = np.zeros(blen, np.int32)
            padded[-plen:] = req.tokens
            pf = self._plan_prefill_fn(blen)
            return pf(self.params, jnp.asarray(padded[None]),
                      jnp.asarray(perms[:, None]))
        return None

    def _release(self, s: int, req: Request) -> None:
        if self.mode != "plan":
            return
        sess = self._slot_sess[s]
        if sess is None:
            return
        self.store.counters["flushed_edges"] += self.inserter.flush(s)
        self.inserter.detach(s)
        self._pend_phys[:, s] = -1
        self._slot_sess[s] = None
        for pb in sess.plans:
            for host in pb.hosts:
                for key in self._tier_totals:
                    self._tier_totals[key] += getattr(host.refresh, key, 0)
        self.store.retire(sess.rid)

    # -- the tick -----------------------------------------------------------

    def step(self) -> int:
        t0 = time.time()
        n = self._plan_step() if self.mode == "plan" else super().step()
        self.tokens_out += n
        self._tick_time += time.time() - t0
        return n

    def _pend_slots(self) -> np.ndarray:
        """Plan-order landing rows of the pending tokens, resolved against
        the CURRENT member orderings (physical slots are stable across
        trims/rebuckets; plan rows are not). Sentinel max_seq = none."""
        out = np.full((self.L, self.slots, self.Hkv), self.max_seq, np.int32)
        for s in range(self.slots):
            sess = self._slot_sess[s]
            if sess is None:
                continue
            for l in range(self.L):
                for h in range(self.Hkv):
                    p = self._pend_phys[l, s, h]
                    if p >= 0:
                        out[l, s, h] = sess.plans[l].hosts[h].inv[p]
        return out

    def _plan_step(self) -> int:
        self._admit()
        active = [s for s, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return 0
        tokens = np.zeros((self.slots, 1), np.int32)
        for s in active:
            tokens[s, 0] = self.slot_req[s].output[-1]
        pend = {"k": self._pend_k, "v": self._pend_v,
                "slot": jnp.asarray(self._pend_slots()),
                "pos": jnp.asarray(self._pend_pos)}
        t0 = time.time()
        logits, self.pstate, nk, nv = self._plan_decode(
            self.params, self.pstate, pend, jnp.asarray(tokens),
            jnp.asarray(self.slot_pos))
        nxt = np.asarray(jnp.argmax(logits, -1))
        self._device_time += time.time() - t0
        # stream this tick's keys into the session plans: the host claims
        # each one's Morton-leaf slot now; the device lands it next tick
        t0 = time.time()
        phys = self.inserter.insert(
            active, nk,
            generations={s: self._plan_gen[s] for s in active})
        self._claim_time += time.time() - t0
        self._pend_phys = phys
        self._pend_k, self._pend_v = nk, nv
        self._pend_pos = self.slot_pos.copy()
        for s in active:
            sess = self._slot_sess[s]
            sess.phys_hist[int(self.slot_pos[s])] = phys[:, s, :].copy()
            self.slot_pos[s] += 1
            self.slot_req[s].output.append(int(nxt[s]))
        self.store.counters["inserts"] += len(active)
        self.ticks += 1
        return len(active)

    # -- session surgery ----------------------------------------------------

    def trim(self, rid: int, positions: Sequence[int]) -> None:
        """Tombstone the given TIME positions out of a live session: the
        member plans take the PR 4 tombstone tier (capacity keeps the
        spec, so no retrace), the device rows are zeroed + re-holed."""
        sess = self.store.get(rid)
        if sess is None:
            raise KeyError(f"no live session {rid}")
        s = sess.slot
        self.store.counters["flushed_edges"] += self.inserter.flush(s)
        del_rows = np.zeros((self.L, self.Hkv, len(positions)), np.int64)
        for i, pos in enumerate(sorted(set(int(p) for p in positions))):
            if pos >= int(self.slot_pos[s]):
                raise ValueError(f"position {pos} not decoded yet")
            if pos < sess.blen:
                del_rows[:, :, i] = pos
            else:
                del_rows[:, :, i] = sess.phys_hist.pop(pos)
                if (int(self._pend_pos[s]) == pos
                        and self._pend_phys[0, s, 0] >= 0):
                    self._pend_phys[:, s] = -1    # never lands
        new_plans = []
        plan_rows = np.zeros_like(del_rows, dtype=np.int32)
        for l in range(self.L):
            idxs = [del_rows[l, h] for h in range(self.Hkv)]
            pb = sess.plans[l].update(delete=idxs, policy="tombstone")
            for h in range(self.Hkv):
                plan_rows[l, h] = pb.hosts[h].inv[del_rows[l, h]]
            new_plans.append(pb)
        sess.plans = new_plans
        self._plan_gen[s] += 1                 # hosts were replaced:
        self.inserter.attach(s, new_plans,     # swap in a new generation
                             generation=self._plan_gen[s])
        self.pstate = _device_trim(self.pstate, jnp.asarray(plan_rows),
                                   s, self.bk)
        self.store.counters["deletes"] += del_rows.shape[-1]

    def rebucket(self, rid: int) -> None:
        """Force the rebucket tier on a live session: re-sort every member
        ordering by its maintained Morton codes (host), re-gather the
        slot's plan-ordered device rows to match. Shapes are untouched, so
        the decode step does not retrace."""
        sess = self.store.get(rid)
        if sess is None:
            raise KeyError(f"no live session {rid}")
        s = sess.slot
        self.store.counters["flushed_edges"] += self.inserter.flush(s)
        S = self.max_seq
        gathers = np.zeros((self.L, self.Hkv, S), np.int64)
        new_plans = []
        for l, pb in enumerate(sess.plans):
            cfg = pb.spec.config
            members = []
            for h, host in enumerate(pb.hosts):
                if host.codes is None:
                    codes, lo, hi = api._stream_codes(host, cfg)
                    host.codes, host.code_lo, host.code_hi = codes, lo, hi
                r2, c2, v2 = host.coo
                pi2, inv2, r2n, c2n = api._stream_rebucket(
                    host.pi, host.codes, r2, c2, S)
                gathers[l, h] = host.inv[pi2]   # new plan row -> old row
                host.pi, host.inv = pi2, inv2
                host.coo = (r2n, c2n, v2)
                host.coo_dev = None
                host.tree = None
                host.gamma = None
                host.shard_cache = {}
                host.refresh = dataclasses.replace(
                    host.refresh, rebuckets=host.refresh.rebuckets + 1,
                    last_action="rebucket")
                members.append(api.InteractionPlan(
                    cfg, S, None, jnp.asarray(pi2), jnp.asarray(inv2), host))
            new_plans.append(api.PlanBatch.from_plans(members, capacity=S))
        sess.plans = new_plans
        self._plan_gen[s] += 1
        self.inserter.attach(s, new_plans, generation=self._plan_gen[s])
        self.pstate = _device_regather(self.pstate, jnp.asarray(gathers),
                                       s, self.bk)
        self.store.counters["rebuckets"] += 1

    # -- drain / snapshot / resume ------------------------------------------

    def snapshot(self, ckpt, step: int, name: str = "sessions",
                 blocking: bool = True) -> None:
        """Flush, pack every live session's device rows + request state
        into its ``aux`` payload, and hand the SessionStore to
        ``Checkpointer.save_plan``."""
        self.store.counters["flushed_edges"] += self.inserter.flush_all()
        # bf16 has no npz representation: widen to f32 (lossless); resume
        # casts back to the cache dtype
        f32 = jnp.float32
        ks = np.asarray(self.pstate["ks"].astype(f32))
        vs = np.asarray(self.pstate["vs"].astype(f32))
        ps = np.asarray(self.pstate["ps"])
        cent = np.asarray(self.pstate["cent"])
        pend_k = np.asarray(self._pend_k.astype(f32))
        pend_v = np.asarray(self._pend_v.astype(f32))
        for sess in self.store.sessions.values():
            s = sess.slot
            req = self.slot_req[s]
            hist_pos = np.asarray(sorted(sess.phys_hist), np.int64)
            hist_phys = (np.stack([sess.phys_hist[int(p)] for p in hist_pos])
                         if hist_pos.size
                         else np.zeros((0, self.L, self.Hkv), np.int64))
            sess.aux = {
                "ks": ks[:, s], "vs": vs[:, s], "ps": ps[:, s],
                "cent": cent[:, s],
                "pend_k": pend_k[:, s], "pend_v": pend_v[:, s],
                "pend_phys": self._pend_phys[:, s].copy(),
                "pend_pos": np.asarray(self._pend_pos[s], np.int32),
                "slot_pos": np.asarray(self.slot_pos[s], np.int32),
                "prompt": np.asarray(req.tokens, np.int32),
                "output": np.asarray(req.output, np.int32),
                "max_new": np.asarray(req.max_new, np.int32),
                "eos_id": np.asarray(
                    -1 if req.eos_id is None else req.eos_id, np.int32),
                "hist_pos": hist_pos, "hist_phys": hist_phys,
            }
        ckpt.save_plan(step, self.store, name=name, blocking=blocking)

    def resume(self, store: SessionStore) -> None:
        """Adopt a restored SessionStore: rebind every session to its slot
        and rebuild the device state, pending token, and request from its
        ``aux`` payload. Decode continues bit-exactly."""
        if self.mode != "plan":
            raise ValueError("resume requires mode='plan'")
        self.store = store
        dt = self.pstate["ks"].dtype
        for sess in store.sessions.values():
            s, aux = sess.slot, sess.aux
            sess.phys_hist = {int(p): aux["hist_phys"][i]
                              for i, p in enumerate(aux["hist_pos"])}
            self.pstate = {
                "ks": self.pstate["ks"].at[:, s].set(
                    jnp.asarray(aux["ks"], dt)),
                "vs": self.pstate["vs"].at[:, s].set(
                    jnp.asarray(aux["vs"], dt)),
                "ps": self.pstate["ps"].at[:, s].set(jnp.asarray(aux["ps"])),
                "cent": self.pstate["cent"].at[:, s].set(
                    jnp.asarray(aux["cent"])),
            }
            self._pend_k = self._pend_k.at[:, s].set(
                jnp.asarray(aux["pend_k"], dt))
            self._pend_v = self._pend_v.at[:, s].set(
                jnp.asarray(aux["pend_v"], dt))
            self._pend_phys[:, s] = aux["pend_phys"]
            self._pend_pos[s] = int(aux["pend_pos"])
            self.slot_pos[s] = int(aux["slot_pos"])
            eos = int(aux["eos_id"])
            req = Request(rid=sess.rid, tokens=np.asarray(aux["prompt"]),
                          max_new=int(aux["max_new"]),
                          eos_id=None if eos < 0 else eos,
                          output=[int(t) for t in aux["output"]])
            self.slot_req[s] = req
            self._slot_sess[s] = sess
            self._plan_gen[s] = 0              # restored plans: fresh
            self.inserter.attach(s, sess.plans, generation=0)

    # -- telemetry ----------------------------------------------------------

    def report(self) -> dict:
        """Machine-readable service telemetry (JSON-safe)."""
        rep = {
            "mode": self.mode, "backend": self.backend,
            "slots": self.slots, "max_seq": self.max_seq,
            "ticks": self.ticks, "tokens_out": self.tokens_out,
            "tokens_per_sec": (self.tokens_out / self._tick_time
                               if self._tick_time else 0.0),
            "decode_traces": self.decode_traces,
            "prefill_traces": len(self._prefills) + len(self._pf_plan),
            "host_claim_s": self._claim_time,
            "device_tick_s": self._device_time,
        }
        if self.mode == "plan":
            rep.update(self.store.report())
            tiers = dict(self._tier_totals)      # retired sessions
            for sess in self.store.sessions.values():
                for pb in sess.plans:
                    for host in pb.hosts:
                        for key in tiers:
                            tiers[key] += getattr(host.refresh, key, 0)
            rep["insert_tiers"] = tiers
        return rep
