"""Sessions and the spec-keyed session store.

A serving *session* is one in-flight request plus its plan assets: one
ordering ``PlanBatch`` per layer (members = kv heads) over the session's
keys, built once at prefill with ``capacity=max_seq`` and thereafter
maintained by the insert tier — never re-sorted per token.

The ``SessionStore`` keys sessions by their shared :class:`~repro.api.PlanSpec`.
Because every session is built to the same pow2-unified capacity and plan
config, spec-identical sessions share ONE compiled decode kernel per
backend/charge shape — the store's ``specs_seen`` set is exactly the
"how many kernels did admission cost" ledger the service gates on.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import numpy as np


@dataclasses.dataclass
class Session:
    rid: int                      # request id
    slot: int                     # engine slot currently hosting it
    blen: int                     # prefill bucket length (prompt positions)
    plans: List                   # one ordering PlanBatch per layer
    # time position -> (L, Hkv) physical plan rows of the generated token
    phys_hist: Dict[int, np.ndarray] = dataclasses.field(default_factory=dict)
    # snapshot payload (device rows, pending token, request state) — filled
    # by ClusterKVEngine.snapshot, consumed by resume
    aux: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)

    @property
    def spec(self):
        return self.plans[0].spec


class SessionStore:
    """Live sessions, their shared specs, and service counters."""

    def __init__(self):
        self.sessions: Dict[int, Session] = {}
        self._spec_rids: Dict[object, Set[int]] = {}
        self.seen_specs: Set[object] = set()
        self.counters: Dict[str, int] = {
            "admits": 0, "retires": 0, "evictions": 0,
            "inserts": 0, "deletes": 0, "rebuckets": 0, "flushed_edges": 0,
        }

    # -- membership ---------------------------------------------------------

    def register(self, sess: Session) -> bool:
        """Track a session without counting an admission (restore path).
        Returns True when its spec is NEW to this store — i.e. admitting
        it would have compiled a fresh kernel family."""
        fresh = sess.spec not in self.seen_specs
        self.seen_specs.add(sess.spec)
        self._spec_rids.setdefault(sess.spec, set()).add(sess.rid)
        self.sessions[sess.rid] = sess
        return fresh

    def admit(self, sess: Session) -> bool:
        fresh = self.register(sess)
        self.counters["admits"] += 1
        return fresh

    def retire(self, rid: int, evict: bool = False) -> Session:
        sess = self.sessions.pop(rid)
        rids = self._spec_rids.get(sess.spec)
        if rids is not None:
            rids.discard(rid)
            if not rids:
                del self._spec_rids[sess.spec]
        self.counters["evictions" if evict else "retires"] += 1
        return sess

    def get(self, rid: int) -> Optional[Session]:
        return self.sessions.get(rid)

    # -- telemetry ----------------------------------------------------------

    @property
    def specs_live(self) -> int:
        return len(self._spec_rids)

    @property
    def specs_seen(self) -> int:
        return len(self.seen_specs)

    def report(self) -> dict:
        return {
            "active_sessions": len(self.sessions),
            "specs_live": self.specs_live,
            "specs_seen": self.specs_seen,
            "counters": dict(self.counters),
        }
