"""Mixture-of-Experts FFN: sort-based capacity routing, static shapes.

Routing is computed locally per data shard inside shard_map (no cross-shard
sort); expert weights are sharded over the tensor axis on their hidden dim
("expert TP" — robust to expert counts not divisible by the mesh, e.g.
granite's 40 experts on a 16-way axis), with the row-parallel down-proj
combined by an explicit psum. Optional EP (experts over the tensor axis with
all-to-all token exchange) is provided for divisible counts.

Dropped-token semantics: tokens beyond an expert's capacity
(ceil(T*k/E * capacity_factor)) are dropped (Switch-style); the residual
stream carries them unchanged.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro import compat
from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models import param as pm
from repro.models.sharding import ShardCtx, ep_axis, resolve_spec, tp_axis

CAPACITY_FACTOR = 1.25


def init_moe(key, cfg: ModelConfig):
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 6)
    scale_in = 1.0 / math.sqrt(d)
    scale_out = 1.0 / math.sqrt(f)
    ep = "ep" if m.expert_parallel else None
    tp_in = None if m.expert_parallel else "tp"
    p = {
        "router": {"w": jax.random.normal(ks[0], (d, e), jnp.float32) * scale_in},
        "wg": jax.random.normal(ks[1], (e, d, f), jnp.float32) * scale_in,
        "wu": jax.random.normal(ks[2], (e, d, f), jnp.float32) * scale_in,
        "wd": jax.random.normal(ks[3], (e, f, d), jnp.float32) * scale_out,
    }
    s = {
        "router": {"w": P("fsdp", None)},
        "wg": P(ep, "fsdp", tp_in),
        "wu": P(ep, "fsdp", tp_in),
        "wd": P(ep, tp_in, "fsdp"),
    }
    if m.n_shared_experts:
        fs = f * m.n_shared_experts
        p["shared"] = {
            "wg": jax.random.normal(ks[4], (d, fs), jnp.float32) * scale_in,
            "wu": jax.random.normal(ks[5], (d, fs), jnp.float32) * scale_in,
            "wd": jax.random.normal(ks[4], (fs, d), jnp.float32) / math.sqrt(fs),
        }
        s["shared"] = {"wg": P("fsdp", "tp"), "wu": P("fsdp", "tp"),
                       "wd": P("tp", "fsdp")}
    return p, s


def _route_local(xf, eidx, gates, wg, wu, wd, capacity: int,
                 psum_axis: Optional[str]):
    """Sort-based dispatch within one shard.

    xf (T, d); eidx/gates (T, k); wg/wu (E, d, f_local); wd (E, f_local, d).
    """
    t, k = eidx.shape
    e = wg.shape[0]
    d = xf.shape[-1]
    flat_e = eidx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < capacity
    dest = sorted_e * capacity + jnp.minimum(rank, capacity - 1)
    token_of = order // k

    vals = xf[token_of] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e * capacity, d), xf.dtype).at[dest].add(vals)
    bufe = buf.reshape(e, capacity, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", bufe, wg.astype(xf.dtype)))
    h = h * jnp.einsum("ecd,edf->ecf", bufe, wu.astype(xf.dtype))
    out = jnp.einsum("ecf,efd->ecd", h, wd.astype(xf.dtype)).reshape(-1, d)
    if psum_axis is not None:
        out = jax.lax.psum(out, psum_axis)
    g = (gates.reshape(-1)[order] * keep)[:, None].astype(xf.dtype)
    y = jnp.zeros_like(xf).at[token_of].add(out[dest] * g)
    return y


def _route_ep(xf, eidx, gates, wg, wu, wd, capacity: int, ep_axis: str):
    """EP: experts sharded over ``ep_axis``; tokens exchanged by all_to_all."""
    t, k = eidx.shape
    d = xf.shape[-1]
    e_local = wg.shape[0]
    n_dev = compat.axis_size(ep_axis)
    e = e_local * n_dev
    cap = capacity
    flat_e = eidx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[sorted_e]
    keep = rank < cap
    dest = sorted_e * cap + jnp.minimum(rank, cap - 1)
    token_of = order // k

    vals = xf[token_of] * keep[:, None].astype(xf.dtype)
    buf = jnp.zeros((e * cap, d), xf.dtype).at[dest].add(vals)
    # exchange: (n_dev, e_local*cap, d) -> all_to_all over devices
    buf = buf.reshape(n_dev, e_local * cap, d)
    buf = jax.lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    # now (n_dev, e_local*cap, d): rows from every peer for MY experts
    bufe = buf.reshape(n_dev, e_local, cap, d)
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", bufe, wg.astype(xf.dtype)))
    h = h * jnp.einsum("necd,edf->necf", bufe, wu.astype(xf.dtype))
    out = jnp.einsum("necf,efd->necd", h, wd.astype(xf.dtype))
    out = out.reshape(n_dev, e_local * cap, d)
    out = jax.lax.all_to_all(out, ep_axis, split_axis=0, concat_axis=0,
                             tiled=False)
    out = out.reshape(e * cap, d)
    g = (gates.reshape(-1)[order] * keep)[:, None].astype(xf.dtype)
    y = jnp.zeros_like(xf).at[token_of].add(out[dest] * g)
    return y


def load_balance_loss(probs, eidx, n_experts: int):
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    t = probs.shape[0]
    f = jnp.zeros((n_experts,), jnp.float32).at[eidx.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    pbar = probs.mean(axis=0)
    return n_experts * jnp.sum(f * pbar)


def moe_ffn(p, x: jax.Array, cfg: ModelConfig, shd: ShardCtx
            ) -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    xf32 = x.astype(jnp.float32)
    logits = xf32 @ p["router"]["w"]                 # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    aux = load_balance_loss(probs.reshape(-1, m.n_experts).astype(jnp.float32),
                            eidx.reshape(-1, m.top_k), m.n_experts)

    n_dp = 1
    tp = tp_axis(shd.mesh) if shd.mesh is not None else None
    if shd.mesh is not None:
        dp_ax = resolve_spec(P("dp"), shd.mesh)[0]
        for a in (dp_ax if isinstance(dp_ax, tuple) else (dp_ax,)):
            n_dp *= shd.mesh.shape[a]
    if shd.mesh is None or b % n_dp != 0:
        # single-device path, or batch too small to shard (e.g. decode B=1):
        # route locally with replicated compute
        t = b * s
        cap = max(1, math.ceil(t * m.top_k * m.capacity_factor
                               / m.n_experts))
        y = _route_local(x.reshape(t, d), eidx.reshape(t, -1),
                         gates.reshape(t, -1).astype(x.dtype),
                         p["wg"], p["wu"], p["wd"], cap, None)
        y = y.reshape(b, s, d)
    else:
        mesh = shd.mesh
        dp = resolve_spec(P("dp"), mesh)[0]
        t_local = b * s // n_dp
        cap = max(1, math.ceil(t_local * m.top_k * m.capacity_factor
                               / m.n_experts))

        epax = ep_axis(mesh)
        dp_axes = dp if isinstance(dp, tuple) else (dp,)
        # sequence dim sharded over tp when tp exists and ep not already in dp
        seq_ax = tp if (tp is not None and tp not in dp_axes) else None
        n_seq = mesh.shape[seq_ax] if seq_ax is not None else 1
        if m.expert_parallel and epax is not None \
                and m.n_experts % mesh.shape[epax] == 0 and s % n_seq == 0:
            # tokens enter fully sharded (batch over dp, seq over tp when
            # distinct) so EP compute is never replicated; all_to_all over
            # the expert axis exchanges token rows with the experts' owners
            cap_ep = max(1, math.ceil(b * s // (n_dp * n_seq) * m.top_k
                                      * m.capacity_factor / m.n_experts))

            def body(xl, el, gl, wg, wu, wd):
                tl = xl.shape[0] * xl.shape[1]
                y = _route_ep(xl.reshape(tl, d), el.reshape(tl, -1),
                              gl.reshape(tl, -1).astype(xl.dtype),
                              wg, wu, wd, cap_ep, epax)
                return y.reshape(xl.shape)
            f = shard_map(body, mesh=mesh,
                          in_specs=(P(dp, seq_ax), P(dp, seq_ax),
                                    P(dp, seq_ax),
                                    P(epax, None, None),
                                    P(epax, None, None),
                                    P(epax, None, None)),
                          out_specs=P(dp, seq_ax), check_vma=False)
        else:
            def body(xl, el, gl, wg, wu, wd):
                tl = xl.shape[0] * xl.shape[1]
                y = _route_local(xl.reshape(tl, d), el.reshape(tl, -1),
                                 gl.reshape(tl, -1).astype(xl.dtype),
                                 wg, wu, wd, cap, tp)
                return y.reshape(xl.shape)
            f = shard_map(body, mesh=mesh,
                          in_specs=(P(dp), P(dp), P(dp),
                                    P(None, None, tp),
                                    P(None, None, tp),
                                    P(None, tp, None)),
                          out_specs=P(dp), check_vma=False)
        y = f(x, eidx, gates, p["wg"], p["wu"], p["wd"])

    if "shared" in p:
        sh = p["shared"]
        h = jax.nn.silu(x @ sh["wg"].astype(x.dtype)) * (x @ sh["wu"].astype(x.dtype))
        y = y + h @ sh["wd"].astype(x.dtype)
    return y, aux
