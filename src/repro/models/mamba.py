"""Mamba blocks: mamba1 selective scan (falcon-mamba) and mamba2 SSD
(zamba2), in chunked forms.

TPU adaptation notes (DESIGN.md §2): the recurrence is evaluated chunk-wise —
within a chunk, mamba1 uses a parallel associative scan and mamba2 uses the
SSD matmul form (dense (l x l) decay kernels on the MXU); across chunks a
lax.scan carries the (B, H, P, N) state. Inner channels are TP-sharded
("tp"); the scan carries only O(B * d_inner * N) state.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import param as pm
from repro.models.sharding import ShardCtx


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------


def conv1d_init(key, channels: int, width: int):
    p = {"w": jax.random.normal(key, (width, 1, channels), jnp.float32)
             / math.sqrt(width),
         "b": jnp.zeros((channels,), jnp.float32)}
    s = {"w": P(None, None, "tp"), "b": P("tp")}
    return p, s


def conv1d_apply(p, x: jax.Array) -> jax.Array:
    """x (B, S, C), causal depthwise conv."""
    width = p["w"].shape[0]
    y = jax.lax.conv_general_dilated(
        x, p["w"].astype(x.dtype),
        window_strides=(1,), padding=[(width - 1, 0)],
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return y + p["b"].astype(x.dtype)


def conv1d_step(p, buf: jax.Array, x1: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Decode: buf (B, width-1, C) history, x1 (B, 1, C) new token."""
    window = jnp.concatenate([buf, x1], axis=1)          # (B, width, C)
    w = p["w"][:, 0, :].astype(x1.dtype)                 # (width, C)
    y = jnp.einsum("bwc,wc->bc", window, w) + p["b"].astype(x1.dtype)
    return window[:, 1:], y[:, None]


# ---------------------------------------------------------------------------
# mamba1 (falcon-mamba)
# ---------------------------------------------------------------------------


def init_mamba1(key, cfg: ModelConfig):
    d = cfg.d_model
    m = cfg.ssm
    di = m.expand * d
    dt_rank = m.dt_rank or -(-d // 16)
    n = m.d_state
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = pm.linear(ks[0], d, 2 * di, spec=("fsdp", "tp"))
    p["conv"], s["conv"] = conv1d_init(ks[1], di, m.d_conv)
    p["x_proj"], s["x_proj"] = pm.linear(ks[2], di, dt_rank + 2 * n,
                                         spec=("tp", None))
    p["dt_proj"], s["dt_proj"] = pm.linear(ks[3], dt_rank, di,
                                           spec=(None, "tp"), bias=True)
    p["A_log"] = jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32), (di, n)))
    s["A_log"] = P("tp", None)
    p["D"] = jnp.ones((di,), jnp.float32)
    s["D"] = P("tp")
    p["out_proj"], s["out_proj"] = pm.linear(ks[4], di, d, spec=("tp", "fsdp"))
    return p, s


def selective_scan(xc, dt, a_mat, bc, cc, chunk: int):
    """Chunked mamba1 scan.

    xc/dt (B,S,di); a_mat (di,N); bc/cc (B,S,N). Returns y (B,S,di)."""
    b, s, di = xc.shape
    n = a_mat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bc = jnp.pad(bc, ((0, 0), (0, pad), (0, 0)))
        cc = jnp.pad(cc, ((0, 0), (0, pad), (0, 0)))
    da = jnp.exp(dt[..., None] * a_mat)                  # (B,S,di,N)
    dbx = dt[..., None] * bc[:, :, None, :] * xc[..., None]

    def chunks(t):
        return jnp.moveaxis(t.reshape((b, nc, chunk) + t.shape[2:]), 1, 0)

    def outer(h, inp):
        dac, dbxc, ccc = inp                             # (B,l,di,N) x2, (B,l,N)
        op = lambda e1, e2: (e2[0] * e1[0], e2[0] * e1[1] + e2[1])
        acum, bcum = jax.lax.associative_scan(op, (dac, dbxc), axis=1)
        hs = acum * h[:, None] + bcum                    # (B,l,di,N)
        y = jnp.einsum("bldn,bln->bld", hs, ccc)
        return hs[:, -1], y

    h0 = jnp.zeros((b, di, n), xc.dtype)
    h_fin, ys = jax.lax.scan(outer, h0, (chunks(da), chunks(dbx), chunks(cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, nc * chunk, di)
    return y[:, :s], h_fin


def mamba1_forward(lp, x, cfg: ModelConfig, shd: ShardCtx) -> jax.Array:
    """One mamba1 block (post-norm residual handled by caller). x (B,S,d)."""
    m = cfg.ssm
    d = cfg.d_model
    di = m.expand * d
    dt_rank = m.dt_rank or -(-d // 16)
    n = m.d_state
    xz = pm.apply_linear(lp["in_proj"], x)
    xin, z = xz[..., :di], xz[..., di:]
    xin = shd.cst(xin, "dp", None, "tp")
    xc = jax.nn.silu(conv1d_apply(lp["conv"], xin))
    proj = pm.apply_linear(lp["x_proj"], xc)
    dt = jax.nn.softplus(pm.apply_linear(lp["dt_proj"], proj[..., :dt_rank]))
    bc = proj[..., dt_rank:dt_rank + n]
    cc = proj[..., dt_rank + n:]
    a_mat = -jnp.exp(lp["A_log"]).astype(xc.dtype)
    y, h_fin = selective_scan(xc.astype(jnp.float32), dt.astype(jnp.float32),
                              a_mat.astype(jnp.float32), bc.astype(jnp.float32),
                              cc.astype(jnp.float32), m.chunk)
    y = y.astype(x.dtype) + lp["D"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z)
    conv_buf = xin[:, -(m.d_conv - 1):, :]
    return pm.apply_linear(lp["out_proj"], y), h_fin, conv_buf


def mamba1_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    m = cfg.ssm
    di = m.expand * cfg.d_model
    return {"h": jnp.zeros((cfg.n_layers, batch, di, m.d_state), dtype),
            "conv": jnp.zeros((cfg.n_layers, batch, m.d_conv - 1, di), dtype)}


def mamba1_step(lp, x1, h, conv_buf, cfg: ModelConfig):
    """Decode: x1 (B,1,d); h (B,di,N); conv_buf (B,width-1,di)."""
    m = cfg.ssm
    d = cfg.d_model
    di = m.expand * d
    dt_rank = m.dt_rank or -(-d // 16)
    n = m.d_state
    xz = pm.apply_linear(lp["in_proj"], x1)
    xin, z = xz[..., :di], xz[..., di:]
    conv_buf, xc = conv1d_step(lp["conv"], conv_buf, xin)
    xc = jax.nn.silu(xc)
    proj = pm.apply_linear(lp["x_proj"], xc)
    dt = jax.nn.softplus(pm.apply_linear(lp["dt_proj"], proj[..., :dt_rank]))
    bc = proj[..., dt_rank:dt_rank + n]
    cc = proj[..., dt_rank + n:]
    a_mat = -jnp.exp(lp["A_log"]).astype(jnp.float32)
    da = jnp.exp(dt[:, 0, :, None].astype(jnp.float32) * a_mat)
    dbx = (dt[:, 0, :, None] * bc[:, 0, None, :] * xc[:, 0, :, None]
           ).astype(jnp.float32)
    h = da * h + dbx
    y = jnp.einsum("bdn,bn->bd", h, cc[:, 0].astype(jnp.float32))
    y = (y + lp["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32))
    y = (y * jax.nn.silu(z[:, 0]).astype(jnp.float32)).astype(x1.dtype)
    return pm.apply_linear(lp["out_proj"], y[:, None]), h, conv_buf


# ---------------------------------------------------------------------------
# mamba2 (SSD) — zamba2
# ---------------------------------------------------------------------------


def init_mamba2(key, cfg: ModelConfig):
    d = cfg.d_model
    m = cfg.ssm
    di = m.expand * d
    n = m.d_state
    nh = di // m.head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    # separate projections keep every sharded dim aligned (no mid-shard splits)
    p["z_proj"], s["z_proj"] = pm.linear(ks[0], d, di, spec=("fsdp", "tp"))
    p["x_proj"], s["x_proj"] = pm.linear(ks[1], d, di, spec=("fsdp", "tp"))
    p["bc_proj"], s["bc_proj"] = pm.linear(ks[2], d, 2 * n, spec=("fsdp", None))
    p["dt_proj"], s["dt_proj"] = pm.linear(ks[3], d, nh, spec=("fsdp", None))
    p["conv_x"], s["conv_x"] = conv1d_init(ks[4], di, m.d_conv)
    p["conv_bc"], s["conv_bc"] = conv1d_init(ks[5], 2 * n, m.d_conv)
    s["conv_bc"] = {"w": P(None, None, None), "b": P(None)}
    p["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, nh).astype(jnp.float32))
    s["A_log"] = P("tp")
    p["D"] = jnp.ones((nh,), jnp.float32)
    s["D"] = P("tp")
    p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
    s["dt_bias"] = P(None)
    p["norm"], s["norm"] = pm.rmsnorm(di)
    p["out_proj"], s["out_proj"] = pm.linear(
        jax.random.fold_in(ks[5], 1), di, d, spec=("tp", "fsdp"))
    return p, s


def _segsum(a):
    """a (..., l) -> (..., l, l) with [i, j] = sum_{k=j+1..i} a_k (i >= j)."""
    l = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    seg = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd(x, dt, a_head, bmat, cmat, chunk: int):
    """Mamba2 SSD. x (B,S,H,P); dt (B,S,H); a_head (H,) negative;
    bmat/cmat (B,S,N). Returns y (B,S,H,P)."""
    b, s, h, pdim = x.shape
    n = bmat.shape[-1]
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
    ch = lambda t: t.reshape((b, nc, chunk) + t.shape[2:])
    xc, dtc = ch(x), ch(dt)
    bc, cc = ch(bmat), ch(cmat)
    xbar = xc * dtc[..., None]                           # (b,c,l,h,p)
    a = dtc * a_head                                     # (b,c,l,h) log decay
    a_t = jnp.moveaxis(a, -1, -2)                        # (b,c,h,l)
    acum = jnp.cumsum(a_t, axis=-1)                      # (b,c,h,l)

    # intra-chunk (diagonal blocks): dense (l,l) decay kernel on the MXU
    ldec = jnp.exp(_segsum(a_t))                         # (b,c,h,l,l)
    y_diag = jnp.einsum("bcln,bcsn,bchls,bcshp->bclhp", cc, bc, ldec, xbar)

    # per-chunk output states
    dstate = jnp.exp(acum[..., -1:] - acum)              # (b,c,h,l)
    states = jnp.einsum("bcln,bchl,bclhp->bchpn", bc, dstate, xbar)

    # inter-chunk recurrence
    cdecay = jnp.exp(acum[..., -1])                      # (b,c,h)

    def outer(carry, inp):
        st, dec = inp                                    # (b,h,p,n), (b,h)
        out = carry
        carry = carry * dec[..., None, None] + st
        return carry, out

    init = jnp.zeros((b, h, pdim, n), x.dtype)
    h_fin, prev = jax.lax.scan(outer, init,
                               (jnp.moveaxis(states, 1, 0),
                                jnp.moveaxis(cdecay, 1, 0)))
    prev = jnp.moveaxis(prev, 0, 1)                      # (b,c,h,p,n)
    y_off = jnp.einsum("bcln,bchl,bchpn->bclhp", cc, jnp.exp(acum), prev)
    y = (y_diag + y_off).reshape(b, nc * chunk, h, pdim)
    return y[:, :s], h_fin


def mamba2_forward(lp, x, cfg: ModelConfig, shd: ShardCtx) -> jax.Array:
    m = cfg.ssm
    d = cfg.d_model
    di = m.expand * d
    n = m.d_state
    nh = di // m.head_dim
    z = pm.apply_linear(lp["z_proj"], x)
    xraw = pm.apply_linear(lp["x_proj"], x)
    bcraw = pm.apply_linear(lp["bc_proj"], x)
    dt = pm.apply_linear(lp["dt_proj"], x)
    xin = jax.nn.silu(conv1d_apply(lp["conv_x"], xraw))
    bcin = jax.nn.silu(conv1d_apply(lp["conv_bc"], bcraw))
    bmat = bcin[..., :n]
    cmat = bcin[..., n:]
    dt = jax.nn.softplus(dt + lp["dt_bias"].astype(dt.dtype))
    a_head = -jnp.exp(lp["A_log"]).astype(jnp.float32)
    bsz, s, _ = x.shape
    xh = xin.reshape(bsz, s, nh, m.head_dim)
    y, h_fin = ssd(xh.astype(jnp.float32), dt.astype(jnp.float32), a_head,
                   bmat.astype(jnp.float32), cmat.astype(jnp.float32), m.chunk)
    y = y + lp["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, di).astype(x.dtype)
    y = pm.apply_rmsnorm(lp["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    w = m.d_conv - 1
    return (pm.apply_linear(lp["out_proj"], y), h_fin,
            xraw[:, -w:, :], bcraw[:, -w:, :])


def mamba2_state(cfg: ModelConfig, n_layers: int, batch: int,
                 dtype=jnp.float32):
    m = cfg.ssm
    di = m.expand * cfg.d_model
    nh = di // m.head_dim
    return {"h": jnp.zeros((n_layers, batch, nh, m.head_dim, m.d_state), dtype),
            "conv_x": jnp.zeros((n_layers, batch, m.d_conv - 1, di), dtype),
            "conv_bc": jnp.zeros((n_layers, batch, m.d_conv - 1,
                                  2 * m.d_state), dtype)}


def mamba2_step(lp, x1, h, conv_x_buf, conv_bc_buf, cfg: ModelConfig):
    """Decode: x1 (B,1,d); h (B,H,P,N); conv bufs (B,w-1,*)."""
    m = cfg.ssm
    d = cfg.d_model
    di = m.expand * d
    n = m.d_state
    nh = di // m.head_dim
    z = pm.apply_linear(lp["z_proj"], x1)
    xin = pm.apply_linear(lp["x_proj"], x1)
    bcin = pm.apply_linear(lp["bc_proj"], x1)
    dt = pm.apply_linear(lp["dt_proj"], x1)
    conv_x_buf, xin = conv1d_step(lp["conv_x"], conv_x_buf, xin)
    conv_bc_buf, bcin = conv1d_step(lp["conv_bc"], conv_bc_buf, bcin)
    xin = jax.nn.silu(xin)
    bcin = jax.nn.silu(bcin)
    bmat = bcin[..., :n]
    cmat = bcin[..., n:]
    dt = jax.nn.softplus(dt + lp["dt_bias"].astype(dt.dtype))[:, 0]  # (B,H)
    a_head = -jnp.exp(lp["A_log"]).astype(jnp.float32)
    xh = xin[:, 0].reshape(-1, nh, m.head_dim).astype(jnp.float32)
    dec = jnp.exp(dt.astype(jnp.float32) * a_head)       # (B,H)
    xbar = xh * dt.astype(jnp.float32)[..., None]
    h = (h * dec[..., None, None]
         + jnp.einsum("bn,bhp->bhpn", bmat[:, 0].astype(jnp.float32), xbar))
    y = jnp.einsum("bhpn,bn->bhp", h, cmat[:, 0].astype(jnp.float32))
    y = y + lp["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(x1.shape[0], di).astype(x1.dtype)
    y = pm.apply_rmsnorm(lp["norm"], y * jax.nn.silu(z[:, 0]), cfg.norm_eps)
    return (pm.apply_linear(lp["out_proj"], y[:, None]), h,
            conv_x_buf, conv_bc_buf)
