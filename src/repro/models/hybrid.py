"""Zamba2-style hybrid: a stack of mamba2 layers with one SHARED attention
block (params reused) applied every ``shared_attn_every`` layers on
concat(h, x_embed) — so the shared block always sees both the residual
stream and the original embedding (Zamba2 design).

Structure per group g: shared_attn(concat(h, x0)) -> 2d -> proj to d, added
residually; then ``shared_attn_every`` mamba2 layers (lax.scan over the
group's stacked params).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba
from repro.models import param as pm
from repro.models.sharding import ShardCtx
from repro.models.transformer import ce_loss


def _n_groups(cfg: ModelConfig) -> int:
    return -(-cfg.n_layers // cfg.shared_attn_every)


def _init_shared(key, cfg: ModelConfig):
    """Shared transformer block over the 2*d concat stream."""
    d2 = 2 * cfg.d_model
    hq = cfg.n_heads
    dh = d2 // hq
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["ln1"], s["ln1"] = pm.rmsnorm(d2)
    p["wq"], s["wq"] = pm.linear(ks[0], d2, hq * dh, spec=("fsdp", "tp"))
    p["wk"], s["wk"] = pm.linear(ks[1], d2, hq * dh, spec=("fsdp", "tp"))
    p["wv"], s["wv"] = pm.linear(ks[2], d2, hq * dh, spec=("fsdp", "tp"))
    p["wo"], s["wo"] = pm.linear(ks[3], hq * dh, d2, spec=("tp", "fsdp"))
    p["ln2"], s["ln2"] = pm.rmsnorm(d2)
    p["wg"], s["wg"] = pm.linear(ks[4], d2, cfg.d_ff, spec=("fsdp", "tp"))
    p["wu"], s["wu"] = pm.linear(ks[5], d2, cfg.d_ff, spec=("fsdp", "tp"))
    p["wd"], s["wd"] = pm.linear(ks[6], cfg.d_ff, d2, spec=("tp", "fsdp"))
    p["out"], s["out"] = pm.linear(jax.random.fold_in(key, 9), d2,
                                   cfg.d_model, spec=("fsdp", "tp"))
    return p, s


def init_lm(cfg: ModelConfig, key) -> Tuple[dict, dict]:
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["embed"], s["embed"] = pm.embedding(ks[0], cfg.vocab, cfg.d_model)
    p["shared"], s["shared"] = _init_shared(ks[1], cfg)

    def layer_init(k):
        lp, ls = {}, {}
        lp["ln"], ls["ln"] = pm.rmsnorm(cfg.d_model)
        lp["mixer"], ls["mixer"] = mamba.init_mamba2(k, cfg)
        return lp, ls

    groups = _n_groups(cfg)
    per = cfg.shared_attn_every
    p["layers"], s["layers"] = pm.stacked(layer_init, groups * per, ks[2])
    p["ln_f"], s["ln_f"] = pm.rmsnorm(cfg.d_model)
    p["head"], s["head"] = pm.linear(ks[3], cfg.d_model, cfg.vocab,
                                     spec=("fsdp", "tp"))
    return p, s


def _shared_qkv(sp, h2, cfg, pos, shd: ShardCtx):
    b, s, d2 = h2.shape
    hq = cfg.n_heads
    dh = d2 // hq
    hn = pm.apply_rmsnorm(sp["ln1"], h2, cfg.norm_eps)
    q = pm.apply_linear(sp["wq"], hn).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    k = pm.apply_linear(sp["wk"], hn).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    v = pm.apply_linear(sp["wv"], hn).reshape(b, s, hq, dh).transpose(0, 2, 1, 3)
    q = attn.rope(q, pos[None, None, :], cfg.rope_theta)
    k = attn.rope(k, pos[None, None, :], cfg.rope_theta)
    q = shd.cst(q, "dp", "tp", None, None)
    k = shd.cst(k, "dp", "tp", None, None)
    return q, k, v


def _shared_block(sp, h, x0, pos, cfg, shd, backend) -> jax.Array:
    """Returns the d-dim residual contribution of the shared block."""
    h2 = jnp.concatenate([h, x0], axis=-1)
    q, k, v = _shared_qkv(sp, h2, cfg, pos, shd)
    if backend == "clusterkv" and cfg.clusterkv.enabled:
        o = attn.clusterkv_attention(q, k, v, pos, pos, cfg.clusterkv)
    elif backend == "dense":
        o = attn.dense_attention(q, k, v, pos, pos)
    else:
        o = attn.flash_attention(q, k, v, pos, pos)
    b, s, d2 = h2.shape
    a = pm.apply_linear(sp["wo"], o.transpose(0, 2, 1, 3).reshape(b, s, -1))
    h2 = h2 + a
    hn = pm.apply_rmsnorm(sp["ln2"], h2, cfg.norm_eps)
    f = jax.nn.silu(pm.apply_linear(sp["wg"], hn)) * pm.apply_linear(sp["wu"], hn)
    h2 = h2 + pm.apply_linear(sp["wd"], f)
    return pm.apply_linear(sp["out"], h2)


def _group_params(p, g: int, per: int):
    return jax.tree.map(lambda a: a[g * per:(g + 1) * per], p["layers"])


def forward(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    x0 = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    x0 = shd.cst(x0, "dp", None, None)
    h = x0
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    per = cfg.shared_attn_every

    def mamba_body(x, lp):
        y, _, _, _ = mamba.mamba2_forward(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps), cfg, shd)
        return x + y, None

    mamba_body = pm.maybe_remat(mamba_body, cfg)

    for g in range(_n_groups(cfg)):
        h = h + _shared_block(p["shared"], h, x0, pos, cfg, shd, backend)
        h, _ = jax.lax.scan(mamba_body, h, _group_params(p, g, per))
    return pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps), jnp.zeros((), jnp.float32)


def loss_fn(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash") -> jax.Array:
    h, _ = forward(p, cfg, batch, shd, backend)
    return ce_loss(h, p["head"]["w"].astype(cfg.dtype), batch["labels"],
                   cfg.loss_chunk)


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    groups = _n_groups(cfg)
    d2 = 2 * cfg.d_model
    hq = cfg.n_heads
    dh = d2 // hq
    st = mamba.mamba2_state(cfg, groups * cfg.shared_attn_every, batch_size)
    return {
        "ssm": st,
        "k": jnp.zeros((groups, batch_size, hq, max_seq, dh), dtype),
        "v": jnp.zeros((groups, batch_size, hq, max_seq, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, long_context: bool = False):
    kv = (P(None, "dp", None, "seq", None) if long_context
          else P(None, "dp", "tp", None, None))
    return {
        "ssm": {"h": P(None, "dp", "tp", None, None),
                "conv_x": P(None, "dp", None, "tp"),
                "conv_bc": P(None, "dp", None, None)},
        "k": kv, "v": kv, "pos": P(),
    }


def prefill(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    x0 = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    x0 = shd.cst(x0, "dp", None, None)
    h = x0
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    per = cfg.shared_attn_every

    def mamba_body(x, lp):
        y, h_fin, cx, cbc = mamba.mamba2_forward(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps), cfg, shd)
        return x + y, (h_fin, cx, cbc)

    mamba_body = pm.maybe_remat(mamba_body, cfg)

    ks, vs, hs, cxs, cbcs = [], [], [], [], []
    for g in range(_n_groups(cfg)):
        h2 = jnp.concatenate([h, x0], axis=-1)
        q, k, v = _shared_qkv(p["shared"], h2, cfg, pos, shd)
        ks.append(k.astype(cfg.dtype))
        vs.append(v.astype(cfg.dtype))
        h = h + _shared_block(p["shared"], h, x0, pos, cfg, shd, backend)
        h, (hf, cx, cbc) = jax.lax.scan(mamba_body, h, _group_params(p, g, per))
        hs.append(hf)
        cxs.append(cx)
        cbcs.append(cbc)
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, -1] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {
        "ssm": {"h": jnp.concatenate(hs, 0),
                "conv_x": jnp.concatenate(cxs, 0).astype(jnp.float32),
                "conv_bc": jnp.concatenate(cbcs, 0).astype(jnp.float32)},
        "k": jnp.stack(ks), "v": jnp.stack(vs),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return cache, logits


def decode_step(p, cfg: ModelConfig, cache, tokens, shd: ShardCtx,
                backend: str = "flash", sharded_long: bool = False):
    x0 = p["embed"]["table"][tokens].astype(cfg.dtype)
    h = x0
    b = h.shape[0]
    qpos = cache["pos"]
    s_max = cache["k"].shape[3]
    kpos = jnp.arange(s_max, dtype=jnp.int32)
    per = cfg.shared_attn_every
    d2 = 2 * cfg.d_model
    hq = cfg.n_heads
    dh = d2 // hq
    sp = p["shared"]

    def mamba_body(x, xs):
        lp, hst, cx, cbc = xs
        y, hst, cx, cbc = mamba.mamba2_step(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps),
            hst, cx, cbc, cfg)
        return x + y, (hst, cx, cbc)

    new_k, new_v, new_h, new_cx, new_cbc = [], [], [], [], []
    for g in range(_n_groups(cfg)):
        h2 = jnp.concatenate([h, x0], axis=-1)
        hn = pm.apply_rmsnorm(sp["ln1"], h2, cfg.norm_eps)
        q = pm.apply_linear(sp["wq"], hn).reshape(b, 1, hq, dh).transpose(0, 2, 1, 3)
        k1 = pm.apply_linear(sp["wk"], hn).reshape(b, 1, hq, dh).transpose(0, 2, 1, 3)
        v1 = pm.apply_linear(sp["wv"], hn).reshape(b, 1, hq, dh).transpose(0, 2, 1, 3)
        q = attn.rope(q, qpos[None, None, None].astype(jnp.int32), cfg.rope_theta)
        k1 = attn.rope(k1, qpos[None, None, None].astype(jnp.int32), cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(cache["k"][g], k1.astype(cache["k"].dtype),
                                          (0, 0, qpos, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"][g], v1.astype(cache["v"].dtype),
                                          (0, 0, qpos, 0))
        new_k.append(kc)
        new_v.append(vc)
        q1 = q[:, :, 0]
        if backend == "clusterkv" and cfg.clusterkv.enabled:
            if sharded_long and shd.mesh is not None:
                o = attn.clusterkv_decode_sharded(q1, kc, vc, kpos, qpos,
                                                  cfg.clusterkv, shd.mesh)
            else:
                o = attn.clusterkv_decode(q1, kc, vc, kpos, qpos, cfg.clusterkv)
        else:
            o = attn.decode_attention(q1, kc, vc, kpos, qpos)
        a = pm.apply_linear(sp["wo"], o.reshape(b, 1, -1))
        h2a = h2 + a
        hn2 = pm.apply_rmsnorm(sp["ln2"], h2a, cfg.norm_eps)
        f = jax.nn.silu(pm.apply_linear(sp["wg"], hn2)) * pm.apply_linear(sp["wu"], hn2)
        h2a = h2a + pm.apply_linear(sp["wd"], f)
        h = h + pm.apply_linear(sp["out"], h2a)

        gp = _group_params(p, g, per)
        sl = lambda a: a[g * per:(g + 1) * per]
        h, (hs_, cx_, cbc_) = jax.lax.scan(
            mamba_body, h, (gp, sl(cache["ssm"]["h"]),
                            sl(cache["ssm"]["conv_x"]),
                            sl(cache["ssm"]["conv_bc"])))
        new_h.append(hs_)
        new_cx.append(cx_)
        new_cbc.append(cbc_)

    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, 0] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {
        "ssm": {"h": jnp.concatenate(new_h, 0),
                "conv_x": jnp.concatenate(new_cx, 0),
                "conv_bc": jnp.concatenate(new_cbc, 0)},
        "k": jnp.stack(new_k), "v": jnp.stack(new_v),
        "pos": cache["pos"] + 1,
    }
    return logits, cache
