"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Train/prefill expand the latent into full per-head K/V and reuse the shared
attention backends. Decode runs the ABSORBED form: the cache holds only the
(normalized) latent c (rank) + shared RoPE key (dr) per token, query-side
projections are absorbed into the latent space, and attention operates on
the latent directly.

ClusterKV on MLA clusters in the *latent* space (DESIGN.md §6): the paper's
"embed first" step is literally MLA's latent projection, so centroids/top-c
selection run on c-blocks — both in the single-device decode path and the
seq-sharded shard_map path for long_500k.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import param as pm
from repro.models.sharding import ShardCtx

NEG_INF = -1e30


def _dims(cfg: ModelConfig):
    m = cfg.mla
    return (m.q_lora_rank, m.kv_lora_rank, m.qk_nope_head_dim,
            m.qk_rope_head_dim, m.v_head_dim)


def init_mla(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    qr, kr, dn, dr, dv = _dims(cfg)
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    p["q_a"], s["q_a"] = pm.linear(ks[0], d, qr, spec=("fsdp", None))
    p["q_ln"], s["q_ln"] = pm.rmsnorm(qr)
    p["q_b"], s["q_b"] = pm.linear(ks[1], qr, h * (dn + dr), spec=(None, "tp"))
    p["kv_a"], s["kv_a"] = pm.linear(ks[2], d, kr + dr, spec=("fsdp", None))
    p["kv_ln"], s["kv_ln"] = pm.rmsnorm(kr)
    p["kv_b"], s["kv_b"] = pm.linear(ks[3], kr, h * (dn + dv), spec=(None, "tp"))
    p["wo"], s["wo"] = pm.linear(ks[4], h * dv, d, spec=("tp", "fsdp"))
    return p, s


def _q_proj(lp, x, cfg: ModelConfig, pos):
    """x (B,S,d) -> q_nope (B,H,S,dn), q_rope (B,H,S,dr) (roped)."""
    b, s, _ = x.shape
    h = cfg.n_heads
    qr, kr, dn, dr, dv = _dims(cfg)
    q = pm.apply_linear(lp["q_b"],
                        pm.apply_rmsnorm(lp["q_ln"],
                                         pm.apply_linear(lp["q_a"], x)))
    q = q.reshape(b, s, h, dn + dr).transpose(0, 2, 1, 3)
    qn, qrope = q[..., :dn], q[..., dn:]
    qrope = attn.rope(qrope, pos[None, None, :], cfg.rope_theta)
    return qn, qrope


def _kv_latent(lp, x, cfg: ModelConfig, pos):
    """x (B,S,d) -> cn (B,S,rank) normalized latent, krope (B,S,dr) roped."""
    qr, kr, dn, dr, dv = _dims(cfg)
    kv = pm.apply_linear(lp["kv_a"], x)
    c, krope = kv[..., :kr], kv[..., kr:]
    cn = pm.apply_rmsnorm(lp["kv_ln"], c)
    krope = attn.rope(krope, pos[None, :], cfg.rope_theta)
    return cn, krope


def _expand_kv(lp, cn, cfg: ModelConfig):
    """cn (B,S,rank) -> k_nope (B,H,S,dn), v (B,H,S,dv)."""
    b, s, _ = cn.shape
    h = cfg.n_heads
    qr, kr, dn, dr, dv = _dims(cfg)
    kv = pm.apply_linear(lp["kv_b"], cn).reshape(b, s, h, dn + dv)
    kv = kv.transpose(0, 2, 1, 3)
    return kv[..., :dn], kv[..., dn:]


def mla_attention(lp, x, pos, cfg: ModelConfig, shd: ShardCtx,
                  backend: str) -> jax.Array:
    """Full (train/prefill) MLA attention, returns (B,S,d) incl. wo."""
    b, s, _ = x.shape
    h = cfg.n_heads
    qn, qrope = _q_proj(lp, x, cfg, pos)
    cn, krope = _kv_latent(lp, x, cfg, pos)
    kn, v = _expand_kv(lp, cn, cfg)
    q = jnp.concatenate([qn, qrope], axis=-1)
    k = jnp.concatenate(
        [kn, jnp.broadcast_to(krope[:, None], kn.shape[:-1] + (krope.shape[-1],))],
        axis=-1)
    q = shd.cst(q, "dp", "tp", None, None)
    k = shd.cst(k, "dp", "tp", None, None)
    if backend == "clusterkv" and cfg.clusterkv.enabled:
        o = attn.clusterkv_attention(q, k, v, pos, pos, cfg.clusterkv,
                                     causal=True)
    elif backend == "dense":
        o = attn.dense_attention(q, k, v, pos, pos, causal=True)
    else:
        o = attn.flash_attention(q, k, v, pos, pos, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, -1)
    return pm.apply_linear(lp["wo"], o)


# ---------------------------------------------------------------------------
# cache / prefill / absorbed decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    qr, kr, dn, dr, dv = _dims(cfg)
    return {
        "c": jnp.zeros((cfg.n_layers, batch_size, max_seq, kr), dtype),
        "kr": jnp.zeros((cfg.n_layers, batch_size, max_seq, dr), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, long_context: bool = False):
    if long_context:
        c = P(None, "dp", "seq", None)
    else:
        c = P(None, "dp", None, None)
    return {"c": c, "kr": c, "pos": P()}


def _mlp(lp, x):
    h = jax.nn.silu(pm.apply_linear(lp["wg"], x)) * pm.apply_linear(lp["wu"], x)
    return pm.apply_linear(lp["wd"], h)


def _embed(p, cfg, batch):
    return p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)


def prefill(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    h = _embed(p, cfg, batch)
    b, s, _ = h.shape
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        a = mla_attention(lp["attn"], hn, pos, cfg, shd, backend)
        cn, krope = _kv_latent(lp["attn"], hn, cfg, pos)
        x = x + a
        hn = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + _mlp(lp["ffn"], hn)
        return x, (cn.astype(cfg.dtype), krope.astype(cfg.dtype))

    body = pm.maybe_remat(body, cfg)
    h, (cs, krs) = jax.lax.scan(body, h, p["layers"])
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    w = (p["embed"]["table"].T if cfg.tie_embeddings else p["head"]["w"])
    logits = (h[:, -1] @ w.astype(cfg.dtype)).astype(jnp.float32)
    return {"c": cs, "kr": krs, "pos": jnp.asarray(s, jnp.int32)}, logits


def _absorbed_scores_attend(lp, qn, qrope, cc, krc, kpos, qpos, cfg,
                            shd: ShardCtx, backend: str, sharded_long: bool):
    """Absorbed-form attention over latent cache.

    qn (B,H,dn), qrope (B,H,dr); cc (B,S,rank); krc (B,S,dr).
    Returns o_lat (B,H,rank)."""
    qr_, kr_, dn, dr, dv = _dims(cfg)
    h = cfg.n_heads
    wkv = lp["kv_b"]["w"].reshape(kr_, h, dn + dv)
    wk = wkv[..., :dn]                                   # (rank, H, dn)
    q_lat = jnp.einsum("bhd,rhd->bhr", qn.astype(jnp.float32),
                       wk.astype(jnp.float32))
    scale = 1.0 / math.sqrt(dn + dr)

    if backend == "clusterkv" and cfg.clusterkv.enabled and shd.mesh is not None \
            and sharded_long:
        return _latent_decode_sharded(q_lat, qrope, cc, krc, kpos, qpos,
                                      cfg, shd, scale)
    logits = (jnp.einsum("bhr,bsr->bhs", q_lat, cc.astype(jnp.float32))
              + jnp.einsum("bhd,bsd->bhs", qrope.astype(jnp.float32),
                           krc.astype(jnp.float32))) * scale
    ok = kpos[None, None, :] <= qpos
    logits = jnp.where(ok, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bsr->bhr", w, cc.astype(jnp.float32))


def _latent_decode_sharded(q_lat, qrope, cc, krc, kpos, qpos, cfg,
                           shd: ShardCtx, scale):
    """ClusterKV decode on the latent cache, seq sharded over 'data':
    per-shard latent-block centroids -> top-c -> partial softmax -> psum."""
    mesh = shd.mesh
    axis = "data"
    b, s, rank = cc.shape
    hq = q_lat.shape[1]
    shards = mesh.shape[axis]
    s_local = s // shards
    bk = min(cfg.clusterkv.block_k, s_local)
    n_sel = min(cfg.clusterkv.decode_clusters, s_local // bk)

    def local(ql, qr2, cl, krl, pl):
        nkb = cl.shape[1] // bk
        cb = cl.reshape(b, nkb, bk, rank)
        krb = krl.reshape(b, nkb, bk, -1)
        pb = pl.reshape(nkb, bk)
        cent_c = cb.mean(axis=2)                          # (b, nkb, rank)
        cent_k = krb.mean(axis=2)
        sc = (jnp.einsum("bhr,bkr->bhk", ql, cent_c.astype(jnp.float32))
              + jnp.einsum("bhd,bkd->bhk", qr2.astype(jnp.float32),
                           cent_k.astype(jnp.float32)))
        sc = sc.mean(axis=1)                              # (b, nkb) shared sel
        _, idx = jax.lax.top_k(sc, n_sel)

        def per_b(qlb, qrb, cbb, krbb, it):
            csel = cbb[it].reshape(-1, rank).astype(jnp.float32)
            ksel = krbb[it].reshape(-1, krbb.shape[-1]).astype(jnp.float32)
            psel = pb.reshape(-1)[(it[:, None] * bk
                                   + jnp.arange(bk)[None, :]).reshape(-1)]
            lg = (qlb @ csel.T + qrb.astype(jnp.float32) @ ksel.T) * scale
            lg = jnp.where(psel[None, :] <= qpos, lg, NEG_INF)
            m = lg.max(axis=-1)
            pexp = jnp.exp(lg - m[:, None])
            return m, pexp.sum(-1), pexp @ csel

        m, l, o = jax.vmap(per_b)(ql, qr2, cb, krb, idx)
        mm = jax.lax.pmax(m, axis)
        alpha = jnp.exp(m - mm)
        ll = jax.lax.psum(l * alpha, axis)
        oo = jax.lax.psum(o * alpha[..., None], axis)
        return oo / jnp.maximum(ll, 1e-30)[..., None]

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(), P(), P(None, axis, None),
                            P(None, axis, None), P(axis)),
                  out_specs=P(), check_vma=False)
    return f(q_lat, qrope, cc, krc, kpos)


def decode_step(p, cfg: ModelConfig, cache, tokens, shd: ShardCtx,
                backend: str = "flash", sharded_long: bool = False):
    h = _embed(p, cfg, {"tokens": tokens})
    b = h.shape[0]
    qpos = cache["pos"]
    s_max = cache["c"].shape[2]
    kpos = jnp.arange(s_max, dtype=jnp.int32)
    qr_, kr_, dn, dr, dv = _dims(cfg)
    nheads = cfg.n_heads

    def body(x, xs):
        lp, cc, krc = xs                      # cc (B,S,rank), krc (B,S,dr)
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        qn, qrope = _q_proj(lp["attn"], hn, cfg, qpos[None].astype(jnp.int32))
        cn1, kr1 = _kv_latent(lp["attn"], hn, cfg,
                              qpos[None].astype(jnp.int32))
        cc = jax.lax.dynamic_update_slice(cc, cn1.astype(cc.dtype),
                                          (0, qpos, 0))
        krc = jax.lax.dynamic_update_slice(krc, kr1.astype(krc.dtype),
                                           (0, qpos, 0))
        o_lat = _absorbed_scores_attend(
            lp["attn"], qn[:, :, 0], qrope[:, :, 0], cc, krc, kpos, qpos,
            cfg, shd, backend, sharded_long)
        wkv = lp["attn"]["kv_b"]["w"].reshape(kr_, nheads, dn + dv)
        wv = wkv[..., dn:]
        o = jnp.einsum("bhr,rhd->bhd", o_lat, wv.astype(jnp.float32))
        a = pm.apply_linear(lp["attn"]["wo"],
                            o.reshape(b, 1, -1).astype(cfg.dtype))
        x = x + a
        hn = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + _mlp(lp["ffn"], hn)
        return x, (cc, krc)

    h, (cs, krs) = jax.lax.scan(body, h, (p["layers"], cache["c"],
                                          cache["kr"]))
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    w = (p["embed"]["table"].T if cfg.tie_embeddings else p["head"]["w"])
    logits = (h[:, 0] @ w.astype(cfg.dtype)).astype(jnp.float32)
    return logits, {"c": cs, "kr": krs, "pos": cache["pos"] + 1}
