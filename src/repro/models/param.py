"""Raw-JAX parameter construction: every init returns (params, specs) trees
with identical structure; specs carry logical axis tokens (models/sharding)."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def linear(key, d_in: int, d_out: int, *, spec=(None, None), bias: bool = False,
           dtype=jnp.float32, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), dtype) * scale)}
    s = {"w": P(*spec)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
        s["b"] = P(spec[-1])
    return p, s


def apply_linear(p, x: jax.Array, compute_dtype=None) -> jax.Array:
    """Matmul in the activation dtype: master params (f32) are cast to
    x.dtype (bf16 compute) so layer outputs keep the residual dtype."""
    dtype = compute_dtype or x.dtype
    y = x.astype(dtype) @ p["w"].astype(dtype)
    if "b" in p:
        y = y + p["b"].astype(y.dtype)
    return y


def embedding(key, vocab: int, d: int, *, spec=("tp", "fsdp"), dtype=jnp.float32):
    p = {"table": jax.random.normal(key, (vocab, d), dtype) * (d ** -0.5)}
    s = {"table": P(*spec)}
    return p, s


def rmsnorm(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": P(None)}


def apply_rmsnorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def stacked(init_fn, n: int, key) -> Tuple[dict, dict]:
    """Stack ``n`` independent layer inits along a new leading axis.

    ``init_fn(key) -> (params, specs)``; returns stacked params with the
    leading layer axis unsharded in specs.
    """
    keys = jax.random.split(key, n)
    p0, s0 = init_fn(keys[0])
    params = jax.vmap(lambda k: init_fn(k)[0])(keys)
    specs = jax.tree.map(lambda s: P(*((None,) + tuple(s))), s0,
                         is_leaf=lambda x: isinstance(x, P))
    return params, specs


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(lambda x: x.astype(dtype)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


def maybe_remat(body, cfg):
    """Wrap a scan body with jax.checkpoint per cfg.remat/remat_policy."""
    import jax
    if not cfg.remat:
        return body
    if getattr(cfg, "remat_policy", "full") == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(body, policy=pol)
    return jax.checkpoint(body)
