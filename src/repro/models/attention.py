"""Attention backends.

Layout convention: q/k/v are (B, H, S, dh); positions are int32.

  dense_attention   naive full logits — tiny smoke tests only
  flash_attention   lax.scan over key tiles with online softmax (GQA-aware,
                    causal and sliding-window masks) — the memory-sane
                    full-attention path used by train/prefill lowerings
  decode_attention  single-token einsum over the whole cache (logits are
                    O(S), never O(S^2)); GSPMD shards the cache seq axis
  clusterkv_*       the paper's technique (core/clusterkv): cluster-sorted
                    keys, top-B dense tiles per query tile; sharded decode
                    combines per-shard partial softmax (flash-decode style)
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from repro.compat import shard_map

from repro.configs.base import ClusterKVConfig
from repro.core import clusterkv as ckv
from repro.core.registry import register_decode_backend

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: jax.Array, pos: jax.Array, theta: float = 1e4) -> jax.Array:
    """Rotary embedding. x (..., S, dh), pos (..., S) broadcastable."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[..., None].astype(jnp.float32) * freqs      # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# full-attention paths
# ---------------------------------------------------------------------------


def _mask(logit, qpos, kpos, causal: bool, window: int):
    ok = jnp.ones(logit.shape[-2:], bool)
    if causal:
        ok = kpos[None, :] <= qpos[:, None]
    if window:
        ok = ok & (kpos[None, :] > qpos[:, None] - window)
    return jnp.where(ok, logit, NEG_INF)


def dense_attention(q, k, v, qpos, kpos, *, causal=True, window=0):
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, s, dh)
    logit = jnp.einsum("bhgsd,bhtd->bhgst", qg.astype(jnp.float32),
                       k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    logit = _mask(logit, qpos, kpos, causal, window)
    w = jax.nn.softmax(logit, axis=-1)
    o = jnp.einsum("bhgst,bhtd->bhgsd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, s, v.shape[-1]).astype(q.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block"))
def flash_attention(q, k, v, qpos, kpos, *, causal=True, window=0,
                    block: int = 512):
    """Blockwise online-softmax attention, scan over key tiles."""
    b, hq, s, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / jnp.sqrt(float(dh))
    nb = -(-skv // block)
    pad = nb * block - skv
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    posp = jnp.pad(kpos, (0, pad), constant_values=jnp.iinfo(jnp.int32).max)
    qg = q.reshape(b, hkv, g, s, dh).astype(jnp.float32)

    kb = kp.reshape(b, hkv, nb, block, dh)
    vb = vp.reshape(b, hkv, nb, block, v.shape[-1])
    pb = posp.reshape(nb, block)

    pad_pos = jnp.iinfo(jnp.int32).max

    def step(carry, xs):
        m, l, acc = carry
        kt, vt, pt = xs                       # (b,hkv,block,dh), ..., (block,)
        logit = jnp.einsum("bhgsd,bhtd->bhgst", qg,
                           kt.astype(jnp.float32)) * scale
        ok = jnp.broadcast_to(pt[None, :] != pad_pos, (s, block))
        if causal:
            ok = ok & (pt[None, :] <= qpos[:, None])
        if window:
            ok = ok & (pt[None, :] > qpos[:, None] - window)
        logit = jnp.where(ok, logit, NEG_INF)
        m_new = jnp.maximum(m, logit.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logit - m_new[..., None])
        l = l * alpha + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgst,bhtd->bhgsd", p, vt.astype(jnp.float32))
        return (m_new, l, acc), None

    dv = v.shape[-1]
    m0 = jnp.full((b, hkv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.moveaxis(kb, 2, 0), jnp.moveaxis(vb, 2, 0), pb))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    return o.reshape(b, hq, s, dv).astype(q.dtype)


def decode_attention(q, k, v, kpos, qpos, *, window=0):
    """q (B,Hq,dh) one token; cache k/v (B,Hkv,S,dh); kpos (B,S) or (S,).

    Entries with kpos > qpos are masked (unfilled cache slots / future)."""
    b, hq, dh = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, kpos.shape[0]))
    qg = q.reshape(b, hkv, g, dh).astype(jnp.float32)
    logit = jnp.einsum("bhgd,bhtd->bhgt", qg,
                       k.astype(jnp.float32)) / jnp.sqrt(float(dh))
    ok = kpos[:, None, None, :] <= qpos
    if window:
        ok = ok & (kpos[:, None, None, :] > qpos - window)
    logit = jnp.where(ok, logit, NEG_INF)
    w = jax.nn.softmax(logit, axis=-1)
    o = jnp.einsum("bhgt,bhtd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# cluster-sparse backend (the paper's technique)
# ---------------------------------------------------------------------------


def clusterkv_attention(q, k, v, qpos, kpos, cfg: ClusterKVConfig, *,
                        causal=True, plan_batch=None):
    """Block-sparse attention over cluster-sorted keys (train/prefill).

    The paper reorders BOTH matrix dimensions (pi_t and pi_s). Keys are
    always cluster-sorted; for non-causal attention (encoder/cross/t-SNE
    style) queries are cluster-sorted too — per head — so query tiles are
    cluster-coherent and centroid selection is sharp; outputs are scattered
    back to original order. For causal LM attention queries stay in time
    order (the local-window boost supplies recency; sorting queries would
    scramble the causal frontier).

    ``plan_batch`` (an ``api.PlanBatch`` from ``ckv.kv_plan_batch(k)``,
    or the stacked (B, Hkv, Skv) ordering array extracted from one)
    supplies the per-head key ordering as a persistent plan asset instead
    of the private per-call Morton sort — the serving path builds it once
    at prefill, refreshes/checkpoints it with the cache, and every
    subsequent call skips the embed+sort work. The array form is traced
    data, so the decode service passes each session's orderings into ONE
    compiled prefill shared by every spec-identical session. Key entries
    with ``kpos == INT32_MAX`` are treated as holes (capacity slots not
    yet streamed into) and never attended.
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    bq = min(cfg.block_q, s)
    bk = min(cfg.block_k, s)
    nqb, nkb = s // bq, k.shape[2] // bk
    n_sel = min(cfg.blocks_per_query, nkb)

    if kpos.ndim == 1:
        kposb = jnp.broadcast_to(kpos, (b, hkv, kpos.shape[0]))
    else:
        kposb = kpos
    if plan_batch is None:
        perm = ckv.cluster_perm(k, d=cfg.embed_dim)
    elif hasattr(plan_batch, "data"):
        perm = ckv.plan_batch_perm(plan_batch, (b, hkv))
    else:
        perm = jnp.asarray(plan_batch).astype(jnp.int32)
    k_s, v_s, pos_s = ckv.permute_kv(k, v, kposb, perm)
    cent = ckv.block_centroids(k_s, bk)
    posb = pos_s.reshape(b, hkv, nkb, bk)
    kpmin = posb.min(-1)
    # hole slots carry the INT32_MAX sentinel: they must not inflate the
    # tile's max position (that would make every holey tile look "recent"
    # and soak up the local-window boost)
    kpmax = jnp.where(posb == jnp.iinfo(jnp.int32).max, -1, posb).max(-1)

    if not causal:
        # pi_t: query cluster sort per kv-head group (positions irrelevant)
        g = hq // hkv
        q_grp = q.reshape(b, hkv, g, s, dh).mean(axis=2)    # (B,Hkv,S,dh)
        qperm = ckv.cluster_perm(q_grp, d=cfg.embed_dim)    # (B,Hkv,S)
        qperm_h = jnp.repeat(qperm, g, axis=1)              # (B,Hq,S)
        q_s = jnp.take_along_axis(q, qperm_h[..., None], axis=-2)
        qc = q_s.reshape(b, hkv, g, nqb, bq, dh).mean(axis=(2, 4))
        zero = jnp.zeros((nqb,), jnp.int32)
        idx = ckv.select_blocks(qc.astype(jnp.float32),
                                cent.astype(jnp.float32), kpmin, kpmax,
                                zero, zero, n_sel, bq, causal=False)
        out_s = _tile_attention(q_s, k_s, v_s, pos_s, qpos, idx, bq, bk,
                                False, cfg)
        inv = jnp.argsort(qperm_h, axis=-1)
        return jnp.take_along_axis(out_s, inv[..., None], axis=-2)

    qpmin = qpos.reshape(nqb, bq).min(-1)
    qpmax = qpos.reshape(nqb, bq).max(-1)
    qc = q.reshape(b, hkv, hq // hkv, nqb, bq, dh).mean(axis=(2, 4))
    idx = ckv.select_blocks(qc.astype(jnp.float32), cent.astype(jnp.float32),
                            kpmin, kpmax, qpmin, qpmax, n_sel, bq,
                            causal=causal,
                            local_window=cfg.local_window_blocks * bk)
    return _tile_attention(q, k_s, v_s, pos_s, qpos, idx, bq, bk, causal, cfg)


def _tile_attention(q, k_s, v_s, pos_s, qpos, idx, bq, bk, causal,
                    cfg: ClusterKVConfig):
    """Dense-tile interaction: Pallas kernel when requested, jnp otherwise."""
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.block_attention(q, k_s, v_s, pos_s, qpos, idx,
                                    bq=bq, bk=bk, causal=causal)
    return ckv.sparse_block_attention(q, k_s, v_s, pos_s, qpos, idx, bq, bk,
                                      causal=causal)


def clusterkv_decode(q, k, v, kpos, qpos, cfg: ClusterKVConfig):
    """Single-token decode: top-c tiles by centroid score, gathered attend.

    ``cfg.use_pallas`` routes the select+gather+attend chain through the
    fused Mosaic kernel (``kernels/decode_attend.py``) instead of the two
    unfused XLA ops — bitwise-identical output, selected tiles stream
    from HBM exactly once."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    bk = min(cfg.block_k, s)
    if s % bk:
        # cache length not tile-aligned (e.g. ad-hoc growth in examples):
        # fall back to dense decode — correct, just not sparse
        kp = kpos if kpos.ndim == 1 else kpos[0, 0]
        return decode_attention(q, k, v, kp, qpos)
    nkb = s // bk
    n_sel = min(cfg.decode_clusters, nkb)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, hkv, kpos.shape[0]))
    cent = ckv.block_centroids(k, bk)
    if cfg.use_pallas:
        from repro.kernels import ops as kops
        return kops.decode_attend_fused(q, k, v, kpos, cent, qpos,
                                        n_sel=n_sel, bk=bk)
    idx = ckv.decode_select(q.astype(jnp.float32), cent.astype(jnp.float32),
                            n_sel)
    return ckv.decode_attend(q, k, v, kpos, qpos, idx, bk)


def clusterkv_plan_decode(q, ks, vs, ps, cent, qpos, cfg: ClusterKVConfig, *,
                          k_self=None, v_self=None):
    """Single-token decode over PLAN-ordered caches (the decode service).

    q (B,Hq,dh); ks/vs (B,Hkv,S,dh) keys/values already in plan (cluster)
    order; ps (B,Hkv,S) int32 original time position of each plan slot,
    with ``INT32_MAX`` marking capacity holes not yet streamed into;
    cent (B,Hkv,S/bk,dh) per-tile centroids maintained incrementally by
    the service; qpos (B,) per-slot decode positions.

    ``k_self``/``v_self`` (B,Hkv,dh) optionally carry the CURRENT token's
    key/value as an always-visible extra column: the service lands each
    generated token into the plan one tick later (insert-tier streaming is
    host-side), so self-attention must not depend on the landing.

    No embed/sort/full-centroid work happens here — that is the point:
    everything order-derived is serving state, this is pure gather+attend.

    Dispatches through the decode-backend registry: ``cfg.decode_backend``
    names ``"xla"`` (the unfused select/gather/attend below) or
    ``"pallas"`` (the fused Mosaic kernel); ``"auto"`` asks the analytic
    cost model (``core.costmodel.choose_decode_backend``) — the same
    ``repro.cost/v1`` model that ranks the SpMV backends — which prices
    the fused kernel's single launch and once-only tile traffic against
    the XLA path's gather round-trip (and its interpret-mode slowdown on
    CPU, where the XLA path keeps winning).
    """
    from repro.core.registry import get_decode_backend

    name = cfg.decode_backend
    if name == "auto":
        from repro.core import costmodel
        from repro.kernels import ops as kops

        b, hq, dh = q.shape
        hkv, s = ks.shape[1], ks.shape[2]
        bk = min(cfg.block_k, s)
        feat = costmodel.DecodeFeatures(
            batch=b, hq=hq, hkv=hkv, s=s, dh=dh, dv=vs.shape[-1], bk=bk,
            n_sel=min(cfg.decode_clusters, s // bk))
        name = costmodel.choose_decode_backend(
            feat, interpret=kops._interpret())
    return get_decode_backend(name)(q, ks, vs, ps, cent, qpos, cfg,
                                    k_self=k_self, v_self=v_self)


@register_decode_backend("xla")
def _plan_decode_xla(q, ks, vs, ps, cent, qpos, cfg: ClusterKVConfig, *,
                     k_self=None, v_self=None):
    """The unfused reference: top-k select, vmapped tile gather, attend."""
    b, hq, dh = q.shape
    hkv, s = ks.shape[1], ks.shape[2]
    g = hq // hkv
    dv = vs.shape[-1]
    bk = min(cfg.block_k, s)
    nkb = s // bk
    n_sel = min(cfg.decode_clusters, nkb)
    big = jnp.iinfo(jnp.int32).max

    pt = ps.reshape(b, hkv, nkb, bk)
    qp = qpos.astype(jnp.int32)                       # (B,)
    live = pt <= qp[:, None, None, None]              # causal AND not-a-hole
    tile_has = live.any(-1)                           # (B,Hkv,nkb)
    qg = q.reshape(b, hkv, g, dh).mean(axis=2).astype(jnp.float32)
    # multiply+reduce, not einsum: batching-stable M=1 contraction (see
    # ckv.decode_select) so the fused kernel scores bitwise-identically
    scores = jnp.sum(qg[:, :, None, :] * cent.astype(jnp.float32), -1)
    scores = jnp.where(tile_has, scores, NEG_INF)
    recent = jnp.where(live, pt, -1).max(-1)
    near = recent >= (qp[:, None, None] - cfg.local_window_blocks * bk)
    scores = jnp.where(near & tile_has, scores + 1e4, scores)
    _, idx = jax.lax.top_k(scores, n_sel)             # (B,Hkv,n_sel)

    kb = ks.reshape(b, hkv, nkb, bk, dh)
    vb = vs.reshape(b, hkv, nkb, bk, dv)
    if k_self is None:
        k_self = jnp.zeros((b, hkv, dh), ks.dtype)
        v_self = jnp.zeros((b, hkv, dv), vs.dtype)
        self_pos = jnp.full((b, hkv), big, jnp.int32)   # masked out
    else:
        self_pos = jnp.broadcast_to(qp[:, None], (b, hkv))

    def per_h(qh, kt, vt, pt_, it, ksf, vsf, spos, qp_):
        # qh (g,dh)  kt (nkb,bk,dh)  vt (nkb,bk,dv)  pt_ (nkb,bk)  it (c,)
        ksel = jnp.concatenate([kt[it].reshape(-1, dh), ksf[None, :]], 0)
        vsel = jnp.concatenate([vt[it].reshape(-1, dv), vsf[None, :]], 0)
        psel = jnp.concatenate([pt_[it].reshape(-1), spos[None]], 0)
        logit = ckv.decode_logits(qh.astype(jnp.float32),
                                  ksel.astype(jnp.float32))
        # guarded (see ckv.masked_softmax): a just-admitted slot can select
        # nothing but holes when no self column rides along
        w = ckv.masked_softmax(logit, psel[None, :] <= qp_)
        return ckv.decode_combine(w, vsel.astype(jnp.float32)).astype(q.dtype)

    out = jax.vmap(jax.vmap(per_h, in_axes=(0, 0, 0, 0, 0, 0, 0, 0, None)),
                   in_axes=(0, 0, 0, 0, 0, 0, 0, 0, 0))(
        q.reshape(b, hkv, g, dh), kb, vb, pt, idx,
        k_self, v_self, self_pos, qp)
    return out.reshape(b, hq, dv)


def clusterkv_percall_decode(q, k, v, kpos, qpos, cfg: ClusterKVConfig):
    """Per-call clusterkv decode for per-slot position vectors (qpos (B,)).

    Re-derives the Morton ordering and ALL tile centroids of the whole
    cache on every generated token — the baseline cost the plan-cached
    service amortizes away. Kept as the continuous-batching analogue of
    :func:`clusterkv_decode` (whose scalar-qpos contract serves the
    single-sequence cache path)."""
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    bk = min(cfg.block_k, s)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, hkv, s))
    if s % bk:
        return decode_attention(q, k, v, kpos[:, 0],
                                qpos[:, None, None, None])
    perm = ckv.cluster_perm(k, d=cfg.embed_dim)       # per call — the cost
    ks, vs, ps = ckv.permute_kv(k, v, kpos, perm)
    cent = ckv.block_centroids(ks.astype(jnp.float32), bk)
    return clusterkv_plan_decode(q, ks, vs, ps, cent, qpos, cfg)


def clusterkv_decode_sharded(q, k, v, kpos, qpos, cfg: ClusterKVConfig,
                             mesh: Mesh, axis: str = "data"):
    """Long-context decode with the cache sequence sharded over ``axis``.

    Every shard selects its local top-c cluster tiles, computes a partial
    softmax (m, l, o), and partials combine with pmax/psum — flash-decode
    with the paper's cluster selection inside each shard. No cross-shard
    gathers ever touch the cache.
    """
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    shards = mesh.shape[axis]
    s_local = s // shards
    bk = min(cfg.block_k, s_local)
    n_sel = min(cfg.decode_clusters, s_local // bk)
    if kpos.ndim == 1:
        kpos = jnp.broadcast_to(kpos, (b, hkv, s))

    def local(qh, kl, vl, pl):
        # kl/vl (b, hkv, s_local, dh); pl (b, hkv, s_local)
        cent = ckv.block_centroids(kl, bk)
        idx = ckv.decode_select(qh.astype(jnp.float32),
                                cent.astype(jnp.float32), n_sel)
        g = hq // hkv
        nkb = s_local // bk
        kb = kl.reshape(b, hkv, nkb, bk, dh)
        vb = vl.reshape(b, hkv, nkb, bk, dh)
        pb = pl.reshape(b, hkv, nkb, bk)

        def per_bh(qg, kt, vt, pt, it):
            ksel = kt[it].reshape(-1, dh).astype(jnp.float32)
            vsel = vt[it].reshape(-1, dh).astype(jnp.float32)
            psel = pt[it].reshape(-1)
            logit = (qg.astype(jnp.float32) @ ksel.T) / jnp.sqrt(float(dh))
            logit = jnp.where(psel[None, :] <= qpos, logit, NEG_INF)
            m = logit.max(axis=-1)
            p = jnp.exp(logit - m[:, None])
            return m, p.sum(-1), p @ vsel

        m, l, o = jax.vmap(jax.vmap(per_bh))(
            qh.reshape(b, hkv, g, dh), kb, vb, pb, idx)
        mm = jax.lax.pmax(m, axis)
        alpha = jnp.exp(m - mm)
        ll = jax.lax.psum(l * alpha, axis)
        oo = jax.lax.psum(o * alpha[..., None], axis)
        out = oo / jnp.maximum(ll, 1e-30)[..., None]
        return out.reshape(b, hq, dh).astype(q.dtype)

    f = shard_map(local, mesh=mesh,
                  in_specs=(P(), P(None, None, axis, None),
                            P(None, None, axis, None), P(None, None, axis)),
                  out_specs=P(), check_vma=False)
    return f(q, k, v, kpos)
