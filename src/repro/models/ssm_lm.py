"""falcon-mamba-style attention-free LM: a stack of mamba1 blocks."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import mamba
from repro.models import param as pm
from repro.models.sharding import ShardCtx
from repro.models.transformer import ce_loss


def _init_layer(key, cfg: ModelConfig):
    p, s = {}, {}
    p["ln"], s["ln"] = pm.rmsnorm(cfg.d_model)
    p["mixer"], s["mixer"] = mamba.init_mamba1(key, cfg)
    return p, s


def init_lm(cfg: ModelConfig, key) -> Tuple[dict, dict]:
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["embed"], s["embed"] = pm.embedding(ks[0], cfg.vocab, cfg.d_model)
    p["layers"], s["layers"] = pm.stacked(
        lambda k: _init_layer(k, cfg), cfg.n_layers, ks[1])
    p["ln_f"], s["ln_f"] = pm.rmsnorm(cfg.d_model)
    p["head"], s["head"] = pm.linear(ks[2], cfg.d_model, cfg.vocab,
                                     spec=("fsdp", "tp"))
    return p, s


def forward(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    h = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    h = shd.cst(h, "dp", None, None)

    def body(x, lp):
        y, _, _ = mamba.mamba1_forward(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps), cfg, shd)
        return x + y, None

    body = pm.maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, h, p["layers"])
    return pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps), jnp.zeros((), jnp.float32)


def loss_fn(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash") -> jax.Array:
    h, _ = forward(p, cfg, batch, shd, backend)
    return ce_loss(h, p["head"]["w"].astype(cfg.dtype), batch["labels"],
                   cfg.loss_chunk)


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=jnp.float32) -> Dict[str, Any]:
    st = mamba.mamba1_state(cfg, batch_size, dtype)
    st["pos"] = jnp.zeros((), jnp.int32)
    return st


def cache_specs(cfg: ModelConfig, long_context: bool = False):
    return {"h": P(None, "dp", "tp", None),
            "conv": P(None, "dp", None, "tp"),
            "pos": P()}


def prefill(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    h = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    h = shd.cst(h, "dp", None, None)
    s = h.shape[1]

    def body(x, lp):
        y, h_fin, conv_buf = mamba.mamba1_forward(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps), cfg, shd)
        return x + y, (h_fin, conv_buf)

    body = pm.maybe_remat(body, cfg)
    h, (hs, convs) = jax.lax.scan(body, h, p["layers"])
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, -1] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {"h": hs, "conv": convs.astype(jnp.float32),
             "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(p, cfg: ModelConfig, cache, tokens, shd: ShardCtx,
                backend: str = "flash", sharded_long: bool = False):
    h = p["embed"]["table"][tokens].astype(cfg.dtype)

    def body(x, xs):
        lp, hst, conv_buf = xs
        y, hst, conv_buf = mamba.mamba1_step(
            lp["mixer"], pm.apply_rmsnorm(lp["ln"], x, cfg.norm_eps),
            hst, conv_buf, cfg)
        return x + y, (hst, conv_buf)

    h, (hs, convs) = jax.lax.scan(body, h, (p["layers"], cache["h"],
                                            cache["conv"]))
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, 0] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    return logits, {"h": hs, "conv": convs, "pos": cache["pos"] + 1}
