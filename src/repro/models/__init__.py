from repro.models import (attention, encdec, hybrid, mamba, mla, model_api,
                          moe, param, sharding, ssm_lm, transformer)

__all__ = ["attention", "encdec", "hybrid", "mamba", "mla", "model_api",
           "moe", "param", "sharding", "ssm_lm", "transformer"]
