"""Decoder-only transformer LM covering the dense / vlm / moe families
(GQA, optional QKV bias, optional SWA, optional MLA via models.mla,
optional MoE FFN via models.moe, optional cluster-sparse attention).

All layers are stacked and applied with lax.scan; remat wraps the layer
body. Params are (tree, spec-tree) pairs from models.param.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import mla as mla_mod
from repro.models import param as pm
from repro.models.sharding import ShardCtx, NO_SHARD

AUX_COEF = 0.01


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ModelConfig):
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    pq, sq = pm.linear(ks[0], d, hq * dh, spec=("fsdp", "tp"), bias=cfg.qkv_bias)
    pk, sk = pm.linear(ks[1], d, hkv * dh, spec=("fsdp", "tp"), bias=cfg.qkv_bias)
    pv, sv = pm.linear(ks[2], d, hkv * dh, spec=("fsdp", "tp"), bias=cfg.qkv_bias)
    po, so = pm.linear(ks[3], hq * dh, d, spec=("tp", "fsdp"))
    return ({"wq": pq, "wk": pk, "wv": pv, "wo": po},
            {"wq": sq, "wk": sk, "wv": sv, "wo": so})


def _init_mlp(key, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    pg, sg = pm.linear(ks[0], d, f, spec=("fsdp", "tp"))
    pu, su = pm.linear(ks[1], d, f, spec=("fsdp", "tp"))
    pd, sd = pm.linear(ks[2], f, d, spec=("tp", "fsdp"))
    return ({"wg": pg, "wu": pu, "wd": pd}, {"wg": sg, "wu": su, "wd": sd})


def _init_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = pm.rmsnorm(cfg.d_model)
    p["ln2"], s["ln2"] = pm.rmsnorm(cfg.d_model)
    if cfg.mla is not None:
        p["attn"], s["attn"] = mla_mod.init_mla(ks[0], cfg)
    else:
        p["attn"], s["attn"] = _init_attn(ks[0], cfg)
    if cfg.moe is not None:
        p["ffn"], s["ffn"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["ffn"], s["ffn"] = _init_mlp(ks[1], cfg)
    return p, s


def init_lm(cfg: ModelConfig, key) -> Tuple[dict, dict]:
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    if not cfg.embedding_inputs:
        p["embed"], s["embed"] = pm.embedding(ks[0], cfg.vocab, cfg.d_model)
    p["layers"], s["layers"] = pm.stacked(
        lambda k: _init_layer(k, cfg), cfg.n_layers, ks[1])
    p["ln_f"], s["ln_f"] = pm.rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        p["head"], s["head"] = pm.linear(ks[2], cfg.d_model, cfg.vocab,
                                         spec=("fsdp", "tp"))
    return p, s


# ---------------------------------------------------------------------------
# layer application
# ---------------------------------------------------------------------------


def _project_qkv(lp, x, cfg: ModelConfig, pos, shd: ShardCtx):
    b, s, d = x.shape
    hq, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = pm.apply_linear(lp["wq"], x).reshape(b, s, hq, dh)
    k = pm.apply_linear(lp["wk"], x).reshape(b, s, hkv, dh)
    v = pm.apply_linear(lp["wv"], x).reshape(b, s, hkv, dh)
    rp = pos if pos.ndim == 3 else pos[None, None, :]   # (B,1,S) or (1,1,S)
    q = attn.rope(q.transpose(0, 2, 1, 3), rp, cfg.rope_theta)
    k = attn.rope(k.transpose(0, 2, 1, 3), rp, cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    q = shd.cst(q, "dp", "tp", None, None)
    k = shd.cst(k, "dp", "tp", None, None)
    v = shd.cst(v, "dp", "tp", None, None)
    return q, k, v


def _attend(q, k, v, pos, cfg: ModelConfig, backend: str):
    if backend == "clusterkv" and cfg.clusterkv.enabled:
        return attn.clusterkv_attention(q, k, v, pos, pos, cfg.clusterkv,
                                        causal=True)
    if backend == "dense":
        return attn.dense_attention(q, k, v, pos, pos, causal=True,
                                    window=cfg.swa_window)
    return attn.flash_attention(q, k, v, pos, pos, causal=True,
                                window=cfg.swa_window)


def _apply_mlp(lp, x):
    h = jax.nn.silu(pm.apply_linear(lp["wg"], x)) * pm.apply_linear(lp["wu"], x)
    return pm.apply_linear(lp["wd"], h)


def _layer(lp, x, pos, cfg: ModelConfig, shd: ShardCtx, backend: str):
    b, s, d = x.shape
    h = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        a = mla_mod.mla_attention(lp["attn"], h, pos, cfg, shd, backend)
    else:
        q, k, v = _project_qkv(lp["attn"], h, cfg, pos, shd)
        o = _attend(q, k, v, pos, cfg, backend)
        a = pm.apply_linear(lp["attn"]["wo"], o.transpose(0, 2, 1, 3)
                            .reshape(b, s, -1))
    x = shd.cst(x + a, "dp", None, None)
    h = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if cfg.moe is not None:
        f, aux = moe_mod.moe_ffn(lp["ffn"], h, cfg, shd)
    else:
        f, aux = _apply_mlp(lp["ffn"], h), jnp.zeros((), jnp.float32)
    x = shd.cst(x + f, "dp", None, None)
    return x, aux


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------


def embed_tokens(p, cfg: ModelConfig, batch: Dict[str, jax.Array],
                 shd: ShardCtx) -> jax.Array:
    if cfg.embedding_inputs:
        h = batch["embeddings"].astype(cfg.dtype)
    else:
        h = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    return shd.cst(h, "dp", None, None)


def forward(p, cfg: ModelConfig, batch: Dict[str, jax.Array], shd: ShardCtx,
            backend: str = "flash") -> Tuple[jax.Array, jax.Array]:
    """Returns (final hidden states (B,S,d), aux loss)."""
    h = embed_tokens(p, cfg, batch, shd)
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        x, a = _layer(lp, x, pos, cfg, shd, backend)
        return (x, aux + a), None

    body = pm.maybe_remat(body, cfg)
    (h, aux), _ = jax.lax.scan(body, (h, jnp.zeros((), jnp.float32)),
                               p["layers"])
    return pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps), aux


def lm_head_weight(p, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return p["embed"]["table"].T
    return p["head"]["w"]


def ce_loss(h: jax.Array, w: jax.Array, labels: jax.Array,
            chunk: int = 0) -> jax.Array:
    """Chunked cross-entropy: logits for one token-chunk at a time, so the
    (tokens x vocab) array is never materialized (vocab stays TP-sharded)."""
    b, s, d = h.shape
    hf = h.reshape(b * s, d)
    lf = labels.reshape(b * s)
    t = b * s
    if chunk and chunk < t and t % chunk == 0:
        def step(_, xs):
            hc, lc = xs
            logits = (hc @ w).astype(jnp.float32)
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, lc[:, None], axis=-1)[:, 0]
            return None, jnp.sum(lse - gold)
        _, partial = jax.lax.scan(
            step, None, (hf.reshape(-1, chunk, d), lf.reshape(-1, chunk)))
        return partial.sum() / t
    logits = (hf @ w).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lf[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold)


def loss_fn(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash") -> jax.Array:
    h, aux = forward(p, cfg, batch, shd, backend)
    w = lm_head_weight(p, cfg).astype(cfg.dtype)
    if not cfg.tie_embeddings:
        # gather the (fsdp-sharded) head weight ONCE, keeping only the vocab
        # dim sharded — otherwise every CE chunk all-reduces a full
        # (chunk x vocab-shard) logits block across the data axis
        # (tied heads skip this: the transposed-table gather would conflict
        # with the embedding lookup's sharding)
        w = shd.cst(w, None, "tp")
    return ce_loss(h, w, batch["labels"], cfg.loss_chunk) + AUX_COEF * aux


# ---------------------------------------------------------------------------
# prefill / decode
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.mla is not None:
        return mla_mod.init_cache(cfg, batch_size, max_seq, dtype)
    l, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((l, batch_size, hkv, max_seq, dh), dtype),
        "v": jnp.zeros((l, batch_size, hkv, max_seq, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, long_context: bool = False):
    """Logical PartitionSpecs for the cache (seq sharded for long ctx)."""
    if cfg.mla is not None:
        return mla_mod.cache_specs(cfg, long_context)
    if long_context:
        kv = P(None, "dp", None, "seq", None)
    else:
        kv = P(None, "dp", "tp", None, None)
    return {"k": kv, "v": kv, "pos": P()}


def prefill(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash") -> Tuple[Dict, jax.Array]:
    """Forward over the prompt, returning a filled cache + last logits."""
    if cfg.mla is not None:
        return mla_mod.prefill(p, cfg, batch, shd, backend)
    h = embed_tokens(p, cfg, batch, shd)
    b, s, _ = h.shape
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg, pos, shd)
        o = _attend(q, k, v, pos, cfg, backend)
        a = pm.apply_linear(lp["attn"]["wo"],
                            o.transpose(0, 2, 1, 3).reshape(b, s, -1))
        x = x + a
        hn = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_mod.moe_ffn(lp["ffn"], hn, cfg, shd)
        else:
            f = _apply_mlp(lp["ffn"], hn)
        return x + f, (k.astype(cfg.dtype), v.astype(cfg.dtype))

    body = pm.maybe_remat(body, cfg)
    h, (ks, vs) = jax.lax.scan(body, h, p["layers"])
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, -1] @ lm_head_weight(p, cfg).astype(cfg.dtype)
              ).astype(jnp.float32)
    cache = {"k": ks, "v": vs, "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(p, cfg: ModelConfig, cache, tokens, shd: ShardCtx,
                backend: str = "flash", sharded_long: bool = False
                ) -> Tuple[jax.Array, Dict]:
    """One decode step. tokens (B, 1); cache from init_cache/prefill.

    cache["pos"] may be a scalar (uniform decode) or a (B,) vector of
    per-sequence positions (continuous batching: every slot writes and
    masks at its own position)."""
    if cfg.mla is not None:
        return mla_mod.decode_step(p, cfg, cache, tokens, shd, backend,
                                   sharded_long)
    if cfg.embedding_inputs:
        # vlm decode consumes token embeddings directly (text continuation)
        h = tokens.astype(cfg.dtype) if tokens.ndim == 3 else \
            p["embed"]["table"][tokens].astype(cfg.dtype)
    else:
        h = p["embed"]["table"][tokens].astype(cfg.dtype)
    b = h.shape[0]
    qpos = cache["pos"]
    per_slot = qpos.ndim == 1
    s_max = cache["k"].shape[3]
    kpos = jnp.arange(s_max, dtype=jnp.int32)
    # rope positions: (S=1,) uniform or (B, 1, S=1) per-slot broadcast
    rope_pos = (qpos[:, None, None] if per_slot
                else qpos[None]).astype(jnp.int32)
    # attention mask positions: scalar or (B, 1, 1, 1)
    mask_qpos = qpos[:, None, None, None] if per_slot else qpos

    def body(x, xs):
        lp, kc, vc = xs                       # kc/vc (B,Hkv,S,dh)
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg, rope_pos, shd)
        if per_slot:
            bi = jnp.arange(b)
            kc = kc.at[bi, :, qpos].set(k[:, :, 0].astype(kc.dtype))
            vc = vc.at[bi, :, qpos].set(v[:, :, 0].astype(vc.dtype))
        else:
            kc = jax.lax.dynamic_update_slice(
                kc, k.astype(kc.dtype), (0, 0, qpos, 0))
            vc = jax.lax.dynamic_update_slice(
                vc, v.astype(vc.dtype), (0, 0, qpos, 0))
        q1 = q[:, :, 0]                        # (B,Hq,dh)
        if backend == "clusterkv" and cfg.clusterkv.enabled:
            if per_slot:
                # continuous batching: per-call ordering over every slot's
                # cache region (the baseline the plan service amortizes)
                o = attn.clusterkv_percall_decode(q1, kc, vc, kpos, qpos,
                                                  cfg.clusterkv)
            elif sharded_long and shd.mesh is not None:
                o = attn.clusterkv_decode_sharded(
                    q1, kc, vc, kpos, qpos, cfg.clusterkv, shd.mesh)
            else:
                o = attn.clusterkv_decode(q1, kc, vc, kpos, qpos,
                                          cfg.clusterkv)
        else:
            o = attn.decode_attention(q1, kc, vc, kpos, mask_qpos,
                                      window=cfg.swa_window)
        a = pm.apply_linear(lp["attn"]["wo"], o.reshape(b, 1, -1))
        x = x + a
        hn = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_mod.moe_ffn(lp["ffn"], hn, cfg, shd)
        else:
            f = _apply_mlp(lp["ffn"], hn)
        return x + f, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (p["layers"], cache["k"], cache["v"]))
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, 0] @ lm_head_weight(p, cfg).astype(cfg.dtype)
              ).astype(jnp.float32)
    new_cache = {"k": ks, "v": vs, "pos": cache["pos"] + 1}
    return logits, new_cache


def plan_prefill(p, cfg: ModelConfig, batch, perms, shd: ShardCtx
                 ) -> jax.Array:
    """Prefill THROUGH per-layer key plans: ``perms`` (L, B, Hkv, S) are
    the sessions' live cluster orderings, driving the ``plan_batch`` path
    of :func:`~repro.models.attention.clusterkv_attention` — so the first
    generated token already comes from the clusterkv kernel the service
    decodes with. Returns last-position logits only (the service keeps its
    cache plan-ordered; the time-ordered cache of :func:`prefill` never
    exists here)."""
    if cfg.mla is not None or cfg.embedding_inputs:
        raise NotImplementedError(
            "plan prefill serves token decoder-only models")
    h = embed_tokens(p, cfg, batch, shd)
    b, s, _ = h.shape
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(x, xs):
        lp, perm = xs
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg, pos, shd)
        o = attn.clusterkv_attention(q, k, v, pos, pos, cfg.clusterkv,
                                     causal=True, plan_batch=perm)
        a = pm.apply_linear(lp["attn"]["wo"],
                            o.transpose(0, 2, 1, 3).reshape(b, s, -1))
        x = x + a
        hn = pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_mod.moe_ffn(lp["ffn"], hn, cfg, shd)
        else:
            f = _apply_mlp(lp["ffn"], hn)
        return x + f, None

    h, _ = jax.lax.scan(body, h, (p["layers"], perms))
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    return (h[:, -1] @ lm_head_weight(p, cfg).astype(cfg.dtype)
            ).astype(jnp.float32)


def plan_decode_step(p, cfg: ModelConfig, pstate, pend, tokens, slot_pos,
                     shd: ShardCtx) -> Tuple[jax.Array, Dict, jax.Array,
                                             jax.Array]:
    """One decode tick over PLAN-ORDERED caches (the ClusterKV service).

    Instead of the time-ordered cache of :func:`decode_step`, the serving
    state keeps each layer's keys/values in their session plan's cluster
    order plus the bookkeeping the sparse decode needs:

      pstate = {"ks","vs": (L,B,Hkv,S,dh) plan-ordered caches,
                "ps": (L,B,Hkv,S) int32 time position per plan slot
                      (INT32_MAX marks capacity holes),
                "cent": (L,B,Hkv,S/bk,dh) f32 per-tile centroids}
      pend   = {"k","v": (L,B,Hkv,dh) LAST tick's key/value,
                "slot": (L,B,Hkv) int32 plan slot the host-side inserter
                        claimed for it (sentinel S = nothing pending),
                "pos": (B,) int32 its time position}

    The host streams each generated token into the session plans through
    ``api.update_plan``'s insert tier *between* ticks; this step only has
    to land the pending k/v rows at their claimed slots (a scatter),
    refresh the one centroid tile each landing touched, and attend with
    the current token's own k/v carried as an extra column (so
    self-attention never waits on the landing). tokens (B,1);
    slot_pos (B,). Returns (logits, new_pstate, k_new, v_new) where
    k_new/v_new (L,B,Hkv,dh) are THIS tick's rows for the host to claim
    slots for.
    """
    if cfg.mla is not None or cfg.embedding_inputs:
        raise NotImplementedError(
            "plan decode serves token decoder-only models")
    ckv_cfg = cfg.clusterkv
    h = p["embed"]["table"][tokens].astype(cfg.dtype)
    b = h.shape[0]
    hkv = cfg.n_kv_heads
    s_cap = pstate["ks"].shape[3]
    bk = min(ckv_cfg.block_k, s_cap)
    qpos = slot_pos.astype(jnp.int32)
    rope_pos = qpos[:, None, None]
    nl = pstate["ks"].shape[0]
    li = jnp.arange(nl)[:, None, None]
    bi = jnp.arange(b)[None, :, None]
    hi = jnp.arange(hkv)[None, None, :]
    ppos = jnp.broadcast_to(pend["pos"].astype(jnp.int32)[None, :, None],
                            (nl, b, hkv))

    # land last tick's pending token at its claimed plan slot, one fused
    # scatter across all layers BEFORE the layer scan so the big caches
    # never ride through it as stacked outputs; the sentinel slot == S is
    # out of bounds -> dropped (nothing pending)
    pslot = pend["slot"]
    ks = pstate["ks"].at[li, bi, hi, pslot].set(
        pend["k"].astype(pstate["ks"].dtype), mode="drop")
    vs = pstate["vs"].at[li, bi, hi, pslot].set(
        pend["v"].astype(pstate["vs"].dtype), mode="drop")
    ps = pstate["ps"].at[li, bi, hi, pslot].set(ppos, mode="drop")
    # refresh the ONE centroid tile each landing touched (recomputing an
    # untouched tile's mean is a no-op, so the clipped sentinel is safe);
    # gather the tile FIRST, then widen — never astype the whole cache
    tile = jnp.clip(pslot, 0, s_cap - 1) // bk                # (L,B,Hkv)
    seg_idx = tile[..., None] * bk + jnp.arange(bk)           # (L,B,Hkv,bk)
    seg = jnp.take_along_axis(ks, seg_idx[..., None], axis=3)
    cent = pstate["cent"].at[li, bi, hi, tile].set(
        seg.astype(jnp.float32).mean(3))

    # unrolled layer loop: a lax.scan would materialize per-layer slices
    # of the (L,B,Hkv,S,dh) caches as carried/stacked buffers every tick;
    # unrolled, XLA fuses the static layer slice into the tile gathers and
    # the landing scatter can alias the donated cache buffers in place
    nks, nvs = [], []
    for l in range(nl):
        lp = jax.tree_util.tree_map(lambda a: a[l], p["layers"])
        hn = pm.apply_rmsnorm(lp["ln1"], h, cfg.norm_eps)
        q, k, v = _project_qkv(lp["attn"], hn, cfg, rope_pos, shd)
        q1, k1, v1 = q[:, :, 0], k[:, :, 0], v[:, :, 0]
        o = attn.clusterkv_plan_decode(q1, ks[l], vs[l], ps[l], cent[l],
                                       qpos, ckv_cfg, k_self=k1, v_self=v1)
        a = pm.apply_linear(lp["attn"]["wo"], o.reshape(b, 1, -1))
        h = h + a
        hn = pm.apply_rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_mod.moe_ffn(lp["ffn"], hn, cfg, shd)
        else:
            f = _apply_mlp(lp["ffn"], hn)
        h = h + f
        nks.append(k1)
        nvs.append(v1)
    nk, nv = jnp.stack(nks), jnp.stack(nvs)
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, 0] @ lm_head_weight(p, cfg).astype(cfg.dtype)
              ).astype(jnp.float32)
    return logits, {"ks": ks, "vs": vs, "ps": ps, "cent": cent}, nk, nv
