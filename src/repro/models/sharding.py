"""Logical-axis sharding: models declare PartitionSpecs over logical tokens,
the launcher resolves them onto the physical mesh.

Tokens:
  "dp"    batch axis            -> ("pod", "data") on multi-pod, ("data",) else
  "fsdp"  param ZeRO-3 axis     -> "data"
  "tp"    tensor-parallel axis  -> "model"
  "seq"   sequence shards       -> "data" (decode KV) — see launch/mesh.py
Specs on a mesh without the token's axis resolve to replicated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

TOKEN_AXES = ("dp", "fsdp", "tp", "ep", "seq")

# Layouts: how logical tokens map onto the (pod, data, model) mesh.
#   2d       baseline: DP/FSDP over 'data', TP over 'model'
#   dp_all   no tensor parallelism: batch + ZeRO over BOTH axes (small
#            models — kills the per-layer TP all-reduces)
#   serve_tp serving: weights resident TP-only (no per-step ZeRO gathers)
LAYOUTS = {
    "2d": {"dp": ("pod", "data"), "fsdp": ("data",), "tp": "model",
           "ep": "model", "seq": "data"},
    "dp_all": {"dp": ("pod", "data", "model"),
               "fsdp": ("data", "model"), "tp": None, "ep": None,
               "seq": "data"},
    # moe_dp: experts stay resident sharded over 'model' (EP) while
    # everything else is pure DP/ZeRO over both axes — kills the
    # attention-TP all-reduces AND the expert-weight gathers
    "moe_dp": {"dp": ("pod", "data", "model"),
               "fsdp": ("data", "model"), "tp": None, "ep": "model",
               "seq": "data"},
    "serve_tp": {"dp": ("pod", "data"), "fsdp": None, "tp": "model",
                 "ep": "model", "seq": "data"},
}
_current_layout = "2d"


def set_layout(name: str) -> None:
    global _current_layout
    if name not in LAYOUTS:
        raise KeyError(f"unknown layout {name!r}; known: {list(LAYOUTS)}")
    _current_layout = name


def get_layout() -> str:
    return _current_layout


def _resolve_token(token, mesh_axes) -> Any:
    if token is None:
        return None
    if isinstance(token, (tuple, list)):
        out: Tuple[str, ...] = ()
        for t in token:
            r = _resolve_token(t, mesh_axes)
            if r is not None:
                out += r if isinstance(r, tuple) else (r,)
        return out or None
    if token in TOKEN_AXES:
        mapped = LAYOUTS[_current_layout][token]
        if isinstance(mapped, tuple):
            avail = tuple(a for a in mapped if a in mesh_axes)
            return avail or None
        return mapped if mapped in mesh_axes else None
    # already a physical axis name
    return token if token in mesh_axes else None


def tp_axis(mesh: Mesh):
    """Physical tensor-parallel axis under the current layout (or None)."""
    return _resolve_token("tp", mesh.axis_names)


def ep_axis(mesh: Mesh):
    """Physical expert-parallel axis under the current layout (or None)."""
    return _resolve_token("ep", mesh.axis_names)


def resolve_spec(spec: P, mesh: Mesh) -> P:
    return P(*(_resolve_token(t, mesh.axis_names) for t in spec))


def resolve_tree(tree, mesh: Mesh):
    """Map a pytree of logical PartitionSpecs to NamedShardings on mesh."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, resolve_spec(s, mesh)),
        tree, is_leaf=lambda x: isinstance(x, P))


def spec_tree(tree, mesh: Mesh):
    """Same, but keep PartitionSpecs (for in/out_shardings of jit)."""
    return jax.tree.map(
        lambda s: resolve_spec(s, mesh),
        tree, is_leaf=lambda x: isinstance(x, P))


def fit_spec(dims, spec: P, mesh: Mesh) -> P:
    """Make a resolved spec valid for a jit argument of shape ``dims``:
    drop mesh axes from dims they don't divide evenly, and drop duplicate
    axis uses (first dim wins). Intermediates may still be padded via
    with_sharding_constraint; argument shardings must be exact."""
    used: set = set()
    new = []
    for i, entry in enumerate(spec):
        if entry is None or i >= len(dims):
            new.append(None if i >= len(dims) else entry)
            continue
        axes = [a for a in (entry if isinstance(entry, (tuple, list))
                            else (entry,)) if a not in used]
        while axes:
            prod = 1
            for a in axes:
                prod *= mesh.shape[a]
            if dims[i] % prod == 0:
                break
            axes.pop()
        used.update(axes)
        new.append(tuple(axes) if len(axes) > 1 else (axes[0] if axes else None))
    return P(*new)


def shardings_for(shapes_tree, logical_specs_tree, mesh: Mesh):
    """Resolve logical tokens -> NamedShardings fitted to the shapes."""
    specs = jax.tree.map(lambda s: resolve_spec(s, mesh), logical_specs_tree,
                         is_leaf=lambda x: isinstance(x, P))
    return jax.tree.map(
        lambda sh, sp: NamedSharding(mesh, fit_spec(sh.shape, sp, mesh)),
        shapes_tree, specs)


@dataclass(frozen=True)
class ShardCtx:
    """Threaded through model code for activation sharding constraints."""
    mesh: Optional[Mesh] = None

    def cst(self, x: jax.Array, *tokens) -> jax.Array:
        if self.mesh is None or self.mesh.axis_names == ():
            return x
        spec = resolve_spec(P(*tokens), self.mesh)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


NO_SHARD = ShardCtx(None)
