"""Model API: family dispatch + input specs for every (arch x shape) cell.

Every family module exposes:
  init_lm(cfg, key) -> (params, spec_tree)
  loss_fn(params, cfg, batch, shd, backend) -> scalar
  forward(params, cfg, batch, shd, backend) -> (hidden, aux)
  init_cache(cfg, batch, max_seq) / cache_specs(cfg, long_context)
  prefill(params, cfg, batch, shd, backend) -> (cache, logits)
  decode_step(params, cfg, cache, tokens, shd, backend, sharded_long)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, ModelConfig
from repro.models import encdec, hybrid, ssm_lm, transformer
from repro.models import param as pm
from repro.models.sharding import ShardCtx

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": transformer,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "encdec": encdec,
}


def module_for(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init(cfg: ModelConfig, key) -> Tuple[dict, dict]:
    p, s = module_for(cfg).init_lm(cfg, key)
    if cfg.param_dtype != "float32":
        p = pm.cast_tree(p, jnp.dtype(cfg.param_dtype))
    return p, s


def param_specs(cfg: ModelConfig) -> dict:
    """Spec tree without allocating params (init under eval_shape discards
    array work; specs are data-independent)."""
    out = {}

    def capture(key):
        p, s = module_for(cfg).init_lm(cfg, key)
        out["specs"] = s
        return p

    jax.eval_shape(capture, jax.random.PRNGKey(0))
    return out["specs"]


def param_shapes(cfg: ModelConfig) -> dict:
    shapes = jax.eval_shape(lambda k: init(cfg, k)[0], jax.random.PRNGKey(0))
    return shapes


# ---------------------------------------------------------------------------
# input specs per (family, shape)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape_name: str
                ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, P]]:
    """ShapeDtypeStruct stand-ins + logical PartitionSpecs for every model
    input of the given shape cell (weak-type-correct, no allocation)."""
    seq, batch, kind = SHAPES[shape_name]
    d = cfg.d_model
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    S = jax.ShapeDtypeStruct

    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            specs = {"embeddings": S((batch, seq, d), bf16)}
            parts = {"embeddings": P("dp", None, None)}
        elif cfg.family == "encdec":
            specs = {"frames": S((batch, seq, d), bf16),
                     "tokens": S((batch, seq), i32)}
            parts = {"frames": P("dp", None, None), "tokens": P("dp", None)}
        else:
            specs = {"tokens": S((batch, seq), i32)}
            parts = {"tokens": P("dp", None)}
        if kind == "train":
            specs["labels"] = S((batch, seq), i32)
            parts["labels"] = P("dp", None)
        return specs, parts

    # decode: one new token against a seq-long cache
    if cfg.family == "vlm":
        specs = {"tokens": S((batch, 1, d), bf16)}
        parts = {"tokens": P("dp", None, None)}
    else:
        specs = {"tokens": S((batch, 1), i32)}
        parts = {"tokens": P("dp", None)}
    return specs, parts


def cache_seq_axes(cfg: ModelConfig, batch: int = 1, seq: int = 8
                   ) -> Dict[str, int]:
    """Which axis of each cache entry is the sequence axis, read off the
    family's own cache spec: ``init_cache`` is eval-shaped at two lengths
    and the axis that differs per entry is the seq axis. Entries that do
    not scale with seq (scalar ``pos``, ssm/conv states) are absent."""
    mod = module_for(cfg)
    small = jax.eval_shape(lambda: mod.init_cache(cfg, batch, seq))
    large = jax.eval_shape(lambda: mod.init_cache(cfg, batch, 2 * seq))
    axes: Dict[str, int] = {}
    for key, sa in small.items():
        sb = large[key]
        if not hasattr(sa, "shape") or sa.shape == sb.shape:
            continue
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cache entry {key!r} scales with seq on axes {diff}")
        axes[key] = diff[0]
    return axes


def grow_cache(cfg: ModelConfig, cache: Dict[str, Any], new_seq: int,
               axes: Dict[str, int] = None) -> Dict[str, Any]:
    """Zero-pad a (prefilled) cache out to ``new_seq`` along each entry's
    discovered sequence axis. Replaces the ad-hoc ``shape[-2] == prompt_len``
    guessing launchers used to do, which silently skipped any entry whose
    layout didn't match."""
    axes = cache_seq_axes(cfg) if axes is None else axes
    out = dict(cache)
    for key, ax in axes.items():
        x = cache[key]
        if x.shape[ax] >= new_seq:
            continue
        pads = [(0, 0)] * x.ndim
        pads[ax] = (0, new_seq - x.shape[ax])
        out[key] = jnp.pad(x, pads)
    return out


def cache_shapes(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStructs + logical specs of the decode cache for a cell."""
    seq, batch, kind = SHAPES[shape_name]
    assert kind == "decode"
    mod = module_for(cfg)
    shapes = jax.eval_shape(lambda: mod.init_cache(cfg, batch, seq))
    long_ctx = shape_name.startswith("long")
    specs = mod.cache_specs(cfg, long_context=long_ctx)
    return shapes, specs


def make_small_batch(cfg: ModelConfig, key, batch: int = 2, seq: int = 64,
                     kind: str = "train") -> Dict[str, jax.Array]:
    """Concrete small batch for CPU smoke tests."""
    ks = jax.random.split(key, 3)
    out: Dict[str, jax.Array] = {}
    if cfg.family == "vlm":
        out["embeddings"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                              jnp.float32).astype(jnp.bfloat16)
    elif cfg.family == "encdec":
        out["frames"] = jax.random.normal(ks[0], (batch, seq, cfg.d_model),
                                          jnp.float32).astype(jnp.bfloat16)
        out["tokens"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    else:
        out["tokens"] = jax.random.randint(ks[1], (batch, seq), 0, cfg.vocab)
    if kind == "train":
        out["labels"] = jax.random.randint(ks[2], (batch, seq), 0, cfg.vocab)
    return out


def backend_for(cfg: ModelConfig, shape_name: str,
                use_clusterkv: bool = False) -> str:
    """Default attention backend per cell (paper-faithful baselines use
    dense/flash; long_500k uses the arch's sub-quadratic path)."""
    if shape_name.startswith("long"):
        if cfg.long_context == "clusterkv":
            return "clusterkv"
        return "flash"      # swa / ssm are natively sub-quadratic
    if use_clusterkv and cfg.clusterkv.enabled:
        return "clusterkv"
    return "flash"
