"""Whisper-style encoder-decoder. The conv/mel frontend is a STUB: the
encoder consumes precomputed frame embeddings (input_specs provides them).
Positional encoding is RoPE in both stacks (deviation from Whisper's
sinusoidal/learned absolute — noted in DESIGN.md; irrelevant to the
system-level questions studied here)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import param as pm
from repro.models.sharding import ShardCtx
from repro.models.transformer import ce_loss


def _init_attn(key, cfg: ModelConfig, d_kv_src: int = 0):
    d = cfg.d_model
    hq, dh = cfg.n_heads, cfg.head_dim
    dkv = d_kv_src or d
    ks = jax.random.split(key, 4)
    pq, sq = pm.linear(ks[0], d, hq * dh, spec=("fsdp", "tp"))
    pk, sk = pm.linear(ks[1], dkv, hq * dh, spec=("fsdp", "tp"))
    pv, sv = pm.linear(ks[2], dkv, hq * dh, spec=("fsdp", "tp"))
    po, so = pm.linear(ks[3], hq * dh, d, spec=("tp", "fsdp"))
    return ({"wq": pq, "wk": pk, "wv": pv, "wo": po},
            {"wq": sq, "wk": sk, "wv": sv, "wo": so})


def _init_mlp(key, cfg):
    ks = jax.random.split(key, 2)
    p1, s1 = pm.linear(ks[0], cfg.d_model, cfg.d_ff, spec=("fsdp", "tp"))
    p2, s2 = pm.linear(ks[1], cfg.d_ff, cfg.d_model, spec=("tp", "fsdp"))
    return {"w1": p1, "w2": p2}, {"w1": s1, "w2": s2}


def _init_enc_layer(key, cfg):
    ks = jax.random.split(key, 2)
    p, s = {}, {}
    p["ln1"], s["ln1"] = pm.rmsnorm(cfg.d_model)
    p["attn"], s["attn"] = _init_attn(ks[0], cfg)
    p["ln2"], s["ln2"] = pm.rmsnorm(cfg.d_model)
    p["mlp"], s["mlp"] = _init_mlp(ks[1], cfg)
    return p, s


def _init_dec_layer(key, cfg):
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    p["ln1"], s["ln1"] = pm.rmsnorm(cfg.d_model)
    p["self"], s["self"] = _init_attn(ks[0], cfg)
    p["ln_x"], s["ln_x"] = pm.rmsnorm(cfg.d_model)
    p["cross"], s["cross"] = _init_attn(ks[1], cfg)
    p["ln2"], s["ln2"] = pm.rmsnorm(cfg.d_model)
    p["mlp"], s["mlp"] = _init_mlp(ks[2], cfg)
    return p, s


def init_lm(cfg: ModelConfig, key) -> Tuple[dict, dict]:
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["embed"], s["embed"] = pm.embedding(ks[0], cfg.vocab, cfg.d_model)
    p["enc"], s["enc"] = pm.stacked(lambda k: _init_enc_layer(k, cfg),
                                    cfg.n_enc_layers, ks[1])
    p["dec"], s["dec"] = pm.stacked(lambda k: _init_dec_layer(k, cfg),
                                    cfg.n_layers, ks[2])
    p["ln_enc"], s["ln_enc"] = pm.rmsnorm(cfg.d_model)
    p["ln_f"], s["ln_f"] = pm.rmsnorm(cfg.d_model)
    p["head"], s["head"] = pm.linear(ks[3], cfg.d_model, cfg.vocab,
                                     spec=("fsdp", "tp"))
    return p, s


def _mha(lp, xq, xkv, cfg, qpos, kpos, shd, *, causal, backend="flash"):
    b, sq_, d = xq.shape
    skv = xkv.shape[1]
    hq, dh = cfg.n_heads, cfg.head_dim
    q = pm.apply_linear(lp["wq"], xq).reshape(b, sq_, hq, dh).transpose(0, 2, 1, 3)
    k = pm.apply_linear(lp["wk"], xkv).reshape(b, skv, hq, dh).transpose(0, 2, 1, 3)
    v = pm.apply_linear(lp["wv"], xkv).reshape(b, skv, hq, dh).transpose(0, 2, 1, 3)
    q = attn.rope(q, qpos[None, None, :], cfg.rope_theta)
    k = attn.rope(k, kpos[None, None, :], cfg.rope_theta)
    q = shd.cst(q, "dp", "tp", None, None)
    k = shd.cst(k, "dp", "tp", None, None)
    if backend == "dense":
        o = attn.dense_attention(q, k, v, qpos, kpos, causal=causal)
    else:
        o = attn.flash_attention(q, k, v, qpos, kpos, causal=causal)
    return pm.apply_linear(lp["wo"], o.transpose(0, 2, 1, 3).reshape(b, sq_, -1))


def _mlp_apply(lp, x):
    return pm.apply_linear(lp["w2"], jax.nn.gelu(pm.apply_linear(lp["w1"], x)))


def encode(p, cfg: ModelConfig, frames, shd: ShardCtx,
           backend: str = "flash") -> jax.Array:
    h = shd.cst(frames.astype(cfg.dtype), "dp", None, None)
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        x = x + _mha(lp["attn"], pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps),
                     pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
                     pos, pos, shd, causal=False, backend=backend)
        x = x + _mlp_apply(lp["mlp"], pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, None

    body = pm.maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, h, p["enc"])
    return pm.apply_rmsnorm(p["ln_enc"], h, cfg.norm_eps)


def forward(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    enc_out = encode(p, cfg, batch["frames"], shd, backend)
    h = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    h = shd.cst(h, "dp", None, None)
    s = h.shape[1]
    pos = jnp.arange(s, dtype=jnp.int32)
    epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(x, lp):
        x = x + _mha(lp["self"], pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps),
                     pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps), cfg,
                     pos, pos, shd, causal=True, backend=backend)
        x = x + _mha(lp["cross"], pm.apply_rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                     enc_out, cfg, pos, epos, shd, causal=False,
                     backend=backend)
        x = x + _mlp_apply(lp["mlp"], pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, None

    body = pm.maybe_remat(body, cfg)
    h, _ = jax.lax.scan(body, h, p["dec"])
    return pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps), jnp.zeros((), jnp.float32)


def loss_fn(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash") -> jax.Array:
    h, _ = forward(p, cfg, batch, shd, backend)
    return ce_loss(h, p["head"]["w"].astype(cfg.dtype), batch["labels"],
                   cfg.loss_chunk)


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int,
               dtype=None) -> Dict[str, Any]:
    dtype = dtype or jnp.dtype(cfg.dtype)
    l, hq, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
    return {
        "k": jnp.zeros((l, batch_size, hq, max_seq, dh), dtype),
        "v": jnp.zeros((l, batch_size, hq, max_seq, dh), dtype),
        "xk": jnp.zeros((l, batch_size, hq, max_seq, dh), dtype),
        "xv": jnp.zeros((l, batch_size, hq, max_seq, dh), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def cache_specs(cfg: ModelConfig, long_context: bool = False):
    kv = P(None, "dp", "tp", None, None)
    return {"k": kv, "v": kv, "xk": kv, "xv": kv, "pos": P()}


def prefill(p, cfg: ModelConfig, batch, shd: ShardCtx,
            backend: str = "flash"):
    """Encoder pass + decoder prompt pass; caches self-KV and cross-KV."""
    enc_out = encode(p, cfg, batch["frames"], shd, backend)
    h = p["embed"]["table"][batch["tokens"]].astype(cfg.dtype)
    b, s, _ = h.shape
    hq, dh = cfg.n_heads, cfg.head_dim
    pos = jnp.arange(s, dtype=jnp.int32)
    epos = jnp.arange(enc_out.shape[1], dtype=jnp.int32)

    def body(x, lp):
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        k = pm.apply_linear(lp["self"]["wk"], hn).reshape(b, s, hq, dh)\
            .transpose(0, 2, 1, 3)
        k = attn.rope(k, pos[None, None, :], cfg.rope_theta)
        v = pm.apply_linear(lp["self"]["wv"], hn).reshape(b, s, hq, dh)\
            .transpose(0, 2, 1, 3)
        xk = pm.apply_linear(lp["cross"]["wk"], enc_out)\
            .reshape(b, -1, hq, dh).transpose(0, 2, 1, 3)
        xk = attn.rope(xk, epos[None, None, :], cfg.rope_theta)
        xv = pm.apply_linear(lp["cross"]["wv"], enc_out)\
            .reshape(b, -1, hq, dh).transpose(0, 2, 1, 3)
        x = x + _mha(lp["self"], hn, hn, cfg, pos, pos, shd, causal=True,
                     backend=backend)
        x = x + _mha(lp["cross"], pm.apply_rmsnorm(lp["ln_x"], x, cfg.norm_eps),
                     enc_out, cfg, pos, epos, shd, causal=False,
                     backend=backend)
        x = x + _mlp_apply(lp["mlp"], pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, (k.astype(cfg.dtype), v.astype(cfg.dtype),
                   xk.astype(cfg.dtype), xv.astype(cfg.dtype))

    body = pm.maybe_remat(body, cfg)
    h, (ks, vs, xks, xvs) = jax.lax.scan(body, h, p["dec"])
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, -1] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs,
             "pos": jnp.asarray(s, jnp.int32)}
    return cache, logits


def decode_step(p, cfg: ModelConfig, cache, tokens, shd: ShardCtx,
                backend: str = "flash", sharded_long: bool = False):
    h = p["embed"]["table"][tokens].astype(cfg.dtype)
    b = h.shape[0]
    hq, dh = cfg.n_heads, cfg.head_dim
    qpos = cache["pos"]
    s_max = cache["k"].shape[3]
    kpos = jnp.arange(s_max, dtype=jnp.int32)

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        hn = pm.apply_rmsnorm(lp["ln1"], x, cfg.norm_eps)
        q = pm.apply_linear(lp["self"]["wq"], hn).reshape(b, 1, hq, dh)\
            .transpose(0, 2, 1, 3)
        k1 = pm.apply_linear(lp["self"]["wk"], hn).reshape(b, 1, hq, dh)\
            .transpose(0, 2, 1, 3)
        v1 = pm.apply_linear(lp["self"]["wv"], hn).reshape(b, 1, hq, dh)\
            .transpose(0, 2, 1, 3)
        q = attn.rope(q, qpos[None, None, None].astype(jnp.int32), cfg.rope_theta)
        k1 = attn.rope(k1, qpos[None, None, None].astype(jnp.int32), cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k1.astype(kc.dtype), (0, 0, qpos, 0))
        vc = jax.lax.dynamic_update_slice(vc, v1.astype(vc.dtype), (0, 0, qpos, 0))
        o = attn.decode_attention(q[:, :, 0], kc, vc, kpos, qpos)
        x = x + pm.apply_linear(lp["self"]["wo"], o.reshape(b, 1, -1))
        # cross attention over cached encoder KV
        hn = pm.apply_rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        qx = pm.apply_linear(lp["cross"]["wq"], hn).reshape(b, 1, hq, dh)\
            .transpose(0, 2, 1, 3)
        qx = attn.rope(qx, qpos[None, None, None].astype(jnp.int32), cfg.rope_theta)
        ox = attn.decode_attention(qx[:, :, 0], xk, xv,
                                   jnp.arange(xk.shape[2], dtype=jnp.int32),
                                   jnp.iinfo(jnp.int32).max - 1)
        x = x + pm.apply_linear(lp["cross"]["wo"], ox.reshape(b, 1, -1))
        x = x + _mlp_apply(lp["mlp"], pm.apply_rmsnorm(lp["ln2"], x, cfg.norm_eps))
        return x, (kc, vc)

    h, (ks, vs) = jax.lax.scan(body, h, (p["dec"], cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
    h = pm.apply_rmsnorm(p["ln_f"], h, cfg.norm_eps)
    logits = (h[:, 0] @ p["head"]["w"].astype(cfg.dtype)).astype(jnp.float32)
    cache = dict(cache, k=ks, v=vs, pos=cache["pos"] + 1)
    return logits, cache
