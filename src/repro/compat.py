"""Version-tolerant wrappers over JAX APIs that moved between releases.

``shard_map`` has lived in three places/shapes:

  - ``jax.experimental.shard_map.shard_map`` with ``check_rep=``  (<= 0.4.x)
  - ``jax.shard_map`` with ``check_rep=``                         (~0.5.x)
  - ``jax.shard_map`` with ``check_vma=``                         (>= 0.6.x)

All repro call sites import ``shard_map`` from here and pass ``check_vma=``;
the wrapper renames the kwarg to whatever the installed JAX expects.
"""
from __future__ import annotations

import inspect

try:  # newer JAX exports it at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - depends on installed JAX
    from jax.experimental.shard_map import shard_map as _shard_map

_PARAMS = frozenset(inspect.signature(_shard_map).parameters)


def shard_map(f, **kwargs):
    """``jax.shard_map`` with the replication-check kwarg normalized."""
    if "check_vma" in kwargs and "check_vma" not in _PARAMS:
        check = kwargs.pop("check_vma")
        if "check_rep" in _PARAMS:
            kwargs["check_rep"] = check
    return _shard_map(f, **kwargs)


try:
    from jax.interpreters.batching import BatchTracer as _BatchTracer
except ImportError:  # pragma: no cover - depends on installed JAX
    _BatchTracer = None


def is_batch_tracer(x) -> bool:
    """True when ``x`` is a ``jax.vmap`` batching tracer.

    Used by the plan API to turn the opaque shape/hash errors a vmapped
    ``InteractionPlan`` produces into a descriptive ``TypeError`` pointing
    at ``PlanBatch``. The tracer class has lived in
    ``jax.interpreters.batching`` for every supported release, but it is
    internal — the import is fenced (at module load, off the hot path) so
    an upstream move degrades to "no early detection", not ImportError.
    """
    return _BatchTracer is not None and isinstance(x, _BatchTracer)


def axis_size(axis_name):
    """``jax.lax.axis_size`` (newer JAX) with the classic constant-folding
    ``psum(1, axis)`` fallback (static under shard_map/pmap on 0.4.x)."""
    import jax

    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return jax.lax.psum(1, axis_name)


def abstract_mesh(axis_sizes, axis_names):
    """``jax.sharding.AbstractMesh`` across ctor-signature changes.

    Newer JAX takes ``(axis_sizes, axis_names)``; 0.4.x takes a single
    tuple of ``(name, size)`` pairs.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
