"""Deterministic synthetic data pipelines.

Two producers:
  token_batches     — LM token streams (deterministic per (seed, step), so a
                      restarted/elastic job regenerates exactly the batches
                      it needs by step index: skip-ahead = free)
  feature_mixture   — high-dimensional Gaussian-mixture feature sets standing
                      in for SIFT (128-d) / GIST (960-d) in the paper's
                      experiments (datasets are not available offline;
                      DESIGN.md §4 records the substitution)

Batches are produced host-side in numpy and device_put with the batch
sharding; a one-deep prefetch thread overlaps generation with compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


def token_batch(cfg: ModelConfig, step: int, batch: int, seq: int,
                seed: int = 0) -> Dict[str, np.ndarray]:
    """Deterministic batch for a given step (Zipf-ish token marginals)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    # Zipfian-ish marginal over the vocab, like natural text
    u = rng.random((batch, seq + 1))
    toks = np.minimum((cfg.vocab * u ** 3).astype(np.int64),
                      cfg.vocab - 1).astype(np.int32)
    out: Dict[str, np.ndarray] = {}
    if cfg.family == "vlm":
        rngf = np.random.default_rng(np.random.SeedSequence([seed, step, 1]))
        out["embeddings"] = rngf.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
        out["labels"] = toks[:, 1:]
    elif cfg.family == "encdec":
        rngf = np.random.default_rng(np.random.SeedSequence([seed, step, 1]))
        out["frames"] = rngf.standard_normal(
            (batch, seq, cfg.d_model)).astype(np.float32)
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    else:
        out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
    return out


def token_batches(cfg: ModelConfig, batch: int, seq: int, seed: int = 0,
                  start_step: int = 0, shardings=None, prefetch: int = 1
                  ) -> Iterator[Dict[str, jax.Array]]:
    """Infinite iterator of device batches with background prefetch."""
    q: queue.Queue = queue.Queue(maxsize=max(prefetch, 1))
    stop = threading.Event()

    def put(step):
        b = token_batch(cfg, step, batch, seq, seed)
        if shardings is not None:
            b = {k: jax.device_put(v, shardings[k] if isinstance(shardings, dict)
                                   else shardings) for k, v in b.items()}
        else:
            b = {k: jnp.asarray(v) for k, v in b.items()}
        return b

    def worker():
        step = start_step
        while not stop.is_set():
            try:
                q.put(put(step), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    try:
        while True:
            yield q.get()
    finally:
        stop.set()


def feature_mixture(n: int, d: int, n_clusters: int = 32, seed: int = 0,
                    spread: float = 0.15) -> np.ndarray:
    """Gaussian-mixture features standing in for SIFT/GIST: cluster centers
    on a low-dimensional manifold embedded in R^d (matching the intrinsic-
    dimension structure the paper's method exploits)."""
    rng = np.random.default_rng(seed)
    # centers live near a random 8-dim subspace, like real descriptors
    basis = rng.standard_normal((8, d)) / np.sqrt(8)
    centers = rng.standard_normal((n_clusters, 8)) @ basis * 3.0
    sizes = rng.multinomial(n, np.ones(n_clusters) / n_clusters)
    parts = []
    for c, m in zip(centers, sizes):
        parts.append(c + spread * rng.standard_normal((m, d)))
    x = np.concatenate(parts).astype(np.float32)
    return x[rng.permutation(n)]


def sift_like(n: int = 16384, seed: int = 0) -> np.ndarray:
    """128-d stand-in for the SIFT descriptors of paper §4.2."""
    return feature_mixture(n, 128, n_clusters=64, seed=seed)


def gist_like(n: int = 16384, seed: int = 0) -> np.ndarray:
    """960-d stand-in for the GIST descriptors of paper §4.2."""
    return feature_mixture(n, 960, n_clusters=48, seed=seed)
