"""Matrix-free iterative solvers on the plan operator.

The plan substrate (build -> order -> ELL-BSR -> batched/sharded matvec)
is this subsystem's ONLY access to the interaction matrix: CG, Lanczos,
kernel ridge regression, and spectral embedding all consume
``InteractionPlan`` / ``PlanBatch`` / ``ShardedPlan`` through their
matvecs. See ``docs/solvers.md``.

  cg        batched preconditioned conjugate gradient (telemetry, early
            exit, one ``lax.while_loop`` for every lane)
  precond   preconditioner factories from the plan's own BSR diagonal
            (block-Jacobi via batched Cholesky; registry-resolved)
  krr       generic ``solve`` dispatch + kernel ridge regression
  lanczos   tridiagonalization with full reorthogonalization
  spectral  KDE similarity graph + normalized-Laplacian embedding

``krr``/``spectral`` import ``repro.api`` and load lazily here so that
``repro.core.registry``'s preconditioner provider import (which pulls
this package in) never recurses into a partially-initialized ``api``.
"""
from __future__ import annotations

from repro.solvers.cg import CGResult, cg
from repro.solvers.lanczos import LanczosResult, lanczos, lanczos_eigsh
from repro.solvers.precond import (block_jacobi, diag_tiles, diag_vector,
                                   identity, jacobi)

__all__ = [
    "CGResult", "cg",
    "LanczosResult", "lanczos", "lanczos_eigsh",
    "block_jacobi", "diag_tiles", "diag_vector", "identity", "jacobi",
    "KRRModel", "solve", "krr_fit", "krr_fit_batch",
    "RBFValues", "similarity_plan", "redress_rbf", "normalized_operator",
    "spectral_embedding",
]

_LAZY = {
    "KRRModel": "repro.solvers.krr",
    "solve": "repro.solvers.krr",
    "krr_fit": "repro.solvers.krr",
    "krr_fit_batch": "repro.solvers.krr",
    "RBFValues": "repro.solvers.spectral",
    "similarity_plan": "repro.solvers.spectral",
    "redress_rbf": "repro.solvers.spectral",
    "normalized_operator": "repro.solvers.spectral",
    "spectral_embedding": "repro.solvers.spectral",
}


def __getattr__(name: str):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(mod), name)
