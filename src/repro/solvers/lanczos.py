"""Lanczos tridiagonalization with full reorthogonalization.

Turns ``m`` matvecs of a symmetric operator into an ``m x m`` tridiagonal
whose eigenpairs (Ritz pairs) approximate the operator's extremal
spectrum — the classic matrix-free eigensolver, and the whole reason the
plan operator can power spectral embedding without ever materializing
the similarity matrix.

In float32 the three-term recurrence loses orthogonality within a
handful of iterations, so every new Krylov vector is *fully*
reorthogonalized against the fixed-size basis buffer (one masked
matmul per iteration — O(m n) work, trivial next to the matvec) and the
projection is applied twice ("twice is enough", Parlett): Ritz vectors
stay orthonormal to ~1e-6 even at m approaching n.

Everything traces: ``lanczos``/``lanczos_eigsh`` run under ``jit`` with
``m``/``k`` static (``lax.fori_loop`` over the iteration, dense ``eigh``
on the small tridiagonal only).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

__all__ = ["LanczosResult", "lanczos", "lanczos_eigsh"]


class LanczosResult(NamedTuple):
    """``alpha`` (m,) diagonal, ``beta`` (m-1,) off-diagonal of the
    tridiagonal ``T``; ``V`` (m, n) the orthonormal Krylov basis rows
    (``V A V^T ~= T``); ``beta_last`` the final residual coupling (a
    posteriori error gauge: ~0 means the Krylov space is invariant)."""
    alpha: jax.Array
    beta: jax.Array
    V: jax.Array
    beta_last: jax.Array


def lanczos(A: Callable, v0: jax.Array, m: int) -> LanczosResult:
    """Run ``m`` Lanczos iterations of symmetric ``A`` from start vector
    ``v0`` (n,). Happy breakdown (an exactly invariant subspace) is
    handled by continuing with a zero vector — the trailing ``beta``
    entries are 0 and the tridiagonal stays block-diagonal, so ``eigh``
    downstream is unaffected."""
    if m < 1:
        raise ValueError(f"lanczos needs m >= 1, got {m}")
    v0 = jnp.asarray(v0)
    n = v0.shape[0]
    nrm = jnp.linalg.norm(v0)
    v = v0 / jnp.where(nrm == 0, 1.0, nrm)

    V = jnp.zeros((m + 1, n), v0.dtype).at[0].set(v)
    alpha = jnp.zeros(m, v0.dtype)
    beta = jnp.zeros(m, v0.dtype)       # beta[j] couples v_j -> v_{j+1}

    def body(j, carry):
        V, alpha, beta = carry
        vj = V[j]
        w = A(vj)
        a = jnp.vdot(vj, w)
        alpha = alpha.at[j].set(a)
        # full reorthogonalization against the basis built so far (rows
        # > j are zero, so the masked matmul projects exactly onto
        # span{v_0..v_j}); applied twice for float32 robustness
        for _ in range(2):
            w = w - V.T @ (V @ w)
        b = jnp.linalg.norm(w)
        beta = beta.at[j].set(b)
        v_next = w / jnp.where(b == 0, 1.0, b)
        V = V.at[j + 1].set(jnp.where(b == 0, jnp.zeros_like(v_next),
                                      v_next))
        return V, alpha, beta

    V, alpha, beta = jax.lax.fori_loop(0, m, body, (V, alpha, beta))
    return LanczosResult(alpha=alpha, beta=beta[:m - 1], V=V[:m],
                         beta_last=beta[m - 1])


def lanczos_eigsh(A: Callable, n: int, k: int, *, m: int = 0,
                  seed: int = 0,
                  v0: jax.Array = None,
                  largest: bool = True) -> Tuple[jax.Array, jax.Array]:
    """Top (or bottom) ``k`` Ritz pairs of symmetric ``A`` of size ``n``.

    Runs :func:`lanczos` for ``m`` iterations (default
    ``min(n, max(2k + 8, 32))``), diagonalizes the small tridiagonal with
    dense ``eigh``, and lifts the eigenvectors back through the Krylov
    basis. Returns ``(w, U)`` with ``w`` (k,) eigenvalues sorted
    descending (``largest``) or ascending and ``U`` (n, k) the matching
    Ritz vectors (unit-norm, orthonormal to reorthogonalization
    accuracy).
    """
    if not m:
        m = min(n, max(2 * k + 8, 32))
    if k > m:
        raise ValueError(f"k={k} Ritz pairs need m >= k iterations, "
                         f"got m={m}")
    if v0 is None:
        v0 = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)
    res = lanczos(A, v0, m)
    T = (jnp.diag(res.alpha)
         + jnp.diag(res.beta, 1) + jnp.diag(res.beta, -1))
    w, s = jnp.linalg.eigh(T)            # ascending
    if largest:
        w, s = w[::-1], s[:, ::-1]
    U = res.V.T @ s[:, :k]               # lift Ritz vectors to R^n
    return w[:k], U
