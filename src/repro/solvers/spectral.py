"""Spectral embedding over a KDE-weighted similarity graph on the plan.

The plan's kNN pattern *is* a similarity graph waiting for weights: dress
the edges with a Gaussian KDE kernel ``w_ij = exp(-d_ij^2 / (2 h^2))``,
degree-normalize, and the top eigenvectors of

    N = D^{-1/2} W D^{-1/2}

are the classic normalized-Laplacian spectral embedding (``L_sym = I - N``
— top of ``N`` == bottom of ``L_sym``). Nothing is ever densified: ``W``
lives in the plan's ELL-BSR, ``D`` is one matvec of ones, and
``repro.solvers.lanczos`` extracts the Ritz pairs from matvecs alone.

Two entry shapes:

  * :func:`similarity_plan` builds the dressed plan from raw points
    (``symmetrize=True`` — CG/Lanczos need the symmetric pattern; the
    bandwidth defaults to the median kNN distance, the usual
    self-tuning heuristic, pinned on the kernel so streaming refresh
    re-dresses patched rows consistently);
  * :func:`redress_rbf` re-dresses an EXISTING plan's pattern through
    ``api.edge_values`` — binary kNN plans from earlier stages become
    KDE similarity graphs without rebuilding ordering or storage.

Streamed plans work mid-lifecycle: dead slots have zero similarity
rows/columns, their degree is clamped, and the scaling zeroes them out of
the operator — they sit in the kernel's nullspace, invisible to the top
of the spectrum.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.solvers.lanczos import lanczos_eigsh

__all__ = ["RBFValues", "similarity_plan", "redress_rbf",
           "normalized_operator", "spectral_embedding"]


class RBFValues:
    """Gaussian edge kernel ``exp(-d2 / (2 h^2))`` in the plan's values
    protocol ``f(rows, cols, d2) -> vals``.

    ``bandwidth=None`` self-tunes: the first batch of edges pins ``h`` to
    the median kNN distance (so later re-dressings — streaming refresh
    patches, out-of-sample cross kernels — reuse the SAME bandwidth and
    stay consistent with the stored weights)."""

    def __init__(self, bandwidth: Optional[float] = None):
        self.bandwidth = None if bandwidth is None else float(bandwidth)

    def __call__(self, rows, cols, d2):
        d2 = np.asarray(d2, np.float32)
        if self.bandwidth is None:
            med = float(np.median(d2[d2 > 0])) if (d2 > 0).any() else 1.0
            self.bandwidth = float(np.sqrt(med))
        h2 = max(self.bandwidth * self.bandwidth, 1e-12)
        return np.exp(-d2 / (2.0 * h2)).astype(np.float32)


def similarity_plan(x, *, k: int = 16,
                    bandwidth: Optional[float] = None,
                    **build_kwargs) -> "api.InteractionPlan":
    """Build a KDE similarity plan over points ``x`` (n, D): symmetrized
    kNN pattern, RBF-dressed edges. Extra kwargs flow to
    :func:`repro.api.build_plan` (``bs``, ``ordering``, ``capacity``...)."""
    build_kwargs.setdefault("symmetrize", True)
    if not build_kwargs["symmetrize"]:
        raise ValueError("spectral embedding needs a symmetric similarity "
                         "pattern; symmetrize=False breaks it")
    return api.build_plan(x, k=k, values=RBFValues(bandwidth),
                          **build_kwargs)


def redress_rbf(plan: "api.InteractionPlan",
                bandwidth: Optional[float] = None) -> "api.InteractionPlan":
    """Re-dress an existing plan's pattern with the RBF kernel.

    Keeps ordering, storage shapes, and compile caches (``with_values``);
    only the edge weights change, computed through ``api.edge_values`` so
    the dressing goes through the same seam streaming refresh uses. The
    plan must carry coordinates (``host.x``)."""
    host = plan.host
    if host.x is None:
        raise ValueError("plan carries no coordinates (built from_coo "
                         "without x); cannot compute edge distances")
    r2, c2, _ = plan.coo                       # cluster index space
    x_cl = np.asarray(host.x, np.float32)[host.pi]
    diff = x_cl[r2] - x_cl[c2]
    d2 = np.einsum("ij,ij->i", diff, diff)
    fn = RBFValues(bandwidth)
    dressed = dataclasses.replace(host, values_mode="fn", values_fn=fn)
    vals = api.edge_values(dressed, r2, c2, d2)
    out = plan.with_values(vals)
    out.host.values_mode = "fn"                # refresh re-dresses via fn
    out.host.values_fn = fn
    return out


def normalized_operator(plan: "api.InteractionPlan",
                        backend: Optional[str] = None,
                        eps: float = 1e-12):
    """The degree-normalized similarity ``N = D^{-1/2} W' D^{-1/2}`` as a
    matvec over CLUSTER-ordered vectors. Returns ``(N, deg)`` with ``deg``
    the cluster-order degree vector (one matvec of ones; zero-degree —
    dead or isolated — slots are scaled out of the operator)."""
    from repro.solvers.krr import _plan_backend

    plan._require_bsr()
    name = _plan_backend(plan, None, backend)
    deg = plan.apply(jnp.ones(plan.n, jnp.float32), backend=name)
    s = jnp.where(deg > eps, 1.0 / jnp.sqrt(jnp.maximum(deg, eps)), 0.0)

    def N(v: jax.Array) -> jax.Array:
        return s * plan.apply(s * v, backend=name)

    return N, deg


def spectral_embedding(x=None, *, plan: "api.InteractionPlan" = None,
                       n_components: int = 2, k: int = 16,
                       bandwidth: Optional[float] = None,
                       m: int = 0, seed: int = 0,
                       backend: Optional[str] = None,
                       drop_first: bool = True,
                       **build_kwargs) -> Tuple[jax.Array, jax.Array]:
    """Spectral embedding of points (or of an existing plan's graph).

    Pass raw points ``x`` (n, D) — a KDE :func:`similarity_plan` is
    built — or ``plan=`` an already-built symmetric plan, which is
    re-dressed with the RBF kernel through :func:`redress_rbf` (pass
    ``bandwidth=0`` to keep the plan's existing weights). Lanczos
    extracts the top ``n_components (+1)`` Ritz pairs of ``N``;
    ``drop_first`` discards the trivial top eigenvector (``D^{1/2} 1``,
    eigenvalue ~1 on a connected graph).

    Returns ``(w, Y)``: eigenvalues ``(n_components,)`` descending and
    the embedding ``Y`` ``(capacity, n_components)`` in ORIGINAL index
    order (dead slots read ~0).
    """
    if (x is None) == (plan is None):
        raise ValueError("pass exactly one of x= (points) or plan=")
    if plan is None:
        plan = similarity_plan(x, k=k, bandwidth=bandwidth, **build_kwargs)
    elif bandwidth != 0:
        plan = redress_rbf(plan, bandwidth)
    N, _deg = normalized_operator(plan, backend=backend)
    k_ritz = n_components + (1 if drop_first else 0)
    w, U = lanczos_eigsh(N, plan.n, k_ritz, m=m, seed=seed, largest=True)
    if drop_first:
        w, U = w[1:], U[:, 1:]
    return w, plan.unpermute(U)
