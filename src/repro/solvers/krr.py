"""Kernel ridge regression (and generic solves) on the plan operator.

Rebrova et al. (1803.10274) drive CG for kernel ridge regression through
a hierarchical kernel format; here the format is the plan's ELL-BSR and
the solver never sees anything but matvecs. The regression system

    (K + lam*I) alpha = y,     K = W + self_weight*I

is solved matrix-free: ``W`` is the plan's dressed near-neighbor pattern
(the kNN pattern excludes self-edges, so the kernel's diagonal rides as
an explicit ``self_weight``) and the whole diagonal ``shift =
self_weight + lam`` is folded into the operator — one fused
``A(v) = plan_apply(v) + shift*v`` per CG iteration, no second kernel.

One compiled solver per spec
----------------------------

``solve`` dispatches on the operator kind:

  InteractionPlan  one jitted kernel per (spec, backend, precond,
                   maxiter, rhs shape): permutation, preconditioner
                   factorization, and the whole CG ``while_loop`` trace
                   into a single XLA computation.
  PlanBatch        the same kernel shape over stacked ``PlanData`` —
                   B member systems solved in lockstep by ONE compiled
                   trace per spec (the batched SpMV kernels do the B-way
                   matvec, the batched Cholesky preconditions every
                   lane), however many members ride the batch.
  ShardedPlan      eager CG over the halo-exchange matvec: each
                   iteration dispatches the compiled shard_map, and the
                   CG dot products reduce over the device axis (psum
                   under the hood — the arrays are mesh-sharded).

Backends resolve through the plan's own autotune; host-bound paths
(``csr`` reads host COO, ``dist`` issues collectives) cannot live inside
the solver jit and fall back to ``bsr``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import knn
from repro.core.registry import get_backend, get_preconditioner
from repro.solvers.cg import CGResult, cg

__all__ = ["KRRModel", "solve", "krr_fit", "krr_fit_batch"]

# backends whose compute is pure device arrays and can be traced into the
# solver kernel (csr reads host COO, dist runs collectives)
_JIT_SAFE = ("bsr", "bsr_ml", "pallas")


def _lane_shift(shift, ndim: int):
    """Broadcast a scalar or per-lane ``(B,)`` shift against the operand
    layout (lanes lead, the n/rhs axes trail)."""
    s = jnp.asarray(shift)
    return s.reshape(s.shape + (1,) * (ndim - s.ndim))


def _solver_knobs(config, backend, precond, tol, maxiter):
    """Per-call overrides fall back to the plan's configured solver
    knobs (validated at PlanConfig construction)."""
    return (backend,
            precond if precond is not None else config.precond,
            float(tol) if tol is not None else config.cg_tol,
            int(maxiter) if maxiter is not None else config.cg_maxiter)


@functools.partial(jax.jit,
                   static_argnames=("spec", "backend", "precond", "maxiter"))
def _solve_single_kernel(spec, data, b, shift, tol, backend: str,
                         precond: str, maxiter: int) -> CGResult:
    """One plan, one compiled solve: permute -> precondition -> CG ->
    unpermute, all inside a single jit."""
    axis = -1 if b.ndim == 1 else -2
    b_cl = jnp.take(b, data.pi, axis=0)
    M = get_preconditioner(precond)(spec, data, shift)
    fn = get_backend(backend)
    view = api.InteractionPlan.from_spec_data(spec, data)
    sh = _lane_shift(shift, b.ndim)

    def A(v):
        return fn(view, v) + sh * v

    res = cg(A, b_cl, M=lambda r: M(r, axis=axis), tol=tol,
             maxiter=maxiter, axis=axis)
    return dataclasses.replace(res, x=jnp.take(res.x, data.inv, axis=0))


@functools.partial(jax.jit,
                   static_argnames=("spec", "backend", "precond", "maxiter"))
def _solve_batch_kernel(spec, data, b, shift, tol, backend: str,
                        precond: str, maxiter: int) -> CGResult:
    """Whole-batch solve under ONE jit: stacked permutations, batched
    preconditioner factorization, lockstep CG on the batched SpMV."""
    axis = -1 if b.ndim == 2 else -2
    b_cl = api._batch_take(b, data.pi)
    M = get_preconditioner(precond)(spec, data, shift)
    sh = _lane_shift(shift, b.ndim)

    def A(v):
        return api._batch_apply_kernel(spec, data, v, backend,
                                       "apply") + sh * v

    res = cg(A, b_cl, M=lambda r: M(r, axis=axis), tol=tol,
             maxiter=maxiter, axis=axis)
    return dataclasses.replace(res, x=api._batch_take(res.x, data.inv))


def _plan_backend(plan: "api.InteractionPlan", b, backend) -> str:
    name = plan.resolve_backend(backend, x=None)
    return name if name in _JIT_SAFE else "bsr"


def solve(operator, b, *, shift: float = 0.0,
          backend: Optional[str] = None,
          precond: Optional[str] = None,
          tol: Optional[float] = None,
          maxiter: Optional[int] = None) -> CGResult:
    """Solve ``(A + shift*I) x = b`` on a plan-shaped operator.

    ``operator`` is an :class:`~repro.api.InteractionPlan`,
    :class:`~repro.api.PlanBatch`, or
    :class:`~repro.core.shardplan.ShardedPlan`; ``b`` is in ORIGINAL
    index order — ``(capacity,)`` / ``(capacity, t)`` for single and
    sharded plans, ``(B, capacity)`` / ``(B, capacity, t)`` for a batch
    (zero-pad dead/hole slots; their solutions come back ``b/shift``,
    i.e. zero). The stored pattern must be symmetric
    (``symmetrize=True`` or symmetric values) — CG assumes it.
    Solver knobs default to the plan's config (``cg_tol``,
    ``cg_maxiter``, ``precond``); returns a :class:`CGResult` with
    telemetry (see ``docs/solvers.md``).
    """
    if isinstance(operator, api.PlanBatch):
        batch = operator
        b = jnp.asarray(b)
        if b.ndim not in (2, 3) or b.shape[0] != batch.batch \
                or b.shape[1] != batch.capacity:
            raise ValueError(
                f"batched right-hand side must be (B={batch.batch}, "
                f"capacity={batch.capacity}[, t]); got {b.shape}")
        name = batch.resolve_backend(backend, x=b)
        _, prec, tol, maxiter = _solver_knobs(batch.spec.config, name,
                                              precond, tol, maxiter)
        return _solve_batch_kernel(batch.spec, batch.data, b,
                                   jnp.asarray(shift, jnp.float32),
                                   jnp.float32(tol), name, prec, maxiter)
    if isinstance(operator, api.ShardedPlan):
        return _solve_sharded(operator, b, shift=shift, precond=precond,
                              tol=tol, maxiter=maxiter)
    plan = operator
    plan._require_bsr()
    b = jnp.asarray(b)
    if b.shape[0] != plan.n:
        raise ValueError(f"right-hand side has {b.shape[0]} rows, plan "
                         f"capacity is {plan.n}")
    name = _plan_backend(plan, b, backend)
    _, prec, tol, maxiter = _solver_knobs(plan.config, name, precond, tol,
                                          maxiter)
    return _solve_single_kernel(plan.spec, plan.data, b,
                                jnp.asarray(shift, jnp.float32),
                                jnp.float32(tol), name, prec, maxiter)


def _solve_sharded(sp, b, *, shift=0.0, precond=None, tol=None,
                   maxiter=None) -> CGResult:
    """CG over the halo-exchange matvec (1-D charges only — the sharded
    apply's contract). The preconditioner factors from the *unsharded*
    tiles the wrapped plan still owns and applies in cluster order."""
    plan = sp.plan
    b = jnp.asarray(b)
    if b.ndim != 1:
        raise ValueError("sharded solves take 1-D right-hand sides "
                         f"(the sharded matvec contract); got {b.shape}")
    _, prec, tol, maxiter = _solver_knobs(plan.config, None, precond, tol,
                                          maxiter)
    M_cl = get_preconditioner(prec)(plan.spec, plan.data,
                                    jnp.float32(shift))

    def A(v):
        return sp.matvec(v) + shift * v

    def M(r):
        return plan.unpermute(M_cl(plan.permute(r), axis=-1))

    return cg(A, b, M=M, tol=tol, maxiter=maxiter)


# ---------------------------------------------------------------------------
# kernel ridge regression
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KRRModel:
    """Fitted KRR weights + the solve's convergence telemetry.

    ``alpha`` is in original index order (``(capacity[, t])`` or
    ``(B, capacity[, t])``); dead/hole slots carry zeros. ``predict()``
    with no argument is the in-sample fit ``K alpha``; ``predict(x_new)``
    (single plans only) evaluates the cross-kernel sparsely through the
    k nearest *training* neighbors of each test point — the same
    near-neighbor truncation the training pattern uses.
    """
    operator: "Union[api.InteractionPlan, api.PlanBatch]"
    alpha: jax.Array
    lam: float
    self_weight: "float | jax.Array"     # per-lane (B,) under "auto"
    result: CGResult

    def predict(self, x_new=None, *, k: Optional[int] = None) -> jax.Array:
        if x_new is None:
            sw = _lane_shift(self.self_weight, self.alpha.ndim)
            return self.operator.matvec(self.alpha) + sw * self.alpha
        op = self.operator
        if isinstance(op, api.PlanBatch):
            raise NotImplementedError(
                "out-of-sample prediction is per-member: call "
                "batch.member(i) and fit/predict on the member plan")
        host = op.host
        if host.x is None:
            raise ValueError("plan carries no training coordinates "
                             "(built from_coo without x); out-of-sample "
                             "prediction needs them")
        x_new = np.asarray(x_new, np.float32)
        k = k or op.config.k
        valid = None if host.alive is None else jnp.asarray(host.alive)
        idx, d2 = knn.knn_graph(jnp.asarray(x_new), jnp.asarray(host.x),
                                k, valid=valid)
        idx, d2 = np.asarray(idx), np.asarray(d2)
        m = x_new.shape[0]
        w = api.edge_values(host, np.repeat(np.arange(m), k),
                            idx.reshape(-1), d2.reshape(-1))
        w = jnp.asarray(w.reshape(m, k))
        anbr = jnp.take(jnp.asarray(self.alpha), jnp.asarray(idx), axis=0)
        if anbr.ndim == 2:                      # (m, k) neighbor weights
            return jnp.sum(w * anbr, axis=1)
        return jnp.sum(w[..., None] * anbr, axis=1)   # multi-target


def _auto_self_weight(op) -> jax.Array:
    """Gershgorin diagonal shift: the max weighted degree of the stored
    pattern (one matvec of ones on the already-compiled apply kernel).
    ``W + deg_max*I`` is diagonally dominant, hence PSD, for NONNEGATIVE
    edge weights — the kNN-truncated RBF kernel is indefinite in general
    (truncation destroys positive definiteness; the example data shows
    eigenvalues below -4), and this shift is what makes the KRR system
    provably SPD whatever the data. Per-lane for a batch."""
    if isinstance(op, api.PlanBatch):
        ones = jnp.ones((op.batch, op.capacity), jnp.float32)
        return jnp.max(op.apply(ones), axis=-1)          # (B,)
    plan = op.plan if isinstance(op, api.ShardedPlan) else op
    deg = op.apply(jnp.ones(plan.n, jnp.float32))
    return jnp.max(deg)


def _resolve_self_weight(op, self_weight):
    if isinstance(self_weight, str):
        if self_weight != "auto":
            raise ValueError(f"self_weight must be a number or 'auto', "
                             f"got {self_weight!r}")
        return _auto_self_weight(op)
    return self_weight


def krr_fit(plan, y, lam: float, *,
            self_weight: "float | str" = "auto",
            backend: Optional[str] = None,
            precond: Optional[str] = None,
            tol: Optional[float] = None,
            maxiter: Optional[int] = None) -> KRRModel:
    """Fit ``(W + (self_weight + lam) I) alpha = y`` on one plan (or a
    sharded plan). ``lam > 0`` is required: dead/hole rows contribute a
    bare ``shift`` diagonal. ``self_weight="auto"`` (default) uses the
    Gershgorin shift (see :func:`_auto_self_weight`) — the kNN-truncated
    kernel is NOT positive definite on clustered data, so a fixed
    ``self_weight=1.0`` (the classical RBF diagonal) only converges when
    the truncation happens to stay definite. ``y``: ``(capacity,)`` or
    ``(capacity, t)``."""
    if lam <= 0:
        raise ValueError(f"krr needs lam > 0, got {lam}")
    sw = _resolve_self_weight(plan, self_weight)
    res = solve(plan, y, shift=sw + lam, backend=backend, precond=precond,
                tol=tol, maxiter=maxiter)
    op = plan.plan if isinstance(plan, api.ShardedPlan) else plan
    return KRRModel(operator=op, alpha=res.x, lam=lam,
                    self_weight=sw, result=res)


def krr_fit_batch(batch, ys, lam: float, *,
                  self_weight: "float | str" = "auto",
                  backend: Optional[str] = None,
                  precond: Optional[str] = None,
                  tol: Optional[float] = None,
                  maxiter: Optional[int] = None) -> KRRModel:
    """Fit B member systems in lockstep — ONE compiled solver trace per
    spec however many members ride the batch (``self_weight="auto"``
    adds one dispatch of the batched *apply* kernel for the per-lane
    Gershgorin shift; the solver kernel still compiles once). ``ys``:
    ``(B, capacity)`` or ``(B, capacity, t)`` (``batch.pad_charges``
    packs ragged member targets)."""
    if lam <= 0:
        raise ValueError(f"krr needs lam > 0, got {lam}")
    sw = _resolve_self_weight(batch, self_weight)
    res = solve(batch, ys, shift=sw + lam, backend=backend,
                precond=precond, tol=tol, maxiter=maxiter)
    return KRRModel(operator=batch, alpha=res.x, lam=lam,
                    self_weight=sw, result=res)
