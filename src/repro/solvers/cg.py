"""Batched preconditioned conjugate gradient over matrix-free operators.

The plan substrate turned the paper's near-neighbor pattern into a fast,
batched, shardable symmetric operator; this module consumes it as one.
``cg`` sees nothing but a callable ``A(x) -> y`` — a single
``InteractionPlan.apply``, a ``PlanBatch`` batched kernel, or a
``ShardedPlan`` halo-exchange matvec all fit — and runs every lane of a
stacked right-hand side in lockstep inside ONE ``lax.while_loop``:

  * early exit: the loop stops as soon as every lane's residual is under
    its tolerance (or ``maxiter`` is reached) — converged lanes freeze
    (their updates are masked out), they never drift or overflow while
    slow lanes finish;
  * telemetry: per-lane iteration counts and the full per-iteration
    residual-norm history ride back on :class:`CGResult` (history entries
    a lane never ran are NaN, so convergence curves plot honestly);
  * preconditioning: ``M`` is any callable ``M(r) -> z`` approximating
    ``A^-1 r`` (see ``repro.solvers.precond`` and the registry in
    ``repro.core.registry``).

Lane layout: the n-axis is ``axis`` (default last). ``b`` of shape
``(n,)`` is one problem; ``(B, n)`` is B lockstep problems; ``(B, n, t)``
with ``axis=-2`` is B problems with t right-hand sides each — exactly the
charge layout the batched SpMV kernels take, so a whole ``PlanBatch`` KRR
fit is one compiled solver kernel.

Everything here traces cleanly: wrap ``cg`` in ``jax.jit`` with the
operator closed over (``repro.solvers.krr`` does, one kernel per
``PlanSpec``), or call it eagerly (the sharded path does — ``A`` then
dispatches the compiled shard_map per iteration, and the dot products
reduce over the device axis with a psum).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

__all__ = ["CGResult", "cg"]


@dataclasses.dataclass
class CGResult:
    """Solution + convergence telemetry of one (batched) CG run.

    ``x`` has ``b``'s shape. ``iters``/``converged``/``resid``/``bnorm``
    have the lane shape (``b``'s shape with the n-axis removed);
    ``history`` appends a trailing ``maxiter + 1`` axis to the lane
    shape: ``history[..., j]`` is the residual 2-norm *after* j
    iterations, NaN for iterations a lane never ran (it had already
    converged, or the loop had exited). ``resid`` is each lane's final
    residual norm; a lane ``converged`` iff ``resid <= tol * bnorm``.
    """
    x: jax.Array
    iters: jax.Array
    resid: jax.Array
    bnorm: jax.Array
    converged: jax.Array
    history: jax.Array

    def tree_flatten(self):
        return ((self.x, self.iters, self.resid, self.bnorm,
                 self.converged, self.history), None)

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    CGResult, CGResult.tree_flatten, CGResult.tree_unflatten)


def _norm(v: jax.Array, axis: int) -> jax.Array:
    """Lane-wise 2-norm, n-axis kept (size 1) for broadcasting."""
    return jnp.sqrt(jnp.sum(v * v, axis=axis, keepdims=True))


def _dot(u: jax.Array, v: jax.Array, axis: int) -> jax.Array:
    return jnp.sum(u * v, axis=axis, keepdims=True)


def cg(A: Callable, b: jax.Array, *,
       M: Optional[Callable] = None,
       tol: float = 1e-5,
       maxiter: int = 256,
       axis: int = -1,
       x0: Optional[jax.Array] = None) -> CGResult:
    """Preconditioned conjugate gradient on the symmetric operator ``A``.

    Solves ``A x = b`` per lane to relative tolerance
    ``||r|| <= tol * ||b||`` (lanes with ``||b|| == 0`` converge
    immediately to ``x = 0``). ``A`` and ``M`` must accept/return arrays
    of ``b``'s full shape. One ``lax.while_loop`` drives all lanes; see
    the module docstring for layout and telemetry semantics.
    """
    if maxiter < 1:
        raise ValueError(f"cg needs maxiter >= 1, got {maxiter}")
    b = jnp.asarray(b)
    ax = axis % b.ndim - b.ndim          # normalize to a negative axis
    M = M if M is not None else (lambda r: r)

    x = jnp.zeros_like(b) if x0 is None else jnp.asarray(x0)
    r = b - A(x) if x0 is not None else b
    z = M(r)
    p = z
    rz = _dot(r, z, ax)
    bnorm = _norm(b, ax)
    rnorm0 = _norm(r, ax)
    target = tol * bnorm

    lane_shape = rnorm0.shape            # n-axis collapsed to 1
    # history rides with an explicit trailing axis; squeeze the kept
    # n-axis out of the lane scalars when writing
    hist = jnp.full(jnp.squeeze(rnorm0, ax).shape + (maxiter + 1,),
                    jnp.nan, b.dtype)
    hist = hist.at[..., 0].set(jnp.squeeze(rnorm0, ax))

    active0 = rnorm0 > target
    iters0 = jnp.zeros(lane_shape, jnp.int32)

    def cond(state):
        k, _x, _r, _z, _p, _rz, active, _it, _h = state
        return jnp.logical_and(k < maxiter, jnp.any(active))

    def body(state):
        k, x, r, z, p, rz, active, iters, hist = state
        Ap = A(p)
        pAp = _dot(p, Ap, ax)
        # frozen lanes take a zero step (guard the 0/0 of a finished lane)
        alpha = jnp.where(active, rz / jnp.where(pAp == 0, 1.0, pAp), 0.0)
        x = x + alpha * p
        r = r - alpha * Ap
        z_new = M(r)
        rz_new = _dot(r, z_new, ax)
        beta = jnp.where(active, rz_new / jnp.where(rz == 0, 1.0, rz), 0.0)
        p = jnp.where(active, z_new + beta * p, p)
        rnorm = _norm(r, ax)
        still = rnorm > target
        iters = iters + active.astype(jnp.int32)
        hist = hist.at[..., k + 1].set(
            jnp.squeeze(jnp.where(active, rnorm, jnp.nan), ax))
        return (k + 1, x, r, z_new, p,
                jnp.where(active, rz_new, rz),
                jnp.logical_and(active, still), iters, hist)

    state = (jnp.asarray(0, jnp.int32), x, r, z, p, rz, active0, iters0,
             hist)
    _, x, r, _, _, _, _, iters, hist = jax.lax.while_loop(cond, body, state)
    resid = _norm(r, ax)
    return CGResult(x=x,
                    iters=jnp.squeeze(iters, ax),
                    resid=jnp.squeeze(resid, ax),
                    bnorm=jnp.squeeze(bnorm, ax),
                    converged=jnp.squeeze(resid <= target, ax),
                    history=hist)
