"""Preconditioners factored from the plan's own block-sparse storage.

The ELL-BSR already stores the near-field of the reordered operator as
dense ``bs x bs`` tiles — and on a well-ordered plan (high γ) the
*diagonal* tiles hold most of the interaction mass. Block-Jacobi exploits
exactly that: slice the diagonal tile of every row-block straight out of
the ELL slots (no densification of the off-diagonal storage, no host
round-trip), shift by the solve's regularizer, Cholesky-factor all blocks
in one batched call, and apply via two batched triangular solves per CG
iteration.

Factories follow the registry protocol (``repro.core.registry``):

    factory(spec: PlanSpec, data: PlanData, shift) -> apply(r) -> z

``spec``/``data`` are the plan's structure/array halves — a stacked
``PlanBatch`` pair works unchanged (every batched op here broadcasts over
leading axes), so one compiled solver kernel preconditions the whole
batch. Factories run *inside* the solver's jit: resolved by static name,
their state (factors) is traced per call.

Dead slots (streaming tombstones, capacity-padding holes) contribute
zero rows/columns to the operator; the extraction rewrites each dead
slot's diagonal entry to 1 so the factored blocks stay SPD whatever the
shift — the solve then returns ``b/shift``-style values on dead rows,
which the callers zero-pad anyway.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from jax.scipy.linalg import cho_solve

from repro.core.registry import register_preconditioner

__all__ = ["diag_tiles", "diag_vector", "block_jacobi", "jacobi",
           "identity"]


def _bcast(shift, ndim: int):
    """Broadcast a scalar or per-lane ``(B,)`` shift against an ``ndim``
    array by appending singleton axes (lanes lead, structure trails)."""
    s = jnp.asarray(shift)
    return s.reshape(s.shape + (1,) * (ndim - s.ndim))


def diag_tiles(spec, data) -> jax.Array:
    """Dense diagonal tiles of the plan operator, in cluster order.

    Returns ``(..., n_rb, bs, bs)`` — for each row-block, the kept ELL
    tile whose column-block equals the row-block (zeros when a row-block
    keeps no diagonal tile). Extraction is one masked reduction over the
    ELL slots: the off-diagonal tiles are read, never materialized into
    anything denser. Dead slots (``data.alive``) have their row/column
    zeroed and their diagonal entry set to 1, so the blocks of
    ``A' + shift*I`` are never singular.
    """
    if data.vals is None:
        raise ValueError("profile-only plan (with_bsr=False) has no tiles "
                         "to precondition from")
    n_rb, bs = spec.n_rb, spec.bs
    rb = jnp.arange(n_rb, dtype=data.col_idx.dtype)
    on_diag = (data.col_idx == rb[:, None]) & data.nbr_mask
    tiles = jnp.sum(
        jnp.where(on_diag[..., None, None], data.vals, 0.0), axis=-3)
    if data.alive is not None:
        # alive is kept in ORIGINAL slot order (it rides the host mask);
        # the tiles live in cluster order — permute, then pad the
        # capacity -> n_rb*bs structural slots as dead
        alive_cl = jnp.take_along_axis(data.alive, data.pi, axis=-1)
        pad = n_rb * bs - spec.capacity
        if pad:
            alive_cl = jnp.pad(
                alive_cl, [(0, 0)] * (alive_cl.ndim - 1) + [(0, pad)])
        live = alive_cl.reshape(
            alive_cl.shape[:-1] + (n_rb, bs)).astype(tiles.dtype)
        tiles = tiles * live[..., :, None] * live[..., None, :]
        tiles = tiles + (1.0 - live[..., :, None]) * jnp.eye(bs,
                                                             dtype=tiles.dtype)
    return tiles


def diag_vector(spec, data) -> jax.Array:
    """Pointwise diagonal of the plan operator ``(..., capacity)`` —
    the diagonal of :func:`diag_tiles` flattened back to slot order."""
    t = diag_tiles(spec, data)
    d = jnp.diagonal(t, axis1=-2, axis2=-1)        # (..., n_rb, bs)
    return d.reshape(d.shape[:-2] + (spec.n_rb * spec.bs,))[
        ..., :spec.capacity]


@register_preconditioner("block_jacobi")
def block_jacobi(spec, data, shift=0.0):
    """Block-Jacobi from the diagonal BSR tiles (batched Cholesky).

    Factors ``D_rb + shift*I`` per row-block in ONE batched
    ``jnp.linalg.cholesky`` over every (lane, row-block); ``apply`` runs
    the paired triangular solves on residual segments reshaped to
    blocks. Requires the tiles to be symmetric positive definite after
    the shift (symmetrized pattern + RBF-style values + a positive
    shift, the KRR setting); fall back to ``"jacobi"`` otherwise.
    """
    n_rb, bs, cap = spec.n_rb, spec.bs, spec.capacity
    tiles = diag_tiles(spec, data)
    shift = _bcast(shift, tiles.ndim).astype(tiles.dtype)
    tiles = tiles + shift * jnp.eye(bs, dtype=tiles.dtype)
    L = jnp.linalg.cholesky(tiles)                  # (..., n_rb, bs, bs)
    # a heavily truncated kernel with a small shift can leave a diagonal
    # block indefinite (no Cholesky factor -> NaN); degrade exactly those
    # blocks to their pointwise-diagonal factor (Jacobi) instead of
    # poisoning the whole solve
    d = jnp.diagonal(tiles, axis1=-2, axis2=-1)
    diag_L = jnp.sqrt(jnp.maximum(d, 1e-12))[..., :, None] \
        * jnp.eye(bs, dtype=tiles.dtype)
    bad = ~jnp.all(jnp.isfinite(L), axis=(-2, -1), keepdims=True)
    L = jnp.where(bad, diag_L, L)
    # invert ONCE at factor time: LAPACK triangular solves dispatch
    # per block and would dominate every CG iteration; an explicit
    # inverse turns the per-iteration apply into one batched matmul
    # (symmetric, and preconditioner accuracy is not solution accuracy)
    minv = cho_solve((L, True),
                     jnp.broadcast_to(jnp.eye(bs, dtype=tiles.dtype),
                                      tiles.shape))

    def apply(r: jax.Array, axis: int = -1) -> jax.Array:
        ax = axis % r.ndim - r.ndim
        rr = jnp.moveaxis(r, ax, -1)                # (..., [f,] cap)
        pad = n_rb * bs - cap
        if pad:
            rr = jnp.pad(rr, [(0, 0)] * (rr.ndim - 1) + [(0, pad)])
        blocks = rr.reshape(rr.shape[:-1] + (n_rb, bs))
        if ax == -1:
            zz = jnp.einsum("...rij,...rj->...ri", minv, blocks)
        else:
            # (..., f, n_rb, bs): hit every right-hand side of a block
            # with the same inverse in one contraction
            zz = jnp.einsum("...rij,...frj->...fri", minv, blocks)
        zz = zz.reshape(rr.shape)[..., :cap]
        return jnp.moveaxis(zz, -1, ax)

    return apply


@register_preconditioner("jacobi")
def jacobi(spec, data, shift=0.0):
    """Pointwise diagonal scaling ``z = r / (diag(A') + shift)`` — the
    plain fallback when the diagonal tiles are not SPD (or ``bs`` is
    large enough that the block solves dominate an iteration)."""
    dv = diag_vector(spec, data)
    d = dv + _bcast(shift, dv.ndim).astype(dv.dtype)
    d = jnp.where(d == 0, 1.0, d)

    def apply(r: jax.Array, axis: int = -1) -> jax.Array:
        ax = axis % r.ndim - r.ndim
        if ax == -1:
            return r / d
        return r / jnp.expand_dims(d, -1)

    return apply


@register_preconditioner("identity")
def identity(spec, data, shift=0.0):
    """No preconditioning (plain CG)."""
    del spec, data, shift

    def apply(r: jax.Array, axis: int = -1) -> jax.Array:
        del axis
        return r

    return apply
