"""Checkpointing: sharded npz + JSON manifest, async save, elastic restore.

Layout:
  <dir>/step_<N>/manifest.json   {step, tree structure, leaf paths, dtypes}
  <dir>/step_<N>/leaf_<i>.npy    one array per leaf (host-gathered)

Design points for the 1000-node posture:
  - saves are ASYNC (background thread; ``wait()`` joins before the next
    save, so training never blocks on I/O);
  - restore is ELASTIC: arrays are stored in logical (unsharded) layout and
    re-device_put with whatever sharding the *new* mesh prescribes — resume
    on a different pod count/mesh shape works by construction;
  - manifests carry the step, so the data pipeline skips ahead
    deterministically (data/pipeline.py) — no data-state file needed;
  - atomicity: writes land in ``.tmp`` and are renamed, so a crash mid-save
    never corrupts the latest-complete checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


class Checkpointer:
    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Host-gather the tree and write it in the background."""
        self.wait()
        flat, treedef = _flatten_with_paths(tree)
        # bf16 has no native numpy save format -> store as f32 (lossless);
        # restore() casts back to the model's leaf dtype
        host = [np.asarray(x.astype(jnp.float32)
                           if hasattr(x, "dtype") and x.dtype == jnp.bfloat16
                           else x) for x in flat]

        def work():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host):
                np.save(tmp / f"leaf_{i}.npy", arr)
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "treedef": jax.tree.unflatten(
                    treedef, list(range(len(host)))).__repr__(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def steps(self):
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like``; device_put each leaf
        with the corresponding sharding (elastic: any mesh works)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        flat, treedef = _flatten_with_paths(tree_like)
        n = json.loads((d / "manifest.json").read_text())["n_leaves"]
        if n != len(flat):
            raise ValueError(f"checkpoint has {n} leaves, model needs "
                             f"{len(flat)} — structure mismatch")
        arrs = [np.load(d / f"leaf_{i}.npy") for i in range(len(flat))]
        if shardings is not None:
            sflat = treedef.flatten_up_to(shardings)
            out = [jax.device_put(a.astype(l.dtype), s)
                   for a, l, s in zip(arrs, flat, sflat)]
        else:
            out = [jnp.asarray(a.astype(l.dtype)) for a, l in zip(arrs, flat)]
        return jax.tree.unflatten(treedef, out), step
