"""Checkpointing: sharded npz + JSON manifest, async save, elastic restore.

Layout:
  <dir>/step_<N>/manifest.json   {step, tree structure, leaf paths, dtypes}
  <dir>/step_<N>/leaf_<i>.npy    one array per leaf (host-gathered)
  <dir>/step_<N>/plan_<name>/    a persisted InteractionPlan (save_plan):
                                 arrays.npz (BSR tiles + permutation + COO
                                 + embedding frame) and manifest.json
                                 (config, tree levels, refresh telemetry)

Design points for the 1000-node posture:
  - saves are ASYNC (background thread; ``wait()`` joins before the next
    save, so training never blocks on I/O);
  - restore is ELASTIC: arrays are stored in logical (unsharded) layout and
    re-device_put with whatever sharding the *new* mesh prescribes — resume
    on a different pod count/mesh shape works by construction;
  - manifests carry the step, so the data pipeline skips ahead
    deterministically (data/pipeline.py) — no data-state file needed;
  - atomicity: writes land in ``.tmp`` and are renamed, so a crash mid-save
    never corrupts the latest-complete checkpoint;
  - plans are first-class: serving restarts ``restore_plan`` instead of
    re-running the embedding -> tree -> ordering -> BSR pipeline, and
    ``restore_plan(refresh_with=x)`` re-validates the stored plan against
    the *current* points (the γ/cell-drift policy decides whether the
    restored ordering still stands or gets re-bucketed/rebuilt).

When saving a model tree and a plan at the same step, save the model tree
first: ``save(step, ...)`` replaces the whole ``step_<N>`` directory.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh


def _validate_plan_arrays(m: dict, arrays: dict, where) -> None:
    """Cross-check a plan manifest against its array payload before any
    reconstruction: a truncated/mismatched checkpoint fails here with a
    message naming the offending array, not deep in BSR math."""
    n = m.get("n")
    required = ["pi", "inv"]
    if m.get("bsr") is not None:
        required += ["bsr_col_idx", "bsr_nbr_mask", "bsr_vals"]
    missing = [k for k in required if k not in arrays]
    if missing:
        raise ValueError(
            f"plan checkpoint {where} is missing arrays {missing} "
            f"(manifest promises them)")
    for key in ("pi", "inv", "alive", "codes"):
        if key in arrays and len(arrays[key]) != n:
            raise ValueError(
                f"plan checkpoint {where}: array {key!r} has "
                f"{len(arrays[key])} entries, manifest says capacity "
                f"n={n}")
    if m.get("bsr") is not None:
        b = m["bsr"]
        want = (b["n_rb"], b["max_nbr"], b["bs"], b["bs"])
        got = arrays["bsr_vals"].shape
        if got != want:
            raise ValueError(
                f"plan checkpoint {where}: bsr_vals shape {got} does not "
                f"match the manifest BSR layout {want}")
        if arrays["bsr_col_idx"].shape != want[:2]:
            raise ValueError(
                f"plan checkpoint {where}: bsr_col_idx shape "
                f"{arrays['bsr_col_idx'].shape} does not match the "
                f"manifest BSR layout {want[:2]}")
    if "coo_rows" in arrays:
        lens = {k: len(arrays[k]) for k in
                ("coo_rows", "coo_cols", "coo_vals") if k in arrays}
        if len(set(lens.values())) > 1 or len(lens) != 3:
            raise ValueError(
                f"plan checkpoint {where}: COO triple is ragged or "
                f"incomplete ({lens})")


def _flatten_with_paths(tree):
    flat, treedef = jax.tree.flatten(tree)
    return flat, treedef


def _plan_payload(plan, step: int):
    """Host-gather one ``InteractionPlan`` into ``(arrays, manifest)`` —
    the single-plan on-disk format (shared by batch members)."""
    import dataclasses

    host = plan.host
    arrays = {"pi": np.asarray(host.pi), "inv": np.asarray(host.inv)}
    if plan.bsr is not None:
        arrays["bsr_col_idx"] = np.asarray(plan.bsr.col_idx)
        arrays["bsr_nbr_mask"] = np.asarray(plan.bsr.nbr_mask)
        arrays["bsr_vals"] = np.asarray(plan.bsr.vals)
    if host.coo is not None:
        arrays["coo_rows"], arrays["coo_cols"], arrays["coo_vals"] = (
            np.asarray(a) for a in host.coo)
    for key in ("embedding", "y_last", "embed_mean", "embed_axes",
                "sources", "x", "alive", "codes", "code_lo", "code_hi"):
        val = getattr(host, key)
        if val is not None:
            arrays[key] = np.asarray(val)
    if host.tree is not None:
        arrays["tree_perm"] = np.asarray(host.tree.perm)
        for i, lvl in enumerate(host.tree.levels):
            arrays[f"tree_level_{i}"] = np.asarray(lvl)
    manifest = {
        "format": 1,
        "step": step,
        "n": plan.n,
        # streaming capacity layout: capacity == n (physical slots);
        # n_alive is the logical live count the restored mask re-derives
        "capacity": plan.n,
        "n_alive": plan.n_alive,
        "peak_alive": host.peak_alive,
        "config": dataclasses.asdict(plan.config),
        "sigma": host.sigma,
        "gamma": host.gamma,
        "pattern_from_knn": host.pattern_from_knn,
        # a callable cannot round-trip: freeze the pattern on restore
        "values_mode": ("static" if host.values_mode == "fn"
                        else host.values_mode),
        "refresh": dataclasses.asdict(host.refresh),
        "bsr": (None if plan.bsr is None else {
            "bs": plan.bsr.bs, "sb": plan.bsr.sb, "n": plan.bsr.n,
            "n_rb": plan.bsr.n_rb, "n_cb": plan.bsr.n_cb,
            "fill": plan.bsr.fill, "max_nbr": plan.bsr.max_nbr}),
        "tree": (None if host.tree is None else {
            "d": host.tree.d, "bits": host.tree.bits,
            "n_levels": host.tree.n_levels}),
        "shard": None,
    }
    return arrays, manifest


def _plan_from_payload(m: dict, arrays: dict):
    """Reconstruct a single ``InteractionPlan`` from a validated
    ``(manifest, arrays)`` payload."""
    from repro import api
    from repro.core.blocksparse import BSR
    from repro.core.hierarchy import Tree

    config = api.PlanConfig(**m["config"])
    n = m["n"]
    bsr = None
    if m["bsr"] is not None:
        b = m["bsr"]
        bsr = BSR(bs=b["bs"], sb=b["sb"], n=b["n"], n_rb=b["n_rb"],
                  n_cb=b["n_cb"], fill=b["fill"], max_nbr=b["max_nbr"],
                  col_idx=jnp.asarray(arrays["bsr_col_idx"]),
                  nbr_mask=jnp.asarray(arrays["bsr_nbr_mask"]),
                  vals=jnp.asarray(arrays["bsr_vals"]))
    tree = None
    if m["tree"] is not None:
        t = m["tree"]
        tree = Tree(perm=arrays["tree_perm"],
                    levels=[arrays[f"tree_level_{i}"]
                            for i in range(t["n_levels"])],
                    d=t["d"], bits=t["bits"])
    coo = (tuple(arrays[k] for k in ("coo_rows", "coo_cols", "coo_vals"))
           if "coo_rows" in arrays else None)
    host = api._PlanHost(
        pi=arrays["pi"], inv=arrays["inv"], coo=coo, tree=tree,
        embedding=arrays.get("embedding"), sigma=m["sigma"],
        gamma=m["gamma"], embed_mean=arrays.get("embed_mean"),
        embed_axes=arrays.get("embed_axes"),
        y_last=arrays.get("y_last"), sources=arrays.get("sources"),
        pattern_from_knn=m["pattern_from_knn"],
        values_mode=m["values_mode"],
        x=arrays.get("x"), alive=arrays.get("alive"),
        codes=arrays.get("codes"), code_lo=arrays.get("code_lo"),
        code_hi=arrays.get("code_hi"),
        peak_alive=m.get("peak_alive"),
        refresh=api.RefreshStats(**m["refresh"]))
    return api.InteractionPlan(
        config, n, bsr, jnp.asarray(arrays["pi"], jnp.int32),
        jnp.asarray(arrays["inv"], jnp.int32), host)


def _batch_payload(pb, step: int):
    """Host-gather one ``PlanBatch`` into ``(member_payloads, manifest)``
    — the on-disk batch format (also each layer of a session store)."""
    import dataclasses

    payloads = [_plan_payload(pb.member(i), step) for i in range(pb.batch)]
    manifest = {
        "format": 1, "step": step, "batch": pb.batch,
        "capacity": pb.capacity,
        "config": dataclasses.asdict(pb.spec.config),
        "tuned": {str(k): v for k, v in pb.tuned.items()},
    }
    return payloads, manifest


def _write_batch_dir(d: Path, payloads, manifest: dict) -> None:
    for i, (arrays, m) in enumerate(payloads):
        sub = d / f"member_{i}"
        sub.mkdir()
        np.savez(sub / "arrays.npz", **arrays)
        (sub / "manifest.json").write_text(json.dumps(m))
    (d / "manifest.json").write_text(json.dumps(manifest))


def _read_batch_dir(d: Path, m: dict):
    """Restore a ``PlanBatch`` from a dir written by ``_write_batch_dir``
    (members re-stacked, so the shared spec is re-derived)."""
    from repro import api

    members = []
    for i in range(m["batch"]):
        sub = d / f"member_{i}"
        try:
            mm = json.loads((sub / "manifest.json").read_text())
            arrays = dict(np.load(sub / "arrays.npz"))
        except Exception as e:
            raise ValueError(
                f"plan batch member {i} is corrupt or missing under "
                f"{sub}: {e}") from e
        _validate_plan_arrays(mm, arrays, sub)
        members.append(_plan_from_payload(mm, arrays))
    pb = api.PlanBatch.from_plans(members, capacity=m["capacity"])
    pb.tuned = {int(k): v for k, v in (m.get("tuned") or {}).items()}
    return pb


class Checkpointer:
    """Atomic, async, elastic checkpointing of model trees and plans.

    ``save(step, tree)`` host-gathers the pytree and writes it on a
    background thread (``wait()`` joins; the next ``save`` joins
    automatically, so training never blocks on I/O). Writes land in a
    ``.tmp`` directory renamed at the end, so a crash mid-save never
    corrupts the latest complete step. ``restore`` re-``device_put``s
    arrays with whatever sharding the *current* mesh prescribes —
    resume on a different pod count works by construction.
    ``save_plan``/``restore_plan`` persist
    :class:`~repro.api.InteractionPlan` / ``PlanBatch`` lineages
    (storage, ordering, streaming state, refresh telemetry) so serving
    restarts skip the embed → tree → order → compress pipeline;
    ``restore_plan(refresh_with=x)`` re-validates the stored ordering
    against current points. The last ``keep`` steps are retained.
    """

    def __init__(self, directory: str | Path, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        """Host-gather the tree and write it in the background."""
        self.wait()
        flat, treedef = _flatten_with_paths(tree)
        # bf16 has no native numpy save format -> store as f32 (lossless);
        # restore() casts back to the model's leaf dtype
        host = [np.asarray(x.astype(jnp.float32)
                           if hasattr(x, "dtype") and x.dtype == jnp.bfloat16
                           else x) for x in flat]

        def work():
            tmp = self.dir / f".tmp_step_{step}"
            final = self.dir / f"step_{step}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            for i, arr in enumerate(host):
                np.save(tmp / f"leaf_{i}.npy", arr)
            manifest = {
                "step": step,
                "n_leaves": len(host),
                "treedef": jax.tree.unflatten(
                    treedef, list(range(len(host)))).__repr__(),
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        # model checkpoints and plans may be saved on different cadences:
        # keep the latest `keep` of EACH kind (a step dir survives if
        # either its model tree or its plan is still wanted)
        keep_model = set(self.steps()[-self.keep:])
        keep_plan = set(self.plan_steps()[-self.keep:])
        for p in self.dir.glob("step_*"):
            s = int(p.name.split("_")[1])
            if s not in keep_model and s not in keep_plan:
                shutil.rmtree(p, ignore_errors=True)

    # -- restore ------------------------------------------------------------

    def steps(self):
        """Steps holding a *model* checkpoint (plan-only steps excluded, so
        ``restore()``'s default step never lands on a dir with no leaves)."""
        out = []
        for p in self.dir.glob("step_*"):
            if (p / "manifest.json").exists():
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def plan_steps(self, name: Optional[str] = None):
        """Steps holding a persisted plan (``name`` filters to one plan)."""
        pattern = f"plan_{name}/manifest.json" if name else \
            "plan_*/manifest.json"
        out = []
        for p in self.dir.glob("step_*"):
            if any(p.glob(pattern)):
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``tree_like``; device_put each leaf
        with the corresponding sharding (elastic: any mesh works)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        flat, treedef = _flatten_with_paths(tree_like)
        n = json.loads((d / "manifest.json").read_text())["n_leaves"]
        if n != len(flat):
            raise ValueError(f"checkpoint has {n} leaves, model needs "
                             f"{len(flat)} — structure mismatch")
        arrs = [np.load(d / f"leaf_{i}.npy") for i in range(len(flat))]
        if shardings is not None:
            sflat = treedef.flatten_up_to(shardings)
            out = [jax.device_put(a.astype(l.dtype), s)
                   for a, l, s in zip(arrs, flat, sflat)]
        else:
            out = [jnp.asarray(a.astype(l.dtype)) for a, l in zip(arrs, flat)]
        return jax.tree.unflatten(treedef, out), step

    # -- interaction plans (repro.api lifecycle: persist stage) -------------

    def save_plan(self, step: int, plan: Any, name: str = "plan",
                  blocking: bool = False) -> None:
        """Persist an ``repro.api.InteractionPlan``.

        BSR arrays, permutation, COO pattern, and the embedding frame are
        stored exactly (float32/int — the restored plan's ``matvec`` is
        bit-identical); config, tree levels, and refresh telemetry ride in
        the JSON manifest. A ``values`` *callable* cannot be serialized:
        the restored plan refreshes in pattern-frozen (reorder-only) mode.

        Shard-aware: a ``repro.api.ShardedPlan`` is accepted directly —
        the *unsharded* plan is what lands on disk (shard arrays are a
        pure transform of it, and the restoring mesh may have a different
        device count), plus a manifest note of the sharding axis so
        ``restore_plan(mesh=...)`` re-shards on load.

        Batch-aware: a ``repro.api.PlanBatch`` is accepted directly — the
        batch manifest records the shared spec/capacity and each member
        lands in ``member_<i>/`` in the exact single-plan format, so
        ``restore_plan`` re-stacks them (and the stacking re-derives the
        shared spec, elastic to code that changed padding policy).

        Service-aware: a ``repro.serve.SessionStore`` is accepted directly
        — each session lands as ``session_<rid>/`` holding its per-layer
        plan batches (``layer_<l>/`` in the exact batch format), its
        ``aux.npz`` device/request payload, and a session manifest; the
        top manifest records rids + service counters. ``restore_plan``
        rebuilds the store so ``ClusterKVEngine.resume`` continues
        bit-exactly (drain -> snapshot -> resume).
        """
        self.wait()
        if hasattr(plan, "sessions") and hasattr(plan, "counters"):
            # a serve.SessionStore: sessions + their per-layer plan batches
            store = plan
            entries = []
            for rid in sorted(store.sessions):
                sess = store.sessions[rid]
                layers = [_batch_payload(pb, step) for pb in sess.plans]
                aux = {k: np.asarray(v) for k, v in sess.aux.items()}
                sman = {"rid": sess.rid, "slot": sess.slot,
                        "blen": sess.blen, "n_layers": len(sess.plans)}
                entries.append((rid, layers, aux, sman))
            manifest = {
                "format": 1, "step": step, "session_store": True,
                "rids": sorted(store.sessions),
                "counters": dict(store.counters),
            }

            def fill_store(tmp: Path) -> None:
                for rid, layers, aux, sman in entries:
                    sd = tmp / f"session_{rid}"
                    sd.mkdir()
                    for l, (payloads, bman) in enumerate(layers):
                        ld = sd / f"layer_{l}"
                        ld.mkdir()
                        _write_batch_dir(ld, payloads, bman)
                    np.savez(sd / "aux.npz", **aux)
                    (sd / "manifest.json").write_text(json.dumps(sman))
                (tmp / "manifest.json").write_text(json.dumps(manifest))

            self._write_plan_dir(step, name, fill_store, blocking)
            return
        if hasattr(plan, "hosts") and hasattr(plan, "member"):
            # a PlanBatch: member payloads + one batch manifest
            payloads, manifest = _batch_payload(plan, step)

            def fill_batch(tmp: Path) -> None:
                _write_batch_dir(tmp, payloads, manifest)

            self._write_plan_dir(step, name, fill_batch, blocking)
            return

        shard_meta = None
        if hasattr(plan, "spec") and hasattr(plan, "unshard"):
            sp = plan
            shard_meta = {"axis": sp.spec.axis, "n_dev": sp.spec.n_dev,
                          "mode": sp.spec.mode}
            plan = sp.plan
        arrays, manifest = _plan_payload(plan, step)
        manifest["shard"] = shard_meta

        def fill(tmp: Path) -> None:
            np.savez(tmp / "arrays.npz", **arrays)
            (tmp / "manifest.json").write_text(json.dumps(manifest))

        self._write_plan_dir(step, name, fill, blocking)

    def _write_plan_dir(self, step: int, name: str, fill,
                        blocking: bool) -> None:
        """The atomic plan-write dance, shared by the single-plan and
        batch paths: populate a ``.tmp`` dir via ``fill(tmp)``, rename it
        into place, garbage-collect — in the background unless blocking.
        (One copy on purpose: durability fixes must not fork.)"""

        def work():
            tmp = self.dir / f".tmp_plan_{step}_{name}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            fill(tmp)
            final = self.dir / f"step_{step}" / f"plan_{name}"
            final.parent.mkdir(parents=True, exist_ok=True)
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def restore_plan(self, step: Optional[int] = None, name: str = "plan",
                     refresh_with: Any = None,
                     policy: Optional[str] = None,
                     mesh: Any = None, axis: Optional[str] = None
                     ) -> Tuple[Any, int]:
        """Restore an ``InteractionPlan`` saved by :meth:`save_plan`.

        With ``refresh_with`` (the *current* points, original order), the
        restored plan is immediately passed through ``refresh_plan`` — the
        recorded cell/γ-drift policy decides whether the persisted ordering
        still stands, gets patched, or is rebuilt, so serving restarts are
        safe against points that moved while the process was down.

        With ``mesh`` (a ``jax.sharding.Mesh``, or ``"auto"`` for a 1-axis
        mesh over every local device), the plan is re-sharded after any
        refresh and a ``ShardedPlan`` is returned — elastic by
        construction: the halo analysis runs against the *restoring*
        mesh's device count, so a plan saved from an 8-way serving mesh
        restores onto any pod shape. ``axis`` defaults to the recorded
        sharding axis (or ``"data"``).
        """
        from repro import api

        if step is None:
            ps = self.plan_steps(name)
            step = ps[-1] if ps else None
        if step is None:
            raise FileNotFoundError(f"no plan {name!r} under {self.dir}")
        d = self.dir / f"step_{step}" / f"plan_{name}"
        if not (d / "manifest.json").exists():
            raise FileNotFoundError(f"no plan {name!r} at step {step} "
                                    f"under {self.dir}")
        if mesh is not None and not (
                mesh == "auto" or isinstance(mesh, Mesh)):
            raise TypeError(
                f"mesh must be a jax.sharding.Mesh or 'auto', got "
                f"{mesh!r} — restore_plan re-shards elastically on "
                "whatever mesh you pass")
        if isinstance(mesh, Mesh) and axis is not None \
                and axis not in mesh.shape:
            raise ValueError(
                f"restoring mesh has no axis {axis!r} (axes: "
                f"{tuple(mesh.axis_names)}, {mesh.size} devices)")
        try:
            m = json.loads((d / "manifest.json").read_text())
        except ValueError as e:
            raise ValueError(
                f"corrupt plan manifest {d / 'manifest.json'}: {e} "
                "(checkpoint writes are atomic — this directory was "
                "modified outside the Checkpointer)") from e
        if m.get("session_store"):
            # a persisted serve.SessionStore: sessions + per-layer batches
            if refresh_with is not None or mesh is not None:
                raise ValueError(
                    f"plan {name!r} at step {step} is a SessionStore; "
                    "refresh_with/mesh apply to single plans")
            from repro.serve.session import Session, SessionStore

            store = SessionStore()
            for rid in m["rids"]:
                sd = d / f"session_{rid}"
                try:
                    sman = json.loads((sd / "manifest.json").read_text())
                    aux = dict(np.load(sd / "aux.npz"))
                except Exception as e:
                    raise ValueError(
                        f"session store {name!r} at step {step}: session "
                        f"{rid} is corrupt or missing under {sd}: {e}"
                    ) from e
                plans = []
                for l in range(sman["n_layers"]):
                    ld = sd / f"layer_{l}"
                    bm = json.loads((ld / "manifest.json").read_text())
                    plans.append(_read_batch_dir(ld, bm))
                # register, not admit: restoring is not an admission
                store.register(Session(rid=sman["rid"], slot=sman["slot"],
                                       blen=sman["blen"], plans=plans,
                                       aux=aux))
            store.counters = dict(m["counters"])
            return store, step
        if m.get("batch"):
            # a persisted PlanBatch: restore members, re-stack
            if refresh_with is not None or mesh is not None:
                raise ValueError(
                    f"plan {name!r} at step {step} is a PlanBatch; "
                    "refresh_with/mesh apply to single plans — restore "
                    "the batch plain and refresh/shard members "
                    "individually if needed")
            return _read_batch_dir(d, m), step
        if not (d / "arrays.npz").exists():
            raise FileNotFoundError(
                f"plan {name!r} at step {step} has a manifest but no "
                f"arrays.npz under {d}")
        try:
            arrays = dict(np.load(d / "arrays.npz"))
        except Exception as e:
            raise ValueError(
                f"corrupt plan arrays {d / 'arrays.npz'}: {e}") from e
        _validate_plan_arrays(m, arrays, d)

        plan = _plan_from_payload(m, arrays)
        if refresh_with is not None:
            plan = api.refresh_plan(plan, refresh_with, policy=policy)
        if mesh is not None:
            # a real single-axis mesh names the axis; otherwise fall back
            # to the recorded saving axis (restoring meshes need not reuse
            # the saving mesh's axis names)
            if (axis is None and hasattr(mesh, "axis_names")
                    and len(mesh.axis_names) == 1):
                axis = mesh.axis_names[0]
            axis = axis or (m.get("shard") or {}).get("axis") or "data"
            plan = api.shard(plan, None if mesh == "auto" else mesh,
                             axis=axis)
        return plan, step
