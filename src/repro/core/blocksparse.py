"""Multi-level compressed block-sparse storage (paper §2.4).

TPU adaptation of the paper's multi-level scheme (DESIGN.md §2): the bottom
level is a fixed MXU-aligned ``bs x bs`` tile; a row-block keeps the list of
column-block indices of its nonzero tiles (ELL-padded so shapes are static
for Pallas). The adaptive tree survives as (i) *which* tiles are kept and
(ii) the second level: tiles are grouped under ``sb x sb``-tile superblocks,
and the per-row tile lists are ordered by superblock then column — the
multi-level iteration schedule that improves charge-segment reuse.

``nnz / covered area`` of the kept tiles is exactly the paper's patch-density
numerator/denominator for this (uniform-grid) covering — reported as
``fill``.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def _pad_edges(rb, slot, rloc, cloc, vals, n_rb: int):
    """Pow2-quantize the edge count for the device tile scatters.

    Padding entries target the out-of-bounds row-block ``n_rb`` and are
    dropped by the scatter (``mode="drop"``), so every nnz inside a pow2
    bucket hits the same compiled kernel — streaming steps with a
    drifting edge count never retrace."""
    e = len(rb)
    pad = (1 << max(e - 1, 0).bit_length()) - e

    def _p(a, fill, dt):
        a = np.asarray(a, dt)
        return a if pad == 0 else np.concatenate(
            [a, np.full(pad, fill, dt)])

    return (jnp.asarray(_p(rb, n_rb, np.int32)),
            jnp.asarray(_p(slot, 0, np.int32)),
            jnp.asarray(_p(rloc, 0, np.int32)),
            jnp.asarray(_p(cloc, 0, np.int32)),
            jnp.asarray(_p(vals, 0.0, np.float32)))


@partial(jax.jit, static_argnames=("n_rb", "m", "bs"))
def _dress_tiles(rb, slot, rloc, cloc, vals, *, n_rb, m, bs):
    """Scatter a COO's edges into a fresh tile tensor, entirely on
    device: only the O(nnz) 1-D index/value arrays cross the host
    boundary, never the (n_rb, m, bs, bs) tensor."""
    dense = jnp.zeros((n_rb, m, bs, bs), jnp.float32)
    return dense.at[rb, slot, rloc, cloc].add(vals, mode="drop")


@jax.jit
def _patch_tiles(vals, ti, rb, slot, rloc, cloc, v):
    """Re-dress ``ti`` row-blocks of the device tile tensor: zero the
    touched rows, then scatter their edges. Row padding repeats a real
    touched block (idempotent zero-write); edge padding is out-of-bounds
    sentinels (dropped)."""
    vals = vals.at[ti].set(0.0)
    return vals.at[rb, slot, rloc, cloc].add(v, mode="drop")


@dataclass
class BSR:
    bs: int                 # bottom-level tile size
    sb: int                 # superblock size, in tiles (level above)
    n: int                  # logical matrix dimension (n x n), pre-padding
    n_rb: int
    n_cb: int
    col_idx: jnp.ndarray    # (n_rb, max_nbr) int32, padded with 0
    nbr_mask: jnp.ndarray   # (n_rb, max_nbr) bool, False on padding
    vals: jnp.ndarray       # (n_rb, max_nbr, bs, bs) dense tiles, 0 padded
    fill: float             # nnz / (kept tiles * bs^2)
    max_nbr: int

    def rowblock_cols(self, r0: int, r1: int) -> np.ndarray:
        """Sorted unique kept column-blocks of row-blocks ``[r0, r1)`` —
        the column support a row-range's charge window must cover (what
        the sharded halo analysis in ``core.shardplan`` reads). Requires
        concrete (non-traced) index arrays."""
        ci = np.asarray(self.col_idx[r0:r1])
        mk = np.asarray(self.nbr_mask[r0:r1])
        return np.unique(ci[mk]).astype(np.int64)

    def to_dense(self) -> np.ndarray:
        a = np.zeros((self.n_rb * self.bs, self.n_cb * self.bs), np.float32)
        ci = np.asarray(self.col_idx)
        mask = np.asarray(self.nbr_mask)
        v = np.asarray(self.vals)
        for rb in range(self.n_rb):
            for t in range(self.max_nbr):
                if mask[rb, t]:
                    cb = ci[rb, t]
                    a[rb * self.bs:(rb + 1) * self.bs,
                      cb * self.bs:(cb + 1) * self.bs] += v[rb, t]
        return a[:self.n, :self.n]

    # -- pytree protocol: array state as leaves, layout metadata static, so
    # -- a BSR (and any plan holding one) crosses jit/scan/shard_map freely.
    def tree_flatten(self):
        children = (self.col_idx, self.nbr_mask, self.vals)
        aux = (self.bs, self.sb, self.n, self.n_rb, self.n_cb, self.fill,
               self.max_nbr)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        bs, sb, n, n_rb, n_cb, fill, max_nbr = aux
        col_idx, nbr_mask, vals = children
        return cls(bs=bs, sb=sb, n=n, n_rb=n_rb, n_cb=n_cb, col_idx=col_idx,
                   nbr_mask=nbr_mask, vals=vals, fill=fill, max_nbr=max_nbr)


jax.tree_util.register_pytree_node(
    BSR, BSR.tree_flatten, BSR.tree_unflatten)


def build_bsr(rows: np.ndarray, cols: np.ndarray, vals: Optional[np.ndarray],
              n: int, bs: int = 32, sb: int = 8,
              max_nbr: Optional[int] = None, slack: int = 0) -> BSR:
    """Build the two-level ELL-BSR from COO. numpy preprocessing (one-off,
    like the paper's tree build); duplicate (i, j) entries are summed.

    ``slack`` widens the ELL slot axis beyond the widest row-block —
    headroom so :func:`patch_bsr` can give a refreshed row *new* neighbor
    tiles in place without a full rebuild (ignored when ``max_nbr`` pins
    the width explicitly).
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnz = len(rows)
    if vals is None:
        vals = np.ones(nnz, np.float32)
    vals = np.asarray(vals, np.float32)
    n_rb = (n + bs - 1) // bs
    n_cb = n_rb

    rb, cb = rows // bs, cols // bs

    # per-row-block tile lists in the multi-level schedule order
    # (superblock-major, then column): one np.unique over keyed tiles
    # yields every row's list already sorted — the same vectorized
    # routine patch_bsr uses, here over all rows (the seed's per-row
    # python lists made build_bsr the dominant cost of every
    # restripe/rebucket at serving sizes)
    skey = (cb // sb).astype(np.int64) * n_cb + cb
    span = np.int64(n_cb) * ((n_cb + sb - 1) // sb + 1)
    uniq = np.unique(rb.astype(np.int64) * span + skey)
    urow = uniq // span
    ucol = (uniq % span) % n_cb
    counts = np.bincount(urow, minlength=n_rb)
    m = int(counts.max(initial=1)) + max(slack, 0)
    if max_nbr is not None:
        m = max_nbr
        if counts.max(initial=0) > m:
            raise ValueError(f"max_nbr={m} < needed {counts.max()}")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    uslot = np.arange(len(uniq)) - starts[urow]
    col_idx = np.zeros((n_rb, m), np.int32)
    nbr_mask = np.zeros((n_rb, m), bool)
    col_idx[urow, uslot] = ucol
    nbr_mask[urow, uslot] = True

    # dress the tiles on device: the (n_rb, m, bs, bs) tensor is never
    # materialized on the host (the host round-trip used to dominate
    # every streaming restripe) — only the edge index/value arrays are
    # uploaded, pow2-padded so restripes over a drifting nnz reuse one
    # compiled scatter
    pos = np.searchsorted(uniq, rb.astype(np.int64) * span + skey)
    dense = _dress_tiles(*_pad_edges(rb, uslot[pos], rows % bs, cols % bs,
                                     vals, n_rb), n_rb=n_rb, m=m, bs=bs)

    # mask-consistency invariants the multi-level (bsr_ml) schedule relies
    # on: padded slots carry column 0 and zero tiles (the scatter only
    # writes (urow, uslot) cells, which are exactly the masked ones), and
    # within every row the kept columns are superblock-major sorted (so a
    # superblock's tiles are contiguous in the ELL slot axis).
    assert not col_idx[~nbr_mask].any(), "padded slots must point at column 0"
    sb_of = col_idx // sb
    keyed = np.where(nbr_mask, sb_of * np.int64(n_cb) + col_idx,
                     np.iinfo(np.int64).max)
    assert (np.diff(keyed, axis=1) >= 0).all(), \
        "tile lists must be superblock-major sorted"

    kept = int(counts.sum())
    fill = nnz / max(kept * bs * bs, 1)
    return BSR(bs=bs, sb=sb, n=n, n_rb=n_rb, n_cb=n_cb,
               col_idx=jnp.asarray(col_idx), nbr_mask=jnp.asarray(nbr_mask),
               vals=dense, fill=fill, max_nbr=m)


def patch_bsr(bsr: BSR, rows: np.ndarray, cols: np.ndarray,
              vals: Optional[np.ndarray], touched_rb: np.ndarray) -> BSR:
    """Rebuild only the ``touched_rb`` row-blocks of ``bsr`` from the (full,
    cluster-order) COO ``(rows, cols, vals)``; every other row-block's
    stored tiles are reused as-is (plan refresh patches migrated rows
    without paying a full :func:`build_bsr`).

    The ELL shape is pinned: raises ``ValueError`` when a patched row-block
    needs more than ``bsr.max_nbr`` tile slots — callers escalate to a full
    rebuild in that case. Maintains the layout invariants (superblock-major
    tile lists, zero padding) and recomputes ``fill`` from the new totals.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnz = len(rows)
    vals = (np.ones(nnz, np.float32) if vals is None
            else np.asarray(vals, np.float32))
    touched = np.unique(np.asarray(touched_rb))
    if touched.size == 0:
        return bsr
    bs, sb, m = bsr.bs, bsr.sb, bsr.max_nbr
    if touched.min(initial=0) < 0 or touched.max(initial=0) >= bsr.n_rb:
        raise ValueError(f"touched_rb out of range for n_rb={bsr.n_rb}")

    rb_all = rows // bs
    sel = np.isin(rb_all, touched)
    r_t, c_t, v_t = rows[sel], cols[sel], vals[sel]
    rb, cb = r_t // bs, c_t // bs

    # dense slot of every touched row-block (row-block id -> 0..t-1)
    slot_of_rb = np.full(bsr.n_rb, -1, np.int64)
    slot_of_rb[touched] = np.arange(touched.size)
    col_rows = np.zeros((touched.size, m), np.int32)
    mask_rows = np.zeros((touched.size, m), bool)

    # unique tiles keyed (row-block, superblock-major column): np.unique
    # yields every touched row's tile list already in schedule order
    skey = (cb // sb).astype(np.int64) * bsr.n_cb + cb
    span = np.int64(bsr.n_cb) * ((bsr.n_cb + sb - 1) // sb + 1)
    uniq = np.unique(rb.astype(np.int64) * span + skey)
    urow = slot_of_rb[uniq // span]               # 0..t-1, sorted runs
    ucol = (uniq % span) % bsr.n_cb
    counts = np.bincount(urow, minlength=touched.size)
    if counts.max(initial=0) > m:
        raise ValueError(
            f"a patched row-block needs {counts.max()} tile slots, "
            f"max_nbr={m} — rebuild the BSR")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    uslot = np.arange(len(uniq)) - starts[urow]   # rank within its row
    col_rows[urow, uslot] = ucol
    mask_rows[urow, uslot] = True

    # route every selected edge to its tile's slot by bisecting the
    # sorted unique-tile keys (no per-edge python); the tiles themselves
    # are dressed on device below — no host tile staging
    pos = np.searchsorted(uniq, rb.astype(np.int64) * span + skey)
    edges = _pad_edges(rb, uslot[pos], r_t % bs, c_t % bs, v_t, bsr.n_rb)

    kept_new = int(mask_rows.sum())
    mask_host = np.asarray(bsr.nbr_mask)
    kept_prev = int(mask_host.sum())
    kept_touched_prev = int(mask_host[touched].sum())

    # quantize the scatter width to a power of two by repeating the last
    # touched row (duplicate indices write identical content): streaming
    # updates patch a different block count every step, and without the
    # quantization each step would compile a fresh scatter kernel
    t = touched.size
    t_pad = 1 << (t - 1).bit_length()
    ti_scatter = touched
    if t_pad > t:
        ti_scatter = np.concatenate([touched,
                                     np.full(t_pad - t, touched[-1])])
        rep = (t_pad - t, 1)
        col_rows = np.concatenate([col_rows, np.tile(col_rows[-1:], rep)])
        mask_rows = np.concatenate([mask_rows, np.tile(mask_rows[-1:], rep)])

    # re-dress the patched rows on device: zero the touched row-blocks of
    # the resident tile tensor and scatter their edges into it — the
    # untouched rows (and the touched tiles themselves) never visit the
    # host
    ti = jnp.asarray(ti_scatter)
    col_idx = bsr.col_idx.at[ti].set(jnp.asarray(col_rows))
    nbr_mask = bsr.nbr_mask.at[ti].set(jnp.asarray(mask_rows))
    new_vals = _patch_tiles(bsr.vals, ti, *edges)

    kept = kept_prev - kept_touched_prev + kept_new
    fill = nnz / max(kept * bs * bs, 1)
    return BSR(bs=bs, sb=sb, n=bsr.n, n_rb=bsr.n_rb, n_cb=bsr.n_cb,
               col_idx=col_idx, nbr_mask=nbr_mask, vals=new_vals,
               fill=fill, max_nbr=m)


def append_rows(bsr: BSR, n_new: int, extra_nbr: int = 0) -> BSR:
    """Grow the (square) matrix dimension to ``n_new`` by appending empty
    row-blocks — the capacity-growth primitive of streaming plans.

    Appended rows carry no tiles (mask False, column 0, zero values), so
    they are valid tombstoned capacity until an insert dresses them via
    :func:`patch_bsr`; the ELL width (and therefore every row's slack
    headroom) is preserved, or widened by ``extra_nbr`` spare slots when
    the caller wants more append room. The column dimension grows in
    lockstep (``n_cb == n_rb``), which existing tiles are agnostic to.
    ``fill`` is unchanged: no kept tile was added or removed.
    """
    if n_new < bsr.n:
        raise ValueError(f"append_rows cannot shrink: n_new={n_new} < "
                         f"n={bsr.n} (delete + compact instead)")
    if extra_nbr < 0:
        raise ValueError(f"extra_nbr must be >= 0, got {extra_nbr}")
    n_rb2 = (n_new + bsr.bs - 1) // bsr.bs
    grow = n_rb2 - bsr.n_rb
    if grow == 0 and extra_nbr == 0:
        return BSR(bs=bsr.bs, sb=bsr.sb, n=n_new, n_rb=bsr.n_rb,
                   n_cb=bsr.n_cb, col_idx=bsr.col_idx,
                   nbr_mask=bsr.nbr_mask, vals=bsr.vals, fill=bsr.fill,
                   max_nbr=bsr.max_nbr)
    col_idx = jnp.pad(bsr.col_idx, ((0, grow), (0, extra_nbr)))
    nbr_mask = jnp.pad(bsr.nbr_mask, ((0, grow), (0, extra_nbr)))
    vals = jnp.pad(bsr.vals, ((0, grow), (0, extra_nbr), (0, 0), (0, 0)))
    return BSR(bs=bsr.bs, sb=bsr.sb, n=n_new, n_rb=n_rb2, n_cb=n_rb2,
               col_idx=col_idx, nbr_mask=nbr_mask, vals=vals,
               fill=bsr.fill, max_nbr=bsr.max_nbr + extra_nbr)


def tombstone_rows(bsr: BSR, rows: np.ndarray, cols: np.ndarray,
                   vals: Optional[np.ndarray], dead: np.ndarray):
    """Remove points ``dead`` (cluster-order indices) from the matrix:
    their rows *and* the edges referencing them as columns vanish.

    Built on :func:`patch_bsr`: the COO ``(rows, cols, vals)`` — the same
    full cluster-order pattern the BSR was built from — is filtered of
    every edge touching a dead point, and only the row-blocks that held
    such an edge are re-dressed in place; all other blocks' tiles are
    untouched device arrays. Returns ``(bsr', rows', cols', vals',
    touched_rb)`` — the filtered COO (so the caller's pattern stays in
    sync with storage) plus the row-blocks that were re-dressed (what an
    incremental shard patch scatters). Cannot overflow the ELL width
    (blocks only lose tiles), so this never escalates.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    vals = (np.ones(len(rows), np.float32) if vals is None
            else np.asarray(vals, np.float32))
    dead = np.unique(np.asarray(dead))
    if dead.size == 0:
        return bsr, rows, cols, vals, np.empty(0, np.int64)
    if dead.min(initial=0) < 0 or dead.max(initial=-1) >= bsr.n:
        raise ValueError(f"dead indices out of range for n={bsr.n}")
    drop = np.isin(rows, dead) | np.isin(cols, dead)
    r2, c2, v2 = rows[~drop], cols[~drop], vals[~drop]
    touched = np.unique(np.concatenate([rows[drop] // bsr.bs,
                                        dead // bsr.bs]))
    return patch_bsr(bsr, r2, c2, v2, touched), r2, c2, v2, touched


def random_bsr(key_seed: int, n: int, bs: int, nbr: int, *, sb: int = 8,
               banded: bool = False) -> BSR:
    """Synthetic BSR with exactly ``nbr`` dense tiles per row-block — the
    micro-benchmark matrices of paper §4.1 (banded best case vs scattered).

    ``sb`` is threaded into the stored layout: per-row tile lists are sorted
    superblock-major (ascending column order satisfies this) and every slot
    is a kept tile, so the ``bsr_ml`` schedule's superblock grouping is
    honest for these matrices too.
    """
    rng = np.random.default_rng(key_seed)
    n_rb = (n + bs - 1) // bs
    cols_list = []
    for r in range(n_rb):
        if banded:
            lo = max(0, min(r - nbr // 2, n_rb - nbr))
            c = np.arange(lo, lo + nbr)
        else:
            c = rng.choice(n_rb, size=nbr, replace=False)
            c.sort()
        cols_list.append(c)
    col_idx = np.stack(cols_list).astype(np.int32)
    vals = rng.standard_normal((n_rb, nbr, bs, bs)).astype(np.float32)
    return BSR(bs=bs, sb=sb, n=n, n_rb=n_rb, n_cb=n_rb,
               col_idx=jnp.asarray(col_idx),
               nbr_mask=jnp.ones((n_rb, nbr), bool),
               vals=jnp.asarray(vals), fill=1.0, max_nbr=nbr)
