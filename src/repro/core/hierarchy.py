"""Hierarchical partitioning of embedded points with an adaptive 2^d tree.

Paper §2.4 "Hierarchical partitioning": in the d-dimensional embedding space
we partition points with an adaptive 2^d-tree (quadtree for d=2, octree for
d=3). The depth-first leaf order of such a tree is exactly the Morton
(Z-curve) order of the quantized coordinates, so the *ordering* is computed
as an argsort of Morton codes (jit-friendly); the *tree* (level boundaries,
used for multi-level blocking) is recovered from code prefixes.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np


def _part1by1(v: jax.Array) -> jax.Array:
    """Spread bits of a 16-bit int so there is one 0 between each (for d=2)."""
    v = v & 0xFFFF
    v = (v | (v << 8)) & 0x00FF00FF
    v = (v | (v << 4)) & 0x0F0F0F0F
    v = (v | (v << 2)) & 0x33333333
    v = (v | (v << 1)) & 0x55555555
    return v


def _part1by2(v: jax.Array) -> jax.Array:
    """Spread bits of a 10-bit int so there are two 0s between each (d=3)."""
    v = v & 0x3FF
    v = (v | (v << 16)) & 0x030000FF
    v = (v | (v << 8)) & 0x0300F00F
    v = (v | (v << 4)) & 0x030C30C3
    v = (v | (v << 2)) & 0x09249249
    return v


MAX_BITS = {1: 30, 2: 16, 3: 10}   # per-dim resolution cap (32-bit codes)


def eff_bits(d: int, bits: int = 0) -> int:
    """Per-dim quantization bits actually used for dimension ``d``."""
    return min(bits or MAX_BITS[d], MAX_BITS[d])


def _interleave(q: jax.Array, d: int) -> jax.Array:
    if d == 1:
        return q[:, 0]
    if d == 2:
        return _part1by1(q[:, 0]) | (_part1by1(q[:, 1]) << 1)
    if d == 3:
        return (_part1by2(q[:, 0])
                | (_part1by2(q[:, 1]) << 1)
                | (_part1by2(q[:, 2]) << 2))
    raise ValueError(f"morton codes support d<=3, got d={d}")


@functools.partial(jax.jit, static_argnames=("bits",))
def morton_codes(y: jax.Array, bits: int = 0) -> jax.Array:
    """Morton codes for points ``y`` (N, d) with d in {1, 2, 3}.

    Coordinates are min-max quantized to ``bits`` bits per dimension
    (default: the maximum that fits a 32-bit code: 30/16/10 for d=1/2/3).
    """
    n, d = y.shape
    lo = jnp.min(y, axis=0, keepdims=True)
    hi = jnp.max(y, axis=0, keepdims=True)
    return morton_codes_box(y, lo, hi, bits)


@functools.partial(jax.jit, static_argnames=("bits",))
def morton_codes_box(y: jax.Array, lo: jax.Array, hi: jax.Array,
                     bits: int = 0) -> jax.Array:
    """Morton codes quantized against an *explicit* bounding box.

    Cell identity is only comparable between two point sets when both are
    quantized against the same box — the refresh migration detector codes
    the old and new coordinates jointly through this. Points outside the
    box clip to the boundary cells.
    """
    n, d = y.shape
    b = eff_bits(d, bits)
    span = jnp.maximum(hi - lo, 1e-30)
    q = jnp.clip((y - lo) / span * (2**b - 1), 0, 2**b - 1
                 ).astype(jnp.uint32)
    return _interleave(q, d)


@functools.partial(jax.jit, static_argnames=("bits",))
def morton_order(y: jax.Array, bits: int = 0) -> jax.Array:
    """Permutation placing points in 2^d-tree depth-first (Z-curve) order."""
    return jnp.argsort(morton_codes(y, bits))


@dataclass
class Tree:
    """Adaptive 2^d tree over Morton-sorted points.

    ``levels[l]`` is an int array of leaf/cluster boundaries (prefix sums of
    cluster sizes) at level ``l``; level 0 is the root (single cluster).
    ``perm`` maps sorted position -> original point index.
    """
    perm: np.ndarray
    levels: List[np.ndarray]
    d: int
    bits: int

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    def clusters(self, level: int) -> np.ndarray:
        """Boundaries at `level` as (n_clusters+1,) offsets into perm."""
        return self.levels[level]


def tree_from_codes(codes: np.ndarray, perm: np.ndarray, d: int,
                    bits: int = 0, leaf_size: int = 64,
                    max_levels: int = 0) -> Tree:
    """Levels of the adaptive 2^d tree from per-*original-index* Morton
    ``codes`` and a permutation ``perm`` placing them in sorted order.

    Splits every cluster by successive code prefixes (= 2^d spatial
    subdivision) until clusters have at most ``leaf_size`` points; clusters
    already small enough are not split further (adaptivity).
    """
    codes = np.asarray(codes)[perm]
    n = len(codes)
    bits_eff = eff_bits(d, bits)
    total_bits = d * bits_eff
    max_levels = max_levels or bits_eff   # default: full quantization depth

    levels = [np.array([0, n])]
    for level in range(1, max_levels + 1):
        shift = max(total_bits - level * d, 0)
        prev = levels[-1]
        bounds = [0]
        for c in range(len(prev) - 1):
            lo, hi = int(prev[c]), int(prev[c + 1])
            if hi - lo <= leaf_size:      # adaptive: leave small clusters be
                bounds.append(hi)
                continue
            seg = codes[lo:hi] >> shift
            # boundaries where the level-prefix changes
            cut = np.nonzero(np.diff(seg))[0] + 1 + lo
            bounds.extend(cut.tolist())
            bounds.append(hi)
        nxt = np.unique(np.array(bounds))
        levels.append(nxt)
        sizes = np.diff(nxt)
        if sizes.max(initial=0) <= leaf_size or shift == 0:
            break
    return Tree(perm=perm, levels=levels, d=d, bits=bits)


def build_tree(y: np.ndarray, bits: int = 0, leaf_size: int = 64,
               max_levels: int = 0) -> Tree:
    """Adaptive hierarchical partition (paper §2.4). Preprocessing runs in
    numpy: the tree is built once per reordering, like the paper's."""
    y = np.asarray(y)
    n, d = y.shape
    codes = np.asarray(morton_codes(jnp.asarray(y), bits))
    perm = np.argsort(codes, kind="stable")
    return tree_from_codes(codes, perm, d, bits, leaf_size, max_levels)


def insertion_positions(codes_in_order: np.ndarray,
                        new_codes: np.ndarray) -> np.ndarray:
    """Cluster-order positions where new Morton codes belong.

    ``codes_in_order`` are the existing points' codes *in cluster order*
    (``codes[pi]``). A freshly built ordering lists them non-decreasing,
    but a streamed lineage drifts: tombstoned slots keep their last
    point's code and patch-tier refreshes leave moved points in place. The
    monotone envelope (running max) restores a sorted key that still
    tracks the leaf structure, so ``searchsorted`` lands each new code at
    the position of the leaf cell it falls into — the streaming insert
    then claims the nearest *free* slot to that position. Positions are a
    locality heuristic, never a correctness requirement.
    """
    codes_in_order = np.asarray(codes_in_order)
    if codes_in_order.size == 0:
        return np.zeros(len(np.asarray(new_codes)), np.int64)
    env = np.maximum.accumulate(codes_in_order)
    return np.searchsorted(env, np.asarray(new_codes)).astype(np.int64)


def rebucket(y_new: np.ndarray, prev: Tree, leaf_size: int = 64,
             max_levels: int = 0) -> Tree:
    """Incremental re-bucket for moved points (plan refresh).

    Reuses the previous tree's dimensionality/resolution and re-sorts the
    *new* Morton codes stably with the previous leaf order as tiebreak —
    points that stayed in their cell keep their relative order (so the
    downstream reordered pattern changes only where points migrated), while
    migrated points slot into their new cells. Levels are recomputed from
    the code prefixes (cheap numpy; no re-embedding, no code re-fit).
    """
    y_new = np.asarray(y_new)
    codes = np.asarray(morton_codes(jnp.asarray(y_new), prev.bits))
    order = np.argsort(codes[prev.perm], kind="stable")
    perm = np.asarray(prev.perm)[order]
    return tree_from_codes(codes, perm, prev.d, prev.bits, leaf_size,
                           max_levels)
