"""Core library: the paper's contribution as composable JAX modules.

  embedding    PCA principal-axis embedding (paper §2.4 step 1)
  hierarchy    Morton codes + adaptive 2^d tree (step 2)
  ordering     the orderings compared in the paper (§4.3)
  measures     patch-density beta estimate + gamma score (§2.2-2.3)
  knn          blocked exact kNN graph (the interaction pattern, Eq. 1)
  blocksparse  two-level ELL-BSR storage (step 3)
  interact     multi-level block-segment interactions (step 4)
  dist         shard_map row-block-sharded SpMV
  clusterkv    the pipeline as an LM attention backend (DESIGN.md §3)
  registry     pluggable SpMV backend registry (csr/bsr/bsr_ml/pallas/dist)
  autotune     backend autotuning (plan backend="auto") + attention budget

The stages compose into one object through ``repro.api.build_plan``.
"""
from repro.core import (blocksparse, clusterkv, dist, embedding, hierarchy,
                        interact, knn, measures, ordering, registry)

__all__ = ["blocksparse", "clusterkv", "dist", "embedding", "hierarchy",
           "interact", "knn", "measures", "ordering", "registry"]
