"""Blocked exact k-nearest-neighbor graph construction in JAX.

Builds the paper's near-neighbor interaction pattern (Eq. 1): column j is a
near neighbor of row i iff s_j is among the k nearest sources to target t_i.
Distances are computed block-by-block (lax.scan over query blocks) so memory
stays O(block * N) rather than O(N^2).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "block", "exclude_self"))
def knn_graph(targets: jax.Array, sources: jax.Array, k: int,
              block: int = 1024, exclude_self: bool = False,
              valid: jax.Array | None = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Exact kNN of each target among sources.

    Returns ``(idx (M, k), dist2 (M, k))``, squared euclidean distances,
    ascending. With ``exclude_self`` the diagonal (i == j) is excluded
    (source and target sets are the same point set). ``valid`` (N,) bool
    restricts candidates to the masked sources — streaming plans hold
    tombstoned points in their physical source buffer, and a dead slot
    must never be picked as a neighbor.
    """
    m, d = targets.shape
    n = sources.shape[0]
    pad = (-m) % block
    tp = jnp.pad(targets, ((0, pad), (0, 0)))
    s_norm = jnp.sum(sources.astype(jnp.float32) ** 2, axis=1)

    def body(_, tb):
        qb, base = tb
        q32 = qb.astype(jnp.float32)
        d2 = (jnp.sum(q32**2, axis=1)[:, None] + s_norm[None, :]
              - 2.0 * q32 @ sources.astype(jnp.float32).T)
        if exclude_self:
            rows = base + jnp.arange(qb.shape[0])
            d2 = d2 + (rows[:, None] == jnp.arange(n)[None, :]) * jnp.inf
        if valid is not None:
            d2 = jnp.where(valid[None, :], d2, jnp.inf)
        neg, idx = jax.lax.top_k(-d2, k)
        return None, (idx, -neg)

    blocks = tp.reshape(-1, block, d)
    bases = jnp.arange(blocks.shape[0]) * block
    _, (idx, dist2) = jax.lax.scan(body, None, (blocks, bases))
    idx = idx.reshape(-1, k)[:m]
    dist2 = jnp.maximum(dist2.reshape(-1, k)[:m], 0.0)
    return idx, dist2


def knn_coo(targets: jax.Array, sources: jax.Array, k: int,
            block: int = 1024, exclude_self: bool = False,
            valid: jax.Array | None = None):
    """kNN graph as COO (rows, cols, dist2) arrays, row-major."""
    idx, dist2 = knn_graph(targets, sources, k, block, exclude_self, valid)
    m = idx.shape[0]
    rows = jnp.repeat(jnp.arange(m), k)
    return rows, idx.reshape(-1), dist2.reshape(-1)
