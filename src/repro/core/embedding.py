"""Low-dimensional embedding with data-specific principal feature axes.

Paper §2.4 "Low-dimensional embedding": an economic truncated SVD/PCA onto
the top-d principal axes of the (centered) feature array. We use subspace
(block power) iteration — d matvec-sweeps per iteration, never forming the
full SVD — which is the "economic-sparse version" the paper calls for.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("d", "iters"))
def pca_axes(x: jax.Array, d: int, iters: int = 8, key: jax.Array | None = None
             ) -> Tuple[jax.Array, jax.Array]:
    """Top-``d`` principal axes of ``x`` (N, D).

    Returns ``(axes (D, d), explained (d,))`` where ``explained`` holds the
    singular values of the centered data restricted to the subspace, so the
    paper's distortion-tolerance ratio sum(sigma_i^2)/||X||_F^2 is available
    cheaply (without all D singular values).
    """
    n, dim = x.shape
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    if key is None:
        key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (dim, d), dtype=xc.dtype)
    q, _ = jnp.linalg.qr(q)

    def body(q, _):
        z = xc.T @ (xc @ q)             # (D, d): one subspace-iteration sweep
        q, _ = jnp.linalg.qr(z)
        return q, None

    q, _ = jax.lax.scan(body, q, None, length=iters)
    # Rayleigh-Ritz for singular values in the subspace
    b = xc @ q                           # (N, d)
    s = jnp.sqrt(jnp.sum(b * b, axis=0))
    order = jnp.argsort(-s)
    return q[:, order], s[order]


def explained_ratio(x: jax.Array, s: jax.Array) -> jax.Array:
    """Paper's tolerance ratio: sum_i sigma_i^2 / ||X||_F^2 (centered)."""
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    return jnp.sum(s**2) / jnp.sum(xc * xc)


@functools.partial(jax.jit, static_argnames=("d", "iters"))
def embed(x: jax.Array, d: int, iters: int = 8,
          key: jax.Array | None = None) -> jax.Array:
    """Project ``x`` (N, D) onto its top-``d`` principal axes -> (N, d)."""
    axes, _ = pca_axes(x, d, iters, key)
    xc = x - jnp.mean(x, axis=0, keepdims=True)
    return xc @ axes


def pca_map(x: jax.Array, d: int, iters: int = 8
            ) -> Tuple[jax.Array, jax.Array]:
    """The affine embedding map itself: ``(mean (D,), axes (D, d))``.

    ``apply_pca_map(x, mean, axes) == embed(x, d)`` for the fitting data;
    plans store the map so that *moved* points re-embed into the same
    coordinate frame (refresh migration detection needs comparable cells).
    """
    axes, _ = pca_axes(x, d, iters)
    return jnp.mean(x, axis=0), axes


def apply_pca_map(x: jax.Array, mean: jax.Array, axes: jax.Array
                  ) -> jax.Array:
    """Project ``x`` with a previously fitted :func:`pca_map`."""
    return (x - mean[None, :]) @ axes


def pca_project_det(x: jax.Array, d: int, iters: int = 4) -> jax.Array:
    """Top-``d`` principal projection with a deterministic start.

    Same subspace iteration as :func:`pca_axes` but seeded from the first
    ``d`` coordinate axes instead of a random key, so it is jit/vmap
    friendly with no PRNG threading — the per-head embedding step of the
    cluster-sparse attention backend (core.clusterkv) runs through this.
    """
    _, dh = x.shape
    xc = (x - jnp.mean(x, axis=0, keepdims=True)).astype(jnp.float32)
    q = jnp.eye(dh, d, dtype=jnp.float32)

    def body(q, _):
        z = xc.T @ (xc @ q)
        q, _ = jnp.linalg.qr(z)
        return q, None

    q, _ = jax.lax.scan(body, q, None, length=iters)
    return xc @ q
