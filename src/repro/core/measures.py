"""Patch-density measures (paper §2.2–2.3).

``beta_estimate``  — lower bound of the combinatorial patch-density measure
    beta(A) (Eq. 2) obtained from a family of feasible patch coverings:
    uniform b x b grid tiles shrunk to the bounding box of their nonzeros
    (disjoint by construction), maximized over b. Exact beta is NP-hard
    (paper §2.3); any feasible covering lower-bounds it.

``gamma_exact`` / ``gamma_score`` — the numerical relaxation (Eq. 4):
    gamma(A; sigma) = 1/(sigma nnz) * sum_{p,q in Inz} exp(-|p-q|^2/sigma^2).
    ``gamma_exact`` is the O(nnz^2) literal sum; ``gamma_score`` bins the
    nonzero coordinates into sigma-sized cells and evaluates the double sum
    by a truncated Gaussian stencil convolution — O(nnz + cells) with error
    only from within-cell quantization.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# gamma score (Eq. 4)
# ---------------------------------------------------------------------------


@jax.jit
def _gamma_exact_dense(rows: jax.Array, cols: jax.Array,
                       sigma: float) -> jax.Array:
    p = jnp.stack([rows, cols], axis=1).astype(jnp.float32)
    d2 = jnp.sum((p[:, None, :] - p[None, :, :]) ** 2, axis=-1)
    return jnp.sum(jnp.exp(-d2 / sigma**2)) / (sigma * rows.shape[0])


def gamma_exact(rows: jax.Array, cols: jax.Array, sigma: float,
                bn: int = 256,
                tiled: "bool | None" = None) -> jax.Array:
    """Exact Eq. 4 over all nnz^2 pairs.

    Small patterns evaluate the literal dense (nnz, nnz) sum; large ones
    route to the tiled Pallas kernel (``kernels.ops.gamma_exact``), whose
    working set is O(bn^2) instead of O(nnz^2). ``tiled`` forces the
    choice (None = auto at nnz > 2048; auto never picks the kernel for a
    traced ``sigma``, which the kernel needs static).
    """
    nnz = rows.shape[0]
    if nnz == 0:                             # empty pattern: no mass, not NaN
        return jnp.float32(0.0)
    sigma_static = not isinstance(sigma, jax.core.Tracer)
    if tiled is None:
        tiled = nnz > 2048 and sigma_static
    if tiled:
        from repro.kernels.ops import gamma_exact as _tiled_gamma
        return _tiled_gamma(rows, cols, float(sigma), bn)
    return _gamma_exact_dense(rows, cols, sigma)


def _gauss_stencil(sigma: float, cell: float, radius_cells: int) -> jax.Array:
    r = radius_cells
    ax = jnp.arange(-r, r + 1, dtype=jnp.float32) * cell
    d2 = ax[:, None] ** 2 + ax[None, :] ** 2
    return jnp.exp(-d2 / sigma**2)


@functools.partial(jax.jit,
                   static_argnames=("sigma", "n", "cells", "radius_cells"))
def gamma_score(rows: jax.Array, cols: jax.Array, sigma: float, n: int,
                cells: int = 0, radius_cells: int = 4,
                weights: jax.Array | None = None) -> jax.Array:
    """Histogram/convolution estimate of Eq. 4.

    Bins nonzeros into a (G, G) grid with cell size ~sigma (so the Gaussian
    is well resolved), then sum_{p,q} exp ~= <h, g * h> with g the truncated
    stencil. ``weights`` (same length as rows) lets callers pad the edge
    arrays to a quantized length with zero-weight entries — the score is
    bit-identical to the unpadded call, but repeated evaluations over a
    drifting nnz (the streaming γ guard) reuse one compiled kernel instead
    of re-tracing per edge count.
    """
    nnz = rows.shape[0]
    if nnz == 0:                             # empty pattern: no mass, not NaN
        return jnp.float32(0.0)
    g = cells or max(8, min(2048, int(np.ceil(n / max(sigma, 1.0)))))
    cell = n / g
    ri = jnp.clip((rows.astype(jnp.float32) / cell).astype(jnp.int32), 0, g - 1)
    ci = jnp.clip((cols.astype(jnp.float32) / cell).astype(jnp.int32), 0, g - 1)
    w = jnp.float32(1.0) if weights is None else weights
    hist = jnp.zeros((g, g), jnp.float32).at[ri, ci].add(w)
    denom = jnp.float32(nnz) if weights is None else jnp.sum(weights)
    stencil = _gauss_stencil(sigma, cell, radius_cells)
    smooth = jax.lax.conv_general_dilated(
        hist[None, None], stencil[None, None],
        window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))[0, 0]
    return jnp.sum(hist * smooth) / (sigma * denom)


# ---------------------------------------------------------------------------
# beta estimate (Eq. 2 lower bound from feasible grid coverings)
# ---------------------------------------------------------------------------


def beta_estimate(rows: np.ndarray, cols: np.ndarray, n: int,
                  block_sizes=(4, 8, 16, 20, 32, 64, 128)) -> dict:
    """Best feasible patch covering over a family of shrunk grid coverings.

    For each tile size b: tiles of the uniform b-grid that contain nonzeros
    become patches, each shrunk to the bounding box of its nonzeros (still
    disjoint). score(b) = (1/count) * nnz / sum(bbox areas). Returns the max
    and the per-b scores.
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    nnz = len(rows)
    if nnz == 0:
        return {"beta": 0.0, "block": None, "per_block": {}}
    out = {}
    best = 0.0
    best_b = None
    for b in block_sizes:
        if b > n:
            continue
        rb, cb = rows // b, cols // b
        tid = rb.astype(np.int64) * ((n + b - 1) // b) + cb
        order = np.argsort(tid, kind="stable")
        tid_s = tid[order]
        bnd = np.concatenate([[0], np.nonzero(np.diff(tid_s))[0] + 1, [nnz]])
        count = len(bnd) - 1
        r_s, c_s = rows[order], cols[order]
        area = 0
        for t in range(count):
            lo, hi = bnd[t], bnd[t + 1]
            rr = r_s[lo:hi]
            cc = c_s[lo:hi]
            area += (rr.max() - rr.min() + 1) * (cc.max() - cc.min() + 1)
        score = (1.0 / count) * nnz / area
        out[b] = score
        if score > best:
            best, best_b = score, b
    return {"beta": best, "block": best_b, "per_block": out}


def fill_ratio(rows: np.ndarray, cols: np.ndarray, n: int, b: int) -> float:
    """nnz / area of the uniform-b covering — density of the kept tiles.

    An empty pattern covers no tiles: its fill is 0 (never a division by
    zero — drift monitoring polls this on arbitrary patched patterns).
    """
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    if len(rows) == 0:
        return 0.0
    rb, cb = rows // b, cols // b
    tid = rb.astype(np.int64) * ((n + b - 1) // b) + cb
    count = len(np.unique(tid))
    return len(rows) / (count * b * b)


def compact_live(rows: np.ndarray, cols: np.ndarray,
                 alive_in_order: np.ndarray):
    """Project a cluster-order pattern onto the live rows only.

    Streaming plans hold tombstoned slots between compactions, so their
    cluster positions have holes; scoring γ on the holey coordinates
    would misread the hole spacing as (lack of) locality and make the
    score incomparable with a fresh build over the surviving points.
    Drops every edge touching a dead slot (defensive — the maintained COO
    should already be live-only) and renumbers both coordinates to the
    rank among live slots. Returns ``(rows', cols', n_alive)``.
    """
    alive_in_order = np.asarray(alive_in_order, bool)
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    keep = alive_in_order[rows] & alive_in_order[cols]
    rank = np.cumsum(alive_in_order) - 1
    return rank[rows[keep]], rank[cols[keep]], int(alive_in_order.sum())


# ---------------------------------------------------------------------------
# drift monitoring (plan refresh lifecycle)
# ---------------------------------------------------------------------------


def gamma_drift(gamma_ref: "float | None",
                gamma_now: "float | None") -> float:
    """Relative γ degradation since ``gamma_ref`` (positive = locality got
    worse). Returns 0 when either score is missing or the reference is 0,
    so drift checks are safe on unscored / empty / single-block plans."""
    if gamma_ref is None or gamma_now is None or gamma_ref == 0:
        return 0.0
    return float((gamma_ref - gamma_now) / abs(gamma_ref))


def fill_drift(fill_ref: "float | None", fill_now: "float | None") -> float:
    """Relative fill degradation since ``fill_ref`` (positive = storage got
    emptier). Same None/zero-safety as :func:`gamma_drift`."""
    if fill_ref is None or fill_now is None or fill_ref == 0:
        return 0.0
    return float((fill_ref - fill_now) / abs(fill_ref))
