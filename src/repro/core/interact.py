"""Multi-level near-neighbor interaction computations (paper §2.4).

The interaction ``y = A x`` is computed block-by-block: every kept tile is a
dense (bs, bs) block multiplying a contiguous charge segment — the paper's
"block-segment multiplication". The low-level paths live here and are
published through the backend registry (``repro.core.registry``) under the
names ``csr`` / ``bsr`` / ``bsr_ml``; prefer ``repro.api`` plans over
calling them directly:

  spmv_csr      element-wise gather baseline (scattered/CSR semantics)
  spmv_bsr      flat single-level block path (one einsum over kept tiles)
  spmv_bsr_ml   multi-level path: lax.scan over row-superblocks so the
                working set per step is a superblock stripe (the TPU analog
                of the paper's multi-level cache blocking)
  spmv_pallas   Pallas kernel (kernels/bsr_spmv.py) — MXU tiles with
                scalar-prefetch column indices; registered as ``pallas``
                by kernels/ops.py

Iterative-application value updates (t-SNE attractive force, mean shift) are
computed *blockwise dense* from the current coordinates — the TPU-native
replacement for per-edge gathers (DESIGN.md §2).
"""
from __future__ import annotations

import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.blocksparse import BSR
from repro.core.registry import register_backend


# ---------------------------------------------------------------------------
# SpMV paths
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_csr(vals: jax.Array, rows: jax.Array, cols: jax.Array,
             x: jax.Array, n: int | None = None) -> jax.Array:
    """Gather-based SpMV over COO/CSR edges: y_i = sum_j a_ij x_j."""
    n = n if n is not None else x.shape[0]
    return jnp.zeros((n,) + x.shape[1:], x.dtype).at[rows].add(
        vals[(...,) + (None,) * (x.ndim - 1)] * x[cols])


def _pad_x(x: jax.Array, n_cb: int, bs: int) -> jax.Array:
    pad = n_cb * bs - x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_bsr(bsr_vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             n: int) -> jax.Array:
    """Flat block path. bsr_vals (n_rb, nbr, bs, bs); x (n,) or (n, f)."""
    n_rb, nbr, bs, _ = bsr_vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    xp = _pad_x(x, n_rb, bs)
    xb = xp.reshape(n_rb, bs, -1)                       # (n_cb, bs, f)
    seg = xb[col_idx]                                   # (n_rb, nbr, bs, f)
    y = jnp.einsum("rnij,rnjf->rif", bsr_vals, seg)
    y = y.reshape(n_rb * bs, -1)[:n]
    return y[:, 0] if squeeze else y


@functools.partial(jax.jit, static_argnames=("n", "sb"))
def spmv_bsr_ml(bsr_vals: jax.Array, col_idx: jax.Array, x: jax.Array,
                n: int, sb: int = 8) -> jax.Array:
    """Multi-level block path: scan over row-superblocks (stripes of ``sb``
    row-blocks); each step touches only that stripe's tiles + segments."""
    n_rb, nbr, bs, _ = bsr_vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    pad_rb = (-n_rb) % sb
    if pad_rb:
        bsr_vals = jnp.pad(bsr_vals, ((0, pad_rb), (0, 0), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, pad_rb), (0, 0)))
    xp = _pad_x(x, n_rb, bs)
    xb = xp.reshape(n_rb, bs, -1)

    v = bsr_vals.reshape(-1, sb, nbr, bs, bs)
    c = col_idx.reshape(-1, sb, nbr)

    def step(_, vc):
        vt, ct = vc
        seg = xb[ct]                                    # (sb, nbr, bs, f)
        return None, jnp.einsum("rnij,rnjf->rif", vt, seg)

    _, ys = jax.lax.scan(step, None, (v, c))
    y = ys.reshape(-1, bs, ys.shape[-1]).reshape(-1, ys.shape[-1])[:n]
    return y[:, 0] if squeeze else y


# -- registry backends (plan, x) -> y, cluster index space ------------------


@register_backend("csr")
def _csr_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    """Per-edge gather baseline over the plan's reordered COO pattern."""
    rows, cols, vals = plan.coo_device()
    return spmv_csr(vals, rows, cols, x, plan.n)


@register_backend("bsr")
def _bsr_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    b = plan.bsr
    return spmv_bsr(b.vals, b.col_idx, x, plan.n)


@register_backend("bsr_ml")
def _bsr_ml_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    b = plan.bsr
    return spmv_bsr_ml(b.vals, b.col_idx, x, plan.n, b.sb)


def spmv(bsr: BSR, x: jax.Array, path: str = "bsr") -> jax.Array:
    """Deprecated shim: string-dispatched SpMV over a bare BSR.

    Use ``repro.api.build_plan(...).apply(x, backend=...)`` — any registered
    backend name works here too (``csr`` excepted: a bare BSR has no COO).
    """
    warnings.warn("interact.spmv(bsr, x, path) is deprecated; use "
                  "repro.api plans and the backend registry",
                  DeprecationWarning, stacklevel=2)
    from repro.api import InteractionPlan
    from repro.core.registry import get_backend
    return get_backend(path)(InteractionPlan.from_bsr(bsr), x)


# ---------------------------------------------------------------------------
# Iterative applications: blockwise-dense value recomputation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def tsne_attractive(p_vals: jax.Array, col_idx: jax.Array, nbr_mask: jax.Array,
                    y: jax.Array, n: int) -> jax.Array:
    """t-SNE attractive force (paper §3.1), blockwise.

    F_i = sum_j p_ij q_ij (y_i - y_j), q_ij = 1/(1 + |y_i - y_j|^2), with
    p the (fixed-profile) kNN-based affinity stored as dense tiles. Values
    p_ij q_ij are recomputed dense per tile from the current embedding y.
    """
    n_rb, nbr, bs, _ = p_vals.shape
    d = y.shape[1]
    yp = _pad_x(y, n_rb, bs).reshape(n_rb, bs, d)
    ysrc = yp[col_idx]                                   # (n_rb, nbr, bs, d)
    ytgt = yp[:, None, :, None, :]                       # (n_rb, 1, bs, 1, d)
    diff = ytgt - ysrc[:, :, None, :, :]                 # (n_rb, nbr, bs_t, bs_s, d)
    q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=-1))
    w = p_vals * q                       # p tile is (target, source) = (t, s)
    f = jnp.einsum("rnts,rntsd->rtd", w, diff)
    return f.reshape(-1, d)[:n]


@functools.partial(jax.jit, static_argnames=("h2", "n"))
def meanshift_step(w_pattern: jax.Array, col_idx: jax.Array,
                   sources_blocked: jax.Array, t: jax.Array,
                   h2: float, n: int) -> jax.Array:
    """One mean-shift iteration (paper §3.2), blockwise.

    New mean m_i = sum_j w_ij s_j / sum_j w_ij with Gaussian weights
    w_ij = exp(-|t_i - s_j|^2 / h2) over the (fixed) neighbor pattern;
    weights are recomputed dense per tile from current targets t.
    ``w_pattern`` (n_rb, nbr, bs, bs) is the 0/1 neighbor-pattern tile.
    ``sources_blocked`` (n_cb, bs, d) are sources in cluster order.
    """
    n_rb, nbr, bs, _ = w_pattern.shape
    d = t.shape[1]
    tp = _pad_x(t, n_rb, bs).reshape(n_rb, bs, d)
    s = sources_blocked[col_idx]                         # (n_rb, nbr, bs, d)
    diff = tp[:, None, :, None, :] - s[:, :, None, :, :]
    w = jnp.exp(-jnp.sum(diff * diff, axis=-1) / h2) * w_pattern
    num = jnp.einsum("rnts,rnsd->rtd", w, s)
    den = jnp.sum(w, axis=(1, 3))[..., None]
    m = num / jnp.maximum(den, 1e-12)
    return m.reshape(-1, d)[:n]
