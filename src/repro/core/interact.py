"""Multi-level near-neighbor interaction computations (paper §2.4).

The interaction ``y = A x`` is computed block-by-block: every kept tile is a
dense (bs, bs) block multiplying a contiguous charge segment — the paper's
"block-segment multiplication". The low-level paths live here and are
published through the backend registry (``repro.core.registry``) under the
names ``csr`` / ``bsr`` / ``bsr_ml``; prefer ``repro.api`` plans over
calling them directly:

  spmv_csr      element-wise gather baseline (scattered/CSR semantics)
  spmv_bsr      flat single-level block path (one einsum over kept tiles)
  spmv_bsr_ml   multi-level path: lax.scan over row-superblocks so the
                working set per step is a superblock stripe (the TPU analog
                of the paper's multi-level cache blocking)
  spmv_pallas   Pallas kernel (kernels/bsr_spmv.py) — MXU tiles with
                scalar-prefetch column indices; registered as ``pallas``
                by kernels/ops.py

Iterative-application value updates (t-SNE attractive force, mean shift) are
computed *blockwise dense* from the current coordinates — the TPU-native
replacement for per-edge gathers (DESIGN.md §2).
"""
from __future__ import annotations

import functools
import warnings
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.blocksparse import BSR
from repro.core.registry import register_backend, register_batched_backend


# ---------------------------------------------------------------------------
# SpMV paths
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_csr(vals: jax.Array, rows: jax.Array, cols: jax.Array,
             x: jax.Array, n: int | None = None) -> jax.Array:
    """Gather-based SpMV over COO/CSR edges: y_i = sum_j a_ij x_j."""
    n = n if n is not None else x.shape[0]
    return jnp.zeros((n,) + x.shape[1:], x.dtype).at[rows].add(
        vals[(...,) + (None,) * (x.ndim - 1)] * x[cols])


def _pad_x(x: jax.Array, n_cb: int, bs: int) -> jax.Array:
    pad = n_cb * bs - x.shape[0]
    if pad:
        x = jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1))
    return x


@functools.partial(jax.jit, static_argnames=("n",))
def spmv_bsr(bsr_vals: jax.Array, col_idx: jax.Array, x: jax.Array,
             n: int) -> jax.Array:
    """Flat block path. bsr_vals (n_rb, nbr, bs, bs); x (n,) or (n, f)."""
    n_rb, nbr, bs, _ = bsr_vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    xp = _pad_x(x, n_rb, bs)
    xb = xp.reshape(n_rb, bs, -1)                       # (n_cb, bs, f)
    seg = xb[col_idx]                                   # (n_rb, nbr, bs, f)
    y = jnp.einsum("rnij,rnjf->rif", bsr_vals, seg)
    y = y.reshape(n_rb * bs, -1)[:n]
    return y[:, 0] if squeeze else y


@functools.partial(jax.jit, static_argnames=("n", "sb"))
def spmv_bsr_ml(bsr_vals: jax.Array, col_idx: jax.Array, x: jax.Array,
                n: int, sb: int = 8) -> jax.Array:
    """Multi-level block path: scan over row-superblocks (stripes of ``sb``
    row-blocks); each step touches only that stripe's tiles + segments."""
    n_rb, nbr, bs, _ = bsr_vals.shape
    squeeze = x.ndim == 1
    if squeeze:
        x = x[:, None]
    pad_rb = (-n_rb) % sb
    if pad_rb:
        bsr_vals = jnp.pad(bsr_vals, ((0, pad_rb), (0, 0), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, pad_rb), (0, 0)))
    xp = _pad_x(x, n_rb, bs)
    xb = xp.reshape(n_rb, bs, -1)

    v = bsr_vals.reshape(-1, sb, nbr, bs, bs)
    c = col_idx.reshape(-1, sb, nbr)

    def step(_, vc):
        vt, ct = vc
        seg = xb[ct]                                    # (sb, nbr, bs, f)
        return None, jnp.einsum("rnij,rnjf->rif", vt, seg)

    _, ys = jax.lax.scan(step, None, (v, c))
    y = ys.reshape(-1, bs, ys.shape[-1]).reshape(-1, ys.shape[-1])[:n]
    return y[:, 0] if squeeze else y


# -- batched paths (PlanBatch: stacked plans, one kernel) -------------------


def _flat_gather_segments(xs: jax.Array, col_idx: jax.Array,
                          bs: int) -> jax.Array:
    """Charge segments for every (lane, row-block, tile) of a batch.

    ``xs`` (B, n, f), ``col_idx`` (B, n_rb, nbr) -> (B, n_rb, nbr, bs, f).
    The naive formulation — ``vmap`` of the single-plan ``xb[col_idx]`` —
    leaves XLA a *batched* gather, which the CPU backend lowers to scalar
    loops (~10x slower than the compute it feeds). Flattening the batch
    into one segment table and offsetting the indices per lane turns it
    back into the plain row gather the single-plan path enjoys.
    """
    B = xs.shape[0]
    n_cb = (xs.shape[1] + bs - 1) // bs
    pad = n_cb * bs - xs.shape[1]
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    flat = xs.reshape(B * n_cb, bs, -1)
    idx = (col_idx + (jnp.arange(B) * n_cb)[:, None, None]).reshape(-1)
    seg = flat[idx]
    return seg.reshape(col_idx.shape + seg.shape[1:])


def _tiles_times_segments(vals: jax.Array, seg: jax.Array) -> jax.Array:
    """(..., nbr, bs, bs) tiles x (..., nbr, bs, f) segments ->
    (..., bs, f), summed over the tile slots.

    NOT an einsum: XLA lowers ``...ij,...jf`` to a dot_general whose
    preferred operand layout *transposes the whole tile tensor on every
    call* (constants get it folded once — arguments pay it each time; at
    batch sizes that copy is 10x the useful compute). The elementwise
    broadcast-multiply + reduce (f == 1) and the layout-preserving
    ``batch_matmul`` (f > 1) keep the tiles in their stored layout.
    """
    lead = vals.shape[:-3]
    nbr, bs = vals.shape[-3], vals.shape[-1]
    f = seg.shape[-1]
    if f == 1:
        y = (vals * seg[..., None, :, 0]).sum(axis=(-3, -1))
        return y[..., None]
    out = jax.lax.batch_matmul(vals.reshape(-1, bs, bs),
                               seg.reshape(-1, bs, f))
    return out.reshape(lead + (nbr, bs, f)).sum(axis=-3)


@jax.jit
def spmv_bsr_batched(vals: jax.Array, col_idx: jax.Array,
                     xs: jax.Array) -> jax.Array:
    """Flat block path over a stacked batch: ``vals`` (B, n_rb, nbr, bs,
    bs), ``xs`` (B, n) or (B, n, f); one gather + one tile contraction
    for every plan in the batch."""
    B, n_rb, nbr, bs, _ = vals.shape
    squeeze = xs.ndim == 2
    if squeeze:
        xs = xs[..., None]
    n = xs.shape[1]
    seg = _flat_gather_segments(xs, col_idx, bs)
    y = _tiles_times_segments(vals, seg)
    y = y.reshape(B, n_rb * bs, -1)[:, :n]
    return y[..., 0] if squeeze else y


@functools.partial(jax.jit, static_argnames=("sb",))
def spmv_bsr_ml_batched(vals: jax.Array, col_idx: jax.Array,
                        xs: jax.Array, sb: int = 8) -> jax.Array:
    """Multi-level batched path: scan over row-superblock stripes (every
    lane's stripe s together), flat-gathering each stripe's segments —
    the working set per step is one stripe *across the batch*."""
    B, n_rb, nbr, bs, _ = vals.shape
    squeeze = xs.ndim == 2
    if squeeze:
        xs = xs[..., None]
    n = xs.shape[1]
    pad_rb = (-n_rb) % sb
    if pad_rb:
        vals = jnp.pad(vals, ((0, 0), (0, pad_rb), (0, 0), (0, 0), (0, 0)))
        col_idx = jnp.pad(col_idx, ((0, 0), (0, pad_rb), (0, 0)))
    n_cb = (n + bs - 1) // bs
    pad = n_cb * bs - n
    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
    flat = xs.reshape(B * n_cb, bs, -1)
    off = (jnp.arange(B) * n_cb)[:, None, None]
    v = jnp.swapaxes(vals.reshape(B, -1, sb, nbr, bs, bs), 0, 1)
    c = jnp.swapaxes((col_idx + off).reshape(B, -1, sb, nbr), 0, 1)

    def step(_, vc):
        vt, ct = vc                          # (B,sb,nbr,bs,bs) (B,sb,nbr)
        seg = flat[ct.reshape(-1)].reshape(ct.shape + flat.shape[1:])
        return None, _tiles_times_segments(vt, seg)

    _, ys = jax.lax.scan(step, None, (v, c))        # (n_sb, B, sb, bs, f)
    y = jnp.swapaxes(ys, 0, 1).reshape(B, -1, ys.shape[-1])[:, :n]
    return y[..., 0] if squeeze else y


@register_batched_backend("bsr")
def _bsr_batched(spec, data, xs: jax.Array) -> jax.Array:
    return spmv_bsr_batched(data.vals, data.col_idx, xs)


@register_batched_backend("bsr_ml")
def _bsr_ml_batched(spec, data, xs: jax.Array) -> jax.Array:
    return spmv_bsr_ml_batched(data.vals, data.col_idx, xs, spec.sb)


# -- registry backends (plan, x) -> y, cluster index space ------------------


@register_backend("csr")
def _csr_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    """Per-edge gather baseline over the plan's reordered COO pattern."""
    rows, cols, vals = plan.coo_device()
    return spmv_csr(vals, rows, cols, x, plan.n)


@register_backend("bsr")
def _bsr_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    b = plan.bsr
    return spmv_bsr(b.vals, b.col_idx, x, plan.n)


@register_backend("bsr_ml")
def _bsr_ml_backend(plan, x: jax.Array, **_kw) -> jax.Array:
    b = plan.bsr
    return spmv_bsr_ml(b.vals, b.col_idx, x, plan.n, b.sb)


def spmv(bsr: BSR, x: jax.Array, path: str = "bsr") -> jax.Array:
    """Deprecated shim: string-dispatched SpMV over a bare BSR.

    Use ``repro.api.build_plan(...).matvec(x, backend=...)`` instead —
    plans carry the COO, host state, and autotune context this shim
    cannot reconstruct. ``path`` accepts any name in
    ``core.registry.backend_names()`` (``csr``/``bsr``/``bsr_ml``/
    ``pallas``/``dist``), but only the pure-storage paths work on a bare
    BSR: ``csr`` needs the plan's COO, ``dist`` needs a mesh-sharded
    plan, and ``backend="auto"`` needs the plan's structural key — all
    raise or misbehave here. See ``docs/backends.md``.
    """
    warnings.warn("interact.spmv(bsr, x, path) is deprecated; use "
                  "repro.api plans and the backend registry",
                  DeprecationWarning, stacklevel=2)
    from repro.api import InteractionPlan
    from repro.core.registry import get_backend
    return get_backend(path)(InteractionPlan.from_bsr(bsr), x)


# ---------------------------------------------------------------------------
# Iterative applications: blockwise-dense value recomputation
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n",))
def tsne_attractive(p_vals: jax.Array, col_idx: jax.Array, nbr_mask: jax.Array,
                    y: jax.Array, n: int) -> jax.Array:
    """t-SNE attractive force (paper §3.1), blockwise.

    F_i = sum_j p_ij q_ij (y_i - y_j), q_ij = 1/(1 + |y_i - y_j|^2), with
    p the (fixed-profile) kNN-based affinity stored as dense tiles. Values
    p_ij q_ij are recomputed dense per tile from the current embedding y.
    """
    n_rb, nbr, bs, _ = p_vals.shape
    d = y.shape[1]
    yp = _pad_x(y, n_rb, bs).reshape(n_rb, bs, d)
    ysrc = yp[col_idx]                                   # (n_rb, nbr, bs, d)
    ytgt = yp[:, None, :, None, :]                       # (n_rb, 1, bs, 1, d)
    diff = ytgt - ysrc[:, :, None, :, :]                 # (n_rb, nbr, bs_t, bs_s, d)
    q = 1.0 / (1.0 + jnp.sum(diff * diff, axis=-1))
    w = p_vals * q                       # p tile is (target, source) = (t, s)
    f = jnp.einsum("rnts,rntsd->rtd", w, diff)
    return f.reshape(-1, d)[:n]


@functools.partial(jax.jit, static_argnames=("h2", "n"))
def meanshift_step(w_pattern: jax.Array, col_idx: jax.Array,
                   sources_blocked: jax.Array, t: jax.Array,
                   h2: float, n: int) -> jax.Array:
    """One mean-shift iteration (paper §3.2), blockwise.

    New mean m_i = sum_j w_ij s_j / sum_j w_ij with Gaussian weights
    w_ij = exp(-|t_i - s_j|^2 / h2) over the (fixed) neighbor pattern;
    weights are recomputed dense per tile from current targets t.
    ``w_pattern`` (n_rb, nbr, bs, bs) is the 0/1 neighbor-pattern tile.
    ``sources_blocked`` (n_cb, bs, d) are sources in cluster order.
    """
    n_rb, nbr, bs, _ = w_pattern.shape
    d = t.shape[1]
    tp = _pad_x(t, n_rb, bs).reshape(n_rb, bs, d)
    s = sources_blocked[col_idx]                         # (n_rb, nbr, bs, d)
    diff = tp[:, None, :, None, :] - s[:, :, None, :, :]
    w = jnp.exp(-jnp.sum(diff * diff, axis=-1) / h2) * w_pattern
    num = jnp.einsum("rnts,rnsd->rtd", w, s)
    den = jnp.sum(w, axis=(1, 3))[..., None]
    m = num / jnp.maximum(den, 1e-12)
    return m.reshape(-1, d)[:n]
