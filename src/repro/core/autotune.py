"""Autotuning: SpMV backend selection for plans + attention budget tuning.

``tune_backend`` resolves ``backend="auto"`` for ``repro.api`` plans. Since
the analytic cost model landed (``core.costmodel``) the stopwatch no longer
decides: backends are ranked by the model's calibrated predicted seconds on
the plan's structural shape, and probes run only as *calibration* — one
measurement per backend (globally memoized in ``_CALIB`` as the
measured/modeled ratio), after which every decision is pure arithmetic on
the hardware config. Changing the hardware config (``costmodel
.set_hardware`` / ``REPRO_HW_CONFIG``) plus ``clear_tune_memo()`` therefore
changes decisions without re-probing anything. Memoized decisions store the
full machine-readable ranking report (``schema repro.cost/v1``).

The attention-budget half below reuses the paper's γ-score idea to size
the cluster-sparse attention budget.

Patch-density-guided autotuning of the cluster-sparse attention budget.

The paper's γ-score measures how much interaction mass concentrates into
dense patches under an ordering (§2.3). The same quantity tunes the LM
backend: after cluster-sorting keys, the centroid score mass captured by
the top-B key tiles per query tile is a direct coverage estimate — pick
the smallest B whose estimated coverage exceeds the target. Models with
strongly clustered keys (high patch density) get small B (fast); diffuse
ones automatically fall back toward dense attention.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ClusterKVConfig
from repro.core import clusterkv as ckv
from repro.core import costmodel
from repro.core.registry import backend_names, get_backend, \
    get_batched_backend


# ---------------------------------------------------------------------------
# SpMV backend autotuning (resolves plan backend="auto")
# ---------------------------------------------------------------------------

# structural memo of auto decisions, keyed by (shape_key, true nnz,
# charge ndim, backend set, device_count) — everything that determines
# which kernels compile plus the csr path's actual edge count; values
# are the full machine-readable ranking reports
# (costmodel.rank_backends envelopes) so a memo hit replays both the
# winner and the model's predicted seconds.
_TUNE_MEMO: Dict[tuple, dict] = {}

# calibration constants: backend name (or "batch:<name>") -> measured /
# modeled seconds ratio from ONE probe. inf marks a backend that failed or
# was skipped (interpret-mode pallas, broken probe) — excluded from
# rankings. This is the only place the stopwatch touches the decision.
_CALIB: Dict[str, float] = {}


def clear_tune_memo() -> None:
    """Drop memoized auto-backend decisions (tests / fresh measurements).
    Calibration constants survive — re-decisions stay probe-free."""
    _TUNE_MEMO.clear()


def clear_calibration() -> None:
    """Drop probe calibration constants (forces fresh measurement)."""
    _CALIB.clear()


def _skip_interpret(fn) -> bool:
    """True when ``fn`` is a Pallas backend currently running interpret
    mode — a full compile + timed Python-loop runs per probe, and it can
    never win on this hardware."""
    gate = getattr(fn, "interpret_only", None)
    return bool(callable(gate) and gate())


def probe_backends(plan, x: Optional[jax.Array] = None,
                   backends: Optional[Iterable[str]] = None,
                   warmup: int = 1, iters: int = 3,
                   atol: float = 1e-3,
                   include_interpret: bool = False) -> Dict[str, float]:
    """Median wall time (s) per registered backend on the plan's shapes.

    Backends that raise (missing COO, mesh indivisibility, ...) or disagree
    with the flat block path by more than ``atol`` max-abs are skipped —
    a fast-but-wrong backend must never win the autotune. Interpret-mode
    Pallas backends are skipped by default (they pay a compile + timed
    interpreter runs and can never win on CPU); pass
    ``include_interpret=True`` to time them anyway (tests).
    """
    if x is None:
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(plan.n), jnp.float32)
    names = tuple(backends) if backends is not None else backend_names()
    try:
        ref = np.asarray(jax.block_until_ready(get_backend("bsr")(plan, x)))
    except Exception:
        ref = None
    times: Dict[str, float] = {}
    for name in names:
        fn = get_backend(name)
        if not include_interpret and _skip_interpret(fn):
            continue
        try:
            y = np.asarray(jax.block_until_ready(fn(plan, x)))
            if ref is not None and np.abs(y - ref).max() > atol:
                continue
            for _ in range(warmup):
                jax.block_until_ready(fn(plan, x))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(plan, x))
                ts.append(time.perf_counter() - t0)
            times[name] = float(np.median(ts))
        except Exception:
            continue
    return times


def _calibrate(names: Iterable[str], feat, plan, x, *,
               interpret: bool) -> None:
    """Probe every backend in ``names`` that has no calibration constant
    yet and store measured/modeled ratios in ``_CALIB``. A backend whose
    probe fails, disagrees, or is interpret-mode Pallas calibrates to inf
    (excluded from rankings until ``clear_calibration``)."""
    missing = [n for n in names if n not in _CALIB]
    if not missing:
        return
    probed = probe_backends(plan, x, missing)
    for name in missing:
        meas = probed.get(name)
        if meas is None:
            _CALIB[name] = float("inf")
            continue
        model_s = costmodel.backend_cost(feat, name,
                                         interpret=interpret)["seconds"]
        _CALIB[name] = meas / model_s if model_s > 0 else float("inf")


def tune_backend(plan, x: Optional[jax.Array] = None,
                 backends: Optional[Iterable[str]] = None,
                 device_count: Optional[int] = None
                 ) -> Tuple[str, Dict[str, float]]:
    """Resolve ``backend="auto"`` for ``plan`` from the analytic model.

    Returns ``(name, calibrated predicted seconds per backend)``; the
    winner is the argmin of the returned dict. Falls back to ``"bsr"``
    when nothing is rankable (tracer plans, every probe failed).

    Probes are demoted to calibration: the first time a backend is seen
    it is timed once and the measured/modeled ratio memoized globally
    (``_CALIB``); every subsequent decision — any shape, any hardware
    config — is model arithmetic. ``clear_tune_memo()`` plus a changed
    hardware config therefore re-decides without re-probing.

    Device-count-aware: on a >=2-device mesh the ``dist`` path wins
    whenever it (a) calibrated healthy and (b) the exchange model prices
    its halo strictly under replication on the configured interconnect.
    Wall-clock probes on a single-host mesh (forced virtual devices,
    shared memory) mismeasure collective cost, so the model — not the
    stopwatch — decides between per-device paths; ``"dist"`` appears in
    the returned dict only when it is the decision.

    Single-device decisions are memoized on the plan's structural key
    (``PlanSpec.shape_key`` + true nnz + charge ndim + backend set); memo
    values are
    the full ranking reports. Multi-device decisions are NOT memoized:
    the dist-vs-replicate call depends on the plan's actual block
    structure (the halo analysis), which two same-shaped plans can
    disagree on.
    """
    ndev = device_count if device_count is not None else jax.device_count()
    names = tuple(backends) if backends is not None else backend_names()
    ndim = x.ndim if x is not None else 1
    concrete = plan.bsr is not None \
        and not isinstance(plan.bsr.vals, jax.core.Tracer)
    if not concrete:
        return "bsr", {}
    # true edge count (the csr path's work); plans built from_bsr have no
    # COO and fall back to the dense-equivalent estimate
    coo = getattr(plan.host, "coo", None)
    nnz = int(len(coo[0])) if coo is not None else None
    key = None
    if ndev < 2:
        key = (plan.spec.shape_key, nnz, ndim, names, ndev)
        hit = _TUNE_MEMO.get(key)
        if hit is not None:
            return hit["winner"], dict(hit["predicted_s"])
    f = x.shape[-1] if (x is not None and x.ndim == 2) else 1
    feat = costmodel.plan_features(plan.spec.shape_key, f=f, nnz=nnz)
    interp = _skip_interpret(get_backend("pallas")) \
        if "pallas" in names else False
    local = tuple(n for n in names if n != "dist")
    _calibrate(local, feat, plan, x, interpret=interp)
    if ndev >= 2 and "dist" in names and "dist" not in _CALIB:
        # dist needs a real mesh to calibrate; a failed probe marks it
        # non-viable here (e.g. indivisible shard counts)
        _calibrate(("dist",), feat, plan, x, interpret=False)
    report = costmodel.rank_backends(
        feat, local, calibration=_CALIB, interpret=interp, n_dev=ndev)
    winner = report["winner"] or "bsr"
    times = dict(report["predicted_s"])
    if ndev >= 2 and "dist" in names \
            and _CALIB.get("dist", float("inf")) != float("inf") \
            and not isinstance(plan.bsr.col_idx, jax.core.Tracer):
        from repro.core.shardplan import analyze_shards

        spec, _ = analyze_shards(plan.bsr, ndev)
        halo_s = costmodel.exchange_cost(spec.transfer_blocks, plan.bsr.bs)
        ag_s = costmodel.exchange_cost(spec.allgather_blocks, plan.bsr.bs)
        if halo_s is not None and ag_s is not None and halo_s < ag_s:
            dist_s = costmodel.backend_cost(
                feat, "dist", n_dev=ndev,
                exchange_blocks=spec.transfer_blocks)["seconds"]
            times["dist"] = _CALIB["dist"] * dist_s
            report = dict(report, winner="dist", predicted_s=times)
            winner = "dist"
    if key is not None:
        report = dict(report, winner=winner)
        _TUNE_MEMO[key] = report
    return winner, times


def tune_batch_backend(batch, x: Optional[jax.Array] = None,
                       backends: Optional[Iterable[str]] = None,
                       warmup: int = 1, iters: int = 3,
                       atol: float = 1e-3) -> Tuple[str, Dict[str, float]]:
    """One shared backend decision for a whole ``api.PlanBatch``.

    Same analytic-first shape as ``tune_backend``, but calibration runs
    the *batched* kernel itself (``api._batch_apply_kernel``) — the
    single-plan calibration does not transfer (batching changes the
    gather shapes and dispatch count), so batch backends calibrate under
    ``"batch:<name>"`` keys. Backends that fail to batch or disagree with
    the batched ``bsr`` path calibrate to inf. The decision is memoized
    on ``(batch shape_key, B, charge ndim, backend set)`` with the full
    ranking report: spec-identical batches — every construction in a
    serving loop — tune once.
    """
    from repro import api

    names = (tuple(backends) if backends is not None
             else tuple(n for n in api._BATCHED_BACKENDS
                        if n in backend_names()))
    ndim = (x.ndim - 1) if x is not None else 1
    key = ("batch", batch.spec.shape_key, batch.batch, ndim, names)
    hit = _TUNE_MEMO.get(key)
    if hit is not None:
        return hit["winner"], dict(hit["predicted_s"])
    f = x.shape[-1] if (x is not None and x.ndim == 3) else 1
    feat = costmodel.plan_features(batch.spec.shape_key, f=f,
                                   batch=batch.batch)
    interp = False
    pfn = get_batched_backend("pallas") if "pallas" in names else None
    if pfn is not None:
        interp = _skip_interpret(pfn)
    missing = [n for n in names if ("batch:" + n) not in _CALIB]
    if missing:
        if x is None:
            x = jnp.asarray(np.random.default_rng(0).standard_normal(
                (batch.batch, batch.capacity)), jnp.float32)
        try:
            ref = np.asarray(jax.block_until_ready(api._batch_apply_kernel(
                batch.spec, batch.data, x, "bsr", "apply")))
        except Exception:
            ref = None
        for name in missing:
            ckey = "batch:" + name
            bfn = get_batched_backend(name)
            if bfn is not None and _skip_interpret(bfn):
                _CALIB[ckey] = float("inf")
                continue
            try:
                y = np.asarray(jax.block_until_ready(
                    api._batch_apply_kernel(
                        batch.spec, batch.data, x, name, "apply")))
                if ref is not None and np.abs(y - ref).max() > atol:
                    _CALIB[ckey] = float("inf")
                    continue
                for _ in range(warmup):
                    jax.block_until_ready(api._batch_apply_kernel(
                        batch.spec, batch.data, x, name, "apply"))
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(api._batch_apply_kernel(
                        batch.spec, batch.data, x, name, "apply"))
                    ts.append(time.perf_counter() - t0)
                meas = float(np.median(ts))
                model_s = costmodel.backend_cost(
                    feat, name, interpret=interp)["seconds"]
                _CALIB[ckey] = meas / model_s if model_s > 0 \
                    else float("inf")
            except Exception:
                _CALIB[ckey] = float("inf")
    cal = {n: _CALIB.get("batch:" + n, 1.0) for n in names}
    report = costmodel.rank_backends(feat, names, calibration=cal,
                                     interpret=interp)
    winner = report["winner"] or "bsr"
    report = dict(report, winner=winner)
    _TUNE_MEMO[key] = report
    return winner, dict(report["predicted_s"])


def coverage_curve(q: jax.Array, k: jax.Array, cfg: ClusterKVConfig
                   ) -> jax.Array:
    """Estimated softmax-mass coverage as a function of B (tiles kept).

    q (B,Hq,S,dh), k (B,Hkv,S,dh). Returns (nkb,) monotone curve: entry i =
    mean over query tiles of the softmax mass (at tile granularity)
    captured by the top-(i+1) key tiles under the cluster ordering.
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    bq = min(cfg.block_q, s)
    bk = min(cfg.block_k, s)
    nqb, nkb = s // bq, s // bk

    perm = ckv.cluster_perm(k, d=cfg.embed_dim)
    k_s = jnp.take_along_axis(k, perm[..., None], axis=-2)
    cent = ckv.block_centroids(k_s, bk)                    # (B,Hkv,nkb,dh)
    qc = q.reshape(b, hkv, hq // hkv, nqb, bq, dh).mean(axis=(2, 4))
    scores = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                        cent.astype(jnp.float32)) / jnp.sqrt(float(dh))
    # tile-granularity softmax mass, sorted descending per query tile
    w = jax.nn.softmax(scores * bk, axis=-1)   # bk: tiles hold bk keys
    w_sorted = -jnp.sort(-w, axis=-1)
    return jnp.mean(jnp.cumsum(w_sorted, axis=-1), axis=(0, 1, 2))


def tune_blocks_per_query(q: jax.Array, k: jax.Array,
                          cfg: ClusterKVConfig,
                          target_coverage: float = 0.95
                          ) -> Tuple[ClusterKVConfig, float]:
    """Smallest B reaching the target estimated coverage (plus the always-
    kept local window). Returns (updated config, achieved coverage)."""
    curve = coverage_curve(q, k, cfg)
    nkb = curve.shape[0]
    b_needed = int(jnp.argmax(curve >= target_coverage)) + 1
    if float(curve[-1]) < target_coverage:
        b_needed = nkb
    b_needed = min(b_needed + cfg.local_window_blocks, nkb)
    return (dataclasses.replace(cfg, blocks_per_query=b_needed),
            float(curve[min(b_needed, nkb) - 1]))
