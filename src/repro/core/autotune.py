"""Autotuning: SpMV backend selection for plans + attention budget tuning.

``tune_backend`` probes the SpMV backend registry on a plan's real shapes
and picks the fastest path — this is what ``backend="auto"`` resolves to in
``repro.api``. The attention-budget half below reuses the paper's γ-score
idea to size the cluster-sparse attention budget.

Patch-density-guided autotuning of the cluster-sparse attention budget.

The paper's γ-score measures how much interaction mass concentrates into
dense patches under an ordering (§2.3). The same quantity tunes the LM
backend: after cluster-sorting keys, the centroid score mass captured by
the top-B key tiles per query tile is a direct coverage estimate — pick
the smallest B whose estimated coverage exceeds the target. Models with
strongly clustered keys (high patch density) get small B (fast); diffuse
ones automatically fall back toward dense attention.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ClusterKVConfig
from repro.core import clusterkv as ckv
from repro.core.registry import backend_names, get_backend


# ---------------------------------------------------------------------------
# SpMV backend autotuning (resolves plan backend="auto")
# ---------------------------------------------------------------------------

# structural memo of auto winners: probing costs a compile + timed runs per
# registered backend, and a *batch* of spec-identical plans (or a stream of
# refreshed lineages with stable shapes) would otherwise re-pay it per plan.
# Keys are (shape_key, charge ndim, backend set, device_count) — everything
# that determines which kernels compile; values are winner names.
_TUNE_MEMO: Dict[tuple, str] = {}


def clear_tune_memo() -> None:
    """Drop memoized auto-backend decisions (tests / fresh measurements)."""
    _TUNE_MEMO.clear()


def probe_backends(plan, x: Optional[jax.Array] = None,
                   backends: Optional[Iterable[str]] = None,
                   warmup: int = 1, iters: int = 3,
                   atol: float = 1e-3) -> Dict[str, float]:
    """Median wall time (s) per registered backend on the plan's shapes.

    Backends that raise (missing COO, mesh indivisibility, ...) or disagree
    with the flat block path by more than ``atol`` max-abs are skipped —
    a fast-but-wrong backend must never win the autotune.
    """
    if x is None:
        x = jnp.asarray(
            np.random.default_rng(0).standard_normal(plan.n), jnp.float32)
    names = tuple(backends) if backends is not None else backend_names()
    try:
        ref = np.asarray(jax.block_until_ready(get_backend("bsr")(plan, x)))
    except Exception:
        ref = None
    times: Dict[str, float] = {}
    for name in names:
        fn = get_backend(name)
        try:
            y = np.asarray(jax.block_until_ready(fn(plan, x)))
            if ref is not None and np.abs(y - ref).max() > atol:
                continue
            for _ in range(warmup):
                jax.block_until_ready(fn(plan, x))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(fn(plan, x))
                ts.append(time.perf_counter() - t0)
            times[name] = float(np.median(ts))
        except Exception:
            continue
    return times


def tune_backend(plan, x: Optional[jax.Array] = None,
                 backends: Optional[Iterable[str]] = None,
                 device_count: Optional[int] = None
                 ) -> Tuple[str, Dict[str, float]]:
    """Pick the fastest registered SpMV backend for ``plan``.

    Returns ``(name, per-backend times)``; falls back to ``"bsr"`` when
    nothing could be probed.

    Device-count-aware: on a >=2-device mesh the sharded ``dist`` path
    wins whenever it (a) probed correct and (b) its halo analysis moves
    strictly less charge than replication. Wall-clock probes on a
    single-host mesh (forced virtual devices, shared memory) mismeasure
    collective cost — they bill inter-device copies at shared-memory
    speed for the replicated paths while charging the halo path its full
    launch overhead — so the transfer model, not the stopwatch, decides
    between per-device paths; the stopwatch still ranks the single-device
    backends against each other.

    Single-device decisions are memoized on the plan's structural key
    (``PlanSpec.shape_key`` + charge ndim + backend set): plans that
    compile to the same kernels get the same winner without re-probing —
    what lets a batch of spec-identical plans autotune once. Multi-device
    decisions are NOT memoized: the dist-vs-replicate call depends on the
    plan's actual block structure (the halo transfer model), which two
    same-shaped plans can disagree on.
    """
    ndev = device_count if device_count is not None else jax.device_count()
    names = tuple(backends) if backends is not None else backend_names()
    key = None
    if ndev < 2 and plan.bsr is not None \
            and not isinstance(plan.bsr.vals, jax.core.Tracer):
        key = (plan.spec.shape_key, x.ndim if x is not None else 1, names,
               ndev)
        hit = _TUNE_MEMO.get(key)
        if hit is not None:
            return hit, {}
    times = probe_backends(plan, x, backends)
    if not times:
        return "bsr", times
    if ndev >= 2 and "dist" in times and plan.bsr is not None \
            and not isinstance(plan.bsr.col_idx, jax.core.Tracer):
        from repro.core.shardplan import analyze_shards

        spec, _ = analyze_shards(plan.bsr, ndev)
        if spec.transfer_blocks < spec.allgather_blocks:
            return "dist", times
    winner = min(times, key=times.get)
    if key is not None:
        _TUNE_MEMO[key] = winner
    return winner, times


def tune_batch_backend(batch, x: Optional[jax.Array] = None,
                       backends: Optional[Iterable[str]] = None,
                       warmup: int = 1, iters: int = 3,
                       atol: float = 1e-3) -> Tuple[str, Dict[str, float]]:
    """One shared backend decision for a whole ``api.PlanBatch``.

    Probes the *batched* kernel itself (``api._batch_apply_kernel``) over
    the vmappable backends — the single-plan stopwatch ranking does not
    transfer (vmap changes the einsum shapes and dispatch count), so the
    batch is measured as the batch. Backends that fail to vmap or disagree
    with the batched ``bsr`` path are skipped. The decision is memoized on
    ``(batch shape_key, B, charge ndim, backend set)``: spec-identical
    batches — every construction in a serving loop — tune once.
    """
    from repro import api

    names = (tuple(backends) if backends is not None
             else tuple(n for n in api._BATCHED_BACKENDS
                        if n in backend_names()))
    ndim = (x.ndim - 1) if x is not None else 1
    key = ("batch", batch.spec.shape_key, batch.batch, ndim, names)
    hit = _TUNE_MEMO.get(key)
    if hit is not None:
        return hit, {}
    if x is None:
        x = jnp.asarray(np.random.default_rng(0).standard_normal(
            (batch.batch, batch.capacity)), jnp.float32)
    try:
        ref = np.asarray(jax.block_until_ready(api._batch_apply_kernel(
            batch.spec, batch.data, x, "bsr", "apply")))
    except Exception:
        ref = None
    times: Dict[str, float] = {}
    for name in names:
        try:
            y = np.asarray(jax.block_until_ready(api._batch_apply_kernel(
                batch.spec, batch.data, x, name, "apply")))
            if ref is not None and np.abs(y - ref).max() > atol:
                continue
            for _ in range(warmup):
                jax.block_until_ready(api._batch_apply_kernel(
                    batch.spec, batch.data, x, name, "apply"))
            ts = []
            for _ in range(iters):
                t0 = time.perf_counter()
                jax.block_until_ready(api._batch_apply_kernel(
                    batch.spec, batch.data, x, name, "apply"))
                ts.append(time.perf_counter() - t0)
            times[name] = float(np.median(ts))
        except Exception:
            continue
    winner = min(times, key=times.get) if times else "bsr"
    _TUNE_MEMO[key] = winner
    return winner, times


def coverage_curve(q: jax.Array, k: jax.Array, cfg: ClusterKVConfig
                   ) -> jax.Array:
    """Estimated softmax-mass coverage as a function of B (tiles kept).

    q (B,Hq,S,dh), k (B,Hkv,S,dh). Returns (nkb,) monotone curve: entry i =
    mean over query tiles of the softmax mass (at tile granularity)
    captured by the top-(i+1) key tiles under the cluster ordering.
    """
    b, hq, s, dh = q.shape
    hkv = k.shape[1]
    bq = min(cfg.block_q, s)
    bk = min(cfg.block_k, s)
    nqb, nkb = s // bq, s // bk

    perm = ckv.cluster_perm(k, d=cfg.embed_dim)
    k_s = jnp.take_along_axis(k, perm[..., None], axis=-2)
    cent = ckv.block_centroids(k_s, bk)                    # (B,Hkv,nkb,dh)
    qc = q.reshape(b, hkv, hq // hkv, nqb, bq, dh).mean(axis=(2, 4))
    scores = jnp.einsum("bhqd,bhkd->bhqk", qc.astype(jnp.float32),
                        cent.astype(jnp.float32)) / jnp.sqrt(float(dh))
    # tile-granularity softmax mass, sorted descending per query tile
    w = jax.nn.softmax(scores * bk, axis=-1)   # bk: tiles hold bk keys
    w_sorted = -jnp.sort(-w, axis=-1)
    return jnp.mean(jnp.cumsum(w_sorted, axis=-1), axis=(0, 1, 2))


def tune_blocks_per_query(q: jax.Array, k: jax.Array,
                          cfg: ClusterKVConfig,
                          target_coverage: float = 0.95
                          ) -> Tuple[ClusterKVConfig, float]:
    """Smallest B reaching the target estimated coverage (plus the always-
    kept local window). Returns (updated config, achieved coverage)."""
    curve = coverage_curve(q, k, cfg)
    nkb = curve.shape[0]
    b_needed = int(jnp.argmax(curve >= target_coverage)) + 1
    if float(curve[-1]) < target_coverage:
        b_needed = nkb
    b_needed = min(b_needed + cfg.local_window_blocks, nkb)
    return (dataclasses.replace(cfg, blocks_per_query=b_needed),
            float(curve[min(b_needed, nkb) - 1]))
