"""Matrix orderings compared in the paper (§4.3, Fig. 2).

Each ordering returns a permutation ``pi`` (numpy int array) such that row i
of the reordered matrix is row ``pi[i]`` of the original — i.e. points are
*placed* in the order listed by ``pi``. The paper's orderings:

  scattered   random permutation (base case)
  rcm         reverse Cuthill-McKee on the symmetrized kNN graph
  pca_1d      sort by most dominant principal component
  lex         lexicographic sort of the first d quantized principal coords
  dual_tree   our hierarchical 2^d-tree (Morton) ordering  (paper's method)
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import numpy as np
import scipy.sparse as sp
from scipy.sparse.csgraph import reverse_cuthill_mckee

from repro.core.embedding import embed
from repro.core.hierarchy import build_tree, morton_order


def scattered(n: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.permutation(n)


def rcm(rows: np.ndarray, cols: np.ndarray, n: int) -> np.ndarray:
    """Reverse Cuthill-McKee on the symmetrized sparsity pattern."""
    a = sp.coo_matrix((np.ones_like(rows, dtype=np.int8), (rows, cols)),
                      shape=(n, n)).tocsr()
    a = (a + a.T).tocsr()
    return np.asarray(reverse_cuthill_mckee(a, symmetric_mode=True))


def pca_1d(x: np.ndarray) -> np.ndarray:
    y = np.asarray(embed(jnp.asarray(x), 1))
    return np.argsort(y[:, 0], kind="stable")


def lex(x: np.ndarray, d: int = 3, bits: int = 10) -> np.ndarray:
    """Lexicographic sort of quantized d-dim principal coordinates."""
    y = np.asarray(embed(jnp.asarray(x), d))
    lo, hi = y.min(0, keepdims=True), y.max(0, keepdims=True)
    q = ((y - lo) / np.maximum(hi - lo, 1e-30) * (2**bits - 1)).astype(np.uint64)
    key = np.zeros(len(y), dtype=np.uint64)
    for j in range(d):
        key = (key << np.uint64(bits)) | q[:, j]
    return np.argsort(key, kind="stable")


def dual_tree(x: np.ndarray, d: int = 3, bits: int = 10,
              leaf_size: int = 64) -> np.ndarray:
    """The paper's ordering: PCA embed -> adaptive 2^d tree -> leaf order."""
    y = np.asarray(embed(jnp.asarray(x), d))
    return build_tree(y, bits=bits, leaf_size=leaf_size).perm


def dual_tree_fast(x: np.ndarray, d: int = 3, bits: int = 10) -> np.ndarray:
    """Morton-only variant (identical order, no tree materialization)."""
    y = embed(jnp.asarray(x), d)
    return np.asarray(morton_order(y, bits))


def stable_partial_reorder(pi_old: np.ndarray,
                           keys: np.ndarray) -> np.ndarray:
    """Re-sort an existing ordering by fresh ``keys`` (plan refresh).

    ``keys`` is indexed by *original* point index (e.g. new Morton codes
    after points moved). The sort is stable with the old placement as
    tiebreak: points whose key did not change keep their relative order —
    the reordered pattern is perturbed only where points actually migrated
    — while changed points slot into their new key position.
    """
    pi_old = np.asarray(pi_old)
    order = np.argsort(np.asarray(keys)[pi_old], kind="stable")
    return pi_old[order]


def stream_rebucket(pi: np.ndarray, codes: np.ndarray, rows: np.ndarray,
                    cols: np.ndarray, n: int):
    """Streaming rebucket: stable re-sort of the physical slots by their
    maintained Morton ``codes`` (indexed by physical slot), relabeling
    the cluster-space COO to match.

    Points (and holes) whose code did not change keep their relative
    order, so the reordering perturbs only what actually drifted. Pure —
    ``api.apply_pending_layout`` runs it on background-thread snapshots.
    Returns ``(pi2, inv2, rows2, cols2)``.
    """
    old_pi = np.asarray(pi)
    pi2 = stable_partial_reorder(old_pi, codes)
    inv2 = np.empty_like(pi2)
    inv2[pi2] = np.arange(n)
    return pi2, inv2, inv2[old_pi[rows]], inv2[old_pi[cols]]


def claim_free_slots(free_pos: np.ndarray,
                     targets: np.ndarray) -> np.ndarray:
    """Assign each target position the nearest remaining free slot.

    ``free_pos`` are the cluster-order positions of tombstoned (dead)
    slots, sorted ascending; ``targets`` are the positions where inserted
    points ideally belong (:func:`repro.core.hierarchy.insertion_positions`).
    Greedy: targets claim slots in input order, each taking the closest
    slot still unclaimed — inserts thereby land in (or right next to) the
    Morton leaf of their neighbors, which is what keeps the patched
    row-blocks' column footprint compact. Raises when there are more
    targets than free slots (the caller grows capacity first).
    """
    import bisect

    free = list(np.asarray(free_pos))
    targets = np.asarray(targets)
    if len(targets) > len(free):
        raise ValueError(f"{len(targets)} inserts but only {len(free)} "
                         "free slots; grow capacity before claiming")
    out = np.empty(len(targets), np.int64)
    for i, t in enumerate(targets):
        j = bisect.bisect_left(free, t)
        if j == len(free):
            j -= 1
        elif j > 0 and t - free[j - 1] <= free[j] - t:
            j -= 1
        out[i] = free.pop(j)
    return out


def apply_ordering(rows: np.ndarray, cols: np.ndarray,
                   pi_t: np.ndarray, pi_s: Optional[np.ndarray] = None):
    """Relabel COO indices under row/col orderings (targets pi_t, sources pi_s)."""
    if pi_s is None:
        pi_s = pi_t
    inv_t = np.empty_like(pi_t)
    inv_t[pi_t] = np.arange(len(pi_t))
    inv_s = np.empty_like(pi_s)
    inv_s[pi_s] = np.arange(len(pi_s))
    return inv_t[rows], inv_s[cols]


ORDERINGS = ("scattered", "rcm", "pca_1d", "lex2", "lex3", "dual_tree")


def compute_ordering(name: str, x: np.ndarray, rows: np.ndarray,
                     cols: np.ndarray, seed: int = 0) -> np.ndarray:
    n = x.shape[0]
    if name == "scattered":
        return scattered(n, seed)
    if name == "rcm":
        return rcm(rows, cols, n)
    if name == "pca_1d":
        return pca_1d(x)
    if name == "lex2":
        return lex(x, d=2)
    if name == "lex3":
        return lex(x, d=3)
    if name == "dual_tree":
        return dual_tree(x, d=3)
    raise ValueError(f"unknown ordering {name!r}")
