"""Distributed block-sparse interaction via shard_map (DESIGN.md §2, §5).

The paper parallelizes SpMV with pthreads over row blocks; the TPU-native
mapping shards row-blocks over a mesh axis. Because the dual-tree ordering
makes each row-block's column footprint compact, every shard needs only a
small window of the charge vector — here realized as one all-gather of the
(cluster-ordered, hence contiguous) charge vector, amortized across the
shard's row-blocks.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import shard_map

from repro.core.blocksparse import BSR
from repro.core.registry import register_backend


def spmv_sharded(bsr: BSR, x: jax.Array, mesh: Mesh, axis: str = "data"
                 ) -> jax.Array:
    """y = A x with row-blocks sharded over ``axis``.

    Requires n_rb divisible by the axis size (pad the matrix if not).
    Single-vector charges only: the local einsum and the final reshape
    assume ``x`` of shape (n,) — reject (n, f) loudly rather than
    scrambling it.
    """
    if x.ndim != 1:
        raise ValueError(f"spmv_sharded supports 1-D charges only, "
                         f"got x.shape={x.shape}")
    n_rb = bsr.vals.shape[0]
    size = mesh.shape[axis]
    if n_rb % size:
        raise ValueError(f"n_rb={n_rb} not divisible by |{axis}|={size}")

    def local(vals, col_idx, xg):
        # vals (n_rb/size, nbr, bs, bs); xg fully replicated (all-gathered)
        xb = xg.reshape(-1, bsr.bs)
        seg = xb[col_idx]                            # (rb_l, nbr, bs)
        return jnp.einsum("rnij,rnj->ri", vals, seg)

    f = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=P(axis),
        check_vma=False)
    pad = n_rb * bsr.bs - x.shape[0]
    xp = jnp.pad(x, (0, pad)) if pad else x
    y = f(bsr.vals, bsr.col_idx, xp)
    return y.reshape(-1)[:bsr.n]


@register_backend("dist")
def _dist_backend(plan, x: jax.Array, *, mesh: Mesh | None = None,
                  axis: str = "data", **_kw) -> jax.Array:
    """InteractionPlan SpMV with row-blocks sharded over a mesh axis.

    With no mesh given, builds a 1-axis mesh over the largest device count
    that divides the plan's row-block count (so the default works for any
    plan regardless of how many host devices XLA was forced to expose).
    Only single-vector charges (``x`` of shape (n,)) are supported; with an
    explicit mesh, ``n_rb`` must divide by the axis size — autotuning
    skips this backend otherwise.
    """
    if mesh is None:
        size = math.gcd(plan.bsr.vals.shape[0], jax.device_count())
        mesh = jax.make_mesh((size,), (axis,))
    return spmv_sharded(plan.bsr, x, mesh, axis)
