"""Distributed block-sparse interaction via shard_map (DESIGN.md §2, §5).

The paper parallelizes SpMV with pthreads over row blocks; the TPU-native
mapping shards row-blocks over a mesh axis. Because the dual-tree ordering
makes each row-block's column footprint compact, every shard needs only a
small window of the charge vector. The registry backend ("dist") realizes
that window as a minimal halo exchange via :mod:`repro.core.shardplan`;
:func:`spmv_sharded` below keeps the simpler replicate-the-charges
all-gather path as the traced-plan fallback and as the traffic baseline
the halo exchange is measured against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.compat import shard_map

from repro.core.blocksparse import BSR
from repro.core.registry import register_backend


def spmv_sharded(bsr: BSR, x: jax.Array, mesh: Mesh, axis: str = "data"
                 ) -> jax.Array:
    """y = A x with row-blocks sharded over ``axis``.

    A row-block count that does not divide the axis size is padded with
    empty row-blocks (column 0, zero tiles — they contribute zero rows
    that are sliced off), so any plan runs on any mesh. Single-vector
    charges only: the local einsum and the final reshape assume ``x`` of
    shape (n,) — reject (n, f) loudly rather than scrambling it.
    """
    if x.ndim != 1:
        raise ValueError(f"spmv_sharded supports 1-D charges only, "
                         f"got x.shape={x.shape}")
    n_rb = bsr.vals.shape[0]
    size = mesh.shape[axis]
    pad_rb = (-n_rb) % size
    vals, col_idx = bsr.vals, bsr.col_idx
    if pad_rb:
        # memoize the padded tile tensor on the BSR: serving loops call
        # this every matvec and must not re-copy O(n_rb*nbr*bs^2) data
        cache = getattr(bsr, "_dist_pad", None)
        if cache is not None and cache[0] == size:
            vals, col_idx = cache[1], cache[2]
        else:
            vals = jnp.pad(vals, ((0, pad_rb), (0, 0), (0, 0), (0, 0)))
            col_idx = jnp.pad(col_idx, ((0, pad_rb), (0, 0)))
            if not isinstance(vals, jax.core.Tracer):  # never cache traces
                bsr._dist_pad = (size, vals, col_idx)

    def local(vals, col_idx, xg):
        # vals (n_rb_p/size, nbr, bs, bs); xg fully replicated (all-gathered)
        xb = xg.reshape(-1, bsr.bs)
        seg = xb[col_idx]                            # (rb_l, nbr, bs)
        return jnp.einsum("rnij,rnj->ri", vals, seg)

    f = shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(axis), P()),
        out_specs=P(axis),
        check_vma=False)
    pad = n_rb * bsr.bs - x.shape[0]
    xp = jnp.pad(x, (0, pad)) if pad else x
    y = f(vals, col_idx, xp)
    return y.reshape(-1)[:bsr.n]


@register_backend("dist")
def _dist_backend(plan, x: jax.Array, *, mesh: Mesh | None = None,
                  axis: str = "data", **_kw) -> jax.Array:
    """InteractionPlan SpMV with row-blocks sharded over a mesh axis.

    Routes through :mod:`repro.core.shardplan`: the plan is sharded once
    (halo exchange analyzed from its ELL schedule, memoized on the plan
    host per mesh shape) and every subsequent call reuses the shards —
    ppermute halos move only the charge window each device actually
    needs, instead of this module's historical full all-gather. Traced
    plans (the plan itself a jit argument) cannot be halo-analyzed on the
    host and fall back to :func:`spmv_sharded`. With no mesh given,
    builds a 1-axis mesh over every host device. Only single-vector
    charges (``x`` of shape (n,)) are supported.
    """
    from repro.core.shardplan import default_mesh, shard

    if mesh is None:
        mesh = default_mesh(axis)
    if isinstance(plan.bsr.col_idx, jax.core.Tracer):
        return spmv_sharded(plan.bsr, x, mesh, axis)
    return shard(plan, mesh, axis=axis).apply(x)
