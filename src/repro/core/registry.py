"""SpMV backend registry — the pluggable compute layer of an InteractionPlan.

Replaces the old string dispatch in ``core.interact.spmv`` with a registry
keyed by backend name. A backend is a callable

    fn(plan: InteractionPlan, x: jax.Array, **kwargs) -> jax.Array

computing ``y = A x`` in the plan's (cluster-ordered) index space. Built-in
backends register themselves on first use:

  csr       per-edge gather baseline           (core.interact, needs COO)
  bsr       flat single-level block path       (core.interact)
  bsr_ml    multi-level superblock scan        (core.interact)
  pallas    MXU Pallas kernel                  (kernels.ops)
  dist      row-block-sharded SpMV with halo   (core.dist -> core.shardplan;
            exchange for the charge window      shards memoized on the plan)

``core.autotune.tune_backend`` probes this registry to resolve
``backend="auto"`` — device-count-aware: on multi-device meshes ``dist``
wins whenever its halo analysis moves less charge than replication. User
code can ``register_backend`` custom paths and they become visible to
autotuning and ``plan.apply`` immediately.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_BACKENDS: Dict[str, Callable] = {}
_DEFAULTS_LOADED = False

# modules that register the built-in backends at import time
_DEFAULT_PROVIDERS = ("repro.core.interact", "repro.kernels.ops",
                      "repro.core.dist")


def register_backend(name: str, fn: Callable | None = None):
    """Register ``fn`` as SpMV backend ``name`` (usable as a decorator)."""

    def _register(f: Callable) -> Callable:
        _BACKENDS[name] = f
        return f

    return _register if fn is None else _register(fn)


def _ensure_defaults() -> None:
    global _DEFAULTS_LOADED
    if _DEFAULTS_LOADED:
        return
    import importlib

    for mod in _DEFAULT_PROVIDERS:
        importlib.import_module(mod)
    # only latch after every provider imported: a transient import failure
    # surfaces on this call and is retried on the next, instead of leaving
    # a silently partial registry
    _DEFAULTS_LOADED = True


def get_backend(name: str) -> Callable:
    _ensure_defaults()
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown SpMV backend {name!r}; registered: {backend_names()}"
        ) from None


def backend_names() -> Tuple[str, ...]:
    _ensure_defaults()
    return tuple(sorted(_BACKENDS))
