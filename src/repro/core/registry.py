"""SpMV backend registry — the pluggable compute layer of an InteractionPlan.

Replaces the old string dispatch in ``core.interact.spmv`` with a registry
keyed by backend name. A backend is a callable

    fn(plan: InteractionPlan, x: jax.Array, **kwargs) -> jax.Array

computing ``y = A x`` in the plan's (cluster-ordered) index space. Built-in
backends register themselves on first use:

  csr       per-edge gather baseline           (core.interact, needs COO)
  bsr       flat single-level block path       (core.interact)
  bsr_ml    multi-level superblock scan        (core.interact)
  pallas    MXU Pallas kernel                  (kernels.ops)
  dist      row-block-sharded SpMV with halo   (core.dist -> core.shardplan;
            exchange for the charge window      shards memoized on the plan)

``core.autotune.tune_backend`` probes this registry to resolve
``backend="auto"`` — device-count-aware: on multi-device meshes ``dist``
wins whenever its halo analysis moves less charge than replication. User
code can ``register_backend`` custom paths and they become visible to
autotuning and ``plan.apply`` immediately.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

_BACKENDS: Dict[str, Callable] = {}
_BATCHED: Dict[str, Callable] = {}
_DECODE: Dict[str, Callable] = {}
_PRECOND: Dict[str, Callable] = {}
_DEFAULTS_LOADED = False
_DECODE_LOADED = False
_PRECOND_LOADED = False

# modules that register the built-in backends at import time
_DEFAULT_PROVIDERS = ("repro.core.interact", "repro.kernels.ops",
                      "repro.core.dist")
# modules that register the built-in DECODE backends; a separate latch so
# importing the SpMV providers never drags the model stack in, and vice
# versa
_DECODE_PROVIDERS = ("repro.models.attention", "repro.kernels.ops")
# modules that register the built-in PRECONDITIONERS (repro.solvers); its
# own latch keeps the solver subsystem out of plain SpMV imports
_PRECOND_PROVIDERS = ("repro.solvers.precond",)


def register_backend(name: str, fn: Callable | None = None, *,
                     overwrite: bool = False):
    """Register ``fn`` as SpMV backend ``name`` (usable as a decorator).

    Re-registering an existing name raises unless ``overwrite=True`` —
    a silent overwrite turns two libraries picking the same name into a
    wrong-answer bug instead of an import-time error. Re-registering the
    *same* callable is a no-op (module re-imports are harmless).
    """

    def _register(f: Callable) -> Callable:
        prev = _BACKENDS.get(name)
        if prev is not None and prev is not f and not overwrite:
            raise ValueError(
                f"SpMV backend {name!r} is already registered "
                f"({prev.__module__}.{prev.__qualname__}); pass "
                "overwrite=True to replace it deliberately")
        _BACKENDS[name] = f
        return f

    return _register if fn is None else _register(fn)


def register_batched_backend(name: str, fn: Callable | None = None, *,
                             overwrite: bool = False):
    """Register the *batched* implementation of backend ``name``.

    A batched backend is ``fn(spec: PlanSpec, data: PlanData, xs) -> ys``
    computing the cluster-order interaction for a whole stacked batch
    (leading axis) in one kernel. ``PlanBatch`` dispatches to it when
    present; backends without one fall back to a generic ``vmap`` of
    their single-plan path — correct, but XLA (CPU especially) lowers
    vmapped gathers poorly, so hot backends should register a real
    batched kernel (see ``core.interact.spmv_bsr_batched``).
    """

    def _register(f: Callable) -> Callable:
        prev = _BATCHED.get(name)
        if prev is not None and prev is not f and not overwrite:
            raise ValueError(
                f"batched SpMV backend {name!r} is already registered; "
                "pass overwrite=True to replace it deliberately")
        _BATCHED[name] = f
        return f

    return _register if fn is None else _register(fn)


def get_batched_backend(name: str) -> Callable | None:
    """The batched implementation of ``name``, or ``None`` when the
    backend only has a single-plan path (callers vmap it generically)."""
    _ensure_defaults()
    return _BATCHED.get(name)


def _ensure_defaults() -> None:
    global _DEFAULTS_LOADED
    if _DEFAULTS_LOADED:
        return
    import importlib

    for mod in _DEFAULT_PROVIDERS:
        importlib.import_module(mod)
    # only latch after every provider imported: a transient import failure
    # surfaces on this call and is retried on the next, instead of leaving
    # a silently partial registry
    _DEFAULTS_LOADED = True


def get_backend(name: str) -> Callable:
    _ensure_defaults()
    try:
        return _BACKENDS[name]
    except KeyError:
        import difflib

        close = difflib.get_close_matches(name, backend_names(), n=1,
                                          cutoff=0.5)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown SpMV backend {name!r}{hint}; "
            f"registered: {backend_names()}"
        ) from None


def backend_names() -> Tuple[str, ...]:
    _ensure_defaults()
    return tuple(sorted(_BACKENDS))


# ---------------------------------------------------------------------------
# decode-attention backends (the serve tick's per-token attend)
# ---------------------------------------------------------------------------
#
# A decode backend is
#
#     fn(q, ks, vs, ps, cent, qpos, cfg, *, k_self=None, v_self=None) -> out
#
# computing ``clusterkv_plan_decode``'s contract over plan-ordered caches
# (see models.attention). Built-ins:
#
#   xla      unfused top-k select + vmapped tile gather + attend
#   pallas   fused Mosaic kernel (kernels.decode_attend) — selection,
#            gather, and softmax in one launch, tiles stream HBM once
#
# ``cfg.decode_backend == "auto"`` resolves through
# ``core.costmodel.choose_decode_backend`` against the same
# ``repro.cost/v1`` model that ranks the SpMV backends.


def register_decode_backend(name: str, fn: Callable | None = None, *,
                            overwrite: bool = False):
    """Register ``fn`` as decode-attention backend ``name`` (decorator-friendly)."""

    def _register(f: Callable) -> Callable:
        prev = _DECODE.get(name)
        if prev is not None and prev is not f and not overwrite:
            raise ValueError(
                f"decode backend {name!r} is already registered "
                f"({prev.__module__}.{prev.__qualname__}); pass "
                "overwrite=True to replace it deliberately")
        _DECODE[name] = f
        return f

    return _register if fn is None else _register(fn)


def _ensure_decode_defaults() -> None:
    global _DECODE_LOADED
    if _DECODE_LOADED:
        return
    import importlib

    for mod in _DECODE_PROVIDERS:
        importlib.import_module(mod)
    _DECODE_LOADED = True


def get_decode_backend(name: str) -> Callable:
    _ensure_decode_defaults()
    try:
        return _DECODE[name]
    except KeyError:
        import difflib

        close = difflib.get_close_matches(name, decode_backend_names(), n=1,
                                          cutoff=0.5)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown decode backend {name!r}{hint}; "
            f"registered: {decode_backend_names()}"
        ) from None


def decode_backend_names() -> Tuple[str, ...]:
    _ensure_decode_defaults()
    return tuple(sorted(_DECODE))


# ---------------------------------------------------------------------------
# preconditioners (repro.solvers: the iterative-solver subsystem)
# ---------------------------------------------------------------------------
#
# A preconditioner is a FACTORY
#
#     fn(spec: PlanSpec, data: PlanData, shift: jax.Array) -> apply
#
# factoring an approximation of ``A' + shift*I`` (the plan operator in
# cluster order, diagonal-shifted) and returning ``apply(r) -> z`` with
# ``z ~= (A' + shift I)^-1 r`` over cluster-ordered residuals ``r`` of
# shape (..., capacity) or (..., capacity, f). Factories are called
# *inside* the jitted solver kernel — state (e.g. Cholesky factors of the
# diagonal tiles) is traced, the factory itself is resolved by (static)
# name, so one compiled solver serves a whole PlanBatch. Built-ins
# (registered by ``repro.solvers.precond``):
#
#   identity      no preconditioning (z = r)
#   jacobi        pointwise diagonal scaling
#   block_jacobi  batched Cholesky of the dense diagonal BSR tiles
#                 (dead/hole slots get identity rows, never singular ones)


def register_preconditioner(name: str, fn: Callable | None = None, *,
                            overwrite: bool = False):
    """Register ``fn`` as preconditioner factory ``name`` (decorator-friendly).

    Mirrors :func:`register_backend`: duplicate names raise unless
    ``overwrite=True``; re-registering the same callable is a no-op.
    """

    def _register(f: Callable) -> Callable:
        prev = _PRECOND.get(name)
        if prev is not None and prev is not f and not overwrite:
            raise ValueError(
                f"preconditioner {name!r} is already registered "
                f"({prev.__module__}.{prev.__qualname__}); pass "
                "overwrite=True to replace it deliberately")
        _PRECOND[name] = f
        return f

    return _register if fn is None else _register(fn)


def _ensure_precond_defaults() -> None:
    global _PRECOND_LOADED
    if _PRECOND_LOADED:
        return
    import importlib

    for mod in _PRECOND_PROVIDERS:
        importlib.import_module(mod)
    _PRECOND_LOADED = True


def get_preconditioner(name: str) -> Callable:
    _ensure_precond_defaults()
    try:
        return _PRECOND[name]
    except KeyError:
        import difflib

        close = difflib.get_close_matches(name, preconditioner_names(), n=1,
                                          cutoff=0.5)
        hint = f" — did you mean {close[0]!r}?" if close else ""
        raise ValueError(
            f"unknown preconditioner {name!r}{hint}; "
            f"registered: {preconditioner_names()}"
        ) from None


def preconditioner_names() -> Tuple[str, ...]:
    _ensure_precond_defaults()
    return tuple(sorted(_PRECOND))
