"""Double-buffered streaming maintenance: serve the current plan while
its layout repair builds in the background, then swap atomically.

The streaming tiers split into two classes. The in-place tiers
(tombstone / append / patch) keep the ELL layout and re-dress touched
row-blocks with on-device scatters — cheap enough to stay on the serving
critical path. The *layout* tiers (γ-drift rebucket, debris/fill-drift
compaction) rebuild the ordering or the whole plan — hygiene, not
correctness, and far too expensive to stall a decode tick on.

:class:`DoubleBufferedPlan` runs the split: every ``update`` applies the
in-place tiers synchronously via ``api.update_plan(...,
defer_layout=True)``; when a layout tier fires, its repair
(``api.apply_pending_layout``) runs on a daemon thread against an
immutable snapshot — the same async shape as
``repro.checkpoint.Checkpointer.save`` — while the foreground keeps
serving matvecs from the current buffer. The successor is adopted
atomically at the next ``update``/``poll``, bumping ``generation``.

Consistency contract:

- ``update_plan`` is copy-on-write and ``apply_pending_layout`` is a
  pure function of its snapshot, so the serving plan is never mutated by
  the background build: a matvec issued mid-build returns the old
  generation's result **bit-exactly**.
- While a build is in flight, incoming updates are *queued*, not
  applied (applying them would fork the lineage the build snapshotted).
  They replay in order right after the swap; a compact swap first remaps
  their delete indices through ``host.compact_map``. Physical indices
  handed out before the swap (``last_inserted_idx``, events) stay valid
  across rebucket swaps and are remapped across compact swaps.
- The swapped-in successor is bit-identical to running the same repair
  synchronously on the snapshot (asserted in ``benchmarks/bench_stream``
  and ``tests/test_streaming.py``).

Downstream state absorbs a swap explicitly: re-shard via
``ShardedPlan.absorb(dbp.plan)``, re-attach a
``serve.LockstepInserter`` with the new ``generation`` (stale-generation
claims raise). See ``docs/streaming.md``.
"""
from __future__ import annotations

import threading
from typing import Optional

import numpy as np


class DoubleBufferedPlan:
    """Serve a streaming :class:`~repro.api.InteractionPlan` while its
    layout repairs build on a background thread.

    Args:
        plan: the streamable plan to wrap (built by ``api.build_plan``
            from points).

    Attributes:
        generation: monotone counter, bumped once per adopted background
            repair (the swap). In-place updates do not bump it.
        events: append-only log of what actually happened, in order —
            ``("apply", inserted_phys)`` when an update was applied
            (``inserted_phys`` is ``host.last_inserted_idx`` or ``None``),
            ``("swap", kind, compact_map)`` when a background repair was
            adopted (``compact_map`` is ``None`` unless ``kind ==
            "compact"``). Callers tracking physical slots (benchmarks,
            serving engines) consume this instead of guessing.
        last_swap: ``(snapshot, successor, kind)`` of the most recent
            swap — the bit-exactness hook: ``api.apply_pending_layout(
            snapshot)`` re-run inline must equal ``successor``.
    """

    def __init__(self, plan):
        from repro import api
        self._api = api
        self._plan = plan
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._result = None
        self._error: Optional[BaseException] = None
        self._snapshot = None
        self._queue: list = []
        self.generation = 0
        self.events: list = []
        self.last_swap = None

    # -- serving ----------------------------------------------------------

    @property
    def plan(self):
        """The current serving plan. Never advanced by the background
        thread — only ``update``/``poll``/``wait``/``flush`` (caller
        thread) swap a finished successor in."""
        return self._plan

    @property
    def building(self) -> bool:
        """True while a background layout repair is in flight."""
        return self._thread is not None and self._thread.is_alive()

    @property
    def queued(self) -> int:
        """Updates waiting for the in-flight repair to land."""
        return len(self._queue)

    def matvec(self, charges, **kw):
        """Matvec on the serving buffer (old generation mid-build)."""
        return self._plan.matvec(charges, **kw)

    def apply(self, charges, **kw):
        """`plan.apply` on the serving buffer (old generation mid-build)."""
        return self._plan.apply(charges, **kw)

    # -- streaming --------------------------------------------------------

    def update(self, *, insert=None, delete=None, policy=None):
        """One streaming step against the double buffer.

        Adopts a finished background repair first (swap + queued-update
        replay). Then: if a repair is still in flight, the op is queued —
        the serving state is frozen at the build's snapshot so mid-build
        reads stay bit-exact — otherwise the in-place tiers run
        synchronously and, when a layout tier fired, its repair is
        launched in the background.

        ``delete`` indices are interpreted against the serving plan as
        the caller last observed it: if this call adopts a compact swap,
        they are remapped through its ``compact_map`` before being
        applied or queued.

        Returns:
            ``"applied"`` or ``"queued"``.
        """
        n_ev = len(self.events)
        while True:
            self.poll()
            if delete is not None:
                # remap across any compact swap this call just adopted —
                # the caller picked these indices before the swap
                for ev in self.events[n_ev:]:
                    if ev[0] == "swap" and ev[2] is not None:
                        d = ev[2][np.asarray(delete, np.int64)]
                        d = d[d >= 0]
                        delete = d if d.size else None
                        if delete is None:
                            break
            n_ev = len(self.events)
            t = self._thread
            if t is None:
                break
            if t.is_alive():
                self._queue.append({"insert": insert, "delete": delete,
                                    "policy": policy})
                return "queued"
            # the build finished between poll() and here: loop to adopt
            # it first — applying now would be clobbered by the swap
        if insert is None and delete is None and policy is None:
            return "applied"        # op fully absorbed by the remap
        new = self._api.update_plan(self._plan, insert=insert,
                                    delete=delete, policy=policy,
                                    defer_layout=True)
        self._plan = new
        self.events.append(("apply", new.host.last_inserted_idx))
        if new.host.pending_layout is not None:
            self._launch(new)
        return "applied"

    def _launch(self, snapshot) -> None:
        """Start the background repair of ``snapshot.pending_layout``
        (daemon thread, mirroring ``Checkpointer``'s async save)."""
        apply_fn = self._api.apply_pending_layout

        def work():
            try:
                self._result = apply_fn(snapshot)
            except BaseException as e:           # surfaced at next poll
                self._error = e

        self._snapshot = snapshot
        self._thread = threading.Thread(target=work, daemon=True,
                                        name="repro-plan-maintenance")
        self._thread.start()

    def poll(self) -> bool:
        """Adopt a finished background repair, if any.

        Swaps the successor in atomically (under the lock), bumps
        ``generation``, remaps queued delete indices through
        ``host.compact_map`` when the repair was a compaction, then
        replays the queued updates in order (which may launch the next
        repair). Returns True when a swap happened. Re-raises an
        exception the background build hit.
        """
        with self._lock:
            if self._thread is None or self._thread.is_alive():
                return False
            self._thread.join()
            self._thread = None
            if self._error is not None:
                err, self._error = self._error, None
                raise err
            successor, self._result = self._result, None
            snapshot, self._snapshot = self._snapshot, None
            kind = snapshot.host.pending_layout
            cmap = successor.host.compact_map if kind == "compact" else None
            if cmap is not None:
                for op in self._queue:
                    if op["delete"] is not None:
                        d = cmap[np.asarray(op["delete"], np.int64)]
                        d = d[d >= 0]   # queued rows were alive: all map
                        op["delete"] = d if d.size else None
            self._plan = successor
            self.generation += 1
            self.last_swap = (snapshot, successor, kind)
            self.events.append(("swap", kind, cmap))
            replay, self._queue = self._queue, []
        for op in replay:
            self.update(**op)
        return True

    # -- barriers (tests, benchmarks, shutdown) ---------------------------

    def wait(self) -> None:
        """Block until the in-flight repair (if any) lands and its swap
        plus queued-update replay have run."""
        t = self._thread
        if t is not None:
            t.join()
        self.poll()

    def flush(self):
        """Drain everything: repeatedly wait/swap/replay until no repair
        is in flight, the queue is empty, and nothing is pending — then
        run any last recorded repair synchronously. Returns the fully
        repaired serving plan."""
        while True:
            self.wait()
            if self.building or self._queue:
                continue
            if self._plan.host.pending_layout is not None:
                # recorded on the very last applied update: no reason to
                # background it when the caller is blocking anyway
                snapshot = self._plan
                kind = snapshot.host.pending_layout
                self._plan = self._api.apply_pending_layout(snapshot)
                self.generation += 1
                cmap = (self._plan.host.compact_map
                        if kind == "compact" else None)
                self.last_swap = (snapshot, self._plan, kind)
                self.events.append(("swap", kind, cmap))
            return self._plan
