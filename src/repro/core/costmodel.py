"""Analytic per-backend cost model + knob-based hardware config.

One model, three consumers:

  * ``core.autotune`` ranks SpMV backends analytically (probes are demoted
    to one-off calibration of the model's constants);
  * ``core.shardplan`` prices halo-vs-ring-vs-allgather exchanges in
    seconds on the configured interconnect instead of raw block counts;
  * ``kernels.ops`` sizes the Pallas batch-grid tiles (row-superblock,
    slot-chunk, feature tile) against the configured VMEM budget.

The hardware is described by a handful of knobs (:class:`HardwareConfig`)
loadable from JSON — point ``REPRO_HW_CONFIG`` at a knob file and every
decision re-derives from the new hardware truth without re-probing.  All
reports emitted here (and by ``launch/roofline.py`` / ``launch/dryrun.py``)
share one machine-readable envelope: ``schema = "repro.cost/v1"`` plus
``kind`` and the hardware knobs that produced the numbers.

Cost shapes come from ``PlanSpec.shape_key`` — ``(capacity, bs, sb, n_rb,
n_cb, max_nbr)`` — which is exactly the structural memo key the autotune
already uses, so a prediction is valid for every plan that would compile
the same kernels.
"""
from __future__ import annotations

import dataclasses
import json
import os
from dataclasses import dataclass
from typing import Dict, Iterable, Mapping, Optional, Tuple

SCHEMA = "repro.cost/v1"

# dense bottom tiles are float32 on every path (build_bsr casts)
_ELEM = 4.0
_IDX = 4.0


@dataclass(frozen=True)
class HardwareConfig:
    """Knob-based description of the target chip (defaults: TPU v5e-like,
    the same constants ``launch/analytic.py`` has always used).

    ``launch_overhead`` is the fixed cost of one dispatched kernel / scan
    step; ``gather_penalty`` multiplies HBM bytes moved by *irregular*
    gathers (XLA lowers them far off the streaming-bandwidth roof,
    catastrophically so on CPU); ``edge_cost`` is the per-edge
    serialization of the csr path's scatter-adds (throughput-bound, not
    byte-bound); ``interpret_penalty`` is the slowdown of
    running a Pallas kernel under ``interpret=True`` (the CPU container) —
    on a real MXU it is 1.0 and the fused kernel wins on its merits.
    """
    name: str = "tpu-v5e"
    peak_flops: float = 197e12       # bf16/f32 MXU flops per chip
    hbm_bw: float = 819e9            # HBM bytes/s per chip
    link_bw: float = 50e9            # ICI bytes/s per link
    vmem_bytes: int = 16 * 2 ** 20   # VMEM per core
    mxu_tile: int = 128              # MXU systolic tile edge
    launch_overhead: float = 2e-6    # s per dispatched kernel / scan step
    gather_penalty: float = 4.0      # HBM multiplier on irregular gathers
    edge_cost: float = 2e-10         # s per scattered COO edge (csr path)
    interpret_penalty: float = 1e4   # Pallas interpret-mode slowdown

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "HardwareConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown hardware knobs {sorted(unknown)}; "
                             f"known: {sorted(known)}")
        return cls(**dict(d))

    def to_json(self, path: str) -> None:
        with open(path, "w") as fh:
            json.dump(self.to_dict(), fh, indent=2)

    @classmethod
    def from_json(cls, path: str) -> "HardwareConfig":
        with open(path) as fh:
            return cls.from_dict(json.load(fh))


_HARDWARE: Optional[HardwareConfig] = None


def get_hardware() -> HardwareConfig:
    """The active hardware config: ``set_hardware``'s, else the JSON file
    named by ``REPRO_HW_CONFIG``, else the built-in TPU v5e knobs."""
    global _HARDWARE
    if _HARDWARE is None:
        path = os.environ.get("REPRO_HW_CONFIG")
        _HARDWARE = (HardwareConfig.from_json(path) if path
                     else HardwareConfig())
    return _HARDWARE


def set_hardware(hw: "HardwareConfig | Mapping | str | None"
                 ) -> HardwareConfig:
    """Install a hardware config (object, knob dict, or JSON path).
    ``None`` resets to the environment default. Returns the active config.
    Decisions derived from the model (autotune winners, tile sizes) are
    re-evaluated lazily — clear the autotune memo to force new decisions.
    """
    global _HARDWARE
    if hw is None:
        _HARDWARE = None
        return get_hardware()
    if isinstance(hw, str):
        hw = HardwareConfig.from_json(hw)
    elif isinstance(hw, Mapping):
        hw = HardwareConfig.from_dict(hw)
    _HARDWARE = hw
    return hw


def make_report(kind: str, payload: Mapping,
                hw: Optional[HardwareConfig] = None) -> dict:
    """Shared machine-readable envelope for every cost/roofline/dry-run
    report: ``{"schema", "kind", "hardware", **payload}``."""
    hw = hw or get_hardware()
    out = {"schema": SCHEMA, "kind": kind, "hardware": hw.to_dict()}
    out.update(payload)
    return out


# ---------------------------------------------------------------------------
# per-backend flops / bytes-accessed model
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CostFeatures:
    """Structural features of one SpMV problem (per batch member).

    ``nnz`` is the *true* COO edge count when known. The blocked paths
    compute every ELL slot (``n_rb * max_nbr`` dense tiles, padding
    included) but the per-edge ``csr`` path touches only the real edges
    — on hub-heavy kNN graphs that is a 10-50x work gap the model must
    see, or it never predicts csr winning. ``None`` falls back to the
    dense-equivalent count (every ELL slot full)."""
    capacity: int
    bs: int
    sb: int
    n_rb: int
    n_cb: int
    max_nbr: int
    f: int = 1                     # charge feature columns
    batch: int = 1                 # stacked lanes (PlanBatch)
    nnz: Optional[int] = None      # true COO edges (csr path work)


def plan_features(shape_key: Tuple[int, ...], f: int = 1,
                  batch: int = 1,
                  nnz: Optional[int] = None) -> CostFeatures:
    """``PlanSpec.shape_key`` -> :class:`CostFeatures`."""
    capacity, bs, sb, n_rb, n_cb, max_nbr = shape_key
    return CostFeatures(capacity=capacity, bs=bs, sb=sb, n_rb=n_rb,
                        n_cb=n_cb, max_nbr=int(max_nbr or 0), f=f,
                        batch=batch, nnz=nnz)


def backend_cost(feat: CostFeatures, backend: str,
                 hw: Optional[HardwareConfig] = None, *,
                 interpret: bool = False, n_dev: int = 1,
                 exchange_blocks: int = 0) -> dict:
    """Closed-form flops / HBM bytes / seconds for one backend.

    The roofline estimate is ``max(flops/peak, bytes/hbm_bw)`` plus the
    per-launch overhead and (``dist`` only) the link time of the halo
    exchange. Absolute seconds are calibrated by the autotune (one probe
    per backend, memoized); *relative* order across shapes and hardware
    configs is what the model owns.
    """
    hw = hw or get_hardware()
    B = feat.batch
    tiles = B * feat.n_rb * max(feat.max_nbr, 1)
    flops = 2.0 * tiles * feat.bs * feat.bs * feat.f
    tile_bytes = tiles * feat.bs * feat.bs * _ELEM
    seg_bytes = tiles * feat.bs * feat.f * _ELEM
    out_bytes = B * feat.n_rb * feat.bs * feat.f * _ELEM
    idx_bytes = tiles * _IDX
    link_bytes = 0.0
    launches = 1.0
    edge_s = 0.0
    if backend == "csr":
        # per-edge path over the TRUE nonzeros (the blocked paths pay for
        # every ELL slot; csr skips the padding entirely): each edge moves
        # an index pair and a value, and both the x-gather and the
        # y-scatter-add are irregular (penalized)
        nnz = B * (feat.nnz if feat.nnz is not None
                   else feat.n_rb * max(feat.max_nbr, 1)
                   * feat.bs * feat.bs)
        flops = 2.0 * nnz * feat.f
        hbm = nnz * (_ELEM + 2 * _IDX) \
            + hw.gather_penalty * nnz * 2 * feat.f * _ELEM + out_bytes
        # scatter-adds serialize per edge on top of the byte traffic
        edge_s = nnz * hw.edge_cost
    elif backend == "bsr":
        # one flat kernel; the segment gather indexes the whole charge
        # vector (penalized — XLA gathers run far off the streaming roof)
        hbm = tile_bytes + hw.gather_penalty * seg_bytes + out_bytes \
            + idx_bytes
    elif backend == "bsr_ml":
        # superblock stripes keep each step's gather window resident, so
        # segments stream at full bandwidth — paid for by one dispatched
        # scan step per stripe
        hbm = tile_bytes + seg_bytes + out_bytes + idx_bytes
        launches = float(max(-(-feat.n_rb // max(feat.sb, 1)), 1))
    elif backend == "pallas":
        # fused gather: column indices are scalar-prefetched and segments
        # are cut from the VMEM-resident charge block, so nothing
        # round-trips HBM between gather and dot
        hbm = tile_bytes + seg_bytes + out_bytes + idx_bytes
    elif backend == "dist":
        hbm = (tile_bytes + hw.gather_penalty * seg_bytes + out_bytes) \
            / max(n_dev, 1)
        flops /= max(n_dev, 1)
        link_bytes = float(exchange_blocks) * feat.bs * _ELEM
    else:
        # unknown backends get the generic flat-path estimate
        hbm = tile_bytes + hw.gather_penalty * seg_bytes + out_bytes \
            + idx_bytes
    seconds = max(flops / hw.peak_flops, hbm / hw.hbm_bw) \
        + launches * hw.launch_overhead + link_bytes / hw.link_bw + edge_s
    if backend == "pallas" and interpret:
        seconds *= hw.interpret_penalty
    return {"backend": backend, "flops": flops, "hbm_bytes": hbm,
            "link_bytes": link_bytes, "launches": launches,
            "seconds": seconds}


def rank_backends(feat: CostFeatures, names: Iterable[str], *,
                  hw: Optional[HardwareConfig] = None,
                  calibration: Optional[Mapping[str, float]] = None,
                  interpret: bool = False, n_dev: int = 1) -> dict:
    """Analytic ranking of ``names`` on ``feat`` — a machine-readable
    report (shared envelope) carrying the per-backend cost breakdown, the
    calibrated predicted seconds, and the ranking.

    ``calibration`` maps backend name -> measured/modeled ratio (from one
    probe, memoized by the autotune); missing backends rank with ratio
    1.0, non-finite ratios (probe failed / skipped) are excluded.
    """
    hw = hw or get_hardware()
    calibration = calibration or {}
    costs: Dict[str, dict] = {}
    predicted: Dict[str, float] = {}
    for name in names:
        ratio = float(calibration.get(name, 1.0))
        if ratio != ratio or ratio == float("inf"):   # NaN or inf: excluded
            continue
        c = backend_cost(feat, name, hw, interpret=interpret, n_dev=n_dev)
        costs[name] = c
        predicted[name] = ratio * c["seconds"]
    ranking = sorted(predicted, key=predicted.get)
    return make_report("backend_rank", {
        "features": dataclasses.asdict(feat),
        "costs": costs,
        "calibration": {k: calibration.get(k) for k in predicted},
        "predicted_s": predicted,
        "ranking": ranking,
        "winner": ranking[0] if ranking else None,
    }, hw)


# ---------------------------------------------------------------------------
# iterative-solver pricing (repro.solvers: CG on the plan matvec)
# ---------------------------------------------------------------------------


def _precond_cost(feat: CostFeatures, precond: str,
                  hw: HardwareConfig) -> Tuple[float, float, float, float]:
    """(setup_flops, setup_bytes, apply_flops, apply_bytes) of one
    preconditioner on one solve. Setup runs once per solve (inside the
    solver kernel); apply runs every iteration."""
    B, f = feat.batch, feat.f
    vec = B * feat.capacity * f * _ELEM
    if precond == "block_jacobi":
        blocks = B * feat.n_rb
        # extraction reads every ELL tile once; Cholesky is bs^3/3 per
        # block; each apply is two triangular solves (bs^2 flops per rhs
        # column) streaming the factors
        setup_flops = blocks * feat.bs ** 3 / 3.0
        setup_bytes = B * feat.n_rb * max(feat.max_nbr, 1) \
            * feat.bs * feat.bs * _ELEM
        apply_flops = 2.0 * blocks * feat.bs ** 2 * f
        apply_bytes = blocks * feat.bs * feat.bs * _ELEM + 2 * vec
        return setup_flops, setup_bytes, apply_flops, apply_bytes
    if precond == "jacobi":
        setup_bytes = B * feat.n_rb * max(feat.max_nbr, 1) \
            * feat.bs * feat.bs * _ELEM        # diagonal still reads tiles
        return 0.0, setup_bytes, B * feat.capacity * f, 3 * vec
    # identity / unknown: free
    return 0.0, 0.0, 0.0, 0.0


def solver_cost(feat: CostFeatures, backend: str, *,
                iters: int, precond: str = "block_jacobi",
                hw: Optional[HardwareConfig] = None,
                interpret: bool = False, n_dev: int = 1) -> dict:
    """Closed-form cost of one (batched) CG solve: ``setup + iters *
    per_iteration``.

    Per iteration: one backend matvec (:func:`backend_cost` — the
    dominant term, which is why solver backend choice is *inherited*
    from :func:`rank_backends`), one preconditioner apply, and the CG
    vector work (axpys + dots, ~10 streamed vector passes per
    iteration). Setup: the preconditioner factorization. The ``iters``
    estimate is the caller's (telemetry from a prior solve, or a bound
    from the expected conditioning).
    """
    hw = hw or get_hardware()
    mv = backend_cost(feat, backend, hw, interpret=interpret, n_dev=n_dev)
    su_f, su_b, ap_f, ap_b = _precond_cost(feat, precond, hw)
    vec = feat.batch * feat.capacity * feat.f * _ELEM
    cg_bytes = 10.0 * vec                   # x/r/z/p updates + two dots
    cg_flops = 10.0 * feat.batch * feat.capacity * feat.f
    iter_s = mv["seconds"] \
        + max(ap_f / hw.peak_flops, (ap_b + cg_bytes) / hw.hbm_bw)
    setup_s = max(su_f / hw.peak_flops, su_b / hw.hbm_bw) \
        + hw.launch_overhead
    total = setup_s + iters * iter_s
    return {"backend": backend, "precond": precond, "iters": iters,
            "matvec": mv,
            "setup_flops": su_f, "setup_bytes": su_b,
            "iter_flops": mv["flops"] + ap_f + cg_flops,
            "iter_bytes": mv["hbm_bytes"] + ap_b + cg_bytes,
            "setup_seconds": setup_s, "iter_seconds": iter_s,
            "seconds": total}


def rank_solver_backends(feat: CostFeatures, names: Iterable[str], *,
                         iters: int, precond: str = "block_jacobi",
                         hw: Optional[HardwareConfig] = None,
                         calibration: Optional[Mapping[str, float]] = None,
                         interpret: bool = False, n_dev: int = 1) -> dict:
    """Analytic solver-backend ranking — the ``repro.cost/v1`` envelope,
    kind ``"solver_rank"``. The preconditioner and CG terms are
    backend-independent, so the induced ranking matches
    :func:`rank_backends` on the same features (the matvec owns the
    iteration); what this report adds is honest absolute totals: setup
    amortization and the per-iteration floor the solver pays on top of
    the SpMV."""
    hw = hw or get_hardware()
    calibration = calibration or {}
    costs: Dict[str, dict] = {}
    predicted: Dict[str, float] = {}
    for name in names:
        ratio = float(calibration.get(name, 1.0))
        if ratio != ratio or ratio == float("inf"):
            continue
        c = solver_cost(feat, name, iters=iters, precond=precond, hw=hw,
                        interpret=interpret, n_dev=n_dev)
        costs[name] = c
        predicted[name] = c["setup_seconds"] \
            + iters * (ratio * c["matvec"]["seconds"]
                       + c["iter_seconds"] - c["matvec"]["seconds"])
    ranking = sorted(predicted, key=predicted.get)
    return make_report("solver_rank", {
        "features": dataclasses.asdict(feat),
        "iters": iters,
        "precond": precond,
        "costs": costs,
        "calibration": {k: calibration.get(k) for k in predicted},
        "predicted_s": predicted,
        "ranking": ranking,
        "winner": ranking[0] if ranking else None,
    }, hw)


# ---------------------------------------------------------------------------
# decode-attention pricing (serve tick: models.attention decode backends)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DecodeFeatures:
    """Structural features of one plan-decode step (whole batch).

    ``s`` is the plan capacity (padded cache length), ``bk`` the tile
    edge, ``n_sel`` the top-c tiles attended per head. The work is
    identical across backends — what differs is how often the selected
    tiles cross HBM and how many launches a tick pays."""
    batch: int
    hq: int
    hkv: int
    s: int
    dh: int
    dv: int
    bk: int
    n_sel: int


def decode_cost(feat: DecodeFeatures, backend: str,
                hw: Optional[HardwareConfig] = None, *,
                interpret: bool = False) -> dict:
    """Closed-form flops / HBM bytes / seconds for one decode backend.

    Both paths score every centroid and attend the same ``n_sel * bk``
    selected rows per (member, kv head). The unfused ``xla`` path pays
    three dispatches (select top-k, gather, attend) and its vmapped tile
    gather is irregular (``gather_penalty``) AND materializes the
    selection back through HBM before the attend re-reads it. The fused
    ``pallas`` kernel is one launch and DMAs each selected tile from HBM
    exactly once, straight into VMEM scratch — but under ``interpret=True``
    (the CPU container) it eats ``interpret_penalty``, which is why
    ``"auto"`` keeps the service on ``xla`` in CI and flips to the kernel
    on a real MXU.
    """
    hw = hw or get_hardware()
    bh = feat.batch * feat.hkv
    nkb = max(feat.s // max(feat.bk, 1), 1)
    sel_rows = bh * feat.n_sel * feat.bk
    sel_bytes = sel_rows * (feat.dh + feat.dv) * _ELEM
    cent_bytes = bh * nkb * feat.dh * _ELEM
    ps_bytes = bh * feat.s * _IDX
    q_bytes = feat.batch * feat.hq * feat.dh * _ELEM
    out_bytes = feat.batch * feat.hq * feat.dv * _ELEM
    flops = 2.0 * bh * nkb * feat.dh \
        + 2.0 * feat.batch * feat.hq * feat.n_sel * feat.bk \
        * (feat.dh + feat.dv)
    base = cent_bytes + ps_bytes + q_bytes + out_bytes
    if backend == "pallas":
        hbm = base + sel_bytes
        launches = 1.0
    else:
        # gather round-trip: irregular read, HBM write-back of the
        # gathered tiles, then the attend streams them back in
        hbm = base + hw.gather_penalty * sel_bytes + 2 * sel_bytes
        launches = 3.0
    seconds = max(flops / hw.peak_flops, hbm / hw.hbm_bw) \
        + launches * hw.launch_overhead
    if backend == "pallas" and interpret:
        seconds *= hw.interpret_penalty
    return {"backend": backend, "flops": flops, "hbm_bytes": hbm,
            "launches": launches, "seconds": seconds}


def rank_decode_backends(feat: DecodeFeatures,
                         names: Iterable[str] = ("xla", "pallas"), *,
                         hw: Optional[HardwareConfig] = None,
                         interpret: bool = False) -> dict:
    """Analytic ranking of decode backends on ``feat`` — the same
    ``repro.cost/v1`` envelope as :func:`rank_backends`, so plan-mode
    backend choice is inspectable with the SpMV tooling."""
    hw = hw or get_hardware()
    costs: Dict[str, dict] = {}
    predicted: Dict[str, float] = {}
    for name in names:
        c = decode_cost(feat, name, hw, interpret=interpret)
        costs[name] = c
        predicted[name] = c["seconds"]
    ranking = sorted(predicted, key=predicted.get)
    return make_report("decode_rank", {
        "features": dataclasses.asdict(feat),
        "costs": costs,
        "predicted_s": predicted,
        "ranking": ranking,
        "winner": ranking[0] if ranking else None,
    }, hw)


_DECODE_CHOICE: Dict[Tuple, str] = {}


def choose_decode_backend(feat: DecodeFeatures, *,
                          interpret: bool = False,
                          hw: Optional[HardwareConfig] = None) -> str:
    """The model's winner for one decode shape, memoized per (shape,
    interpret, hardware) — the serve loop calls this every tick and the
    answer must not cost a ranking each time."""
    hw = hw or get_hardware()
    key = (feat, bool(interpret), hw)
    got = _DECODE_CHOICE.get(key)
    if got is None:
        got = rank_decode_backends(feat, hw=hw,
                                   interpret=interpret)["winner"]
        _DECODE_CHOICE[key] = got
    return got


# ---------------------------------------------------------------------------
# exchange pricing (core.shardplan halo-vs-ring-vs-allgather)
# ---------------------------------------------------------------------------


def exchange_cost(transfer_blocks: "int | None", bs: int,
                  hw: Optional[HardwareConfig] = None) -> Optional[float]:
    """Seconds to move ``transfer_blocks`` charge blocks of ``bs`` float32
    charges over the configured interconnect (``None`` passes through —
    infeasible exchange candidates stay infeasible)."""
    if transfer_blocks is None:
        return None
    hw = hw or get_hardware()
    return float(transfer_blocks) * bs * _ELEM / hw.link_bw


# ---------------------------------------------------------------------------
# Pallas tile sizing (kernels.ops batch-grid kernel)
# ---------------------------------------------------------------------------


def choose_tiles(shape_key: Tuple[int, ...], f: int = 1,
                 hw: Optional[HardwareConfig] = None
                 ) -> Tuple[int, int, int]:
    """Batch-grid tile sizes ``(rbs, chunk, fc)`` under the VMEM knob.

    ``rbs`` row blocks ride one grid step (amortizing grid overhead),
    ``chunk`` ELL slots are contracted per step, and charges are tiled to
    ``fc`` feature columns. ``chunk`` stays at the full ELL width: a
    split slot reduction changes the floating-point summation order and
    breaks the bit-parity gate against the XLA paths (the CPU-container
    acceptance); memory pressure is instead relieved by shrinking ``fc``
    then ``rbs``. Resident VMEM per step is the vals block
    ``rbs*chunk*bs^2``, the charge block ``n_cb*bs*fc`` and the output
    block ``rbs*bs*fc``.
    """
    capacity, bs, sb, n_rb, n_cb, max_nbr = shape_key
    hw = hw or get_hardware()
    budget = hw.vmem_bytes / 2          # leave headroom for double-buffering
    chunk = max(int(max_nbr or 1), 1)
    fc = max(int(f), 1)
    while fc > 1 and n_cb * bs * fc * _ELEM > budget / 2:
        fc = -(-fc // 2)

    def fits(r: int) -> bool:
        vals_b = r * chunk * bs * bs * _ELEM
        y_b = r * bs * fc * _ELEM
        x_b = n_cb * bs * fc * _ELEM
        return vals_b + y_b + x_b <= budget

    rbs = 1
    while rbs * 2 <= min(max(n_rb, 1), 8) and fits(rbs * 2):
        rbs *= 2
    return rbs, chunk, fc
