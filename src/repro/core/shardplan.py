"""Sharded plans: per-device row-block BSR shards with halo exchange.

``shard(plan, mesh)`` transforms an :class:`repro.api.InteractionPlan` into
a :class:`ShardedPlan` whose row-blocks are partitioned contiguously over a
mesh axis. Because the cluster ordering makes every row-block's column
footprint compact (the paper's whole point — §2.4 step 2), the charge
window each device needs is its *own* charge shard plus a small **halo** of
neighboring blocks. The halo is computed exactly from the ELL schedule
(``col_idx`` under ``nbr_mask``), so on banded/clustered patterns each
matvec moves only the halo blocks between neighbor devices
(``lax.ppermute``) instead of all-gathering the full charge vector the way
``core.dist.spmv_sharded`` does.

Exchange modes, chosen per plan by :func:`analyze_shards`:

  halo       left/right halos (each capped at one shard) moved by one
             ppermute per side, plus an optional **hot set**: the few
             column blocks referenced from outside any window (stray
             cross-cluster kNN edges) are replicated to every device with
             one psum — so a handful of long-range tiles costs
             ``2 * n_hot`` blocks instead of forcing a full gather
  ring       a dense band wider than one shard: whole neighbor shards are
             fetched hop-by-hop; still less traffic than replication
             while ``hops_lo + hops_hi < n_dev - 1``
  allgather  scattered patterns with near-global support: windows + hot
             set would move more than replication, so fall back to one
             all-gather (identical traffic to ``spmv_sharded``)

The column indices of each shard are remapped to *window-local* coordinates
on the host at shard time, so the device loop is a gather + one einsum with
no index arithmetic. ``unshard()`` reverses the transform bit-exactly.

Lifecycle: ``ShardedPlan.refresh(x_new)`` composes with the PR 2 plan
lifecycle — a patch-tier refresh updates only the shards owning migrated
row-blocks (no global rebuild of the shard arrays); rebucket/rebuild tiers
(or a patch whose new columns escape the halo window) fall back to a full
re-shard of the refreshed plan.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import costmodel
from repro.core.blocksparse import BSR

__all__ = ["ShardSpec", "ShardedPlan", "analyze_shards", "shard",
           "default_mesh"]


@functools.lru_cache(maxsize=None)
def default_mesh(axis: str = "data") -> Mesh:
    """1-axis mesh over every local device (shared by `shard` and the
    `dist` registry backend, so their memoized shards agree)."""
    return jax.make_mesh((jax.device_count(),), (axis,))


@dataclass(frozen=True)
class ShardSpec:
    """Host-side halo analysis of a BSR over an ``n_dev``-way row split.

    All quantities are in *column-block* units (one block = ``bs`` charges).
    ``transfer_blocks`` is the number of charge blocks each device moves
    per matvec — the quantity the halo exchange minimizes (replication via
    all-gather costs ``(n_dev - 1) * rb_per``). Hot-set blocks are billed
    at 2x: a psum ring both sends and receives each contribution.
    """
    axis: str
    n_dev: int
    rb_per: int            # row-blocks owned per device (after padding)
    n_rb_pad: int          # rb_per * n_dev
    halo_lo: int           # left-halo width (max over devices, <= rb_per)
    halo_hi: int           # right-halo width (max over devices, <= rb_per)
    hops_lo: int           # whole-shard hops left (ring mode)
    hops_hi: int           # whole-shard hops right (ring mode)
    n_hot: int             # replicated out-of-window column blocks
    mode: str              # halo | ring | allgather
    win: int               # halo-window length per device, in blocks

    @property
    def transfer_blocks(self) -> int:
        if self.mode == "halo":
            return self.halo_lo + self.halo_hi + 2 * self.n_hot
        if self.mode == "ring":
            return (self.hops_lo + self.hops_hi) * self.rb_per
        return (self.n_dev - 1) * self.rb_per

    @property
    def allgather_blocks(self) -> int:
        return (self.n_dev - 1) * self.rb_per

    def window_base(self, dev: int) -> int:
        """First global column-block of device ``dev``'s halo window."""
        if self.mode == "halo":
            return dev * self.rb_per - self.halo_lo
        if self.mode == "ring":
            return (dev - self.hops_lo) * self.rb_per
        return 0


def _support(bsr: BSR, rb_per: int, n_dev: int):
    """Per-device sorted unique column support from the ELL schedule."""
    out = []
    for d in range(n_dev):
        r0, r1 = d * rb_per, min((d + 1) * rb_per, bsr.n_rb)
        out.append(bsr.rowblock_cols(r0, r1) if r0 < r1
                   else np.empty(0, np.int64))
    return out


def analyze_shards(bsr: BSR, n_dev: int, axis: str = "data"
                   ) -> Tuple[ShardSpec, np.ndarray]:
    """Exchange plan for ``bsr`` row-sharded ``n_dev`` ways.

    Reads the ELL schedule on the host (concrete arrays required) and
    costs three covers of every device's column support — capped halo +
    replicated hot set, whole-shard ring hops, full all-gather — picking
    the cheapest. Returns ``(spec, hot)`` where ``hot`` is the sorted
    global column blocks of the hot set (empty outside halo mode).
    """
    n_rb = bsr.n_rb
    rb_per = -(-n_rb // n_dev)
    n_rb_pad = rb_per * n_dev
    no_hot = np.empty(0, np.int64)

    if n_dev == 1:
        return ShardSpec(axis=axis, n_dev=1, rb_per=rb_per,
                         n_rb_pad=n_rb_pad, halo_lo=0, halo_hi=0,
                         hops_lo=0, hops_hi=0, n_hot=0, mode="halo",
                         win=rb_per), no_hot

    support = _support(bsr, rb_per, n_dev)

    # candidate 1: halo capped at one shard per side + hot set for the rest
    halo_lo = halo_hi = 0
    far = []
    for d, cols in enumerate(support):
        if cols.size == 0:
            continue
        r0, r1 = d * rb_per, (d + 1) * rb_per
        near = cols[(cols >= r0 - rb_per) & (cols < r1 + rb_per)]
        far.append(cols[(cols < r0 - rb_per) | (cols >= r1 + rb_per)])
        if near.size:
            halo_lo = max(halo_lo, r0 - int(near.min()))
            halo_hi = max(halo_hi, int(near.max()) - (r1 - 1))
    halo_lo, halo_hi = max(halo_lo, 0), max(halo_hi, 0)
    hot = (np.unique(np.concatenate(far)) if far else no_hot
           ).astype(np.int64)
    blocks_halo = halo_lo + halo_hi + 2 * len(hot)

    # candidate 2: uncapped whole-shard ring hops (wide dense bands)
    span_lo = span_hi = 0
    for d, cols in enumerate(support):
        if cols.size == 0:
            continue
        r0, r1 = d * rb_per, (d + 1) * rb_per
        span_lo = max(span_lo, r0 - int(cols.min()))
        span_hi = max(span_hi, int(cols.max()) - (r1 - 1))
    hops_lo, hops_hi = -(-span_lo // rb_per), -(-span_hi // rb_per)
    ring_ok = hops_lo + hops_hi < n_dev - 1
    blocks_ring = (hops_lo + hops_hi) * rb_per if ring_ok else None

    blocks_ag = (n_dev - 1) * rb_per
    # all three candidates are priced in seconds on the configured
    # interconnect by the shared analytic cost model (a monotone map of
    # the block counts, so decisions match the historical block compare)
    cost_halo = costmodel.exchange_cost(blocks_halo, bsr.bs)
    cost_ring = costmodel.exchange_cost(blocks_ring, bsr.bs)
    cost_ag = costmodel.exchange_cost(blocks_ag, bsr.bs)
    best = min(c for c in (cost_halo, cost_ring, cost_ag) if c is not None)
    if best == cost_halo and cost_halo < cost_ag:
        return ShardSpec(axis=axis, n_dev=n_dev, rb_per=rb_per,
                         n_rb_pad=n_rb_pad, halo_lo=halo_lo,
                         halo_hi=halo_hi, hops_lo=0, hops_hi=0,
                         n_hot=len(hot), mode="halo",
                         win=halo_lo + rb_per + halo_hi), hot
    if cost_ring is not None and best == cost_ring and cost_ring < cost_ag:
        return ShardSpec(axis=axis, n_dev=n_dev, rb_per=rb_per,
                         n_rb_pad=n_rb_pad, halo_lo=min(span_lo, rb_per),
                         halo_hi=min(span_hi, rb_per), hops_lo=hops_lo,
                         hops_hi=hops_hi, n_hot=0, mode="ring",
                         win=(hops_lo + 1 + hops_hi) * rb_per), no_hot
    return ShardSpec(axis=axis, n_dev=n_dev, rb_per=rb_per,
                     n_rb_pad=n_rb_pad, halo_lo=0, halo_hi=0, hops_lo=0,
                     hops_hi=0, n_hot=0, mode="allgather",
                     win=n_rb_pad), no_hot


def _row_bases(spec: ShardSpec, rows: np.ndarray) -> np.ndarray:
    """Window base of each row-block's owning device."""
    base = np.array([spec.window_base(d) for d in range(spec.n_dev)],
                    np.int64)
    return base[rows // spec.rb_per]


def _remap_cols(col: np.ndarray, mask: np.ndarray, base: np.ndarray,
                spec: ShardSpec, hot: np.ndarray):
    """Global column-blocks -> window-local slots, given per-row bases.

    Real columns inside the row's halo window map to ``col - base``; real
    columns outside it map to ``win + index-in-hot``. Padded slots (mask
    False) map to slot 0 — their tiles are zero, so whatever segment they
    gather contributes nothing. Returns ``(local, covered)``: ``covered``
    is False where a *real* column escapes both window and hot set (the
    incremental refresh uses it to detect overflow; at shard time the
    analysis guarantees full coverage).
    """
    local = col.astype(np.int64) - base[:, None]
    in_win = (local >= 0) & (local < spec.win)
    if spec.n_hot:
        pos = np.searchsorted(hot, col)
        in_hot = (pos < spec.n_hot) & (
            hot[np.clip(pos, 0, spec.n_hot - 1)] == col)
    else:
        pos = np.zeros(col.shape, np.int64)
        in_hot = np.zeros(col.shape, bool)
    out = np.where(in_win, np.clip(local, 0, spec.win - 1),
                   np.where(in_hot, spec.win + pos, 0)).astype(np.int32)
    return out, in_win | in_hot | ~mask


def _local_cols(col_idx: np.ndarray, mask: np.ndarray, spec: ShardSpec,
                hot: np.ndarray) -> np.ndarray:
    """Remap the full (row-padded) ELL schedule to window-local slots."""
    n_rb_pad = spec.n_rb_pad
    padded = np.zeros((n_rb_pad, col_idx.shape[1]), np.int64)
    padded[:col_idx.shape[0]] = col_idx
    mask_full = np.zeros(padded.shape, bool)
    mask_full[:mask.shape[0]] = mask
    out, covered = _remap_cols(padded, mask_full,
                               _row_bases(spec, np.arange(n_rb_pad)),
                               spec, hot)
    assert covered.all(), "halo analysis must cover every real column"
    return out


def _hot_routing(spec: ShardSpec, hot: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-device scatter routes for the hot-set psum.

    Device ``d`` owns the hot blocks lying in its row range; it writes its
    local block ``hot_local`` into slot ``hot_dst`` of the shared buffer
    (padded routes target the extra drop slot ``n_hot``).
    """
    owner = hot // spec.rb_per
    counts = np.bincount(owner, minlength=spec.n_dev)
    max_own = int(counts.max(initial=0))
    hot_local = np.zeros((spec.n_dev, max_own), np.int32)
    hot_dst = np.full((spec.n_dev, max_own), spec.n_hot, np.int32)
    for d in range(spec.n_dev):
        mine = np.nonzero(owner == d)[0]
        hot_local[d, :len(mine)] = hot[mine] - d * spec.rb_per
        hot_dst[d, :len(mine)] = mine
    return hot_local, hot_dst


class ShardedPlan:
    """Per-device row-block BSR shards of an InteractionPlan.

    Arrays are laid out with :class:`~jax.sharding.NamedSharding` over
    ``mesh`` so each device owns its row-blocks' tiles and (window-local)
    column schedule; ``apply``/``matvec`` run the halo exchange chosen by
    ``spec``. The wrapped ``plan`` keeps serving permutation helpers,
    stats, and the refresh lifecycle.
    """

    def __init__(self, plan, mesh: Mesh, spec: ShardSpec,
                 vals: jax.Array, lcol: jax.Array, mask: jax.Array,
                 hot: np.ndarray, hot_local: jax.Array,
                 hot_dst: jax.Array):
        self.plan = plan
        self.mesh = mesh
        self.spec = spec
        self.vals = vals          # (n_rb_pad, nbr, bs, bs), P(axis)
        self.lcol = lcol          # (n_rb_pad, nbr) window-local, P(axis)
        self.mask = mask          # (n_rb_pad, nbr) bool, P(axis)
        self.hot = hot            # (n_hot,) sorted global blocks, host
        self.hot_local = hot_local  # (n_dev, max_own) owner routes, P(axis)
        self.hot_dst = hot_dst      # (n_dev, max_own) buffer slots, P(axis)
        self.shard_patches = 0    # incremental refreshes applied in place
        self.reshards = 0         # full re-shards (tier escalation)
        self._fn = None

    # -- compute -----------------------------------------------------------

    def _local_matvec(self):
        spec, bs = self.spec, self.plan.bsr.bs
        axis, n_dev, rb_per = spec.axis, spec.n_dev, spec.rb_per
        fwd = [(i, (i + 1) % n_dev) for i in range(n_dev)]   # from left
        bwd = [((i + 1) % n_dev, i) for i in range(n_dev)]   # from right

        def local(vals, lcol, hot_local, hot_dst, xs):
            # xs: this device's charge shard, (rb_per * bs,)
            if spec.mode == "allgather":
                win = jax.lax.all_gather(xs, axis, tiled=True)
            elif spec.mode == "ring":
                parts, cur = [], xs
                for _ in range(spec.hops_lo):
                    cur = jax.lax.ppermute(cur, axis, fwd)
                    parts.insert(0, cur)
                parts.append(xs)
                cur = xs
                for _ in range(spec.hops_hi):
                    cur = jax.lax.ppermute(cur, axis, bwd)
                    parts.append(cur)
                win = jnp.concatenate(parts)
            else:                           # halo: minimal slice exchange
                parts = []
                if spec.halo_lo:
                    parts.append(jax.lax.ppermute(
                        xs[rb_per * bs - spec.halo_lo * bs:], axis, fwd))
                parts.append(xs)
                if spec.halo_hi:
                    parts.append(jax.lax.ppermute(
                        xs[:spec.halo_hi * bs], axis, bwd))
                win = jnp.concatenate(parts) if len(parts) > 1 else xs
            if spec.n_hot:
                # replicate the hot set: each owner scatters its blocks
                # into a shared buffer slot, one psum merges them (each
                # slot written by exactly one device; slot n_hot drops
                # the padded routes)
                xb_own = xs.reshape(rb_per, bs)
                buf = jnp.zeros((spec.n_hot + 1, bs), xs.dtype)
                buf = buf.at[hot_dst[0]].set(xb_own[hot_local[0]])
                buf = jax.lax.psum(buf, axis)
                win = jnp.concatenate([win, buf[:spec.n_hot].reshape(-1)])
            xb = win.reshape(spec.win + spec.n_hot, bs)
            seg = xb[lcol]                               # (rb_l, nbr, bs)
            return jnp.einsum("rnij,rnj->ri", vals, seg).reshape(-1)

        return shard_map(local, mesh=self.mesh,
                         in_specs=(P(axis),) * 5,
                         out_specs=P(axis), check_vma=False)

    def apply(self, x: jax.Array) -> jax.Array:
        """``y = A' x`` in cluster order via the sharded halo path."""
        x = jnp.asarray(x)
        if x.ndim != 1:
            raise ValueError(f"sharded plans take 1-D charges, got "
                             f"shape {x.shape}")
        if self._fn is None:
            self._fn = jax.jit(self._local_matvec())
        bs = self.plan.bsr.bs
        pad = self.spec.n_rb_pad * bs - x.shape[0]
        xp = jnp.pad(x, (0, pad)) if pad else x
        return self._fn(self.vals, self.lcol, self.hot_local,
                        self.hot_dst, xp)[:self.plan.n]

    def matvec(self, x: jax.Array) -> jax.Array:
        """``y = A x`` in original order (permute ∘ apply ∘ unpermute)."""
        return self.plan.unpermute(self.apply(self.plan.permute(x)))

    def solve(self, b: jax.Array, *, shift: float = 0.0,
              precond: "str | None" = None,
              tol: "float | None" = None,
              maxiter: "int | None" = None):
        """CG on the sharded matvec: each iteration runs the compiled
        halo-exchange SpMV, the dot products reduce over the device axis
        (mesh-sharded arrays psum implicitly). 1-D right-hand sides only
        (the sharded apply's contract); see ``docs/solvers.md``."""
        from repro.solvers.krr import solve as _solve
        return _solve(self, b, shift=shift, precond=precond, tol=tol,
                      maxiter=maxiter)

    # -- introspection -----------------------------------------------------

    @property
    def n(self) -> int:
        return self.plan.n

    @property
    def transfer_fraction(self) -> float:
        """Charge blocks received per device, as a fraction of what a full
        all-gather of the (padded) charge vector would move."""
        ag = self.spec.allgather_blocks
        return self.spec.transfer_blocks / ag if ag else 0.0

    def unshard(self) -> BSR:
        """Reconstruct the unsharded BSR from the shard arrays (bit-exact
        inverse of :func:`shard`: unpad rows, window-local / hot-slot ->
        global columns, padded slots restored to column 0)."""
        b = self.plan.bsr
        spec = self.spec
        vals = np.asarray(self.vals)[:b.n_rb]
        lcol = np.asarray(self.lcol)[:b.n_rb].astype(np.int64)
        mask = np.asarray(self.mask)[:b.n_rb]
        col = lcol + _row_bases(spec, np.arange(b.n_rb))[:, None]
        if spec.n_hot:
            far = lcol >= spec.win
            col[far] = self.hot[np.clip(lcol[far] - spec.win, 0,
                                        spec.n_hot - 1)]
        col = np.where(mask, col, 0)
        return BSR(bs=b.bs, sb=b.sb, n=b.n, n_rb=b.n_rb, n_cb=b.n_cb,
                   col_idx=jnp.asarray(col.astype(np.int32)),
                   nbr_mask=jnp.asarray(mask), vals=jnp.asarray(vals),
                   fill=b.fill, max_nbr=b.max_nbr)

    # -- lifecycle (compose with repro.api.refresh_plan) -------------------

    def _register(self) -> "ShardedPlan":
        """Enter this ShardedPlan into its plan's shard memo (the same
        cache ``shard()`` and the ``dist`` backend consult)."""
        cache = getattr(self.plan.host, "shard_cache", None)
        if cache is not None:
            cache[(self.spec.n_dev, self.spec.axis)] = self
        return self

    def _handoff(self, prev: "ShardedPlan", patched: int = 0,
                 resharded: int = 0) -> "ShardedPlan":
        """Carry lineage telemetry (and, when the exchange program is
        unchanged, the compiled fn) from ``prev`` onto this plan."""
        self.shard_patches = prev.shard_patches + patched
        self.reshards = prev.reshards + resharded
        if self._fn is None and self.spec == prev.spec:
            self._fn = prev._fn
        return self._register()

    def _absorb(self, new_plan, in_place_actions: Tuple[str, ...]
                ) -> "ShardedPlan":
        """Fold an already-updated wrapped plan into the shard arrays.

        When the update was one of ``in_place_actions`` (layout-preserving
        tiers that record ``last_patch_rb``), only the shards owning the
        touched row-blocks are scattered into — devices whose rows were
        untouched keep their arrays, and no halo re-analysis happens,
        *provided* the new columns still fit the existing halo window.
        Everything else (rebucket/rebuild/compact/capacity growth, or a
        window overflow) re-shards the new plan from scratch.
        """
        st = new_plan.refresh_stats
        touched = new_plan.host.last_patch_rb
        same_layout = (
            st.last_action in in_place_actions and touched is not None
            and new_plan.bsr is not None and self.plan.bsr is not None
            and new_plan.bsr.n_rb == self.plan.bsr.n_rb
            and new_plan.bsr.max_nbr == self.plan.bsr.max_nbr)
        if not same_layout:
            return shard(new_plan, self.mesh, axis=self.spec.axis
                         )._handoff(self, resharded=1)
        if len(touched) == 0:      # nothing changed: shards already valid
            return ShardedPlan(new_plan, self.mesh, self.spec, self.vals,
                               self.lcol, self.mask, self.hot,
                               self.hot_local, self.hot_dst
                               )._handoff(self)

        spec = self.spec
        b = new_plan.bsr
        col_np = np.asarray(b.col_idx[touched]).astype(np.int64)
        mask_np = np.asarray(b.nbr_mask[touched])
        local, covered = _remap_cols(col_np, mask_np,
                                     _row_bases(spec, touched), spec,
                                     self.hot)
        if not covered.all():
            # a changed row grew support beyond window + hot set
            return shard(new_plan, self.mesh, axis=self.spec.axis
                         )._handoff(self, resharded=1)
        ti = jnp.asarray(touched)
        return ShardedPlan(
            new_plan, self.mesh, spec,
            self.vals.at[ti].set(b.vals[ti]),
            self.lcol.at[ti].set(jnp.asarray(local)),
            self.mask.at[ti].set(jnp.asarray(mask_np)),
            self.hot, self.hot_local, self.hot_dst
        )._handoff(self, patched=1)

    def refresh(self, x_new, *, policy: Optional[str] = None
                ) -> "ShardedPlan":
        """Refresh the wrapped plan, then update shards incrementally.

        A patch-tier refresh (permutation and ELL shapes kept) scatters
        only the migrated row-blocks' tiles/columns into the owning shards
        — devices whose rows did not move keep their arrays untouched and
        no halo re-analysis or global rebuild happens, *provided* the new
        columns still fit the existing halo window. Rebucket/rebuild (or a
        window overflow) re-shard the refreshed plan from scratch.
        """
        return self._absorb(self.plan.refresh(x_new, policy=policy),
                            ("patch",))

    # -- streaming (compose with repro.api.update_plan) --------------------

    def update(self, *, insert=None, delete=None,
               policy: Optional[str] = None) -> "ShardedPlan":
        """One streaming step on the wrapped plan, shards kept in sync.

        Append/tombstone tiers touch a recorded set of row-blocks at a
        fixed layout, so only the shards owning them are scattered into —
        exactly the refresh patch path. A compaction (or capacity growth,
        which changes ``n_rb``, or a halo-window overflow from a streamed
        row's new columns) re-shards the updated plan on the same mesh.
        """
        from repro import api

        return self._absorb(
            api.update_plan(self.plan, insert=insert, delete=delete,
                            policy=policy),
            ("append", "tombstone"))

    def absorb(self, new_plan) -> "ShardedPlan":
        """Absorb an externally-updated successor of the wrapped plan —
        the shard-local half of a double-buffer swap.

        ``repro.core.doublebuf.DoubleBufferedPlan`` maintains the host
        plan (in-place tiers on the caller thread, layout repairs on a
        background thread); after a swap, the sharded view absorbs the
        successor here. In-place steps (append/tombstone/patch, recorded
        ``last_patch_rb`` at an unchanged layout) scatter only the
        touched shards; a swapped-in rebucket/compact re-shards — on the
        same mesh, carrying the compiled matvec when the shard spec is
        unchanged (shard-local swap, no recompilation).
        """
        return self._absorb(new_plan, ("append", "tombstone", "patch"))

    def insert(self, x_new, *, policy: Optional[str] = None):
        """Streamed insert; returns ``(sharded_plan, physical_indices)``."""
        sp = self.update(insert=x_new, policy=policy)
        return sp, sp.plan.host.last_inserted_idx

    def delete(self, idx, *, policy: Optional[str] = None) -> "ShardedPlan":
        """Streamed delete (tombstone) of physical rows ``idx``."""
        return self.update(delete=idx, policy=policy)

    def __repr__(self) -> str:
        s = self.spec
        return (f"ShardedPlan(n={self.plan.n}, devices={s.n_dev}, "
                f"rb_per={s.rb_per}, mode={s.mode!r}, "
                f"halo=({s.halo_lo},{s.halo_hi}), hot={s.n_hot}, "
                f"transfer={self.transfer_fraction:.2f}x-allgather)")


def shard(plan, mesh: Optional[Mesh] = None, axis: str = "data"
          ) -> ShardedPlan:
    """Shard ``plan``'s row-blocks over ``mesh`` (default: every device).

    Analyzes the ELL schedule for the minimal halo exchange (plus hot
    set), remaps the column schedule to window-local coordinates, and
    places tiles/columns with a row-sharded
    :class:`~jax.sharding.NamedSharding`. Requires a concrete
    (non-traced) plan with a BSR.

    Memoized per ``(device count, axis)`` on the plan host — repeated
    calls (including the ``dist`` registry backend's) return the same
    ShardedPlan instead of re-analyzing and re-placing the tiles.
    """
    if plan.bsr is None:
        raise ValueError("profile-only plan has no BSR to shard "
                         "(rebuild with with_bsr=True)")
    if isinstance(plan.bsr.col_idx, jax.core.Tracer):
        raise ValueError("shard() analyzes the ELL schedule on the host; "
                         "call it outside jit")
    if mesh is None:
        mesh = default_mesh(axis)
    if axis not in mesh.shape:
        raise ValueError(f"mesh has no axis {axis!r} (axes: "
                         f"{tuple(mesh.axis_names)}); pass axis=")
    cache = getattr(plan.host, "shard_cache", None)
    key = (mesh.shape[axis], axis)
    if cache is not None:
        sp = cache.get(key)
        if sp is not None and sp.plan.bsr is plan.bsr and sp.mesh == mesh:
            return sp
    b = plan.bsr
    spec, hot = analyze_shards(b, mesh.shape[axis], axis)
    col_np = np.asarray(b.col_idx)
    mask_np = np.zeros((spec.n_rb_pad, b.max_nbr), bool)
    mask_np[:b.n_rb] = np.asarray(b.nbr_mask)
    lcol = _local_cols(col_np, mask_np[:b.n_rb], spec, hot)
    hot_local, hot_dst = _hot_routing(spec, hot)
    pad_rb = spec.n_rb_pad - b.n_rb
    vals = (jnp.pad(b.vals, ((0, pad_rb), (0, 0), (0, 0), (0, 0)))
            if pad_rb else b.vals)
    sh = NamedSharding(mesh, P(axis))
    return ShardedPlan(plan, mesh, spec,
                       jax.device_put(vals, sh),
                       jax.device_put(jnp.asarray(lcol), sh),
                       jax.device_put(jnp.asarray(mask_np), sh),
                       hot,
                       jax.device_put(jnp.asarray(hot_local), sh),
                       jax.device_put(jnp.asarray(hot_dst), sh)
                       )._register()
