"""Cluster-sparse attention — the paper's pipeline as an LM attention backend.

Mapping (DESIGN.md §3): attention's score matrix *is* a near-neighbor
interaction matrix (queries = targets, keys = sources). The paper's
reordering pipeline is applied per (batch, kv-head):

  1. low-dimensional embedding of the keys onto their top-d principal axes
     (core.embedding, paper §2.4 step 1);
  2. hierarchical clustering by Morton order in the embedding space
     (core.hierarchy, step 2) -> keys permuted into cluster order;
  3. the interaction is computed *block-sparse with dense blocks*: for each
     128-wide query tile only the top-B key tiles (by centroid score) are
     kept, and each kept (q-tile, k-tile) pair is a dense MXU block
     (steps 3-4: multi-level storage + block-segment interaction).

Causality is preserved exactly *within* the computed blocks via gathered
key positions; block selection always boosts blocks containing the local
causal window so recent tokens are never dropped. Like the paper's method
(and kNN attention generally) the set of computed blocks is an
approximation of full attention; tests bound the error against dense
attention on clustered data.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.embedding import pca_project_det as _pca_project
from repro.core.hierarchy import morton_codes

NEG_INF = -1e30


def masked_softmax(logit: jax.Array, mask: jax.Array) -> jax.Array:
    """Softmax over the last axis with a guarded normalizer.

    Bitwise-identical to ``jax.nn.softmax`` whenever at least one column
    of ``mask`` is live — masked entries carry ``NEG_INF`` logits whose
    ``exp`` underflows to exactly ``+0.0`` — but returns exact zeros
    instead of a uniform row when EVERY column is masked (an
    early-position decode whose selected tiles are all holes/future:
    ``exp(NEG_INF - NEG_INF) == 1`` would weight garbage rows uniformly).
    The guard is ``sparse_block_attention``'s ``jnp.maximum(l, 1e-30)``
    applied to the flat-softmax form."""
    logit = jnp.where(mask, logit, NEG_INF)
    m = jnp.max(logit, axis=-1, keepdims=True)
    e = jnp.exp(logit - jax.lax.stop_gradient(m))
    e = jnp.where(mask, e, 0.0)
    return e / jnp.maximum(jnp.sum(e, axis=-1, keepdims=True), 1e-30)


def decode_logits(qh: jax.Array, ksel: jax.Array) -> jax.Array:
    """Scaled q·k logits for one (batch, kv-head) slice: qh (g,dh) float32,
    ksel (c,dh) float32 -> (g,c).

    The form is conditioned on the STATIC group size because the decode
    bitwise gate compares a per-slice kernel against the vmapped XLA
    reference: an M=1 dot is strength-reduced by XLA:CPU into a fused
    multiply+reduce whose rounding depends on the surrounding fusion
    context, so no per-slice form can reproduce it stably. Padding the
    single query row to M=2 keeps the contraction a real materialized
    GEMM — bit-stable between the per-slice and vmapped lowerings — at
    the cost of one duplicated row of a tiny matvec. g >= 2 is already
    a real matmul and hits the MXU unchanged."""
    scale = jnp.sqrt(jnp.asarray(qh.shape[-1], jnp.float32))
    if qh.shape[0] == 1:
        q2 = jnp.concatenate([qh, qh], axis=0)
        return (q2 @ ksel.T)[:1] / scale
    return qh @ ksel.T / scale


def decode_combine(w: jax.Array, vsel: jax.Array) -> jax.Array:
    """Weighted value combine w (g,c) @ vsel (c,dv) float32 -> (g,dv),
    with the same static g == 1 row-padding as :func:`decode_logits`
    (the output dot is M=1 there too)."""
    if w.shape[0] == 1:
        w2 = jnp.concatenate([w, w], axis=0)
        return (w2 @ vsel)[:1]
    return w @ vsel


# ---------------------------------------------------------------------------
# per-head orderings as a PlanBatch (the plan API as the ordering asset)
# ---------------------------------------------------------------------------
#
# ``cluster_perm`` below re-derives a throwaway Morton sort on every call —
# fine inside a traced training step, but the serving path (prefill + many
# decode steps over one cache) wants the ordering to be an *asset*: built
# once per (batch, kv-head), reused across calls, refreshable when the cache
# churns, and checkpointable with the model. That asset is exactly an
# ``api.PlanBatch``: one plan per head, stacked on a shared spec.


def kv_plan_batch(k: jax.Array, *, d: int = 3, bits: int = 10,
                  leaf_size: int = 64, knn: int = 8,
                  with_bsr: bool = False, capacity: int = None):
    """One ``InteractionPlan`` per (batch, kv-head) over the keys, stacked
    as an ``api.PlanBatch`` — the per-head ordering `select_blocks`
    consumes (see :func:`plan_batch_perm`).

    Host-side (concrete keys: prefill/serving, not inside a traced step).
    ``with_bsr=True`` additionally dresses each head's kNN pattern into
    storage, so the same batch serves batched near-neighbor matvecs over
    the key sets; the default builds ordering-only members (cheap).

    ``capacity`` over-allocates every member to the given (pow2-unified)
    slot count with Morton-spread holes, so generated tokens stream in
    through ``api.update_plan``'s insert tier instead of re-sorting — the
    decode service builds every session at ``capacity=max_seq`` and all
    sessions share one ``PlanSpec`` (and one compiled decode kernel).
    """
    from repro import api

    kn = np.asarray(k, np.float32)
    s, dh = kn.shape[-2:]
    flat = kn.reshape((-1, s, dh))
    return api.build_plan_batch(flat, k=min(knn, s - 1), d=min(d, dh),
                                bits=bits, leaf_size=leaf_size,
                                with_bsr=with_bsr, backend="bsr",
                                capacity=capacity)


def plan_batch_perm(pb, lead: Tuple[int, ...]) -> jax.Array:
    """Stacked cluster ordering of a :func:`kv_plan_batch` result, shaped
    ``lead + (S,)`` (e.g. ``(B, Hkv, S)``) — a drop-in for the permutation
    :func:`cluster_perm` derives privately per call."""
    pi = pb.data.pi
    want = int(np.prod(lead)) if lead else 1
    if pi.shape[0] != want:
        raise ValueError(
            f"PlanBatch has {pi.shape[0]} members, lead shape {lead} "
            f"needs {want} (one plan per (batch, kv-head))")
    return pi.reshape(tuple(lead) + (pi.shape[-1],)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# steps 1+2: embed + cluster order (embedding shared with core.embedding —
# the same §2.4 step-1 projection the InteractionPlan pipeline uses)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("d", "bits"))
def cluster_perm(k: jax.Array, d: int = 3, bits: int = 10) -> jax.Array:
    """Cluster ordering of keys ``k`` (..., S, dh) -> perm (..., S).

    perm[i] = index (into original order) of the i-th key in cluster order.
    """
    lead = k.shape[:-2]
    flat = k.reshape((-1,) + k.shape[-2:])

    def one(kh):
        y = _pca_project(kh, d)
        return jnp.argsort(morton_codes(y, bits)).astype(jnp.int32)

    return jax.vmap(one)(flat).reshape(lead + (k.shape[-2],))


def permute_kv(k: jax.Array, v: jax.Array, pos: jax.Array, perm: jax.Array):
    """Apply cluster order along the S axis of k, v (B, H, S, dh), pos (B, H, S)."""
    take = lambda a: jnp.take_along_axis(a, perm[..., None], axis=-2)
    return take(k), take(v), jnp.take_along_axis(pos, perm, axis=-1)


# ---------------------------------------------------------------------------
# step 3: block centroids + top-B causal selection
# ---------------------------------------------------------------------------


def block_centroids(k_sorted: jax.Array, bk: int) -> jax.Array:
    """(B, H, S, dh) -> (B, H, S/bk, dh) mean key per cluster tile."""
    b, h, s, dh = k_sorted.shape
    return k_sorted.reshape(b, h, s // bk, bk, dh).mean(axis=3)


@functools.partial(jax.jit, static_argnames=("n_sel", "bq", "causal"))
def select_blocks(q_cent: jax.Array, k_cent: jax.Array,
                  kpos_min: jax.Array, kpos_max: jax.Array,
                  qpos_min: jax.Array, qpos_max: jax.Array,
                  n_sel: int, bq: int, causal: bool = True,
                  local_window: int = 128) -> jax.Array:
    """Top-``n_sel`` key tiles per query tile.

    q_cent (B,H,nqb,dh), k_cent (B,H,nkb,dh); kpos_min/max (B,H,nkb) are the
    min/max original positions inside each (cluster-sorted) key tile;
    qpos_min/max (nqb,). Returns idx (B,H,nqb,n_sel) int32.
    """
    scores = jnp.einsum("bhqd,bhkd->bhqk", q_cent, k_cent)
    if causal:
        # key tile fully in the future of the whole query tile -> never valid
        invalid = kpos_min[:, :, None, :] > qpos_max[None, None, :, None]
        scores = jnp.where(invalid, NEG_INF, scores)
        # boost tiles holding the local causal window (recent tokens)
        recent = (kpos_max[:, :, None, :] >=
                  (qpos_min[None, None, :, None] - local_window))
        near = recent & ~invalid
        scores = jnp.where(near, scores + 1e4, scores)
    _, idx = jax.lax.top_k(scores, n_sel)
    return idx.astype(jnp.int32)


# ---------------------------------------------------------------------------
# step 4: block-segment interaction (online-softmax over selected tiles)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("bq", "bk", "causal"))
def sparse_block_attention(q: jax.Array, k_sorted: jax.Array,
                           v_sorted: jax.Array, pos_sorted: jax.Array,
                           qpos: jax.Array, idx: jax.Array,
                           bq: int, bk: int, causal: bool = True
                           ) -> jax.Array:
    """Block-sparse attention with dense MXU tiles (pure-JAX reference path;
    the Pallas kernel in kernels/block_attention.py implements the same
    contract).

    q (B,Hq,S,dh); k_sorted/v_sorted (B,Hkv,S,dh) in cluster order;
    pos_sorted (B,Hkv,S) original positions; qpos (S,) query positions;
    idx (B,Hkv,nqb,n_sel) selected key tiles per query tile.
    Hq must be a multiple of Hkv (GQA).
    """
    b, hq, s, dh = q.shape
    hkv = k_sorted.shape[1]
    g = hq // hkv
    nqb = s // bq
    n_sel = idx.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    qb = q.reshape(b, hkv, g, nqb, bq, dh)
    kb = k_sorted.reshape(b, hkv, s // bk, bk, dh)
    vb = v_sorted.reshape(b, hkv, s // bk, bk, v_sorted.shape[-1])
    pb = pos_sorted.reshape(b, hkv, s // bk, bk)
    qp = qpos.reshape(nqb, bq)

    def gather_tiles(x, i):                    # x (nkb, ...) i (nqb, n_sel)
        return x[i]                            # (nqb, n_sel, ...)

    def per_bh(qg, kt, vt, pt, it):
        # qg (g,nqb,bq,dh)  kt/vt (nkb,bk,dh)  pt (nkb,bk)  it (nqb,n_sel)
        ksel = gather_tiles(kt, it)            # (nqb, n_sel, bk, dh)
        vsel = gather_tiles(vt, it)
        psel = gather_tiles(pt, it)            # (nqb, n_sel, bk)

        def over_sel(carry, xs):
            m, l, acc = carry
            kt_, vt_, pt_ = xs                 # (nqb,bk,dh),(nqb,bk,dh),(nqb,bk)
            logit = jnp.einsum("gqtd,qsd->gqts", qg, kt_) * scale
            if causal:
                mask = pt_[None, :, None, :] <= qp[None, :, :, None]
                logit = jnp.where(mask, logit, NEG_INF)
            m_new = jnp.maximum(m, logit.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(logit - m_new[..., None])
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "gqts,qsd->gqtd", p, vt_.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((g, nqb, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((g, nqb, bq), jnp.float32)
        a0 = jnp.zeros((g, nqb, bq, v_sorted.shape[-1]), jnp.float32)
        xs = (jnp.swapaxes(ksel, 0, 1), jnp.swapaxes(vsel, 0, 1),
              jnp.swapaxes(psel, 0, 1))        # scan over n_sel
        (m, l, acc), _ = jax.lax.scan(over_sel, (m0, l0, a0), xs)
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.vmap(jax.vmap(per_bh))(qb, kb, vb, pb, idx)
    return out.reshape(b, hq, s, v_sorted.shape[-1]).astype(q.dtype)


# ---------------------------------------------------------------------------
# decode: top-c cluster selection + gathered attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_sel",))
def decode_select(q: jax.Array, centroids: jax.Array, n_sel: int) -> jax.Array:
    """q (B,Hq,dh) grouped to kv heads scores centroids (B,Hkv,nkb,dh);
    returns idx (B,Hkv,n_sel)."""
    b, hq, dh = q.shape
    hkv = centroids.shape[1]
    qg = q.reshape(b, hkv, hq // hkv, dh).mean(axis=2)
    # multiply+reduce, not einsum: the grouped query is a single row per
    # kv head, and an M=1 contraction is strength-reduced shape-dependently
    # by XLA:CPU — the elementwise form scores identically per-slice and
    # batched, which the fused decode kernel's bitwise gate relies on
    scores = jnp.sum(qg[:, :, None, :] * centroids, -1)
    _, idx = jax.lax.top_k(scores, n_sel)
    return idx.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("bk",))
def decode_attend(q: jax.Array, k: jax.Array, v: jax.Array,
                  pos: jax.Array, qpos: jax.Array, idx: jax.Array,
                  bk: int) -> jax.Array:
    """Single-token attention over gathered cluster tiles.

    q (B,Hq,dh); k/v (B,Hkv,S,dh); pos (B,Hkv,S); idx (B,Hkv,c) tile ids.
    Returns (B,Hq,dh). Entries with pos > qpos are masked (cache slots not
    yet filled, or future positions).
    """
    b, hq, dh = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    dv = v.shape[-1]
    nkb = s // bk
    kb = k.reshape(b, hkv, nkb, bk, dh)
    vb = v.reshape(b, hkv, nkb, bk, dv)
    pb = pos.reshape(b, hkv, nkb, bk)

    def per_bh(qh, kt, vt, pt, it):
        # qh (g,dh)  kt (nkb,bk,dh)  vt (nkb,bk,dv)  pt (nkb,bk)  it (c,)
        ksel = kt[it].reshape(-1, dh)          # (c*bk, dh)
        vsel = vt[it].reshape(-1, dv)
        psel = pt[it].reshape(-1)
        logit = decode_logits(qh.astype(jnp.float32),
                              ksel.astype(jnp.float32))
        # guarded: an early-position decode can select only holes/future
        # tiles, and an unguarded softmax would weight them uniformly
        w = masked_softmax(logit, psel[None, :] <= qpos)
        return decode_combine(w, vsel.astype(jnp.float32)).astype(q.dtype)

    out = jax.vmap(jax.vmap(per_bh))(
        q.reshape(b, hkv, g, dh), kb, vb, pb, idx)
    return out.reshape(b, hq, dv)
