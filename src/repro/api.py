"""Unified planner API: one object from points -> ordering -> BSR -> SpMV.

The paper's method is a pipeline; ``build_plan`` runs it end-to-end and
returns an :class:`InteractionPlan` that owns every stage's artifact:

  paper section                       plan artifact
  -------------------------------------------------------------------------
  §2.2  patch-density model           ``plan.gamma`` (Eq. 4 score of the
                                      reordered pattern), ``plan.fill``
  §2.3  ordering quality (γ-score)    computed per ordering; compare by
                                      building profile-only plans
                                      (``with_bsr=False``) per ordering
  §2.4  step 1: low-dim embedding     ``plan.embedding`` (PCA coords)
  §2.4  step 2: hierarchical          ``plan.tree`` (adaptive 2^d tree),
        partitioning                  ``plan.pi`` / ``permute`` /
                                      ``unpermute`` (cluster ordering)
  §2.4  step 3: multi-level           ``plan.bsr`` (two-level ELL-BSR,
        compressed storage            registered as a JAX pytree)
  §2.4  step 4: block-segment         ``plan.apply`` / ``plan.matvec`` over
        interaction                   the pluggable backend registry;
                                      iterative value updates via
                                      ``plan.tsne_attractive`` (§3.1) and
                                      ``plan.meanshift_step`` (§3.2)

Index spaces: ``plan.apply(x)`` computes ``y = A' x`` in *cluster order*
(``A' = P A Pᵀ``); ``plan.matvec(x)`` is the original-order convenience
``unpermute(apply(permute(x)))``. Backends are named entries in
``repro.core.registry`` (``csr``, ``bsr``, ``bsr_ml``, ``pallas``, ``dist``,
plus anything user-registered); ``backend="auto"`` lets
``core.autotune.tune_backend`` probe the registry and pick the fastest for
this plan's shapes.

Plans and their BSR are JAX pytrees: array state (tiles, indices,
permutation) flattens to leaves while layout metadata and host-side
artifacts (tree, COO, stats) ride along as static aux data, so plans cross
``jit`` / ``scan`` / ``shard_map`` boundaries intact.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import interact, knn, measures
from repro.core import ordering as ordering_mod
from repro.core.blocksparse import BSR, build_bsr
from repro.core.embedding import embed
from repro.core.hierarchy import Tree, build_tree
from repro.core.ordering import ORDERINGS  # noqa: F401  (re-export)
from repro.core.registry import (backend_names, get_backend,  # noqa: F401
                                 register_backend)

__all__ = [
    "PlanConfig", "InteractionPlan", "build_plan", "cluster_order",
    "ORDERINGS", "register_backend", "backend_names", "get_backend",
]


@dataclass(frozen=True)
class PlanConfig:
    """Static knobs of an interaction plan (hashable; jit-cache friendly)."""
    k: int = 16                  # neighbors per target (Eq. 1 pattern)
    ordering: str = "dual_tree"  # one of core.ordering.ORDERINGS
    bs: int = 32                 # bottom-level tile size (MXU-aligned)
    sb: int = 8                  # superblock size, in tiles
    backend: str = "auto"        # registry name or "auto"
    d: int = 3                   # embedding dimension (§2.4 step 1)
    bits: int = 10               # Morton quantization bits per dim
    leaf_size: int = 64          # adaptive-tree leaf bound (§2.4 step 2)
    symmetrize: bool = False     # symmetrize the kNN pattern
    seed: int = 0


@dataclasses.dataclass(eq=False)
class _PlanHost:
    """Host-side (numpy) artifacts of a plan.

    Identity-hashed static aux data: not traced, shared across pytree
    flatten/unflatten round-trips (so e.g. the autotune cache survives jit).
    """
    pi: np.ndarray                       # sorted position -> original index
    inv: np.ndarray                      # original index -> sorted position
    coo: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # reordered
    tree: Optional[Tree]
    embedding: Optional[np.ndarray]      # (n, d) PCA coords (§2.4 step 1)
    sigma: float = 1.0                   # γ-score bandwidth (Eq. 4)
    gamma: Optional[float] = None        # lazily scored on first access
    tuned_backend: dict = dataclasses.field(default_factory=dict)
    # ^ backend="auto" winners, keyed by charge ndim: a backend valid for
    #   1-D vectors (e.g. dist) must not be pinned for (n, f) charges
    coo_dev: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None


def _symmetrize_pattern(rows: np.ndarray, cols: np.ndarray,
                        aux: np.ndarray, n: int):
    """Pattern-union symmetrization; first occurrence of an (i, j) wins
    for the rider array ``aux`` (values or distances)."""
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    a2 = np.concatenate([aux, aux])
    key = r2.astype(np.int64) * n + c2
    _, first = np.unique(key, return_index=True)
    return r2[first], c2[first], a2[first]


class InteractionPlan:
    """Planner object owning ordering, storage, and compute backend."""

    def __init__(self, config: PlanConfig, n: int, bsr: Optional[BSR],
                 pi: jax.Array, inv: jax.Array, host: _PlanHost):
        self.config = config
        self.n = n
        self.bsr = bsr
        self.pi = pi
        self.inv = inv
        self.host = host

    # -- construction ------------------------------------------------------

    @classmethod
    def from_coo(cls, rows, cols, vals, n: int, *,
                 x: Optional[np.ndarray] = None,
                 pi: Optional[np.ndarray] = None,
                 config: Optional[PlanConfig] = None,
                 sigma: Optional[float] = None,
                 with_bsr: bool = True,
                 max_nbr: Optional[int] = None,
                 _symmetrized: bool = False,
                 **overrides) -> "InteractionPlan":
        """Plan from an explicit COO pattern (original index space).

        The ordering is ``pi`` if given, else computed from ``x`` with
        ``config.ordering``, else identity (pattern already cluster-ordered).
        """
        config = dataclasses.replace(config or PlanConfig(), **overrides)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = (np.ones(len(rows), np.float32) if vals is None
                else np.asarray(vals, np.float32))
        if config.symmetrize and not _symmetrized:
            rows, cols, vals = _symmetrize_pattern(rows, cols, vals, n)

        tree = None
        embedding = None
        if pi is None and x is not None:
            x = np.asarray(x, np.float32)
            if config.ordering == "dual_tree":
                embedding = np.asarray(embed(jnp.asarray(x), config.d))
                tree = build_tree(embedding, bits=config.bits,
                                  leaf_size=config.leaf_size)
                pi = tree.perm
            else:
                pi = ordering_mod.compute_ordering(
                    config.ordering, x, rows, cols, seed=config.seed)
        if pi is None:
            pi = np.arange(n)
        pi = np.asarray(pi)
        inv = np.empty_like(pi)
        inv[pi] = np.arange(n)

        r2, c2 = ordering_mod.apply_ordering(rows, cols, pi)
        sigma = sigma if sigma is not None else max(config.k / 2.0, 1.0)
        bsr = (build_bsr(r2, c2, vals, n, bs=config.bs, sb=config.sb,
                         max_nbr=max_nbr) if with_bsr else None)
        host = _PlanHost(pi=pi, inv=inv, coo=(r2, c2, vals), tree=tree,
                         embedding=embedding, sigma=sigma)
        return cls(config, n, bsr, jnp.asarray(pi, jnp.int32),
                   jnp.asarray(inv, jnp.int32), host)

    @classmethod
    def from_bsr(cls, bsr: BSR,
                 config: Optional[PlanConfig] = None) -> "InteractionPlan":
        """Wrap an existing BSR (identity ordering, no COO/tree/gamma)."""
        config = config or PlanConfig(bs=bsr.bs, sb=bsr.sb, backend="bsr")
        pi = np.arange(bsr.n)
        host = _PlanHost(pi=pi, inv=pi, coo=None, tree=None, embedding=None)
        dev = jnp.asarray(pi, jnp.int32)
        return cls(config, bsr.n, bsr, dev, dev, host)

    # -- stage artifacts ---------------------------------------------------

    @property
    def tree(self) -> Optional[Tree]:
        return self.host.tree

    @property
    def embedding(self) -> Optional[np.ndarray]:
        return self.host.embedding

    @property
    def coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Reordered COO ``(rows, cols, vals)`` (cluster index space)."""
        if self.host.coo is None:
            raise ValueError("plan has no COO pattern (built from_bsr)")
        return self.host.coo

    def coo_device(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Reordered COO as device arrays (cached — the csr backend is
        called repeatedly and must not re-upload O(nnz) data per call)."""
        if self.host.coo_dev is None:
            r, c, v = self.coo
            self.host.coo_dev = (jnp.asarray(r), jnp.asarray(c),
                                 jnp.asarray(v))
        return self.host.coo_dev

    @property
    def gamma(self) -> Optional[float]:
        """γ-score (Eq. 4) of the reordered pattern, computed lazily."""
        if self.host.gamma is None and self.host.coo is not None:
            r2, c2, _ = self.host.coo
            self.host.gamma = float(measures.gamma_score(
                jnp.asarray(r2), jnp.asarray(c2), self.host.sigma, self.n))
        return self.host.gamma

    @property
    def fill(self) -> Optional[float]:
        return self.bsr.fill if self.bsr is not None else None

    @property
    def stats(self) -> dict:
        kept = (int(np.asarray(self.bsr.nbr_mask).sum())
                if self.bsr is not None else 0)
        return {"n": self.n, "gamma": self.gamma, "fill": self.fill,
                "kept_tiles": kept,
                "max_nbr": self.bsr.max_nbr if self.bsr else None,
                "backend": self.resolve_backend(probe=False)}

    # -- permutation helpers (§2.4 step 2) ---------------------------------

    def permute(self, a):
        """Original order -> cluster order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.pi]
        return jnp.take(jnp.asarray(a), self.pi, axis=0)

    def unpermute(self, a):
        """Cluster order -> original order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.inv]
        return jnp.take(jnp.asarray(a), self.inv, axis=0)

    # -- backend resolution ------------------------------------------------

    def resolve_backend(self, name: Optional[str] = None,
                        probe: bool = True,
                        x: Optional[jax.Array] = None) -> str:
        """Resolve ``name`` (default: the config backend); ``"auto"`` is
        answered from the per-charge-shape tuned cache, probing the
        registry with ``x`` (or a synthetic 1-D vector) on first use."""
        name = name or self.config.backend
        if name != "auto":
            return name
        ndim = x.ndim if x is not None else 1
        if ndim not in self.host.tuned_backend and probe:
            if (self.bsr is None
                    or isinstance(self.bsr.vals, jax.core.Tracer)
                    or (x is not None and isinstance(x, jax.core.Tracer))):
                return "bsr"        # probing needs concrete arrays
            from repro.core.autotune import tune_backend
            self.host.tuned_backend[ndim], _ = tune_backend(self, x)
        return self.host.tuned_backend.get(ndim, "bsr")

    # -- interaction (§2.4 step 4) -----------------------------------------

    def apply(self, x: jax.Array, backend: Optional[str] = None,
              **kwargs) -> jax.Array:
        """``y = A' x`` in cluster order (``A'`` the reordered matrix)."""
        name = self.resolve_backend(backend, x=x)
        if self.bsr is None and name != "csr":
            raise ValueError(
                f"profile-only plan has no BSR for backend {name!r}; "
                "rebuild with with_bsr=True (only 'csr' runs off the COO)")
        return get_backend(name)(self, x, **kwargs)

    def matvec(self, x: jax.Array, backend: Optional[str] = None,
               **kwargs) -> jax.Array:
        """``y = A x`` in original order: unpermute ∘ apply ∘ permute."""
        return self.unpermute(self.apply(self.permute(x), backend, **kwargs))

    # -- iterative value-update hooks (paper §3) ---------------------------

    def tsne_attractive(self, y: jax.Array) -> jax.Array:
        """t-SNE attractive force (§3.1) on embedding ``y`` (cluster order);
        the stored tiles are the (fixed-profile) affinities ``p``."""
        b = self._require_bsr()
        return interact.tsne_attractive(b.vals, b.col_idx, b.nbr_mask,
                                        y, self.n)

    def meanshift_step(self, targets: jax.Array, sources: jax.Array,
                       h2: float) -> jax.Array:
        """One mean-shift iteration (§3.2). ``sources`` (n, d) in cluster
        order; the stored tiles are the 0/1 neighbor pattern."""
        b = self._require_bsr()
        s = jnp.asarray(sources)
        pad = b.n_cb * b.bs - s.shape[0]
        if pad:
            s = jnp.pad(s, ((0, pad), (0, 0)))
        s_blocked = s.reshape(b.n_cb, b.bs, -1)
        return interact.meanshift_step(b.vals, b.col_idx, s_blocked,
                                       jnp.asarray(targets), h2, self.n)

    def with_values(self, vals) -> "InteractionPlan":
        """New plan with the same pattern/ordering but fresh edge values
        (aligned with ``plan.coo``). Storage shapes are pinned
        (``max_nbr`` carried over), so the per-backend jitted kernels and
        any ``jit(plan.apply)``-style closures keep their compile caches;
        a plan passed *as a jit argument* still retraces once (its static
        host aux is a fresh identity)."""
        r2, c2, _ = self.coo
        vals = np.asarray(vals, np.float32)
        b = self._require_bsr()
        bsr = build_bsr(r2, c2, vals, self.n, bs=b.bs, sb=b.sb,
                        max_nbr=b.max_nbr)
        host = dataclasses.replace(self.host, coo=(r2, c2, vals),
                                   coo_dev=None)
        return InteractionPlan(self.config, self.n, bsr, self.pi, self.inv,
                               host)

    def _require_bsr(self) -> BSR:
        if self.bsr is None:
            raise ValueError("profile-only plan: rebuild with with_bsr=True")
        return self.bsr

    def __repr__(self) -> str:
        g = (f"{self.host.gamma:.2f}" if self.host.gamma is not None
             else "unscored" if self.host.coo is not None else "n/a")
        f = f"{self.fill:.3f}" if self.fill is not None else "n/a"
        return (f"InteractionPlan(n={self.n}, ordering="
                f"{self.config.ordering!r}, bs={self.config.bs}, "
                f"sb={self.config.sb}, gamma={g}, fill={f}, "
                f"backend={self.config.backend!r})")

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        return (self.bsr, self.pi, self.inv), (self.config, self.n, self.host)

    @classmethod
    def tree_unflatten(cls, aux, children):
        config, n, host = aux
        bsr, pi, inv = children
        return cls(config, n, bsr, pi, inv, host)


jax.tree_util.register_pytree_node(
    InteractionPlan, InteractionPlan.tree_flatten,
    InteractionPlan.tree_unflatten)


def cluster_order(x, *, ordering: str = "dual_tree", d: int = 3,
                  bits: int = 10, leaf_size: int = 64,
                  seed: int = 0) -> np.ndarray:
    """Pipeline steps 1–2 only (§2.4): the cluster permutation of ``x``,
    with no interaction pattern built. Cheap when only the ordering is
    needed (e.g. pre-sorting a fixed source set). Graph-based orderings
    (``rcm``) need a pattern — use :func:`build_plan` for those.
    """
    x = np.asarray(x, np.float32)
    if ordering == "rcm":
        raise ValueError("rcm needs an interaction pattern; use build_plan")
    if ordering == "dual_tree":
        y = np.asarray(embed(jnp.asarray(x), d))
        return build_tree(y, bits=bits, leaf_size=leaf_size).perm
    return ordering_mod.compute_ordering(ordering, x, np.empty(0, np.int64),
                                         np.empty(0, np.int64), seed=seed)


def build_plan(x, *, k: int = 16, ordering: str = "dual_tree", bs: int = 32,
               sb: int = 8, backend: str = "auto", d: int = 3,
               bits: int = 10, leaf_size: int = 64, symmetrize: bool = False,
               seed: int = 0,
               values: "np.ndarray | Callable | None" = None,
               sigma: Optional[float] = None,
               with_bsr: bool = True) -> InteractionPlan:
    """Run the full pipeline (§2.4) over points ``x`` (n, D).

    Builds the kNN interaction pattern (Eq. 1), orders it, scores it (γ,
    Eq. 4), and compresses it into the two-level ELL-BSR. ``values`` dresses
    the pattern: ``None`` -> 1.0 per edge, an array aligned with the
    (row-major, post-symmetrization) kNN edges, or a callable
    ``f(rows, cols, dist2) -> vals``. ``with_bsr=False`` builds a
    profile-only plan (ordering + γ, no storage) — cheap for comparing
    orderings as in §2.3.
    """
    config = PlanConfig(k=k, ordering=ordering, bs=bs, sb=sb,
                        backend=backend, d=d, bits=bits,
                        leaf_size=leaf_size, symmetrize=symmetrize,
                        seed=seed)
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    xd = jnp.asarray(x)
    rows, cols, d2 = knn.knn_coo(xd, xd, k, exclude_self=True)
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    d2 = np.asarray(d2)

    if symmetrize:
        # pattern-level symmetrization (first occurrence wins, like the
        # paper's Fig. 2 interaction patterns) — before values, so a
        # callable sees the symmetrized edge list
        rows, cols, d2 = _symmetrize_pattern(rows, cols, d2, n)

    if values is None:
        vals = np.ones(len(rows), np.float32)
    elif callable(values):
        vals = np.asarray(values(rows, cols, d2), np.float32)
    else:
        vals = np.asarray(values, np.float32)
        if vals.shape[0] != len(rows):
            raise ValueError(
                f"values has {vals.shape[0]} entries, pattern has "
                f"{len(rows)} edges (symmetrize={symmetrize})")

    return InteractionPlan.from_coo(rows, cols, vals, n, x=x, config=config,
                                    sigma=sigma, with_bsr=with_bsr,
                                    _symmetrized=True)
