"""Unified planner API: one object from points -> ordering -> BSR -> SpMV.

The paper's method is a pipeline; ``build_plan`` runs it end-to-end and
returns an :class:`InteractionPlan` that owns every stage's artifact:

  paper section                       plan artifact
  -------------------------------------------------------------------------
  §2.2  patch-density model           ``plan.gamma`` (Eq. 4 score of the
                                      reordered pattern), ``plan.fill``
  §2.3  ordering quality (γ-score)    computed per ordering; compare by
                                      building profile-only plans
                                      (``with_bsr=False``) per ordering
  §2.4  step 1: low-dim embedding     ``plan.embedding`` (PCA coords)
  §2.4  step 2: hierarchical          ``plan.tree`` (adaptive 2^d tree),
        partitioning                  ``plan.pi`` / ``permute`` /
                                      ``unpermute`` (cluster ordering)
  §2.4  step 3: multi-level           ``plan.bsr`` (two-level ELL-BSR,
        compressed storage            registered as a JAX pytree)
  §2.4  step 4: block-segment         ``plan.apply`` / ``plan.matvec`` over
        interaction                   the pluggable backend registry;
                                      iterative value updates via
                                      ``plan.tsne_attractive`` (§3.1) and
                                      ``plan.meanshift_step`` (§3.2)

Index spaces: ``plan.apply(x)`` computes ``y = A' x`` in *cluster order*
(``A' = P A Pᵀ``); ``plan.matvec(x)`` is the original-order convenience
``unpermute(apply(permute(x)))``. Backends are named entries in
``repro.core.registry`` (``csr``, ``bsr``, ``bsr_ml``, ``pallas``, ``dist``,
plus anything user-registered); ``backend="auto"`` lets
``core.autotune.tune_backend`` probe the registry and pick the fastest for
this plan's shapes.

Plans and their BSR are JAX pytrees: array state (tiles, indices,
permutation) flattens to leaves while layout metadata and host-side
artifacts (tree, COO, stats) ride along as static aux data, so plans cross
``jit`` / ``scan`` / ``shard_map`` boundaries intact.

Plan lifecycle: build -> apply -> refresh -> persist
----------------------------------------------------

A plan is a *refreshable, durable* asset, not a one-shot artifact. For the
paper's iterative case studies (§3.1 t-SNE, §3.2 mean shift) the points
move every iteration; rebuilding embedding -> tree -> ordering -> BSR from
scratch each time forfeits exactly the cost the multi-scale structure
amortizes. Instead::

    plan = build_plan(x, k=16)                  # build (once)
    y = plan.matvec(charges)                    # apply (every iteration)
    for step in range(iters):
        x = advance(x)                          # points move
        plan = plan.refresh(x)                  # patch / re-bucket / rebuild
    ckpt = Checkpointer(dir)
    ckpt.save_plan(step, plan, blocking=True)   # persist (serving restarts
    plan, _ = ckpt.restore_plan(                #   skip planning; stale
        refresh_with=x_current)                 #   plans refresh on load)

``refresh`` re-embeds the moved points through the *stored* PCA map, codes
old and new coordinates against a joint bounding box, and compares Morton
cells at the tree's leaf granularity. The migrated fraction (and recorded
fill/γ degradation — ``core.measures.gamma_drift``) picks one of three
escalation tiers against ``PlanConfig.refresh_policy``:

  patch      permutation kept; kNN recomputed for migrated rows only and
             the affected BSR row-block tiles patched in place
  rebucket   stable partial reorder (unmoved runs keep their order; see
             ``core.ordering.stable_partial_reorder``), tree levels
             re-bucketed from new codes, storage rebuilt — but the PCA
             embedding map, quantization frame, and unmigrated kNN rows
             are all reused
  rebuild    full ``build_plan`` (fresh embedding fit, tree, kNN, BSR)

γ and fill are recomputed lazily after a refresh (``plan.gamma`` /
``plan.gamma_drift()``), so the hot loop never pays for scoring it does
not read.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hierarchy, interact, knn, measures
from repro.core import ordering as ordering_mod
from repro.core.blocksparse import BSR, build_bsr, patch_bsr
from repro.core.embedding import apply_pca_map, embed, pca_map
from repro.core.hierarchy import Tree, build_tree
from repro.core.ordering import ORDERINGS  # noqa: F401  (re-export)
from repro.core.registry import (backend_names, get_backend,  # noqa: F401
                                 register_backend)
from repro.core.shardplan import ShardedPlan, shard  # noqa: F401

__all__ = [
    "PlanConfig", "InteractionPlan", "RefreshStats", "build_plan",
    "refresh_plan", "cluster_order", "shard", "ShardedPlan",
    "ORDERINGS", "register_backend", "backend_names", "get_backend",
]


@dataclass(frozen=True)
class PlanConfig:
    """Static knobs of an interaction plan (hashable; jit-cache friendly)."""
    k: int = 16                  # neighbors per target (Eq. 1 pattern)
    ordering: str = "dual_tree"  # one of core.ordering.ORDERINGS
    bs: int = 32                 # bottom-level tile size (MXU-aligned)
    sb: int = 8                  # superblock size, in tiles
    backend: str = "auto"        # registry name or "auto"
    d: int = 3                   # embedding dimension (§2.4 step 1)
    bits: int = 10               # Morton quantization bits per dim
    leaf_size: int = 64          # adaptive-tree leaf bound (§2.4 step 2)
    symmetrize: bool = False     # symmetrize the kNN pattern
    seed: int = 0
    # -- refresh lifecycle (refresh_plan escalation policy) -----------------
    refresh_policy: str = "auto"  # auto | patch | rebucket | rebuild
    patch_frac: float = 0.10     # auto: ordering drift <= this -> patch
    rebuild_frac: float = 0.40   # auto: ordering drift > this -> rebuild
    drift_tol: float = 0.25      # fill/γ degradation that forces escalation
    ell_slack: int = 0           # spare ELL tile slots per row-block, so
    #   an in-place patch can add neighbor tiles without escalating


@dataclasses.dataclass
class RefreshStats:
    """Lifecycle telemetry of a plan lineage (mutable, host-side).

    ``ordering_drift_frac`` is the fraction of points whose Morton cell
    differs from the cell the *current ordering* was derived from (resets
    on rebucket/rebuild); ``last_migrated_frac`` is measured against the
    previous refresh (what the last patch actually had to touch).
    """
    builds: int = 1
    patches: int = 0
    rebuckets: int = 0
    rebuilds: int = 0
    last_action: str = "build"
    last_migrated_frac: float = 0.0
    ordering_drift_frac: float = 0.0
    patched_rows: int = 0
    fill0: Optional[float] = None     # fill at last (re)build of the layout
    gamma0: Optional[float] = None    # γ reference for gamma_drift
    degraded: bool = False            # fill drift beyond tol -> escalate


@dataclasses.dataclass(eq=False)
class _PlanHost:
    """Host-side (numpy) artifacts of a plan.

    Identity-hashed static aux data: not traced, shared across pytree
    flatten/unflatten round-trips (so e.g. the autotune cache survives jit).
    """
    pi: np.ndarray                       # sorted position -> original index
    inv: np.ndarray                      # original index -> sorted position
    coo: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # reordered
    tree: Optional[Tree]
    embedding: Optional[np.ndarray]      # (n, d) PCA coords the *current
    #   ordering* was derived from (refresh measures drift against these)
    sigma: float = 1.0                   # γ-score bandwidth (Eq. 4)
    gamma: Optional[float] = None        # lazily scored on first access
    tuned_backend: dict = dataclasses.field(default_factory=dict)
    # ^ backend="auto" winners, keyed by charge ndim: a backend valid for
    #   1-D vectors (e.g. dist) must not be pinned for (n, f) charges
    coo_dev: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None
    # -- refresh lifecycle state -------------------------------------------
    embed_mean: Optional[np.ndarray] = None   # (D,) fitted PCA map: moved
    embed_axes: Optional[np.ndarray] = None   # (D, d) points re-embed here
    y_last: Optional[np.ndarray] = None  # (n, d) coords at last refresh
    #   (a patch touches only rows whose cell changed since then)
    sources: Optional[np.ndarray] = None  # fixed source set, original order
    pattern_from_knn: bool = False       # pattern derives from the coords
    values_mode: str = "ones"            # ones | fn | static
    values_fn: Optional[Callable] = None
    refresh: RefreshStats = dataclasses.field(default_factory=RefreshStats)
    last_patch_rb: Optional[np.ndarray] = None  # row-blocks the last patch
    #   tier touched (None once the ordering changed) — ShardedPlan.refresh
    #   patches exactly these shards instead of re-sharding
    shard_cache: dict = dataclasses.field(default_factory=dict)
    # ^ ShardedPlan per (n_dev, axis) for the "dist" backend; entries are
    #   validated by BSR identity, so a refreshed lineage re-shards lazily


def _symmetrize_pattern(rows: np.ndarray, cols: np.ndarray,
                        aux: np.ndarray, n: int):
    """Pattern-union symmetrization; first occurrence of an (i, j) wins
    for the rider array ``aux`` (values or distances)."""
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    a2 = np.concatenate([aux, aux])
    key = r2.astype(np.int64) * n + c2
    _, first = np.unique(key, return_index=True)
    return r2[first], c2[first], a2[first]


class InteractionPlan:
    """Planner object owning ordering, storage, and compute backend."""

    def __init__(self, config: PlanConfig, n: int, bsr: Optional[BSR],
                 pi: jax.Array, inv: jax.Array, host: _PlanHost):
        self.config = config
        self.n = n
        self.bsr = bsr
        self.pi = pi
        self.inv = inv
        self.host = host

    # -- construction ------------------------------------------------------

    @classmethod
    def from_coo(cls, rows, cols, vals, n: int, *,
                 x: Optional[np.ndarray] = None,
                 pi: Optional[np.ndarray] = None,
                 config: Optional[PlanConfig] = None,
                 sigma: Optional[float] = None,
                 with_bsr: bool = True,
                 max_nbr: Optional[int] = None,
                 _symmetrized: bool = False,
                 **overrides) -> "InteractionPlan":
        """Plan from an explicit COO pattern (original index space).

        The ordering is ``pi`` if given, else computed from ``x`` with
        ``config.ordering``, else identity (pattern already cluster-ordered).
        """
        config = dataclasses.replace(config or PlanConfig(), **overrides)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = (np.ones(len(rows), np.float32) if vals is None
                else np.asarray(vals, np.float32))
        if config.symmetrize and not _symmetrized:
            rows, cols, vals = _symmetrize_pattern(rows, cols, vals, n)

        tree = None
        embedding = None
        emean = eaxes = None
        if pi is None and x is not None:
            x = np.asarray(x, np.float32)
            if config.ordering == "dual_tree":
                d = min(config.d, x.shape[1])
                emean, eaxes = (np.asarray(a) for a in
                                pca_map(jnp.asarray(x), d))
                embedding = np.asarray(apply_pca_map(
                    jnp.asarray(x), jnp.asarray(emean), jnp.asarray(eaxes)))
                tree = build_tree(embedding, bits=config.bits,
                                  leaf_size=config.leaf_size)
                pi = tree.perm
            else:
                pi = ordering_mod.compute_ordering(
                    config.ordering, x, rows, cols, seed=config.seed)
        if pi is None:
            pi = np.arange(n)
        pi = np.asarray(pi)
        inv = np.empty_like(pi)
        inv[pi] = np.arange(n)

        r2, c2 = ordering_mod.apply_ordering(rows, cols, pi)
        sigma = sigma if sigma is not None else max(config.k / 2.0, 1.0)
        bsr = (build_bsr(r2, c2, vals, n, bs=config.bs, sb=config.sb,
                         max_nbr=max_nbr, slack=config.ell_slack)
               if with_bsr else None)
        host = _PlanHost(pi=pi, inv=inv, coo=(r2, c2, vals), tree=tree,
                         embedding=embedding, sigma=sigma,
                         embed_mean=emean, embed_axes=eaxes,
                         y_last=embedding)
        host.refresh.fill0 = bsr.fill if bsr is not None else None
        return cls(config, n, bsr, jnp.asarray(pi, jnp.int32),
                   jnp.asarray(inv, jnp.int32), host)

    @classmethod
    def from_bsr(cls, bsr: BSR,
                 config: Optional[PlanConfig] = None) -> "InteractionPlan":
        """Wrap an existing BSR (identity ordering, no COO/tree/gamma)."""
        config = config or PlanConfig(bs=bsr.bs, sb=bsr.sb, backend="bsr")
        pi = np.arange(bsr.n)
        host = _PlanHost(pi=pi, inv=pi, coo=None, tree=None, embedding=None)
        dev = jnp.asarray(pi, jnp.int32)
        return cls(config, bsr.n, bsr, dev, dev, host)

    # -- stage artifacts ---------------------------------------------------

    @property
    def tree(self) -> Optional[Tree]:
        return self.host.tree

    @property
    def embedding(self) -> Optional[np.ndarray]:
        return self.host.embedding

    @property
    def coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Reordered COO ``(rows, cols, vals)`` (cluster index space)."""
        if self.host.coo is None:
            raise ValueError("plan has no COO pattern (built from_bsr)")
        return self.host.coo

    def coo_device(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Reordered COO as device arrays (cached — the csr backend is
        called repeatedly and must not re-upload O(nnz) data per call)."""
        if self.host.coo_dev is None:
            r, c, v = self.coo
            self.host.coo_dev = (jnp.asarray(r), jnp.asarray(c),
                                 jnp.asarray(v))
        return self.host.coo_dev

    @property
    def gamma(self) -> Optional[float]:
        """γ-score (Eq. 4) of the reordered pattern, computed lazily."""
        if self.host.gamma is None and self.host.coo is not None:
            r2, c2, _ = self.host.coo
            self.host.gamma = float(measures.gamma_score(
                jnp.asarray(r2), jnp.asarray(c2), self.host.sigma, self.n))
        return self.host.gamma

    @property
    def fill(self) -> Optional[float]:
        return self.bsr.fill if self.bsr is not None else None

    @property
    def stats(self) -> dict:
        kept = (int(np.asarray(self.bsr.nbr_mask).sum())
                if self.bsr is not None else 0)
        return {"n": self.n, "gamma": self.gamma, "fill": self.fill,
                "kept_tiles": kept,
                "max_nbr": self.bsr.max_nbr if self.bsr else None,
                "backend": self.resolve_backend(probe=False)}

    # -- permutation helpers (§2.4 step 2) ---------------------------------

    def permute(self, a):
        """Original order -> cluster order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.pi]
        return jnp.take(jnp.asarray(a), self.pi, axis=0)

    def unpermute(self, a):
        """Cluster order -> original order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.inv]
        return jnp.take(jnp.asarray(a), self.inv, axis=0)

    # -- backend resolution ------------------------------------------------

    def resolve_backend(self, name: Optional[str] = None,
                        probe: bool = True,
                        x: Optional[jax.Array] = None) -> str:
        """Resolve ``name`` (default: the config backend); ``"auto"`` is
        answered from the per-charge-shape tuned cache, probing the
        registry with ``x`` (or a synthetic 1-D vector) on first use."""
        name = name or self.config.backend
        if name != "auto":
            return name
        ndim = x.ndim if x is not None else 1
        if ndim not in self.host.tuned_backend and probe:
            if (self.bsr is None
                    or isinstance(self.bsr.vals, jax.core.Tracer)
                    or (x is not None and isinstance(x, jax.core.Tracer))):
                return "bsr"        # probing needs concrete arrays
            from repro.core.autotune import tune_backend
            self.host.tuned_backend[ndim], _ = tune_backend(self, x)
        return self.host.tuned_backend.get(ndim, "bsr")

    # -- interaction (§2.4 step 4) -----------------------------------------

    def apply(self, x: jax.Array, backend: Optional[str] = None,
              **kwargs) -> jax.Array:
        """``y = A' x`` in cluster order (``A'`` the reordered matrix)."""
        name = self.resolve_backend(backend, x=x)
        if self.bsr is None and name != "csr":
            raise ValueError(
                f"profile-only plan has no BSR for backend {name!r}; "
                "rebuild with with_bsr=True (only 'csr' runs off the COO)")
        return get_backend(name)(self, x, **kwargs)

    def matvec(self, x: jax.Array, backend: Optional[str] = None,
               **kwargs) -> jax.Array:
        """``y = A x`` in original order: unpermute ∘ apply ∘ permute."""
        return self.unpermute(self.apply(self.permute(x), backend, **kwargs))

    # -- iterative value-update hooks (paper §3) ---------------------------

    def tsne_attractive(self, y: jax.Array) -> jax.Array:
        """t-SNE attractive force (§3.1) on embedding ``y`` (cluster order);
        the stored tiles are the (fixed-profile) affinities ``p``."""
        b = self._require_bsr()
        return interact.tsne_attractive(b.vals, b.col_idx, b.nbr_mask,
                                        y, self.n)

    def meanshift_step(self, targets: jax.Array, sources: jax.Array,
                       h2: float) -> jax.Array:
        """One mean-shift iteration (§3.2). ``sources`` (n, d) in cluster
        order; the stored tiles are the 0/1 neighbor pattern."""
        b = self._require_bsr()
        s = jnp.asarray(sources)
        pad = b.n_cb * b.bs - s.shape[0]
        if pad:
            s = jnp.pad(s, ((0, pad), (0, 0)))
        s_blocked = s.reshape(b.n_cb, b.bs, -1)
        return interact.meanshift_step(b.vals, b.col_idx, s_blocked,
                                       jnp.asarray(targets), h2, self.n)

    def with_values(self, vals) -> "InteractionPlan":
        """New plan with the same pattern/ordering but fresh edge values
        (aligned with ``plan.coo``). Storage shapes are pinned
        (``max_nbr`` carried over), so the per-backend jitted kernels and
        any ``jit(plan.apply)``-style closures keep their compile caches;
        a plan passed *as a jit argument* still retraces once (its static
        host aux is a fresh identity)."""
        r2, c2, _ = self.coo
        vals = np.asarray(vals, np.float32)
        b = self._require_bsr()
        bsr = build_bsr(r2, c2, vals, self.n, bs=b.bs, sb=b.sb,
                        max_nbr=b.max_nbr)
        host = dataclasses.replace(self.host, coo=(r2, c2, vals),
                                   coo_dev=None, shard_cache={})
        return InteractionPlan(self.config, self.n, bsr, self.pi, self.inv,
                               host)

    def shard(self, mesh=None, axis: str = "data") -> ShardedPlan:
        """Per-device row-block shards with halo exchange — see
        :func:`repro.core.shardplan.shard`."""
        return shard(self, mesh, axis=axis)

    # -- lifecycle (refresh + drift monitoring) ----------------------------

    def refresh(self, x_new, *, policy: Optional[str] = None
                ) -> "InteractionPlan":
        """See :func:`refresh_plan`."""
        return refresh_plan(self, x_new, policy=policy)

    @property
    def refresh_stats(self) -> RefreshStats:
        return self.host.refresh

    def gamma_drift(self) -> float:
        """Relative γ degradation against the lineage's reference score
        (positive = locality got worse). The reference is pinned at the
        first scoring after a (re)build; γ itself is computed lazily, so
        hot loops that never call this never pay for scoring."""
        st = self.host.refresh
        g = self.gamma
        if st.gamma0 is None:
            st.gamma0 = g
            return 0.0
        return measures.gamma_drift(st.gamma0, g)

    def _require_bsr(self) -> BSR:
        if self.bsr is None:
            raise ValueError("profile-only plan: rebuild with with_bsr=True")
        return self.bsr

    def __repr__(self) -> str:
        g = (f"{self.host.gamma:.2f}" if self.host.gamma is not None
             else "unscored" if self.host.coo is not None else "n/a")
        f = f"{self.fill:.3f}" if self.fill is not None else "n/a"
        return (f"InteractionPlan(n={self.n}, ordering="
                f"{self.config.ordering!r}, bs={self.config.bs}, "
                f"sb={self.config.sb}, gamma={g}, fill={f}, "
                f"backend={self.config.backend!r})")

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        return (self.bsr, self.pi, self.inv), (self.config, self.n, self.host)

    @classmethod
    def tree_unflatten(cls, aux, children):
        config, n, host = aux
        bsr, pi, inv = children
        return cls(config, n, bsr, pi, inv, host)


jax.tree_util.register_pytree_node(
    InteractionPlan, InteractionPlan.tree_flatten,
    InteractionPlan.tree_unflatten)


def cluster_order(x, *, ordering: str = "dual_tree", d: int = 3,
                  bits: int = 10, leaf_size: int = 64,
                  seed: int = 0) -> np.ndarray:
    """Pipeline steps 1–2 only (§2.4): the cluster permutation of ``x``,
    with no interaction pattern built. Cheap when only the ordering is
    needed (e.g. pre-sorting a fixed source set). Graph-based orderings
    (``rcm``) need a pattern — use :func:`build_plan` for those.
    """
    x = np.asarray(x, np.float32)
    if ordering == "rcm":
        raise ValueError("rcm needs an interaction pattern; use build_plan")
    if ordering == "dual_tree":
        y = np.asarray(embed(jnp.asarray(x), d))
        return build_tree(y, bits=bits, leaf_size=leaf_size).perm
    return ordering_mod.compute_ordering(ordering, x, np.empty(0, np.int64),
                                         np.empty(0, np.int64), seed=seed)


def build_plan(x, *, k: int = 16, ordering: str = "dual_tree", bs: int = 32,
               sb: int = 8, backend: str = "auto", d: int = 3,
               bits: int = 10, leaf_size: int = 64, symmetrize: bool = False,
               seed: int = 0,
               values: "np.ndarray | Callable | None" = None,
               sigma: Optional[float] = None,
               with_bsr: bool = True,
               sources: Optional[np.ndarray] = None,
               config: Optional[PlanConfig] = None,
               **cfg_overrides) -> InteractionPlan:
    """Run the full pipeline (§2.4) over points ``x`` (n, D).

    Builds the kNN interaction pattern (Eq. 1), orders it, scores it (γ,
    Eq. 4), and compresses it into the two-level ELL-BSR. ``values`` dresses
    the pattern: ``None`` -> 1.0 per edge, an array aligned with the
    (row-major, post-symmetrization) kNN edges, or a callable
    ``f(rows, cols, dist2) -> vals`` (stored on the plan: ``refresh``
    re-dresses patched rows through it; a static array pins the pattern —
    refresh then only re-orders). ``with_bsr=False`` builds a profile-only
    plan (ordering + γ, no storage) — cheap for comparing orderings as in
    §2.3. ``sources`` (n, D) switches to the fixed-source-set pattern of
    §3.2: neighbors of the (moving) targets ``x`` among ``sources``; the
    target ordering is applied to both sides, so both must have n points.
    ``config`` overrides every individual knob at once (refresh reuses the
    lineage's config this way).
    """
    if config is None:
        config = PlanConfig(k=k, ordering=ordering, bs=bs, sb=sb,
                            backend=backend, d=d, bits=bits,
                            leaf_size=leaf_size, symmetrize=symmetrize,
                            seed=seed, **cfg_overrides)
    elif cfg_overrides:
        config = dataclasses.replace(config, **cfg_overrides)
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    if sources is not None:
        sources = np.asarray(sources, np.float32)
        if sources.shape[0] != n:
            raise ValueError(
                f"sources has {sources.shape[0]} points, targets have {n}; "
                "one ordering indexes both sides of the square plan")
        if config.symmetrize:
            raise ValueError("symmetrize crosses the target/source index "
                             "spaces; not meaningful with fixed sources")
    xd = jnp.asarray(x)
    sd = xd if sources is None else jnp.asarray(sources)
    rows, cols, d2 = knn.knn_coo(xd, sd, config.k,
                                 exclude_self=sources is None)
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    d2 = np.asarray(d2)

    if config.symmetrize:
        # pattern-level symmetrization (first occurrence wins, like the
        # paper's Fig. 2 interaction patterns) — before values, so a
        # callable sees the symmetrized edge list
        rows, cols, d2 = _symmetrize_pattern(rows, cols, d2, n)

    if values is None:
        vals = np.ones(len(rows), np.float32)
    elif callable(values):
        vals = np.asarray(values(rows, cols, d2), np.float32)
    else:
        vals = np.asarray(values, np.float32)
        if vals.shape[0] != len(rows):
            raise ValueError(
                f"values has {vals.shape[0]} entries, pattern has "
                f"{len(rows)} edges (symmetrize={config.symmetrize})")

    plan = InteractionPlan.from_coo(rows, cols, vals, n, x=x, config=config,
                                    sigma=sigma, with_bsr=with_bsr,
                                    _symmetrized=True)
    plan.host.pattern_from_knn = True
    plan.host.sources = sources
    if callable(values):
        plan.host.values_mode = "fn"
        plan.host.values_fn = values
    elif values is not None:
        plan.host.values_mode = "static"
    return plan


# ---------------------------------------------------------------------------
# plan refresh (lifecycle: the non-stationary targets of paper §3.2)
# ---------------------------------------------------------------------------


def _cmp_shift(n: int, d: int, bits: int, tree: Optional[Tree],
               leaf_size: int) -> int:
    """Morton-code shift at which cell identity is compared for migration.

    Uses the tree's realized depth (cells at leaf granularity) when one
    exists, else the depth a balanced 2^d tree would need for ~leaf_size
    points per cell. Comparing at full code resolution would flag every
    sub-cell wiggle as migration."""
    total = d * hierarchy.eff_bits(d, bits)
    if tree is not None and tree.n_levels > 1:
        level = tree.n_levels - 1
    else:
        cells_per_dim = max(float(n) / max(leaf_size, 1), 1.0) ** (1.0 / d)
        level = max(int(np.ceil(np.log2(max(cells_per_dim, 1.0)))), 1)
    return max(total - level * d, 0)


def _cell_migration(y_ref: np.ndarray, y_new: np.ndarray, bits: int,
                    shift: int) -> np.ndarray:
    """Mask of points whose Morton cell (at leaf granularity) changed.

    Both coordinate sets are quantized against their joint bounding box,
    so a global translation/expansion of the cloud (which leaves relative
    order intact) does not read as migration."""
    lo = jnp.asarray(np.minimum(y_ref.min(0), y_new.min(0)))
    hi = jnp.asarray(np.maximum(y_ref.max(0), y_new.max(0)))
    ca = np.asarray(hierarchy.morton_codes_box(jnp.asarray(y_ref), lo, hi,
                                               bits))
    cb = np.asarray(hierarchy.morton_codes_box(jnp.asarray(y_new), lo, hi,
                                               bits))
    return (ca >> shift) != (cb >> shift)


def _knn_subset(x_new: np.ndarray, rows_idx: np.ndarray,
                sources: Optional[np.ndarray], k: int):
    """Exact kNN edges (original index space) for a subset of target rows."""
    tq = jnp.asarray(x_new[rows_idx])
    # size the scan block to the subset (quantized to powers of two so a
    # lifetime of refreshes compiles a handful of kernels, not one per
    # migration count) — the default 1024 pads small patches 10x
    block = min(1 << max(7, int(np.ceil(np.log2(max(len(rows_idx), 1))))),
                1024)
    if sources is None:
        # targets are a subset of the sources: take k+1 and drop each
        # row's own point (knn_graph's exclude_self assumes aligned sets)
        idx, d2 = knn.knn_graph(tq, jnp.asarray(x_new), k + 1, block=block)
        idx, d2 = np.asarray(idx), np.asarray(d2)
        keep = idx != rows_idx[:, None]
        order = np.argsort(~keep, axis=1, kind="stable")  # kept first,
        idx = np.take_along_axis(idx, order, 1)[:, :k]    # distance order
        d2 = np.take_along_axis(d2, order, 1)[:, :k]      # preserved
    else:
        idx, d2 = knn.knn_graph(tq, jnp.asarray(sources), k, block=block)
        idx, d2 = np.asarray(idx), np.asarray(d2)
    return np.repeat(rows_idx, k), idx.reshape(-1), d2.reshape(-1)


def _edge_values(host: _PlanHost, rows, cols, d2) -> np.ndarray:
    if host.values_mode == "fn":
        return np.asarray(host.values_fn(rows, cols, d2), np.float32)
    return np.ones(len(rows), np.float32)


def _patch_pattern(host: _PlanHost, cfg: PlanConfig, n: int,
                   x_new: np.ndarray, rows_m: np.ndarray):
    """Original-space COO with migrated rows' kNN edges recomputed."""
    r2, c2, v2 = host.coo
    r_o, c_o = host.pi[r2], host.pi[c2]
    drop = np.isin(r_o, rows_m)
    if cfg.symmetrize:
        drop |= np.isin(c_o, rows_m)
    nr, nc, nd2 = _knn_subset(x_new, rows_m, host.sources, cfg.k)
    nv = _edge_values(host, nr, nc, nd2)
    if cfg.symmetrize:
        nr, nc, nv = _symmetrize_pattern(nr, nc, nv, n)
    r_all = np.concatenate([r_o[~drop], nr])
    c_all = np.concatenate([c_o[~drop], nc])
    v_all = np.concatenate([v2[~drop], nv])
    if cfg.symmetrize:  # mirrored new edges may duplicate kept ones
        key = r_all.astype(np.int64) * n + c_all
        _, first = np.unique(key, return_index=True)
        r_all, c_all, v_all = r_all[first], c_all[first], v_all[first]
    dropped_rows = r_o[drop]
    return r_all, c_all, v_all, dropped_rows


def _refresh_patch(plan: InteractionPlan, x_new, y_new, moved, stats,
                   moved_frac: float, drift_frac: float):
    """Cheapest tier: permutation kept, migrated rows' tiles patched in
    place. Returns None when a patched row-block overflows the pinned ELL
    width (caller escalates to rebucket)."""
    host, cfg, n = plan.host, plan.config, plan.n
    rows_m = np.nonzero(moved)[0]
    refreshes_pattern = (host.pattern_from_knn
                         and host.values_mode != "static"
                         and len(rows_m) > 0)
    stats = dataclasses.replace(
        stats, patches=stats.patches + 1, last_action="patch",
        last_migrated_frac=moved_frac, ordering_drift_frac=drift_frac,
        patched_rows=stats.patched_rows
        + (len(rows_m) if refreshes_pattern else 0))
    if not refreshes_pattern:
        # pattern does not follow the coords (or nothing changed cells):
        # bookkeeping only; ordering drift keeps accumulating
        host2 = dataclasses.replace(host, y_last=y_new, refresh=stats,
                                    last_patch_rb=np.empty(0, np.int64))
        return InteractionPlan(cfg, n, plan.bsr, plan.pi, plan.inv, host2)
    r_all, c_all, v_all, dropped_rows = _patch_pattern(host, cfg, n, x_new,
                                                       rows_m)
    r2n, c2n = ordering_mod.apply_ordering(r_all, c_all, host.pi)
    bsr = plan.bsr
    affected = np.concatenate([host.inv[dropped_rows], host.inv[rows_m]])
    touched_rb = np.unique(affected // cfg.bs)
    if bsr is not None:
        try:
            bsr = patch_bsr(bsr, r2n, c2n, v_all, touched_rb)
        except ValueError:
            return None
        if measures.fill_drift(stats.fill0, bsr.fill) > cfg.drift_tol:
            stats = dataclasses.replace(stats, degraded=True)
    host2 = dataclasses.replace(host, coo=(r2n, c2n, v_all), coo_dev=None,
                                gamma=None, y_last=y_new, refresh=stats,
                                last_patch_rb=touched_rb, shard_cache={})
    return InteractionPlan(cfg, n, bsr, plan.pi, plan.inv, host2)


def _refresh_rebucket(plan: InteractionPlan, x_new, y_new, moved, stats,
                      moved_frac: float) -> InteractionPlan:
    """Middle tier: stable partial reorder + re-bucketed tree levels;
    embedding map, quantization frame and unmigrated kNN rows reused."""
    host, cfg, n = plan.host, plan.config, plan.n
    if host.tree is not None:
        tree = hierarchy.rebucket(y_new, host.tree, cfg.leaf_size)
        pi = np.asarray(tree.perm)
    else:
        # every plan from_coo builds carries a tree alongside its embedding
        # map; this fallback covers externally restored hosts whose tree
        # arrays were not persisted (the ordering still refreshes)
        codes = np.asarray(hierarchy.morton_codes(jnp.asarray(y_new),
                                                  cfg.bits))
        pi = ordering_mod.stable_partial_reorder(host.pi, codes)
        tree = None
    inv = np.empty_like(pi)
    inv[pi] = np.arange(n)

    rows_m = np.nonzero(moved)[0]
    refreshes_pattern = (host.pattern_from_knn
                         and host.values_mode != "static"
                         and len(rows_m) > 0)
    if refreshes_pattern:
        r_o, c_o, v2, _ = _patch_pattern(host, cfg, n, x_new, rows_m)
    else:
        r2, c2, v2 = host.coo
        r_o, c_o = host.pi[r2], host.pi[c2]
    r2n, c2n = ordering_mod.apply_ordering(r_o, c_o, pi)
    bsr = (build_bsr(r2n, c2n, v2, n, bs=cfg.bs, sb=cfg.sb,
                     slack=cfg.ell_slack)
           if plan.bsr is not None else None)
    stats = dataclasses.replace(
        stats, rebuckets=stats.rebuckets + 1, last_action="rebucket",
        last_migrated_frac=moved_frac, ordering_drift_frac=0.0,
        patched_rows=stats.patched_rows
        + (len(rows_m) if refreshes_pattern else 0),
        fill0=bsr.fill if bsr is not None else None, gamma0=None,
        degraded=False)
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, coo=(r2n, c2n, v2), coo_dev=None, tree=tree,
        embedding=y_new, y_last=y_new, gamma=None, refresh=stats,
        tuned_backend={}, last_patch_rb=None, shard_cache={})
    return InteractionPlan(cfg, n, bsr, jnp.asarray(pi, jnp.int32),
                           jnp.asarray(inv, jnp.int32), host2)


def _refresh_rebuild(plan: InteractionPlan, x_new, stats,
                     moved_frac: float) -> InteractionPlan:
    """Top tier: the full pipeline again (fresh embedding fit, tree, kNN,
    BSR); only the config and lineage telemetry carry over."""
    host, cfg = plan.host, plan.config
    if host.pattern_from_knn and host.values_mode != "static":
        values = host.values_fn if host.values_mode == "fn" else None
        new = build_plan(x_new, config=cfg, values=values, sigma=host.sigma,
                         sources=host.sources,
                         with_bsr=plan.bsr is not None)
    else:
        r2, c2, v2 = host.coo
        r_o, c_o = host.pi[r2], host.pi[c2]
        new = InteractionPlan.from_coo(
            r_o, c_o, v2, plan.n, x=np.asarray(x_new, np.float32),
            config=cfg, sigma=host.sigma, with_bsr=plan.bsr is not None,
            _symmetrized=True)
        new.host.pattern_from_knn = host.pattern_from_knn
        new.host.values_mode = host.values_mode
        new.host.values_fn = host.values_fn
        new.host.sources = host.sources
    new.host.refresh = dataclasses.replace(
        new.host.refresh, builds=stats.builds + 1, patches=stats.patches,
        rebuckets=stats.rebuckets, rebuilds=stats.rebuilds + 1,
        last_action="rebuild", last_migrated_frac=moved_frac,
        patched_rows=stats.patched_rows)
    return new


def refresh_plan(plan: InteractionPlan, x_new,
                 *, policy: Optional[str] = None) -> InteractionPlan:
    """Refresh ``plan`` for moved points ``x_new`` (n, D, original order).

    Re-embeds the points through the plan's *stored* PCA map, detects
    Morton-cell migration at leaf granularity (old/new coords quantized
    jointly), and escalates through three tiers — see the module docstring:

      patch     permutation kept; kNN recomputed for migrated rows only,
                affected BSR row-block tiles patched in place
      rebucket  stable partial reorder + re-bucketed tree levels; storage
                rebuilt, everything upstream reused
      rebuild   full ``build_plan`` pipeline

    ``policy`` (or ``plan.config.refresh_policy``) forces a tier; the
    default ``"auto"`` picks by the ordering-drift fraction against
    ``PlanConfig.patch_frac`` / ``rebuild_frac``, with recorded fill
    degradation (``refresh_stats.degraded``) forcing escalation. The
    pattern follows the points only when edge values are recomputable
    (default 1.0 or a ``values`` callable); plans with static value arrays
    or an externally fixed COO pattern refresh their *ordering* only.
    Returns a new plan (the input is not mutated); γ/fill of the result
    are recomputed lazily.
    """
    host, cfg = plan.host, plan.config
    if host.embed_axes is None or host.embedding is None:
        raise ValueError(
            "plan is not refreshable: no stored embedding map (build with "
            "ordering='dual_tree' and coordinates x)")
    x_new = np.asarray(x_new, np.float32)
    if x_new.shape[0] != plan.n:
        raise ValueError(
            f"refresh expects the same {plan.n} points, got "
            f"{x_new.shape[0]} (insertion/deletion needs a fresh build)")
    if x_new.shape[1] != host.embed_axes.shape[0]:
        raise ValueError(
            f"refresh expects {host.embed_axes.shape[0]}-dim points, got "
            f"{x_new.shape[1]}")
    stats = host.refresh
    y_new = np.asarray(apply_pca_map(jnp.asarray(x_new),
                                     jnp.asarray(host.embed_mean),
                                     jnp.asarray(host.embed_axes)))
    d = y_new.shape[1]
    shift = _cmp_shift(plan.n, d, cfg.bits, host.tree, cfg.leaf_size)
    drift = _cell_migration(host.embedding, y_new, cfg.bits, shift)
    moved = _cell_migration(host.y_last, y_new, cfg.bits, shift)
    drift_frac = float(drift.mean())
    moved_frac = float(moved.mean())

    action = policy or cfg.refresh_policy
    if action == "auto":
        if drift_frac > cfg.rebuild_frac:
            action = "rebuild"
        elif drift_frac > cfg.patch_frac or stats.degraded:
            action = "rebucket"
        else:
            action = "patch"
    if action not in ("patch", "rebucket", "rebuild"):
        raise ValueError(f"unknown refresh policy {action!r}; expected "
                         "auto | patch | rebucket | rebuild")

    # free γ-reference snapshot: if a score was already computed for the
    # outgoing pattern, keep it as the drift baseline for this lineage
    if stats.gamma0 is None and host.gamma is not None:
        stats = dataclasses.replace(stats, gamma0=host.gamma)

    if action == "patch":
        out = _refresh_patch(plan, x_new, y_new, moved, stats, moved_frac,
                             drift_frac)
        if out is not None:
            return out
        action = "rebucket"  # pinned ELL width overflowed: escalate
    if action == "rebucket":
        return _refresh_rebucket(plan, x_new, y_new, moved, stats,
                                 moved_frac)
    return _refresh_rebuild(plan, x_new, stats, moved_frac)
