"""Unified planner API: one object from points -> ordering -> BSR -> SpMV.

The paper's method is a pipeline; ``build_plan`` runs it end-to-end and
returns an :class:`InteractionPlan` that owns every stage's artifact:

  paper section                       plan artifact
  -------------------------------------------------------------------------
  §2.2  patch-density model           ``plan.gamma`` (Eq. 4 score of the
                                      reordered pattern), ``plan.fill``
  §2.3  ordering quality (γ-score)    computed per ordering; compare by
                                      building profile-only plans
                                      (``with_bsr=False``) per ordering
  §2.4  step 1: low-dim embedding     ``plan.embedding`` (PCA coords)
  §2.4  step 2: hierarchical          ``plan.tree`` (adaptive 2^d tree),
        partitioning                  ``plan.pi`` / ``permute`` /
                                      ``unpermute`` (cluster ordering)
  §2.4  step 3: multi-level           ``plan.bsr`` (two-level ELL-BSR,
        compressed storage            registered as a JAX pytree)
  §2.4  step 4: block-segment         ``plan.apply`` / ``plan.matvec`` over
        interaction                   the pluggable backend registry;
                                      iterative value updates via
                                      ``plan.tsne_attractive`` (§3.1) and
                                      ``plan.meanshift_step`` (§3.2)

Index spaces: ``plan.apply(x)`` computes ``y = A' x`` in *cluster order*
(``A' = P A Pᵀ``); ``plan.matvec(x)`` is the original-order convenience
``unpermute(apply(permute(x)))``. Backends are named entries in
``repro.core.registry`` (``csr``, ``bsr``, ``bsr_ml``, ``pallas``, ``dist``,
plus anything user-registered); ``backend="auto"`` lets
``core.autotune.tune_backend`` probe the registry and pick the fastest for
this plan's shapes.

Plans and their BSR are JAX pytrees: array state (tiles, indices,
permutation) flattens to leaves while layout metadata and host-side
artifacts (tree, COO, stats) ride along as static aux data, so plans cross
``jit`` / ``scan`` / ``shard_map`` boundaries intact.

Plan lifecycle: build -> apply -> refresh -> persist
----------------------------------------------------

A plan is a *refreshable, durable* asset, not a one-shot artifact. For the
paper's iterative case studies (§3.1 t-SNE, §3.2 mean shift) the points
move every iteration; rebuilding embedding -> tree -> ordering -> BSR from
scratch each time forfeits exactly the cost the multi-scale structure
amortizes. Instead::

    plan = build_plan(x, k=16)                  # build (once)
    y = plan.matvec(charges)                    # apply (every iteration)
    for step in range(iters):
        x = advance(x)                          # points move
        plan = plan.refresh(x)                  # patch / re-bucket / rebuild
    ckpt = Checkpointer(dir)
    ckpt.save_plan(step, plan, blocking=True)   # persist (serving restarts
    plan, _ = ckpt.restore_plan(                #   skip planning; stale
        refresh_with=x_current)                 #   plans refresh on load)

``refresh`` re-embeds the moved points through the *stored* PCA map, codes
old and new coordinates against a joint bounding box, and compares Morton
cells at the tree's leaf granularity. The migrated fraction (and recorded
fill/γ degradation — ``core.measures.gamma_drift``) picks one of three
escalation tiers against ``PlanConfig.refresh_policy``:

  patch      permutation kept; kNN recomputed for migrated rows only and
             the affected BSR row-block tiles patched in place
  rebucket   stable partial reorder (unmoved runs keep their order; see
             ``core.ordering.stable_partial_reorder``), tree levels
             re-bucketed from new codes, storage rebuilt — but the PCA
             embedding map, quantization frame, and unmigrated kNN rows
             are all reused
  rebuild    full ``build_plan`` (fresh embedding fit, tree, kNN, BSR)

γ and fill are recomputed lazily after a refresh (``plan.gamma`` /
``plan.gamma_drift()``), so the hot loop never pays for scoring it does
not read.

Spec/data split and batched plans
---------------------------------

Every plan factors into a hashable, structure-only :class:`PlanSpec`
(config + capacity + ELL-BSR layout — everything that fixes shapes and
compiled code paths) and an array-only :class:`PlanData` pytree (pi/inv,
BSR arrays, alive mask); ``InteractionPlan.from_spec_data`` reconstructs a
working plan from the pair. Spec-identical plans stack:
``build_plan_batch(xs)`` returns a :class:`PlanBatch` — many small
problems (one plan per attention head / batch entry, clusterkv-style) on
one shared spec, served by ONE compiled kernel per
(spec, backend, charge shape) however many plans ride the batch, with one
shared autotune decision, lockstep streaming through the update tiers,
and checkpoint support. Mapping a *single* plan with ``jax.vmap`` raises
a TypeError pointing there.
"""
from __future__ import annotations

import dataclasses
import functools
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import is_batch_tracer
from repro.core import hierarchy, interact, knn, measures
from repro.core import ordering as ordering_mod
from repro.core.blocksparse import (BSR, append_rows, build_bsr, patch_bsr,
                                    tombstone_rows)
from repro.core.embedding import apply_pca_map, embed, pca_map
from repro.core.hierarchy import Tree, build_tree
from repro.core.ordering import ORDERINGS  # noqa: F401  (re-export)
from repro.core.registry import (backend_names, get_backend,  # noqa: F401
                                 get_batched_backend,
                                 get_preconditioner, preconditioner_names,
                                 register_backend,
                                 register_batched_backend,
                                 register_preconditioner)
from repro.core.shardplan import ShardedPlan, shard  # noqa: F401

__all__ = [
    "PlanConfig", "PlanSpec", "PlanData", "InteractionPlan", "PlanBatch",
    "RefreshStats", "build_plan", "build_plan_batch", "refresh_plan",
    "update_plan", "apply_pending_layout", "cluster_order", "shard",
    "ShardedPlan", "ORDERINGS",
    "register_backend", "register_batched_backend", "backend_names",
    "get_backend", "get_batched_backend", "edge_values",
    "register_preconditioner", "preconditioner_names", "get_preconditioner",
]


@dataclass(frozen=True)
class PlanConfig:
    """Static knobs of an interaction plan (hashable; jit-cache friendly).

    Validated at construction: a bad refresh/streaming threshold raises a
    ``ValueError`` here, not three tiers deep into a refresh.
    """
    k: int = 16                  # neighbors per target (Eq. 1 pattern)
    ordering: str = "dual_tree"  # one of core.ordering.ORDERINGS
    bs: int = 32                 # bottom-level tile size (MXU-aligned)
    sb: int = 8                  # superblock size, in tiles
    backend: str = "auto"        # registry name or "auto"
    d: int = 3                   # embedding dimension (§2.4 step 1)
    bits: int = 10               # Morton quantization bits per dim
    leaf_size: int = 64          # adaptive-tree leaf bound (§2.4 step 2)
    symmetrize: bool = False     # symmetrize the kNN pattern
    seed: int = 0
    # -- refresh lifecycle (refresh_plan escalation policy) -----------------
    refresh_policy: str = "auto"  # auto | patch | rebucket | rebuild
    patch_frac: float = 0.10     # auto: ordering drift <= this -> patch
    rebuild_frac: float = 0.40   # auto: ordering drift > this -> rebuild
    drift_tol: float = 0.25     # fill/γ degradation that forces escalation
    ell_slack: int = 0           # spare ELL tile slots per row-block, so
    #   an in-place patch (or streamed insert) can add neighbor tiles
    #   without escalating
    # -- streaming (update_plan: insert/delete/compact policy) --------------
    max_dead_frac: float = 0.25  # capacity fraction *lost since the
    #   lineage's live peak* that triggers an amortized compaction
    #   rebuild — tombstone debris, not pre-allocated capacity holes
    #   (build_plan(capacity=) / PlanBatch padding never counts until
    #   the slots have actually been claimed and then deleted)
    grow_frac: float = 0.25      # capacity growth chunk, as a fraction of
    #   current capacity (amortizes append reallocation to O(1)/insert)
    gamma_tol: float = 0.05      # streamed-γ drift that triggers the
    #   rebucket guard (armed once the plan is γ-scored; distinct from
    #   drift_tol, which gates refresh/fill escalation)
    # -- iterative solvers (repro.solvers: plan.solve / krr / spectral) ------
    cg_tol: float = 1e-5         # relative residual target ||r|| <= tol ||b||
    cg_maxiter: int = 256        # CG iteration cap (static: sizes telemetry)
    precond: str = "block_jacobi"  # preconditioner registry name

    def __post_init__(self):
        if self.ell_slack < 0:
            raise ValueError(
                f"ell_slack must be >= 0, got {self.ell_slack}")
        for fname in ("patch_frac", "rebuild_frac", "drift_tol",
                      "gamma_tol"):
            v = getattr(self, fname)
            if not (isinstance(v, (int, float)) and 0.0 <= v <= 1.0):
                raise ValueError(
                    f"{fname} must be a fraction in [0, 1], got {v!r}")
        if self.patch_frac > self.rebuild_frac:
            raise ValueError(
                f"patch_frac={self.patch_frac} > rebuild_frac="
                f"{self.rebuild_frac}: the auto policy would escalate to "
                "rebuild before patch ever applied")
        if not 0.0 < self.max_dead_frac <= 1.0:
            raise ValueError(
                f"max_dead_frac must be in (0, 1], got {self.max_dead_frac}")
        if self.grow_frac <= 0.0:
            raise ValueError(
                f"grow_frac must be > 0, got {self.grow_frac}")
        if not (isinstance(self.cg_tol, (int, float)) and self.cg_tol > 0):
            raise ValueError(
                f"cg_tol must be a positive relative tolerance, got "
                f"{self.cg_tol!r}")
        if not (isinstance(self.cg_maxiter, int) and self.cg_maxiter >= 1):
            raise ValueError(
                f"cg_maxiter must be an int >= 1, got {self.cg_maxiter!r}")
        # lazy: the registry provider imports repro.solvers, which must
        # not load during plain api import
        from repro.core.registry import preconditioner_names
        if self.precond not in preconditioner_names():
            raise ValueError(
                f"unknown preconditioner {self.precond!r}; registered: "
                f"{preconditioner_names()}")


@dataclass(frozen=True)
class PlanSpec:
    """The structure-only half of a plan (hashable; shared across a batch).

    Everything that fixes array *shapes* and compiled *code paths* lives
    here: the config knobs, the physical capacity, and the ELL-BSR layout.
    Two plans with equal specs are shape-compatible — their
    :class:`PlanData` pytrees stack on a leading batch axis and one
    compiled kernel serves all of them (:class:`PlanBatch`). ``jit`` can
    treat a spec as a static argument; array state never lives here.

    ``bs``/``sb``/``n_rb``/``n_cb``/``max_nbr`` are ``None`` for
    profile-only plans (``with_bsr=False``).
    """
    config: PlanConfig
    capacity: int                 # physical row slots (plan.n)
    bs: Optional[int] = None      # BSR layout, None when no storage
    sb: Optional[int] = None
    n_rb: Optional[int] = None
    n_cb: Optional[int] = None
    max_nbr: Optional[int] = None

    @property
    def shape_key(self) -> tuple:
        """Structural key without the config: what autotune memoizes on
        (two plans with these numbers equal compile to the same kernels,
        whatever their drift thresholds say)."""
        return (self.capacity, self.bs, self.sb, self.n_rb, self.n_cb,
                self.max_nbr)


@dataclasses.dataclass
class PlanData:
    """The array-only half of a plan (a JAX pytree; every leaf traced).

    Holds exactly the device state a plan's compute path reads: the
    permutation pair, the ELL-BSR arrays, and (for streaming plans) the
    row-validity mask. Stacking the ``PlanData`` of spec-identical plans
    on a leading axis yields the batched data a :class:`PlanBatch` vmaps
    over. Per-slot Morton codes and the rest of the streaming state stay
    host-side (``_PlanHost``): they are bookkeeping for *plan mutation*,
    which runs on the host anyway, and uint64 codes do not round-trip
    through 32-bit-default JAX.
    """
    pi: jax.Array
    inv: jax.Array
    col_idx: Optional[jax.Array] = None
    nbr_mask: Optional[jax.Array] = None
    vals: Optional[jax.Array] = None
    alive: Optional[jax.Array] = None

    def tree_flatten(self):
        return ((self.pi, self.inv, self.col_idx, self.nbr_mask,
                 self.vals, self.alive), None)

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    PlanData, PlanData.tree_flatten, PlanData.tree_unflatten)


@dataclasses.dataclass
class RefreshStats:
    """Lifecycle telemetry of a plan lineage (mutable, host-side).

    ``ordering_drift_frac`` is the fraction of points whose Morton cell
    differs from the cell the *current ordering* was derived from (resets
    on rebucket/rebuild); ``last_migrated_frac`` is measured against the
    previous refresh (what the last patch actually had to touch).
    """
    builds: int = 1
    patches: int = 0
    rebuckets: int = 0
    rebuilds: int = 0
    last_action: str = "build"
    last_migrated_frac: float = 0.0
    ordering_drift_frac: float = 0.0
    patched_rows: int = 0
    fill0: Optional[float] = None     # fill at last (re)build of the layout
    gamma0: Optional[float] = None    # γ reference for gamma_drift
    degraded: bool = False            # fill drift beyond tol -> escalate
    # -- streaming tiers (update_plan) -------------------------------------
    appends: int = 0                  # insert batches applied in place
    tombstones: int = 0               # delete batches applied in place
    compactions: int = 0              # dead-frac/degradation rebuilds
    restripes: int = 0                # storage-only rebuilds (ELL overflow
    #   at a kept ordering: build_bsr cost, full pipeline skipped)
    grows: int = 0                    # capacity reallocations
    inserted_total: int = 0
    deleted_total: int = 0


@dataclasses.dataclass(eq=False)
class _PlanHost:
    """Host-side (numpy) artifacts of a plan.

    Identity-hashed static aux data: not traced, shared across pytree
    flatten/unflatten round-trips (so e.g. the autotune cache survives jit).
    """
    pi: np.ndarray                       # sorted position -> original index
    inv: np.ndarray                      # original index -> sorted position
    coo: Optional[Tuple[np.ndarray, np.ndarray, np.ndarray]]  # reordered
    tree: Optional[Tree]
    embedding: Optional[np.ndarray]      # (n, d) PCA coords the *current
    #   ordering* was derived from (refresh measures drift against these)
    sigma: float = 1.0                   # γ-score bandwidth (Eq. 4)
    gamma: Optional[float] = None        # lazily scored on first access
    tuned_backend: dict = dataclasses.field(default_factory=dict)
    # ^ backend="auto" winners, keyed by charge ndim: a backend valid for
    #   1-D vectors (e.g. dist) must not be pinned for (n, f) charges
    coo_dev: Optional[Tuple[jax.Array, jax.Array, jax.Array]] = None
    # -- refresh lifecycle state -------------------------------------------
    embed_mean: Optional[np.ndarray] = None   # (D,) fitted PCA map: moved
    embed_axes: Optional[np.ndarray] = None   # (D, d) points re-embed here
    y_last: Optional[np.ndarray] = None  # (n, d) coords at last refresh
    #   (a patch touches only rows whose cell changed since then)
    sources: Optional[np.ndarray] = None  # fixed source set, original order
    pattern_from_knn: bool = False       # pattern derives from the coords
    values_mode: str = "ones"            # ones | fn | static
    values_fn: Optional[Callable] = None
    refresh: RefreshStats = dataclasses.field(default_factory=RefreshStats)
    # -- streaming state (logical n vs physical capacity) ------------------
    x: Optional[np.ndarray] = None       # (capacity, D) original coords —
    #   inserts kNN against these (dead rows are garbage, masked by alive)
    alive: Optional[np.ndarray] = None   # (capacity,) bool row validity;
    #   None means every physical slot holds a live point
    codes: Optional[np.ndarray] = None   # (capacity,) uint64 Morton codes
    #   in the frozen code box below (leaf placement of streamed inserts;
    #   tombstoned slots keep their last code so holes stay localized)
    code_lo: Optional[np.ndarray] = None  # (d,) frozen quantization box —
    code_hi: Optional[np.ndarray] = None  # new points code comparably
    last_inserted_idx: Optional[np.ndarray] = None  # physical slots the
    #   last update_plan insert batch landed in (post-compact indices when
    #   the batch triggered a compaction)
    peak_alive: Optional[int] = None  # highest live count this layout has
    #   held (None = never streamed): the compaction trigger measures
    #   debris against this peak, so pre-allocated capacity holes are
    #   not mistaken for decay
    compact_map: Optional[np.ndarray] = None  # (old_capacity,) old physical
    #   slot -> new index after the last compaction, -1 for dead slots
    last_patch_rb: Optional[np.ndarray] = None  # row-blocks the last patch
    #   tier touched (None once the ordering changed) — ShardedPlan.refresh
    #   patches exactly these shards instead of re-sharding
    pending_layout: Optional[str] = None  # layout tier a defer_layout
    #   update recorded instead of running ("rebucket" | "compact"):
    #   apply_pending_layout runs it — typically on a background thread
    #   behind core.doublebuf.DoubleBufferedPlan
    shard_cache: dict = dataclasses.field(default_factory=dict)
    # ^ ShardedPlan per (n_dev, axis) for the "dist" backend; entries are
    #   validated by BSR identity, so a refreshed lineage re-shards lazily


def _symmetrize_pattern(rows: np.ndarray, cols: np.ndarray,
                        aux: np.ndarray, n: int):
    """Pattern-union symmetrization; first occurrence of an (i, j) wins
    for the rider array ``aux`` (values or distances)."""
    r2 = np.concatenate([rows, cols])
    c2 = np.concatenate([cols, rows])
    a2 = np.concatenate([aux, aux])
    key = r2.astype(np.int64) * n + c2
    _, first = np.unique(key, return_index=True)
    return r2[first], c2[first], a2[first]


class InteractionPlan:
    """Planner object owning ordering, storage, and compute backend.

    One plan = the pipeline's artifacts over one point set: the
    principal-axis embedding frame, the 2^d-tree ordering
    (``pi``/``inv``), the γ profile score, and the two-level ELL-BSR
    storage, plus the host-side state the lifecycle tiers maintain
    (COO edges, validity mask, refresh telemetry). Compute
    (:meth:`matvec`/:meth:`apply`) dispatches through the backend
    registry (``docs/backends.md``); lifecycle methods
    (:meth:`refresh`, :meth:`insert`/:meth:`delete`/:meth:`update`,
    :meth:`compact`, :meth:`shard`) all return *new* plans — a plan is
    never mutated, which is what makes double-buffered maintenance
    (:class:`repro.core.doublebuf.DoubleBufferedPlan`) and async
    checkpointing safe. ``docs/architecture.md`` maps the lifecycle.
    """

    def __init__(self, config: PlanConfig, n: int, bsr: Optional[BSR],
                 pi: jax.Array, inv: jax.Array, host: _PlanHost):
        self.config = config
        self.n = n
        self.bsr = bsr
        self.pi = pi
        self.inv = inv
        self.host = host

    # -- construction ------------------------------------------------------

    @classmethod
    def from_coo(cls, rows, cols, vals, n: int, *,
                 x: Optional[np.ndarray] = None,
                 pi: Optional[np.ndarray] = None,
                 config: Optional[PlanConfig] = None,
                 sigma: Optional[float] = None,
                 with_bsr: bool = True,
                 max_nbr: Optional[int] = None,
                 _symmetrized: bool = False,
                 **overrides) -> "InteractionPlan":
        """Plan from an explicit COO pattern (original index space).

        The ordering is ``pi`` if given, else computed from ``x`` with
        ``config.ordering``, else identity (pattern already cluster-ordered).
        """
        config = dataclasses.replace(config or PlanConfig(), **overrides)
        rows = np.asarray(rows)
        cols = np.asarray(cols)
        vals = (np.ones(len(rows), np.float32) if vals is None
                else np.asarray(vals, np.float32))
        if config.symmetrize and not _symmetrized:
            rows, cols, vals = _symmetrize_pattern(rows, cols, vals, n)

        tree = None
        embedding = None
        emean = eaxes = None
        if pi is None and x is not None:
            x = np.asarray(x, np.float32)
            if config.ordering == "dual_tree":
                d = min(config.d, x.shape[1])
                emean, eaxes = (np.asarray(a) for a in
                                pca_map(jnp.asarray(x), d))
                embedding = np.asarray(apply_pca_map(
                    jnp.asarray(x), jnp.asarray(emean), jnp.asarray(eaxes)))
                tree = build_tree(embedding, bits=config.bits,
                                  leaf_size=config.leaf_size)
                pi = tree.perm
            else:
                pi = ordering_mod.compute_ordering(
                    config.ordering, x, rows, cols, seed=config.seed)
        if pi is None:
            pi = np.arange(n)
        pi = np.asarray(pi)
        inv = np.empty_like(pi)
        inv[pi] = np.arange(n)

        r2, c2 = ordering_mod.apply_ordering(rows, cols, pi)
        sigma = sigma if sigma is not None else max(config.k / 2.0, 1.0)
        bsr = (build_bsr(r2, c2, vals, n, bs=config.bs, sb=config.sb,
                         max_nbr=max_nbr, slack=config.ell_slack)
               if with_bsr else None)
        host = _PlanHost(pi=pi, inv=inv, coo=(r2, c2, vals), tree=tree,
                         embedding=embedding, sigma=sigma,
                         embed_mean=emean, embed_axes=eaxes,
                         y_last=embedding,
                         x=None if x is None else np.asarray(x, np.float32))
        host.refresh.fill0 = bsr.fill if bsr is not None else None
        return cls(config, n, bsr, jnp.asarray(pi, jnp.int32),
                   jnp.asarray(inv, jnp.int32), host)

    @classmethod
    def from_spec_data(cls, spec: PlanSpec, data: PlanData,
                       host: Optional[_PlanHost] = None,
                       fill: float = 0.0) -> "InteractionPlan":
        """Thin view over a (spec, data) pair — the split's constructor.

        The pair fully determines the compute path: ``spec`` pins shapes
        and code paths, ``data`` carries every traced array. With concrete
        arrays and no ``host``, a minimal host is derived so the view is a
        fully working single plan; with traced ``data`` (inside the
        :class:`PlanBatch` vmap) the host stays ``None`` and only the
        compute surface (``bsr``/``n``) may be touched. ``fill`` dresses
        the reconstructed BSR's (data-dependent) fill statistic.
        """
        bsr = None
        if spec.max_nbr is not None and data.vals is not None:
            bsr = BSR(bs=spec.bs, sb=spec.sb, n=spec.capacity,
                      n_rb=spec.n_rb, n_cb=spec.n_cb, col_idx=data.col_idx,
                      nbr_mask=data.nbr_mask, vals=data.vals, fill=fill,
                      max_nbr=spec.max_nbr)
        if host is None and not isinstance(data.pi, jax.core.Tracer):
            pi = np.asarray(data.pi)
            inv = np.asarray(data.inv)
            host = _PlanHost(pi=pi, inv=inv, coo=None, tree=None,
                             embedding=None,
                             alive=(None if data.alive is None
                                    else np.asarray(data.alive)))
        return cls(spec.config, spec.capacity, bsr, data.pi, data.inv, host)

    @classmethod
    def from_bsr(cls, bsr: BSR,
                 config: Optional[PlanConfig] = None) -> "InteractionPlan":
        """Wrap an existing BSR (identity ordering, no COO/tree/gamma)."""
        config = config or PlanConfig(bs=bsr.bs, sb=bsr.sb, backend="bsr")
        pi = np.arange(bsr.n)
        host = _PlanHost(pi=pi, inv=pi, coo=None, tree=None, embedding=None)
        dev = jnp.asarray(pi, jnp.int32)
        return cls(config, bsr.n, bsr, dev, dev, host)

    # -- spec/data split (the vmap-able halves of a plan) ------------------

    @property
    def spec(self) -> PlanSpec:
        """Structure-only half: hashable, shared by shape-compatible
        plans, static under ``jit`` (see :class:`PlanSpec`)."""
        b = self.bsr
        if b is None:
            return PlanSpec(config=self.config, capacity=self.n)
        return PlanSpec(config=self.config, capacity=self.n, bs=b.bs,
                        sb=b.sb, n_rb=b.n_rb, n_cb=b.n_cb,
                        max_nbr=b.max_nbr)

    @property
    def data(self) -> PlanData:
        """Array-only half: the traced leaves this plan's compute path
        reads (see :class:`PlanData`). ``from_spec_data(spec, data)``
        reconstructs an equivalent view."""
        b = self.bsr
        alive = (None if self.host is None or self.host.alive is None
                 else jnp.asarray(self.host.alive))
        if b is None:
            return PlanData(pi=self.pi, inv=self.inv, alive=alive)
        return PlanData(pi=self.pi, inv=self.inv, col_idx=b.col_idx,
                        nbr_mask=b.nbr_mask, vals=b.vals, alive=alive)

    def _reject_vmapped(self) -> None:
        """Single plans cannot be mapped over by ``jax.vmap`` — their host
        aux is identity-hashed, so batching them either fails to stack or
        dies in an opaque tracer/shape error. Catch it early and point at
        the supported path."""
        batched = is_batch_tracer(self.pi) or (
            self.bsr is not None and is_batch_tracer(self.bsr.vals))
        if batched:
            raise TypeError(
                "this InteractionPlan is being batched by jax.vmap; single"
                " plans carry identity-hashed host state and cannot be"
                " vmapped/scanned over. Stack shape-compatible plans with"
                " api.build_plan_batch(...) (or PlanBatch.from_plans) and"
                " call PlanBatch.matvec/apply — one compiled kernel for"
                " the whole batch.")

    # -- stage artifacts ---------------------------------------------------

    @property
    def tree(self) -> Optional[Tree]:
        """The 2^d hierarchy the ordering was derived from (``None``
        after streaming steps that invalidated it)."""
        return self.host.tree

    @property
    def embedding(self) -> Optional[np.ndarray]:
        """Principal-axis embedding of the points (n, d) — the image the
        tree ordered (§2.2)."""
        return self.host.embedding

    @property
    def coo(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Reordered COO ``(rows, cols, vals)`` (cluster index space)."""
        if self.host.coo is None:
            raise ValueError("plan has no COO pattern (built from_bsr)")
        return self.host.coo

    def coo_device(self) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Reordered COO as device arrays (cached — the csr backend is
        called repeatedly and must not re-upload O(nnz) data per call)."""
        if self.host.coo_dev is None:
            r, c, v = self.coo
            self.host.coo_dev = (jnp.asarray(r), jnp.asarray(c),
                                 jnp.asarray(v))
        return self.host.coo_dev

    # -- logical n vs physical capacity (streaming substrate) --------------

    @property
    def capacity(self) -> int:
        """Physical row slots (== ``plan.n``, the matvec dimension every
        backend sees). Streaming plans keep ``n_alive <= capacity``."""
        return self.n

    @property
    def alive(self) -> np.ndarray:
        """Row-validity mask over physical slots (original index space)."""
        if self.host.alive is None:
            return np.ones(self.n, bool)
        return self.host.alive

    @property
    def n_alive(self) -> int:
        """Logical point count: physical slots holding a live point."""
        if self.host.alive is None:
            return self.n
        return int(self.host.alive.sum())

    @property
    def dead_frac(self) -> float:
        """Tombstoned fraction of capacity (reporting only — the
        compaction trigger measures capacity lost since the lineage's
        live peak, so pre-allocated holes never read as decay)."""
        return 1.0 - self.n_alive / max(self.n, 1)

    @property
    def gamma(self) -> Optional[float]:
        """γ-score (Eq. 4) of the reordered pattern, computed lazily.

        Dead rows are ignored: a streamed plan is scored on the live
        pattern projected to compacted (hole-free) coordinates, so the
        score stays comparable with a fresh build over the survivors."""
        if self.host.gamma is None and self.host.coo is not None:
            r2, c2, _ = self.host.coo
            n_eff = self.n
            if self.host.alive is not None and not self.host.alive.all():
                r2, c2, n_eff = measures.compact_live(
                    r2, c2, self.host.alive[self.host.pi])
            self.host.gamma = float(measures.gamma_score(
                jnp.asarray(r2), jnp.asarray(c2), self.host.sigma, n_eff))
        return self.host.gamma

    @property
    def fill(self) -> Optional[float]:
        """Dense-entry fraction of the kept ELL tiles (``None`` for
        profile-only plans)."""
        return self.bsr.fill if self.bsr is not None else None

    @property
    def stats(self) -> dict:
        """One-call telemetry: live count, capacity, dead fraction, γ,
        fill, kept tiles, ELL width, and the resolved backend."""
        kept = (int(np.asarray(self.bsr.nbr_mask).sum())
                if self.bsr is not None else 0)
        return {"n": self.n_alive, "capacity": self.capacity,
                "dead_frac": self.dead_frac,
                "gamma": self.gamma, "fill": self.fill,
                "kept_tiles": kept,
                "max_nbr": self.bsr.max_nbr if self.bsr else None,
                "backend": self.resolve_backend(probe=False)}

    # -- permutation helpers (§2.4 step 2) ---------------------------------

    def permute(self, a):
        """Original order -> cluster order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.pi]
        return jnp.take(jnp.asarray(a), self.pi, axis=0)

    def unpermute(self, a):
        """Cluster order -> original order along the leading axis."""
        if isinstance(a, np.ndarray):
            return a[self.host.inv]
        return jnp.take(jnp.asarray(a), self.inv, axis=0)

    # -- backend resolution ------------------------------------------------

    def resolve_backend(self, name: Optional[str] = None,
                        probe: bool = True,
                        x: Optional[jax.Array] = None) -> str:
        """Resolve ``name`` (default: the config backend); ``"auto"`` is
        answered from the per-charge-shape tuned cache, probing the
        registry with ``x`` (or a synthetic 1-D vector) on first use."""
        name = name or self.config.backend
        if name != "auto":
            return name
        ndim = x.ndim if x is not None else 1
        if ndim not in self.host.tuned_backend and probe:
            if (self.bsr is None
                    or isinstance(self.bsr.vals, jax.core.Tracer)
                    or (x is not None and isinstance(x, jax.core.Tracer))):
                return "bsr"        # probing needs concrete arrays
            from repro.core.autotune import tune_backend
            self.host.tuned_backend[ndim], _ = tune_backend(self, x)
        return self.host.tuned_backend.get(ndim, "bsr")

    # -- interaction (§2.4 step 4) -----------------------------------------

    def apply(self, x: jax.Array, backend: Optional[str] = None,
              **kwargs) -> jax.Array:
        """``y = A' x`` in cluster order (``A'`` the reordered matrix)."""
        self._reject_vmapped()
        name = self.resolve_backend(backend, x=x)
        if self.bsr is None and name != "csr":
            raise ValueError(
                f"profile-only plan has no BSR for backend {name!r}; "
                "rebuild with with_bsr=True (only 'csr' runs off the COO)")
        return get_backend(name)(self, x, **kwargs)

    def matvec(self, x: jax.Array, backend: Optional[str] = None,
               **kwargs) -> jax.Array:
        """``y = A x`` in original order: unpermute ∘ apply ∘ permute."""
        self._reject_vmapped()
        return self.unpermute(self.apply(self.permute(x), backend, **kwargs))

    # -- iterative solvers (repro.solvers rides the matvec) ----------------

    def solve(self, b: jax.Array, *, shift: float = 0.0,
              backend: Optional[str] = None, precond: Optional[str] = None,
              tol: Optional[float] = None, maxiter: Optional[int] = None):
        """Solve ``(A + shift*I) x = b`` by preconditioned CG on this
        plan's matvec (original index order; symmetric pattern required).
        Knobs default to the config's ``cg_tol``/``cg_maxiter``/
        ``precond``; returns :class:`repro.solvers.CGResult` with
        per-iteration telemetry. See ``docs/solvers.md``."""
        from repro.solvers.krr import solve as _solve
        return _solve(self, b, shift=shift, backend=backend,
                      precond=precond, tol=tol, maxiter=maxiter)

    def eigs(self, k: int = 6, *, m: int = 0, seed: int = 0,
             backend: Optional[str] = None, largest: bool = True):
        """Top (or bottom) ``k`` eigenpairs of the symmetric plan
        operator by Lanczos on the matvec — ``(w, U)`` with ``U``
        ``(capacity, k)`` in original index order."""
        from repro.solvers.krr import _plan_backend
        from repro.solvers.lanczos import lanczos_eigsh
        self._require_bsr()
        name = _plan_backend(self, None, backend)
        w, U = lanczos_eigsh(lambda v: self.apply(v, backend=name),
                             self.n, k, m=m, seed=seed, largest=largest)
        return w, self.unpermute(U)

    # -- iterative value-update hooks (paper §3) ---------------------------

    def tsne_attractive(self, y: jax.Array,
                        backend: Optional[str] = None) -> jax.Array:
        """t-SNE attractive force (§3.1) on embedding ``y`` (cluster order);
        the stored tiles are the (fixed-profile) affinities ``p``.
        ``backend="pallas"`` routes through the fused Mosaic kernel
        (``kernels.ops.tsne_force``); default stays the XLA blockwise path.
        """
        b = self._require_bsr()
        if backend == "pallas":
            from repro.kernels import ops as _kops
            return _kops.tsne_force(b.vals, b.col_idx, y, self.n)
        return interact.tsne_attractive(b.vals, b.col_idx, b.nbr_mask,
                                        y, self.n)

    def meanshift_step(self, targets: jax.Array, sources: jax.Array,
                       h2: float) -> jax.Array:
        """One mean-shift iteration (§3.2). ``sources`` (n, d) in cluster
        order; the stored tiles are the 0/1 neighbor pattern."""
        b = self._require_bsr()
        s = jnp.asarray(sources)
        pad = b.n_cb * b.bs - s.shape[0]
        if pad:
            s = jnp.pad(s, ((0, pad), (0, 0)))
        s_blocked = s.reshape(b.n_cb, b.bs, -1)
        return interact.meanshift_step(b.vals, b.col_idx, s_blocked,
                                       jnp.asarray(targets), h2, self.n)

    def with_values(self, vals) -> "InteractionPlan":
        """New plan with the same pattern/ordering but fresh edge values
        (aligned with ``plan.coo``). Storage shapes are pinned
        (``max_nbr`` carried over), so the per-backend jitted kernels and
        any ``jit(plan.apply)``-style closures keep their compile caches;
        a plan passed *as a jit argument* still retraces once (its static
        host aux is a fresh identity)."""
        r2, c2, _ = self.coo
        vals = np.asarray(vals, np.float32)
        b = self._require_bsr()
        bsr = build_bsr(r2, c2, vals, self.n, bs=b.bs, sb=b.sb,
                        max_nbr=b.max_nbr)
        host = dataclasses.replace(self.host, coo=(r2, c2, vals),
                                   coo_dev=None, shard_cache={})
        return InteractionPlan(self.config, self.n, bsr, self.pi, self.inv,
                               host)

    def shard(self, mesh=None, axis: str = "data") -> ShardedPlan:
        """Per-device row-block shards with halo exchange — see
        :func:`repro.core.shardplan.shard`."""
        return shard(self, mesh, axis=axis)

    # -- lifecycle (refresh + drift monitoring) ----------------------------

    def refresh(self, x_new, *, policy: Optional[str] = None
                ) -> "InteractionPlan":
        """See :func:`refresh_plan`."""
        return refresh_plan(self, x_new, policy=policy)

    # -- streaming (insert / delete / compact) -----------------------------

    def insert(self, x_new, *, policy: Optional[str] = None
               ) -> Tuple["InteractionPlan", np.ndarray]:
        """Insert points ``x_new`` (m, D); returns ``(plan, idx)`` where
        ``idx`` are the physical slots the points landed in (their row
        indices for ``matvec``/``delete``). See :func:`update_plan`."""
        plan = update_plan(self, insert=x_new, policy=policy)
        return plan, plan.host.last_inserted_idx

    def delete(self, idx, *, policy: Optional[str] = None
               ) -> "InteractionPlan":
        """Tombstone the live points at physical slots ``idx``.
        See :func:`update_plan`."""
        return update_plan(self, delete=idx, policy=policy)

    def update(self, *, insert=None, delete=None,
               policy: Optional[str] = None) -> "InteractionPlan":
        """See :func:`update_plan` (one batched insert+delete step)."""
        return update_plan(self, insert=insert, delete=delete,
                           policy=policy)

    def compact(self) -> "InteractionPlan":
        """Force the compaction tier: rebuild on the surviving points
        (capacity shrinks to ``n_alive``; ``host.compact_map`` maps old
        physical slots to new indices). See :func:`update_plan`."""
        return update_plan(self, policy="compact")

    @property
    def refresh_stats(self) -> RefreshStats:
        """Lifecycle counters for this plan lineage (patches, rebuckets,
        restripes, compactions, last action...)."""
        return self.host.refresh

    def gamma_drift(self) -> float:
        """Relative γ degradation against the lineage's reference score
        (positive = locality got worse). The reference is pinned at the
        first scoring after a (re)build; γ itself is computed lazily, so
        hot loops that never call this never pay for scoring."""
        st = self.host.refresh
        g = self.gamma
        if st.gamma0 is None:
            st.gamma0 = g
            return 0.0
        return measures.gamma_drift(st.gamma0, g)

    def _require_bsr(self) -> BSR:
        if self.bsr is None:
            raise ValueError("profile-only plan: rebuild with with_bsr=True")
        return self.bsr

    def __repr__(self) -> str:
        g = (f"{self.host.gamma:.2f}" if self.host.gamma is not None
             else "unscored" if self.host.coo is not None else "n/a")
        f = f"{self.fill:.3f}" if self.fill is not None else "n/a"
        size = (f"n={self.n}" if self.host.alive is None
                else f"n={self.n_alive}/cap={self.capacity}")
        return (f"InteractionPlan({size}, ordering="
                f"{self.config.ordering!r}, bs={self.config.bs}, "
                f"sb={self.config.sb}, gamma={g}, fill={f}, "
                f"backend={self.config.backend!r})")

    # -- pytree protocol ---------------------------------------------------

    def tree_flatten(self):
        return (self.bsr, self.pi, self.inv), (self.config, self.n, self.host)

    @classmethod
    def tree_unflatten(cls, aux, children):
        config, n, host = aux
        bsr, pi, inv = children
        return cls(config, n, bsr, pi, inv, host)


jax.tree_util.register_pytree_node(
    InteractionPlan, InteractionPlan.tree_flatten,
    InteractionPlan.tree_unflatten)


def cluster_order(x, *, ordering: str = "dual_tree", d: int = 3,
                  bits: int = 10, leaf_size: int = 64,
                  seed: int = 0) -> np.ndarray:
    """Pipeline steps 1–2 only (§2.4): the cluster permutation of ``x``,
    with no interaction pattern built. Cheap when only the ordering is
    needed (e.g. pre-sorting a fixed source set). Graph-based orderings
    (``rcm``) need a pattern — use :func:`build_plan` for those.
    """
    x = np.asarray(x, np.float32)
    if ordering == "rcm":
        raise ValueError("rcm needs an interaction pattern; use build_plan")
    if ordering == "dual_tree":
        y = np.asarray(embed(jnp.asarray(x), d))
        return build_tree(y, bits=bits, leaf_size=leaf_size).perm
    return ordering_mod.compute_ordering(ordering, x, np.empty(0, np.int64),
                                         np.empty(0, np.int64), seed=seed)


def build_plan(x, *, k: int = 16, ordering: str = "dual_tree", bs: int = 32,
               sb: int = 8, backend: str = "auto", d: int = 3,
               bits: int = 10, leaf_size: int = 64, symmetrize: bool = False,
               seed: int = 0,
               values: "np.ndarray | Callable | None" = None,
               sigma: Optional[float] = None,
               with_bsr: bool = True,
               sources: Optional[np.ndarray] = None,
               config: Optional[PlanConfig] = None,
               capacity: Optional[int] = None,
               **cfg_overrides) -> InteractionPlan:
    """Run the full pipeline (§2.4) over points ``x`` (n, D).

    Builds the kNN interaction pattern (Eq. 1), orders it, scores it (γ,
    Eq. 4), and compresses it into the two-level ELL-BSR. ``values`` dresses
    the pattern: ``None`` -> 1.0 per edge, an array aligned with the
    (row-major, post-symmetrization) kNN edges, or a callable
    ``f(rows, cols, dist2) -> vals`` (stored on the plan: ``refresh``
    re-dresses patched rows through it; a static array pins the pattern —
    refresh then only re-orders). ``with_bsr=False`` builds a profile-only
    plan (ordering + γ, no storage) — cheap for comparing orderings as in
    §2.3. ``sources`` (n, D) switches to the fixed-source-set pattern of
    §3.2: neighbors of the (moving) targets ``x`` among ``sources``; the
    target ordering is applied to both sides, so both must have n points.
    ``config`` overrides every individual knob at once (refresh reuses the
    lineage's config this way). ``capacity`` pre-allocates physical row
    slots beyond ``len(x)``: the extra slots are tombstoned (dead) until
    ``plan.insert`` claims them, so a known insert rate can be absorbed
    without any reallocation (§streaming; requires ``with_bsr=True``
    semantics to matter but is accepted for profile-only plans too).

    Example:
        >>> import numpy as np
        >>> from repro import api
        >>> x = np.random.default_rng(0).standard_normal((64, 8))
        >>> plan = api.build_plan(x, k=4, bs=8, sb=2, backend="bsr")
        >>> plan.n, plan.bsr.bs
        (64, 8)
        >>> plan.matvec(np.ones(64, np.float32)).shape
        (64,)
    """
    if config is None:
        config = PlanConfig(k=k, ordering=ordering, bs=bs, sb=sb,
                            backend=backend, d=d, bits=bits,
                            leaf_size=leaf_size, symmetrize=symmetrize,
                            seed=seed, **cfg_overrides)
    elif cfg_overrides:
        config = dataclasses.replace(config, **cfg_overrides)
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    if sources is not None:
        sources = np.asarray(sources, np.float32)
        if sources.shape[0] != n:
            raise ValueError(
                f"sources has {sources.shape[0]} points, targets have {n}; "
                "one ordering indexes both sides of the square plan")
        if config.symmetrize:
            raise ValueError("symmetrize crosses the target/source index "
                             "spaces; not meaningful with fixed sources")
    xd = jnp.asarray(x)
    sd = xd if sources is None else jnp.asarray(sources)
    rows, cols, d2 = knn.knn_coo(xd, sd, config.k,
                                 exclude_self=sources is None)
    rows = np.asarray(rows)
    cols = np.asarray(cols)
    d2 = np.asarray(d2)

    if config.symmetrize:
        # pattern-level symmetrization (first occurrence wins, like the
        # paper's Fig. 2 interaction patterns) — before values, so a
        # callable sees the symmetrized edge list
        rows, cols, d2 = _symmetrize_pattern(rows, cols, d2, n)

    if values is None:
        vals = np.ones(len(rows), np.float32)
    elif callable(values):
        vals = np.asarray(values(rows, cols, d2), np.float32)
    else:
        vals = np.asarray(values, np.float32)
        if vals.shape[0] != len(rows):
            raise ValueError(
                f"values has {vals.shape[0]} entries, pattern has "
                f"{len(rows)} edges (symmetrize={config.symmetrize})")

    plan = InteractionPlan.from_coo(rows, cols, vals, n, x=x, config=config,
                                    sigma=sigma, with_bsr=with_bsr,
                                    _symmetrized=True)
    plan.host.pattern_from_knn = True
    plan.host.sources = sources
    if callable(values):
        plan.host.values_mode = "fn"
        plan.host.values_fn = values
    elif values is not None:
        plan.host.values_mode = "static"
    if capacity is not None:
        if capacity < n:
            raise ValueError(f"capacity={capacity} < n={n} points")
        if capacity > n:
            plan = _spread_holes(_grow_plan(plan, capacity))
    return plan


# ---------------------------------------------------------------------------
# plan refresh (lifecycle: the non-stationary targets of paper §3.2)
# ---------------------------------------------------------------------------


def _cmp_shift(n: int, d: int, bits: int, tree: Optional[Tree],
               leaf_size: int) -> int:
    """Morton-code shift at which cell identity is compared for migration.

    Uses the tree's realized depth (cells at leaf granularity) when one
    exists, else the depth a balanced 2^d tree would need for ~leaf_size
    points per cell. Comparing at full code resolution would flag every
    sub-cell wiggle as migration."""
    total = d * hierarchy.eff_bits(d, bits)
    if tree is not None and tree.n_levels > 1:
        level = tree.n_levels - 1
    else:
        cells_per_dim = max(float(n) / max(leaf_size, 1), 1.0) ** (1.0 / d)
        level = max(int(np.ceil(np.log2(max(cells_per_dim, 1.0)))), 1)
    return max(total - level * d, 0)


def _cell_migration(y_ref: np.ndarray, y_new: np.ndarray, bits: int,
                    shift: int) -> np.ndarray:
    """Mask of points whose Morton cell (at leaf granularity) changed.

    Both coordinate sets are quantized against their joint bounding box,
    so a global translation/expansion of the cloud (which leaves relative
    order intact) does not read as migration."""
    lo = jnp.asarray(np.minimum(y_ref.min(0), y_new.min(0)))
    hi = jnp.asarray(np.maximum(y_ref.max(0), y_new.max(0)))
    ca = np.asarray(hierarchy.morton_codes_box(jnp.asarray(y_ref), lo, hi,
                                               bits))
    cb = np.asarray(hierarchy.morton_codes_box(jnp.asarray(y_new), lo, hi,
                                               bits))
    return (ca >> shift) != (cb >> shift)


def _knn_subset(x_new: np.ndarray, rows_idx: np.ndarray,
                sources: Optional[np.ndarray], k: int,
                valid: Optional[np.ndarray] = None):
    """Exact kNN edges (original index space) for a subset of target rows.

    ``valid`` masks the candidate sources (streaming: tombstoned physical
    slots hold stale coordinates and must never be picked as neighbors).
    """
    tq = jnp.asarray(x_new[rows_idx])
    vd = None if valid is None else jnp.asarray(valid)
    # size the scan block to the subset (quantized to powers of two so a
    # lifetime of refreshes compiles a handful of kernels, not one per
    # migration count) — the default 1024 pads small patches 10x
    block = min(1 << max(7, int(np.ceil(np.log2(max(len(rows_idx), 1))))),
                1024)
    if sources is None:
        # targets are a subset of the sources: take k+1 and drop each
        # row's own point (knn_graph's exclude_self assumes aligned sets)
        idx, d2 = knn.knn_graph(tq, jnp.asarray(x_new), k + 1, block=block,
                                valid=vd)
        idx, d2 = np.asarray(idx), np.asarray(d2)
        keep = idx != rows_idx[:, None]
        order = np.argsort(~keep, axis=1, kind="stable")  # kept first,
        idx = np.take_along_axis(idx, order, 1)[:, :k]    # distance order
        d2 = np.take_along_axis(d2, order, 1)[:, :k]      # preserved
    else:
        idx, d2 = knn.knn_graph(tq, jnp.asarray(sources), k, block=block,
                                valid=vd)
        idx, d2 = np.asarray(idx), np.asarray(d2)
    return np.repeat(rows_idx, k), idx.reshape(-1), d2.reshape(-1)


def edge_values(host: _PlanHost, rows, cols, d2) -> np.ndarray:
    """Edge weights for a batch of (row, col, squared-distance) triples
    under the host's values mode — the single place interaction strengths
    are computed, shared by plan construction, migration patching, and
    the serve-tier streaming inserter's deferred COO folds."""
    if host.values_mode == "fn":
        return np.asarray(host.values_fn(rows, cols, d2), np.float32)
    return np.ones(len(rows), np.float32)


_edge_values = edge_values  # pre-promotion private name, kept for callers


def _patch_pattern(host: _PlanHost, cfg: PlanConfig, n: int,
                   x_new: np.ndarray, rows_m: np.ndarray):
    """Original-space COO with migrated rows' kNN edges recomputed."""
    r2, c2, v2 = host.coo
    r_o, c_o = host.pi[r2], host.pi[c2]
    drop = np.isin(r_o, rows_m)
    if cfg.symmetrize:
        drop |= np.isin(c_o, rows_m)
    nr, nc, nd2 = _knn_subset(x_new, rows_m, host.sources, cfg.k,
                              valid=host.alive)
    nv = _edge_values(host, nr, nc, nd2)
    if cfg.symmetrize:
        nr, nc, nv = _symmetrize_pattern(nr, nc, nv, n)
    r_all = np.concatenate([r_o[~drop], nr])
    c_all = np.concatenate([c_o[~drop], nc])
    v_all = np.concatenate([v2[~drop], nv])
    if cfg.symmetrize:  # mirrored new edges may duplicate kept ones
        key = r_all.astype(np.int64) * n + c_all
        _, first = np.unique(key, return_index=True)
        r_all, c_all, v_all = r_all[first], c_all[first], v_all[first]
    dropped_rows = r_o[drop]
    return r_all, c_all, v_all, dropped_rows


def _refresh_patch(plan: InteractionPlan, x_new, y_new, moved, stats,
                   moved_frac: float, drift_frac: float):
    """Cheapest tier: permutation kept, migrated rows' tiles patched in
    place. Returns None when a patched row-block overflows the pinned ELL
    width (caller escalates to rebucket)."""
    host, cfg, n = plan.host, plan.config, plan.n
    rows_m = np.nonzero(moved)[0]
    refreshes_pattern = (host.pattern_from_knn
                         and host.values_mode != "static"
                         and len(rows_m) > 0)
    stats = dataclasses.replace(
        stats, patches=stats.patches + 1, last_action="patch",
        last_migrated_frac=moved_frac, ordering_drift_frac=drift_frac,
        patched_rows=stats.patched_rows
        + (len(rows_m) if refreshes_pattern else 0))
    if not refreshes_pattern:
        # pattern does not follow the coords (or nothing changed cells):
        # bookkeeping only; ordering drift keeps accumulating
        host2 = dataclasses.replace(host, y_last=y_new, refresh=stats,
                                    x=x_new, codes=None,
                                    last_patch_rb=np.empty(0, np.int64))
        return InteractionPlan(cfg, n, plan.bsr, plan.pi, plan.inv, host2)
    r_all, c_all, v_all, dropped_rows = _patch_pattern(host, cfg, n, x_new,
                                                       rows_m)
    r2n, c2n = ordering_mod.apply_ordering(r_all, c_all, host.pi)
    bsr = plan.bsr
    affected = np.concatenate([host.inv[dropped_rows], host.inv[rows_m]])
    touched_rb = np.unique(affected // cfg.bs)
    if bsr is not None:
        try:
            bsr = patch_bsr(bsr, r2n, c2n, v_all, touched_rb)
        except ValueError:
            return None
        if measures.fill_drift(stats.fill0, bsr.fill) > cfg.drift_tol:
            stats = dataclasses.replace(stats, degraded=True)
    host2 = dataclasses.replace(host, coo=(r2n, c2n, v_all), coo_dev=None,
                                gamma=None, y_last=y_new, refresh=stats,
                                x=x_new, codes=None,
                                last_patch_rb=touched_rb, shard_cache={})
    return InteractionPlan(cfg, n, bsr, plan.pi, plan.inv, host2)


def _refresh_rebucket(plan: InteractionPlan, x_new, y_new, moved, stats,
                      moved_frac: float) -> InteractionPlan:
    """Middle tier: stable partial reorder + re-bucketed tree levels;
    embedding map, quantization frame and unmigrated kNN rows reused."""
    host, cfg, n = plan.host, plan.config, plan.n
    if host.tree is not None:
        tree = hierarchy.rebucket(y_new, host.tree, cfg.leaf_size)
        pi = np.asarray(tree.perm)
    else:
        # every plan from_coo builds carries a tree alongside its embedding
        # map; this fallback covers externally restored hosts whose tree
        # arrays were not persisted (the ordering still refreshes)
        codes = np.asarray(hierarchy.morton_codes(jnp.asarray(y_new),
                                                  cfg.bits))
        pi = ordering_mod.stable_partial_reorder(host.pi, codes)
        tree = None
    inv = np.empty_like(pi)
    inv[pi] = np.arange(n)

    rows_m = np.nonzero(moved)[0]
    refreshes_pattern = (host.pattern_from_knn
                         and host.values_mode != "static"
                         and len(rows_m) > 0)
    if refreshes_pattern:
        r_o, c_o, v2, _ = _patch_pattern(host, cfg, n, x_new, rows_m)
    else:
        r2, c2, v2 = host.coo
        r_o, c_o = host.pi[r2], host.pi[c2]
    r2n, c2n = ordering_mod.apply_ordering(r_o, c_o, pi)
    bsr = (build_bsr(r2n, c2n, v2, n, bs=cfg.bs, sb=cfg.sb,
                     slack=cfg.ell_slack)
           if plan.bsr is not None else None)
    stats = dataclasses.replace(
        stats, rebuckets=stats.rebuckets + 1, last_action="rebucket",
        last_migrated_frac=moved_frac, ordering_drift_frac=0.0,
        patched_rows=stats.patched_rows
        + (len(rows_m) if refreshes_pattern else 0),
        fill0=bsr.fill if bsr is not None else None, gamma0=None,
        degraded=False)
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, coo=(r2n, c2n, v2), coo_dev=None, tree=tree,
        embedding=y_new, y_last=y_new, gamma=None, refresh=stats,
        x=x_new, codes=None, code_lo=None, code_hi=None,
        tuned_backend={}, last_patch_rb=None, shard_cache={})
    return InteractionPlan(cfg, n, bsr, jnp.asarray(pi, jnp.int32),
                           jnp.asarray(inv, jnp.int32), host2)


def _refresh_rebuild(plan: InteractionPlan, x_new, stats,
                     moved_frac: float) -> InteractionPlan:
    """Top tier: the full pipeline again (fresh embedding fit, tree, kNN,
    BSR); only the config and lineage telemetry carry over."""
    host, cfg = plan.host, plan.config
    if host.pattern_from_knn and host.values_mode != "static":
        values = host.values_fn if host.values_mode == "fn" else None
        new = build_plan(x_new, config=cfg, values=values, sigma=host.sigma,
                         sources=host.sources,
                         with_bsr=plan.bsr is not None)
    else:
        r2, c2, v2 = host.coo
        r_o, c_o = host.pi[r2], host.pi[c2]
        new = InteractionPlan.from_coo(
            r_o, c_o, v2, plan.n, x=np.asarray(x_new, np.float32),
            config=cfg, sigma=host.sigma, with_bsr=plan.bsr is not None,
            _symmetrized=True)
        new.host.pattern_from_knn = host.pattern_from_knn
        new.host.values_mode = host.values_mode
        new.host.values_fn = host.values_fn
        new.host.sources = host.sources
    new.host.refresh = dataclasses.replace(
        new.host.refresh, builds=stats.builds + 1, patches=stats.patches,
        rebuckets=stats.rebuckets, rebuilds=stats.rebuilds + 1,
        last_action="rebuild", last_migrated_frac=moved_frac,
        patched_rows=stats.patched_rows)
    return new


def refresh_plan(plan: InteractionPlan, x_new,
                 *, policy: Optional[str] = None) -> InteractionPlan:
    """Refresh ``plan`` for moved points ``x_new`` (n, D, original order).

    Re-embeds the points through the plan's *stored* PCA map, detects
    Morton-cell migration at leaf granularity (old/new coords quantized
    jointly), and escalates through three tiers — see the module docstring:

      patch     permutation kept; kNN recomputed for migrated rows only,
                affected BSR row-block tiles patched in place
      rebucket  stable partial reorder + re-bucketed tree levels; storage
                rebuilt, everything upstream reused
      rebuild   full ``build_plan`` pipeline

    ``policy`` (or ``plan.config.refresh_policy``) forces a tier; the
    default ``"auto"`` picks by the ordering-drift fraction against
    ``PlanConfig.patch_frac`` / ``rebuild_frac``, with recorded fill
    degradation (``refresh_stats.degraded``) forcing escalation. The
    pattern follows the points only when edge values are recomputable
    (default 1.0 or a ``values`` callable); plans with static value arrays
    or an externally fixed COO pattern refresh their *ordering* only.
    Returns a new plan (the input is not mutated); γ/fill of the result
    are recomputed lazily.
    """
    host, cfg = plan.host, plan.config
    if host.embed_axes is None or host.embedding is None:
        raise ValueError(
            "plan is not refreshable: no stored embedding map (build with "
            "ordering='dual_tree' and coordinates x)")
    x_new = np.asarray(x_new, np.float32)
    if x_new.shape[0] != plan.n:
        raise ValueError(
            f"refresh expects the same {plan.n}-slot physical buffer, got "
            f"{x_new.shape[0]} (use plan.insert/plan.delete/update_plan "
            "for growing or shrinking point sets)")
    if x_new.shape[1] != host.embed_axes.shape[0]:
        raise ValueError(
            f"refresh expects {host.embed_axes.shape[0]}-dim points, got "
            f"{x_new.shape[1]}")
    stats = host.refresh
    y_new = np.asarray(apply_pca_map(jnp.asarray(x_new),
                                     jnp.asarray(host.embed_mean),
                                     jnp.asarray(host.embed_axes)))
    d = y_new.shape[1]
    shift = _cmp_shift(plan.n, d, cfg.bits, host.tree, cfg.leaf_size)
    holey = host.alive is not None and not host.alive.all()
    if holey:
        # tombstoned slots carry stale/garbage coordinates: they must
        # neither read as migration nor pollute the joint quantization
        # bounding box, so detection runs on the live rows only
        live = np.nonzero(host.alive)[0]
        drift = np.zeros(plan.n, bool)
        moved = np.zeros(plan.n, bool)
        drift[live] = _cell_migration(host.embedding[live], y_new[live],
                                      cfg.bits, shift)
        moved[live] = _cell_migration(host.y_last[live], y_new[live],
                                      cfg.bits, shift)
        denom = max(live.size, 1)
    else:
        drift = _cell_migration(host.embedding, y_new, cfg.bits, shift)
        moved = _cell_migration(host.y_last, y_new, cfg.bits, shift)
        denom = plan.n
    drift_frac = float(drift.sum()) / denom
    moved_frac = float(moved.sum()) / denom

    action = policy or cfg.refresh_policy
    if action == "auto":
        if drift_frac > cfg.rebuild_frac:
            action = "rebuild"
        elif drift_frac > cfg.patch_frac or stats.degraded:
            action = "rebucket"
        else:
            action = "patch"
    if action not in ("patch", "rebucket", "rebuild"):
        raise ValueError(f"unknown refresh policy {action!r}; expected "
                         "auto | patch | rebucket | rebuild")
    if action == "rebuild" and holey:
        if policy == "rebuild":
            raise ValueError(
                "rebuild on a plan with tombstoned rows would renumber "
                "the physical slots; use plan.compact() (or "
                "update_plan(policy='compact')) to rebuild on the "
                "survivors explicitly")
        action = "rebucket"  # index-stable escalation cap for streamers

    # free γ-reference snapshot: if a score was already computed for the
    # outgoing pattern, keep it as the drift baseline for this lineage
    if stats.gamma0 is None and host.gamma is not None:
        stats = dataclasses.replace(stats, gamma0=host.gamma)

    if action == "patch":
        out = _refresh_patch(plan, x_new, y_new, moved, stats, moved_frac,
                             drift_frac)
        if out is not None:
            return out
        action = "rebucket"  # pinned ELL width overflowed: escalate
    if action == "rebucket":
        return _refresh_rebucket(plan, x_new, y_new, moved, stats,
                                 moved_frac)
    return _refresh_rebuild(plan, x_new, stats, moved_frac)


# ---------------------------------------------------------------------------
# streaming point sets (lifecycle: growing/shrinking n, capacity layout)
# ---------------------------------------------------------------------------


def _round_up(v: int, q: int) -> int:
    return -(-v // q) * q


def _stream_codes(host: _PlanHost, cfg: PlanConfig):
    """Per-physical-slot Morton codes in a frozen quantization box.

    Computed lazily on the first streamed insert of a lineage (and
    invalidated by every refresh tier, whose coordinates supersede them):
    live slots code their current embedding against the live bounding
    box; holes are seeded with quantile codes (:func:`_seed_hole_codes`)
    so they interleave through the ordering on the next rebucket. The box
    is frozen so codes of points inserted later are comparable — new
    points outside it clip to the boundary cells, which only softens the
    placement heuristic, never correctness.
    """
    if host.codes is not None:
        return host.codes.copy(), host.code_lo, host.code_hi
    emb = host.embedding
    alive = (np.ones(len(emb), bool) if host.alive is None
             else host.alive)
    live = emb[alive]
    lo, hi = live.min(0), live.max(0)
    codes = np.empty(len(emb), np.uint64)
    codes[alive] = np.asarray(hierarchy.morton_codes_box(
        jnp.asarray(live), jnp.asarray(lo), jnp.asarray(hi),
        cfg.bits)).astype(np.uint64)
    holes = ~alive
    if holes.any():
        codes[holes] = _seed_hole_codes(codes[alive], int(holes.sum()))
    return codes, lo, hi


def _seed_hole_codes(live_codes: np.ndarray, n_holes: int) -> np.ndarray:
    """Codes for unoccupied capacity: quantiles of the live code
    distribution. On the next rebucket the holes interleave *uniformly
    through the ordering* (proportional to point density), so streamed
    inserts find a free slot close to their Morton leaf instead of
    displacing to wherever the last deletion happened to be."""
    qs = np.sort(live_codes)
    idx = ((np.arange(n_holes) + 0.5) * len(qs) / n_holes).astype(np.int64)
    return qs[np.clip(idx, 0, len(qs) - 1)]


def _route_dead_edges(r2, c2, v2, dead_cl, C, host, x, pi, cfg):
    """Replacement edges for rows that lose a tombstoned neighbor.

    Exactly recomputing kNN for every row that referenced a deleted point
    costs a distance scan per deletion — the same O(n) the tombstone tier
    exists to avoid. Instead each broken edge (i -> j_dead) is *routed
    around the tombstone*: i adopts one of j's own surviving neighbors
    (they are already in the pattern, cluster-local by construction, and
    were within one hop of the lost edge). The pattern stays near-k-full
    and local between compactions — an approximation of the exact kNN
    profile that the compaction tier periodically re-exactifies.

    Returns cluster-space ``(rows, cols, vals)`` of the replacement edges
    (both endpoints alive; deduplicated against existing edges).
    """
    empty = (np.empty(0, r2.dtype), np.empty(0, c2.dtype),
             np.empty(0, np.float32))
    dead_c = np.isin(c2, dead_cl)
    dead_r = np.isin(r2, dead_cl)
    lost = dead_c & ~dead_r             # surviving row -> dead neighbor
    if not lost.any():
        return empty
    lost_r, lost_j = r2[lost], c2[lost]
    sel = dead_r & ~dead_c              # dead row -> surviving neighbor
    order = np.argsort(r2[sel], kind="stable")
    j_s, nbr_s = r2[sel][order], c2[sel][order]
    uj, ustart = np.unique(j_s, return_index=True)
    if uj.size == 0:
        return empty
    counts = np.diff(np.append(ustart, len(j_s)))
    kmax = int(counts.max(initial=0))
    # candidate table: row g holds dead point uj[g]'s surviving neighbors
    mat = np.full((len(uj), kmax), -1, np.int64)
    grp = np.searchsorted(uj, j_s)
    mat[grp, np.arange(len(j_s)) - ustart[grp]] = nbr_s
    pos = np.searchsorted(uj, lost_j)
    has = (pos < len(uj)) & (uj[np.clip(pos, 0, len(uj) - 1)] == lost_j)
    if not has.any():
        return empty
    lost_r, pos = lost_r[has], pos[has]
    cand = mat[pos]                                      # (L, kmax)
    valid = (cand >= 0) & (cand != lost_r[:, None])
    # a candidate i already points at is no replacement
    kept_key = np.sort(r2[~(dead_r | dead_c)].astype(np.int64) * C
                       + c2[~(dead_r | dead_c)])
    ckey = lost_r[:, None].astype(np.int64) * C + np.clip(cand, 0, None)
    valid &= ~np.isin(ckey, kept_key)
    # nearest valid candidate, by actual distance (the routed edge should
    # be the best of j's neighborhood for i, not an arbitrary member)
    xi = x[pi[lost_r]]
    xc = x[pi[np.clip(cand, 0, None)]]
    d2 = np.sum((xi[:, None, :] - xc) ** 2, axis=2)
    d2 = np.where(valid, d2, np.inf)
    best = np.argmin(d2, axis=1)
    bd2 = d2[np.arange(len(best)), best]
    ok = np.isfinite(bd2)
    if not ok.any():
        return empty
    rr = lost_r[ok]
    cc = cand[np.arange(len(best)), best][ok]
    dd2 = bd2[ok]
    # two broken edges of one row may route to the same candidate
    key = rr.astype(np.int64) * C + cc
    _, first = np.unique(key, return_index=True)
    rr, cc, dd2 = rr[first], cc[first], dd2[first]
    if host.values_mode == "fn":
        vv = np.asarray(host.values_fn(pi[rr], pi[cc], dd2), np.float32)
    else:
        vv = np.ones(rr.size, np.float32)
    return rr, cc, vv


def _guard_gamma(r2, c2, alive_sorted, sigma: float, C: int) -> float:
    """γ of the live pattern, for the streaming drift guard.

    Same estimator as ``plan.gamma`` (dead slots compacted away), with the
    edge arrays zero-weight-padded to a quantized length so per-step guard
    evaluations over a drifting nnz reuse one compiled kernel."""
    if alive_sorted.all():
        rr, cc = r2, c2
    else:
        rr, cc, _ = measures.compact_live(r2, c2, alive_sorted)
    q = -(-max(len(rr), 1) // 8192) * 8192
    pad = q - len(rr)
    w = np.ones(len(rr), np.float32)
    if pad:
        rr = np.concatenate([rr, np.zeros(pad, rr.dtype)])
        cc = np.concatenate([cc, np.zeros(pad, cc.dtype)])
        w = np.concatenate([w, np.zeros(pad, np.float32)])
    # scored at grid size n=C (stable across steps, unlike the live
    # count) on a coarse 256-cell grid: successive guard calls and their
    # reference stay one cheap compiled kernel and one consistent
    # estimator — only the *relative* drift matters to the guard
    return float(measures.gamma_score(jnp.asarray(rr), jnp.asarray(cc),
                                      sigma, C, cells=256,
                                      weights=jnp.asarray(w)))


def _adopt_arrivals(r2, c2, v2, rn, cn, d2_fwd, host, x, pi, C,
                    cfg: PlanConfig):
    """Online reverse-kNN maintenance: existing rows adopt an arrival.

    A fresh build would point every row whose kNN the new point enters at
    it; the streamed pattern gets the same effect edge-exactly enough by
    letting each neighbor ``q`` of an arrival ``p`` adopt ``p`` iff
    ``d(q, p)`` beats ``q``'s current worst neighbor — which is then
    dropped, so rows keep k edges and nnz stays balanced (naively
    *adding* reverse edges inflates γ above a fresh build's). One
    adoption per row per batch, the closest arrival.

    ``(rn, cn, d2_fwd)`` are the arrivals' forward edges p -> q (cluster
    space, squared distances). Returns the updated ``(r2, c2, v2)`` plus
    the adopters' row set (their blocks join the patch).
    """
    no_rows = np.empty(0, np.int64)
    # best arrival per adopter q (closest first occurrence)
    order = np.lexsort((d2_fwd, cn))
    uq, first = np.unique(cn[order], return_index=True)
    chosen = order[first]
    q_all, p_all, d2_all = cn[chosen], rn[chosen], d2_fwd[chosen]

    # current worst neighbor of each candidate adopter (distances derived
    # from coordinates — the pattern does not store them)
    sel = np.nonzero(np.isin(r2, q_all))[0]
    if sel.size == 0:
        return r2, c2, v2, no_rows
    er, ec = r2[sel], c2[sel]
    ed2 = np.sum((x[pi[er]] - x[pi[ec]]) ** 2, axis=1)
    worst_order = np.lexsort((-ed2, er))
    wq, wfirst = np.unique(er[worst_order], return_index=True)
    worst_idx = sel[worst_order[wfirst]]          # global COO index
    worst_d2 = ed2[worst_order[wfirst]]

    pos = np.searchsorted(wq, q_all)
    hasq = (pos < len(wq)) & (wq[np.clip(pos, 0, max(len(wq) - 1, 0))]
                              == q_all)
    adopt = hasq & (d2_all < worst_d2[np.clip(pos, 0, max(len(wq) - 1, 0))])
    if not adopt.any():
        return r2, c2, v2, no_rows
    q_a, p_a, d2_a = q_all[adopt], p_all[adopt], d2_all[adopt]
    drop_idx = worst_idx[pos[adopt]]

    keep = np.ones(len(r2), bool)
    keep[drop_idx] = False
    if host.values_mode == "fn":
        va = np.asarray(host.values_fn(pi[q_a], pi[p_a], d2_a), np.float32)
    else:
        va = np.ones(q_a.size, np.float32)
    r2 = np.concatenate([r2[keep], q_a])
    c2 = np.concatenate([c2[keep], p_a])
    v2 = np.concatenate([v2[keep], va])
    return r2, c2, v2, np.unique(q_a)


def _stream_rebucket(pi, codes, r2, c2, C: int):
    """Stable re-sort of the physical slots by their maintained Morton
    codes; relabels the cluster-space COO to match. Points (and holes)
    with unchanged codes keep their relative order (see
    :func:`repro.core.ordering.stream_rebucket`)."""
    return ordering_mod.stream_rebucket(pi, codes, r2, c2, C)


def _spread_holes(plan: InteractionPlan) -> InteractionPlan:
    """Interleave pre-allocated capacity through the ordering (build-time
    only): seed the holes with quantile codes and rebucket once, so the
    spare slots sit inside the leaves inserts will target — instead of
    bunched at the tail where every early insert would displace to."""
    host, cfg = plan.host, plan.config
    if host.embedding is None:
        return plan            # no spatial ordering to interleave into
    codes, lo, hi = _stream_codes(host, cfg)
    r2, c2, v2 = host.coo
    pi, inv, r2n, c2n = _stream_rebucket(host.pi, codes, r2, c2, plan.n)
    bsr = (build_bsr(r2n, c2n, v2, plan.n, bs=cfg.bs, sb=cfg.sb,
                     slack=cfg.ell_slack)
           if plan.bsr is not None else None)
    stats = host.refresh
    if bsr is not None:
        stats = dataclasses.replace(stats, fill0=bsr.fill)
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, coo=(r2n, c2n, v2), coo_dev=None, tree=None,
        codes=codes, code_lo=lo, code_hi=hi, refresh=stats,
        shard_cache={}, last_patch_rb=None)
    return InteractionPlan(cfg, plan.n, bsr, jnp.asarray(pi, jnp.int32),
                           jnp.asarray(inv, jnp.int32), host2)


def _require_streamable(plan: InteractionPlan) -> None:
    host = plan.host
    if host.embed_axes is None or host.embedding is None:
        raise ValueError(
            "plan is not streamable: no stored embedding map (build with "
            "ordering='dual_tree' and coordinates x)")
    if host.x is None:
        raise ValueError(
            "plan is not streamable: original coordinates were not "
            "retained (rebuild via build_plan, or restore a checkpoint "
            "saved from a streamable plan)")
    if not host.pattern_from_knn or host.values_mode == "static":
        raise ValueError(
            "plan is not streamable: its pattern/values are externally "
            "fixed, so edges for inserted points cannot be derived "
            "(build from points with values=None or a callable)")
    if host.sources is not None:
        raise ValueError(
            "fixed-source plans (sources=) tie targets and sources to "
            "one index space; streaming inserts/deletes are not "
            "meaningful there")


def _compact_plan(plan: InteractionPlan, alive: np.ndarray, x: np.ndarray,
                  stats: RefreshStats, n_ins: int, n_del: int,
                  inserted_phys: Optional[np.ndarray],
                  grows: int) -> InteractionPlan:
    """Compaction tier: full build on the surviving points (capacity
    shrinks to the live count — identical, bit for bit, to a fresh
    ``build_plan`` over those points) with lineage telemetry carried and
    ``host.compact_map`` recording old physical slot -> new index."""
    host, cfg = plan.host, plan.config
    values = host.values_fn if host.values_mode == "fn" else None
    new = build_plan(x[alive], config=cfg, values=values, sigma=host.sigma,
                     with_bsr=plan.bsr is not None)
    cmap = np.full(len(alive), -1, np.int64)
    cmap[alive] = np.arange(int(alive.sum()))
    new.host.compact_map = cmap
    if inserted_phys is not None:
        new.host.last_inserted_idx = cmap[inserted_phys]
    if stats.gamma0 is not None or host.gamma is not None:
        # the lineage had a γ reference: score the compacted plan so the
        # guard stays armed. gamma0 itself is left None — the next
        # update_plan re-derives the reference with the guard's own
        # (coarse-grid) estimator, which is not comparable to this exact
        # score.
        _ = new.gamma
    new.host.refresh = dataclasses.replace(
        new.host.refresh, builds=stats.builds + 1, patches=stats.patches,
        rebuckets=stats.rebuckets, rebuilds=stats.rebuilds,
        appends=stats.appends + (1 if n_ins else 0),
        tombstones=stats.tombstones + (1 if n_del else 0),
        compactions=stats.compactions + 1, grows=grows,
        restripes=stats.restripes,
        inserted_total=stats.inserted_total + n_ins,
        deleted_total=stats.deleted_total + n_del,
        last_action="compact")
    return new


def _grow_plan(plan: InteractionPlan, capacity: int) -> InteractionPlan:
    """Reallocate the physical layout to ``capacity`` slots: new slots
    are appended at the tail of both index spaces as tombstoned (dead)
    capacity — empty BSR row-blocks (``blocksparse.append_rows``), tail
    permutation entries, sentinel placement codes."""
    host = plan.host
    n0, grow = plan.n, capacity - plan.n
    if grow <= 0:
        return plan
    pi = np.concatenate([host.pi, np.arange(n0, capacity)])
    inv = np.concatenate([host.inv, np.arange(n0, capacity)])
    alive = np.zeros(capacity, bool)
    alive[:n0] = True if host.alive is None else host.alive
    pad2 = ((0, grow), (0, 0))

    def _pad_rows(a, fill=0.0):
        return (None if a is None
                else np.pad(a, pad2, constant_values=fill))

    live_mask = (np.ones(n0, bool) if host.alive is None else host.alive)
    codes = (None if host.codes is None
             else np.concatenate([host.codes,
                                  _seed_hole_codes(
                                      host.codes[live_mask], grow)]))
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, alive=alive, x=_pad_rows(host.x),
        embedding=_pad_rows(host.embedding), y_last=_pad_rows(host.y_last),
        codes=codes, coo_dev=None, shard_cache={},
        last_patch_rb=None)
    bsr = (append_rows(plan.bsr, capacity)
           if plan.bsr is not None else None)
    return InteractionPlan(plan.config, capacity, bsr,
                           jnp.asarray(pi, jnp.int32),
                           jnp.asarray(inv, jnp.int32), host2)


def update_plan(plan: InteractionPlan, *, insert=None, delete=None,
                policy: Optional[str] = None,
                defer_layout: bool = False) -> InteractionPlan:
    """One streaming step: delete ``delete`` (physical row indices), then
    insert ``insert`` (m, D) new points, escalating through the streaming
    tiers of the drift policy:

      tombstone  (deletes) rows are marked dead in the validity mask, the
                 COO drops every edge touching them, and only the
                 row-blocks that held such an edge are re-dressed in
                 place (``blocksparse.tombstone_rows``) — the permutation
                 and every other block are untouched
      append     (inserts) points re-embed through the stored PCA map,
                 claim the free (tombstoned) cluster slot nearest their
                 Morton leaf, kNN is computed for the new rows only, and
                 the affected row-blocks are patched in place; when no
                 free slot remains, capacity grows by
                 ``PlanConfig.grow_frac`` (tail slots, amortized O(1))
      restripe   an append that overflows the pinned ELL width (slack
                 from ``PlanConfig.ell_slack``) rebuilds the *storage
                 only* from the maintained COO — ordering, permutation
                 and kNN rows kept, so it costs a ``build_bsr``, not the
                 pipeline (counted in ``RefreshStats.restripes``; sharded
                 plans re-shard on it)
      compact    full rebuild on the surviving points — triggered when
                 the capacity fraction lost since the lineage's live
                 peak exceeds ``PlanConfig.max_dead_frac`` (tombstone
                 debris; pre-allocated holes never count)
                 or an overflow restripe shows fill degradation beyond
                 ``PlanConfig.drift_tol`` (the layout genuinely decayed);
                 bit-identical to a fresh ``build_plan`` over the
                 survivors, with ``host.compact_map`` mapping old
                 physical slots to new indices

    ``policy`` forces a tier: ``"append"``/``"tombstone"`` pin the
    in-place tiers (an ELL overflow then raises instead of restriping),
    ``"compact"`` forces the rebuild, ``None``/``"auto"`` escalate as
    above. Between compactions the pattern is maintained approximately:
    inserted rows get exact kNN edges, but a surviving row whose
    neighbor was deleted keeps a short row until the next compaction
    (the γ telemetry and ``plan.dead_frac`` expose the decay). Returns a
    new plan; the input is never mutated. The inserted points' physical
    row indices land in ``host.last_inserted_idx`` (see
    :meth:`InteractionPlan.insert`).

    ``defer_layout=True`` keeps the step on the in-place tiers: the
    *optional* layout repairs (γ-drift rebucket, debris/fill-drift
    compaction) are detected but not run — the tier that fired is
    recorded in ``host.pending_layout`` for :func:`apply_pending_layout`
    to execute later, typically on a background thread behind
    :class:`repro.core.doublebuf.DoubleBufferedPlan`. An ELL overflow
    still restripes synchronously (the storage would otherwise be out of
    sync with the maintained COO); an explicit ``policy="compact"`` also
    still runs synchronously.

    Example:
        >>> import numpy as np
        >>> from repro import api
        >>> x = np.random.default_rng(0).standard_normal((64, 8))
        >>> plan = api.build_plan(x, k=4, bs=8, sb=2, backend="bsr")
        >>> p2 = api.update_plan(plan, delete=[3, 11])
        >>> p2.n_alive, p2.refresh_stats.last_action
        (62, 'tombstone')
        >>> api.update_plan(p2, insert=x[:2]).n_alive   # reuses the holes
        64
        >>> p3 = api.update_plan(plan, delete=list(range(24)),
        ...                      defer_layout=True)     # past max_dead_frac
        >>> p3.host.pending_layout
        'compact'
        >>> api.apply_pending_layout(p3).n_alive
        40

    Raises:
        ValueError: on a non-streamable plan, out-of-range/already-dead
            delete indices, mis-shaped inserts, too few surviving points
            (``<= k``), an unknown ``policy``, or an ELL overflow under a
            forced in-place policy.
    """
    if policy not in (None, "auto", "append", "tombstone", "compact"):
        raise ValueError(f"unknown streaming policy {policy!r}; expected "
                         "auto | append | tombstone | compact")
    _require_streamable(plan)
    host, cfg = plan.host, plan.config
    stats = host.refresh

    ins = None
    if insert is not None:
        ins = np.asarray(insert, np.float32)
        if ins.ndim != 2 or ins.shape[1] != host.embed_axes.shape[0]:
            raise ValueError(
                f"insert expects (m, {host.embed_axes.shape[0]}) points, "
                f"got shape {ins.shape}")
        if ins.shape[0] == 0:
            ins = None
    del_idx = None
    if delete is not None:
        del_idx = np.unique(np.asarray(delete, np.int64))
        if del_idx.size == 0:
            del_idx = None
    if ins is None and del_idx is None and policy != "compact":
        return plan

    grows = stats.grows

    # -- copy-on-write streaming state (the input plan stays valid) --------
    C = plan.n
    alive = (np.ones(C, bool) if host.alive is None else host.alive.copy())
    x = host.x.copy()
    emb = host.embedding.copy()
    y_last = (emb.copy() if host.y_last is None else host.y_last.copy())
    pi, inv = host.pi, host.inv
    r2, c2, v2 = host.coo
    bsr = plan.bsr
    touched_parts = []
    overflow = False
    restriped_del = False

    n_del = 0
    if del_idx is not None:
        if del_idx.min(initial=0) < 0 or del_idx.max(initial=-1) >= C:
            raise ValueError(
                f"delete indices out of range for capacity {C}")
        if not alive[del_idx].all():
            dead = del_idx[~alive[del_idx]]
            raise ValueError(
                f"delete of already-dead rows {dead[:8].tolist()}"
                f"{'...' if dead.size > 8 else ''}")
        n_del = int(del_idx.size)
        alive[del_idx] = False
        if int(alive.sum()) <= cfg.k:
            raise ValueError(
                f"deleting {n_del} rows leaves {int(alive.sum())} live "
                f"points <= k={cfg.k}; the kNN pattern needs more")
        if not cfg.symmetrize:
            # route broken edges around the tombstones before they are
            # filtered (replacements touch the same blocks the drops do)
            rr, cc, vv = _route_dead_edges(r2, c2, v2, inv[del_idx], C,
                                           host, x, pi, cfg)
            if rr.size:
                r2 = np.concatenate([r2, rr])
                c2 = np.concatenate([c2, cc])
                v2 = np.concatenate([v2, vv])
        if bsr is not None and ins is None:
            # pure delete: the storage-level tombstone primitive. The
            # routed replacement edges above can push an ELL-full block
            # over its width — restripe then, like the insert path.
            try:
                bsr, r2, c2, v2, touched_del = tombstone_rows(
                    bsr, r2, c2, v2, inv[del_idx])
            except ValueError:
                dead_cl = inv[del_idx]
                drop = np.isin(r2, dead_cl) | np.isin(c2, dead_cl)
                r2, c2, v2 = r2[~drop], c2[~drop], v2[~drop]
                if policy in ("append", "tombstone"):
                    raise ValueError(
                        "a routed tombstone edge overflowed the pinned "
                        f"ELL width under policy={policy!r}; raise "
                        "PlanConfig.ell_slack or let the auto policy "
                        "restripe")
                bsr = build_bsr(r2, c2, v2, C, bs=cfg.bs, sb=cfg.sb,
                                slack=cfg.ell_slack)
                restriped_del = True
                touched_del = np.empty(0, np.int64)
        else:
            # combined with an insert below: filter the pattern here and
            # re-dress delete- and insert-touched blocks in ONE patch
            dead_cl = inv[del_idx]
            drop = np.isin(r2, dead_cl) | np.isin(c2, dead_cl)
            touched_del = np.unique(np.concatenate(
                [r2[drop] // cfg.bs, dead_cl // cfg.bs]))
            r2, c2, v2 = r2[~drop], c2[~drop], v2[~drop]
        touched_parts.append(touched_del)

    inserted_phys = None
    n_ins = 0
    codes = code_lo = code_hi = None
    if ins is not None:
        n_ins = int(ins.shape[0])
        # codes from the *pre-delete* validity state: a slot tombstoned
        # this very step keeps its point's code, so the hole it leaves
        # advertises the leaf neighborhood it sits in
        codes, code_lo, code_hi = _stream_codes(host, cfg)
        free_phys = np.nonzero(~alive)[0]
        if n_ins > free_phys.size:
            # grow capacity: reallocate with a chunk of tail slots so the
            # amortized cost per insert is O(1)
            need = n_ins - free_phys.size
            grow = max(need, int(np.ceil(cfg.grow_frac * C)))
            C2 = _round_up(C + grow, cfg.bs)
            scratch = InteractionPlan(cfg, C, bsr,
                                      plan.pi, plan.inv,
                                      dataclasses.replace(
                                          host, alive=alive, x=x,
                                          embedding=emb, y_last=y_last,
                                          codes=codes, code_lo=code_lo,
                                          code_hi=code_hi,
                                          coo=(r2, c2, v2)))
            grown = _grow_plan(scratch, C2)
            h2 = grown.host
            C, bsr = C2, grown.bsr
            alive, x, emb, y_last = h2.alive, h2.x, h2.embedding, h2.y_last
            pi, inv, codes = h2.pi, h2.inv, h2.codes
            grows += 1

        y_ins = np.asarray(apply_pca_map(jnp.asarray(ins),
                                         jnp.asarray(host.embed_mean),
                                         jnp.asarray(host.embed_axes)))
        codes_ins = np.asarray(hierarchy.morton_codes_box(
            jnp.asarray(y_ins), jnp.asarray(code_lo),
            jnp.asarray(code_hi), cfg.bits)).astype(np.uint64)

        # claim the free cluster slot nearest each point's Morton leaf;
        # claiming in code order keeps batch-mates from the same leaf in
        # adjacent slots (tail blocks then see a compact column footprint)
        free_pos = np.nonzero(~alive[pi])[0]
        targets = hierarchy.insertion_positions(codes[pi], codes_ins)
        order = np.argsort(codes_ins, kind="stable")
        pos_sorted = ordering_mod.claim_free_slots(free_pos, targets[order])
        pos = np.empty_like(pos_sorted)
        pos[order] = pos_sorted
        phys = np.asarray(pi[pos], np.int64)
        alive[phys] = True
        x[phys] = ins
        emb[phys] = y_ins
        y_last[phys] = y_ins
        codes[phys] = codes_ins
        inserted_phys = phys

        if int(alive.sum()) <= cfg.k:
            raise ValueError(
                f"{int(alive.sum())} live points after insert but "
                f"k={cfg.k}; the kNN pattern needs more")
        nr, nc, nd2 = _knn_subset(x, phys, None, cfg.k, valid=alive)
        nv = _edge_values(host, nr, nc, nd2)
        if cfg.symmetrize:
            nr, nc, nv = _symmetrize_pattern(nr, nc, nv, C)
        rn, cn = ordering_mod.apply_ordering(nr, nc, pi)
        if not cfg.symmetrize:
            # reverse maintenance: rows whose kNN the arrivals enter
            # adopt them (dropping their previous worst neighbor), like
            # a fresh build would point them at the new points
            r2, c2, v2, adopters = _adopt_arrivals(
                r2, c2, v2, rn, cn, nd2, host, x, pi, C, cfg)
            if adopters.size:
                touched_parts.append(np.unique(adopters // cfg.bs))
        r2 = np.concatenate([r2, rn])
        c2 = np.concatenate([c2, cn])
        v2 = np.concatenate([v2, nv])
        if cfg.symmetrize:   # mirrored edges may duplicate kept ones
            key = r2.astype(np.int64) * C + c2
            _, first = np.unique(key, return_index=True)
            r2, c2, v2 = r2[first], c2[first], v2[first]
        touched_ins = np.unique(rn // cfg.bs)
        touched_parts.append(touched_ins)

    # -- tier decision ------------------------------------------------------
    # debris, not holes: the compaction trigger measures live points LOST
    # since the layout's peak, so capacity pre-allocated as insert
    # headroom (build_plan(capacity=) / PlanBatch pow2 padding — often a
    # large fraction by construction) never reads as decay. Otherwise a
    # generously padded plan would compact on its first delete, and the
    # re-padded result would compact again on every subsequent step.
    n_alive_now = int(alive.sum())
    prev_alive = plan.n if host.alive is None else int(host.alive.sum())
    peak = max(host.peak_alive or 0, prev_alive, n_alive_now)
    debris_frac = (peak - n_alive_now) / max(C, 1)
    force_inplace = policy in ("append", "tombstone")
    pending = host.pending_layout if defer_layout else None
    if (policy == "compact" or debris_frac > cfg.max_dead_frac) \
            and not force_inplace:
        if defer_layout and policy != "compact":
            pending = "compact"   # hygiene, not correctness: defer it
        else:
            return _compact_plan(plan, alive, x, stats, n_ins, n_del,
                                 inserted_phys, grows)

    # γ-drift guard (armed once the lineage holds a γ reference — score
    # the plan once to opt in): displaced inserts decay the *ordering*,
    # which a streaming rebucket repairs at build_bsr cost — a stable
    # re-sort of the maintained per-slot Morton codes, no kNN, no
    # re-embedding (the paper's ordering stays the asset; only its
    # bookkeeping is refreshed)
    g_now = None
    rebucketed = False
    alive_sorted = alive[pi]
    if bsr is not None and n_ins and not force_inplace:
        ref = stats.gamma0
        if ref is None and host.gamma is not None:
            # arm the guard: the reference must come from the same (cheap,
            # coarse-grid) estimator the per-step evaluations use, so
            # score the pre-update pattern once
            r0, c0, _ = host.coo
            prev_alive = (np.ones(plan.n, bool) if host.alive is None
                          else host.alive)[host.pi]
            ref = _guard_gamma(r0, c0, prev_alive, host.sigma, C)
        if ref is not None:
            if stats.gamma0 is None:
                stats = dataclasses.replace(stats, gamma0=ref)
            g_now = _guard_gamma(r2, c2, alive_sorted, host.sigma, C)
            rebucketed = measures.gamma_drift(ref, g_now) > cfg.gamma_tol

    gamma0_next = stats.gamma0
    if rebucketed and (defer_layout or pending == "compact"):
        # drift detected but the repair is deferred (a pending compact
        # subsumes it — the rebuild re-derives the ordering anyway); the
        # step stays on the in-place patch below, and the reference is
        # kept so the guard keeps firing until the repair lands
        pending = pending or "rebucket"
        rebucketed = False
    if rebucketed:
        pi, inv, r2, c2 = _stream_rebucket(pi, codes, r2, c2, C)
        bsr = build_bsr(r2, c2, v2, C, bs=cfg.bs, sb=cfg.sb,
                        slack=cfg.ell_slack)
        # re-score under the repaired ordering: the new γ is both the
        # plan's score and the reference the guard stays armed with
        g_now = _guard_gamma(r2, c2, alive[pi], host.sigma, C)
        gamma0_next = g_now
    elif bsr is not None and touched_parts and ins is not None:
        # in-place: delete- and insert-touched blocks re-dressed in ONE
        # patch pass (pure deletes were patched by tombstone_rows). The
        # tiles are scattered on device, so even scattered churn touching
        # most row-blocks stays cheaper than a restripe — and, unlike a
        # restripe, keeps the ELL layout (and every compiled consumer)
        # intact.
        touched_now = np.unique(np.concatenate(touched_parts))
        try:
            bsr = patch_bsr(bsr, r2, c2, v2, touched_now)
        except ValueError:
            overflow = True   # pinned ELL width exhausted

    restriped = restriped_del
    if overflow:
        # restripe: rebuild the *storage only* from the maintained COO —
        # ordering, permutation, kNN rows all kept — re-deriving the ELL
        # width (plus fresh slack) at build_bsr cost, not the pipeline's.
        # Never deferred: the patch failed, so the stored tiles no longer
        # match the maintained COO.
        if force_inplace:
            raise ValueError(
                "streamed insert overflowed the pinned ELL width under "
                f"policy={policy!r}; raise PlanConfig.ell_slack or let "
                "the auto policy restripe/compact")
        bsr = build_bsr(r2, c2, v2, C, bs=cfg.bs, sb=cfg.sb,
                        slack=cfg.ell_slack)
        restriped = True
        if measures.fill_drift(stats.fill0, bsr.fill) > cfg.drift_tol:
            # the restriped layout shows real locality decay: escalate
            if defer_layout:
                pending = "compact"
            else:
                return _compact_plan(plan, alive, x, stats, n_ins, n_del,
                                     inserted_phys, grows)

    layout_changed = rebucketed or restriped
    stats2 = dataclasses.replace(
        stats,
        appends=stats.appends + (1 if n_ins else 0),
        tombstones=stats.tombstones + (1 if n_del else 0),
        grows=grows,
        restripes=stats.restripes + (1 if restriped else 0),
        rebuckets=stats.rebuckets + (1 if rebucketed else 0),
        fill0=(bsr.fill if layout_changed and bsr is not None
               else stats.fill0),
        gamma0=gamma0_next,
        inserted_total=stats.inserted_total + n_ins,
        deleted_total=stats.deleted_total + n_del,
        last_action="append" if n_ins else "tombstone")
    touched = (np.unique(np.concatenate(touched_parts))
               if touched_parts else np.empty(0, np.int64))
    if layout_changed:
        # the ELL layout (or the ordering itself) changed wholesale:
        # incremental shard patches do not apply (ShardedPlan.update
        # re-shards on this)
        touched = None
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, coo=(r2, c2, v2), coo_dev=None,
        gamma=None,   # lazily rescored; the guard chain (gamma0) is kept
        #   on its own capacity-grid estimator, see _guard_gamma
        tree=None if rebucketed else host.tree,
        embedding=emb, y_last=y_last, x=x, alive=alive,
        codes=codes if codes is not None else host.codes,
        code_lo=code_lo if codes is not None else host.code_lo,
        code_hi=code_hi if codes is not None else host.code_hi,
        refresh=stats2, last_patch_rb=touched, peak_alive=peak,
        last_inserted_idx=inserted_phys, compact_map=None,
        pending_layout=pending, shard_cache={})
    new_dev = C != plan.n or rebucketed
    pi_dev = jnp.asarray(pi, jnp.int32) if new_dev else plan.pi
    inv_dev = jnp.asarray(inv, jnp.int32) if new_dev else plan.inv
    return InteractionPlan(cfg, C, bsr, pi_dev, inv_dev, host2)


def _apply_stream_rebucket(plan: InteractionPlan) -> InteractionPlan:
    """Run the streaming rebucket tier on ``plan`` as it stands: stable
    re-sort of the physical slots by their maintained Morton codes, then
    a restripe of the storage under the repaired ordering. Pure function
    of the input plan — safe to run on a snapshot from another thread."""
    host, cfg, C = plan.host, plan.config, plan.n
    stats = host.refresh
    codes, lo, hi = _stream_codes(host, cfg)
    r2, c2, v2 = host.coo
    pi, inv, r2n, c2n = _stream_rebucket(host.pi, codes, r2, c2, C)
    bsr = (build_bsr(r2n, c2n, v2, C, bs=cfg.bs, sb=cfg.sb,
                     slack=cfg.ell_slack)
           if plan.bsr is not None else None)
    alive = np.ones(C, bool) if host.alive is None else host.alive
    gamma0 = stats.gamma0
    if gamma0 is not None:
        # keep the guard armed with the repaired ordering's own score
        gamma0 = _guard_gamma(r2n, c2n, alive[pi], host.sigma, C)
    stats2 = dataclasses.replace(
        stats, rebuckets=stats.rebuckets + 1, last_action="rebucket",
        fill0=bsr.fill if bsr is not None else stats.fill0,
        gamma0=gamma0)
    host2 = dataclasses.replace(
        host, pi=pi, inv=inv, coo=(r2n, c2n, v2), coo_dev=None,
        gamma=None, tree=None, codes=codes, code_lo=lo, code_hi=hi,
        refresh=stats2, last_patch_rb=None, pending_layout=None,
        shard_cache={})
    return InteractionPlan(cfg, C, bsr, jnp.asarray(pi, jnp.int32),
                           jnp.asarray(inv, jnp.int32), host2)


def apply_pending_layout(plan: InteractionPlan) -> InteractionPlan:
    """Run the layout tier a ``defer_layout`` update recorded.

    A streaming step under ``update_plan(..., defer_layout=True)`` stays
    on the in-place tiers and records the layout repair it *would* have
    escalated to in ``host.pending_layout``:

      ``"rebucket"``  γ drifted past ``PlanConfig.gamma_tol`` — re-sort
                      the slots by their maintained Morton codes and
                      restripe the storage under the repaired ordering
      ``"compact"``   tombstone debris or fill drift — full rebuild on
                      the survivors, bit-identical to a fresh
                      ``build_plan`` over them (``host.compact_map``
                      maps old physical slots to new indices)

    This function executes that repair synchronously and returns the
    successor plan (the input is never mutated, and keeps serving valid
    results while this runs — the double-buffer property
    :class:`repro.core.doublebuf.DoubleBufferedPlan` builds on). A plan
    with nothing pending is returned unchanged.

    Returns:
        The repaired :class:`InteractionPlan` (``pending_layout`` is
        cleared), or ``plan`` itself when nothing was pending.
    """
    kind = plan.host.pending_layout
    if kind is None:
        return plan
    if kind == "rebucket":
        return _apply_stream_rebucket(plan)
    if kind == "compact":
        host, stats = plan.host, plan.host.refresh
        alive = (np.ones(plan.n, bool) if host.alive is None
                 else host.alive)
        return _compact_plan(plan, alive, host.x, stats, 0, 0, None,
                             stats.grows)
    raise ValueError(f"unknown pending layout tier {kind!r}")


# ---------------------------------------------------------------------------
# batched plans (many small problems in lockstep: one plan per head/batch
# entry, one compiled kernel for the whole batch)
# ---------------------------------------------------------------------------


def _pow2_capacity(n: int, bs: int) -> int:
    """Shared physical capacity for a batch: the next power of two at or
    above ``n``, rounded up to a whole bottom-level block. Quantizing keeps
    a *stream* of heterogeneous batches on a handful of compiled specs
    instead of one per max-member-size."""
    cap = 1 << max(int(np.ceil(np.log2(max(n, 1)))), 0)
    return _round_up(max(cap, n), bs)


# backends whose compute is pure device arrays (plan.bsr + n), and therefore
# vmap cleanly over stacked PlanData; csr reads the host COO and dist runs
# mesh collectives — neither can live under vmap
_BATCHED_BACKENDS = ("bsr", "bsr_ml", "pallas")


def _batch_take(xs: jax.Array, idx: jax.Array) -> jax.Array:
    """Per-lane permutation of a stacked batch: ``xs`` (B, n, ...) indexed
    by ``idx`` (B, n) along axis 1. Flattened to ONE offset gather — a
    vmapped/batched take lowers to scalar loops on the CPU backend."""
    B, n = idx.shape
    flat = xs.reshape((B * n,) + xs.shape[2:])
    off = (jnp.arange(B) * n)[:, None]
    return flat[(idx + off).reshape(-1)].reshape(xs.shape)


@functools.partial(jax.jit, static_argnames=("spec", "backend", "mode"))
def _batch_apply_kernel(spec: PlanSpec, data: PlanData, xs: jax.Array,
                        backend: str, mode: str) -> jax.Array:
    """One SpMV kernel over a whole stacked batch.

    ``spec`` (static) fixes shapes/code paths for every member; ``data``
    carries the stacked arrays. Backends with a registered *batched*
    implementation (``register_batched_backend`` — bsr/bsr_ml ship one)
    get the whole stack at once; anything else falls back to a generic
    ``vmap`` of its single-plan path, each lane reconstructing a traced
    view via ``InteractionPlan.from_spec_data`` — the spec/data split is
    exactly what makes both legal. Compiles once per (spec, backend,
    charge shape), however many plans ride the batch.
    """
    bfn = get_batched_backend(backend)
    if bfn is not None:
        if mode == "matvec":
            xs = _batch_take(xs, data.pi)
        ys = bfn(spec, data, xs)
        if mode == "matvec":
            ys = _batch_take(ys, data.inv)
        return ys

    fn = get_backend(backend)

    def one(d: PlanData, x: jax.Array) -> jax.Array:
        view = InteractionPlan.from_spec_data(spec, d)
        if mode == "matvec":
            x = jnp.take(x, d.pi, axis=0)
        y = fn(view, x)
        if mode == "matvec":
            y = jnp.take(y, d.inv, axis=0)
        return y

    return jax.vmap(one)(data, xs)


@functools.partial(jax.jit, static_argnames=("spec", "backend", "mode"))
def _batch_apply_scan(spec: PlanSpec, data: PlanData, xs: jax.Array,
                      backend: str, mode: str) -> jax.Array:
    """Serial variant of :func:`_batch_apply_kernel`: ``lax.scan`` over the
    batch axis, so the working set per step is one member's tiles (memory-
    bound batches). Still one trace/compilation for the whole batch."""
    fn = get_backend(backend)

    def step(_, dx):
        d, x = dx
        view = InteractionPlan.from_spec_data(spec, d)
        if mode == "matvec":
            x = jnp.take(x, d.pi, axis=0)
        y = fn(view, x)
        if mode == "matvec":
            y = jnp.take(y, d.inv, axis=0)
        return None, y

    _, ys = jax.lax.scan(step, None, (data, xs))
    return ys


class PlanBatch:
    """Many spec-identical plans stacked on a leading batch axis.

    The highest-traffic consumers of near-neighbor interaction run many
    *small* problems in lockstep — one interaction pattern per attention
    head or batch entry (the clusterkv-style workload). A single
    :class:`InteractionPlan` is identity-hashed static aux under ``jit``,
    so N plans mean N traces; a ``PlanBatch`` holds ONE hashable
    :class:`PlanSpec` plus stacked :class:`PlanData`, and every
    ``matvec``/``apply`` is one vmapped (or scanned) kernel — one
    compilation and one dispatch for the whole batch, any batch size.

    Members are padded to the shared spec at construction: capacity is
    pow2-quantized (`_pow2_capacity`) with the spare slots living as
    tombstoned streaming holes, and the ELL width is the max over members
    (extra slots are exactly `ell_slack` headroom). Both paddings are the
    PR-4 streaming substrate, so a member view (:meth:`member`) is a fully
    functional, streamable single plan.

    Streaming runs in lockstep: :meth:`update` pushes per-member
    insert/delete batches through the usual tiers (escalation decided
    *per plan* by each member's own drift policy), then re-unifies the
    spec — capacity/width only grow when some member outgrew the shared
    layout, so the compiled kernels survive almost every step.
    """

    def __init__(self, spec: PlanSpec, data: PlanData,
                 hosts: Sequence[_PlanHost], fills: Sequence[float],
                 tuned: Optional[dict] = None):
        self.spec = spec
        self.data = data
        self.hosts = list(hosts)
        self.fills = list(fills)
        self.tuned = dict(tuned or {})   # shared auto winners, per charge ndim

    # -- construction ------------------------------------------------------

    @classmethod
    def from_plans(cls, plans: Sequence[InteractionPlan], *,
                   capacity: Optional[int] = None) -> "PlanBatch":
        """Stack shape-compatible plans into one batch.

        Every member must share one ``PlanConfig`` (the spec is shared, so
        the knobs must be too) and agree on ``with_bsr``-ness. Members are
        padded to a common capacity (given, or the max member size pow2-
        quantized when sizes differ) and to the widest member's ELL width;
        padding reuses the streaming primitives (tail tombstone slots +
        spare ELL slots), so member views stay real streamable plans.
        """
        plans = list(plans)
        if not plans:
            raise ValueError("PlanBatch needs at least one plan")
        cfg = plans[0].config
        has_bsr = plans[0].bsr is not None
        for p in plans:
            if p.config != cfg:
                raise ValueError(
                    "PlanBatch members must share one PlanConfig (the "
                    f"spec is shared); got {p.config} vs {cfg}")
            if (p.bsr is not None) != has_bsr:
                raise ValueError("cannot mix profile-only (with_bsr=False) "
                                 "and storage-backed plans in one batch")
        ns = [p.n for p in plans]
        bs = plans[0].bsr.bs if has_bsr else cfg.bs
        if capacity is None:
            cap = ns[0] if len(set(ns)) == 1 else _pow2_capacity(max(ns), bs)
        else:
            if capacity < max(ns):
                raise ValueError(f"capacity={capacity} < largest member "
                                 f"n={max(ns)}")
            cap = capacity

        padded = []
        for p in plans:
            if p.n < cap:
                p = _grow_plan(p, cap)
                if p.host.embedding is not None:
                    # interleave the new holes through the ordering, like
                    # build_plan(capacity=): streamed inserts then land
                    # near their Morton leaf instead of at the tail
                    p = _spread_holes(p)
            padded.append(p)
        if has_bsr:
            m = max(p.bsr.max_nbr for p in padded)
            padded = [
                p if p.bsr.max_nbr == m
                else InteractionPlan(p.config, p.n,
                                     append_rows(p.bsr, p.n,
                                                 extra_nbr=m - p.bsr.max_nbr),
                                     p.pi, p.inv, p.host)
                for p in padded]

        spec = padded[0].spec
        for p in padded[1:]:
            assert p.spec == spec, (p.spec, spec)
        any_alive = any(p.host.alive is not None for p in padded)
        data = PlanData(
            pi=jnp.stack([p.pi for p in padded]),
            inv=jnp.stack([p.inv for p in padded]),
            col_idx=(jnp.stack([p.bsr.col_idx for p in padded])
                     if has_bsr else None),
            nbr_mask=(jnp.stack([p.bsr.nbr_mask for p in padded])
                      if has_bsr else None),
            vals=(jnp.stack([p.bsr.vals for p in padded])
                  if has_bsr else None),
            alive=(jnp.stack([jnp.asarray(p.alive) for p in padded])
                   if any_alive else None))
        fills = [p.bsr.fill if has_bsr else 0.0 for p in padded]
        return cls(spec, data, [p.host for p in padded], fills)

    # -- shape surface -----------------------------------------------------

    def __len__(self) -> int:
        return len(self.hosts)

    @property
    def batch(self) -> int:
        """Number of stacked members (the leading data axis B)."""
        return len(self.hosts)

    @property
    def capacity(self) -> int:
        """Shared physical capacity every member is padded to."""
        return self.spec.capacity

    @property
    def n_alive(self) -> np.ndarray:
        """(B,) live point count per member."""
        return np.array([int(np.asarray(h.alive).sum())
                         if h.alive is not None else self.capacity
                         for h in self.hosts])

    @property
    def stats(self) -> dict:
        """Batch telemetry: size, shared layout, per-member live counts,
        mean fill, and the tuned backend."""
        return {"batch": self.batch, "capacity": self.capacity,
                "max_nbr": self.spec.max_nbr,
                "n_alive": self.n_alive.tolist(),
                "fill_mean": float(np.mean(self.fills)),
                "backend": self.tuned.get(1, self.spec.config.backend)}

    def __repr__(self) -> str:
        return (f"PlanBatch(B={self.batch}, capacity={self.capacity}, "
                f"bs={self.spec.bs}, max_nbr={self.spec.max_nbr}, "
                f"backend={self.tuned.get(1, self.spec.config.backend)!r})")

    # -- members (single-plan views over slices of the stacked data) -------

    def member(self, i: int) -> InteractionPlan:
        """The i-th plan as a real single ``InteractionPlan`` view (its
        BSR arrays are slices of the stacked data; its host is the live
        per-member host, so lifecycle/streaming calls work)."""
        d = PlanData(
            pi=self.data.pi[i], inv=self.data.inv[i],
            col_idx=None if self.data.col_idx is None else
            self.data.col_idx[i],
            nbr_mask=None if self.data.nbr_mask is None else
            self.data.nbr_mask[i],
            vals=None if self.data.vals is None else self.data.vals[i],
            alive=None if self.data.alive is None else self.data.alive[i])
        return InteractionPlan.from_spec_data(self.spec, d,
                                              host=self.hosts[i],
                                              fill=self.fills[i])

    def members(self) -> List[InteractionPlan]:
        return [self.member(i) for i in range(self.batch)]

    # -- charges -----------------------------------------------------------

    def pad_charges(self, charges: Sequence[np.ndarray]) -> jax.Array:
        """Per-member charge arrays (n_i, ...) -> one (B, capacity, ...)
        batch, zero-padded on the capacity slots (construction-time
        convenience: member points occupy physical slots 0..n_i-1)."""
        if len(charges) != self.batch:
            raise ValueError(f"{len(charges)} charge arrays for batch of "
                             f"{self.batch}")
        first = np.asarray(charges[0])
        out = np.zeros((self.batch, self.capacity) + first.shape[1:],
                       np.float32)
        for i, c in enumerate(charges):
            c = np.asarray(c, np.float32)
            out[i, :c.shape[0]] = c
        return jnp.asarray(out)

    # -- interaction (one kernel for the whole batch) ----------------------

    def resolve_backend(self, name: Optional[str] = None,
                        x: Optional[jax.Array] = None) -> str:
        """Resolve a backend for the *whole batch* (one shared decision).
        ``"auto"`` probes the batched kernel over the batchable backends
        once per charge ndim — memoized structurally in
        ``core.autotune``, so spec-identical batches never re-probe."""
        name = name or self.spec.config.backend
        if name != "auto":
            if name in ("csr", "dist"):
                raise ValueError(
                    f"backend {name!r} cannot run batched: csr reads the "
                    "host-side COO and dist issues mesh collectives, "
                    "neither of which is vmappable — use one of "
                    f"{_BATCHED_BACKENDS} (or register a vmappable "
                    "backend)")
            return name
        ndim = (x.ndim - 1) if x is not None else 1
        if ndim not in self.tuned:
            if self.spec.max_nbr is None:
                raise ValueError("profile-only batch has no storage to "
                                 "run; rebuild with with_bsr=True")
            from repro.core.autotune import tune_batch_backend
            probe_x = x
            if probe_x is not None and isinstance(probe_x,
                                                  jax.core.Tracer):
                # can't time a tracer, but its (static) shape is exactly
                # what the probe must match — backend ranking changes
                # with the charge shape, so a synthetic stand-in of the
                # same shape keeps the cached winner honest
                probe_x = jnp.asarray(np.random.default_rng(0)
                                      .standard_normal(x.shape),
                                      jnp.float32)
            self.tuned[ndim], _ = tune_batch_backend(self, probe_x)
        return self.tuned[ndim]

    def _dispatch(self, xs: jax.Array, backend: Optional[str], mode: str,
                  serial: bool) -> jax.Array:
        if self.spec.max_nbr is None:
            raise ValueError("profile-only batch (with_bsr=False) has no "
                             "storage; rebuild with with_bsr=True")
        xs = jnp.asarray(xs)
        if xs.ndim not in (2, 3) or xs.shape[0] != self.batch \
                or xs.shape[1] != self.capacity:
            raise ValueError(
                f"batched charges must be (B={self.batch}, "
                f"capacity={self.capacity}) or (B, capacity, f); got "
                f"{xs.shape} (pad_charges packs ragged member charges)")
        name = self.resolve_backend(backend, x=xs)
        kern = _batch_apply_scan if serial else _batch_apply_kernel
        return kern(self.spec, self.data, xs, name, mode)

    def apply(self, xs: jax.Array, backend: Optional[str] = None, *,
              serial: bool = False) -> jax.Array:
        """Batched ``y_b = A'_b x_b`` in each member's cluster order.
        ``serial=True`` scans members instead of vmapping them (one
        member's tiles resident at a time); both compile once."""
        return self._dispatch(xs, backend, "apply", serial)

    def matvec(self, xs: jax.Array, backend: Optional[str] = None, *,
               serial: bool = False) -> jax.Array:
        """Batched ``y_b = A_b x_b`` in original order (per-member
        permute/apply/unpermute fused into the same compiled kernel)."""
        return self._dispatch(xs, backend, "matvec", serial)

    def solve(self, bs: jax.Array, *, shift: float = 0.0,
              backend: Optional[str] = None, precond: Optional[str] = None,
              tol: Optional[float] = None, maxiter: Optional[int] = None):
        """Solve all B member systems ``(A_b + shift*I) x_b = b_b`` in
        lockstep — ONE compiled CG kernel per spec (batched SpMV inside,
        batched-Cholesky preconditioning, per-lane early freeze).
        ``bs``: (B, capacity) or (B, capacity, t), original order, zeros
        on hole slots. Returns :class:`repro.solvers.CGResult` with
        per-lane telemetry."""
        from repro.solvers.krr import solve as _solve
        return _solve(self, bs, shift=shift, backend=backend,
                      precond=precond, tol=tol, maxiter=maxiter)

    # -- lockstep streaming (per-member tiers, one shared re-spec) ---------

    @staticmethod
    def _per_member(arg, B: int, what: str) -> list:
        if arg is None:
            return [None] * B
        if isinstance(arg, (list, tuple)):
            if len(arg) != B:
                raise ValueError(f"{what} has {len(arg)} entries for a "
                                 f"batch of {B}")
            return list(arg)
        arr = np.asarray(arg)
        if arr.shape[0] != B:
            raise ValueError(f"{what} leading axis {arr.shape[0]} != batch "
                             f"{B} (pass a (B, ...) array or a length-B "
                             "list, None entries to skip members)")
        return [arr[i] for i in range(B)]

    def update(self, *, insert=None, delete=None,
               policy: Optional[str] = None) -> "PlanBatch":
        """One lockstep streaming step over every member.

        ``insert``: (B, m, D) array or length-B list of (m_i, D) arrays
        (``None`` entries skip a member); ``delete`` likewise with
        physical row indices. Each member escalates through its own
        tombstone/append/rebucket/restripe/compact tiers
        (:func:`update_plan` — escalation is decided per plan), then the
        batch re-unifies: capacity and ELL width grow only when some
        member outgrew the shared layout, so the compiled batch kernels
        survive the step whenever no member escalated shapes. Returns a
        new batch; the input batch stays valid. Members skipped with
        ``None`` entries are carried through untouched — their host
        telemetry (``last_inserted_idx`` included) still reflects their
        *previous* step (:meth:`insert` masks this for its return value).
        """
        B = self.batch
        ins = self._per_member(insert, B, "insert")
        dels = self._per_member(delete, B, "delete")
        new = []
        for i in range(B):
            p = self.member(i)
            if ins[i] is not None or dels[i] is not None \
                    or policy == "compact":
                p = update_plan(p, insert=ins[i], delete=dels[i],
                                policy=policy)
            new.append(p)
        cap = max(p.n for p in new)
        cap = (self.capacity if cap <= self.capacity
               else _pow2_capacity(cap, self.spec.bs or self.spec.config.bs))
        out = PlanBatch.from_plans(new, capacity=cap)
        if out.spec == self.spec:
            out.tuned = dict(self.tuned)   # kernels + decision still valid
        return out

    def insert(self, xs) -> Tuple["PlanBatch", List[Optional[np.ndarray]]]:
        """Lockstep insert; returns ``(batch, idx)`` with each member's
        landed physical row indices (see ``InteractionPlan.insert``).
        Members skipped with a ``None`` entry get ``None`` back — their
        host still remembers an *earlier* step's landing slots, which
        must not be mistaken for this one's."""
        ins = self._per_member(xs, self.batch, "insert")
        out = self.update(insert=xs)
        return out, [out.hosts[i].last_inserted_idx
                     if ins[i] is not None else None
                     for i in range(self.batch)]

    def delete(self, idxs) -> "PlanBatch":
        """Lockstep tombstone of per-member physical row indices."""
        return self.update(delete=idxs)

    def compact(self) -> "PlanBatch":
        """Force every member through the compaction tier (fresh build on
        each member's survivors), then re-stack."""
        return self.update(policy="compact")

    @property
    def refresh_stats(self) -> List[RefreshStats]:
        """Per-member lifecycle counters, in batch order."""
        return [h.refresh for h in self.hosts]


def build_plan_batch(xs, *, k: int = 16, ordering: str = "dual_tree",
                     bs: int = 32, sb: int = 8, backend: str = "auto",
                     d: int = 3, bits: int = 10, leaf_size: int = 64,
                     symmetrize: bool = False, seed: int = 0,
                     values: "Callable | None" = None,
                     sigma: Optional[float] = None,
                     with_bsr: bool = True,
                     capacity: Optional[int] = None,
                     config: Optional[PlanConfig] = None,
                     **cfg_overrides) -> PlanBatch:
    """Run the pipeline once per member and stack the results (§2.4 × B).

    ``xs`` is a (B, n, D) array or a sequence of (n_i, D) point sets (sizes
    may differ — members are padded to a shared pow2-quantized capacity,
    the spare slots living as streaming holes interleaved through each
    member's leaves). Every member shares one ``PlanConfig``; ``values``
    must be ``None`` or a callable (a static per-member value array cannot
    ride the shared spec — dress members individually and use
    ``PlanBatch.from_plans`` for that). ``backend="auto"`` tunes ONE
    backend for the whole batch on first use, probing the batched kernel
    itself (memoized structurally, so spec-identical batches never
    re-probe).

    Example:
        >>> import numpy as np
        >>> from repro import api
        >>> rng = np.random.default_rng(0)
        >>> xs = [rng.standard_normal((48, 8)), rng.standard_normal((40, 8))]
        >>> batch = api.build_plan_batch(xs, k=4, bs=8, sb=2, backend="bsr")
        >>> batch.batch, batch.capacity       # pow2-quantized shared spec
        (2, 64)
        >>> batch.matvec(batch.pad_charges(
        ...     [np.ones(48, np.float32), np.ones(40, np.float32)])).shape
        (2, 64)
    """
    if values is not None and not callable(values):
        raise ValueError(
            "build_plan_batch values= must be None or a callable; a "
            "static value array is member-specific — build members with "
            "build_plan and stack them via PlanBatch.from_plans")
    if config is None:
        config = PlanConfig(k=k, ordering=ordering, bs=bs, sb=sb,
                            backend=backend, d=d, bits=bits,
                            leaf_size=leaf_size, symmetrize=symmetrize,
                            seed=seed, **cfg_overrides)
    elif cfg_overrides:
        config = dataclasses.replace(config, **cfg_overrides)
    members = [np.asarray(x, np.float32) for x in xs]
    if not members:
        raise ValueError("build_plan_batch needs at least one point set")
    ns = [m.shape[0] for m in members]
    if capacity is None:
        cap = ns[0] if len(set(ns)) == 1 else _pow2_capacity(max(ns),
                                                             config.bs)
    else:
        if capacity < max(ns):
            raise ValueError(f"capacity={capacity} < largest member "
                             f"n={max(ns)}")
        cap = capacity
    plans = [build_plan(x, config=config, values=values, sigma=sigma,
                        with_bsr=with_bsr,
                        capacity=cap if cap > x.shape[0] else None)
             for x in members]
    return PlanBatch.from_plans(plans, capacity=cap)
