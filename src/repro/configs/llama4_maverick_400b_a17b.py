"""llama4-maverick-400b-a17b [moe]: MoE 128 experts top-1 + 1 shared expert,
early fusion (text path only here). [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]
adafactor: Adam m/v at 400B does not fit 16 GB/chip at 256 chips even fully
sharded (12 B/param * 400e9 / 256 = 18.75 GB)."""
from repro.configs.base import ClusterKVConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff_expert=8192, n_shared_experts=1),
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    optimizer="adafactor",
    param_dtype="bfloat16",
    loss_chunk=4096,
)

REDUCED = ModelConfig(
    name="llama4-maverick-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff_expert=128, n_shared_experts=1),
    remat=False,
)
