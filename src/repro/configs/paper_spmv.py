"""The paper's OWN experiment configuration (§4): dataset sizes, neighbor
counts, orderings and block sizes used by the benchmark harness. Kept as a
config module so the benchmarks and the core library share one source of
truth."""
from dataclasses import dataclass, field
from typing import Tuple


@dataclass(frozen=True)
class SpMVExperiment:
    dataset: str              # "sift" (128-d) | "gist" (960-d) stand-ins
    n_points: int
    k_neighbors: int
    sigma: float              # gamma-score scale (paper: k/2)
    orderings: Tuple[str, ...] = ("scattered", "rcm", "pca_1d",
                                  "lex2", "lex3", "dual_tree")
    tile: int = 32            # bottom-level MXU tile (TPU adaptation)
    superblock: int = 8       # level-2 grouping, in tiles


TABLE1 = (
    SpMVExperiment("sift", 4096, 30, 15.0),
    SpMVExperiment("gist", 4096, 90, 45.0),
)

FIG3 = (
    SpMVExperiment("sift", 4096, 30, 15.0),
    SpMVExperiment("gist", 2048, 45, 22.5),
)

MICRO = {"n": 8192, "tile": 32, "tiles_per_row": 16}
