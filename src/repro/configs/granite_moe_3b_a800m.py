"""granite-moe-3b-a800m [moe]: 40 experts top-8, tiny expert d_ff=512.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]"""
from repro.configs.base import ClusterKVConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    moe=MoEConfig(n_experts=40, top_k=8, d_ff_expert=512),
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="granite-moe-3b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=64,
    vocab=256,
    moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=64),
    remat=False,
)
