from repro.configs.base import (
    ARCH_IDS,
    SHAPES,
    ClusterKVConfig,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    all_cells,
    cells,
    get_config,
    reduced_config,
)

__all__ = [
    "ARCH_IDS",
    "SHAPES",
    "ClusterKVConfig",
    "MLAConfig",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "all_cells",
    "cells",
    "get_config",
    "reduced_config",
]
