"""Config system: model/arch configs, input shapes, and the registry.

Every assigned architecture gets one file in this package defining a
``ModelConfig``; ``get_config(arch_id)`` returns it and
``reduced_config(arch_id)`` returns a CPU-smoke-test-sized variant of the
same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field, replace
from typing import Any, Dict, Optional, Tuple

# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
#   kind: "train" lowers train_step; "decode" lowers serve_step (1 new token
#   against a KV cache of seq_len); "prefill" lowers a prefill forward.
# ---------------------------------------------------------------------------
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "decode"),
}


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    n_shared_experts: int = 0          # shared (always-on) experts
    router_jitter: float = 0.0
    expert_parallel: bool = False      # EP all-to-all instead of expert-dim TP
    capacity_factor: float = 1.25      # tokens/expert cap multiplier


@dataclass(frozen=True)
class SSMConfig:
    version: int = 1                   # 1 = mamba1 selective scan, 2 = mamba2 SSD
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0                   # 0 -> ceil(d_model/16)
    head_dim: int = 64                 # mamba2 head dim
    chunk: int = 256                   # mamba2 SSD chunk length


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ClusterKVConfig:
    """The paper's technique as an attention backend (see core/clusterkv.py)."""
    enabled: bool = False
    embed_dim: int = 3                 # PCA embedding dim (paper: d = 1..3)
    block_q: int = 128                 # query tile (MXU aligned)
    block_k: int = 128                 # key tile
    blocks_per_query: int = 16         # top-B key blocks kept per query block
    local_window_blocks: int = 1       # always-kept local diagonal blocks
    decode_clusters: int = 16          # top-c clusters gathered at decode
    use_pallas: bool = False           # kernels/block_attention for the tiles
                                       # (interpret-mode on CPU; Mosaic on TPU)
    decode_backend: str = "auto"       # plan-decode attend: "xla" | "pallas"
                                       # | "auto" (cost-model pick)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                        # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                    # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    swa_window: int = 0                # sliding-window attention; 0 = full
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (zamba2-like): shared attention block every `shared_attn_every`
    shared_attn_every: int = 0
    # enc-dec (whisper-like)
    n_enc_layers: int = 0
    # vlm/audio stub frontends: inputs are precomputed embeddings
    embedding_inputs: bool = False
    # attention backend: "dense" | "clusterkv"
    clusterkv: ClusterKVConfig = field(default_factory=ClusterKVConfig)
    # training knobs
    optimizer: str = "adamw"           # adamw | adafactor
    remat: bool = True
    remat_policy: str = "full"         # full | dots (save matmul outputs)
    loss_chunk: int = 0                # 0 = unchunked CE; else tokens/chunk
    dtype: str = "bfloat16"            # compute dtype
    param_dtype: str = "float32"       # master param dtype (bf16 for 100B+)
    # sub-quadratic long-context backend for long_500k ("swa"|"clusterkv"|"ssm"|"skip")
    long_context: str = "clusterkv"

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def with_(self, **kw: Any) -> "ModelConfig":
        return replace(self, **kw)


ARCH_IDS = [
    "llava-next-34b",
    "qwen2-0.5b",
    "minicpm3-4b",
    "h2o-danube-3-4b",
    "mistral-large-123b",
    "falcon-mamba-7b",
    "whisper-medium",
    "llama4-maverick-400b-a17b",
    "granite-moe-3b-a800m",
    "zamba2-1.2b",
]

_MOD_FOR: Dict[str, str] = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MOD_FOR:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MOD_FOR[arch_id]}")
    return mod.CONFIG


def reduced_config(arch_id: str) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests."""
    mod = importlib.import_module(f"repro.configs.{_MOD_FOR[arch_id]}")
    return mod.REDUCED


def cells(arch_id: str):
    """Yield the (shape_name, seq, batch, kind) cells assigned to this arch."""
    cfg = get_config(arch_id)
    for name, (seq, batch, kind) in SHAPES.items():
        if name == "long_500k" and cfg.long_context == "skip":
            continue
        yield name, seq, batch, kind


def all_cells():
    for a in ARCH_IDS:
        for c in cells(a):
            yield (a,) + c
