"""llava-next-34b [vlm]: transformer backbone only (anyres patch frontend is a
stub; input_specs supplies precomputed patch+text embeddings).
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.configs.base import ClusterKVConfig, ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    embedding_inputs=True,
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="llava-next-34b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    embedding_inputs=True,
    remat=False,
)
