"""mistral-large-123b [dense]: 123B dense GQA.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]
adafactor: Adam m/v at 123B still fits, but adafactor keeps headroom for
activations at train_4k; see EXPERIMENTS.md §Dry-run."""
from repro.configs.base import ClusterKVConfig, ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=28672,
    vocab=32768,
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    optimizer="adafactor",
    param_dtype="bfloat16",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="mistral-large-123b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    remat=False,
)
