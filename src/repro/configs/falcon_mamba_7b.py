"""falcon-mamba-7b [ssm]: attention-free mamba1. [arXiv:2410.05355; unverified]
d_inner = 2*d_model = 8192, dt_rank = d_model/16 = 256."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(version=1, d_state=16, d_conv=4, expand=2, dt_rank=256),
    long_context="ssm",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="falcon-mamba-7b-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=256,
    ssm=SSMConfig(version=1, d_state=8, d_conv=4, expand=2, dt_rank=8),
    remat=False,
)
