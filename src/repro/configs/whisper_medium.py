"""whisper-medium [audio]: enc-dec, conv frontend stubbed (input_specs gives
frame embeddings). 24 enc + 24 dec layers. [arXiv:2212.04356; unverified]
long_500k SKIPPED: 500k-frame audio exceeds the architecture's positional
design (see DESIGN.md §6)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=51865,
    embedding_inputs=True,       # encoder takes precomputed frame embeddings
    long_context="skip",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="whisper-medium-reduced",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    embedding_inputs=True,
    long_context="skip",
    remat=False,
)
