"""qwen2-0.5b [dense]: GQA with QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.configs.base import ClusterKVConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="qwen2-0.5b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    qkv_bias=True,
    tie_embeddings=True,
    remat=False,
)
