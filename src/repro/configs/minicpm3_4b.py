"""minicpm3-4b [dense]: Multi-head Latent Attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]  MLA dims from the public HF config."""
from repro.configs.base import ClusterKVConfig, MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab=73448,
    mla=MLAConfig(
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_head_dim=64,
        qk_rope_head_dim=32,
        v_head_dim=64,
    ),
    d_head=64,
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="clusterkv",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="minicpm3-4b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    mla=MLAConfig(
        q_lora_rank=32,
        kv_lora_rank=16,
        qk_nope_head_dim=8,
        qk_rope_head_dim=4,
        v_head_dim=8,
    ),
    d_head=8,
    remat=False,
)
