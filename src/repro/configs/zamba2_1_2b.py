"""zamba2-1.2b [hybrid]: 38 mamba2 layers + a SHARED attention block applied
every 6 layers on concat(h, x_emb). [arXiv:2411.15242; hf]"""
from repro.configs.base import ClusterKVConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm=SSMConfig(version=2, d_state=64, head_dim=64, expand=2, chunk=256),
    shared_attn_every=6,
    clusterkv=ClusterKVConfig(enabled=True),
    long_context="ssm",
    loss_chunk=8192,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    ssm=SSMConfig(version=2, d_state=16, head_dim=16, expand=2, chunk=32),
    shared_attn_every=2,
    remat=False,
)
