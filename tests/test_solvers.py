"""Iterative solvers on the plan operator (ISSUE 10): batched CG with
telemetry, block-Jacobi preconditioning sliced from the plan's own BSR
tiles, KRR fit/predict, Lanczos eigensolves, and spectral embedding —
verified against dense references across single plans, PlanBatch
lockstep, sharded operators, and streamed plans mid-lifecycle.

Runs on any device count (1 under plain pytest, 8 under the CI
``multidevice`` job) — the sharded-CG leg exercises whatever mesh the
process has.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import registry
from repro.data.pipeline import feature_mixture
from repro.solvers import (RBFValues, cg, krr_fit, krr_fit_batch,
                           lanczos_eigsh, normalized_operator, redress_rbf,
                           solve, spectral_embedding)
from repro.solvers.precond import (block_jacobi, diag_tiles, diag_vector,
                                   jacobi)

N, D, K = 256, 16, 8
SHIFT = 5.0           # comfortably above |lambda_min| of the truncated W


@pytest.fixture(scope="module")
def x():
    return feature_mixture(N, D, n_clusters=8, seed=0)


@pytest.fixture(scope="module")
def plan(x):
    return api.build_plan(x, k=K, bs=16, sb=4, backend="bsr",
                          symmetrize=True, values=RBFValues())


def dense_shifted(p, shift=SHIFT):
    return np.asarray(p.bsr.to_dense()) + shift * np.eye(p.n)


def dense_solve_original(p, b, shift=SHIFT):
    """Dense reference in ORIGINAL index order."""
    pi, inv = np.asarray(p.pi), np.asarray(p.inv)
    sol = np.linalg.solve(dense_shifted(p, shift), np.asarray(b)[pi])
    return sol[inv]


# ---------------------------------------------------------------------------
# cg core
# ---------------------------------------------------------------------------


def test_cg_matches_dense():
    rng = np.random.default_rng(0)
    q = rng.standard_normal((24, 24)).astype(np.float32)
    a = q @ q.T + 24 * np.eye(24, dtype=np.float32)
    b = rng.standard_normal(24).astype(np.float32)
    res = cg(lambda v: jnp.asarray(a) @ v, jnp.asarray(b), tol=1e-6,
             maxiter=200)
    ref = np.linalg.solve(a, b)
    assert bool(res.converged)
    np.testing.assert_allclose(np.asarray(res.x), ref, rtol=2e-4, atol=1e-5)


def test_cg_multirhs_axis():
    """(B, n, t) lanes with axis=-2: every (lane, target) column solved."""
    rng = np.random.default_rng(1)
    a = np.stack([np.eye(16, dtype=np.float32) * (3 + i) for i in range(2)])
    b = rng.standard_normal((2, 16, 3)).astype(np.float32)
    res = cg(lambda v: jnp.einsum("bij,bjt->bit", jnp.asarray(a), v),
             jnp.asarray(b), axis=-2, tol=1e-6, maxiter=50)
    assert res.x.shape == (2, 16, 3)
    assert res.iters.shape == (2, 3)
    for i in range(2):
        np.testing.assert_allclose(np.asarray(res.x[i]), b[i] / (3 + i),
                                   rtol=1e-4)


def test_cg_telemetry_and_early_exit():
    """Lanes freeze individually: a trivial lane converges at iteration
    1 while a harder lane keeps running; its frozen history is NaN."""
    rng = np.random.default_rng(2)
    q = rng.standard_normal((32, 32)).astype(np.float32)
    hard = q @ q.T + 1e-1 * np.eye(32, dtype=np.float32)
    easy = np.eye(32, dtype=np.float32)
    a = jnp.stack([jnp.asarray(easy), jnp.asarray(hard)])
    b = jnp.asarray(rng.standard_normal((2, 32)), jnp.float32)
    res = cg(lambda v: jnp.einsum("bij,bj->bi", a, v), b, tol=1e-5,
             maxiter=400)
    it = np.asarray(res.iters)
    assert it[0] == 1 and it[1] > it[0]
    hist = np.asarray(res.history)
    assert hist.shape == (2, 401)
    # the easy lane ran exactly 1 iteration: entries past it are NaN
    assert np.isnan(hist[0, 2:]).all()
    assert np.isfinite(hist[1, :it[1] + 1]).all()
    # the recorded final residual is the history's last finite entry
    np.testing.assert_allclose(hist[1, it[1]], np.asarray(res.resid)[1],
                               rtol=1e-6)
    assert bool(np.asarray(res.converged).all())


def test_cg_zero_rhs_converges_immediately():
    res = cg(lambda v: 2.0 * v, jnp.zeros(8), tol=1e-5, maxiter=10)
    assert bool(res.converged) and int(res.iters) == 0
    np.testing.assert_array_equal(np.asarray(res.x), np.zeros(8))


# ---------------------------------------------------------------------------
# preconditioner extraction (satellite: bitwise against the dense matrix)
# ---------------------------------------------------------------------------


def test_diag_tiles_bitwise_match_dense(plan):
    """Block-Jacobi tiles must equal the diagonal blocks sliced from the
    densified operator BITWISE — extraction is a masked read of the very
    same ELL slots the dense path sums."""
    tiles = np.asarray(diag_tiles(plan.spec, plan.data))
    n_rb, bs = plan.spec.n_rb, plan.spec.bs
    dense = np.zeros((n_rb * bs, n_rb * bs), np.float32)
    d0 = np.asarray(plan.bsr.to_dense())
    dense[:d0.shape[0], :d0.shape[1]] = d0
    for rb in range(n_rb):
        sl = slice(rb * bs, (rb + 1) * bs)
        np.testing.assert_array_equal(tiles[rb], dense[sl, sl])


def test_diag_tiles_dead_slots_get_identity():
    """Capacity-padded plan with deleted points: dead slots must carry
    identity rows (never singular blocks), live blocks stay bitwise."""
    x = feature_mixture(200, D, n_clusters=4, seed=3)
    p = api.build_plan(x, k=K, bs=16, sb=4, backend="bsr", capacity=256,
                      symmetrize=True, values=RBFValues())
    p = p.update(delete=np.arange(0, 40))
    assert p.host.alive is not None and not bool(
        np.asarray(p.host.alive).all())
    tiles = np.asarray(diag_tiles(p.spec, p.data))
    n_rb, bs, cap = p.spec.n_rb, p.spec.bs, p.spec.capacity
    dense = np.zeros((n_rb * bs, n_rb * bs), np.float32)
    d0 = np.asarray(p.bsr.to_dense())
    dense[:d0.shape[0], :d0.shape[1]] = d0
    alive_cl = np.zeros(n_rb * bs, bool)
    alive_cl[:cap] = np.asarray(p.host.alive)[np.asarray(p.pi)]
    for rb in range(n_rb):
        sl = slice(rb * bs, (rb + 1) * bs)
        blk = dense[sl, sl].copy()
        a = alive_cl[sl]
        blk[~a, :] = 0.0
        blk[:, ~a] = 0.0
        blk[~a, ~a] = 1.0
        np.testing.assert_array_equal(tiles[rb], blk)
    # dead-slot identity rows keep every block SPD under the KRR-regime
    # shift (the truncated kernel itself is indefinite, so the shift must
    # clear its spectral floor — SHIFT does)
    L = np.linalg.cholesky(tiles + SHIFT * np.eye(bs, dtype=np.float32))
    assert np.isfinite(L).all()


def test_block_jacobi_inverts_diag_blocks(plan):
    """apply(r) == (D + shift I)^-1 r block-by-block."""
    rng = np.random.default_rng(4)
    r = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
    z = np.asarray(block_jacobi(plan.spec, plan.data, SHIFT)(r))
    tiles = np.asarray(diag_tiles(plan.spec, plan.data))
    bs = plan.spec.bs
    rp = np.zeros(plan.spec.n_rb * bs, np.float32)
    rp[:plan.n] = np.asarray(r)
    ref = np.concatenate([
        np.linalg.solve(tiles[i] + SHIFT * np.eye(bs), rp[i*bs:(i+1)*bs])
        for i in range(plan.spec.n_rb)])[:plan.n]
    np.testing.assert_allclose(z, ref, rtol=2e-4, atol=1e-5)


def test_jacobi_matches_pointwise_diag(plan):
    rng = np.random.default_rng(5)
    r = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
    z = np.asarray(jacobi(plan.spec, plan.data, SHIFT)(r))
    d = np.asarray(diag_vector(plan.spec, plan.data)) + SHIFT
    np.testing.assert_allclose(z, np.asarray(r) / d, rtol=1e-5)


# ---------------------------------------------------------------------------
# preconditioner registry (mirrors the backend registry)
# ---------------------------------------------------------------------------


def test_registry_defaults_registered():
    names = api.preconditioner_names()
    for name in ("block_jacobi", "jacobi", "identity"):
        assert name in names


def test_registry_unknown_has_did_you_mean():
    with pytest.raises(ValueError, match="block_jacobi"):
        api.get_preconditioner("blck_jacobi")


def test_registry_duplicate_raises():
    with pytest.raises(ValueError, match="already registered"):
        api.register_preconditioner("block_jacobi")(lambda s, d, sh: None)
    # overwrite with the original is allowed (and restores state)
    orig = api.get_preconditioner("block_jacobi")
    api.register_preconditioner("block_jacobi", orig, overwrite=True)


# ---------------------------------------------------------------------------
# config validation (satellite)
# ---------------------------------------------------------------------------


def test_config_validates_solver_knobs():
    with pytest.raises(ValueError, match="cg_tol"):
        api.PlanConfig(k=K, bs=16, sb=4, cg_tol=0.0)
    with pytest.raises(ValueError, match="cg_maxiter"):
        api.PlanConfig(k=K, bs=16, sb=4, cg_maxiter=0)
    with pytest.raises(ValueError, match="preconditioner"):
        api.PlanConfig(k=K, bs=16, sb=4, precond="no_such_precond")
    cfg = api.PlanConfig(k=K, bs=16, sb=4, cg_tol=1e-4, cg_maxiter=32,
                         precond="jacobi")
    assert cfg.cg_tol == 1e-4 and cfg.precond == "jacobi"


# ---------------------------------------------------------------------------
# plan.solve: single, streamed, batch, sharded
# ---------------------------------------------------------------------------


def test_plan_solve_matches_dense(plan):
    rng = np.random.default_rng(6)
    b = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
    res = plan.solve(b, shift=SHIFT, tol=1e-6, maxiter=400)
    assert bool(res.converged)
    ref = dense_solve_original(plan, b)
    np.testing.assert_allclose(np.asarray(res.x), ref, rtol=1e-3, atol=1e-5)


def test_plan_solve_multirhs(plan):
    rng = np.random.default_rng(7)
    b = jnp.asarray(rng.standard_normal((plan.n, 3)), jnp.float32)
    res = plan.solve(b, shift=SHIFT, tol=1e-6, maxiter=400)
    assert res.x.shape == (plan.n, 3) and res.iters.shape == (3,)
    for t in range(3):
        ref = dense_solve_original(plan, np.asarray(b[:, t]))
        np.testing.assert_allclose(np.asarray(res.x[:, t]), ref,
                                   rtol=1e-3, atol=1e-5)


def test_streamed_plan_solve_mid_lifecycle():
    """Solve after delete+insert tiers: converges to the dense reference
    of the CURRENT pattern; dead slots return exactly zero."""
    rng = np.random.default_rng(8)
    x0 = feature_mixture(300, D, n_clusters=8, seed=9)
    p = api.build_plan(x0, k=K, bs=16, sb=4, backend="bsr", capacity=384,
                      symmetrize=True, values=RBFValues())
    p = p.update(insert=feature_mixture(30, D, n_clusters=8, seed=10))
    p = p.update(delete=rng.choice(300, 40, replace=False))
    assert p.host.alive is not None
    alive = np.asarray(p.host.alive)
    b = np.where(alive, rng.standard_normal(p.n), 0.0).astype(np.float32)
    res = p.solve(jnp.asarray(b), shift=SHIFT, tol=1e-6, maxiter=400)
    assert bool(res.converged)
    ref = dense_solve_original(p, b)
    np.testing.assert_allclose(np.asarray(res.x), ref, rtol=1e-3, atol=1e-5)
    assert np.all(np.asarray(res.x)[~alive] == 0.0)


def test_batch_solve_matches_members():
    rng = np.random.default_rng(11)
    xs = [feature_mixture(N, D, n_clusters=8, seed=s) for s in range(4)]
    batch = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr",
                                 symmetrize=True, values=RBFValues())
    b = jnp.asarray(rng.standard_normal((4, batch.capacity)), jnp.float32)
    res = batch.solve(b, shift=SHIFT, tol=1e-6, maxiter=400)
    assert bool(np.asarray(res.converged).all())
    assert res.iters.shape == (4,)
    for i, m in enumerate(batch.members()):
        ref = dense_solve_original(m, np.asarray(b[i]))
        np.testing.assert_allclose(np.asarray(res.x[i]), ref,
                                   rtol=1e-3, atol=1e-5)


def test_batch_solve_single_trace():
    """B member systems under ONE compiled solver kernel: the backend
    traces exactly once however many members ride the batch."""
    xs = [feature_mixture(N, D, n_clusters=8, seed=s) for s in range(3)]
    batch = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr",
                                 symmetrize=True, values=RBFValues())
    b = jnp.ones((3, batch.capacity), jnp.float32)
    calls = []

    @api.register_backend("test_solver_counter")
    def _counting(p, v, **kw):
        calls.append(1)
        return api.get_backend("bsr")(p, v)

    try:
        jax.block_until_ready(batch.solve(
            b, shift=SHIFT, backend="test_solver_counter", maxiter=64).x)
        jax.block_until_ready(batch.solve(
            b, shift=SHIFT, backend="test_solver_counter", maxiter=64).x)
    finally:
        registry._BACKENDS.pop("test_solver_counter", None)
    assert len(calls) == 1


def test_sharded_solve_matches_single(plan):
    """CG over the halo-exchange matvec (psum'd dots under the mesh) on
    whatever mesh the process has — 8 devices in the CI multidevice job."""
    rng = np.random.default_rng(12)
    b = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
    sp = plan.shard()
    res = sp.solve(b, shift=SHIFT, tol=1e-6, maxiter=400)
    assert bool(res.converged)
    ref = np.asarray(plan.solve(b, shift=SHIFT, tol=1e-6, maxiter=400).x)
    np.testing.assert_allclose(np.asarray(res.x), ref, rtol=1e-3,
                               atol=1e-5)


def test_block_jacobi_beats_identity_iterations(plan):
    rng = np.random.default_rng(13)
    b = jnp.asarray(rng.standard_normal(plan.n), jnp.float32)
    it_bj = int(plan.solve(b, shift=SHIFT, precond="block_jacobi",
                           maxiter=400).iters)
    it_id = int(plan.solve(b, shift=SHIFT, precond="identity",
                           maxiter=400).iters)
    assert it_bj < it_id


# ---------------------------------------------------------------------------
# lanczos / eigs
# ---------------------------------------------------------------------------


def test_lanczos_eigsh_matches_dense():
    rng = np.random.default_rng(14)
    q = rng.standard_normal((64, 64)).astype(np.float32)
    a = (q + q.T) / 2
    w, u = lanczos_eigsh(lambda v: jnp.asarray(a) @ v, 64, 4, seed=0)
    ref = np.linalg.eigvalsh(a)[::-1][:4]
    np.testing.assert_allclose(np.asarray(w), ref, rtol=1e-4, atol=1e-4)
    # Ritz vectors are orthonormal and satisfy the eigen equation
    g = np.asarray(u).T @ np.asarray(u)
    np.testing.assert_allclose(g, np.eye(4), atol=1e-3)
    resid = a @ np.asarray(u) - np.asarray(u) * np.asarray(w)
    assert np.abs(resid).max() < 1e-2


def test_plan_eigs_matches_dense(plan):
    w, u = plan.eigs(k=3, seed=0)
    dense = np.asarray(plan.bsr.to_dense())
    ref = np.linalg.eigvalsh(dense)[::-1][:3]
    np.testing.assert_allclose(np.asarray(w), ref, rtol=1e-3, atol=1e-3)
    # eigenvectors come back in ORIGINAL order: check the eigen equation
    # through the original-order matvec
    av = np.asarray(plan.matvec(u))
    np.testing.assert_allclose(av, np.asarray(u) * np.asarray(w), atol=5e-3)


# ---------------------------------------------------------------------------
# spectral embedding on the KDE-weighted similarity graph
# ---------------------------------------------------------------------------


def test_redress_rbf_pins_bandwidth(plan):
    p2 = redress_rbf(plan, bandwidth=0.9)
    vals = np.asarray(p2.coo[2])
    assert (vals > 0).all() and (vals <= 1.0).all()
    # symmetric operator: <y, Ax> == <x, Ay>
    rng = np.random.default_rng(15)
    a = jnp.asarray(rng.standard_normal(p2.n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(p2.n), jnp.float32)
    lhs = float(jnp.vdot(b, p2.matvec(a)))
    rhs = float(jnp.vdot(a, p2.matvec(b)))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4)


def test_normalized_operator_spectrum_bounded(plan):
    n_op, deg = normalized_operator(plan)
    assert deg.shape == (plan.n,) and bool(jnp.all(deg >= 0))
    w, _ = lanczos_eigsh(n_op, plan.n, 2, seed=1)
    # D^-1/2 W D^-1/2 of a nonnegative graph has spectrum in [-1, 1]
    assert float(np.asarray(w).max()) <= 1.0 + 1e-4


def test_spectral_embedding_separates_two_clusters():
    """Two weakly-bridged components: the 2-D embedding must recover the
    plant by nearest centroid. (Bridged, not disconnected — a fully
    disconnected graph has eigenvalue 1 with multiplicity 2, and a
    single-vector Krylov method cannot split a degenerate eigenspace.)"""
    rng = np.random.default_rng(17)
    c = rng.standard_normal((2, 4)).astype(np.float32)
    labels = np.arange(256) % 2
    x = (c[labels] + 0.45 * rng.standard_normal((256, 4))).astype(np.float32)
    w, y = spectral_embedding(x, n_components=2, k=8, bs=16, sb=4,
                              backend="bsr", drop_first=False, seed=2)
    assert y.shape == (256, 2)
    y = np.asarray(y)
    y = y / np.maximum(np.linalg.norm(y, axis=1, keepdims=True), 1e-12)
    cents = np.stack([y[labels == i].mean(0) for i in range(2)])
    pred = (((y[:, None, :] - cents[None]) ** 2).sum(-1)).argmin(1)
    acc = max((pred == labels).mean(), (pred == (1 - labels)).mean())
    assert acc > 0.95


# ---------------------------------------------------------------------------
# kernel ridge regression
# ---------------------------------------------------------------------------


def test_krr_fit_matches_dense(plan, x):
    rng = np.random.default_rng(18)
    w_true = rng.standard_normal(D).astype(np.float32)
    y = np.tanh(x @ w_true).astype(np.float32)
    model = krr_fit(plan, jnp.asarray(y), lam=0.5, tol=1e-6, maxiter=400)
    assert bool(model.result.converged)
    shift = float(np.asarray(model.self_weight)) + 0.5
    ref = dense_solve_original(plan, y, shift=shift)
    np.testing.assert_allclose(np.asarray(model.alpha), ref, rtol=1e-3,
                               atol=1e-5)
    # in-sample prediction is K alpha = (W + sw I) alpha
    yhat = np.asarray(model.predict())
    ref_hat = (np.asarray(plan.matvec(model.alpha))
               + float(np.asarray(model.self_weight))
               * np.asarray(model.alpha))
    np.testing.assert_allclose(yhat, ref_hat, rtol=1e-5)


def test_krr_predict_out_of_sample(plan, x):
    rng = np.random.default_rng(19)
    y = np.tanh(x @ rng.standard_normal(D).astype(np.float32))
    model = krr_fit(plan, jnp.asarray(y.astype(np.float32)), lam=0.5)
    x_new = feature_mixture(32, D, n_clusters=8, seed=20)
    out = np.asarray(model.predict(x_new))
    assert out.shape == (32,) and np.isfinite(out).all()
    # prediction AT a training point through the cross-kernel stays close
    # to that point's in-sample neighbor contribution (same truncation)
    out_tr = np.asarray(model.predict(x[:8]))
    assert np.isfinite(out_tr).all()


def test_krr_fit_batch_lockstep_multitarget():
    rng = np.random.default_rng(21)
    xs = [feature_mixture(N, D, n_clusters=8, seed=30 + s) for s in range(3)]
    batch = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr",
                                 symmetrize=True, values=RBFValues())
    ys = jnp.asarray(rng.standard_normal((3, batch.capacity, 2)),
                     jnp.float32)
    model = krr_fit_batch(batch, ys, lam=0.5, tol=1e-6, maxiter=400)
    assert model.alpha.shape == (3, batch.capacity, 2)
    assert bool(np.asarray(model.result.converged).all())
    sw = np.asarray(model.self_weight)
    assert sw.shape == (3,)          # per-lane Gershgorin shift
    for i, m in enumerate(batch.members()):
        for t in range(2):
            ref = dense_solve_original(m, np.asarray(ys[i, :, t]),
                                       shift=float(sw[i]) + 0.5)
            np.testing.assert_allclose(np.asarray(model.alpha[i, :, t]),
                                       ref, rtol=1e-3, atol=1e-5)


def test_krr_rejects_nonpositive_lam(plan):
    with pytest.raises(ValueError, match="lam"):
        krr_fit(plan, jnp.ones(plan.n), lam=0.0)


def test_solve_validates_rhs_shape(plan):
    with pytest.raises(ValueError, match="rows"):
        solve(plan, jnp.ones(plan.n + 1), shift=SHIFT)
