"""End-to-end behaviour tests for the paper's system: the t-SNE and
mean-shift case studies (paper §3) run through the full pipeline —
kNN -> dual-tree reorder -> ELL-BSR -> blockwise iterative interactions —
and must produce the algorithmic outcomes (cluster separation, mode
convergence), not just matching numerics."""
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import blocksparse, interact, knn, measures, ordering

ROOT = Path(__file__).resolve().parents[1]


def test_tsne_example_end_to_end():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "tsne.py"),
         "--n", "512", "--iters", "220", "--k", "16"],
        capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "clusters separated OK" in r.stdout


def test_meanshift_example_end_to_end():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "meanshift.py")],
        capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "converged to modes OK" in r.stdout


def test_train_lm_example_with_restart():
    r = subprocess.run(
        [sys.executable, str(ROOT / "examples" / "train_lm.py"),
         "--steps", "30", "--batch", "4", "--seq", "64"],
        capture_output=True, text=True, timeout=1200)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "trained through a simulated failure" in r.stdout


def test_iterative_interaction_profile_stability():
    """Paper §3.1: in t-SNE the sparsity PROFILE is fixed across iterations,
    only values change — the BSR pattern is built once and reused. Verify
    the blockwise path equals a fresh dense computation after many value
    updates (i.e. no pattern staleness)."""
    rng = np.random.default_rng(0)
    n, k = 256, 8
    x = rng.standard_normal((n, 32)).astype(np.float32)
    rows, cols, _ = knn.knn_coo(jnp.asarray(x), jnp.asarray(x), k,
                                exclude_self=True)
    rows, cols = np.asarray(rows), np.asarray(cols)
    pi = ordering.dual_tree(x, d=2)
    r2, c2 = ordering.apply_ordering(rows, cols, pi)
    pv = rng.random(len(r2)).astype(np.float32)
    bsr = blocksparse.build_bsr(r2, c2, pv, n, bs=16)
    y = jnp.asarray(rng.standard_normal((n, 2)), jnp.float32)
    for _ in range(5):
        f = interact.tsne_attractive(bsr.vals, bsr.col_idx, bsr.nbr_mask, y, n)
        y = y - 0.1 * f
    # dense reference with the SAME P
    dense_p = np.zeros((n, n), np.float32)
    dense_p[r2, c2] = pv
    yn = np.asarray(y)
    diff = yn[:, None] - yn[None]
    q = 1.0 / (1.0 + (diff ** 2).sum(-1))
    want = np.einsum("ij,ijd->id", dense_p * q, diff)
    got = np.asarray(interact.tsne_attractive(bsr.vals, bsr.col_idx,
                                              bsr.nbr_mask, y, n))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
