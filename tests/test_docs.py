"""Docs hygiene: every relative link in docs/*.md and README.md points
at a real file, every ``#anchor`` matches a heading in its target, and
the docs tree is reachable from the README."""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
DOC_FILES = sorted((ROOT / "docs").glob("*.md")) + [ROOT / "README.md"]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _strip_fences(text: str) -> str:
    return _FENCE.sub("", text)


def _slugify(heading: str) -> str:
    """GitHub's heading -> anchor rule: lowercase, drop punctuation,
    spaces to dashes."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(path: Path) -> set:
    text = _strip_fences(path.read_text())
    return {_slugify(m.group(1))
            for m in re.finditer(r"^#{1,6}\s+(.+)$", text, re.MULTILINE)}


def _links(path: Path):
    text = _strip_fences(path.read_text())
    for m in _LINK.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        yield target


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_relative_links_resolve(doc):
    missing = []
    for target in _links(doc):
        file_part, _, anchor = target.partition("#")
        dest = (doc.parent / file_part).resolve() if file_part else doc
        if not dest.exists():
            missing.append(f"{target} -> {dest} (missing file)")
            continue
        if anchor and dest.suffix == ".md" and anchor not in _anchors(dest):
            missing.append(f"{target} (no heading for #{anchor} in "
                           f"{dest.name}; have {sorted(_anchors(dest))})")
    assert not missing, f"{doc.name}: broken links:\n  " + \
        "\n  ".join(missing)


def test_docs_guides_linked_from_readme():
    readme = (ROOT / "README.md").read_text()
    for guide in sorted((ROOT / "docs").glob("*.md")):
        assert f"docs/{guide.name}" in readme, (
            f"{guide.name} exists but README.md never links it")


def test_readme_examples_and_tests_exist():
    # backtick-quoted repo paths the README promises (examples/, docs/)
    readme = (ROOT / "README.md").read_text()
    for m in re.finditer(r"`((?:examples|docs|benchmarks)/[\w./]+)`",
                         readme):
        assert (ROOT / m.group(1)).exists(), (
            f"README references {m.group(1)} which does not exist")
