"""Lint: version-sensitive JAX APIs are only touched via repro.compat.

Every seed failure of this repo traced to JAX API moves (shard_map
location/kwargs, AbstractMesh ctor, lax.axis_size). PR 1 routed them all
through ``src/repro/compat.py``; this test keeps it that way — new code
must import the wrappers, not the moving targets.
"""
import re
from pathlib import Path

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"

# import/usage forms that break across JAX releases (fine only in compat.py)
FORBIDDEN = (
    r"jax\.experimental\.shard_map",
    r"from\s+jax\s+import\s+[^\n]*\bshard_map\b",
    r"jax\.shard_map",
    r"\bAbstractMesh\b",
    r"\blax\.axis_size\b",
    r"\bcheck_rep\b",
)


def test_version_sensitive_jax_imports_only_in_compat():
    offenders = []
    for path in sorted(SRC.rglob("*.py")):
        if path.name == "compat.py":
            continue
        text = path.read_text()
        for pat in FORBIDDEN:
            for m in re.finditer(pat, text):
                line = text[:m.start()].count("\n") + 1
                offenders.append(f"{path.relative_to(SRC.parent)}:{line} "
                                 f"matches {pat!r}")
    assert not offenders, (
        "version-sensitive JAX usage outside repro/compat.py — import the "
        "compat wrapper instead:\n" + "\n".join(offenders))
