"""Hypothesis import shim: property tests degrade to a few deterministic
examples when hypothesis is not installed, instead of erroring at collection.

Usage in test modules::

    from _hyp import given, settings, st

With hypothesis installed these are the real objects; without it, ``given``
zips up to three deterministic samples per keyword strategy and runs the
test body once per sample tuple (kwargs-style ``@given`` only).
"""
from __future__ import annotations

import functools
import inspect

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, samples):
            self.samples = list(samples)

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def integers(lo, hi):
            return _Strategy([lo, (lo + hi) // 2, hi])

        @staticmethod
        def floats(lo, hi):
            return _Strategy([lo, (lo + hi) / 2.0, hi])

        @staticmethod
        def booleans():
            return _Strategy([False, True])

        @staticmethod
        def sampled_from(xs):
            return _Strategy(xs)

    def settings(*_a, **_kw):
        return lambda f: f

    def given(**strats):
        names = list(strats)
        pools = [strats[n].samples for n in names]
        n_cases = min(3, max(len(p) for p in pools))
        cases = [{n: pools[j][i % len(pools[j])] for j, n in enumerate(names)}
                 for i in range(n_cases)]

        def deco(f):
            @functools.wraps(f)
            def wrapper(*args, **kwargs):
                for case in cases:
                    f(*args, **case, **kwargs)

            # hide the sampled params from pytest's fixture resolution
            del wrapper.__wrapped__
            sig = inspect.signature(f)
            keep = [p for p in sig.parameters.values() if p.name not in strats]
            wrapper.__signature__ = sig.replace(parameters=keep)
            return wrapper

        return deco
