"""Continuous-batching engine: slot reuse, backfill, per-request outputs."""
import jax
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.models import model_api
from repro.train.serve_loop import Engine, Request


@pytest.fixture(scope="module")
def engine():
    cfg = reduced_config("qwen2-0.5b")
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    return Engine(cfg, params, slots=2, max_seq=160, prefill_bucket=32)


def test_engine_serves_more_requests_than_slots(engine):
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    tokens=rng.integers(0, engine.cfg.vocab,
                                        rng.integers(5, 40)).astype(np.int32),
                    max_new=8)
            for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    for r in reqs:
        assert len(r.output) == 8, (r.rid, len(r.output))
        assert r.t_done >= r.t_first >= r.t_submit
    # 5 requests through 2 slots: ticks must exceed one batch's worth
    assert engine.ticks >= 8


def test_engine_greedy_matches_unbatched(engine):
    """A single request through the engine == plain prefill+decode greedy."""
    from repro.models.sharding import NO_SHARD
    cfg = engine.cfg
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab, 32).astype(np.int32)  # = bucket size
    req = Request(rid=99, tokens=prompt, max_new=6)
    engine.submit(req)
    engine.run()

    import jax.numpy as jnp
    mod = model_api.module_for(cfg)
    cache, logits = mod.prefill(engine.params, cfg,
                                {"tokens": jnp.asarray(prompt[None])},
                                NO_SHARD, "flash")
    # grow cache for decode room
    grown = {}
    for k, v in cache.items():
        if hasattr(v, "ndim") and v.ndim >= 4:
            pads = [(0, 0)] * v.ndim
            pads[-2] = (0, 32)
            grown[k] = jnp.pad(v, pads)
        else:
            grown[k] = v
    toks = [int(jnp.argmax(logits[0]))]
    cache = grown
    for _ in range(5):
        lg, cache = mod.decode_step(engine.params, cfg, cache,
                                    jnp.asarray([[toks[-1]]], jnp.int32),
                                    NO_SHARD, "flash")
        toks.append(int(jnp.argmax(lg[0])))
    assert req.output == toks, (req.output, toks)


def test_engine_mixed_lengths_match_unbatched(engine):
    """Two simultaneous requests with DIFFERENT prompt lengths must each
    match their own unbatched greedy decode (per-slot position masking)."""
    from repro.models.sharding import NO_SHARD
    import jax.numpy as jnp
    cfg = engine.cfg
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 32).astype(np.int32),
               rng.integers(0, cfg.vocab, 64).astype(np.int32)]
    reqs = [Request(rid=i, tokens=p, max_new=5)
            for i, p in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    engine.run()

    mod = model_api.module_for(cfg)
    for r, p in zip(reqs, prompts):
        cache, logits = mod.prefill(engine.params, cfg,
                                    {"tokens": jnp.asarray(p[None])},
                                    NO_SHARD, "flash")
        grown = {}
        for k, v in cache.items():
            if hasattr(v, "ndim") and v.ndim >= 4:
                pads = [(0, 0)] * v.ndim
                pads[-2] = (0, 32)
                grown[k] = jnp.pad(v, pads)
            else:
                grown[k] = v
        toks = [int(jnp.argmax(logits[0]))]
        cache = grown
        for _ in range(4):
            lg, cache = mod.decode_step(engine.params, cfg, cache,
                                        jnp.asarray([[toks[-1]]], jnp.int32),
                                        NO_SHARD, "flash")
            toks.append(int(jnp.argmax(lg[0])))
        assert r.output == toks, (r.rid, r.output, toks)
