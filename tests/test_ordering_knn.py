"""Orderings (paper §4.3) + blocked exact kNN."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import knn, measures, ordering
from repro.data.pipeline import feature_mixture


@settings(max_examples=10, deadline=None)
@given(n=st.integers(20, 300), d=st.integers(2, 16), k=st.integers(1, 8),
       seed=st.integers(0, 1000))
def test_knn_matches_bruteforce(n, d, k, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((n, d)).astype(np.float32)
    idx, dist2 = knn.knn_graph(jnp.asarray(x), jnp.asarray(x), k,
                               block=64, exclude_self=True)
    idx = np.asarray(idx)
    for i in range(0, n, max(n // 7, 1)):
        d2 = ((x[i] - x) ** 2).sum(1)
        d2[i] = np.inf
        want = np.sort(d2)[:k]
        got = np.sort(((x[i] - x[idx[i]]) ** 2).sum(1))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_knn_rectangular():
    rng = np.random.default_rng(0)
    t = rng.standard_normal((50, 8)).astype(np.float32)
    s = rng.standard_normal((80, 8)).astype(np.float32)
    idx, _ = knn.knn_graph(jnp.asarray(t), jnp.asarray(s), 5, block=32)
    assert idx.shape == (50, 5)
    assert int(idx.max()) < 80


@pytest.fixture(scope="module")
def clustered_graph():
    x = feature_mixture(1024, 64, n_clusters=16, seed=3)
    rows, cols, _ = knn.knn_coo(jnp.asarray(x), jnp.asarray(x), 10,
                                block=256, exclude_self=True)
    return x, np.asarray(rows), np.asarray(cols)


def test_all_orderings_are_permutations(clustered_graph):
    x, rows, cols = clustered_graph
    for name in ordering.ORDERINGS:
        pi = ordering.compute_ordering(name, x, rows, cols)
        assert sorted(pi.tolist()) == list(range(len(x))), name


def test_dual_tree_beats_scattered_gamma(clustered_graph):
    """The paper's core claim, in miniature: hierarchical ordering gives a
    much denser patch profile than the scattered base case."""
    x, rows, cols = clustered_graph
    n = len(x)
    gammas = {}
    for name in ["scattered", "pca_1d", "dual_tree"]:
        pi = ordering.compute_ordering(name, x, rows, cols)
        r, c = ordering.apply_ordering(rows, cols, pi)
        gammas[name] = float(measures.gamma_score(
            jnp.asarray(r), jnp.asarray(c), 5.0, n))
    assert gammas["dual_tree"] > 2 * gammas["scattered"]
    assert gammas["pca_1d"] > gammas["scattered"]


def test_dual_tree_equals_morton_fast_path(clustered_graph):
    x, rows, cols = clustered_graph
    a = ordering.dual_tree(x, d=3)
    b = ordering.dual_tree_fast(x, d=3)
    # same leaf order up to stable-sort ties
    assert np.array_equal(np.sort(a), np.sort(b))
    ga = measures.gamma_score(*[jnp.asarray(v) for v in
                                ordering.apply_ordering(rows, cols, a)],
                              5.0, len(x))
    gb = measures.gamma_score(*[jnp.asarray(v) for v in
                                ordering.apply_ordering(rows, cols, b)],
                              5.0, len(x))
    assert float(ga) == pytest.approx(float(gb), rel=0.02)
