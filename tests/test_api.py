"""Unified planner API: plan round-trip, backend equivalence, pytree
contract, registry behavior, autotuning (ISSUE 1 tentpole)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import blocksparse, interact
from repro.data.pipeline import feature_mixture

N, D, K = 512, 64, 8


@pytest.fixture(scope="module")
def points():
    return feature_mixture(N, D, n_clusters=8, seed=0)


@pytest.fixture(scope="module")
def plan(points):
    rng = np.random.default_rng(0)
    return api.build_plan(points, k=K, ordering="dual_tree", bs=16, sb=4,
                          backend="bsr",
                          values=lambda r, c, d2: rng.random(len(r)))


def test_plan_owns_every_stage(plan):
    assert plan.embedding is not None and plan.embedding.shape == (N, 3)
    assert plan.tree is not None and plan.tree.n_levels >= 2
    assert sorted(plan.host.pi) == list(range(N))
    assert plan.gamma is not None and plan.gamma > 0
    assert plan.bsr is not None and 0 < plan.fill <= 1
    r, c, v = plan.coo
    assert len(r) == len(c) == len(v) == N * K


def test_permute_round_trip(plan):
    x = np.random.default_rng(1).standard_normal((N, 3)).astype(np.float32)
    np.testing.assert_array_equal(plan.unpermute(plan.permute(x)), x)
    xj = jnp.asarray(x)
    np.testing.assert_array_equal(
        np.asarray(plan.unpermute(plan.permute(xj))), x)


def test_plan_round_trip_matches_unordered_csr(plan):
    """unpermute(apply(permute(x))) == A x on the unordered graph."""
    x = jnp.asarray(np.random.default_rng(2).standard_normal(N), jnp.float32)
    r2, c2, v = plan.coo
    rows0, cols0 = plan.host.pi[r2], plan.host.pi[c2]  # original labels
    want = interact.spmv_csr(jnp.asarray(v), jnp.asarray(rows0),
                             jnp.asarray(cols0), x, N)
    got = plan.unpermute(plan.apply(plan.permute(x)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    got_mv = plan.matvec(x)
    np.testing.assert_allclose(np.asarray(got_mv), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("backend", ["csr", "bsr", "bsr_ml", "pallas"])
def test_backend_equivalence(plan, backend):
    """All registered single-host backends agree on the same plan."""
    x = jnp.asarray(np.random.default_rng(3).standard_normal(N), jnp.float32)
    ref = np.asarray(plan.apply(x, backend="csr"))
    got = np.asarray(plan.apply(x, backend=backend))
    assert np.abs(got - ref).max() <= 1e-4


def test_dist_backend_matches(plan):
    x = jnp.asarray(np.random.default_rng(4).standard_normal(N), jnp.float32)
    ref = np.asarray(plan.apply(x, backend="bsr"))
    got = np.asarray(plan.apply(x, backend="dist"))
    assert np.abs(got - ref).max() <= 1e-4


def test_bsr_pytree_round_trip(plan):
    leaves, treedef = jax.tree_util.tree_flatten(plan.bsr)
    assert len(leaves) == 3                       # col_idx, nbr_mask, vals
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, blocksparse.BSR)
    assert (back.bs, back.sb, back.n, back.max_nbr) == \
        (plan.bsr.bs, plan.bsr.sb, plan.bsr.n, plan.bsr.max_nbr)
    np.testing.assert_array_equal(np.asarray(back.vals),
                                  np.asarray(plan.bsr.vals))


def test_plan_pytree_crosses_jit(plan):
    """A plan flattens to leaves and can be passed through jit as an arg."""
    x = jnp.asarray(np.random.default_rng(5).standard_normal(N), jnp.float32)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    plan2 = jax.tree_util.tree_unflatten(treedef, leaves)
    ref = np.asarray(plan.apply(x, backend="bsr"))
    np.testing.assert_allclose(np.asarray(plan2.apply(x, backend="bsr")),
                               ref, rtol=1e-5, atol=1e-5)

    f = jax.jit(lambda p, xx: p.apply(xx, backend="bsr"))
    np.testing.assert_allclose(np.asarray(f(plan, x)), ref,
                               rtol=1e-5, atol=1e-5)


def test_jit_apply_retraces_only_on_shape_change(plan):
    traces = []

    @jax.jit
    def f(x):
        traces.append(x.shape)
        return plan.apply(x, backend="bsr")

    rng = np.random.default_rng(6)
    f(jnp.asarray(rng.standard_normal(N), jnp.float32))
    f(jnp.asarray(rng.standard_normal(N), jnp.float32))
    assert len(traces) == 1                       # same shape: cached
    f(jnp.asarray(rng.standard_normal((N, 2)), jnp.float32))
    assert len(traces) == 2                       # new shape: one retrace


def test_registry_unknown_and_custom_backend(plan):
    with pytest.raises(ValueError, match="unknown SpMV backend"):
        plan.apply(jnp.zeros(N), backend="no_such_backend")

    @api.register_backend("test_double_bsr")
    def _double(p, x, **kw):
        return 2.0 * api.get_backend("bsr")(p, x)

    try:
        assert "test_double_bsr" in api.backend_names()
        x = jnp.asarray(np.random.default_rng(7).standard_normal(N),
                        jnp.float32)
        np.testing.assert_allclose(
            np.asarray(plan.apply(x, backend="test_double_bsr")),
            2.0 * np.asarray(plan.apply(x, backend="bsr")),
            rtol=1e-6)
    finally:
        from repro.core import registry
        registry._BACKENDS.pop("test_double_bsr", None)


def test_auto_backend_resolves_and_caches(points):
    plan = api.build_plan(points, k=K, bs=16, sb=4, backend="auto")
    name = plan.resolve_backend()
    assert name in api.backend_names()
    assert plan.host.tuned_backend[1] == name     # cached per charge ndim
    x = jnp.asarray(np.random.default_rng(8).standard_normal(N), jnp.float32)
    ref = np.asarray(plan.apply(x, backend="csr"))
    np.testing.assert_allclose(np.asarray(plan.apply(x)), ref,
                               rtol=1e-4, atol=1e-4)
    # multi-feature charges tune separately: dist (1-D only) can never be
    # pinned for (n, f), and the result still matches csr
    xf = jnp.asarray(np.random.default_rng(9).standard_normal((N, 3)),
                     jnp.float32)
    reff = np.asarray(plan.apply(xf, backend="csr"))
    np.testing.assert_allclose(np.asarray(plan.apply(xf)), reff,
                               rtol=1e-4, atol=1e-4)
    assert plan.resolve_backend(x=xf) != "dist"


def test_dist_backend_rejects_2d():
    plan = api.InteractionPlan.from_bsr(blocksparse.random_bsr(0, 256, 16, 4))
    with pytest.raises(ValueError, match="1-D charges"):
        plan.apply(jnp.ones((256, 2)), backend="dist")


def test_cluster_order_matches_plan_ordering(points):
    pi = api.cluster_order(points, ordering="dual_tree")
    plan = api.build_plan(points, k=K, with_bsr=False)
    np.testing.assert_array_equal(pi, plan.host.pi)
    with pytest.raises(ValueError, match="rcm"):
        api.cluster_order(points, ordering="rcm")


def test_profile_only_plan(points):
    profile = api.build_plan(points, k=K, ordering="scattered",
                             with_bsr=False)
    assert profile.bsr is None and profile.gamma is not None
    with pytest.raises(ValueError, match="profile-only"):
        profile.tsne_attractive(jnp.zeros((N, 2)))
    with pytest.raises(ValueError, match="profile-only"):
        profile.apply(jnp.zeros(N), backend="bsr")
    # csr still runs off the COO pattern
    assert profile.apply(jnp.ones(N), backend="csr").shape == (N,)


def test_with_values_same_pattern(plan):
    r2, c2, _ = plan.coo
    new_vals = np.random.default_rng(9).random(len(r2)).astype(np.float32)
    plan2 = plan.with_values(new_vals)
    assert plan2.bsr.vals.shape == plan.bsr.vals.shape   # pinned max_nbr
    x = jnp.asarray(np.random.default_rng(10).standard_normal(N),
                    jnp.float32)
    want = interact.spmv_csr(jnp.asarray(new_vals), jnp.asarray(r2),
                             jnp.asarray(c2), x, N)
    np.testing.assert_allclose(np.asarray(plan2.apply(x, backend="bsr")),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


def test_from_coo_identity_and_hooks():
    """Identity-ordered plan: mean-shift hook equals the dense reference."""
    rng = np.random.default_rng(11)
    n, k, d = 96, 6, 3
    src = rng.standard_normal((n, d)).astype(np.float32)
    t = src + 0.1 * rng.standard_normal((n, d)).astype(np.float32)
    rows = np.repeat(np.arange(n), k)
    cols = rng.integers(0, n, n * k)
    key = rows.astype(np.int64) * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    plan = api.InteractionPlan.from_coo(rows, cols, None, n, bs=16)
    np.testing.assert_array_equal(plan.host.pi, np.arange(n))

    got = np.asarray(plan.meanshift_step(jnp.asarray(t), jnp.asarray(src),
                                         0.5))
    pattern = np.zeros((n, n), np.float32)
    pattern[rows, cols] = 1.0
    w = np.exp(-((t[:, None, :] - src[None]) ** 2).sum(-1) / 0.5) * pattern
    want = (w @ src) / np.maximum(w.sum(1, keepdims=True), 1e-12)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_from_coo_honors_symmetrize():
    rows = np.array([0, 1])
    cols = np.array([1, 2])
    plan = api.InteractionPlan.from_coo(rows, cols, None, 4,
                                        symmetrize=True, bs=2, sb=2)
    r, c, v = plan.coo
    assert len(r) == 4                            # union with the transpose
    dense = plan.bsr.to_dense()
    np.testing.assert_allclose(dense, dense.T)
    assert plan.config.symmetrize is True


def test_tsne_hook_matches_edges(plan):
    r2, c2, v = plan.coo
    y = np.random.default_rng(12).standard_normal((N, 2)).astype(np.float32)
    got = np.asarray(plan.tsne_attractive(jnp.asarray(y)))
    want = np.zeros((N, 2), np.float32)
    for r, c, pv in zip(r2, c2, v):
        diff = y[r] - y[c]
        q = 1.0 / (1.0 + (diff ** 2).sum())
        want[r] += pv * q * diff
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_spmv_shim_still_works_and_warns():
    bsr = blocksparse.random_bsr(0, 256, 16, 4, sb=4)
    x = jnp.asarray(np.random.default_rng(13).standard_normal(256),
                    jnp.float32)
    with pytest.warns(DeprecationWarning):
        y = interact.spmv(bsr, x, "bsr")
    plan = api.InteractionPlan.from_bsr(bsr)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(plan.apply(x, backend="bsr")),
                               rtol=1e-6)


def test_random_bsr_threads_sb():
    bsr = blocksparse.random_bsr(0, 256, 16, 4, sb=2)
    assert bsr.sb == 2
    assert bool(np.asarray(bsr.nbr_mask).all())


def test_plan_config_is_hashable():
    a = api.PlanConfig(k=8)
    b = dataclasses.replace(a)
    assert hash(a) == hash(b) and a == b
