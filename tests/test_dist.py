"""Multi-device tests — each runs in a SUBPROCESS with a host-platform
device-count override so the main pytest process keeps 1 device."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_sub(body: str, devices: int = 8, timeout: int = 900):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {SRC!r})
        import jax
        assert jax.device_count() == {devices}
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=timeout)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_spmv_sharded_matches_dense():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.blocksparse import random_bsr
        from repro.core.dist import spmv_sharded
        from repro.core import interact
        mesh = jax.make_mesh((8,), ("data",))
        bsr = random_bsr(0, 512, 32, 4)      # n_rb=16 divisible by 8
        x = jnp.asarray(np.random.default_rng(0).standard_normal(512), jnp.float32)
        y = spmv_sharded(bsr, x, mesh)
        y_ref = interact.spmv(bsr, x, "bsr")
        assert float(jnp.abs(y - y_ref).max()) < 1e-4, "sharded spmv mismatch"
        print("spmv_sharded OK")
    """)


def test_spmv_sharded_pads_nondivisible():
    """n_rb not divisible by the mesh axis: padded, not rejected."""
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.blocksparse import random_bsr
        from repro.core.dist import spmv_sharded
        from repro.api import InteractionPlan
        mesh = jax.make_mesh((8,), ("data",))
        bsr = random_bsr(0, 320, 32, 4)      # n_rb=10, pads to 16
        x = jnp.asarray(np.random.default_rng(0).standard_normal(320), jnp.float32)
        y = spmv_sharded(bsr, x, mesh)
        plan = InteractionPlan.from_bsr(bsr)
        y_ref = plan.apply(x, backend="bsr")
        assert y.shape == (320,)
        assert float(jnp.abs(y - y_ref).max()) < 1e-4, "padded spmv mismatch"
        # the dist registry backend takes any plan on the full device mesh
        y2 = plan.apply(x, backend="dist")
        assert float(jnp.abs(y2 - y_ref).max()) < 1e-4, "dist backend mismatch"
        print("nondivisible padding OK")
    """)


def test_clusterkv_decode_sharded_matches_local():
    run_sub("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ClusterKVConfig
        from repro.models import attention as attn
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rng = np.random.default_rng(0)
        B,Hq,Hkv,S,dh = 1,4,2,256,16
        q = jnp.asarray(rng.standard_normal((B,Hq,dh)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B,Hkv,S,dh)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B,Hkv,S,dh)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B,Hkv,S))
        # full local selection == dense; sharded with full local coverage
        cfg = ClusterKVConfig(enabled=True, block_k=32, decode_clusters=64)
        o_sh = attn.clusterkv_decode_sharded(q, k, v, pos, S-1, cfg, mesh)
        o_ref = attn.decode_attention(q, k, v, pos[0,0], S-1)
        err = float(jnp.abs(o_sh - o_ref).max())
        assert err < 1e-3, f"sharded decode err {err}"
        print("clusterkv_decode_sharded OK")
    """)


def test_small_mesh_train_lower_and_run():
    """Lower AND execute a sharded train step on a 2x2 CPU mesh."""
    run_sub("""
        import jax, jax.numpy as jnp
        from repro.configs import reduced_config
        from repro.models import model_api
        from repro.models.sharding import shardings_for
        from repro.optim.optimizers import make_optimizer
        from repro.train import trainer
        from repro.data import pipeline
        from jax.sharding import PartitionSpec as P

        cfg = reduced_config("granite-moe-3b-a800m")
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        opt = make_optimizer("adamw")
        step, _ = trainer.make_train_step(cfg, mesh, "flash", optimizer=opt)
        params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        pspec = shardings_for(params, model_api.param_specs(cfg), mesh)
        ospec = shardings_for(opt_state,
                              opt.state_specs(model_api.param_specs(cfg)), mesh)
        params = jax.device_put(params, pspec)
        opt_state = jax.device_put(opt_state, ospec)
        batch = {k: jnp.asarray(v) for k, v in
                 pipeline.token_batch(cfg, 0, 4, 32).items()}
        bspec = shardings_for(batch, {"tokens": P("dp", None),
                                      "labels": P("dp", None)}, mesh)
        batch = jax.device_put(batch, bspec)
        fn = jax.jit(step, in_shardings=(pspec, ospec, bspec),
                     donate_argnums=(0, 1))
        p2, o2, m = fn(params, opt_state, batch)
        loss = float(m["loss"])
        assert loss == loss and loss > 0, "bad loss"
        print("2x2 mesh train step OK, loss", loss)
    """, devices=4)


def test_elastic_checkpoint_reshard():
    """Save on a 4-way mesh, restore onto a 2-way mesh (elastic resume)."""
    run_sub("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.ckpt import Checkpointer
        mesh4 = jax.make_mesh((4,), ("data",))
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        t = {"w": jnp.arange(64.0).reshape(8, 8)}
        t4 = jax.device_put(t, {"w": NamedSharding(mesh4, P("data"))})
        ck = Checkpointer(tempfile.mkdtemp())
        ck.save(0, t4, blocking=True)
        restored, _ = ck.restore(
            t, shardings={"w": NamedSharding(mesh2, P("data", "model"))})
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(t["w"]))
        assert restored["w"].sharding.mesh.shape == {"data": 2, "model": 2}
        print("elastic reshard OK")
    """, devices=4)


def test_moe_ep_all_to_all_matches_tp():
    """Expert-parallel (all_to_all) routing == expert-TP routing."""
    run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import reduced_config
        from repro.models import moe as moe_mod
        from repro.models.sharding import ShardCtx
        import dataclasses
        cfg = reduced_config("llama4-maverick-400b-a17b")
        # generous capacity so neither path drops tokens (drop sets differ
        # between shard-local and global capacity accounting)
        cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        key = jax.random.PRNGKey(0)
        p, _ = moe_mod.init_moe(key, cfg)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
        y_tp, _ = moe_mod.moe_ffn(p, x, cfg, ShardCtx(mesh))
        cfg_ep = cfg.with_(moe=dataclasses.replace(cfg.moe,
                                                   expert_parallel=True))
        y_ep, _ = moe_mod.moe_ffn(p, x, cfg_ep, ShardCtx(mesh))
        err = float(jnp.abs(y_tp - y_ep).max())
        rel = err / float(jnp.abs(y_tp).max())
        assert rel < 2e-2, f"EP vs TP mismatch rel={rel}"
        print("MoE EP==TP OK rel", rel)
    """, devices=4)
