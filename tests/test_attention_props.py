"""Attention backend invariants (property tests)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import attention as attn


def mk(seed, B=1, Hq=4, Hkv=2, S=64, dh=8, dv=None):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Hq, S, dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, Hkv, S, dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, Hkv, S, dv or dh), jnp.float32)
    return q, k, v


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), s=st.sampled_from([16, 48, 64, 100]),
       block=st.sampled_from([8, 16, 512]), causal=st.booleans())
def test_flash_matches_dense(seed, s, block, causal):
    q, k, v = mk(seed, S=s)
    pos = jnp.arange(s, dtype=jnp.int32)
    a = attn.dense_attention(q, k, v, pos, pos, causal=causal)
    b = attn.flash_attention(q, k, v, pos, pos, causal=causal, block=block)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000), window=st.sampled_from([4, 16, 32]))
def test_swa_window_masks_far_past(seed, window):
    """Poisoning values beyond the window never changes the output."""
    s = 64
    q, k, v = mk(seed, S=s)
    pos = jnp.arange(s, dtype=jnp.int32)
    out1 = attn.flash_attention(q, k, v, pos, pos, causal=True,
                                window=window)
    v2 = v.at[:, :, :s - window - 1].add(1e3)
    k2 = k.at[:, :, :s - window - 1].add(1e3)
    out2 = attn.flash_attention(q, k2, v2, pos, pos, causal=True,
                                window=window)
    # the last row attends only within the window -> unchanged
    np.testing.assert_allclose(np.asarray(out1[:, :, -1]),
                               np.asarray(out2[:, :, -1]), rtol=1e-3,
                               atol=1e-3)


def test_decode_matches_dense_last_row():
    s = 64
    q, k, v = mk(0, S=s)
    pos = jnp.arange(s, dtype=jnp.int32)
    full = attn.dense_attention(q, k, v, pos, pos, causal=True)
    dec = attn.decode_attention(q[:, :, -1], k, v, pos, s - 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, :, -1]),
                               rtol=2e-4, atol=2e-4)


def test_decode_ignores_unfilled_slots():
    """Slots with pos > qpos (ring-buffer holes) carry zero weight."""
    s = 64
    q, k, v = mk(1, S=s)
    pos = jnp.arange(s, dtype=jnp.int32)
    qpos = 40
    o1 = attn.decode_attention(q[:, :, -1], k, v, pos, qpos)
    v2 = v.at[:, :, qpos + 1:].set(1e4)
    o2 = attn.decode_attention(q[:, :, -1], k, v2, pos, qpos)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 1, 8, 16))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 8, 16))
    def scores(offset):
        pos = jnp.arange(8, dtype=jnp.int32) + offset
        qr = attn.rope(q, pos[None, None, :])
        kr = attn.rope(k, pos[None, None, :])
        return jnp.einsum("bhsd,bhtd->bhst", qr, kr)
    np.testing.assert_allclose(np.asarray(scores(0)),
                               np.asarray(scores(100)), rtol=2e-3, atol=2e-3)


def test_gqa_grouping_matches_repeated_kv():
    """GQA with Hkv<Hq == MHA with kv heads explicitly repeated."""
    q, k, v = mk(5, Hq=6, Hkv=2, S=32)
    pos = jnp.arange(32, dtype=jnp.int32)
    a = attn.flash_attention(q, k, v, pos, pos)
    k_rep = jnp.repeat(k, 3, axis=1)
    v_rep = jnp.repeat(v, 3, axis=1)
    b = attn.flash_attention(q, k_rep, v_rep, pos, pos)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)


def test_mla_absorbed_decode_equals_expanded():
    """Absorbed-form MLA decode == expanded-KV attention on the last row."""
    from repro.configs import reduced_config
    from repro.models import mla, model_api
    from repro.models.sharding import NO_SHARD
    cfg = reduced_config("minicpm3-4b").with_(dtype="float32", remat=False)
    key = jax.random.PRNGKey(7)
    params, _ = model_api.init(cfg, key)
    batch = model_api.make_small_batch(cfg, key, 2, 33, kind="prefill")
    # prefill of S, then compare against prefill(S-1)+decode — covered in
    # test_models; here check the absorbed math directly on one layer
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    x = jax.random.normal(jax.random.fold_in(key, 2), (2, 17, cfg.d_model))
    pos = jnp.arange(17, dtype=jnp.int32)
    full = mla.mla_attention(lp["attn"], x, pos, cfg, NO_SHARD, "dense")
    # absorbed: build latent cache from the same x, decode last position
    cn, kr = mla._kv_latent(lp["attn"], x, cfg, pos)
    qn, qrope = mla._q_proj(lp["attn"], x, cfg, pos)
    o_lat = mla._absorbed_scores_attend(
        lp["attn"], qn[:, :, -1], qrope[:, :, -1], cn, kr,
        pos, 16, cfg, NO_SHARD, "dense", False)
    m = cfg.mla
    wkv = lp["attn"]["kv_b"]["w"].reshape(m.kv_lora_rank, cfg.n_heads,
                                          m.qk_nope_head_dim + m.v_head_dim)
    wv = wkv[..., m.qk_nope_head_dim:]
    o = jnp.einsum("bhr,rhd->bhd", o_lat, wv)
    import repro.models.param as pm
    a_last = pm.apply_linear(lp["attn"]["wo"],
                             o.reshape(2, 1, -1).astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(a_last[:, 0]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)
