"""ClusterKV decode service: plan-cached continuous batching.

Covers the serve subsystem (SessionStore, LockstepInserter,
ClusterKVEngine) plus the base-Engine edge cases the service's admission
churn leans on: EOS on the last active slot, queue > slots, prefill
buckets at the max_seq boundary, retire-then-backfill in one tick.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.configs.base import ClusterKVConfig
from repro.models import model_api
from repro.train.serve_loop import Engine, Request
from repro.serve import ClusterKVEngine, Session, SessionStore

MAX_SEQ = 128   # block_k 32 -> 4 tiles; decode_clusters 8 covers all of
                # them, so the sparse plan decode is EXACT


@pytest.fixture(scope="module")
def setup():
    # float32: the exactness tests compare greedy argmax tokens between the
    # scan-compiled dense decode and the unrolled plan decode, and with
    # random-init weights bf16 reassociation noise is enough to flip
    # near-tied logits; the routing being tested is dtype-independent
    cfg = reduced_config("qwen2-0.5b").with_(
        dtype="float32",
        clusterkv=ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                                  blocks_per_query=8, decode_clusters=8))
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _requests(cfg, lengths, max_new=6, eos=None, rid0=0):
    rng = np.random.default_rng(7)
    return [Request(rid=rid0 + i,
                    tokens=rng.integers(1, cfg.vocab, n).astype(np.int32),
                    max_new=max_new, eos_id=eos)
            for i, n in enumerate(lengths)]


def _service(cfg, params, slots=2, **kw):
    kw.setdefault("mode", "plan")
    return ClusterKVEngine(cfg, params, slots=slots, max_seq=MAX_SEQ,
                           prefill_bucket=32, **kw)


# ---------------------------------------------------------------------------
# base Engine edge cases
# ---------------------------------------------------------------------------


def test_engine_eos_on_last_active_slot(setup):
    """EOS retiring the LAST active slot must free it and end the run
    cleanly (no spin on an engine with zero active slots)."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_seq=MAX_SEQ, prefill_bucket=32)
    reqs = _requests(cfg, [20, 30], max_new=32)
    for r in reqs:
        eng.submit(r)
    eng.step()                          # both admitted + first decode
    reqs[0].eos_id = reqs[0].output[-1]
    eng._retire()
    assert eng.slot_req[0] is None and eng.slot_req[1] is not None
    reqs[1].eos_id = reqs[1].output[-1]  # EOS on the only active slot
    eng._retire()
    assert eng.slot_req == [None, None]
    ticks0 = eng.ticks
    eng.run()                           # nothing left: exit, no spinning
    assert eng.ticks == ticks0
    assert all(r.t_done > 0 for r in reqs)


def test_engine_queue_outnumbers_slots_fifo(setup):
    """More queued requests than free slots: everything is served, and
    admission order is FIFO (first two finish before the last starts)."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=2, max_seq=MAX_SEQ, prefill_bucket=32)
    reqs = _requests(cfg, [20, 25, 30, 18, 22], max_new=4)
    for r in reqs:
        eng.submit(r)
    eng.run()
    for r in reqs:
        assert len(r.output) == 4, r.rid
    assert max(reqs[0].t_done, reqs[1].t_done) <= reqs[4].t_first


def test_engine_prefill_bucket_at_max_seq_boundary(setup):
    """A prompt whose bucket rounds up to max_seq leaves no decode room:
    the engine must retire it promptly instead of looping or crashing."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=1, max_seq=64, prefill_bucket=32)
    req = _requests(cfg, [50], max_new=8)[0]   # bucket -> 64 == max_seq
    eng.submit(req)
    eng.run(max_ticks=20)
    assert req.t_done > 0
    assert len(req.output) < 8       # cut off by the max_seq guard
    assert eng.slot_req == [None]


def test_engine_retire_then_backfill_same_tick(setup):
    """With one slot and max_new=2, each request needs exactly one decode
    tick; the freed slot must be re-filled on the very next tick."""
    cfg, params = setup
    eng = Engine(cfg, params, slots=1, max_seq=MAX_SEQ, prefill_bucket=32)
    reqs = _requests(cfg, [20, 24], max_new=2)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert [len(r.output) for r in reqs] == [2, 2]
    assert eng.ticks == 2            # no idle tick between the two


# ---------------------------------------------------------------------------
# the decode service
# ---------------------------------------------------------------------------


def test_service_matches_dense_engine(setup):
    """Plan-cached service decode == dense-attention engine, token for
    token, across slot churn and mixed prompt lengths."""
    cfg, params = setup
    lengths = [20, 35, 17, 40]
    ref = _requests(cfg, lengths)
    dense = Engine(cfg, params, slots=2, max_seq=MAX_SEQ, prefill_bucket=32,
                   backend="flash")
    for r in ref:
        dense.submit(r)
    dense.run()

    got = _requests(cfg, lengths)
    svc = _service(cfg, params)
    for r in got:
        svc.submit(r)
    svc.run()
    for a, b in zip(ref, got):
        assert a.output == b.output, (a.rid, a.output, b.output)


def test_service_plan_prefill_matches_dense(setup):
    """plan_prefill routes the prompt through clusterkv_attention's
    plan_batch path; first tokens must still match dense decode."""
    cfg, params = setup
    ref = _requests(cfg, [20, 30])
    dense = Engine(cfg, params, slots=2, max_seq=MAX_SEQ, prefill_bucket=32)
    for r in ref:
        dense.submit(r)
    dense.run()

    got = _requests(cfg, [20, 30])
    svc = _service(cfg, params, plan_prefill=True)
    for r in got:
        svc.submit(r)
    svc.run()
    for a, b in zip(ref, got):
        assert a.output == b.output, (a.rid, a.output, b.output)


def test_service_one_spec_one_decode_trace(setup):
    """THE service gate: admissions across different prefill buckets all
    re-unify to one PlanSpec and re-enter ONE compiled decode kernel."""
    cfg, params = setup
    svc = _service(cfg, params)
    reqs = _requests(cfg, [20, 40, 60, 25, 50, 33])  # buckets 32 and 64
    for r in reqs:
        svc.submit(r)
    svc.run()
    rep = svc.report()
    assert rep["counters"]["admits"] == 6
    assert rep["specs_seen"] == 1, "admission retriggered spec derivation"
    assert rep["decode_traces"] == 1, "admission retriggered compilation"
    assert rep["prefill_traces"] == 2          # two buckets, per design


def test_service_insert_tier_telemetry(setup):
    """Every generated token streams through the append tier of every
    (layer, head) member plan — refresh telemetry must account for all
    of them, and the kNN edges must be folded on retire."""
    cfg, params = setup
    svc = _service(cfg, params)
    reqs = _requests(cfg, [20, 30], max_new=5)
    for r in reqs:
        svc.submit(r)
    svc.run()
    rep = svc.report()
    members = cfg.n_layers * cfg.n_kv_heads
    # per request: max_new tokens, the first from prefill -> max_new-1
    # decode ticks, each inserting into every member plan
    inserts = sum(len(r.output) - 1 for r in reqs)
    assert rep["counters"]["inserts"] == inserts
    assert rep["insert_tiers"]["appends"] == inserts * members
    assert rep["counters"]["flushed_edges"] == inserts * members * svc.knn


def test_inserter_claims_update_plan_slots(setup):
    """The lockstep inserter's Morton-leaf slot claim must land each key
    exactly where ``api.update_plan``'s insert tier would."""
    from repro.core import clusterkv as ckv
    from repro.serve.streaming import LockstepInserter

    cfg, _ = setup
    hkv, s, cap, dh = cfg.n_kv_heads, 32, 64, cfg.head_dim
    rng = np.random.default_rng(3)
    keys = rng.normal(size=(hkv, s, dh)).astype(np.float32)
    new = rng.normal(size=(hkv, dh)).astype(np.float32)

    # reference: the real insert tier (fresh batch -> fresh hosts)
    pb_ref = ckv.kv_plan_batch(jnp.asarray(keys), knn=8, capacity=cap)
    _, idx_ref = pb_ref.insert([new[h][None] for h in range(hkv)])

    pb = ckv.kv_plan_batch(jnp.asarray(keys), knn=8, capacity=cap)
    ins = LockstepInserter(n_layers=1, slots=1, n_heads=hkv, capacity=cap,
                          head_dim=dh, embed_d=min(3, dh), knn=8)
    ins.attach(0, [pb])
    phys = ins.insert([0], jnp.asarray(new[None, None]))   # (1,1,H)
    for h in range(hkv):
        assert phys[0, 0, h] == idx_ref[h][0], h
        host = pb.hosts[h]
        assert bool(host.alive[phys[0, 0, h]])
        assert host.refresh.appends == 1
    assert ins.flush(0) > 0                   # edges folded into the COO


def test_service_trim_tombstones(setup):
    """Trimming live positions takes the tombstone tier (no retrace) and
    decode continues."""
    cfg, params = setup
    svc = _service(cfg, params, slots=1)
    req = _requests(cfg, [20], max_new=10)[0]
    svc.submit(req)
    for _ in range(4):
        svc.step()
    sess = svc.store.get(req.rid)
    gen_pos = sorted(sess.phys_hist)[0]       # an already-landed token
    svc.trim(req.rid, [3, gen_pos])           # one prefill + one generated
    assert svc.store.counters["deletes"] == 2
    for pb in sess.plans:
        for host in pb.hosts:
            assert host.refresh.tombstones == 1
            assert host.refresh.deleted_total == 2
    svc.run()
    assert len(req.output) == 10
    assert svc.report()["decode_traces"] == 1


def test_service_rebucket_keeps_decode_exact(setup):
    """Rebucketing mid-decode only reorders the plan rows; with a
    full-coverage cluster budget the remaining tokens are unchanged."""
    cfg, params = setup
    ref = _requests(cfg, [24], max_new=10)[0]
    e0 = _service(cfg, params, slots=1)
    e0.submit(ref)
    e0.run()

    req = _requests(cfg, [24], max_new=10)[0]
    e1 = _service(cfg, params, slots=1)
    e1.submit(req)
    for _ in range(4):
        e1.step()
    e1.rebucket(req.rid)
    assert e1.store.counters["rebuckets"] == 1
    e1.run()
    assert req.output == ref.output
    assert e1.report()["decode_traces"] == 1


def test_service_snapshot_resume_bit_exact(setup, tmp_path):
    """Drain -> save_plan(SessionStore) -> restore -> resume continues
    decode bit-exactly in a FRESH engine."""
    from repro.checkpoint.ckpt import Checkpointer

    cfg, params = setup
    lengths = [20, 30]
    ref = _requests(cfg, lengths, max_new=10)
    e0 = _service(cfg, params)
    for r in ref:
        e0.submit(r)
    e0.run()

    e1 = _service(cfg, params)
    reqs = _requests(cfg, lengths, max_new=10)
    for r in reqs:
        e1.submit(r)
    for _ in range(4):
        e1.step()
    ck = Checkpointer(tmp_path)
    e1.snapshot(ck, step=4)

    store, step = ck.restore_plan(name="sessions")
    assert step == 4
    assert sorted(store.sessions) == [0, 1]
    assert store.counters == e1.store.counters
    e2 = _service(cfg, params)
    e2.resume(store)
    restored = {r.rid: r for r in e2.slot_req if r is not None}
    e2.run()
    for a in ref:
        assert restored[a.rid].output == a.output, a.rid


def test_session_store_bookkeeping():
    """Spec-keyed membership + counters, without any engine."""
    store = SessionStore()

    class _Plan:        # stand-in with a hashable spec
        spec = ("cfg", 64)

    s1 = Session(rid=1, slot=0, blen=32, plans=[_Plan()])
    s2 = Session(rid=2, slot=1, blen=64, plans=[_Plan()])
    assert store.admit(s1) is True            # first spec sighting
    assert store.admit(s2) is False           # shared spec
    assert store.specs_live == 1 and store.specs_seen == 1
    store.retire(1)
    assert store.specs_live == 1              # rid 2 still holds the spec
    store.retire(2, evict=True)
    assert store.specs_live == 0 and store.specs_seen == 1
    rep = store.report()
    assert rep["counters"]["retires"] == 1
    assert rep["counters"]["evictions"] == 1
    assert rep["active_sessions"] == 0


def test_inserter_batched_claims_agree_with_loop():
    """claim_slots_batched == a per-member claim_slot loop under tick
    churn (random occupancies, permuted physical layouts, with and
    without the maintained block maxima)."""
    from repro.serve.streaming import (CLAIM_BLOCK, claim_slot,
                                       claim_slots_batched)

    class _Host:
        __slots__ = ("pi", "codes", "alive")

    rng = np.random.default_rng(5)
    for cap, m, ticks in [(64, 6, 12), (256, 4, 20), (512, 8, 8)]:
        codes_io = rng.integers(0, 1 << 30, (m, cap)).astype(np.uint64)
        codes_io.sort(axis=1)
        alive_io = rng.random((m, cap)) < rng.uniform(0.1, 0.9)
        hosts, pis = [], np.zeros((m, cap), np.int64)
        for i in range(m):
            h = _Host()
            h.pi = rng.permutation(cap)
            h.codes = np.empty(cap, np.uint64)
            h.codes[h.pi] = codes_io[i]
            h.alive = np.empty(cap, bool)
            h.alive[h.pi] = alive_io[i]
            hosts.append(h)
            pis[i] = h.pi
        use_bm = cap % CLAIM_BLOCK == 0 and cap >= 2 * CLAIM_BLOCK
        bm = (codes_io.reshape(m, -1, CLAIM_BLOCK).max(axis=2)
              if use_bm else None)
        rows = np.arange(m)
        for _ in range(ticks):
            arr = rng.integers(0, 1 << 30, (m,)).astype(np.uint64)
            want = np.array([claim_slot(h, arr[i])
                             for i, h in enumerate(hosts)])
            pos = claim_slots_batched(codes_io, alive_io, arr,
                                      block_max=bm)
            assert (pis[rows, pos] == want).all()
            # churn: mutate exactly as the inserter does
            for i, h in enumerate(hosts):
                h.alive[want[i]] = True
                h.codes[want[i]] = arr[i]
            alive_io[rows, pos] = True
            codes_io[rows, pos] = arr
            if use_bm:
                blk = pos // CLAIM_BLOCK
                seg = codes_io[rows[:, None], (blk * CLAIM_BLOCK)[:, None]
                               + np.arange(CLAIM_BLOCK)]
                bm[rows, blk] = seg.max(axis=1)
    full = np.ones((2, 32), bool)
    with pytest.raises(ValueError, match="no free plan slots"):
        claim_slots_batched(np.zeros((2, 32), np.uint64), full,
                            np.zeros(2, np.uint64))


def test_inserter_stale_generation_raises():
    """An insert streamed against a stale attachment must raise, not
    silently mutate hosts the serving plan no longer reads."""
    from repro.core import clusterkv as ckv
    from repro.serve.streaming import LockstepInserter

    rng = np.random.default_rng(9)
    hkv, s, cap, dh = 2, 32, 64, 16
    keys = rng.normal(size=(hkv, s, dh)).astype(np.float32)
    pb = ckv.kv_plan_batch(jnp.asarray(keys), knn=8, capacity=cap)
    ins = LockstepInserter(n_layers=1, slots=1, n_heads=hkv, capacity=cap,
                          head_dim=dh, embed_d=3, knn=8)
    ins.attach(0, [pb], generation=2)
    assert ins.generation(0) == 2
    new = jnp.asarray(rng.normal(size=(1, 1, hkv, dh)), jnp.float32)
    ins.insert([0], new, generations={0: 2})        # in sync: fine
    with pytest.raises(RuntimeError, match="re-attach after a plan swap"):
        ins.insert([0], new, generations={0: 3})    # plans swapped since
