"""Analytic cost model + hardware-config knobs + analytic-first autotune."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import autotune, costmodel
from repro.core.costmodel import HardwareConfig


@pytest.fixture(autouse=True)
def _reset_model_state():
    yield
    costmodel.set_hardware(None)
    autotune.clear_tune_memo()
    autotune.clear_calibration()


def _plan(n=256, bs=16, sb=4):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((n, 8)), jnp.float32)
    return api.build_plan(x, k=8, bs=bs, sb=sb, backend="bsr")


# -- hardware config --------------------------------------------------------


def test_hardware_config_json_roundtrip(tmp_path):
    hw = HardwareConfig(name="test-chip", peak_flops=1e12, hbm_bw=1e11,
                        vmem_bytes=1 << 20)
    p = tmp_path / "hw.json"
    hw.to_json(str(p))
    assert HardwareConfig.from_json(str(p)) == hw
    # knob files with unknown keys fail loudly, not silently
    p.write_text(json.dumps({"peak_flops": 1.0, "warp_size": 32}))
    with pytest.raises(ValueError, match="warp_size"):
        HardwareConfig.from_json(str(p))


def test_set_hardware_accepts_dict_and_resets():
    hw = costmodel.set_hardware({"name": "knobs", "gather_penalty": 2.0})
    assert costmodel.get_hardware() is hw
    assert costmodel.get_hardware().gather_penalty == 2.0
    default = costmodel.set_hardware(None)
    assert default.name == "tpu-v5e"


def test_report_envelope():
    rep = costmodel.make_report("backend_rank", {"winner": "bsr"})
    assert rep["schema"] == costmodel.SCHEMA == "repro.cost/v1"
    assert rep["kind"] == "backend_rank"
    assert rep["hardware"]["peak_flops"] == costmodel.get_hardware().peak_flops
    assert rep["winner"] == "bsr"


# -- per-backend cost shapes ------------------------------------------------


def test_backend_cost_orderings():
    feat = costmodel.plan_features((512, 16, 4, 32, 32, 6), f=1)
    hw = HardwareConfig()
    csr = costmodel.backend_cost(feat, "csr", hw)
    bsr = costmodel.backend_cost(feat, "bsr", hw)
    ml = costmodel.backend_cost(feat, "bsr_ml", hw)
    pallas = costmodel.backend_cost(feat, "pallas", hw)
    # fused kernel moves the least HBM; the per-edge gather path the most
    assert pallas["hbm_bytes"] < bsr["hbm_bytes"] < csr["hbm_bytes"]
    assert ml["launches"] == 8 and bsr["launches"] == 1
    # interpret mode makes pallas unwinnable
    interp = costmodel.backend_cost(feat, "pallas", hw, interpret=True)
    assert interp["seconds"] > bsr["seconds"]


def test_csr_priced_on_true_nnz():
    """The per-edge path pays for real COO edges, not ELL padding: on a
    hub-heavy key (fill ~1%) it must undercut the blocked paths, while
    the dense-equivalent fallback keeps the old blocked-wins ordering."""
    key = (1024, 16, 8, 64, 64, 38)          # kNN hubs: max_nbr >> k
    sparse = costmodel.plan_features(key, nnz=8192)
    dense = costmodel.plan_features(key)     # fallback: every slot full
    hw = HardwareConfig()
    assert costmodel.backend_cost(sparse, "csr", hw)["seconds"] \
        < costmodel.backend_cost(dense, "csr", hw)["seconds"]
    assert costmodel.backend_cost(sparse, "csr", hw)["seconds"] \
        < costmodel.backend_cost(sparse, "bsr", hw)["seconds"]
    assert costmodel.backend_cost(dense, "bsr", hw)["seconds"] \
        < costmodel.backend_cost(dense, "csr", hw)["seconds"]


def test_rank_backends_excludes_inf_calibration():
    feat = costmodel.plan_features((512, 16, 4, 32, 32, 6))
    rep = costmodel.rank_backends(
        feat, ("csr", "bsr", "bsr_ml", "pallas"),
        calibration={"pallas": float("inf"), "csr": 1.0})
    assert "pallas" not in rep["predicted_s"]
    assert rep["winner"] == rep["ranking"][0]
    assert rep["schema"] == costmodel.SCHEMA
    assert rep["winner"] == min(rep["predicted_s"], key=rep["predicted_s"].get)


def test_exchange_cost_monotone_and_none_passthrough():
    assert costmodel.exchange_cost(None, 16) is None
    a = costmodel.exchange_cost(3, 16)
    b = costmodel.exchange_cost(7, 16)
    assert 0 < a < b
    # halved link bandwidth doubles the price
    slow = HardwareConfig(link_bw=HardwareConfig().link_bw / 2)
    assert costmodel.exchange_cost(3, 16, slow) == pytest.approx(2 * a)


def test_choose_tiles_contracts():
    key = (512, 16, 8, 32, 32, 6)
    rbs, chunk, fc = costmodel.choose_tiles(key, f=4)
    assert chunk == 6          # full ELL width always (bit parity)
    assert fc == 4
    assert rbs in (1, 2, 4, 8) and rbs <= 8
    # a starved VMEM budget shrinks the feature tile and superblock
    tiny = HardwareConfig(vmem_bytes=64 * 1024)
    rbs_t, chunk_t, fc_t = costmodel.choose_tiles(key, f=16, hw=tiny)
    assert chunk_t == 6
    assert fc_t < 16
    assert rbs_t <= rbs


# -- analytic-first autotune ------------------------------------------------


def test_tune_backend_reports_ranking_in_memo():
    autotune.clear_tune_memo()
    plan = _plan()
    name, times = autotune.tune_backend(plan, device_count=1)
    assert times and name == min(times, key=times.get)
    (report,) = autotune._TUNE_MEMO.values()
    assert report["schema"] == costmodel.SCHEMA
    assert report["kind"] == "backend_rank"
    assert report["winner"] == name
    assert report["ranking"][0] == name
    # memo hit replays winner + predicted seconds without touching probes
    name2, times2 = autotune.tune_backend(plan, device_count=1)
    assert (name2, times2) == (name, times)


def test_hw_config_flip_changes_decision_without_reprobing(monkeypatch):
    """clear_tune_memo + a different hardware config re-decides purely from
    the model: probes must not run (calibration constants are reused)."""
    plan = _plan(n=256, bs=16, sb=4)     # n_rb=16, sb=4 -> bsr_ml launches 4
    autotune.clear_tune_memo()
    autotune.clear_calibration()
    autotune._CALIB.update({"bsr": 1.0, "bsr_ml": 1.0,
                            "csr": float("inf"), "pallas": float("inf")})

    def boom(*a, **k):
        raise AssertionError("probe ran despite existing calibration")

    monkeypatch.setattr(autotune, "probe_backends", boom)

    costmodel.set_hardware(HardwareConfig(gather_penalty=100.0,
                                          launch_overhead=0.0))
    name_a, _ = autotune.tune_backend(plan, device_count=1)
    assert name_a == "bsr_ml"            # flat path pays the gather penalty

    autotune.clear_tune_memo()
    costmodel.set_hardware(HardwareConfig(gather_penalty=1.0,
                                          launch_overhead=1.0))
    name_b, _ = autotune.tune_backend(plan, device_count=1)
    assert name_b == "bsr"               # striped path pays 4 launches


def test_probe_backends_skips_interpret_pallas():
    plan = _plan(n=128)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(plan.n),
                    jnp.float32)
    times = autotune.probe_backends(plan, x, backends=("bsr", "pallas"),
                                    iters=1, warmup=0)
    assert "pallas" not in times         # interpret-mode: skipped by default
    assert "bsr" in times
    times_inc = autotune.probe_backends(plan, x, backends=("pallas",),
                                        iters=1, warmup=0,
                                        include_interpret=True)
    assert "pallas" in times_inc         # escape hatch still times it


def _decode_feat(**kw):
    base = dict(batch=8, hq=14, hkv=2, s=8192, dh=64, dv=64, bk=128,
                n_sel=4)
    base.update(kw)
    return costmodel.DecodeFeatures(**base)


def test_decode_cost_orderings():
    """Compiled: the fused kernel's once-only tile traffic and single
    launch beat the xla gather round-trip. Interpreted (the CPU CI
    container): the kernel eats interpret_penalty and xla must win —
    that asymmetry is what keeps "auto" correct on both targets."""
    feat = _decode_feat()
    xla = costmodel.decode_cost(feat, "xla")
    pal = costmodel.decode_cost(feat, "pallas")
    assert pal["hbm_bytes"] < xla["hbm_bytes"]
    assert pal["launches"] < xla["launches"]
    assert pal["seconds"] < xla["seconds"]
    pal_i = costmodel.decode_cost(feat, "pallas", interpret=True)
    assert pal_i["seconds"] > xla["seconds"]
    assert costmodel.choose_decode_backend(feat) == "pallas"
    assert costmodel.choose_decode_backend(feat, interpret=True) == "xla"


def test_decode_rank_report_envelope():
    rep = costmodel.rank_decode_backends(_decode_feat())
    assert rep["schema"] == "repro.cost/v1"
    assert rep["kind"] == "decode_rank"
    assert rep["winner"] == rep["ranking"][0]
    assert set(rep["costs"]) == {"xla", "pallas"}
    assert rep["features"]["s"] == 8192
    json.dumps(rep)                                  # JSON-safe


def test_decode_choice_memoized():
    feat = _decode_feat(batch=3)
    costmodel._DECODE_CHOICE.clear()
    a = costmodel.choose_decode_backend(feat)
    assert len(costmodel._DECODE_CHOICE) == 1
    b = costmodel.choose_decode_backend(feat)
    assert a == b and len(costmodel._DECODE_CHOICE) == 1
    costmodel.choose_decode_backend(feat, interpret=True)
    assert len(costmodel._DECODE_CHOICE) == 2
