"""Plan lifecycle (ISSUE 2): refresh tiers, stable partial reorder, BSR
patching, drift-measure edge cases, pytree round-trips under jit/vmap,
and checkpoint save -> restore -> matvec equivalence."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.checkpoint.ckpt import Checkpointer
from repro.core import blocksparse, hierarchy, interact, measures
from repro.core.ordering import stable_partial_reorder
from repro.data.pipeline import feature_mixture

N, D, K = 512, 32, 8


@pytest.fixture(scope="module")
def points():
    return feature_mixture(N, D, n_clusters=8, seed=0)


@pytest.fixture(scope="module")
def plan(points):
    return api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8)


def _teleport(x, frac, seed=1):
    """Move a fraction of points onto other clusters' locations."""
    rng = np.random.default_rng(seed)
    x2 = x.copy()
    mv = rng.choice(len(x), size=max(int(len(x) * frac), 1), replace=False)
    x2[mv] = x[(mv + len(x) // 2) % len(x)]
    x2[mv] += 0.01 * rng.standard_normal((len(mv), x.shape[1])
                                         ).astype(np.float32)
    return x2, mv


def _detected(plan, x_new):
    """Original indices the refresh migration detector flags (a teleport
    landing in the SAME leaf cell is — by design — not a migration)."""
    host, cfg = plan.host, plan.config
    y_new = np.asarray(api.apply_pca_map(jnp.asarray(x_new),
                                         jnp.asarray(host.embed_mean),
                                         jnp.asarray(host.embed_axes)))
    shift = api._cmp_shift(plan.n, y_new.shape[1], cfg.bits, host.tree,
                           cfg.leaf_size)
    return np.nonzero(api._cell_migration(host.y_last, y_new, cfg.bits,
                                          shift))[0]


# ---------------------------------------------------------------------------
# refresh tiers
# ---------------------------------------------------------------------------


def test_refresh_noop_when_nothing_moved(plan, points):
    p2 = plan.refresh(points)
    st = p2.refresh_stats
    assert st.last_action == "patch"
    assert st.last_migrated_frac == 0.0
    # untouched structure is shared, not copied
    assert p2.bsr is plan.bsr
    np.testing.assert_array_equal(p2.host.pi, plan.host.pi)


def test_refresh_patch_small_migration(plan, points):
    x2, mv = _teleport(points, 0.03)
    p2 = plan.refresh(x2, policy="patch")
    st = p2.refresh_stats
    assert st.last_action == "patch" and st.patches == 1
    # permutation untouched by the cheap tier
    np.testing.assert_array_equal(p2.host.pi, plan.host.pi)

    # patched storage is self-consistent: bsr path == csr over its own COO
    xq = jnp.asarray(np.random.default_rng(2).standard_normal(N),
                     jnp.float32)
    ref = np.asarray(p2.apply(xq, backend="csr"))
    got = np.asarray(p2.apply(xq, backend="bsr"))
    assert np.abs(got - ref).max() <= 1e-4

    # detected-migrated rows got their *exact* fresh kNN
    det = _detected(plan, x2)
    assert len(det) > 0 and set(det) <= set(mv)
    fresh = api.build_plan(x2, k=K, bs=16, sb=4, backend="bsr")
    r2, c2, _ = p2.coo
    ro, co = p2.host.pi[r2], p2.host.pi[c2]
    fr, fc, _ = fresh.coo
    fro, fco = fresh.host.pi[fr], fresh.host.pi[fc]
    for i in det:
        assert set(co[ro == i]) == set(fco[fro == i])


def test_refresh_gamma_close_to_rebuild(plan, points):
    x2, _ = _teleport(points, 0.03)
    p2 = plan.refresh(x2)
    rebuilt = api.build_plan(x2, k=K, bs=16, sb=4, backend="bsr")
    assert p2.gamma == pytest.approx(rebuilt.gamma, rel=0.05)


def test_refresh_escalates_with_drift(plan, points):
    x2, _ = _teleport(points, 0.25, seed=3)
    p2 = plan.refresh(x2)
    assert p2.refresh_stats.last_action in ("rebucket", "rebuild")
    # a shuffled cloud is a different ordering problem: full rebuild
    x3 = np.random.default_rng(4).permutation(points).copy()
    p3 = plan.refresh(x3)
    assert p3.refresh_stats.last_action == "rebuild"
    assert p3.refresh_stats.builds == 2


def test_refresh_rebucket_keeps_matvec_semantics(plan, points):
    """After a forced re-bucket, matvec in ORIGINAL order still equals the
    csr reference on the relabeled pattern."""
    x2, _ = _teleport(points, 0.03, seed=5)
    p2 = plan.refresh(x2, policy="rebucket")
    assert p2.refresh_stats.last_action == "rebucket"
    assert sorted(p2.host.pi) == list(range(N))
    xq = jnp.asarray(np.random.default_rng(6).standard_normal(N),
                     jnp.float32)
    r2, c2, v = p2.coo
    rows0, cols0 = p2.host.pi[r2], p2.host.pi[c2]
    want = interact.spmv_csr(jnp.asarray(v), jnp.asarray(rows0),
                             jnp.asarray(cols0), xq, N)
    np.testing.assert_allclose(np.asarray(p2.matvec(xq)),
                               np.asarray(want), rtol=1e-4, atol=1e-4)


def test_refresh_fixed_pattern_reorders_only(points):
    """from_coo plans (externally fixed pattern) refresh their ordering but
    keep edges and values bit-for-bit."""
    rng = np.random.default_rng(7)
    rows = np.repeat(np.arange(N), K)
    cols = rng.integers(0, N, N * K)
    key = rows.astype(np.int64) * N + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = rng.random(len(rows)).astype(np.float32)
    plan = api.InteractionPlan.from_coo(rows, cols, vals, N, x=points,
                                        bs=16, sb=4)
    x2, _ = _teleport(points, 0.3, seed=8)
    p2 = plan.refresh(x2)

    def orig_edges(p):
        r2, c2, v = p.coo
        return sorted(zip(p.host.pi[r2], p.host.pi[c2], v))

    assert orig_edges(p2) == orig_edges(plan)


def test_refresh_policy_validation(plan, points):
    with pytest.raises(ValueError, match="unknown refresh policy"):
        plan.refresh(points, policy="nope")
    with pytest.raises(ValueError, match="same"):
        plan.refresh(points[:-1])
    prof = api.build_plan(points, k=K, ordering="scattered", with_bsr=False)
    with pytest.raises(ValueError, match="not refreshable"):
        prof.refresh(points)


def test_refresh_values_callable_redressed(points):
    """Patched rows get values recomputed through the stored callable."""
    plan = api.build_plan(points, k=K, bs=16, sb=4, backend="bsr",
                          ell_slack=8,
                          values=lambda r, c, d2: 1.0 / (1.0 + d2))
    x2, mv = _teleport(points, 0.03, seed=9)
    det = _detected(plan, x2)
    assert len(det) > 0
    p2 = plan.refresh(x2, policy="patch")
    assert p2.refresh_stats.last_action == "patch"
    r2, c2, v = p2.coo
    ro, co = p2.host.pi[r2], p2.host.pi[c2]
    sel = np.isin(ro, det)
    d2 = ((x2[ro[sel]] - x2[co[sel]]) ** 2).sum(1)
    # knn's |a|^2+|b|^2-2ab distances differ from the direct form by
    # float32 cancellation noise
    np.testing.assert_allclose(v[sel], 1.0 / (1.0 + d2), atol=1e-3)


def test_gamma_drift_monitor(plan, points):
    assert plan.gamma_drift() == 0.0          # pins the reference
    x2, _ = _teleport(points, 0.05, seed=10)
    p2 = plan.refresh(x2, policy="patch")
    assert p2.refresh_stats.gamma0 == pytest.approx(plan.gamma)
    assert isinstance(p2.gamma_drift(), float)


# ---------------------------------------------------------------------------
# building blocks: stable reorder, tree rebucket, patch_bsr, measures
# ---------------------------------------------------------------------------


def test_stable_partial_reorder_properties():
    rng = np.random.default_rng(0)
    n = 200
    keys = rng.integers(0, 50, n)
    pi = np.argsort(keys, kind="stable")
    # unchanged keys -> identical ordering
    np.testing.assert_array_equal(stable_partial_reorder(pi, keys), pi)
    # perturb a few keys: result is sorted, and unmoved points keep their
    # relative order
    keys2 = keys.copy()
    mv = rng.choice(n, 10, replace=False)
    keys2[mv] = rng.integers(0, 50, 10)
    pi2 = stable_partial_reorder(pi, keys2)
    assert sorted(pi2) == list(range(n))
    assert (np.diff(keys2[pi2]) >= 0).all()
    stay = ~np.isin(pi, mv)
    stay2 = ~np.isin(pi2, mv)
    np.testing.assert_array_equal(pi[stay], pi2[stay2])


def test_tree_rebucket_matches_fresh_build():
    rng = np.random.default_rng(1)
    y = rng.standard_normal((300, 3)).astype(np.float32)
    tree = hierarchy.build_tree(y, leaf_size=32)
    y2 = y.copy()
    y2[:30] += 2.0
    re = hierarchy.rebucket(y2, tree, leaf_size=32)
    fresh = hierarchy.build_tree(y2, leaf_size=32)
    # same cells (codes equal), possibly different within-cell tiebreaks
    codes_re = np.asarray(hierarchy.morton_codes(jnp.asarray(y2)))[re.perm]
    codes_fr = np.asarray(hierarchy.morton_codes(jnp.asarray(y2)))[fresh.perm]
    np.testing.assert_array_equal(codes_re, codes_fr)
    assert len(re.levels) == len(fresh.levels)
    for a, b in zip(re.levels, fresh.levels):
        np.testing.assert_array_equal(a, b)


def test_patch_bsr_matches_full_build():
    rng = np.random.default_rng(2)
    n, bs, sb, k = 300, 16, 4, 6
    rows = np.repeat(np.arange(n), k)
    cols = rng.integers(0, n, n * k)
    key = rows.astype(np.int64) * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = rng.random(len(rows)).astype(np.float32)
    base = blocksparse.build_bsr(rows, cols, vals, n, bs=bs, sb=sb, slack=2)

    mod = rng.choice(n, 30, replace=False)
    drop = np.isin(rows, mod)
    nr = np.repeat(mod, k)
    nc = rng.integers(0, n, len(nr))
    k2 = nr.astype(np.int64) * n + nc
    _, f2 = np.unique(k2, return_index=True)
    nr, nc = nr[f2], nc[f2]
    r_all = np.concatenate([rows[~drop], nr])
    c_all = np.concatenate([cols[~drop], nc])
    v_all = np.concatenate([vals[~drop],
                            rng.random(len(nr)).astype(np.float32)])
    touched = np.unique(np.concatenate([rows[drop], nr]) // bs)
    patched = blocksparse.patch_bsr(base, r_all, c_all, v_all, touched)
    fresh = blocksparse.build_bsr(r_all, c_all, v_all, n, bs=bs, sb=sb,
                                  max_nbr=base.max_nbr)
    np.testing.assert_array_equal(patched.to_dense(), fresh.to_dense())
    np.testing.assert_array_equal(np.asarray(patched.col_idx),
                                  np.asarray(fresh.col_idx))
    np.testing.assert_array_equal(np.asarray(patched.nbr_mask),
                                  np.asarray(fresh.nbr_mask))
    assert patched.fill == pytest.approx(fresh.fill)


def test_patch_bsr_overflow_raises():
    base = blocksparse.build_bsr(np.array([0]), np.array([0]), None, 64,
                                 bs=16, sb=4)
    assert base.max_nbr == 1
    rows = np.zeros(4, np.int64)
    cols = np.array([0, 16, 32, 48])
    with pytest.raises(ValueError, match="tile slots"):
        blocksparse.patch_bsr(base, rows, cols, None, np.array([0]))


def test_measures_edge_cases():
    empty = np.empty(0, np.int64)
    assert measures.fill_ratio(empty, empty, 64, 16) == 0.0
    assert float(measures.gamma_score(jnp.asarray(empty),
                                      jnp.asarray(empty), 4.0, 64)) == 0.0
    assert float(measures.gamma_exact(jnp.asarray(empty),
                                      jnp.asarray(empty), 4.0)) == 0.0
    assert measures.beta_estimate(empty, empty, 64) == {
        "beta": 0.0, "block": None, "per_block": {}}
    # single-block pattern (n < bs): well-defined, no division by zero
    rows = np.arange(4)
    assert 0 < measures.fill_ratio(rows, rows, 4, 16) <= 1
    assert measures.gamma_drift(None, 1.0) == 0.0
    assert measures.gamma_drift(0.0, 1.0) == 0.0
    assert measures.gamma_drift(2.0, 1.0) == pytest.approx(0.5)
    assert measures.fill_drift(0.5, 0.25) == pytest.approx(0.5)
    assert measures.fill_drift(None, 0.25) == 0.0


# ---------------------------------------------------------------------------
# pytree round-trips under jit / vmap
# ---------------------------------------------------------------------------


def test_plan_pytree_round_trip_jit_vmap(plan, points):
    xq = jnp.asarray(np.random.default_rng(11).standard_normal(N),
                     jnp.float32)
    leaves, treedef = jax.tree_util.tree_flatten(plan)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    ref = np.asarray(plan.apply(xq, backend="bsr"))
    np.testing.assert_allclose(np.asarray(back.apply(xq, backend="bsr")),
                               ref, rtol=1e-5)

    f = jax.jit(lambda p, v: p.apply(v, backend="bsr"))
    np.testing.assert_allclose(np.asarray(f(plan, xq)), ref, rtol=1e-5)

    X = jnp.asarray(np.random.default_rng(12).standard_normal((4, N)),
                    jnp.float32)
    Y = jax.vmap(lambda v: plan.apply(v, backend="bsr"))(X)
    for i in range(4):
        np.testing.assert_allclose(
            np.asarray(Y[i]), np.asarray(plan.apply(X[i], backend="bsr")),
            rtol=1e-5, atol=1e-5)


def test_refreshed_plan_still_crosses_jit(plan, points):
    x2, _ = _teleport(points, 0.03, seed=13)
    p2 = plan.refresh(x2)
    xq = jnp.asarray(np.random.default_rng(14).standard_normal(N),
                     jnp.float32)
    f = jax.jit(lambda p, v: p.apply(v, backend="bsr"))
    np.testing.assert_allclose(np.asarray(f(p2, xq)),
                               np.asarray(p2.apply(xq, backend="bsr")),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# persistence: save -> restore -> matvec equivalence, refresh-on-restore
# ---------------------------------------------------------------------------


def test_checkpoint_plan_round_trip(plan, points):
    _ = plan.gamma                        # score rides the manifest
    ck = Checkpointer(tempfile.mkdtemp())
    ck.save_plan(7, plan, blocking=True)
    assert ck.plan_steps() == [7]
    assert ck.steps() == []              # no *model* checkpoint here
    p2, step = ck.restore_plan()
    assert step == 7
    xq = jnp.asarray(np.random.default_rng(15).standard_normal(N),
                     jnp.float32)
    # bit-identical matvec after the round trip
    np.testing.assert_array_equal(np.asarray(plan.matvec(xq)),
                                  np.asarray(p2.matvec(xq)))
    assert p2.config == plan.config
    assert p2.host.gamma == plan.host.gamma
    assert p2.tree is not None and p2.tree.n_levels == plan.tree.n_levels
    assert dataclasses.asdict(p2.refresh_stats) == \
        dataclasses.asdict(plan.refresh_stats)


def test_checkpoint_restore_refreshes_on_drift(plan, points):
    ck = Checkpointer(tempfile.mkdtemp())
    ck.save_plan(0, plan, blocking=True)
    # unmoved points: the restored plan validates as fresh
    p_same, _ = ck.restore_plan(refresh_with=points)
    assert p_same.refresh_stats.last_migrated_frac == 0.0
    # drifted points: restore invalidates the stale ordering
    x2 = np.random.default_rng(16).permutation(points).copy()
    p_moved, _ = ck.restore_plan(refresh_with=x2)
    assert p_moved.refresh_stats.last_action == "rebuild"


def test_checkpoint_plans_and_models_gc_independently(plan):
    """Plans saved on a different cadence must not evict (or shadow) model
    checkpoints: each kind keeps its own latest `keep` steps."""
    ck = Checkpointer(tempfile.mkdtemp(), keep=2)
    tree = {"w": jnp.arange(4.0)}
    for s in (10, 20):
        ck.save(s, tree, blocking=True)
    for s in (30, 40, 50):
        ck.save_plan(s, plan, blocking=True)
    assert ck.steps() == [10, 20]        # model ckpts survive plan gc
    assert ck.plan_steps() == [40, 50]   # plans keep their own window
    restored, step = ck.restore(tree)    # default step is a *model* step
    assert step == 20
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(tree["w"]))
    _, pstep = ck.restore_plan()
    assert pstep == 50


def test_checkpoint_async_save_plan(plan):
    ck = Checkpointer(tempfile.mkdtemp())
    ck.save_plan(1, plan)                 # async path
    ck.wait()
    p2, _ = ck.restore_plan(step=1)
    assert p2.n == plan.n


def test_plan_config_validated_at_construction():
    """Bad thresholds fail loudly at PlanConfig(), not deep in a refresh."""
    with pytest.raises(ValueError, match="ell_slack"):
        api.PlanConfig(ell_slack=-1)
    with pytest.raises(ValueError, match="patch_frac.*rebuild_frac"):
        api.PlanConfig(patch_frac=0.5, rebuild_frac=0.2)
    with pytest.raises(ValueError, match="drift_tol"):
        api.PlanConfig(drift_tol=-0.1)
    with pytest.raises(ValueError, match="drift_tol"):
        api.PlanConfig(drift_tol=1.5)
    with pytest.raises(ValueError, match="patch_frac"):
        api.PlanConfig(patch_frac=-0.2)
    with pytest.raises(ValueError, match="max_dead_frac"):
        api.PlanConfig(max_dead_frac=0.0)
    with pytest.raises(ValueError, match="grow_frac"):
        api.PlanConfig(grow_frac=-1.0)
    # dataclasses.replace re-validates
    good = api.PlanConfig()
    with pytest.raises(ValueError, match="rebuild_frac"):
        dataclasses.replace(good, rebuild_frac=0.05)
    # build_plan overrides route through the same gate
    with pytest.raises(ValueError, match="ell_slack"):
        api.build_plan(np.zeros((32, 4), np.float32), k=2, ell_slack=-3)


# ---------------------------------------------------------------------------
# restore_plan error paths (descriptive, not opaque tracebacks)
# ---------------------------------------------------------------------------


def test_restore_plan_missing(plan):
    ck = Checkpointer(tempfile.mkdtemp())
    with pytest.raises(FileNotFoundError, match="no plan 'plan'"):
        ck.restore_plan()
    ck.save_plan(3, plan, blocking=True)
    with pytest.raises(FileNotFoundError, match="no plan 'other'"):
        ck.restore_plan(name="other")
    with pytest.raises(FileNotFoundError, match="step 9"):
        ck.restore_plan(step=9)


def test_restore_plan_corrupt_manifest(plan):
    from pathlib import Path
    d = Path(tempfile.mkdtemp())
    ck = Checkpointer(d)
    ck.save_plan(1, plan, blocking=True)
    mf = d / "step_1" / "plan_plan" / "manifest.json"
    mf.write_text("{not json")
    with pytest.raises(ValueError, match="corrupt plan manifest"):
        ck.restore_plan()


def test_restore_plan_array_shape_mismatch(plan):
    import json as _json
    from pathlib import Path
    d = Path(tempfile.mkdtemp())
    ck = Checkpointer(d)
    ck.save_plan(1, plan, blocking=True)
    pd = d / "step_1" / "plan_plan"
    arrays = dict(np.load(pd / "arrays.npz"))

    # truncated pi: capacity disagrees with the manifest
    trunc = dict(arrays)
    trunc["pi"] = trunc["pi"][:-5]
    np.savez(pd / "arrays.npz", **trunc)
    with pytest.raises(ValueError, match="pi.*capacity"):
        ck.restore_plan()

    # missing BSR payload the manifest promises
    nobsr = {k: v for k, v in arrays.items() if k != "bsr_vals"}
    np.savez(pd / "arrays.npz", **nobsr)
    with pytest.raises(ValueError, match="missing arrays.*bsr_vals"):
        ck.restore_plan()

    # tile tensor reshaped behind the manifest's back
    bad = dict(arrays)
    bad["bsr_vals"] = bad["bsr_vals"][:, :-1]
    np.savez(pd / "arrays.npz", **bad)
    with pytest.raises(ValueError, match="bsr_vals shape"):
        ck.restore_plan()

    # manifest edited to a different layout than the arrays
    np.savez(pd / "arrays.npz", **arrays)
    m = _json.loads((pd / "manifest.json").read_text())
    m["bsr"]["max_nbr"] += 1
    (pd / "manifest.json").write_text(_json.dumps(m))
    with pytest.raises(ValueError, match="does not match the manifest"):
        ck.restore_plan()


def test_restore_plan_mesh_validation(plan):
    ck = Checkpointer(tempfile.mkdtemp())
    ck.save_plan(1, plan, blocking=True)
    with pytest.raises(TypeError, match="Mesh or 'auto'"):
        ck.restore_plan(mesh="bogus")
    with pytest.raises(TypeError, match="Mesh or 'auto'"):
        ck.restore_plan(mesh=3)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    with pytest.raises(ValueError, match="no axis 'model'"):
        ck.restore_plan(mesh=mesh, axis="model")
    sp, _ = ck.restore_plan(mesh=mesh)       # happy path still works
    assert sp.spec.n_dev == jax.device_count()


# ---------------------------------------------------------------------------
# fixed-source (mean-shift) plans
# ---------------------------------------------------------------------------


def test_sources_mode_build_and_refresh(points):
    rng = np.random.default_rng(17)
    src = points
    t = src + 0.05 * rng.standard_normal(src.shape).astype(np.float32)
    plan = api.build_plan(t, k=K, sources=src, bs=16, sb=4, backend="bsr",
                          ell_slack=8)
    assert plan.host.sources is not None
    # pattern is kNN(targets among sources), self NOT excluded
    r2, c2, _ = plan.coo
    assert len(r2) == N * K

    t2 = t.copy()
    mv = rng.choice(N, 12, replace=False)
    t2[mv] = src[(mv + N // 2) % N]
    det = _detected(plan, t2)
    assert len(det) > 0 and set(det) <= set(mv)
    p2 = plan.refresh(t2, policy="patch")
    # migrated rows' neighbors match a direct kNN against the fixed sources
    from repro.core import knn
    idx, _ = knn.knn_graph(jnp.asarray(t2[det]), jnp.asarray(src), K)
    r2, c2, _ = p2.coo
    ro, co = p2.host.pi[r2], p2.host.pi[c2]
    for j, i in enumerate(det):
        assert set(co[ro == i]) == set(np.asarray(idx[j]))


def test_sources_mode_rejects_mismatch(points):
    with pytest.raises(ValueError, match="sources"):
        api.build_plan(points, k=K, sources=points[:-1])
    with pytest.raises(ValueError, match="symmetrize"):
        api.build_plan(points, k=K, sources=points, symmetrize=True)
