"""Checkpointing (async, elastic, GC), fault-tolerance supervisor, and the
deterministic data pipeline."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import Checkpointer
from repro.configs import reduced_config
from repro.data import pipeline
from repro.launch.ft import StepTimeout, Supervisor


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "step": jnp.asarray(7, jnp.int32)}}


def test_ckpt_roundtrip_async(tmp_path):
    ck = Checkpointer(tmp_path)
    t = tree()
    ck.save(3, t)
    ck.wait()
    restored, step = ck.restore(t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_ckpt_gc_keeps_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(), blocking=True)
    assert ck.steps() == [3, 4]


def test_ckpt_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(0, tree(), blocking=True)
    with pytest.raises(ValueError):
        ck.restore({"only": jnp.zeros(3)})


def test_ckpt_elastic_resharding_roundtrip(tmp_path):
    """Restore device_puts with provided shardings (single-device here;
    the mesh case is exercised in test_dist.py subprocesses)."""
    ck = Checkpointer(tmp_path)
    t = tree()
    ck.save(1, t, blocking=True)
    sh = jax.tree.map(lambda _: jax.devices()[0], t)
    restored, _ = ck.restore(t, shardings=sh)
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(t["a"]))


def test_supervisor_restarts_after_failure(tmp_path):
    ck = Checkpointer(tmp_path)
    calls = {"fail": True, "restarts": 0}

    def step_fn(state, step):
        if step == 5 and calls["fail"]:
            calls["fail"] = False
            raise RuntimeError("injected node failure")
        return state + 1

    sup = Supervisor(step_deadline_s=60,
                     on_restart=lambda n: calls.__setitem__("restarts", n))
    out = sup.run(n_steps=10,
                  make_state=lambda: 0,
                  step_fn=step_fn,
                  save=lambda s, st: ck.save(s, jnp.asarray(st),
                                             blocking=True),
                  restore=lambda: (lambda t: (int(t[0]), t[1]))(
                      ck.restore(jnp.asarray(0))),
                  ckpt_every=2)
    assert calls["restarts"] == 1
    assert int(out) == 10       # every step ran exactly once post-resume


def test_supervisor_straggler_deadline():
    sup = Supervisor(step_deadline_s=0.3, max_restarts=0)

    def slow_step(state, step):
        if step == 1:
            time.sleep(1.0)      # straggling step
        return state

    with pytest.raises((StepTimeout, RuntimeError)):
        sup.run(n_steps=5, make_state=lambda: 0, step_fn=slow_step,
                save=lambda s, st: None,
                restore=lambda: (_ for _ in ()).throw(FileNotFoundError()),
                ckpt_every=0)


def test_pipeline_deterministic_and_skippable():
    cfg = reduced_config("qwen2-0.5b")
    a = pipeline.token_batch(cfg, 7, 4, 16)
    b = pipeline.token_batch(cfg, 7, 4, 16)
    c = pipeline.token_batch(cfg, 8, 4, 16)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 16)
    assert (a["tokens"] >= 0).all() and (a["tokens"] < cfg.vocab).all()


def test_pipeline_prefetch_iterator():
    cfg = reduced_config("qwen2-0.5b")
    it = pipeline.token_batches(cfg, 2, 8, start_step=3)
    first = next(it)
    ref = pipeline.token_batch(cfg, 3, 2, 8)
    np.testing.assert_array_equal(np.asarray(first["tokens"]), ref["tokens"])


def test_feature_mixture_is_clustered():
    x = pipeline.feature_mixture(512, 64, n_clusters=8, seed=0)
    assert x.shape == (512, 64)
    # cluster structure: nearest-neighbor distance << random-pair distance
    d_nn = np.sort(((x[:64, None] - x[None, :64]) ** 2).sum(-1), axis=1)[:, 1]
    d_rand = ((x[:64] - x[64:128]) ** 2).sum(-1)
    assert np.median(d_nn) < 0.3 * np.median(d_rand)
