"""Dry-run machinery unit tests: HLO collective parser, sharding fit,
analytic-model self-consistency (no compilation needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_mesh_2x2():
    # single-device "mesh" stand-ins don't work for NamedSharding paths;
    # use abstract mesh for spec fitting (ctor signature varies by version)
    from repro.compat import abstract_mesh
    return abstract_mesh((2, 2), ("data", "model"))


def test_parse_collectives_sections_and_bytes():
    from repro.launch.dryrun import parse_collectives
    hlo = """
HloModule jit_step

%region_1.2 {
  %x = f32[128,256]{1,0} parameter(0)
  %all-reduce.1 = f32[128,256]{1,0} all-reduce(%x), replica_groups={}
  ROOT %r = f32[128,256]{1,0} add(%all-reduce.1, %x)
}

ENTRY %main {
  %p0 = bf16[64]{0} parameter(0)
  %ag = bf16[1024]{0} all-gather(%p0), dimensions={0}
  %a2a = f32[16,8]{1,0} all-to-all(%p0), dimensions={0}
  ROOT %out = f32[16,8]{1,0} copy(%a2a)
}
"""
    out = parse_collectives(hlo)
    # body: one all-reduce of 128*256*4 bytes, weighted 2x
    assert out["body"]["counts"]["all-reduce"] == 1
    assert out["body"]["weighted_bytes"] == 128 * 256 * 4 * 2.0
    # entry: all-gather 1024*2 bytes + all-to-all 16*8*4
    assert out["entry"]["counts"]["all-gather"] == 1
    assert out["entry"]["counts"]["all-to-all"] == 1
    assert out["entry"]["weighted_bytes"] == 1024 * 2 + 16 * 8 * 4


def test_fit_spec_drops_nondividing_and_duplicates():
    from repro.models.sharding import fit_spec
    mesh = make_mesh_2x2()
    # 3 % 2 != 0 -> drop axis from dim 0; the freed axis may then be
    # claimed by a later dim (only surviving axes count as "used")
    s = fit_spec((3, 8), P("data", ("data", "model")), mesh)
    assert s == P(None, ("data", "model"))
    # duplicate use when the first dim keeps the axis -> later dim drops it
    s = fit_spec((2, 8), P("data", ("data", "model")), mesh)
    assert s == P("data", "model")
    # tuple axes: keeps the prefix that divides
    s = fit_spec((4, 6), P(("data", "model"), None), mesh)
    assert s == P(("data", "model"), None)
    s = fit_spec((2, 6), P(("data", "model"), None), mesh)
    assert s == P("data", None)
    # spec longer than rank handled
    s = fit_spec((8,), P("data"), mesh)
    assert s == P("data")


def test_layouts_resolve():
    from repro.models.sharding import LAYOUTS, resolve_spec, set_layout
    mesh = make_mesh_2x2()
    try:
        set_layout("dp_all")
        assert resolve_spec(P("tp"), mesh) == P(None)
        assert resolve_spec(P("dp"), mesh) == P(("data", "model"))
        set_layout("2d")
        assert resolve_spec(P("tp"), mesh) == P("model")
        assert resolve_spec(P("fsdp"), mesh) == P(("data",))
    finally:
        set_layout("2d")


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-0.5b", "train_4k"),
    ("mistral-large-123b", "train_4k"),
    ("llama4-maverick-400b-a17b", "train_4k"),
    ("falcon-mamba-7b", "prefill_32k"),
    ("minicpm3-4b", "decode_32k"),
    ("zamba2-1.2b", "long_500k"),
])
def test_analytic_model_self_consistency(arch, shape):
    from repro.launch.analytic import cell_model, n_active_params, n_params
    m = cell_model(arch, shape)
    assert m.flops > 0 and m.hbm_bytes > 0
    # useful flops never exceed lowered flops
    assert m.model_flops <= m.flops * 1.05, (m.model_flops, m.flops)
    assert n_active_params(
        __import__("repro.configs", fromlist=["get_config"]
                   ).get_config(arch)) <= n_params(
        __import__("repro.configs", fromlist=["get_config"]
                   ).get_config(arch))


def test_analytic_collectives_layout_ordering():
    """dp_all must beat 2d for mistral train (the Cell A hypothesis),
    moe_dp must beat plain EP for llama4 (Cell B)."""
    from repro.launch.analytic import analytic_collectives
    a2d = analytic_collectives("mistral-large-123b", "train_4k")["total"]
    adp = analytic_collectives("mistral-large-123b", "train_4k",
                               layout="dp_all")["total"]
    assert adp < a2d
    lep = analytic_collectives("llama4-maverick-400b-a17b", "train_4k",
                               ep=True)["total"]
    lmd = analytic_collectives("llama4-maverick-400b-a17b", "train_4k",
                               layout="moe_dp", ep=True)["total"]
    assert lmd < lep < analytic_collectives(
        "llama4-maverick-400b-a17b", "train_4k")["total"]


def test_moe_active_params_much_smaller():
    from repro.configs import get_config
    from repro.launch.analytic import n_active_params, n_params
    cfg = get_config("llama4-maverick-400b-a17b")
    assert n_active_params(cfg) < 0.1 * n_params(cfg)
