"""Two-level ELL-BSR storage + multi-level interactions (paper §2.4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import blocksparse, interact
from repro.kernels import ops as kops


def random_coo(rng, n, nnz):
    rows = rng.integers(0, n, nnz)
    cols = rng.integers(0, n, nnz)
    # dedupe to keep the dense comparison simple
    key = rows.astype(np.int64) * n + cols
    _, first = np.unique(key, return_index=True)
    rows, cols = rows[first], cols[first]
    vals = rng.standard_normal(len(rows)).astype(np.float32)
    return rows, cols, vals


@settings(max_examples=12, deadline=None)
@given(n=st.integers(40, 400), bs=st.sampled_from([8, 16, 32]),
       frac=st.floats(0.002, 0.05), seed=st.integers(0, 999))
def test_bsr_roundtrip_and_spmv(n, bs, frac, seed):
    rng = np.random.default_rng(seed)
    rows, cols, vals = random_coo(rng, n, max(int(n * n * frac), 5))
    bsr = blocksparse.build_bsr(rows, cols, vals, n, bs=bs, sb=4)
    dense = np.zeros((n, n), np.float32)
    dense[rows, cols] = vals
    np.testing.assert_allclose(bsr.to_dense(), dense, atol=1e-6)
    x = rng.standard_normal(n).astype(np.float32)
    want = dense @ x
    for path in ("bsr", "bsr_ml"):
        got = np.asarray(interact.spmv(bsr, jnp.asarray(x), path))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_spmv_paths_agree_with_pallas():
    rng = np.random.default_rng(7)
    n = 512
    rows, cols, vals = random_coo(rng, n, 4000)
    bsr = blocksparse.build_bsr(rows, cols, vals, n, bs=32)
    x = rng.standard_normal(n).astype(np.float32)
    y_jax = np.asarray(interact.spmv(bsr, jnp.asarray(x), "bsr"))
    y_pal = np.asarray(kops.bsr_spmv(bsr.vals, bsr.col_idx, jnp.asarray(x), n))
    np.testing.assert_allclose(y_pal, y_jax, rtol=1e-4, atol=1e-4)


def test_spmv_shim_warns_and_delegates_bit_exactly():
    """The deprecated ``interact.spmv`` shim must keep warning AND keep
    returning exactly what the plan path returns — so it cannot silently
    rot while callers migrate (ISSUE 4 satellite)."""
    from repro.api import InteractionPlan
    from repro.core.registry import get_backend

    rng = np.random.default_rng(11)
    n = 256
    rows, cols, vals = random_coo(rng, n, 1500)
    bsr = blocksparse.build_bsr(rows, cols, vals, n, bs=32, sb=4)
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    for path in ("bsr", "bsr_ml"):
        with pytest.warns(DeprecationWarning, match="interact.spmv"):
            y_shim = np.asarray(interact.spmv(bsr, x, path))
        y_plan = np.asarray(get_backend(path)(InteractionPlan.from_bsr(bsr),
                                              x))
        assert np.array_equal(y_shim, y_plan), \
            f"shim diverged from the plan path for {path!r}"


def test_csr_path():
    rng = np.random.default_rng(3)
    n = 200
    rows, cols, vals = random_coo(rng, n, 900)
    dense = np.zeros((n, n), np.float32)
    dense[rows, cols] = vals
    x = rng.standard_normal((n, 2)).astype(np.float32)
    got = np.asarray(interact.spmv_csr(jnp.asarray(vals), jnp.asarray(rows),
                                       jnp.asarray(cols), jnp.asarray(x), n))
    np.testing.assert_allclose(got, dense @ x, rtol=1e-4, atol=1e-4)


def test_tsne_attractive_blockwise_matches_edges():
    """Blockwise-dense value recomputation == per-edge reference."""
    rng = np.random.default_rng(5)
    n, k, d = 96, 6, 2
    p_rows = np.repeat(np.arange(n), k)
    p_cols = rng.integers(0, n, n * k)
    p_vals = rng.random(n * k).astype(np.float32)
    bsr = blocksparse.build_bsr(p_rows, p_cols, p_vals, n, bs=16)
    y = rng.standard_normal((n, d)).astype(np.float32)
    got = np.asarray(interact.tsne_attractive(bsr.vals, bsr.col_idx,
                                              bsr.nbr_mask, jnp.asarray(y), n))
    want = np.zeros((n, d), np.float32)
    for r, c, pv in zip(p_rows, p_cols, p_vals):
        diff = y[r] - y[c]
        q = 1.0 / (1.0 + (diff ** 2).sum())
        want[r] += pv * q * diff
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)


def test_meanshift_step_matches_dense():
    rng = np.random.default_rng(6)
    n, k, d = 64, 8, 3
    src = rng.standard_normal((n, d)).astype(np.float32)
    t = src + 0.1 * rng.standard_normal((n, d)).astype(np.float32)
    w_rows = np.repeat(np.arange(n), k)
    w_cols = rng.integers(0, n, n * k)
    key = w_rows.astype(np.int64) * n + w_cols       # dedupe (i,j) pairs:
    _, first = np.unique(key, return_index=True)     # the 0/1 pattern must
    w_rows, w_cols = w_rows[first], w_cols[first]    # not sum duplicates
    bsr = blocksparse.build_bsr(w_rows, w_cols,
                                np.ones(len(w_rows), np.float32), n, bs=16)
    n_cb = bsr.n_cb
    src_pad = np.zeros((n_cb * bsr.bs, d), np.float32)
    src_pad[:n] = src
    got = np.asarray(interact.meanshift_step(
        bsr.vals, bsr.col_idx, jnp.asarray(src_pad.reshape(n_cb, bsr.bs, d)),
        jnp.asarray(t), 0.5, n))
    pattern = np.zeros((n, n), np.float32)
    pattern[w_rows, w_cols] = 1.0
    w = np.exp(-((t[:, None, :] - src[None]) ** 2).sum(-1) / 0.5) * pattern
    want = (w @ src) / np.maximum(w.sum(1, keepdims=True), 1e-12)
    np.testing.assert_allclose(got, want, rtol=5e-3, atol=5e-3)
