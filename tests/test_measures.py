"""Patch-density measures (paper §2.2–2.3): beta / gamma behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import measures
from repro.kernels import ops as kops


def arrowhead(n=500, b=20, seed=0):
    """Fig. 1a: block arrowhead with full b x b blocks."""
    rng = np.random.default_rng(seed)
    rows, cols = [], []
    nb = n // b
    for k in range(nb):
        r0 = k * b
        for i in range(b):
            for j in range(b):
                rows.append(r0 + i), cols.append(r0 + j)      # diagonal
                if k > 0:
                    rows.append(i), cols.append(r0 + j)        # top stripe
                    rows.append(r0 + i), cols.append(j)        # left stripe
    return np.array(rows), np.array(cols)


@pytest.fixture(scope="module")
def fig1():
    rows, cols = arrowhead()
    n = 500
    rng = np.random.default_rng(1)
    pb = rng.permutation(500 // 20)                # block permutation
    perm_block = np.concatenate([np.arange(20) + 20 * p for p in pb])
    perm_rows = rng.permutation(n)
    perm_cols = rng.permutation(n)
    return n, rows, cols, perm_block, perm_rows, perm_cols


def _apply(perm, idx):
    inv = np.empty_like(perm)
    inv[perm] = np.arange(len(perm))
    return inv[idx]


def test_fig1_beta_ordering(fig1):
    """beta: (a) arrowhead == (b) block-permuted > (c) row-perm > (d) both.

    The principled equivalence of (a) and (b) is exact at the natural
    block size (20 — a block permutation maps 20-tiles onto 20-tiles);
    the max-over-sizes estimate may differ slightly at other tilings."""
    n, rows, cols, pb, pr, pc = fig1
    b_a = measures.beta_estimate(rows, cols, n)
    b_b = measures.beta_estimate(_apply(pb, rows), _apply(pb, cols), n)
    b_c = measures.beta_estimate(_apply(pr, rows), cols, n)
    b_d = measures.beta_estimate(_apply(pr, rows), _apply(pc, cols), n)
    assert b_a["per_block"][20] == pytest.approx(b_b["per_block"][20],
                                                 rel=1e-6)
    assert b_a["beta"] == pytest.approx(b_b["beta"], rel=0.25)
    assert b_a["beta"] > 2 * b_c["beta"] > 2 * b_d["beta"]    # degradation


def test_fig1_gamma_monotone_with_beta(fig1):
    """gamma correlates with beta across the four orderings (paper Fig. 1)."""
    n, rows, cols, pb, pr, pc = fig1
    g = []
    for r, c in [(rows, cols),
                 (_apply(pb, rows), _apply(pb, cols)),
                 (_apply(pr, rows), cols),
                 (_apply(pr, rows), _apply(pc, cols))]:
        g.append(float(measures.gamma_score(jnp.asarray(r), jnp.asarray(c),
                                            10.0, n)))
    assert g[0] == pytest.approx(g[1], rel=0.15)
    assert g[1] > g[2] > g[3]


def test_gamma_hist_matches_exact():
    rng = np.random.default_rng(0)
    rows = jnp.asarray(rng.integers(0, 300, 400))
    cols = jnp.asarray(rng.integers(0, 300, 400))
    exact = float(measures.gamma_exact(rows, cols, 8.0))
    hist = float(measures.gamma_score(rows, cols, 8.0, 300))
    assert hist == pytest.approx(exact, rel=0.05)


def test_gamma_kernel_matches_exact():
    rng = np.random.default_rng(1)
    rows = jnp.asarray(rng.integers(0, 200, 300), jnp.int32)
    cols = jnp.asarray(rng.integers(0, 200, 300), jnp.int32)
    exact = float(measures.gamma_exact(rows, cols, 6.0))
    kern = float(kops.gamma_exact(rows, cols, 6.0, bn=128))
    assert kern == pytest.approx(exact, rel=1e-4)


def test_beta_dense_block_is_high():
    """A single full block has beta = 1 (1 patch, density 1)."""
    b = 32
    rows, cols = np.meshgrid(np.arange(b), np.arange(b), indexing="ij")
    out = measures.beta_estimate(rows.ravel(), cols.ravel(), 256)
    assert out["beta"] == pytest.approx(1.0)


def test_fill_ratio():
    b = 16
    rows, cols = np.meshgrid(np.arange(b), np.arange(b), indexing="ij")
    assert measures.fill_ratio(rows.ravel(), cols.ravel(), 64, 16) == 1.0
