"""Cluster-sparse attention (the paper's technique as an LM backend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clusterkv as ckv
from repro.configs.base import ClusterKVConfig
from repro.models import attention as attn


def _clustered_qkv(key, B=1, Hq=4, Hkv=2, S=256, dh=16, n_clusters=4,
                   contrast=4.0):
    ks = jax.random.split(key, 4)
    cc = jax.random.normal(ks[0], (n_clusters, 1, 1, dh)) * contrast
    asg = jax.random.randint(ks[1], (S,), 0, n_clusters)
    k = (cc[asg].reshape(1, 1, S, dh)
         + 0.2 * jax.random.normal(ks[2], (B, Hkv, S, dh))).astype(jnp.float32)
    q = jnp.repeat(k, Hq // Hkv, axis=1) \
        + 0.05 * jax.random.normal(ks[3], (B, Hq, S, dh))
    v = jax.random.normal(ks[0], (B, Hkv, S, dh))
    return q, k, v


def _dense_ref(q, k, v, causal=True):
    B, Hq, S, dh = q.shape
    g = Hq // k.shape[1]
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    lg = jnp.einsum("bhsd,bhtd->bhst", q, kk) / np.sqrt(dh)
    if causal:
        lg = jnp.where(jnp.tril(jnp.ones((S, S), bool)), lg, -1e30)
    return jnp.einsum("bhst,bhtd->bhsd", jax.nn.softmax(lg, -1), vv)


def test_full_selection_is_exact():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(0))
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=256 // 32, embed_dim=2)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attn.clusterkv_attention(q, k, v, pos, pos, cfg)
    ref = _dense_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_topk_approximation_quality_on_clustered_data():
    """With strongly clustered keys, half the blocks capture most mass."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(1), contrast=6.0)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=5, embed_dim=2)
    out = attn.clusterkv_attention(q, k, v, pos, pos, cfg, causal=False)
    ref = _dense_ref(q, k, v, causal=False)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.3, rel


def test_more_blocks_monotone_better():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(2))
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = _dense_ref(q, k, v, causal=False)
    errs = []
    for nb in (2, 4, 8):
        cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                              blocks_per_query=nb, embed_dim=2)
        out = attn.clusterkv_attention(q, k, v, pos, pos, cfg, causal=False)
        errs.append(float(jnp.linalg.norm(out - ref)))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1e-3  # 8 of 8 blocks = exact


def test_causal_never_attends_future():
    """Probe: values loaded from future positions must have zero weight —
    set future v to huge constants and check output unaffected."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(3))
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=4, embed_dim=2)
    out1 = attn.clusterkv_attention(q, k, v, pos, pos, cfg)
    v_poison = v.at[:, :, S // 2:].add(1e4)
    out2 = attn.clusterkv_attention(q, k, v_poison, pos, pos, cfg)
    np.testing.assert_allclose(np.asarray(out1[:, :, :S // 2 - 32]),
                               np.asarray(out2[:, :, :S // 2 - 32]),
                               rtol=1e-3, atol=1e-3)


def test_decode_full_selection_matches_dense_last_row():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(4))
    S = q.shape[2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), k.shape[:3])
    cfg = ClusterKVConfig(enabled=True, block_k=32,
                          decode_clusters=S // 32)
    qd = q[:, :, -1]
    out = attn.clusterkv_decode(qd, k, v, pos, S - 1, cfg)
    ref = _dense_ref(q, k, v)[:, :, -1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_cluster_perm_groups_clusters():
    """After the paper's reorder, cluster labels are (mostly) contiguous."""
    key = jax.random.PRNGKey(5)
    S, dh = 256, 32
    cc = jax.random.normal(key, (4, dh)) * 8
    asg = jax.random.randint(jax.random.fold_in(key, 1), (S,), 0, 4)
    k = (cc[asg] + 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (S, dh)))[None, None]
    perm = ckv.cluster_perm(k, d=2)
    lab = np.asarray(asg)[np.asarray(perm[0, 0])]
    changes = np.count_nonzero(np.diff(lab))
    assert changes <= 12   # ~3 changes ideal; allow boundary noise


def test_pallas_tile_path_matches_jnp():
    """use_pallas=True (kernel tiles, interpret on CPU) == jnp tile path."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(8), S=128, dh=16)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    base = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                           blocks_per_query=3, embed_dim=2)
    pal = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=3, embed_dim=2, use_pallas=True)
    for causal in (True, False):
        a = attn.clusterkv_attention(q, k, v, pos, pos, base, causal=causal)
        b = attn.clusterkv_attention(q, k, v, pos, pos, pal, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_autotune_adapts_to_clusterability():
    """Tightly clustered keys need few tiles; diffuse keys need many."""
    from repro.core.autotune import coverage_curve, tune_blocks_per_query
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32, embed_dim=2)
    q_t, k_t, _ = _clustered_qkv(jax.random.PRNGKey(11), contrast=10.0)
    q_d, k_d, _ = _clustered_qkv(jax.random.PRNGKey(12), contrast=0.0)
    cfg_t, cov_t = tune_blocks_per_query(q_t, k_t, cfg, 0.9)
    cfg_d, cov_d = tune_blocks_per_query(q_d, k_d, cfg, 0.9)
    assert cfg_t.blocks_per_query < cfg_d.blocks_per_query
    assert cov_t >= 0.9
    # curve is monotone nondecreasing and ends at ~1
    curve = coverage_curve(q_t, k_t, cfg)
    assert float(curve[-1]) == pytest.approx(1.0, abs=1e-3)
    assert bool(jnp.all(jnp.diff(curve) >= -1e-6))
