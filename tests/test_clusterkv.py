"""Cluster-sparse attention (the paper's technique as an LM backend)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import clusterkv as ckv
from repro.configs.base import ClusterKVConfig
from repro.models import attention as attn


def _clustered_qkv(key, B=1, Hq=4, Hkv=2, S=256, dh=16, n_clusters=4,
                   contrast=4.0):
    ks = jax.random.split(key, 4)
    cc = jax.random.normal(ks[0], (n_clusters, 1, 1, dh)) * contrast
    asg = jax.random.randint(ks[1], (S,), 0, n_clusters)
    k = (cc[asg].reshape(1, 1, S, dh)
         + 0.2 * jax.random.normal(ks[2], (B, Hkv, S, dh))).astype(jnp.float32)
    q = jnp.repeat(k, Hq // Hkv, axis=1) \
        + 0.05 * jax.random.normal(ks[3], (B, Hq, S, dh))
    v = jax.random.normal(ks[0], (B, Hkv, S, dh))
    return q, k, v


def _dense_ref(q, k, v, causal=True):
    B, Hq, S, dh = q.shape
    g = Hq // k.shape[1]
    kk = jnp.repeat(k, g, axis=1)
    vv = jnp.repeat(v, g, axis=1)
    lg = jnp.einsum("bhsd,bhtd->bhst", q, kk) / np.sqrt(dh)
    if causal:
        lg = jnp.where(jnp.tril(jnp.ones((S, S), bool)), lg, -1e30)
    return jnp.einsum("bhst,bhtd->bhsd", jax.nn.softmax(lg, -1), vv)


def test_full_selection_is_exact():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(0))
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=256 // 32, embed_dim=2)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    out = attn.clusterkv_attention(q, k, v, pos, pos, cfg)
    ref = _dense_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_topk_approximation_quality_on_clustered_data():
    """With strongly clustered keys, half the blocks capture most mass."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(1), contrast=6.0)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=5, embed_dim=2)
    out = attn.clusterkv_attention(q, k, v, pos, pos, cfg, causal=False)
    ref = _dense_ref(q, k, v, causal=False)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.3, rel


def test_more_blocks_monotone_better():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(2))
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    ref = _dense_ref(q, k, v, causal=False)
    errs = []
    for nb in (2, 4, 8):
        cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                              blocks_per_query=nb, embed_dim=2)
        out = attn.clusterkv_attention(q, k, v, pos, pos, cfg, causal=False)
        errs.append(float(jnp.linalg.norm(out - ref)))
    assert errs[0] >= errs[1] >= errs[2]
    assert errs[2] < 1e-3  # 8 of 8 blocks = exact


def test_causal_never_attends_future():
    """Probe: values loaded from future positions must have zero weight —
    set future v to huge constants and check output unaffected."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(3))
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=4, embed_dim=2)
    out1 = attn.clusterkv_attention(q, k, v, pos, pos, cfg)
    v_poison = v.at[:, :, S // 2:].add(1e4)
    out2 = attn.clusterkv_attention(q, k, v_poison, pos, pos, cfg)
    np.testing.assert_allclose(np.asarray(out1[:, :, :S // 2 - 32]),
                               np.asarray(out2[:, :, :S // 2 - 32]),
                               rtol=1e-3, atol=1e-3)


def test_decode_full_selection_matches_dense_last_row():
    q, k, v = _clustered_qkv(jax.random.PRNGKey(4))
    S = q.shape[2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), k.shape[:3])
    cfg = ClusterKVConfig(enabled=True, block_k=32,
                          decode_clusters=S // 32)
    qd = q[:, :, -1]
    out = attn.clusterkv_decode(qd, k, v, pos, S - 1, cfg)
    ref = _dense_ref(q, k, v)[:, :, -1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_cluster_perm_groups_clusters():
    """After the paper's reorder, cluster labels are (mostly) contiguous."""
    key = jax.random.PRNGKey(5)
    S, dh = 256, 32
    cc = jax.random.normal(key, (4, dh)) * 8
    asg = jax.random.randint(jax.random.fold_in(key, 1), (S,), 0, 4)
    k = (cc[asg] + 0.1 * jax.random.normal(jax.random.fold_in(key, 2),
                                           (S, dh)))[None, None]
    perm = ckv.cluster_perm(k, d=2)
    lab = np.asarray(asg)[np.asarray(perm[0, 0])]
    changes = np.count_nonzero(np.diff(lab))
    assert changes <= 12   # ~3 changes ideal; allow boundary noise


def test_pallas_tile_path_matches_jnp():
    """use_pallas=True (kernel tiles, interpret on CPU) == jnp tile path."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(8), S=128, dh=16)
    S = q.shape[2]
    pos = jnp.arange(S, dtype=jnp.int32)
    base = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                           blocks_per_query=3, embed_dim=2)
    pal = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=3, embed_dim=2, use_pallas=True)
    for causal in (True, False):
        a = attn.clusterkv_attention(q, k, v, pos, pos, base, causal=causal)
        b = attn.clusterkv_attention(q, k, v, pos, pos, pal, causal=causal)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_autotune_adapts_to_clusterability():
    """Tightly clustered keys need few tiles; diffuse keys need many."""
    from repro.core.autotune import coverage_curve, tune_blocks_per_query
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32, embed_dim=2)
    q_t, k_t, _ = _clustered_qkv(jax.random.PRNGKey(11), contrast=10.0)
    q_d, k_d, _ = _clustered_qkv(jax.random.PRNGKey(12), contrast=0.0)
    cfg_t, cov_t = tune_blocks_per_query(q_t, k_t, cfg, 0.9)
    cfg_d, cov_d = tune_blocks_per_query(q_d, k_d, cfg, 0.9)
    assert cfg_t.blocks_per_query < cfg_d.blocks_per_query
    assert cov_t >= 0.9
    # curve is monotone nondecreasing and ends at ~1
    curve = coverage_curve(q_t, k_t, cfg)
    assert float(curve[-1]) == pytest.approx(1.0, abs=1e-3)
    assert bool(jnp.all(jnp.diff(curve) >= -1e-6))


# ---------------------------------------------------------------------------
# direct core/clusterkv tests (no models.attention wrapper in the loop)
# ---------------------------------------------------------------------------


def _direct_pipeline(q, k, v, n_sel, bq=32, bk=32, causal=True):
    """Drive the module's own stages: perm -> permute_kv -> centroids ->
    select_blocks -> sparse_block_attention."""
    B, Hq, S, dh = q.shape
    Hkv = k.shape[1]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, Hkv, S))
    qpos = jnp.arange(S, dtype=jnp.int32)
    perm = ckv.cluster_perm(k, d=2)
    k_s, v_s, pos_s = ckv.permute_kv(k, v, pos, perm)
    cent = ckv.block_centroids(k_s, bk)
    nqb, nkb = S // bq, S // bk
    kpmin = pos_s.reshape(B, Hkv, nkb, bk).min(-1)
    kpmax = pos_s.reshape(B, Hkv, nkb, bk).max(-1)
    qpmin = qpos.reshape(nqb, bq).min(-1)
    qpmax = qpos.reshape(nqb, bq).max(-1)
    qc = q.reshape(B, Hkv, Hq // Hkv, nqb, bq, dh).mean(axis=(2, 4))
    idx = ckv.select_blocks(qc.astype(jnp.float32),
                            cent.astype(jnp.float32), kpmin, kpmax,
                            qpmin, qpmax, n_sel, bq, causal=causal)
    out = ckv.sparse_block_attention(q, k_s, v_s, pos_s, qpos, idx,
                                     bq, bk, causal=causal)
    return out, cent, idx


def test_sparse_block_attention_full_selection_matches_dense_direct():
    """sparse_block_attention itself (not the attention wrapper) is exact
    against dense attention when every key tile is selected."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(20), S=128, dh=16)
    for causal in (True, False):
        out, _, _ = _direct_pipeline(q, k, v, n_sel=128 // 32,
                                     causal=causal)
        ref = _dense_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)


def test_sparse_block_attention_topk_close_on_clustered_direct():
    """Non-causal top-k with cluster-coherent query tiles (queries sorted
    by the key permutation, like the wrapper's pi_t sort): a third of the
    tiles capture most of the mass on clustered data."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(21), S=256, dh=16,
                             contrast=8.0)
    g = q.shape[1] // k.shape[1]
    perm = ckv.cluster_perm(k, d=2)
    q_s = jnp.take_along_axis(q, jnp.repeat(perm, g, axis=1)[..., None],
                              axis=-2)
    out, _, _ = _direct_pipeline(q_s, k, v, n_sel=5, causal=False)
    ref = _dense_ref(q_s, k, v, causal=False)
    rel = float(jnp.linalg.norm(out - ref) / jnp.linalg.norm(ref))
    assert rel < 0.4, rel              # unsorted queries give rel > 1.0


def test_decode_select_agrees_with_select_blocks():
    """The decode-time selector scores the same centroids as the prefill
    selector: for a single query tile with a constant query (so the tile
    centroid IS the decode query) and causality off, both must pick the
    same key-tile set."""
    key = jax.random.PRNGKey(22)
    B, Hq, Hkv, S, dh, bk = 1, 4, 2, 256, 16, 32
    k = jax.random.normal(key, (B, Hkv, S, dh))
    qvec = jax.random.normal(jax.random.fold_in(key, 1), (B, Hq, dh))
    nkb = S // bk
    n_sel = 4
    cent = ckv.block_centroids(k, bk)                    # natural order
    # prefill selector: one query tile whose every row is qvec
    q_cent = qvec.reshape(B, Hkv, Hq // Hkv, dh).mean(axis=2)[:, :, None]
    zeros = jnp.zeros((B, Hkv, nkb), jnp.int32)
    ones_q = jnp.zeros((1,), jnp.int32)
    idx_prefill = ckv.select_blocks(q_cent, cent.astype(jnp.float32),
                                    zeros, zeros, ones_q, ones_q,
                                    n_sel, bq=1, causal=False)
    idx_decode = ckv.decode_select(qvec, cent.astype(jnp.float32), n_sel)
    got = np.sort(np.asarray(idx_decode), axis=-1)
    want = np.sort(np.asarray(idx_prefill[:, :, 0]), axis=-1)
    np.testing.assert_array_equal(got, want)


def test_decode_attend_full_selection_matches_dense_direct():
    """decode_attend over every tile == the dense last-row reference,
    driven directly (no attention-module wrapper)."""
    q, k, v = _clustered_qkv(jax.random.PRNGKey(23), S=128, dh=16)
    B, Hq, S, dh = q.shape
    Hkv, bk = k.shape[1], 32
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, Hkv, S))
    cent = ckv.block_centroids(k, bk)
    qd = q[:, :, -1]
    idx = ckv.decode_select(qd.astype(jnp.float32),
                            cent.astype(jnp.float32), S // bk)
    out = ckv.decode_attend(qd, k, v, pos, S - 1, idx, bk)
    ref = _dense_ref(q, k, v)[:, :, -1]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_select_blocks_never_selects_pure_future_tiles():
    """Causal selection: a key tile strictly in the future of the whole
    query tile must not appear among the selected indices (its score is
    NEG_INF, and there are enough valid tiles to fill n_sel)."""
    key = jax.random.PRNGKey(24)
    B, Hkv, S, dh, bk, bq = 1, 2, 256, 16, 32, 32
    nkb, nqb = S // bk, S // bq
    cent = jax.random.normal(key, (B, Hkv, nkb, dh))
    qc = jax.random.normal(jax.random.fold_in(key, 1), (B, Hkv, nqb, dh))
    # identity layout: tile t holds positions [t*bk, (t+1)*bk)
    kpmin = jnp.broadcast_to(jnp.arange(nkb) * bk, (B, Hkv, nkb))
    kpmax = kpmin + bk - 1
    qpos = jnp.arange(S)
    qpmin = qpos.reshape(nqb, bq).min(-1)
    qpmax = qpos.reshape(nqb, bq).max(-1)
    n_sel = 4
    idx = ckv.select_blocks(qc, cent, kpmin, kpmax, qpmin, qpmax,
                            n_sel=n_sel, bq=bq, causal=True,
                            local_window=bk)
    idx = np.asarray(idx)
    for qt in range(nqb):
        if qt + 1 >= n_sel:
            # enough valid (non-future) tiles to fill the selection: no
            # selected tile may lie strictly in this query tile's future
            # (when fewer exist, top_k fills from NEG_INF ties and the
            # kernel's per-element position mask zeroes them instead)
            assert (idx[:, :, qt] <= qt).all(), (qt, idx[:, :, qt])
        # the boosted local window (this tile + the one before) always
        # makes the selection — recency is never dropped
        assert (idx[:, :, qt] == qt).any(axis=-1).all()
        if qt >= 1:
            assert (idx[:, :, qt] == qt - 1).any(axis=-1).all()


def test_masked_softmax_guard():
    """Bitwise jax.nn.softmax while any column is live; exact zeros (not
    a uniform garbage row) when the whole selection is masked."""
    rng = np.random.default_rng(21)
    logit = jnp.asarray(rng.standard_normal((4, 7)), jnp.float32)
    mask = jnp.asarray(rng.random((4, 7)) < 0.5)
    mask = mask.at[:, 0].set(True)                 # >= 1 live per row
    got = jax.jit(ckv.masked_softmax)(logit, mask)
    want = jax.jit(lambda x: jax.nn.softmax(x, axis=-1))(
        jnp.where(mask, logit, ckv.NEG_INF))
    assert bool(jnp.array_equal(got, want))
    dead = jax.jit(ckv.masked_softmax)(logit, jnp.zeros((4, 7), bool))
    assert bool(jnp.all(dead == 0.0))


def test_decode_attend_empty_selection_is_zero():
    """Early-position decode whose selected tiles are ALL unfilled or
    future must return exact zeros — previously the all-masked softmax
    weighted the garbage rows uniformly."""
    rng = np.random.default_rng(22)
    B, hq, hkv, S, dh, bk = 1, 2, 1, 64, 16, 16
    q = jnp.asarray(rng.standard_normal((B, hq, dh)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, hkv, S, dh)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, hkv, S, dh)), jnp.float32)
    pos = jnp.full((B, hkv, S), np.iinfo(np.int32).max, jnp.int32)
    idx = jnp.zeros((B, hkv, 2), jnp.int32)
    out = ckv.decode_attend(q, k, v, pos, 0, idx, bk)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert bool(jnp.all(out == 0.0))
