"""Optimizers + train-step factory."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import reduced_config
from repro.data import pipeline
from repro.models import model_api
from repro.optim.optimizers import (Adafactor, AdamW, make_optimizer,
                                    warmup_cosine)
from repro.train import trainer


def quad_loss(p):
    return jnp.sum((p["w"] - 3.0) ** 2) + jnp.sum((p["b"] + 1.0) ** 2)


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizer_converges_on_quadratic(name):
    opt = make_optimizer(name, lr=0.1, warmup=5, total=200)
    params = {"w": jnp.ones((4, 8)), "b": jnp.zeros((8,))}
    state = opt.init(params)
    for _ in range(150):
        g = jax.grad(quad_loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(quad_loss(params)) < 0.5


def test_adafactor_state_is_factored():
    opt = make_optimizer("adafactor")
    params = {"w": jnp.ones((64, 128))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state["v"]))
    assert n_state == 64 + 128            # vr + vc, not 64*128


def test_schedule_warmup_and_decay():
    lr = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(lr(jnp.asarray(0))) < 2e-4
    assert float(lr(jnp.asarray(10))) == pytest.approx(1e-3, rel=0.1)
    assert float(lr(jnp.asarray(99))) < 3e-4


def test_train_step_decreases_loss():
    cfg = reduced_config("qwen2-0.5b")
    opt = make_optimizer("adamw", lr=2e-3, warmup=2, total=40)
    step_fn, _ = trainer.make_train_step(cfg, None, "flash", optimizer=opt)
    step = jax.jit(step_fn, donate_argnums=(0, 1))
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    losses = []
    for s in range(25):
        batch = {k: jnp.asarray(v) for k, v in
                 pipeline.token_batch(cfg, s % 2, 4, 64).items()}
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatch_accumulation_matches_full_batch():
    cfg = reduced_config("qwen2-0.5b").with_(dtype="float32")
    opt = make_optimizer("adamw")
    full, _ = trainer.make_train_step(cfg, None, "flash", microbatch=1,
                                      optimizer=opt)
    micro, _ = trainer.make_train_step(cfg, None, "flash", microbatch=4,
                                       optimizer=opt)
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.token_batch(cfg, 0, 8, 32).items()}
    p1, _, m1 = jax.jit(full)(params, state, batch)
    p2, _, m2 = jax.jit(micro)(params, state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = max(float(jnp.abs(a - b).max())
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-3


def test_compressed_grad_accumulation_close_to_exact():
    cfg = reduced_config("qwen2-0.5b").with_(dtype="float32")
    opt = make_optimizer("adamw")
    exact, _ = trainer.make_train_step(cfg, None, "flash", microbatch=4,
                                       optimizer=opt)
    comp, _ = trainer.make_train_step(cfg, None, "flash", microbatch=4,
                                      compress_grads=True, optimizer=opt)
    params, _ = model_api.init(cfg, jax.random.PRNGKey(0))
    state = opt.init(params)
    batch = {k: jnp.asarray(v) for k, v in
             pipeline.token_batch(cfg, 0, 8, 32).items()}
    p1, _, _ = jax.jit(exact)(params, state, batch)
    p2, _, _ = jax.jit(comp)(params, state, batch)
    rel = max(float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
              for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert rel < 0.05      # bf16 accumulation with error feedback stays close
