"""SSM math: chunked scans vs naive sequential recurrences (the ground
truth the chunked/SSD forms must reproduce exactly)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.models.mamba import selective_scan, ssd, conv1d_apply, conv1d_init


def naive_mamba1(xc, dt, a_mat, bc, cc):
    b, s, di = xc.shape
    n = a_mat.shape[-1]
    h = np.zeros((b, di, n), np.float64)
    ys = []
    for t in range(s):
        da = np.exp(dt[:, t, :, None] * a_mat[None])
        dbx = dt[:, t, :, None] * bc[:, t, None, :] * xc[:, t, :, None]
        h = da * h + dbx
        ys.append(np.einsum("bdn,bn->bd", h, cc[:, t]))
    return np.stack(ys, 1), h


@settings(max_examples=8, deadline=None)
@given(s=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_selective_scan_matches_naive(s, chunk, seed):
    rng = np.random.default_rng(seed)
    b, di, n = 2, 6, 4
    xc = rng.standard_normal((b, s, di)).astype(np.float64)
    dt = (0.1 + rng.random((b, s, di))).astype(np.float64)
    a_mat = -np.exp(rng.standard_normal((di, n))).astype(np.float64)
    bc = rng.standard_normal((b, s, n)).astype(np.float64)
    cc = rng.standard_normal((b, s, n)).astype(np.float64)
    want_y, want_h = naive_mamba1(xc, dt, a_mat, bc, cc)
    got_y, got_h = selective_scan(
        jnp.asarray(xc, jnp.float32), jnp.asarray(dt, jnp.float32),
        jnp.asarray(a_mat, jnp.float32), jnp.asarray(bc, jnp.float32),
        jnp.asarray(cc, jnp.float32), chunk)
    np.testing.assert_allclose(np.asarray(got_y), want_y, rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(got_h), want_h, rtol=2e-3,
                               atol=2e-3)


def naive_mamba2(x, dt, a_head, bmat, cmat):
    b, s, h, p = x.shape
    n = bmat.shape[-1]
    st_ = np.zeros((b, h, p, n), np.float64)
    ys = []
    for t in range(s):
        dec = np.exp(dt[:, t] * a_head[None])            # (b,h)
        xbar = x[:, t] * dt[:, t][..., None]             # (b,h,p)
        st_ = (st_ * dec[..., None, None]
               + np.einsum("bn,bhp->bhpn", bmat[:, t], xbar))
        ys.append(np.einsum("bhpn,bn->bhp", st_, cmat[:, t]))
    return np.stack(ys, 1), st_


@settings(max_examples=8, deadline=None)
@given(s=st.integers(3, 40), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_ssd_matches_naive(s, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, p, n = 2, 3, 4, 5
    x = rng.standard_normal((b, s, h, p)).astype(np.float64)
    dt = (0.1 + rng.random((b, s, h))).astype(np.float64)
    a_head = -np.exp(rng.standard_normal(h)).astype(np.float64)
    bmat = rng.standard_normal((b, s, n)).astype(np.float64)
    cmat = rng.standard_normal((b, s, n)).astype(np.float64)
    want_y, want_h = naive_mamba2(x, dt, a_head, bmat, cmat)
    got_y, got_h = ssd(jnp.asarray(x, jnp.float32),
                       jnp.asarray(dt, jnp.float32),
                       jnp.asarray(a_head, jnp.float32),
                       jnp.asarray(bmat, jnp.float32),
                       jnp.asarray(cmat, jnp.float32), chunk)
    np.testing.assert_allclose(np.asarray(got_y), want_y, rtol=3e-3,
                               atol=3e-3)
    np.testing.assert_allclose(np.asarray(got_h), want_h, rtol=3e-3,
                               atol=3e-3)


def test_conv1d_causal():
    """y_t depends only on x_{t-w+1..t}."""
    key = jax.random.PRNGKey(0)
    p, _ = conv1d_init(key, 4, 3)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 10, 4))
    y1 = conv1d_apply(p, x)
    x2 = x.at[:, 7:, :].add(100.0)       # poison the future
    y2 = conv1d_apply(p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :7]), np.asarray(y2[:, :7]),
                               rtol=1e-5)
    assert float(jnp.abs(y1[:, 7:] - y2[:, 7:]).max()) > 1.0
