"""Morton codes + adaptive 2^d tree (paper §2.4), incl. hypothesis property
tests on the system's ordering invariants."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import hierarchy


@pytest.mark.parametrize("d", [1, 2, 3])
def test_morton_order_is_permutation(d):
    rng = np.random.default_rng(0)
    y = jnp.asarray(rng.standard_normal((257, d)).astype(np.float32))
    perm = np.asarray(hierarchy.morton_order(y))
    assert sorted(perm.tolist()) == list(range(257))


def test_morton_1d_is_sort():
    rng = np.random.default_rng(1)
    y = rng.standard_normal((100, 1)).astype(np.float32)
    perm = np.asarray(hierarchy.morton_order(jnp.asarray(y)))
    assert np.all(np.diff(y[perm, 0]) >= -1e-6)


def test_morton_locality_2d():
    """Points in the same quadrant stay contiguous (Z-curve property)."""
    pts = np.array([[x, ybit] for x in (0.1, 0.9) for ybit in (0.1, 0.9)]
                   * 8, np.float32)
    pts += np.random.default_rng(0).normal(0, 0.01, pts.shape).astype(np.float32)
    perm = np.asarray(hierarchy.morton_order(jnp.asarray(pts)))
    quad = (pts[perm, 0] > 0.5).astype(int) * 2 + (pts[perm, 1] > 0.5)
    # each quadrant's points must be contiguous in the ordering
    changes = np.count_nonzero(np.diff(quad))
    assert changes == 3


@settings(max_examples=25, deadline=None)
@given(n=st.integers(10, 200), d=st.integers(1, 3),
       leaf=st.integers(4, 64), seed=st.integers(0, 10**6))
def test_tree_invariants(n, d, leaf, seed):
    rng = np.random.default_rng(seed)
    y = rng.standard_normal((n, d)).astype(np.float32)
    tree = hierarchy.build_tree(y, leaf_size=leaf)
    assert sorted(tree.perm.tolist()) == list(range(n))
    for lvl in tree.levels:
        assert lvl[0] == 0 and lvl[-1] == n
        assert np.all(np.diff(lvl) > 0)
    # levels are nested refinements
    for a, b in zip(tree.levels[:-1], tree.levels[1:]):
        assert set(a.tolist()) <= set(b.tolist())


def test_tree_adaptive_leaf_bound():
    """Clusters split until <= leaf_size unless at max quantization depth."""
    rng = np.random.default_rng(0)
    y = rng.standard_normal((1000, 3)).astype(np.float32)
    tree = hierarchy.build_tree(y, leaf_size=32)
    sizes = np.diff(tree.levels[-1])
    assert sizes.max() <= 32


def test_tree_adaptivity_sparse_regions_stay_coarse():
    """A tight cluster + far sparse points: sparse side should not be
    over-split (adaptive stop)."""
    rng = np.random.default_rng(0)
    tight = rng.normal(0, 0.001, (256, 2))
    sparse = rng.uniform(5, 10, (8, 2))
    y = np.concatenate([tight, sparse]).astype(np.float32)
    tree = hierarchy.build_tree(y, leaf_size=16)
    # the 8 sparse points end in few leaves; tight cluster in many
    last = tree.levels[-1]
    sizes = np.diff(last)
    assert len(sizes) >= 256 // 16
