import numpy as np
import pytest

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
# must see 1 device. Mesh-dependent tests run in subprocesses (test_dist.py).


@pytest.fixture
def rng():
    return np.random.default_rng(0)
