"""PlanBatch: spec/data split, vmap-able batched plans (ISSUE 5 tentpole).

Covers the split itself (PlanSpec hashable + PlanData pytree +
from_spec_data view bit-exactness), batched matvec equivalence against
single plans (uniform and ragged member sizes), the one-compilation
contract (trace-count via a counting backend, both the vmap and the scan
kernel), the shared autotune decision with structural memoization, lockstep
streaming through the PR 4 tiers with per-plan escalation, checkpoint
round-trips, and the descriptive TypeError a vmapped single plan raises.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.core import autotune, registry
from repro.data.pipeline import feature_mixture

B, N, D, K = 4, 256, 32, 8


def _points(n=N, b=B, seed0=0):
    return [feature_mixture(n, D, n_clusters=8, seed=seed0 + s)
            for s in range(b)]


@pytest.fixture(scope="module")
def batch():
    return api.build_plan_batch(_points(), k=K, bs=16, sb=4, backend="bsr")


@pytest.fixture(scope="module")
def charges():
    rng = np.random.default_rng(0)
    return [rng.standard_normal(N).astype(np.float32) for _ in range(B)]


# -- spec/data split --------------------------------------------------------


def test_spec_is_hashable_and_shared():
    x = _points(b=1)[0]
    rng = np.random.default_rng(0)
    p0 = api.build_plan(x, k=K, bs=16, sb=4, backend="bsr")
    p1 = api.build_plan(x, k=K, bs=16, sb=4, backend="bsr",
                        values=lambda r, c, d2: rng.random(len(r)))
    s0, s1 = p0.spec, p1.spec          # same structure, different data
    assert hash(s0) == hash(s1) and s0 == s1
    assert s0.shape_key == (N, 16, 4, N // 16, N // 16, s0.max_nbr)
    # a different layout is a different spec
    p2 = api.build_plan(x, k=K, bs=32, sb=4, backend="bsr")
    assert p2.spec != s0
    # batch members are padded onto ONE spec even from different clouds
    pb = api.build_plan_batch(_points(b=2), k=K, bs=16, sb=4,
                              backend="bsr")
    assert pb.member(0).spec == pb.member(1).spec == pb.spec


def test_data_is_a_pytree_of_arrays():
    p = api.build_plan(_points(b=1)[0], k=K, bs=16, sb=4, backend="bsr")
    leaves, treedef = jax.tree_util.tree_flatten(p.data)
    assert len(leaves) == 5            # pi, inv, col_idx, nbr_mask, vals
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, api.PlanData)
    np.testing.assert_array_equal(np.asarray(back.pi), np.asarray(p.pi))


def test_from_spec_data_view_is_bit_exact():
    p = api.build_plan(_points(b=1)[0], k=K, bs=16, sb=4, backend="bsr")
    view = api.InteractionPlan.from_spec_data(p.spec, p.data,
                                              fill=p.bsr.fill)
    x = jnp.asarray(np.random.default_rng(1).standard_normal(N), jnp.float32)
    np.testing.assert_array_equal(np.asarray(view.apply(x)),
                                  np.asarray(p.apply(x)))
    assert view.spec == p.spec


# -- batched interaction ----------------------------------------------------


def test_batched_matvec_matches_single_plans(batch, charges):
    xs = batch.pad_charges(charges)
    y = np.asarray(batch.matvec(xs))
    for i, x in enumerate(_points()):
        p = api.build_plan(x, k=K, bs=16, sb=4, backend="bsr")
        yi = np.asarray(p.matvec(jnp.asarray(charges[i])))
        np.testing.assert_allclose(y[i, :N], yi, rtol=1e-4, atol=1e-4)


def test_batched_apply_matches_members(batch, charges):
    """The batched kernel (transpose-free tile contraction) agrees with
    each member's single-plan path to float associativity."""
    xs = batch.pad_charges(charges)
    ya = np.asarray(batch.apply(xs))
    for i in range(B):
        m = batch.member(i)
        np.testing.assert_allclose(ya[i], np.asarray(m.apply(xs[i])),
                                   rtol=1e-5, atol=1e-5)


def test_member_view_is_a_working_single_plan(batch, charges):
    m = batch.member(1)
    assert isinstance(m, api.InteractionPlan)
    assert m.spec == batch.spec
    y = m.matvec(jnp.asarray(np.pad(charges[1],
                                    (0, batch.capacity - N))))
    assert y.shape == (batch.capacity,)


def test_ragged_members_pad_to_pow2_capacity():
    sizes = [100, 200, 300]
    xs = [feature_mixture(n, D, n_clusters=4, seed=s)
          for s, n in enumerate(sizes)]
    pb = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr")
    assert pb.capacity == 512                      # pow2-quantized max n
    assert (pb.n_alive == np.array(sizes)).all()
    rng = np.random.default_rng(2)
    ch = [rng.standard_normal(n).astype(np.float32) for n in sizes]
    y = np.asarray(pb.matvec(pb.pad_charges(ch)))
    for i, n in enumerate(sizes):
        p = api.build_plan(xs[i], k=K, bs=16, sb=4, backend="bsr")
        np.testing.assert_allclose(
            y[i, :n], np.asarray(p.matvec(jnp.asarray(ch[i]))),
            rtol=1e-4, atol=1e-4)
        assert not np.asarray(y[i, n:]).any()      # dead capacity is zero


def test_matvec_multifeature_charges(batch):
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.standard_normal((B, batch.capacity, 3)),
                     jnp.float32)
    y = np.asarray(batch.matvec(xs))
    for i in range(B):
        np.testing.assert_allclose(
            y[i], np.asarray(batch.member(i).matvec(xs[i])),
            rtol=1e-5, atol=1e-5)


def test_charge_shape_errors(batch):
    with pytest.raises(ValueError, match="batched charges"):
        batch.matvec(jnp.zeros((B + 1, batch.capacity)))
    with pytest.raises(ValueError, match="batched charges"):
        batch.matvec(jnp.zeros((B, batch.capacity - 1)))
    with pytest.raises(ValueError, match="charge arrays"):
        batch.pad_charges([np.zeros(N)] * (B + 1))


def test_unbatchable_backends_rejected(batch):
    for name in ("csr", "dist"):
        with pytest.raises(ValueError, match="cannot run batched"):
            batch.matvec(jnp.zeros((B, batch.capacity)), backend=name)


# -- one compilation for the whole batch ------------------------------------


def test_single_trace_for_whole_batch(batch, charges):
    """The acceptance contract: vmapping/scanning over PlanBatch.matvec
    compiles exactly once however many plans ride the batch."""
    xs = batch.pad_charges(charges)
    calls = []

    @api.register_backend("trace_counter")
    def _counting(p, x, **kw):
        calls.append(1)                 # runs at trace time only
        return api.get_backend("bsr")(p, x)

    try:
        batch.matvec(xs, backend="trace_counter")
        assert len(calls) == 1, f"vmap kernel traced {len(calls)}x for " \
                                f"a batch of {batch.batch}"
        batch.matvec(xs, backend="trace_counter")
        assert len(calls) == 1          # second call: compiled cache hit
        batch.matvec(xs, backend="trace_counter", serial=True)
        assert len(calls) == 2          # lax.scan body traced once too
        batch.matvec(xs, backend="trace_counter", serial=True)
        assert len(calls) == 2
    finally:
        registry._BACKENDS.pop("trace_counter", None)


def test_vmap_and_scan_kernels_agree(batch, charges):
    xs = batch.pad_charges(charges)
    np.testing.assert_allclose(np.asarray(batch.matvec(xs)),
                               np.asarray(batch.matvec(xs, serial=True)),
                               rtol=1e-5, atol=1e-5)


# -- single-plan vmap: descriptive error ------------------------------------


def test_single_plan_under_vmap_raises_typeerror():
    """Regression: a vmapped InteractionPlan used to die in an opaque
    tracer/shape error; now it names the supported path."""
    p = api.build_plan(_points(b=1)[0], k=K, bs=16, sb=4, backend="bsr")
    fake = jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (2,) + a.shape), p)
    x = jnp.zeros(N, jnp.float32)
    with pytest.raises(TypeError, match="PlanBatch"):
        jax.vmap(lambda pp: pp.matvec(x))(fake)
    with pytest.raises(TypeError, match="build_plan_batch"):
        jax.vmap(lambda pp: pp.apply(x))(fake)


def test_vmap_over_charges_still_works():
    """Only mapping the *plan* is unsupported; charge-batched vmap of a
    closed-over plan keeps working."""
    p = api.build_plan(_points(b=1)[0], k=K, bs=16, sb=4, backend="bsr")
    xs = jnp.asarray(np.random.default_rng(4).standard_normal((3, N)),
                     jnp.float32)
    y = np.asarray(jax.vmap(p.matvec)(xs))
    np.testing.assert_allclose(y[0], np.asarray(p.matvec(xs[0])),
                               rtol=1e-5, atol=1e-5)


# -- shared autotune --------------------------------------------------------


def test_auto_backend_shared_and_memoized(monkeypatch):
    autotune.clear_tune_memo()
    probes = []
    real = autotune.probe_backends
    monkeypatch.setattr(autotune, "probe_backends",
                        lambda *a, **k: probes.append(1) or real(*a, **k))
    xs = _points(b=3, seed0=20)
    pb = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="auto")
    x = jnp.asarray(np.random.default_rng(5).standard_normal(
        (3, pb.capacity)), jnp.float32)
    name = pb.resolve_backend(x=x)
    assert name in ("bsr", "bsr_ml", "pallas")
    assert pb.tuned[1] == name           # one shared decision
    # a spec-identical batch answers from the memo without re-probing
    n_memo = len(autotune._TUNE_MEMO)
    pb2 = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="auto")
    assert pb2.resolve_backend(x=x) == name
    assert len(autotune._TUNE_MEMO) == n_memo
    y = np.asarray(pb.matvec(x))
    np.testing.assert_allclose(
        y[0, :N], np.asarray(pb.member(0).matvec(x[0])[:N]),
        rtol=1e-4, atol=1e-4)


def test_single_plan_tune_memoized(monkeypatch):
    if jax.device_count() >= 2:
        pytest.skip("single-device memo path (multi-device decisions "
                    "depend on block structure, not shapes)")
    autotune.clear_tune_memo()
    x = _points(b=1, seed0=40)[0]
    rng = np.random.default_rng(40)
    p1 = api.build_plan(x, k=K, bs=16, sb=4, backend="auto")
    p2 = api.build_plan(x, k=K, bs=16, sb=4, backend="auto",
                        values=lambda r, c, d2: rng.random(len(r)))
    assert p1.spec.shape_key == p2.spec.shape_key
    name1 = p1.resolve_backend()
    probes = []
    monkeypatch.setattr(autotune, "probe_backends",
                        lambda *a, **k: probes.append(1) or {})
    assert p2.resolve_backend() == name1     # memo hit, no probe
    assert not probes


# -- lockstep streaming -----------------------------------------------------


def _stream_batch():
    xs = _points(seed0=60)
    return api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr",
                                ell_slack=4, capacity=N + 64), xs


def test_lockstep_update_matches_single_plan_updates():
    pb, xs = _stream_batch()
    rng = np.random.default_rng(7)
    kills = [rng.choice(N, 8, replace=False) for _ in range(B)]
    arrivals = [feature_mixture(8, D, n_clusters=8, seed=100 + i)
                for i in range(B)]
    pb2 = pb.update(insert=arrivals, delete=kills)
    assert (pb2.n_alive == N).all()
    x = jnp.asarray(rng.standard_normal(pb2.capacity), jnp.float32)
    for i in range(B):
        single = api.update_plan(pb.member(i), insert=arrivals[i],
                                 delete=kills[i])
        xp = x[:single.n]
        np.testing.assert_allclose(
            np.asarray(pb2.member(i).matvec(x)[:single.n]),
            np.asarray(single.matvec(xp)), rtol=1e-4, atol=1e-4)


def test_update_keeps_spec_and_tuned_when_no_member_escalates():
    pb, _ = _stream_batch()
    pb.tuned[1] = "bsr"
    rng = np.random.default_rng(8)
    kills = [rng.choice(N, 4, replace=False) for _ in range(B)]
    pb2 = pb.delete(kills)
    assert pb2.spec == pb.spec           # compiled kernels survive
    assert pb2.tuned == pb.tuned
    assert all(st.tombstones == 1 for st in pb2.refresh_stats)


def test_update_escalation_is_per_plan():
    """One member outgrows the shared capacity; only the batch-level spec
    re-unifies — every member still matches its single-plan twin."""
    pb, _ = _stream_batch()
    rng = np.random.default_rng(9)
    big = feature_mixture(96, D, n_clusters=8, seed=300)   # > free slots
    arrivals = [big if i == 0 else None for i in range(B)]
    pb2 = pb.update(insert=arrivals)
    assert pb2.capacity > pb.capacity            # member 0 forced a grow
    assert pb2.n_alive[0] == N + 96 and (pb2.n_alive[1:] == N).all()
    assert pb2.refresh_stats[0].grows == 1
    assert pb2.refresh_stats[1].grows == 0       # others untouched tiers
    x = jnp.asarray(rng.standard_normal(pb2.capacity), jnp.float32)
    y = np.asarray(pb2.matvec(jnp.broadcast_to(x, (B, pb2.capacity))))
    for i in range(B):
        np.testing.assert_allclose(
            y[i], np.asarray(pb2.member(i).matvec(x)),
            rtol=1e-4, atol=1e-4)


def test_padding_holes_are_not_compaction_debris():
    """Regression: pow2 padding can leave a ragged member mostly holes
    (dead_frac far above max_dead_frac). The compaction trigger measures
    points lost since the live peak, so a small delete must stream
    through the tombstone tier — not full-rebuild (and get re-padded,
    and rebuild again) on every step."""
    sizes = [100, 200, 300]
    xs = [feature_mixture(n, D, n_clusters=4, seed=s)
          for s, n in enumerate(sizes)]
    pb = api.build_plan_batch(xs, k=K, bs=16, sb=4, backend="bsr",
                              ell_slack=4)
    assert pb.capacity == 512          # member 0 is ~80% holes
    rng = np.random.default_rng(13)
    kills = [rng.choice(n, 5, replace=False) for n in sizes]
    pb2 = pb.delete(kills)
    for i, st in enumerate(pb2.refresh_stats):
        assert st.compactions == 0, (i, st)
        assert st.tombstones == 1
    assert (pb2.n_alive == np.array(sizes) - 5).all()
    pb3 = pb2.delete([rng.choice(np.nonzero(pb2.member(i).alive)[0], 5,
                                 replace=False) for i in range(3)])
    assert all(st.compactions == 0 for st in pb3.refresh_stats)
    # real debris still triggers: lose >25% of member 2's peak vs its
    # 512-slot capacity -> (300 - 160)/512 > 0.25
    big_kill = rng.choice(np.nonzero(pb3.member(2).alive)[0], 140,
                          replace=False)
    pb4 = pb3.update(delete=[None, None, big_kill])
    assert pb4.refresh_stats[2].compactions == 1


def test_insert_skipped_members_get_none_indices():
    pb, _ = _stream_batch()
    arrivals0 = [feature_mixture(4, D, n_clusters=8, seed=600 + i)
                 for i in range(B)]
    pb1, ids1 = pb.insert(arrivals0)
    assert all(i is not None for i in ids1)
    pb2, ids2 = pb1.insert([arrivals0[0]] + [None] * (B - 1))
    assert ids2[0] is not None and ids2[0].shape == (4,)
    assert all(i is None for i in ids2[1:])   # not step-1 leftovers


def test_insert_returns_per_member_indices():
    pb, _ = _stream_batch()
    arrivals = [feature_mixture(5, D, n_clusters=8, seed=400 + i)
                for i in range(B)]
    pb2, ids = pb.insert(arrivals)
    assert len(ids) == B
    for i in range(B):
        assert ids[i].shape == (5,)
        assert np.asarray(pb2.member(i).alive)[ids[i]].all()


def test_batch_compact_is_fresh_build_per_member():
    """Each member goes through the bit-exact compact tier; the batch then
    re-pads to the shared capacity (hole spread = a rebucket), so the
    re-stacked members match a fresh build on the survivors to float
    associativity, with compaction telemetry recorded."""
    pb, _ = _stream_batch()
    rng = np.random.default_rng(11)
    kills = [rng.choice(N, 16, replace=False) for _ in range(B)]
    pb2 = pb.delete(kills).compact()
    assert all(st.compactions == 1 for st in pb2.refresh_stats)
    assert (pb2.n_alive == N - 16).all()
    for i in range(B):
        m = pb2.member(i)
        survivors = m.host.x[np.asarray(m.alive)]
        fresh = api.build_plan(survivors, config=m.config)
        x = jnp.asarray(rng.standard_normal(pb2.capacity), jnp.float32)
        live = np.asarray(m.alive)
        got = np.asarray(m.matvec(x))[live]
        want = np.asarray(fresh.matvec(x[live]))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# -- construction validation ------------------------------------------------


def test_from_plans_rejects_mixed_configs():
    xs = _points(b=2, seed0=80)
    p1 = api.build_plan(xs[0], k=K, bs=16, sb=4, backend="bsr")
    p2 = api.build_plan(xs[1], k=K + 2, bs=16, sb=4, backend="bsr")
    with pytest.raises(ValueError, match="share one PlanConfig"):
        api.PlanBatch.from_plans([p1, p2])


def test_build_plan_batch_rejects_static_values():
    with pytest.raises(ValueError, match="values"):
        api.build_plan_batch(_points(b=2), k=K, values=np.ones(3))


def test_profile_only_batch_has_no_matvec():
    pb = api.build_plan_batch(_points(b=2, seed0=90), k=K, bs=16, sb=4,
                              with_bsr=False)
    assert pb.spec.max_nbr is None
    with pytest.raises(ValueError, match="profile-only"):
        pb.matvec(jnp.zeros((2, pb.capacity)))


# -- checkpoint -------------------------------------------------------------


def test_batch_checkpoint_round_trip(tmp_path, batch, charges):
    from repro.checkpoint.ckpt import Checkpointer

    xs = batch.pad_charges(charges)
    y0 = np.asarray(batch.matvec(xs))
    ck = Checkpointer(tmp_path)
    ck.save_plan(3, batch, name="heads", blocking=True)
    pb2, step = ck.restore_plan(name="heads")
    assert step == 3 and pb2.batch == batch.batch
    assert pb2.spec == batch.spec
    np.testing.assert_array_equal(np.asarray(pb2.matvec(xs)), y0)
    with pytest.raises(ValueError, match="PlanBatch"):
        ck.restore_plan(name="heads", refresh_with=np.zeros((N, D)))


def test_batch_checkpoint_streams_after_restore(tmp_path):
    from repro.checkpoint.ckpt import Checkpointer

    pb, _ = _stream_batch()
    ck = Checkpointer(tmp_path)
    ck.save_plan(1, pb, name="stream", blocking=True)
    pb2, _ = ck.restore_plan(name="stream")
    arrivals = [feature_mixture(4, D, n_clusters=8, seed=500 + i)
                for i in range(B)]
    pb3, ids = pb2.insert(arrivals)
    assert (pb3.n_alive == N + 4).all()
    assert all(i.shape == (4,) for i in ids)


# -- registry satellites ----------------------------------------------------


def test_register_backend_duplicate_raises_unless_overwrite():
    @api.register_backend("dup_test")
    def _one(p, x, **kw):
        return x

    try:
        with pytest.raises(ValueError, match="already registered"):
            @api.register_backend("dup_test")
            def _two(p, x, **kw):
                return 2 * x

        @api.register_backend("dup_test", overwrite=True)
        def _three(p, x, **kw):
            return 3 * x

        assert registry._BACKENDS["dup_test"] is _three
        # re-registering the same callable is a no-op (module re-import)
        api.register_backend("dup_test", _three)
    finally:
        registry._BACKENDS.pop("dup_test", None)


def test_get_backend_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'bsr'"):
        api.get_backend("bssr")
    with pytest.raises(ValueError, match="registered:"):
        api.get_backend("no_such_thing_at_all")


# -- clusterkv wiring -------------------------------------------------------


def test_kv_plan_batch_orders_attention():
    from repro.configs.base import ClusterKVConfig
    from repro.core import clusterkv as ckv
    from repro.models import attention as attn

    key = jax.random.PRNGKey(0)
    b, hq, hkv, s, dh = 1, 4, 2, 128, 16
    k = jax.random.normal(key, (b, hkv, s, dh))
    q = jnp.repeat(k, hq // hkv, axis=1)
    v = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, s, dh))
    pb = ckv.kv_plan_batch(k, d=2)
    assert pb.batch == b * hkv and pb.capacity == s
    perm = ckv.plan_batch_perm(pb, (b, hkv))
    assert perm.shape == (b, hkv, s)
    # each lane is a true permutation of the keys
    assert (np.sort(np.asarray(perm[0, 0])) == np.arange(s)).all()
    pos = jnp.arange(s, dtype=jnp.int32)
    cfg = ClusterKVConfig(enabled=True, block_q=32, block_k=32,
                          blocks_per_query=s // 32, embed_dim=2)
    out = attn.clusterkv_attention(q, k, v, pos, pos, cfg, plan_batch=pb)
    # full selection through the plan-batch ordering is exact
    g = hq // hkv
    kk, vv = jnp.repeat(k, g, 1), jnp.repeat(v, g, 1)
    lg = jnp.einsum("bhsd,bhtd->bhst", q, kk) / np.sqrt(dh)
    lg = jnp.where(jnp.tril(jnp.ones((s, s), bool)), lg, -1e30)
    ref = jnp.einsum("bhst,bhtd->bhsd", jax.nn.softmax(lg, -1), vv)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_plan_batch_perm_wrong_lead():
    from repro.core import clusterkv as ckv

    pb = api.build_plan_batch(_points(b=2, seed0=95), k=K, bs=16, sb=4,
                              with_bsr=False)
    with pytest.raises(ValueError, match="members"):
        ckv.plan_batch_perm(pb, (3,))
